"""Auto-loaded (via PYTHONPATH=src) to install jax forward-compat
polyfills before any user code runs — subprocess test scripts use modern
jax names (jax.shard_map, jax.sharding.AxisType) before importing repro.

Python imports only the FIRST sitecustomize on sys.path, so this module
also chain-loads the next one (a venv's coverage bootstrap etc.) that it
would otherwise shadow.  Failures are reported to stderr, never raised —
interpreter startup must not break.
"""

import os
import sys

_SRC_DIR = os.path.dirname(os.path.abspath(__file__))

# NB: this imports jax at interpreter startup for every process carrying
# PYTHONPATH=src — the cost that buys subprocess scripts the modern jax
# names before they import repro.  Set REPRO_SKIP_COMPAT=1 to opt out for
# jax-free tooling.
if os.environ.get("REPRO_SKIP_COMPAT") != "1":
    try:
        from repro import _compat  # noqa: F401
    except Exception as e:  # pragma: no cover - never block startup
        sys.stderr.write(
            f"[repro] sitecustomize: jax compat polyfills not installed: "
            f"{e!r}\n"
        )


def _chain_load_next_sitecustomize():
    import importlib.machinery
    import importlib.util

    paths = [
        p for p in sys.path
        if os.path.abspath(p or os.getcwd()) != _SRC_DIR
    ]
    spec = importlib.machinery.PathFinder.find_spec("sitecustomize", paths)
    if spec is None or spec.origin is None:
        return
    if os.path.abspath(spec.origin) == os.path.abspath(__file__):
        return
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)


try:
    _chain_load_next_sitecustomize()
except Exception as e:  # pragma: no cover
    sys.stderr.write(f"[repro] sitecustomize: chain-load failed: {e!r}\n")
