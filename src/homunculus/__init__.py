"""``import homunculus`` — the package name the paper uses (Figure 3).

Thin facade over repro.core so Alchemy programs read exactly like the
paper's listings::

    import homunculus
    from homunculus.alchemy import DataLoader, Model, Platforms
    ...
    homunculus.generate(platform)
"""

from repro.core import alchemy
from repro.core.chaining import compile_dag, run_dag
from repro.core.dse import generate, search_model, GenerationResult

__all__ = [
    "alchemy", "generate", "search_model", "GenerationResult",
    "compile_dag", "run_dag",
]
