from repro.core.alchemy import *  # noqa: F401,F403
from repro.core.alchemy import (  # noqa: F401
    DataLoader, IOMap, IOMapper, Model, Par, Platform, Platforms, Seq,
)
