"""Pallas kernel: the WHOLE stateful pipeline in ONE launch.

``FlowKey -> RegisterUpdate -> feature-emit -> classifier`` previously
cost two dispatches: the flow-update kernel (kernels/flow_update) wrote
[B, W] feature rows back to HBM, and the fused-MLP kernel
(kernels/fused_mlp) read them again.  Here the post-update feature rows
feed the snapped-lane MLP matmuls *inside the same kernel body* — the
register table AND the classifier weight stack are co-resident in VMEM
for the launch, and only int32 verdicts (plus the updated table) cross
the kernel boundary.  This is the Taurus per-packet story (PAPERS.md):
stateful features and the ML decision as one dataplane pass.

The update phase is literally ``flow_update.kernel._flow_phase`` — the
segmented hybrid schedule (compacted lockstep rounds + doubly-compacted
unrolled drain) — so state and features are bit-identical to the scan
reference by the same per-slot decomposition.  The classifier phase
(``_suffix_eval``) reproduces the two-dispatch composition bit for bit:

  * the WindowStats readout is the same elementwise divide
    (``hist / max(count, 1)``) the stage applies, with ``mode`` folded
    statically (``all`` | ``hist`` | ``raw`` = no WindowStats);
  * the matmul chain runs at the SAME snapped lane the stateless
    lowering would pick (``fused_mlp.snap_lane`` over the same widths),
    so every dot has the same reduction length — pad lanes are exact
    zeros and per-row reductions round identically;
  * padded lanes >= num_classes mask to -inf before the in-kernel argmax,
    exactly as ``fused_mlp._classify_kernel``.

Feature rows never exist in HBM at all: the suffix consumes them in
SORTED (segment) order and the wrapper inverse-permutes only the [B]
int32 verdicts back to arrival order.

Grid: (1,) — the update phase is a sequential dependency chain; the
register table, batch operands and weight stack are all VMEM-resident
(``vmem_bytes`` is the feasibility claim).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flow_update.kernel import LANE, _flow_phase

READOUT_MODES = ("all", "hist", "raw")


def _suffix_eval(feats, w_stack, b_stack, *, head: int, mode: str,
                 width: int, n_layers: int, num_classes: int, lane: int):
    """Post-update feature rows -> int32 class ids, inside the kernel.

    feats [B, >=width] f32 (zero beyond ``width``); w_stack
    [L, lane, lane]; b_stack [L, lane].  Reproduces WindowStats.apply +
    fused_mlp's ``_classify_kernel`` bit for bit: same elementwise
    divide, same lane-padded dot shapes, same -inf argmax masking.
    Rows that are all zero (ragged padding / sentinels) classify to the
    bias chain's argmax — the engine slices those verdicts off."""
    if mode not in READOUT_MODES:
        raise KeyError(f"readout mode must be one of {READOUT_MODES}")
    denom = jnp.maximum(feats[:, :1], 1.0)      # counter 0 = pkt count
    if mode == "raw":
        z = feats[:, :width]
    elif mode == "hist":
        z = feats[:, head:width] / denom
    else:                                        # "all"
        z = jnp.concatenate(
            [feats[:, :head], feats[:, head:width] / denom], 1
        )
    z = jnp.pad(z, ((0, 0), (0, lane - z.shape[1])))
    h = z.astype(jnp.float32)
    for l in range(n_layers):   # static unroll: the whole DNN in-kernel
        w = w_stack[l].astype(jnp.float32)
        h = jnp.dot(h, w, preferred_element_type=jnp.float32)
        h = h + b_stack[l][None, :]
        if l < n_layers - 1:
            h = jnp.maximum(h, 0.0)
    lane_ids = jax.lax.broadcasted_iota(jnp.int32, h.shape, 1)
    h = jnp.where(lane_ids < num_classes, h, -jnp.inf)
    return jnp.argmax(h, axis=1).astype(jnp.int32)


def _kernel(keys_ref, regs_ref, pk_ref, upd_ref, bins_ref, valid_ref,
            rank_ref, segf_ref, segl_ref, segs_ref, dord_ref, dsid_ref,
            dsrc_ref, w_ref, b_ref, keys_out, regs_out, verd_out, *,
            n_counters: int, n_ewma: int, n_hists: int, alpha: float,
            head: int, mode: str, width: int, n_layers: int,
            num_classes: int, lane: int):
    keys = keys_ref[...][:, 0]
    regs = regs_ref[...]
    pk = pk_ref[...][:, 0]
    upd = upd_ref[...]
    bins = bins_ref[...][:, :max(n_hists, 1)]
    valid = valid_ref[...][:, 0]
    rank = rank_ref[...][:, 0]
    seg_first = segf_ref[...][:, 0]
    seg_len = segl_ref[...][:, 0]
    seg_slot = segs_ref[...][:, 0]
    drain_order = dord_ref[...][:, 0]
    drain_sid = dsid_ref[...][:, 0]
    deep_src = dsrc_ref[...][:, 0]

    keys2, regs2, feats = _flow_phase(
        keys, regs, pk, upd, bins, valid, rank, seg_first, seg_len,
        seg_slot, drain_order, drain_sid, deep_src,
        n_counters=n_counters, n_ewma=n_ewma, alpha=alpha,
    )
    verd = _suffix_eval(
        feats, w_ref[...], b_ref[...], head=head, mode=mode, width=width,
        n_layers=n_layers, num_classes=num_classes, lane=lane,
    )
    keys_out[...] = jnp.pad(
        keys2[:, None], ((0, 0), (0, keys_ref.shape[1] - 1))
    )
    regs_out[...] = regs2
    verd_out[...] = jnp.broadcast_to(verd[:, None], verd_out.shape)


@functools.partial(
    jax.jit,
    static_argnames=("n_counters", "n_ewma", "n_hists", "alpha", "head",
                     "mode", "width", "n_layers", "num_classes", "lane",
                     "interpret"),
)
def fused_flow_classify_padded(
    keys, regs, pkt_keys, upd, bins, valid, rank, seg_first, seg_len,
    seg_slot, drain_order, drain_sid, deep_src, w_stack, b_stack, *,
    n_counters: int, n_ewma: int, n_hists: int, alpha: float, head: int,
    mode: str, width: int, n_layers: int, num_classes: int, lane: int,
    interpret: bool = False,
):
    """Padded/segmented operands -> (keys' [S, kw], regs' [S, w_pad],
    verdicts [B_pad, kw] int32 in SORTED order, class id in column 0)."""
    S, w_pad = regs.shape
    B, k_w = pkt_keys.shape
    d_rows = deep_src.shape[0]
    full = lambda r, c: pl.BlockSpec((r, c), lambda i: (0, 0))
    narrow = full(B, k_w)
    return pl.pallas_call(
        functools.partial(
            _kernel, n_counters=n_counters, n_ewma=n_ewma,
            n_hists=n_hists, alpha=alpha, head=head, mode=mode,
            width=width, n_layers=n_layers, num_classes=num_classes,
            lane=lane,
        ),
        grid=(1,),
        in_specs=[
            full(S, k_w),                        # stored keys
            full(S, w_pad),                      # register rows
            narrow,                              # pkt keys
            full(B, upd.shape[1]),               # update vectors
            full(B, bins.shape[1]),              # hist columns
            narrow,                              # valid
            narrow,                              # rank
            narrow,                              # seg_first
            narrow,                              # seg_len
            narrow,                              # seg_slot
            narrow,                              # drain_order
            narrow,                              # drain_sid
            full(d_rows, k_w),                   # deep_src
            pl.BlockSpec((n_layers, lane, lane), lambda i: (0, 0, 0)),
            pl.BlockSpec((n_layers, lane), lambda i: (0, 0)),
        ],
        out_specs=[full(S, k_w), full(S, w_pad), narrow],
        out_shape=[
            jax.ShapeDtypeStruct((S, k_w), jnp.int32),
            jax.ShapeDtypeStruct((S, w_pad), jnp.float32),
            jax.ShapeDtypeStruct((B, k_w), jnp.int32),
        ],
        interpret=interpret,
    )(keys, regs, pkt_keys, upd, bins, valid, rank, seg_first, seg_len,
      seg_slot, drain_order, drain_sid, deep_src, w_stack, b_stack)


def vmem_bytes(n_slots: int, width: int, n_layers: int, lane: int,
               batch: int = 256) -> int:
    """Resident working set of the fused launch: the flow-update set plus
    the classifier weight stack and one activation tile (feasibility
    input; mirrors flow_update.vmem_bytes + fused_mlp.vmem_bytes)."""
    from repro.kernels.flow_update.kernel import vmem_bytes as flow_bytes

    weights = n_layers * (lane * lane + lane) * 4
    act = 2 * batch * lane * 4
    return flow_bytes(n_slots, width, batch) + weights + act
