"""Pallas kernel: the WHOLE stateful pipeline in ONE launch.

``FlowKey -> RegisterUpdate -> feature-emit -> classifier [-> Mitigate]``
previously cost two dispatches (flow-update kernel writing [B, W] feature
rows back to HBM, classifier kernel reading them again) plus a host-side
jnp scan for the action table.  Here the post-update feature rows feed the
classifier *inside the same kernel body* — the register table(s), the
classifier parameters AND the mitigation action table are co-resident in
VMEM for the launch, and only int32 verdicts (plus the updated tables)
cross the kernel boundary.  This is the Taurus per-packet story
(PAPERS.md): stateful features, the ML decision and the enforcement
action as one dataplane pass.

The update phase is literally ``flow_update.kernel._flow_phase`` — the
segmented hybrid schedule (compacted lockstep rounds + doubly-compacted
unrolled drain) — so state and features are bit-identical to the scan
reference by the same per-slot decomposition.  The launch is described by
a static ``Plan``:

  * ``Plan.tables`` — one ``TablePlan`` per flow table.  A single-table
    launch feeds the suffix in SORTED (segment) order, exactly the PR-6
    form; a multi-table launch runs one ``_flow_phase`` per table (each
    with its own slot segmentation), gathers every table's feature rows
    back to ARRIVAL order in-kernel and concatenates the per-table
    readouts into one classifier input.
  * ``Plan.suffix`` — the classifier form.  ``"mlp"`` is the snapped-lane
    matmul chain (same dot shapes as the stateless fused_mlp lowering);
    ``"mat"`` replays ``mat_lut``'s compare-and-count searchsorted +
    one-hot-matmul MATs on the readout rows; ``"centroid"`` computes the
    per-centroid squared distances (zero-padded lanes contribute exact
    zeros) with the masked arg-reduce and LabelMap rewrite in-kernel.
    ``suffix_readout``/``suffix_verdicts`` are plain-jnp and shared with
    the wrapper's reference fallback, so every path computes identical
    bits.
  * ``Plan.mit`` — the folded action table.  Unlike the flow phase, the
    [hits, since] scan admits a CLOSED FORM over each maximal same-key
    run of a slot chain (``_mitigation_phase``): hits is a segmented
    prefix sum of attack indicators, marked is therefore monotone within
    a run, and since is the marked-predecessor count — so the whole
    phase is ONE loop-free vectorized pass (cumsums + gathers), no
    lockstep rounds, no drain.  The drop / rate-limit decision is one
    extra masked lane over the int32 verdicts.  When the action table
    has the SAME slot count as a single flow table, ``hash(key) &
    (S-1)`` gives identical slots, so the launch reuses the flow table's
    segmentation operands wholesale (``MitPlan.shared_seg``: no second
    sort, no verdict permutation, two extra operands instead of seven).
    Every quantity equals the arrival-order scan's value exactly
    (integer-valued f32, exact below 2**24 like the LabelMap matvec), so
    the result is bit-identical to
    ``flowstate.mitigation.mitigate_update``.

Grid: (1,) — the update phases are sequential dependency chains; every
operand is a full VMEM-resident block (``vmem_bytes`` is the feasibility
claim).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flow_update.kernel import LANE, _flow_phase

READOUT_MODES = ("all", "hist", "raw")
SUFFIX_KINDS = ("mlp", "mat", "centroid")

# verdict sentinel for a dropped packet (flowstate.mitigation.MITIGATED)
_MITIGATED = -1


class TablePlan(NamedTuple):
    """Static description of one flow table's update + readout."""

    n_counters: int
    n_ewma: int
    n_hists: int
    alpha: float
    width: int                 # true register width (pre-padding)
    mode: str                  # readout: all | hist | raw


class SuffixPlan(NamedTuple):
    """Static description of the in-kernel classifier."""

    kind: str                  # mlp | mat | centroid
    num_classes: int           # score lanes before any LabelMap rewrite
    n_layers: int = 0          # mlp: layer count
    lane: int = 0              # mlp: snapped lane
    n_features: int = 0        # mat: real (unpadded) feature count
    use_min: bool = False      # mat/centroid: argmin vs argmax
    n_centroids: int = 0       # centroid: real centroid count
    feature_idx: tuple = ()    # centroid: optional static FeatureSelect


class MitPlan(NamedTuple):
    """Static description of the folded mitigation action table."""

    threshold: int
    keep_every: int
    attack_class: int
    drop: bool                 # mode == "drop" (else rate_limit)
    shared_seg: bool = False   # action slots == flow slots: reuse the
                               # flow table's segmentation operands


class Plan(NamedTuple):
    """The whole launch, statically: tables, classifier, action table."""

    tables: tuple              # of TablePlan
    suffix: SuffixPlan
    mit: MitPlan | None = None


# operand count per suffix kind (see the layout walked by _serve_kernel)
N_SUFFIX_OPS = {"mlp": 2, "mat": 3, "centroid": 2}


def n_mit_ops(mp: MitPlan) -> int:
    """Mitigation block operand count.  The shared-segmentation fast path
    ships only the table pair (mit_keys, mit_regs); the general form adds
    its own segmentation + the verdict-order gather: pk, valid, rank,
    seg_slot, from_v."""
    return 2 if mp.shared_seg else 7


# ------------------------------------------------------- suffix evaluation
#
# Plain-jnp, shared bit-for-bit by the kernel body and the wrapper's
# reference fallback (ops.py) — the over-envelope fallback is then a
# pure schedule choice.


def suffix_readout(feats, tp: TablePlan):
    """Post-update feature rows -> model-ready readout (WindowStats.apply
    folded statically: same elementwise divide, ``mode`` in
    ``READOUT_MODES`` with ``"raw"`` = no WindowStats stage)."""
    if tp.mode not in READOUT_MODES:
        raise KeyError(f"readout mode must be one of {READOUT_MODES}")
    denom = jnp.maximum(feats[:, :1], 1.0)      # counter 0 = pkt count
    head = tp.n_counters + tp.n_ewma
    if tp.mode == "raw":
        return feats[:, :tp.width]
    if tp.mode == "hist":
        return feats[:, head:tp.width] / denom
    return jnp.concatenate(
        [feats[:, :head], feats[:, head:tp.width] / denom], 1
    )


def _label_rewrite(ids, lmap):
    """LabelMap as a one-hot matvec (exact for int values < 2**24) — the
    same gather-as-matmul idiom as ``mat_lut._kernel``."""
    n_pkt = ids.shape[0]
    k_pad = lmap.shape[1]
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (n_pkt, k_pad), 1)
    onehot = (k_iota == ids[:, None]).astype(jnp.float32)
    return jnp.dot(
        onehot, lmap[0].astype(jnp.float32)[:, None],
        preferred_element_type=jnp.float32,
    )[:, 0].astype(jnp.int32)


def _arg_reduce(scores, n_real: int, use_min: bool):
    """Mask lanes >= ``n_real`` to -/+inf, then argmin/argmax (ties to the
    lowest index, matching the interpreter's Reduce)."""
    lane_ids = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    if use_min:
        scores = jnp.where(lane_ids < n_real, scores, jnp.inf)
        return jnp.argmin(scores, axis=1).astype(jnp.int32)
    scores = jnp.where(lane_ids < n_real, scores, -jnp.inf)
    return jnp.argmax(scores, axis=1).astype(jnp.int32)


def suffix_verdicts(z, arrays: tuple, sp: SuffixPlan):
    """Readout rows [B, n_in] -> int32 class ids, per ``sp.kind``.

    ``arrays`` are the PRE-PADDED suffix parameters (packed once at
    lowering time — see ``pallas_backend.lower_stateful_fused``):

      * mlp:      (w_stack [L, lane, lane], b_stack [L, lane]) — the
        snapped-lane matmul chain with -inf argmax masking, identical to
        ``fused_mlp._classify_kernel``;
      * mat:      (edges [F8, E_pad] +inf-padded, tables [F8, BINS, C_pad]
        zero-padded, lmap [1, K_pad]) — per-feature compare-and-count
        searchsorted + one-hot-matmul LUT gathers, identical to
        ``mat_lut._kernel``;
      * centroid: (cent [K8, F_pad] zero-padded, lmap [1, K_pad]) —
        per-centroid squared distances (zero pad lanes add exact zeros),
        +inf-masked arg-reduce, LabelMap rewrite.

    Rows that are all zero (ragged padding / sentinels) classify to some
    fixed class — the engine slices those verdicts off."""
    z = z.astype(jnp.float32)
    n_pkt = z.shape[0]
    if sp.kind == "mlp":
        w_stack, b_stack = arrays
        h = jnp.pad(z, ((0, 0), (0, sp.lane - z.shape[1])))
        for l in range(sp.n_layers):     # static unroll: whole DNN in-kernel
            w = w_stack[l].astype(jnp.float32)
            h = jnp.dot(h, w, preferred_element_type=jnp.float32)
            h = h + b_stack[l][None, :]
            if l < sp.n_layers - 1:
                h = jnp.maximum(h, 0.0)
        lane_ids = jax.lax.broadcasted_iota(jnp.int32, h.shape, 1)
        h = jnp.where(lane_ids < sp.num_classes, h, -jnp.inf)
        return jnp.argmax(h, axis=1).astype(jnp.int32)
    if sp.kind == "mat":
        edges, tables, lmap = arrays
        bins_cap = tables.shape[1]
        bin_iota = jax.lax.broadcasted_iota(jnp.int32, (n_pkt, bins_cap), 1)
        scores = jnp.zeros((n_pkt, tables.shape[2]), jnp.float32)
        for f in range(sp.n_features):   # static unroll: one MAT per feature
            col = z[:, f][:, None]
            e = edges[f][None, :]
            # searchsorted(side='left'): bucket = #edges strictly below
            bucket = jnp.sum((col > e).astype(jnp.int32), axis=1)
            onehot = (bin_iota == bucket[:, None]).astype(jnp.float32)
            scores = scores + jnp.dot(
                onehot, tables[f].astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
        ids = _arg_reduce(scores, sp.num_classes, sp.use_min)
        return _label_rewrite(ids, lmap)
    if sp.kind == "centroid":
        cent, lmap = arrays
        if sp.feature_idx:               # folded FeatureSelect: static gather
            z = jnp.concatenate([z[:, i:i + 1] for i in sp.feature_idx], 1)
        zp = jnp.pad(z, ((0, 0), (0, cent.shape[1] - z.shape[1])))
        dists = []
        for k in range(sp.n_centroids):  # static unroll: one centroid each
            d = jnp.sum((zp - cent[k][None, :]) ** 2, axis=1)
            dists.append(d[:, None])
        scores = jnp.concatenate(dists, axis=1)
        k_pad = lmap.shape[1]
        fill = jnp.inf if sp.use_min else -jnp.inf
        scores = jnp.pad(scores, ((0, 0), (0, k_pad - sp.n_centroids)),
                         constant_values=fill)
        ids = _arg_reduce(scores, sp.n_centroids, sp.use_min)
        return _label_rewrite(ids, lmap)
    raise KeyError(f"suffix kind must be one of {SUFFIX_KINDS}")


# ------------------------------------------------------- mitigation phase


def _mitigation_phase(mkeys, mregs, pk, vd, valid, rank, seg_slot,
                      mp: MitPlan):
    """The action-table update as ONE loop-free vectorized pass.

    The arrival-order scan of ``flowstate.mitigation.mitigate_update``
    factorizes over maximal same-key RUNS of each slot chain (a mid-chain
    key change is an evict-on-collision reset — a fresh row, exactly a
    run head).  Within a run the state admits a closed form:

      * ``hits`` before packet i is the run head's carry-in plus the
        prefix count of attack verdicts — a segmented cumsum;
      * ``marked`` (``hits >= threshold``) is therefore MONOTONE within
        the run, so the consecutive-marked streak feeding ``since`` is
        just the count of marked predecessors in the run (plus the
        head's carry-in when the head itself is marked).

    Every quantity equals the sequential scan's value as an
    integer-valued f32 (exact below 2**24, the same bound as the
    LabelMap one-hot matvec), so verdicts and final state are
    bit-identical to the reference — with no lockstep rounds and no
    drain, just cumsums, gathers and two scatters.

    mkeys [Sm] i32; mregs [Sm, Wt] f32 (columns 0/1 live, rest zero
    padding); batch operands are [B_pad]-sized and SORTED by MITIGATION
    slot (stable, so per-slot arrival order is preserved) with trailing
    sentinels (``valid == 0``).  ``vd`` carries each packet's classifier
    verdict in the same sorted order; ``seg_slot`` holds each segment's
    slot at its segment-id row (the ``segment_batch`` convention).

    -> (mkeys' [Sm], mregs' [Sm, Wt], out_verdicts [B_pad] sorted order;
    untouched rows pass their classifier verdict through)."""
    Sm, Wt = mregs.shape
    B = pk.shape[0]
    live = valid != 0
    thr = jnp.float32(mp.threshold)
    keep = jnp.float32(mp.keep_every)
    atk = jnp.int32(mp.attack_class)
    pos = jnp.arange(B, dtype=jnp.int32)

    is_head = live & (rank == 0)                 # chain heads
    seg_id = jnp.cumsum(is_head.astype(jnp.int32)) - 1
    slot = seg_slot[jnp.maximum(seg_id, 0)]      # slot per sorted row

    prev_pk = jnp.concatenate([pk[:1], pk[:-1]])
    run_head = live & (is_head | (pk != prev_pk))
    hidx = jax.lax.cummax(jnp.where(run_head, pos, 0))

    # table state carries in at CHAIN heads only; a key mismatch there —
    # and every mid-chain run head — is a fresh (evicted) row
    carry = is_head & (mkeys[slot] == pk)
    h0 = jnp.where(carry, mregs[slot, 0], 0.0)
    s0 = jnp.where(carry, mregs[slot, 1], 0.0)

    a = (vd == atk).astype(jnp.float32)          # attack indicator
    ecs = jnp.cumsum(a) - a                      # exclusive prefix count
    h_before = h0[hidx] + (ecs - ecs[hidx])      # hits BEFORE each packet
    m = h_before >= thr                          # marked BEFORE each packet
    mf = m.astype(jnp.float32)
    ems = jnp.cumsum(mf) - mf
    m_run = ems - ems[hidx]                      # marked predecessors in run
    since_before = m_run + jnp.where(m[hidx], s0[hidx], 0.0)

    # the state BEFORE a packet decides its fate (mitigation contract)
    if mp.drop:
        drop = m
    else:
        # pass every keep_every-th packet of a marked flow through
        drop = m & (jnp.mod(since_before, keep) != 0.0)
    out = jnp.where(live & drop, jnp.int32(_MITIGATED), vd)

    # the last live packet of each chain writes the row home
    nxt_rank = jnp.concatenate([rank[1:], jnp.zeros((1,), rank.dtype)])
    nxt_live = jnp.concatenate([live[1:], jnp.zeros((1,), bool)])
    tail = live & (~nxt_live | (nxt_rank == 0))
    hits1 = h_before + a
    since1 = jnp.where(m, since_before + 1.0, 0.0)
    colw = jax.lax.broadcasted_iota(jnp.int32, (B, Wt), 1)
    new = jnp.where(colw == 0, hits1[:, None],
                    jnp.where(colw == 1, since1[:, None], 0.0))
    tgt = jnp.where(tail, slot, Sm)
    mkeys = mkeys.at[tgt].set(pk, mode="drop")
    mregs = mregs.at[tgt].set(new, mode="drop")
    return mkeys, mregs, out


# ------------------------------------------------------------ kernel body


def _serve_kernel(*refs, plan: Plan):
    """One launch: per-table flow phases, suffix classify, optional
    mitigation phase.  ``refs`` = input refs (layout below) ++ output
    refs.  Narrow int operands keep column 0 live only.

    Input layout: per table 13 flow-phase operands (as
    ``flow_update._kernel``); then, multi-table only, one arrival-gather
    index per table (``inv``); then the suffix parameter arrays
    (``N_SUFFIX_OPS[kind]`` of them); then, mitigated only, the
    ``n_mit_ops(plan.mit)`` mitigation operands — just (mit_keys,
    mit_regs) on the shared-segmentation fast path (the flow table's
    operands are reused wholesale), else the table pair + own
    segmentation + ``from_v``, the verdict-order gather."""
    nt = len(plan.tables)
    n_in = (13 * nt + (nt if nt > 1 else 0)
            + N_SUFFIX_OPS[plan.suffix.kind]
            + (n_mit_ops(plan.mit) if plan.mit is not None else 0))
    ins, outs = refs[:n_in], refs[n_in:]

    cur = 0
    new_tabs = []
    feats_list = []
    t0_seg = None
    for tp in plan.tables:
        (kr, rr, pkr, ur, br, vr, rkr, sfr, slr, ssr,
         dor, dsr, dcr) = ins[cur:cur + 13]
        cur += 13
        if t0_seg is None:
            # retained for the mitigation shared-segmentation fast path
            t0_seg = (pkr, vr, rkr, ssr)
        k2, r2, feats = _flow_phase(
            kr[...][:, 0], rr[...], pkr[...][:, 0], ur[...],
            br[...][:, :max(tp.n_hists, 1)], vr[...][:, 0],
            rkr[...][:, 0], sfr[...][:, 0], slr[...][:, 0],
            ssr[...][:, 0], dor[...][:, 0], dsr[...][:, 0],
            dcr[...][:, 0],
            n_counters=tp.n_counters, n_ewma=tp.n_ewma, alpha=tp.alpha,
        )
        new_tabs.append((k2, r2))
        feats_list.append(feats)

    if nt > 1:
        # gather every table's feature rows to ARRIVAL order and feed the
        # shared suffix the concatenated readouts; verdicts come out in
        # arrival order directly
        invs = ins[cur:cur + nt]
        cur += nt
        zs = [
            suffix_readout(feats_list[t][invs[t][...][:, 0]],
                           plan.tables[t])
            for t in range(nt)
        ]
        z = jnp.concatenate(zs, axis=1)
    else:
        z = suffix_readout(feats_list[0], plan.tables[0])

    n_sfx = N_SUFFIX_OPS[plan.suffix.kind]
    s_arrays = tuple(r[...] for r in ins[cur:cur + n_sfx])
    cur += n_sfx
    verd = suffix_verdicts(z, s_arrays, plan.suffix)

    if plan.mit is not None:
        if plan.mit.shared_seg:
            # action slots == flow slots: the detection segmentation IS
            # the mitigation segmentation and the suffix's sorted order
            # is already mitigation order — no gather, two operands
            mkr, mrr = ins[cur:cur + 2]
            pkr, vr, rkr, ssr = t0_seg
            mk2, mr2, final = _mitigation_phase(
                mkr[...][:, 0], mrr[...], pkr[...][:, 0], verd,
                vr[...][:, 0], rkr[...][:, 0], ssr[...][:, 0],
                plan.mit,
            )
        else:
            (mkr, mrr, mpkr, mvr, mrkr, mssr,
             mfvr) = ins[cur:cur + 7]
            # verdicts permute from the suffix's order (sorted-by-
            # detection-slot, or arrival for multi-table) into
            # mitigation-sorted order
            vd_m = verd[mfvr[...][:, 0]]
            mk2, mr2, final = _mitigation_phase(
                mkr[...][:, 0], mrr[...], mpkr[...][:, 0], vd_m,
                mvr[...][:, 0], mrkr[...][:, 0], mssr[...][:, 0],
                plan.mit,
            )
    else:
        final = verd

    oc = 0
    for k2, r2 in new_tabs:
        ko, ro = outs[oc:oc + 2]
        oc += 2
        ko[...] = jnp.pad(k2[:, None], ((0, 0), (0, ko.shape[1] - 1)))
        ro[...] = r2
    if plan.mit is not None:
        mko, mro = outs[oc:oc + 2]
        oc += 2
        mko[...] = jnp.pad(mk2[:, None], ((0, 0), (0, mko.shape[1] - 1)))
        mro[...] = mr2
    vo = outs[oc]
    vo[...] = jnp.broadcast_to(final[:, None], vo.shape)


@functools.partial(jax.jit, static_argnames=("plan", "interpret"))
def fused_flow_serve_padded(*ops, plan: Plan, interpret: bool = False):
    """Padded/segmented operands (layout in ``_serve_kernel``) -> flat
    outputs: per table (keys' [S, kw], regs' [S, w_pad]), then the
    mitigated table pair when ``plan.mit``, then verdicts [B_pad, kw]
    int32 (class id in column 0) in the suffix's order — SORTED for one
    table, ARRIVAL for multi-table, MITIGATION-SORTED when mitigated."""
    nt = len(plan.tables)
    tile = ops[0].shape[1]
    b_pad = ops[2].shape[0]

    def full(arr):
        nd = arr.ndim
        return pl.BlockSpec(arr.shape, lambda i, _n=nd: (0,) * _n)

    out_specs, out_shape = [], []

    def add_out(shape, dtype):
        out_specs.append(pl.BlockSpec(shape, lambda i, _n=len(shape):
                                      (0,) * _n))
        out_shape.append(jax.ShapeDtypeStruct(shape, dtype))

    for t in range(nt):
        s_t = ops[13 * t].shape[0]
        w_pad_t = ops[13 * t + 1].shape[1]
        add_out((s_t, tile), jnp.int32)
        add_out((s_t, w_pad_t), jnp.float32)
    if plan.mit is not None:
        m_off = (13 * nt + (nt if nt > 1 else 0)
                 + N_SUFFIX_OPS[plan.suffix.kind])
        sm = ops[m_off].shape[0]
        wt = ops[m_off + 1].shape[1]
        add_out((sm, tile), jnp.int32)
        add_out((sm, wt), jnp.float32)
    add_out((b_pad, tile), jnp.int32)

    return pl.pallas_call(
        functools.partial(_serve_kernel, plan=plan),
        grid=(1,),
        in_specs=[full(a) for a in ops],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*ops)


def fused_flow_classify_padded(
    keys, regs, pkt_keys, upd, bins, valid, rank, seg_first, seg_len,
    seg_slot, drain_order, drain_sid, deep_src, w_stack, b_stack, *,
    n_counters: int, n_ewma: int, n_hists: int, alpha: float, head: int,
    mode: str, width: int, n_layers: int, num_classes: int, lane: int,
    interpret: bool = False,
):
    """The PR-6 single-table MLP form, kept as a thin wrapper over the
    ``Plan``-driven launcher: -> (keys' [S, kw], regs' [S, w_pad],
    verdicts [B_pad, kw] int32 in SORTED order, class id in column 0)."""
    del head
    plan = Plan(
        tables=(TablePlan(n_counters, n_ewma, n_hists, alpha, width, mode),),
        suffix=SuffixPlan("mlp", num_classes, n_layers=n_layers, lane=lane),
    )
    return fused_flow_serve_padded(
        keys, regs, pkt_keys, upd, bins, valid, rank, seg_first, seg_len,
        seg_slot, drain_order, drain_sid, deep_src, w_stack, b_stack,
        plan=plan, interpret=interpret,
    )


def vmem_bytes(n_slots: int, width: int, n_layers: int, lane: int,
               batch: int = 256, *, suffix: str = "mlp",
               n_features: int = 0, n_bins: int = 0, num_classes: int = 0,
               n_centroids: int = 0, extra_tables: tuple = (),
               mit_slots: int = 0) -> int:
    """Resident working set of the fused launch (feasibility input):
    flow-update set(s) plus the suffix parameters, one activation tile,
    and — when mitigation is folded in — the action table with its own
    scheduling operands.  ``extra_tables`` lists additional flow tables
    as (n_slots, width) pairs for the multi-table form."""
    from repro.kernels.flow_update.kernel import vmem_bytes as flow_bytes

    total = flow_bytes(n_slots, width, batch)
    for s2, w2 in extra_tables:
        total += flow_bytes(s2, w2, batch)
    if suffix == "mlp":
        total += n_layers * (lane * lane + lane) * 4 + 2 * batch * lane * 4
    elif suffix == "mat":
        total += n_features * (n_bins * max(num_classes, 1) + n_bins) * 4
        total += 2 * batch * max(n_features, num_classes, 1) * 4
    elif suffix == "centroid":
        total += n_centroids * max(lane, 1) * 4 + 2 * batch * lane * 4
    if mit_slots:
        # [hits, since] + key per slot, plus (worst case, non-shared
        # segmentation) the 7 per-batch mitigation operand columns
        total += mit_slots * 3 * 4 + batch * 4 * 7
    return total
