from repro.kernels.fused_flow.kernel import (
    LANE,
    READOUT_MODES,
    SUFFIX_KINDS,
    MitPlan,
    Plan,
    SuffixPlan,
    TablePlan,
    fused_flow_classify_padded,
    fused_flow_serve_padded,
    suffix_readout,
    suffix_verdicts,
    vmem_bytes,
)
from repro.kernels.fused_flow.ops import fused_flow_classify, fused_flow_serve
