from repro.kernels.fused_flow.kernel import (
    LANE,
    READOUT_MODES,
    fused_flow_classify_padded,
    vmem_bytes,
)
from repro.kernels.fused_flow.ops import fused_flow_classify
