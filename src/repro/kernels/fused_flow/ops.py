"""Public op: the whole stateful pipeline as ONE fused kernel launch.

``fused_flow_serve(tables, valid, ...)`` segments the batch by slot once
per flow table (the shared ``flow_update.segment_batch`` prelude) — plus
once more over the action table's own slot space when mitigation is
folded in with a slot count different from the flow table's (same count:
the flow segmentation is reused wholesale, ``MitPlan.shared_seg``) —
launches the ``Plan``-driven fused Pallas kernel (interpret=True on CPU)
and restores the [B] int32 verdicts to arrival order.  This is the executable artifact
``core.pallas_backend.lower_stateful_fused`` emits for a fused-eligible
stateful pipeline — the backend string ``"pallas-fused-flow"`` means
exactly this launch is serving.  ``fused_flow_classify`` keeps the PR-6
single-table MLP signature as a thin wrapper.

Suffix parameters arrive PRE-PADDED (lane-snapped MLP stacks, +inf-padded
MAT edges, zero-padded tables/centroids): packing happens once at
lowering time, not per batch.

Bit-identity contract: state, features and verdicts equal the split
composition (flow_update + WindowStats.apply + classifier [+
mitigate_update]) bit for bit — the update phases are the shared
``_flow_phase``/``_mitigation_phase`` schedules and the classifier phase
shares ``suffix_readout``/``suffix_verdicts`` with the reference path
(MAT parity quantization-bounded per the lowering contract).  The kernel
serves every in-envelope batch — the doubly-compacted drain walks deep
chains at a small fixed per-packet cost, measured well under the
reference walk even on a fully-degenerate single-chain batch, so there
is no drain-routing ``lax.cond`` (``telemetry.flow_health`` still flags
drain-heavy batches as a traffic-shape signal).  Outside the kernel
envelope (table over VMEM bounds, B == 0) the op falls back to the jnp
scan reference + the same suffix evaluation; every path computes
identical bits.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flow_update.ops import (
    MAX_HISTS,
    MAX_SLOTS,
    MAX_WIDTH,
    _snap,
    pack_segmented_operands,
    segment_batch,
)
from repro.kernels.flow_update.ref import flow_update_ref, hash_slot
from repro.kernels.fused_flow.kernel import (
    LANE,
    MitPlan,
    Plan,
    SuffixPlan,
    TablePlan,
    fused_flow_serve_padded,
    suffix_readout,
    suffix_verdicts,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _icol(vals, b: int, tile: int, fill: int = 0):
    """[b] int values -> [b + tile, tile] int32 with column 0 live and
    ``fill`` everywhere else (the narrow-operand sentinel convention)."""
    out = jnp.full((b + tile, tile), fill, jnp.int32)
    return out.at[:b, 0].set(vals)


def _pack_mit_table(mit_keys, mit_regs, *, tile: int):
    """Pad the action table pair to kernel tile shapes."""
    Sm = mit_keys.shape[0]
    return (
        jnp.zeros((Sm, tile), jnp.int32).at[:, 0].set(mit_keys),
        jnp.pad(mit_regs, ((0, 0), (0, tile - mit_regs.shape[1]))),
    )


def _pack_mitigation_operands(mseg, mit_keys, mit_regs, pkt_keys, valid,
                              from_v, *, tile: int):
    """Permute the batch into MITIGATION-slot-sorted order and pad the
    action table + its segmentation to kernel tile shapes (the loop-free
    closed-form phase needs only rank + seg_slot, no lockstep/drain
    bookkeeping).  ``from_v[i]`` maps the packet at mitigation-sorted
    position i to its row in the suffix's verdict array; sentinels point
    at a sentinel verdict row."""
    B = pkt_keys.shape[0]
    o = mseg.order
    icol = functools.partial(_icol, b=B, tile=tile)
    return _pack_mit_table(mit_keys, mit_regs, tile=tile) + (
        icol(pkt_keys[o]),
        icol(valid[o]),
        icol(mseg.rank),
        icol(mseg.seg_slot),
        icol(from_v, fill=B),
    )


def fused_flow_serve(
    tables,                # seq of (keys [S], regs [S, W], pkt_keys [B],
                           #         upd [B, C+E], bins [B, H])
    valid,                 # [B] int-ish; 0 = padding row, never applied
    table_plans,           # seq of kernel.TablePlan (one per flow table)
    suffix_plan,           # kernel.SuffixPlan
    suffix_arrays,         # tuple of PRE-PADDED suffix parameter arrays
    mitigation=None,       # (mit_keys [Sm], mit_regs [Sm, 2],
                           #  flowstate.mitigation.MitigationSpec)
    interpret: bool | None = None,
):
    """-> flat tuple: per table (keys' [S], regs' [S, W]), then
    (mit_keys', mit_regs') when mitigated, then verdicts [B] int32 in
    ARRIVAL order — one kernel launch.

    Rows with ``valid == 0`` never touch any table and keep meaningless
    verdicts (the engine slices them off).  Bit-identical to the split
    composition; see the flow-state and mitigation contracts in
    docs/pipeline_ir.md."""
    if interpret is None:
        interpret = not _on_tpu()
    tables = [
        (jnp.asarray(k, jnp.int32), jnp.asarray(r, jnp.float32),
         jnp.asarray(pk, jnp.int32), jnp.asarray(u, jnp.float32),
         jnp.asarray(b, jnp.int32))
        for (k, r, pk, u, b) in tables
    ]
    table_plans = tuple(table_plans)
    suffix_arrays = tuple(jnp.asarray(a) for a in suffix_arrays)
    valid = jnp.asarray(valid, jnp.int32)
    nt = len(tables)
    B = int(tables[0][2].shape[0])

    if mitigation is not None:
        from repro.flowstate.mitigation import mitigate_update

        mit_keys = jnp.asarray(mitigation[0], jnp.int32)
        mit_regs = jnp.asarray(mitigation[1], jnp.float32)
        mspec = mitigation[2]
        # same slot count as a single flow table -> hash(key) & (S-1)
        # gives identical slots, so the flow segmentation is reused
        # wholesale (no second sort, no verdict permutation)
        shared = (len(tables) == 1
                  and int(mit_keys.shape[0]) == int(tables[0][0].shape[0]))
        mit_plan = MitPlan(mspec.threshold, mspec.keep_every,
                           mspec.attack_class, mspec.mode == "drop",
                           shared_seg=shared)
    else:
        mit_plan = None

    def reference_full():
        outs = []
        zs = []
        for (k, r, pk, u, b), tp in zip(tables, table_plans):
            k2, r2, feats = flow_update_ref(
                k, r, pk, u, b, valid,
                n_counters=tp.n_counters, n_ewma=tp.n_ewma, alpha=tp.alpha,
            )
            outs += [k2, r2]
            zs.append(suffix_readout(feats, tp))
        z = jnp.concatenate(zs, 1) if nt > 1 else zs[0]
        verd = suffix_verdicts(z, suffix_arrays, suffix_plan)
        if mit_plan is not None:
            mk2, mr2, verd = mitigate_update(
                mit_keys, mit_regs, tables[0][2], verd, valid, spec=mspec)
            outs += [mk2, mr2]
        return tuple(outs) + (verd,)

    over = any(
        int(r.shape[0]) > MAX_SLOTS or int(r.shape[1]) > MAX_WIDTH
        or int(b.shape[1] if b.ndim == 2 else 0) > MAX_HISTS
        for (_, r, _, _, b) in tables
    )
    if mit_plan is not None and int(mit_keys.shape[0]) > MAX_SLOTS:
        over = True
    if over or B == 0:
        return reference_full()

    # CPU interpret mode snaps pads to 8-wide tiles; TPU pads the last
    # dim to the full 128 lane.
    tile = 8 if interpret else LANE
    segs = [
        segment_batch(hash_slot(pk, int(k.shape[0])), valid,
                      int(k.shape[0]))
        for (k, _, pk, _, _) in tables
    ]
    if mit_plan is not None:
        mseg = (segs[0] if mit_plan.shared_seg else segment_batch(
            hash_slot(tables[0][2], int(mit_keys.shape[0])), valid,
            int(mit_keys.shape[0])))
    plan = Plan(tables=table_plans, suffix=suffix_plan, mit=mit_plan)

    def launch():
        flat = []
        for (k, r, pk, u, b), tp, seg in zip(tables, table_plans, segs):
            H = int(b.shape[1]) if b.ndim == 2 else 0
            flat += list(pack_segmented_operands(
                seg, k, r, pk, u, b, valid, tile=tile,
                w_pad=_snap(int(r.shape[1]), tile),
                u_pad=_snap(int(u.shape[1]), tile),
                h_pad=_snap(H, tile) if not interpret else max(H, 1),
            ))
        if nt > 1:
            # arrival-gather index per table: suffix rows re-assemble in
            # arrival order inside the kernel
            flat += [_icol(seg.inv, B, tile, fill=B) for seg in segs]
        flat += list(suffix_arrays)
        if mit_plan is not None:
            if mit_plan.shared_seg:
                flat += list(_pack_mit_table(mit_keys, mit_regs,
                                             tile=tile))
            else:
                from_v = (mseg.order if nt > 1
                          else segs[0].inv[mseg.order])
                flat += list(_pack_mitigation_operands(
                    mseg, mit_keys, mit_regs, tables[0][2], valid,
                    from_v, tile=tile,
                ))
        res = fused_flow_serve_padded(*flat, plan=plan,
                                      interpret=interpret)
        outs = []
        i = 0
        for (_, r, _, _, _) in tables:
            outs += [res[i][:, 0], res[i + 1][:, :int(r.shape[1])]]
            i += 2
        if mit_plan is not None:
            outs += [res[i][:, 0], res[i + 1][:, :mit_regs.shape[1]]]
            i += 2
        verd = res[i][:B, 0]
        if mit_plan is not None:
            verd = verd[mseg.inv]        # mitigation-sorted -> arrival
        elif nt == 1:
            verd = verd[segs[0].inv]     # detection-sorted -> arrival
        return tuple(outs) + (verd,)

    return launch()


def fused_flow_classify(
    keys: jax.Array,       # [S] int32 stored keys (-1 = empty)
    regs: jax.Array,       # [S, W] f32 register rows
    pkt_keys: jax.Array,   # [B] int32 per-packet flow keys (>= 0)
    upd: jax.Array,        # [B, C+E] f32 counter increments ++ EWMA values
    bins: jax.Array,       # [B, H] int32 absolute hist columns (-1 = none)
    valid: jax.Array,      # [B] int-ish; 0 = padding row, never applied
    w_stack: jax.Array,    # [L, lane, lane] packed layer weights
    b_stack: jax.Array,    # [L, lane] packed biases
    *,
    n_counters: int,
    n_ewma: int,
    alpha: float,
    mode: str,             # WindowStats readout: all | hist | raw (none)
    num_classes: int,
    lane: int,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """The PR-6 single-table MLP form -> (keys' [S], regs' [S, W],
    verdicts [B] int32 in arrival order), one kernel launch."""
    H = int(bins.shape[1]) if bins.ndim == 2 else 0
    tp = TablePlan(n_counters, n_ewma, H, float(alpha),
                   int(regs.shape[1]), mode)
    sp = SuffixPlan("mlp", num_classes, n_layers=int(w_stack.shape[0]),
                    lane=lane)
    k2, r2, verd = fused_flow_serve(
        [(keys, regs, pkt_keys, upd, bins)], valid, (tp,), sp,
        (w_stack, b_stack), interpret=interpret,
    )
    return k2, r2, verd
