"""Public op: the whole stateful pipeline as ONE fused kernel launch.

``fused_flow_classify(keys, regs, pkt_keys, upd, bins, valid, w_stack,
b_stack, ...)`` segments the batch by slot (the same
``flow_update.segment_batch`` prelude), launches the fused Pallas kernel
(update phase + in-kernel classifier; interpret=True on CPU) and
inverse-permutes the [B] int32 verdicts back to arrival order.  This is
the executable artifact ``core.pallas_backend.lower_stateful_fused``
emits for a fused-eligible stateful pipeline — the backend string
``"pallas-fused-flow"`` means exactly this launch is serving.

Weights arrive PRE-PACKED (``fused_mlp.pack_params`` at the snapped
lane): packing happens once at lowering time, not per batch.

Bit-identity contract: state, features and verdicts equal the
two-dispatch composition (flow_update + WindowStats.apply + fused-MLP
classify) bit for bit — the update phase is the shared ``_flow_phase``
schedule and the classifier phase reuses the composition's lane-padded
dot shapes (see kernels/fused_flow/kernel.py).  Outside the kernel
envelope the op falls back to the jnp scan reference + the same suffix
evaluation, and the drain-routing ``lax.cond`` (same profile as
``flow_update``) routes near-degenerate batches — more than 7/8 of live
packets deeper than ``PAR_ROUNDS`` in one chain — to that reference
walk; every path computes identical bits.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flow_update.ops import (
    MAX_HISTS,
    MAX_SLOTS,
    MAX_WIDTH,
    _snap,
    pack_segmented_operands,
    segment_batch,
)
from repro.kernels.flow_update.ref import flow_update_ref, hash_slot
from repro.kernels.fused_flow.kernel import (
    LANE,
    _suffix_eval,
    fused_flow_classify_padded,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fused_flow_classify(
    keys: jax.Array,       # [S] int32 stored keys (-1 = empty)
    regs: jax.Array,       # [S, W] f32 register rows
    pkt_keys: jax.Array,   # [B] int32 per-packet flow keys (>= 0)
    upd: jax.Array,        # [B, C+E] f32 counter increments ++ EWMA values
    bins: jax.Array,       # [B, H] int32 absolute hist columns (-1 = none)
    valid: jax.Array,      # [B] int-ish; 0 = padding row, never applied
    w_stack: jax.Array,    # [L, lane, lane] packed layer weights
    b_stack: jax.Array,    # [L, lane] packed biases
    *,
    n_counters: int,
    n_ewma: int,
    alpha: float,
    mode: str,             # WindowStats readout: all | hist | raw (none)
    num_classes: int,
    lane: int,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """-> (keys' [S], regs' [S, W], verdicts [B] int32), one kernel launch.

    Verdicts are in arrival order; rows with ``valid == 0`` never touch
    the table and classify the all-zero feature row (the engine slices
    them off).  Bit-identical to the two-dispatch composition; see the
    flow-state contract in docs/pipeline_ir.md."""
    if interpret is None:
        interpret = not _on_tpu()
    S, W = regs.shape
    B = int(pkt_keys.shape[0])
    H = int(bins.shape[1]) if bins.ndim == 2 else 0
    head = n_counters + n_ewma
    n_layers = int(w_stack.shape[0])

    keys = jnp.asarray(keys, jnp.int32)
    regs = jnp.asarray(regs, jnp.float32)
    pkt_keys = jnp.asarray(pkt_keys, jnp.int32)
    upd = jnp.asarray(upd, jnp.float32)
    bins = jnp.asarray(bins, jnp.int32)
    valid = jnp.asarray(valid, jnp.int32)

    def suffix(feats):
        return _suffix_eval(
            feats, w_stack, b_stack, head=head, mode=mode, width=W,
            n_layers=n_layers, num_classes=num_classes, lane=lane,
        )

    def reference_full():
        k, r, feats = flow_update_ref(
            keys, regs, pkt_keys, upd, bins, valid,
            n_counters=n_counters, n_ewma=n_ewma, alpha=alpha,
        )
        return k, r, suffix(feats)

    if S > MAX_SLOTS or W > MAX_WIDTH or H > MAX_HISTS or B == 0:
        return reference_full()

    tile = 8 if interpret else LANE
    w_pad = _snap(W, tile)
    u_pad = _snap(upd.shape[1], tile)
    h_pad = _snap(H, tile) if not interpret else max(H, 1)

    seg = segment_batch(hash_slot(pkt_keys, S), valid, S)

    def launch(_):
        ops = pack_segmented_operands(
            seg, keys, regs, pkt_keys, upd, bins, valid,
            tile=tile, w_pad=w_pad, u_pad=u_pad, h_pad=h_pad,
        )
        k_out, r_out, verd = fused_flow_classify_padded(
            *ops, w_stack, b_stack, n_counters=n_counters, n_ewma=n_ewma,
            n_hists=H, alpha=float(alpha), head=head, mode=mode, width=W,
            n_layers=n_layers, num_classes=num_classes, lane=lane,
            interpret=interpret,
        )
        # verdicts come back in sorted order: inverse-permute to arrival
        return k_out[:, 0], r_out[:, :W], verd[:B, 0][seg.inv]

    def reference(_):
        return reference_full()

    return jax.lax.cond(seg.n_deep * 8 > seg.n_live * 7,
                        reference, launch, 0)
