from repro.kernels.flow_update.ops import (
    MAX_HISTS,
    MAX_SLOTS,
    MAX_WIDTH,
    flow_update,
)
from repro.kernels.flow_update.ref import flow_update_ref, hash_slot
from repro.kernels.flow_update.kernel import LANE, vmem_bytes
