"""Pallas kernel: batched flow-state scatter/gather update in ONE launch.

The jnp reference (ref.py) walks the batch packet-by-packet — the update is
order-dependent (EWMAs are non-commutative, collisions evict), so a naive
vectorization is wrong.  This kernel exploits the one independence the
semantics do give: REGISTER SLOTS NEVER INTERACT.  Each slot's final state
is a function of its own packets' subsequence only, so the sequential loop
factorizes into per-slot chains.

The wrapper (ops.py) pre-SEGMENTS the batch: a stable sort by slot turns
every per-slot chain into a contiguous run, preserving per-slot arrival
order (stable sort), and hands the kernel the segment tables
(``seg_first/seg_len/seg_slot``) plus each packet's ``rank`` within its
chain.  The kernel then runs a hybrid, exact schedule:

  1. COMPACTED LOCKSTEP ROUNDS — round r applies, simultaneously for
     every occupied segment, that segment's (r+1)-th packet.  The active
     rows are gathered once into a compacted [B]-sized table (cost
     independent of the slot count), updated with the same elementwise
     f32 expressions as the reference's ``_packet_step``, and scattered
     back after the last round.  Within a round all targets are distinct
     segments; across rounds each segment sees its packets in arrival
     order.  Runs ``min(max_rank + 1, PAR_ROUNDS)`` rounds.

  2. UNROLLED SEQUENTIAL DRAIN — the deep-chain remainder
     (``rank >= PAR_ROUNDS``) replays against the full table with the
     same per-packet expressions as the reference's ``_packet_step``,
     statically unrolled ``DRAIN_UNROLL`` packets per loop trip with the
     operand slicing hoisted to the block and the feature-row emit
     buffered (one store per trip) — the dispatch overhead that dominates
     the plain scan is amortized away.  ``drain_order`` (from the
     wrapper) lists those packets in sorted-segment order — per slot that
     extends the round order exactly — padded with a sentinel row whose
     ``valid == 0``, so over-stepping past ``n_rem`` is a no-op.

Both phases respect per-slot arrival order and use the SAME per-slot
arithmetic in the same order as ``_packet_step``, so state, features and
verdicts are **bit-identical** to ``flow_update_ref`` by the per-slot
decomposition — the conformance suite pins this over random
collision-heavy batches.  Feature rows come out in SORTED order; the
wrapper applies the inverse permutation to restore arrival order.

``_flow_phase`` is the schedule factored over plain jnp values so the
fused stateful kernel (kernels/fused_flow) can run the identical update
phase and feed the feature rows straight into its classifier matmuls
without leaving VMEM.

Grid: (1,) — rounds are a sequential dependency chain; everything is a
full VMEM-resident block.  VMEM working set = S*(W+1) words + batch rows
(``vmem_bytes``), which feasibility checks against the platform budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flow_update.ref import ewma_blend

LANE = 128
# ranks executed as compacted lockstep rounds before the schedule switches
# to the unrolled sequential drain (crossover: one round costs ~a dozen
# [B, W] vector ops, one drained packet ~a dozen [1, W] ops)
PAR_ROUNDS = 8
# packets replayed per drain-loop trip; the static unroll amortizes the
# while-loop dispatch overhead that dominates a packet-at-a-time scan
DRAIN_UNROLL = 8


def _flow_phase(keys, regs, pk, upd, bins, valid, rank, seg_first, seg_len,
                seg_slot, drain_order, drain_sid, deep_src, *,
                n_counters: int, n_ewma: int, alpha: float):
    """The hybrid update schedule over plain jnp values.

    keys [S] i32; regs [S, W] f32; the batch operands are [B_pad]-sized and
    SORTED by slot (stable, so per-slot arrival order is preserved), with
    at least one trailing sentinel row (``valid == 0``, ``bins == -1``).
    ``seg_first/seg_len/seg_slot[k]`` describe segment k (0 for padding
    entries past the live segment count, which carry ``seg_len == 0``);
    ``drain_order`` lists the ``rank >= PAR_ROUNDS`` packets in sorted
    order and ``drain_sid`` their rows in the deep table, both padded
    with a sentinel index; ``deep_src`` [D] maps deep-table rows back to
    segment ids (the last row is the drain sentinel).

    -> (keys' [S], regs' [S, W], feats [B_pad, W] in SORTED order)."""
    S, W = regs.shape
    B = pk.shape[0]
    C, E = n_counters, n_ewma
    n_hists = bins.shape[1]
    live = valid != 0

    n_rounds = jnp.minimum(
        jnp.max(jnp.where(live, rank, 0)) + 1, PAR_ROUNDS
    )
    col = jax.lax.broadcasted_iota(jnp.int32, (B, W), 1)

    # precompute every packet's full-width update terms ONCE, vectorized
    # over the batch — the sequential phases then just gather rows:
    #   add_full[i] = counter increments + hist one-hot bumps.  Counter,
    #     EWMA and hist columns are DISJOINT, so each column sums at most
    #     one nonzero term and folding them into one additive tensor is
    #     exact (same bits as the reference's sequential adds);
    #   val_full[i] = EWMA set-values padded to full width.
    add_full = jnp.pad(upd[:, :C], ((0, 0), (0, W - C)))
    for j in range(n_hists):                     # static unroll per hist
        add_full = add_full + (col == bins[:, j:j + 1]).astype(jnp.float32)
    val_full = jnp.pad(upd[:, C:C + E], ((0, 0), (C, W - C - E)))
    col1 = jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)
    m_ewma = (col1 >= C) & (col1 < C + E)        # [1, W], broadcasts

    # gather each live segment's row ONCE into a compacted [B]-sized active
    # table; rounds update the compacted copy (cost independent of S)
    seg_slot_c = jnp.where(seg_len > 0, seg_slot, 0)
    act_keys = keys[seg_slot_c]                  # [B]
    act_regs = regs[seg_slot_c]                  # [B, W]
    feats0 = jnp.zeros((B, W), jnp.float32)

    def round_body(state):
        r, ak, ar, feats = state
        ok = r < seg_len                         # segment still has packets
        pid = jnp.where(ok, seg_first + r, 0)    # this round's packet ids
        key_r = pk[pid]
        add_r = add_full[pid]
        val_r = val_full[pid]

        # identical per-slot arithmetic to ref._packet_step, vectorized
        # across segments (elementwise f32: bit-identical per element)
        fresh = ak != key_r                      # evict-on-collision
        row0 = jnp.where(fresh[:, None], jnp.zeros_like(ar), ar)
        ewma = jnp.where(fresh[:, None], val_r,
                         ewma_blend(row0, val_r, alpha))
        new = jnp.where(m_ewma, ewma, row0) + add_r

        ar = jnp.where(ok[:, None], new, ar)
        ak = jnp.where(ok, key_r, ak)
        # this round's packets read their segment's post-round row
        feats = feats.at[jnp.where(ok, pid, B)].set(new, mode="drop")
        return r + 1, ak, ar, feats

    _, act_keys, act_regs, feats = jax.lax.while_loop(
        lambda s: s[0] < n_rounds, round_body,
        (jnp.int32(0), act_keys, act_regs, feats0),
    )

    # unrolled sequential drain: deep-chain packets (rank >= PAR_ROUNDS)
    # replay in sorted order — per slot that extends the round order
    # exactly — against a DOUBLY-COMPACTED table holding only the deep
    # segments' rows (at most B/(PAR_ROUNDS+1) of them): each step's row
    # load/store then slices a cache-sized [D, W] buffer (a full-table
    # dynamic-update would copy S rows per packet, and the active table
    # still B).  Operands are pre-gathered into drain order so each trip
    # block-slices them contiguously, and feature rows accumulate in a
    # drain-order buffer written back with ONE scatter at the end.
    # Over-stepping past n_rem lands on the sentinel entry (valid == 0,
    # deep row D-1), which writes the stored values back and emits a zero
    # feature row.
    rem = live & (rank >= PAR_ROUNDS)
    n_rem = jnp.sum(rem.astype(jnp.int32))
    trips = (n_rem + DRAIN_UNROLL - 1) // DRAIN_UNROLL
    pk_d = pk[drain_order]                       # [B] drain-ordered
    add_d = add_full[drain_order]                # [B, W] precomputed terms
    val_d = val_full[drain_order]
    valid_d = valid[drain_order]
    deep_keys = act_keys[deep_src]               # [D]
    deep_regs = act_regs[deep_src]               # [D, W]
    dfeats0 = jnp.zeros((B, W), jnp.float32)

    def drain_step(u, pk_b, sid_b, add_b, val_b, valid_b, ak2, ar2):
        """One packet against the active table — the same elementwise f32
        expressions as ref._packet_step, minus its per-packet operand
        slicing (hoisted to the block), update-term construction (the
        precomputed add_full/val_full rows) and feats scatter (buffered)."""
        sid = sid_b[u]
        key = pk_b[u:u + 1, None]                # [1, 1]
        stored = jax.lax.dynamic_slice(ak2, (sid, 0), (1, 1))
        row = jax.lax.dynamic_slice(ar2, (sid, 0), (1, W))
        fresh = stored != key
        row0 = jnp.where(fresh, jnp.zeros_like(row), row)
        val_u = val_b[u:u + 1]
        ewma = jnp.where(fresh, val_u, ewma_blend(row0, val_u, alpha))
        new = jnp.where(m_ewma, ewma, row0) + add_b[u:u + 1]
        ok = valid_b[u:u + 1, None] != 0
        new_row = jnp.where(ok, new, row)
        ak2 = jax.lax.dynamic_update_slice(
            ak2, jnp.where(ok, key, stored), (sid, 0))
        ar2 = jax.lax.dynamic_update_slice(ar2, new_row, (sid, 0))
        return ak2, ar2, jnp.where(ok, new_row, jnp.zeros_like(new_row))

    def drain_body(state):
        t, ak2, ar2, dfeats = state
        base = t * DRAIN_UNROLL
        pk_b = jax.lax.dynamic_slice(pk_d, (base,), (DRAIN_UNROLL,))
        sid_b = jax.lax.dynamic_slice(drain_sid, (base,), (DRAIN_UNROLL,))
        add_b = jax.lax.dynamic_slice(
            add_d, (base, 0), (DRAIN_UNROLL, W))
        val_b = jax.lax.dynamic_slice(
            val_d, (base, 0), (DRAIN_UNROLL, W))
        valid_b = jax.lax.dynamic_slice(valid_d, (base,), (DRAIN_UNROLL,))
        out = []
        for u in range(DRAIN_UNROLL):            # static unroll
            ak2, ar2, frow = drain_step(
                u, pk_b, sid_b, add_b, val_b, valid_b, ak2, ar2)
            out.append(frow)
        dfeats = jax.lax.dynamic_update_slice(
            dfeats, jnp.concatenate(out, axis=0), (base, 0))
        return t + 1, ak2, ar2, dfeats

    _, deep_keys2, deep_regs, dfeats = jax.lax.while_loop(
        lambda s: s[0] < trips, drain_body,
        (jnp.int32(0), deep_keys[:, None], deep_regs, dfeats0),
    )
    deep_keys = deep_keys2[:, 0]
    # sentinel drain entries all write zero rows onto the sentinel row,
    # which the wrapper slices off; live entries are distinct positions
    feats = feats.at[drain_order].set(dfeats, mode="drop")

    # fold the drained deep rows back into the active table (only the
    # live deep rows; junk copies and the sentinel row drop out of range)
    n_deep_segs = jnp.sum((seg_len > PAR_ROUNDS).astype(jnp.int32))
    d_idx = jnp.arange(deep_src.shape[0], dtype=jnp.int32)
    src_tgt = jnp.where(d_idx < n_deep_segs, deep_src, B)
    act_keys = act_keys.at[src_tgt].set(deep_keys, mode="drop")
    act_regs = act_regs.at[src_tgt].set(deep_regs, mode="drop")

    # scatter the compacted rows back; padding segments drop out of range
    tgt = jnp.where(seg_len > 0, seg_slot, S)
    keys = keys.at[tgt].set(act_keys, mode="drop")
    regs = regs.at[tgt].set(act_regs, mode="drop")
    return keys, regs, feats


def _kernel(keys_ref, regs_ref, pk_ref, upd_ref, bins_ref, valid_ref,
            rank_ref, segf_ref, segl_ref, segs_ref, dord_ref, dsid_ref,
            dsrc_ref, keys_out, regs_out, feats_out, *,
            n_counters: int, n_ewma: int, n_hists: int, alpha: float):
    """keys_ref [S, Kw] i32; regs_ref [S, W_pad] f32; batch refs are
    [B_pad, *]-shaped and slot-sorted (see ``_flow_phase``).  Only column 0
    of the narrow int refs is live (rest is tile padding); only the first
    ``n_hists`` bins columns are real."""
    keys, regs, feats = _flow_phase(
        keys_ref[...][:, 0],
        regs_ref[...],
        pk_ref[...][:, 0],
        upd_ref[...],
        bins_ref[...][:, :max(n_hists, 1)],
        valid_ref[...][:, 0],
        rank_ref[...][:, 0],
        segf_ref[...][:, 0],
        segl_ref[...][:, 0],
        segs_ref[...][:, 0],
        dord_ref[...][:, 0],
        dsid_ref[...][:, 0],
        dsrc_ref[...][:, 0],
        n_counters=n_counters, n_ewma=n_ewma, alpha=alpha,
    )
    k_w = keys_out.shape[1]
    keys_out[...] = jnp.pad(keys[:, None], ((0, 0), (0, k_w - 1)))
    regs_out[...] = regs
    feats_out[...] = feats


@functools.partial(
    jax.jit, static_argnames=("n_counters", "n_ewma", "n_hists", "alpha",
                              "interpret")
)
def flow_update_padded(
    keys: jax.Array,        # [S, Kw] int32 (-1 = empty; col 0 live)
    regs: jax.Array,        # [S, W_pad] f32
    pkt_keys: jax.Array,    # [B_pad, Kw] int32, slot-sorted
    upd: jax.Array,         # [B_pad, U_pad] f32, slot-sorted
    bins: jax.Array,        # [B_pad, H_pad] int32 absolute cols (-1 = none)
    valid: jax.Array,       # [B_pad, Kw] int32 (sentinel rows 0)
    rank: jax.Array,        # [B_pad, Kw] int32 position within slot chain
    seg_first: jax.Array,   # [B_pad, Kw] int32 segment start positions
    seg_len: jax.Array,     # [B_pad, Kw] int32 segment lengths (0 = pad)
    seg_slot: jax.Array,    # [B_pad, Kw] int32 segment target slots
    drain_order: jax.Array,  # [B_pad, Kw] int32 deep-packet replay order
    drain_sid: jax.Array,    # [B_pad, Kw] int32 deep-packet deep-table rows
    deep_src: jax.Array,     # [D, Kw] int32 deep-table row -> segment id
    *,
    n_counters: int,
    n_ewma: int,
    n_hists: int,
    alpha: float,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """-> (keys' [S, Kw], regs' [S, W_pad], feats [B_pad, W_pad] sorted)."""
    S, k_w = keys.shape
    _, w_pad = regs.shape
    B = pkt_keys.shape[0]
    assert S & (S - 1) == 0, "slot count must be a power of two"
    narrow = pl.BlockSpec((B, k_w), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(
            _kernel, n_counters=n_counters, n_ewma=n_ewma,
            n_hists=n_hists, alpha=alpha,
        ),
        grid=(1,),
        in_specs=[
            # sequential round chain: every operand is one resident block
            pl.BlockSpec((S, k_w), lambda i: (0, 0)),
            pl.BlockSpec((S, w_pad), lambda i: (0, 0)),
            narrow,
            pl.BlockSpec((B, upd.shape[1]), lambda i: (0, 0)),
            pl.BlockSpec((B, bins.shape[1]), lambda i: (0, 0)),
            narrow, narrow, narrow, narrow, narrow, narrow, narrow,
            pl.BlockSpec((deep_src.shape[0], k_w), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((S, k_w), lambda i: (0, 0)),
            pl.BlockSpec((S, w_pad), lambda i: (0, 0)),
            pl.BlockSpec((B, w_pad), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, k_w), jnp.int32),
            jax.ShapeDtypeStruct((S, w_pad), jnp.float32),
            jax.ShapeDtypeStruct((B, w_pad), jnp.float32),
        ],
        interpret=interpret,
    )(keys, regs, pkt_keys, upd, bins, valid, rank, seg_first, seg_len,
      seg_slot, drain_order, drain_sid, deep_src)


def vmem_bytes(n_slots: int, width: int, batch: int = 256) -> int:
    """VMEM working set the kernel claims (feasibility input): the whole
    register file (rows + keys), the batch's packet/update/feature rows,
    the compacted active table, and the int32 scheduling operands
    (keys/valid/rank/segment tables/drain order + hist bins)."""
    table = n_slots * (width + 1) * 4
    batch_rows = batch * (width + 1) * 4 * 3   # upd in + feats out + active
    aux = batch * 4 * 16                       # scheduling ints + hist bins
    return table + batch_rows + aux
