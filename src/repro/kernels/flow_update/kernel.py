"""Pallas kernel: batched flow-state scatter/gather update in ONE launch.

The jnp reference (ref.py) walks the batch packet-by-packet — the update is
order-dependent (EWMAs are non-commutative, collisions evict), so a naive
vectorization is wrong.  This kernel exploits the one independence the
semantics do give: REGISTER SLOTS NEVER INTERACT.  Each slot's final state
is a function of its own packets' subsequence only, so the sequential loop
factorizes into per-slot chains, and the kernel executes a *conflict-free
round schedule*:

  round r applies, simultaneously for every slot, the (r+1)-th packet
  that hashes to it (``rank[p]`` = number of earlier same-slot packets in
  the batch).  Within a round all targets are distinct, so the whole
  table updates as a few [S, W] vector ops; across rounds each slot sees
  its packets in arrival order.

The schedule is HYBRID: the first ``PAR_ROUNDS`` ranks run as vectorized
rounds — in busy interleaved traffic (the serving regime this subsystem
exists for) that retires nearly every packet, since per-flow multiplicity
within one batch is small — and the deep-chain remainder
(``rank >= PAR_ROUNDS``) drains through a COMPACTED sequential loop over
just those packets, reusing the reference's ``_packet_step``.  Both phases
respect per-slot arrival order, so the combination is exact.  The wrapper
(ops.py) only launches this kernel when rounds retire most of the batch;
drain-dominated batches take the reference schedule instead — a pure
schedule choice, since every schedule computes the same bits.

Per-slot arithmetic is the SAME elementwise f32 expressions as the
reference's ``_packet_step`` in the same order, so state, features and
verdicts are **bit-identical** to ``flow_update_ref`` by the per-slot
decomposition — the conformance suite pins this over random collision-heavy
batches.

The whole dataflow — key hash, slot gather, counter/EWMA/histogram
update, slot scatter, per-packet feature emit — runs in one
``pallas_call`` with the register table resident in VMEM; only the updated
table and the [B, W] feature rows cross the kernel boundary.  The [B]
rank vector (each packet's position within its slot's chain, valid rows
only) is precomputed once by the wrapper — it doubles as the schedule-
choice input there, and keeps the O(B^2) rank derivation and its [B, B]
intermediates out of the kernel's VMEM footprint.  The gather/scatter
constructions use jnp indexing (exact), which the interpret path executes
directly; on TPU they lower through Mosaic's gather support.

Grid: (1,) — rounds are a sequential dependency chain; everything is a
full VMEM-resident block.  VMEM working set = S*(W+1) words + batch rows
(``vmem_bytes``), which feasibility checks against the platform budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flow_update.ref import _packet_step, hash_slot

LANE = 128
# ranks executed as vectorized cross-slot rounds before the schedule
# switches to the compacted sequential drain (crossover: one round costs
# ~a dozen [S, W] vector ops, one drained packet ~a dozen [1, W] ops)
PAR_ROUNDS = 4


def _kernel(keys_ref, regs_ref, pk_ref, upd_ref, bins_ref, valid_ref,
            rank_ref, keys_out, regs_out, feats_out, *,
            n_counters: int, n_ewma: int, n_hists: int, alpha: float):
    """keys_ref [S, Kw] i32; regs_ref [S, W_pad] f32; pk_ref [B, Kw] i32;
    upd_ref [B, U_pad] f32; bins_ref [B, H_pad] i32; valid_ref/rank_ref
    [B, Kw] i32.  Only column 0 of the narrow int refs is live (rest is
    tile padding); only the first ``n_hists`` bins columns are real.

    ``rank[p]`` (precomputed by ops.py) = number of earlier VALID
    same-slot packets — the round in which p fires.  Padding rows carry
    ``valid == 0``: they are excluded from every round and from the
    drain, and their feature rows stay zero (matching the reference)."""
    keys = keys_ref[...][:, 0]                   # [S]
    regs = regs_ref[...]                         # [S, W]
    pk = pk_ref[...][:, 0]                       # [B]
    upd = upd_ref[...]
    bins = bins_ref[...][:, :max(n_hists, 1)]
    valid = valid_ref[...][:, 0]
    rank = rank_ref[...][:, 0]
    S, W = regs.shape
    B = pk.shape[0]
    C, E = n_counters, n_ewma

    slot = hash_slot(pk, S)                      # key-hash inside the launch
    live = valid != 0
    n_rounds = jnp.minimum(
        jnp.max(jnp.where(live, rank, 0)) + 1, PAR_ROUNDS
    )

    col = jax.lax.broadcasted_iota(jnp.int32, (S, W), 1)
    b_idx = jnp.arange(B, dtype=jnp.int32)
    feats0 = jnp.zeros((B, W), jnp.float32)
    pk2, slot2, valid2 = pk[:, None], slot[:, None], valid[:, None]

    def round_body(state):
        r, keys1, regs1, feats = state
        sel = (rank == r) & live
        # at most one selected packet per slot: scatter packet ids, drop
        # the non-selected (targets pushed out of range)
        tgt = jnp.where(sel, slot, S)
        pid = jnp.full((S,), -1, jnp.int32).at[tgt].set(b_idx, mode="drop")
        ok = pid >= 0
        pidc = jnp.maximum(pid, 0)
        pk_s = pk[pidc]                          # [S] this round's keys
        upd_s = upd[pidc]                        # [S, U]
        bins_s = bins[pidc]                      # [S, H]

        # identical per-slot arithmetic to ref._packet_step, vectorized
        # across slots (elementwise f32: bit-identical per element)
        fresh = keys1 != pk_s                    # evict-on-collision
        row0 = jnp.where(fresh[:, None], jnp.zeros_like(regs1), regs1)
        inc_full = jnp.pad(upd_s[:, :C], ((0, 0), (0, W - C)))
        val_full = jnp.pad(upd_s[:, C:C + E], ((0, 0), (C, W - C - E)))
        new = jnp.where(col < C, row0 + inc_full, row0)
        ewma = jnp.where(fresh[:, None], val_full,
                         row0 * (1.0 - alpha) + val_full * alpha)
        new = jnp.where((col >= C) & (col < C + E), ewma, new)
        for j in range(n_hists):                 # static unroll per hist
            new = new + (col == bins_s[:, j:j + 1]).astype(jnp.float32)

        regs1 = jnp.where(ok[:, None], new, regs1)
        keys1 = jnp.where(ok, pk_s, keys1)
        # this round's packets read their slot's post-round row
        feats = jnp.where(sel[:, None], regs1[slot], feats)
        return r + 1, keys1, regs1, feats

    _, keys, regs, feats = jax.lax.while_loop(
        lambda s: s[0] < n_rounds, round_body,
        (jnp.int32(0), keys, regs, feats0),
    )

    # compacted sequential drain: deep-chain packets (rank >= PAR_ROUNDS)
    # in arrival order — per slot that extends the round order exactly
    rem = (rank >= PAR_ROUNDS) & live
    n_rem = jnp.sum(rem.astype(jnp.int32))
    rem_order = jnp.argsort(jnp.where(rem, b_idx, B + b_idx))

    def drain_body(state):
        i, keys2, regs2, feats = state
        p = rem_order[i]
        keys2, regs2, feats = _packet_step(
            p, (keys2, regs2, feats), pk2, slot2, upd, bins, valid2,
            n_counters=C, n_ewma=E, alpha=alpha,
        )
        return i + 1, keys2, regs2, feats

    _, keys2, regs, feats = jax.lax.while_loop(
        lambda s: s[0] < n_rem, drain_body,
        (jnp.int32(0), keys[:, None], regs, feats),
    )
    keys = keys2[:, 0]
    k_w = keys_out.shape[1]
    keys_out[...] = jnp.pad(keys[:, None], ((0, 0), (0, k_w - 1)))
    regs_out[...] = regs
    feats_out[...] = feats


@functools.partial(
    jax.jit, static_argnames=("n_counters", "n_ewma", "n_hists", "alpha",
                              "interpret")
)
def flow_update_padded(
    keys: jax.Array,       # [S, Kw] int32 (-1 = empty; col 0 live)
    regs: jax.Array,       # [S, W_pad] f32
    pkt_keys: jax.Array,   # [B, Kw] int32
    upd: jax.Array,        # [B, U_pad] f32
    bins: jax.Array,       # [B, H_pad] int32 absolute cols (-1 = none)
    valid: jax.Array,      # [B, Kw] int32
    rank: jax.Array,       # [B, Kw] int32 (earlier valid same-slot count)
    *,
    n_counters: int,
    n_ewma: int,
    n_hists: int,
    alpha: float,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """-> (keys' [S, Kw], regs' [S, W_pad], feats [B, W_pad])."""
    S, k_w = keys.shape
    _, w_pad = regs.shape
    B = pkt_keys.shape[0]
    assert S & (S - 1) == 0, "slot count must be a power of two"
    return pl.pallas_call(
        functools.partial(
            _kernel, n_counters=n_counters, n_ewma=n_ewma,
            n_hists=n_hists, alpha=alpha,
        ),
        grid=(1,),
        in_specs=[
            # sequential round chain: every operand is one resident block
            pl.BlockSpec((S, k_w), lambda i: (0, 0)),
            pl.BlockSpec((S, w_pad), lambda i: (0, 0)),
            pl.BlockSpec((B, k_w), lambda i: (0, 0)),
            pl.BlockSpec((B, upd.shape[1]), lambda i: (0, 0)),
            pl.BlockSpec((B, bins.shape[1]), lambda i: (0, 0)),
            pl.BlockSpec((B, k_w), lambda i: (0, 0)),
            pl.BlockSpec((B, k_w), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((S, k_w), lambda i: (0, 0)),
            pl.BlockSpec((S, w_pad), lambda i: (0, 0)),
            pl.BlockSpec((B, w_pad), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, k_w), jnp.int32),
            jax.ShapeDtypeStruct((S, w_pad), jnp.float32),
            jax.ShapeDtypeStruct((B, w_pad), jnp.float32),
        ],
        interpret=interpret,
    )(keys, regs, pkt_keys, upd, bins, valid, rank)


def vmem_bytes(n_slots: int, width: int, batch: int = 256) -> int:
    """VMEM working set the kernel claims (feasibility input): the whole
    register file (rows + keys), the batch's packet/update/feature rows,
    and the int32 scheduling operands (keys/valid/rank/bins)."""
    table = n_slots * (width + 1) * 4
    batch_rows = batch * (width + 1) * 4 * 2   # upd in + feats out
    aux = batch * 4 * 12                       # pk/valid/rank + hist bins
    return table + batch_rows + aux
