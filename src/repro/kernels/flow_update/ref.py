"""Pure-jnp oracle for the fused flow-state update (scatter/gather) kernel.

The flow register file (repro.flowstate.registers) is a direct-indexed hash
table: ``slot = hash(key) & (S-1)``, evict-on-collision.  One batched update
is ORDER-DEPENDENT — EWMAs are non-commutative and a later packet may evict
an earlier packet's flow — so both execution paths walk the batch in arrival
order.  To make the Pallas kernel *bit-identical* to this reference by
construction, the per-packet math lives in ONE shared function
(``_packet_step``): the reference runs it under ``jax.lax.fori_loop`` here,
and the kernel (kernel.py) runs the very same function inside its
``pallas_call`` body.  Same ops, same order, same f32 constants — state,
features and therefore verdicts cannot drift between engines.

Register row layout (width W = C + E + sum(hist_sizes)):

  ``[0, C)``        counters      ``row += inc``    (counter 0 = pkt count)
  ``[C, C+E)``      EWMAs         first packet of a flow sets ``row = v``;
                                  after that ``row = row*(1-a) + v*a``
  ``[C+E, W)``      histograms    ``row[bin] += 1`` per histogram column
                                  (``bins`` carries ABSOLUTE column ids;
                                  ``-1`` means "no histogram update")

Collision policy (the documented contract): the stored key is compared to
the incoming key; empty (``-1``) or different-flow slots are *evicted* —
state resets to zero and the new flow claims the slot (last-writer-wins).
Rows with ``valid == 0`` (ragged-batch padding) never touch the table and
emit all-zero feature rows — they are invisible to both the register file
and the downstream classifier (the engine slices their verdicts off).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Knuth multiplicative constant (2654435761 = 2^32 / phi), xor-folded so
# low-entropy keys (sequential flow ids) still spread across slots
_HASH_MULT = 2654435761


def ewma_blend(row0: jax.Array, val: jax.Array, alpha: float) -> jax.Array:
    """EWMA blend with CONTRACTION-PROOF rounding, shared by every engine.

    The naive ``row0*(1-a) + val*a`` is a mul+add pair that LLVM may or
    may not contract into an FMA depending on the surrounding fusion
    cluster (``lax.optimization_barrier`` does not survive to codegen), so
    the same expression rounds differently in the reference loop, the
    kernel's lockstep rounds and its drain — a one-ulp break of the
    bit-identity contract.  Instead we rely on ``alpha`` being a power of
    two (validated by ``FlowStateSpec``; it is the hardware shift-EWMA
    regime the dataplane targets anyway): ``row0*alpha`` and ``val*alpha``
    are then EXACT in f32, and an FMA whose product is exact rounds
    identically to the separate mul+add.  Every grouping LLVM can pick
    computes the same bits."""
    ta = row0 * alpha   # exact: power-of-two scaling never rounds
    tv = val * alpha    # exact
    return (row0 - ta) + tv


def hash_slot(keys: jax.Array, n_slots: int) -> jax.Array:
    """int32 flow keys -> int32 slot ids in [0, n_slots).  n_slots must be a
    power of two (masked, not modulo — same cheap op a switch ALU does)."""
    h = keys.astype(jnp.uint32) * jnp.uint32(_HASH_MULT)
    h = h ^ (h >> jnp.uint32(16))
    return (h & jnp.uint32(n_slots - 1)).astype(jnp.int32)


def _packet_step(p, carry, pkt_keys, slots, upd, bins, valid, *,
                 n_counters: int, n_ewma: int, alpha: float):
    """Apply packet ``p`` to the register file.  Shared by the jnp
    reference and the Pallas kernel body — all arrays 2-D:

      carry   = (keys [S, Kw] i32, regs [S, W] f32, feats [B, W] f32)
      pkt_keys [B, Kw] i32, slots [B, Kw] i32 (col 0 live, rest padding),
      upd [B, U>=C+E] f32, bins [B, H] i32 (absolute cols, -1 = none),
      valid [B, Kw] i32.
    """
    keys, regs, feats = carry
    W = regs.shape[1]
    C, E = n_counters, n_ewma

    slot = jax.lax.dynamic_slice(slots, (p, 0), (1, 1))[0, 0]
    key = jax.lax.dynamic_slice(pkt_keys, (p, 0), (1, 1))          # [1, 1]
    stored = jax.lax.dynamic_slice(keys, (slot, 0), (1, 1))
    row = jax.lax.dynamic_slice(regs, (slot, 0), (1, W))

    # evict-on-collision: empty (-1) or different flow -> state resets
    fresh = stored != key
    row0 = jnp.where(fresh, jnp.zeros_like(row), row)

    u = jax.lax.dynamic_slice(upd, (p, 0), (1, upd.shape[1]))
    col = jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)
    inc_full = jnp.pad(u[:, :C], ((0, 0), (0, W - C)))
    val_full = jnp.pad(u[:, C:C + E], ((0, 0), (C, W - C - E)))

    new = jnp.where(col < C, row0 + inc_full, row0)
    ewma = jnp.where(fresh, val_full, ewma_blend(row0, val_full, alpha))
    new = jnp.where((col >= C) & (col < C + E), ewma, new)
    b = jax.lax.dynamic_slice(bins, (p, 0), (1, bins.shape[1]))
    for j in range(bins.shape[1]):       # static unroll: one hist per column
        new = new + (col == b[0, j]).astype(jnp.float32)

    ok = jax.lax.dynamic_slice(valid, (p, 0), (1, 1)) != 0
    new_row = jnp.where(ok, new, row)
    new_key = jnp.where(ok, key, stored)

    keys = jax.lax.dynamic_update_slice(keys, new_key, (slot, 0))
    regs = jax.lax.dynamic_update_slice(regs, new_row, (slot, 0))
    # padding rows emit zero feature rows (invisible downstream)
    feats = jax.lax.dynamic_update_slice(
        feats, jnp.where(ok, new_row, jnp.zeros_like(new_row)), (p, 0)
    )
    return keys, regs, feats


def flow_update_ref(
    keys: jax.Array,       # [S] int32 stored flow keys (-1 = empty)
    regs: jax.Array,       # [S, W] f32 register rows
    pkt_keys: jax.Array,   # [B] int32 flow key per packet (>= 0)
    upd: jax.Array,        # [B, C+E] f32 counter increments ++ EWMA values
    bins: jax.Array,       # [B, H] int32 absolute hist columns (-1 = none)
    valid: jax.Array,      # [B] packets to apply (0 = padding row, skipped)
    *,
    n_counters: int,
    n_ewma: int,
    alpha: float,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """-> (keys' [S], regs' [S, W], feats [B, W]): the register file after
    the batch, plus each packet's post-update row (what the classifier
    sees; all-zero for ``valid == 0`` padding rows).  Traceable/jittable;
    arrival order within the batch preserved."""
    S, W = regs.shape
    B = pkt_keys.shape[0]
    bins = jnp.asarray(bins, jnp.int32)
    if bins.ndim != 2 or bins.shape[1] == 0:   # no histograms configured
        bins = jnp.full((B, 1), -1, jnp.int32)
    k2 = jnp.asarray(keys, jnp.int32)[:, None]
    pk = jnp.asarray(pkt_keys, jnp.int32)[:, None]
    v2 = jnp.asarray(valid, jnp.int32)[:, None]
    slots = hash_slot(pk, S)
    feats0 = jnp.zeros((B, W), jnp.float32)

    def body(p, carry):
        return _packet_step(
            p, carry, pk, slots, jnp.asarray(upd, jnp.float32), bins, v2,
            n_counters=n_counters, n_ewma=n_ewma, alpha=alpha,
        )

    k_out, r_out, feats = jax.lax.fori_loop(
        0, B, body, (k2, jnp.asarray(regs, jnp.float32), feats0)
    )
    return k_out[:, 0], r_out, feats
