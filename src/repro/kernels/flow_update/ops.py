"""Public op: batched flow-register update, fused scatter/gather form.

``flow_update(keys, regs, pkt_keys, upd, bins, valid)`` segments the batch
by slot, pads to tile widths, launches the Pallas kernel (interpret=True on
CPU — the TPU path is the same kernel compiled by Mosaic) and restores
arrival order.  This is the executable artifact the Pallas serving backend
(core.pallas_backend.lower_stateful_pallas) emits for the stateful stage
prefix ``FlowKey -> RegisterUpdate``.

Falls back to the jnp scan reference when the table is outside the kernel
envelope (too many slots/too wide a row for resident VMEM).  Padding is
self-masking: padded register columns start zero and are never addressed
(absolute hist columns < W, counter/EWMA sections are static slices), so
the real columns are bit-identical to the unpadded reference.

Slot segmentation (``segment_batch``, shared with kernels/fused_flow): a
STABLE argsort by slot makes every per-slot chain contiguous while
preserving per-slot arrival order, so each packet's rank within its chain
falls out of a cumulative max in O(B log B) — no [B, B] intermediates —
and deep same-slot bursts become dense segments the kernel's lockstep
rounds and unrolled drain both walk efficiently.  The inverse permutation
restores arrival-order feature rows; the table update itself is
order-independent across slots, so sorting never changes the final state.

Schedule choice: the hybrid kernel covers every traffic shape — lockstep
rounds retire interleaved traffic, and the doubly-compacted drain replays
deep chains at a small fixed cost per packet (a [1, W] row move against a
cache-sized deep table), measured well under the reference walk's
per-packet cost even on a fully-degenerate single-chain batch.  The
kernel therefore serves every in-envelope batch; the scan reference
remains only for shapes outside the VMEM envelope.  A pure schedule
choice either way: every path computes identical bits.
(``telemetry.flow_health`` still flags drain-heavy batches — more than
7/8 of live packets deeper than ``PAR_ROUNDS`` — as a traffic-shape
signal; it is no longer a routing decision.)
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.flow_update.kernel import (
    LANE,
    PAR_ROUNDS,
    flow_update_padded,
)
from repro.kernels.flow_update.ref import flow_update_ref, hash_slot

# kernel envelope: the whole table must sit in VMEM for the launch
MAX_SLOTS = 1 << 16
MAX_WIDTH = 256
MAX_HISTS = 8


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _snap(n: int, tile: int) -> int:
    return max(tile, -(-n // tile) * tile)


class Segments(NamedTuple):
    """Slot-segmented batch layout (all entries in SORTED order except
    ``order``/``inv``, which map between arrival and sorted order)."""

    order: jax.Array       # [B] arrival index of sorted position i
    inv: jax.Array         # [B] sorted position of arrival index p
    rank: jax.Array        # [B] position within the slot's chain
    seg_first: jax.Array   # [B] segment k's first sorted position
    seg_len: jax.Array     # [B] segment k's packet count (0 = padding)
    seg_slot: jax.Array    # [B] segment k's table slot
    drain_order: jax.Array  # [B] rank >= PAR_ROUNDS packets, sorted; B = pad
    drain_sid: jax.Array   # [B] those packets' deep-table rows; -1 = pad
    deep_src: jax.Array    # [B] segment id behind each deep-table row
    n_deep: jax.Array      # [] live packets with rank >= par_rounds
    n_live: jax.Array      # [] live packets


def segment_batch(slot: jax.Array, valid: jax.Array, n_slots: int, *,
                  par_rounds: int = PAR_ROUNDS) -> Segments:
    """Stable-sort the batch by slot and derive the segment tables.

    Stability preserves per-slot arrival order, so ranks — and therefore
    the final table state — are exactly those of the arrival-order walk.
    Invalid rows sort last (keyed ``n_slots``) and never start or extend a
    segment.  Runs as part of the jitted serving step."""
    B = slot.shape[0]
    live = valid != 0
    pos = jnp.arange(B, dtype=jnp.int32)
    order = jnp.argsort(jnp.where(live, slot, n_slots), stable=True)
    slot_s = slot[order]
    live_s = live[order]

    is_new = jnp.concatenate(
        [jnp.ones((1,), bool), slot_s[1:] != slot_s[:-1]]
    ) & live_s
    seg_id = jnp.cumsum(is_new.astype(jnp.int32)) - 1
    # rank = distance from the most recent segment head (live rows only)
    rank = pos - jax.lax.cummax(jnp.where(is_new, pos, 0))
    head_tgt = jnp.where(is_new, seg_id, B)
    seg_first = jnp.zeros(B, jnp.int32).at[head_tgt].set(pos, mode="drop")
    seg_slot = jnp.zeros(B, jnp.int32).at[head_tgt].set(slot_s, mode="drop")
    seg_len = jnp.zeros(B, jnp.int32).at[
        jnp.where(live_s, seg_id, B)
    ].add(1, mode="drop")
    inv = jnp.zeros(B, jnp.int32).at[order].set(pos)

    rem = live_s & (rank >= par_rounds)
    remi = rem.astype(jnp.int32)
    csum = jnp.cumsum(remi)
    n_deep = csum[-1]
    n_live = jnp.sum(live_s.astype(jnp.int32))
    # stable partition (drain rows first, in sorted order) via scatter —
    # no second argsort: drain row i lands at csum[i]-1, the rest fill
    # the tail in order
    dest = jnp.where(rem, csum - 1, n_deep + pos - csum)
    packed = jnp.zeros(B, jnp.int32).at[dest].set(pos, mode="drop")
    drain_order = jnp.where(pos < n_deep, packed, B)
    # the drain runs against a doubly-compacted table holding only the
    # DEEP segments (seg_len > par_rounds, so at most B/(par_rounds+1)
    # rows): each replay step then moves [1, W] of a cache-sized buffer.
    # drain_sid[i] = deep-table row of drain packet i (-1 = sentinel,
    # remapped by pack_segmented_operands); deep_src[d] = segment id the
    # deep-table row d was compacted from.
    deep = seg_len > par_rounds
    did = jnp.cumsum(deep.astype(jnp.int32)) - 1
    drain_sid = jnp.where(pos < n_deep, did[seg_id[packed]], -1)
    deep_src = jnp.zeros(B, jnp.int32).at[
        jnp.where(deep, did, B)
    ].set(pos, mode="drop")
    return Segments(order, inv, rank, seg_first, seg_len, seg_slot,
                    drain_order, drain_sid, deep_src, n_deep, n_live)


def deep_rows(batch: int, tile: int, par_rounds: int = PAR_ROUNDS) -> int:
    """Rows of the kernel's doubly-compacted deep-segment table: at most
    ``batch // (par_rounds + 1)`` segments can be deep, plus one sentinel
    row, snapped to the 8-row sublane tile (both CPU and TPU)."""
    del tile
    return _snap(batch // (par_rounds + 1) + 1, 8)


def pack_segmented_operands(seg: Segments, keys, regs, pkt_keys, upd, bins,
                            valid, *, tile: int, w_pad: int, u_pad: int,
                            h_pad: int):
    """Permute the batch into sorted-segment order and pad to kernel tile
    shapes.  Adds ``tile`` trailing sentinel rows (``valid == 0``,
    ``bins == -1``, ``drain_order == B``) so the kernel's unrolled drain
    can over-step past ``n_rem`` as a no-op; sentinel drain packets are
    remapped onto the deep table's reserved last row.  Narrow int
    operands keep column 0 live only."""
    S = keys.shape[0]
    B = pkt_keys.shape[0]
    b_pad = B + tile
    d_rows = deep_rows(B, tile)
    o = seg.order

    def icol(vals, fill=0):
        out = jnp.full((b_pad, tile), fill, jnp.int32)
        return out.at[:B, 0].set(vals)

    sid = jnp.where(seg.drain_sid < 0, d_rows - 1, seg.drain_sid)
    take = min(d_rows, B)
    deep_src = jnp.zeros((d_rows, tile), jnp.int32).at[:take, 0].set(
        seg.deep_src[:take])
    return (
        jnp.zeros((S, tile), jnp.int32).at[:, 0].set(keys),
        jnp.pad(regs, ((0, 0), (0, w_pad - regs.shape[1]))),
        icol(pkt_keys[o]),
        jnp.pad(upd[o], ((0, tile), (0, u_pad - upd.shape[1]))),
        jnp.pad(bins[o], ((0, tile), (0, h_pad - bins.shape[1])),
                constant_values=-1),
        icol(valid[o]),
        icol(seg.rank),
        icol(seg.seg_first),
        icol(seg.seg_len),
        icol(seg.seg_slot),
        icol(seg.drain_order, fill=B),
        icol(sid, fill=d_rows - 1),
        deep_src,
    )


def flow_update(
    keys: jax.Array,       # [S] int32 stored keys (-1 = empty)
    regs: jax.Array,       # [S, W] f32 register rows
    pkt_keys: jax.Array,   # [B] int32 per-packet flow keys (>= 0)
    upd: jax.Array,        # [B, C+E] f32 counter increments ++ EWMA values
    bins: jax.Array,       # [B, H] int32 absolute hist columns (-1 = none)
    valid: jax.Array,      # [B] int-ish; 0 = padding row, never applied
    *,
    n_counters: int,
    n_ewma: int,
    alpha: float,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """-> (keys' [S], regs' [S, W], feats [B, W]), one kernel launch.

    Bit-identical to ``flow_update_ref`` (shared per-packet step); arrival
    order within the batch preserved; see the flow-state contract in
    docs/pipeline_ir.md for the eviction/ordering guarantees."""
    if interpret is None:
        interpret = not _on_tpu()
    S, W = regs.shape
    B = int(pkt_keys.shape[0])
    H = int(bins.shape[1]) if bins.ndim == 2 else 0
    if S > MAX_SLOTS or W > MAX_WIDTH or H > MAX_HISTS or B == 0:
        return flow_update_ref(
            keys, regs, pkt_keys, upd, bins, valid,
            n_counters=n_counters, n_ewma=n_ewma, alpha=alpha,
        )
    # CPU interpret mode snaps pads to 8-wide tiles; TPU pads the last dim
    # to the full 128 lane.
    tile = 8 if interpret else LANE
    w_pad = _snap(W, tile)
    u_pad = _snap(upd.shape[1], tile)
    h_pad = _snap(H, tile) if not interpret else max(H, 1)

    keys = jnp.asarray(keys, jnp.int32)
    regs = jnp.asarray(regs, jnp.float32)
    pkt_keys = jnp.asarray(pkt_keys, jnp.int32)
    upd = jnp.asarray(upd, jnp.float32)
    bins = jnp.asarray(bins, jnp.int32)
    valid = jnp.asarray(valid, jnp.int32)

    # segment ONCE: the layout IS the kernel's schedule.  Padding rows
    # (valid=0) are excluded, so a ragged tail cannot fake a deep chain.
    seg = segment_batch(hash_slot(pkt_keys, S), valid, S)
    ops = pack_segmented_operands(
        seg, keys, regs, pkt_keys, upd, bins, valid,
        tile=tile, w_pad=w_pad, u_pad=u_pad, h_pad=h_pad,
    )
    k_out, r_out, feats = flow_update_padded(
        *ops, n_counters=n_counters, n_ewma=n_ewma, n_hists=H,
        alpha=float(alpha), interpret=interpret,
    )
    # feats come back in sorted order: inverse-permute to arrival order
    return k_out[:, 0], r_out[:, :W], feats[:B, :W][seg.inv]
