"""Public op: batched flow-register update, fused scatter/gather form.

``flow_update(keys, regs, pkt_keys, upd, bins, valid)`` pads to tile
widths, launches the Pallas kernel (interpret=True on CPU — the TPU path is
the same kernel compiled by Mosaic) and slices the padding back off.  This
is the executable artifact the Pallas serving backend
(core.pallas_backend.lower_stateful_pallas) emits for the stateful stage
prefix ``FlowKey -> RegisterUpdate``.

Falls back to the jnp scan reference when the table is outside the kernel
envelope (too many slots/too wide a row for resident VMEM).  Padding is
self-masking: padded register columns start zero and are never addressed
(absolute hist columns < W, counter/EWMA sections are static slices), so
the real columns are bit-identical to the unpadded reference.

Schedule choice: the kernel's conflict-free rounds only pay off when they
retire most of the batch (busy interleaved traffic, small per-flow
multiplicity).  The wrapper computes the batch's rank profile ONCE over
the valid rows — padding rows are excluded, so ragged tails cannot fake a
deep chain — routes drain-dominated batches (one flow owning a quiet
batch) to the reference schedule via ``lax.cond``, and passes the rank
vector into the kernel as its round schedule.  All inside the same jitted
program, and a pure schedule choice: every schedule computes identical
bits.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flow_update.kernel import (
    LANE,
    PAR_ROUNDS,
    flow_update_padded,
)
from repro.kernels.flow_update.ref import flow_update_ref, hash_slot

# kernel envelope: the whole table must sit in VMEM for the launch
MAX_SLOTS = 1 << 16
MAX_WIDTH = 256
MAX_HISTS = 8


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _snap(n: int, tile: int) -> int:
    return max(tile, -(-n // tile) * tile)


def flow_update(
    keys: jax.Array,       # [S] int32 stored keys (-1 = empty)
    regs: jax.Array,       # [S, W] f32 register rows
    pkt_keys: jax.Array,   # [B] int32 per-packet flow keys (>= 0)
    upd: jax.Array,        # [B, C+E] f32 counter increments ++ EWMA values
    bins: jax.Array,       # [B, H] int32 absolute hist columns (-1 = none)
    valid: jax.Array,      # [B] int-ish; 0 = padding row, never applied
    *,
    n_counters: int,
    n_ewma: int,
    alpha: float,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """-> (keys' [S], regs' [S, W], feats [B, W]), one kernel launch.

    Bit-identical to ``flow_update_ref`` (shared per-packet step); arrival
    order within the batch preserved; see the flow-state contract in
    docs/pipeline_ir.md for the eviction/ordering guarantees."""
    if interpret is None:
        interpret = not _on_tpu()
    S, W = regs.shape
    B = int(pkt_keys.shape[0])
    H = int(bins.shape[1]) if bins.ndim == 2 else 0
    if S > MAX_SLOTS or W > MAX_WIDTH or H > MAX_HISTS or B == 0:
        return flow_update_ref(
            keys, regs, pkt_keys, upd, bins, valid,
            n_counters=n_counters, n_ewma=n_ewma, alpha=alpha,
        )
    # CPU interpret mode snaps pads to 8-wide tiles; TPU pads the last dim
    # to the full 128 lane.  Narrow int operands keep col 0 live only.
    tile = 8 if interpret else LANE
    w_pad = _snap(W, tile)
    u_pad = _snap(upd.shape[1], tile)
    h_pad = _snap(H, tile) if not interpret else max(H, 1)

    keys = jnp.asarray(keys, jnp.int32)
    regs = jnp.asarray(regs, jnp.float32)
    pkt_keys = jnp.asarray(pkt_keys, jnp.int32)
    upd = jnp.asarray(upd, jnp.float32)
    bins = jnp.asarray(bins, jnp.int32)
    valid = jnp.asarray(valid, jnp.int32)

    # rank[p] = earlier VALID packets hashing to p's slot — the kernel's
    # round schedule AND the schedule-choice profile, computed once.
    # Padding rows (valid=0) are excluded on both sides: they never touch
    # the table, so a ragged tail cannot fake a deep chain.
    live = valid != 0
    slot = hash_slot(pkt_keys, S)
    p_i = jax.lax.broadcasted_iota(jnp.int32, (B, B), 0)
    q_i = jax.lax.broadcasted_iota(jnp.int32, (B, B), 1)
    rank = jnp.sum(((slot[:, None] == slot[None, :]) & (q_i < p_i)
                    & live[None, :]).astype(jnp.int32), axis=1)

    def launch(_):
        keys2 = jnp.zeros((S, tile), jnp.int32).at[:, 0].set(keys)
        regs2 = jnp.pad(regs, ((0, 0), (0, w_pad - W)))
        pk2 = jnp.zeros((B, tile), jnp.int32).at[:, 0].set(pkt_keys)
        upd2 = jnp.pad(upd, ((0, 0), (0, u_pad - upd.shape[1])))
        bins2 = jnp.pad(bins, ((0, 0), (0, h_pad - H)), constant_values=-1)
        valid2 = jnp.zeros((B, tile), jnp.int32).at[:, 0].set(valid)
        rank2 = jnp.zeros((B, tile), jnp.int32).at[:, 0].set(rank)
        k_out, r_out, feats = flow_update_padded(
            keys2, regs2, pk2, upd2, bins2, valid2, rank2,
            n_counters=n_counters, n_ewma=n_ewma, n_hists=H,
            alpha=float(alpha), interpret=interpret,
        )
        return k_out[:, 0], r_out[:, :W], feats[:, :W]

    def reference(_):
        return flow_update_ref(
            keys, regs, pkt_keys, upd, bins, valid,
            n_counters=n_counters, n_ewma=n_ewma, alpha=alpha,
        )

    # route drain-dominated batches (deep chains the rounds cannot retire)
    # to the reference walk
    n_deep = jnp.sum((live & (rank >= PAR_ROUNDS)).astype(jnp.int32))
    n_live = jnp.maximum(jnp.sum(live.astype(jnp.int32)), 1)
    return jax.lax.cond(n_deep * 2 > n_live, reference, launch, 0)
