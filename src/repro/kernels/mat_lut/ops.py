"""Public op: fused MAT (quantized-LUT) pipeline inference.

``mat_classify(x, edges, tables, label_map)`` pads/packs, launches the
Pallas kernel (interpret=True on CPU — the TPU path is the same kernel
compiled by Mosaic), and returns int32 verdicts.  This is the executable
artifact the Pallas serving backend (core.pallas_backend) emits for
Tofino-style Quantize -> LUTGather -> Reduce -> LabelMap stage pipelines.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.mat_lut.kernel import (
    DEFAULT_BLOCK_B,
    LANE,
    mat_pipeline_padded,
)
from repro.kernels.mat_lut.ref import mat_pipeline_ref

# kernel envelope: per-feature MATs are unrolled statically, tables must
# sit in VMEM, verdict lanes in one tile
MAX_FEATURES = 64
MAX_BINS = 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _snap(n: int, tile: int) -> int:
    return max(tile, -(-n // tile) * tile)


def mat_classify(
    x: jax.Array,          # [B, F] f32
    edges: jax.Array,      # [F, BINS-1]
    tables: jax.Array,     # [F, BINS, C]
    label_map: jax.Array,  # [K] int
    *,
    use_min: bool = False,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool | None = None,
) -> jax.Array:
    """x: [B, F] -> verdicts [B] int32, the whole MAT pipeline fused.

    Falls back to the jnp reference when the tables are outside the kernel
    envelope (too many features/bins/classes for resident VMEM tables)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, F = x.shape
    bins, C = tables.shape[1], tables.shape[2]
    K = label_map.shape[0]
    if F > MAX_FEATURES or bins > MAX_BINS or C > LANE or K > LANE:
        return mat_pipeline_ref(
            x, edges, tables, label_map, use_min=use_min
        ).astype(jnp.int32)
    # CPU interpret mode snaps pads to 8-wide tiles; TPU pads last dims to
    # the full 128 lane (second-to-last / leading dims only need sublanes)
    tile = 8 if interpret else LANE
    block_b = min(block_b, max(8, B))
    pad_b = (-B) % block_b
    x_pad = jnp.pad(
        jnp.asarray(x, jnp.float32),
        ((0, pad_b), (0, _snap(F, tile) - F)),   # features are x's LAST dim
    )
    e_pad = _snap(edges.shape[1], tile)
    edges_pad = jnp.pad(
        jnp.asarray(edges, jnp.float32),
        ((0, _snap(F, 8) - F), (0, e_pad - edges.shape[1])),
        constant_values=jnp.inf,      # padded edges never count into buckets
    )
    c_pad = _snap(C, tile)
    tables_pad = jnp.pad(
        jnp.asarray(tables, jnp.float32),
        ((0, _snap(F, 8) - F), (0, _snap(bins, tile) - bins),
         (0, c_pad - C)),
    )
    lmap_pad = jnp.pad(
        jnp.asarray(label_map, jnp.float32), (0, _snap(K, tile) - K)
    )[None, :]
    out = mat_pipeline_padded(
        x_pad, edges_pad, tables_pad, lmap_pad,
        n_features=F, n_classes=C, use_min=use_min,
        block_b=block_b, interpret=interpret,
    )
    return out[:B, 0]


def mat_classify_reference(x, edges, tables, label_map, *, use_min=False):
    return mat_pipeline_ref(x, edges, tables, label_map, use_min=use_min)
