"""Pallas TPU kernel: whole MAT (quantized-LUT) pipeline in ONE launch.

This is the TPU-native translation of the IIsy-style match-action-table
pipeline the Tofino backend emits (core.codegen.mat_stages): per-feature
range tables quantize each value to a bucket, per-feature MATs map bucket ->
per-class partial scores, partials sum across features, and argmax/argmin
plus the verdict-rewrite table pick the class.  The interpreter executes
that as four stage applies (searchsorted, gather, reduce, gather); here the
whole dataflow is one ``pallas_call``, so a packet batch makes a single
HBM->VMEM round trip and only int32 verdicts come back.

Two gather-free constructions keep it on the vector/matrix units:

  * quantize: ``searchsorted(edges, v)`` (side='left') == the count of
    edges strictly below v, computed as a [block_b, BINS-1] compare+sum —
    exact integer math, no binary search;
  * LUT gather: ``table[bucket]`` as a one-hot [block_b, BINS] x
    [BINS, C] matmul (the classic TPU gather-as-matmul idiom; exact —
    each row sums one table entry and zeros).  The verdict rewrite
    (LabelMap) reuses the same trick on [K] at the end.

Grid: (B / block_b,).  Edges [F, BINS-1], tables [F, BINS, C] and the label
map stay resident in VMEM across the whole launch; the batch tile streams.
Zero/`+inf` padding is self-masking: padded edges (+inf) never count into a
bucket, padded table lanes contribute exact zeros, and padded class lanes
are masked to -/+inf before the arg-reduce.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK_B = 256


def _kernel(x_ref, edges_ref, tables_ref, lmap_ref, o_ref, *,
            n_features: int, n_classes: int, use_min: bool):
    """x_ref: [block_b, F_pad]; edges_ref: [F_pad, E_pad];
    tables_ref: [F_pad, BINS, C_pad]; lmap_ref: [1, K_pad]."""
    x = x_ref[...].astype(jnp.float32)
    bins_cap = tables_ref.shape[1]
    n_pkt = x.shape[0]
    bin_iota = jax.lax.broadcasted_iota(jnp.int32, (n_pkt, bins_cap), 1)
    scores = jnp.zeros((n_pkt, tables_ref.shape[2]), jnp.float32)
    for f in range(n_features):      # static unroll: one MAT per feature
        col = x[:, f][:, None]                              # [B, 1]
        edges = edges_ref[f][None, :]                       # [1, E_pad]
        # searchsorted(side='left'): bucket = #edges strictly below value
        bucket = jnp.sum((col > edges).astype(jnp.int32), axis=1)
        onehot = (bin_iota == bucket[:, None]).astype(jnp.float32)
        scores = scores + jnp.dot(
            onehot, tables_ref[f].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
    lane = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    if use_min:
        scores = jnp.where(lane < n_classes, scores, jnp.inf)
        ids = jnp.argmin(scores, axis=1).astype(jnp.int32)
    else:
        scores = jnp.where(lane < n_classes, scores, -jnp.inf)
        ids = jnp.argmax(scores, axis=1).astype(jnp.int32)
    # LabelMap: verdict rewrite as one more one-hot matvec (exact)
    k_pad = lmap_ref.shape[1]
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (n_pkt, k_pad), 1)
    onehot_k = (k_iota == ids[:, None]).astype(jnp.float32)
    verdict = jnp.dot(
        onehot_k, lmap_ref[0].astype(jnp.float32)[:, None],
        preferred_element_type=jnp.float32,
    )[:, 0].astype(jnp.int32)
    o_ref[...] = jnp.broadcast_to(verdict[:, None], o_ref.shape)


@functools.partial(
    jax.jit, static_argnames=("n_features", "n_classes", "use_min",
                              "block_b", "interpret")
)
def mat_pipeline_padded(
    x_pad: jax.Array,      # [B_pad, F_pad] f32
    edges: jax.Array,      # [F_pad, E_pad] f32 (+inf padded)
    tables: jax.Array,     # [F_pad, BINS, C_pad] f32 (zero padded)
    lmap: jax.Array,       # [1, K_pad] f32 (zero padded)
    *,
    n_features: int,
    n_classes: int,
    use_min: bool,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
) -> jax.Array:
    """-> [B_pad, C_pad] int32, verdict broadcast across lanes (take col 0)."""
    B, f_pad = x_pad.shape
    assert B % block_b == 0
    _, e_pad = edges.shape
    _, bins, c_pad = tables.shape
    k_pad = lmap.shape[1]
    grid = (B // block_b,)
    return pl.pallas_call(
        functools.partial(
            _kernel, n_features=n_features, n_classes=n_classes,
            use_min=use_min,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, f_pad), lambda i: (i, 0)),
            # tables resident in VMEM across the whole launch
            pl.BlockSpec((f_pad, e_pad), lambda i: (0, 0)),
            pl.BlockSpec((f_pad, bins, c_pad), lambda i: (0, 0, 0)),
            pl.BlockSpec((1, k_pad), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, c_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, c_pad), jnp.int32),
        interpret=interpret,
    )(x_pad, edges, tables, lmap)


def vmem_bytes(n_features: int, bins: int, n_classes: int,
               block_b: int = DEFAULT_BLOCK_B) -> int:
    """VMEM working set the kernel claims (feasibility input)."""
    tables = n_features * bins * n_classes * 4 + n_features * (bins - 1) * 4
    tiles = 2 * 2 * block_b * max(n_features, n_classes) * 4
    return tables + tiles
