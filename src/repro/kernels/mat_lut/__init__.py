from repro.kernels.mat_lut.ops import (
    mat_classify,
    mat_classify_reference,
    MAX_BINS,
    MAX_FEATURES,
)
from repro.kernels.mat_lut.ref import mat_pipeline_ref
from repro.kernels.mat_lut.kernel import vmem_bytes, LANE
