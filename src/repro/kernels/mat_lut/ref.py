"""Pure-jnp oracle for the fused MAT (quantized-LUT) pipeline.

Exactly the stage math of the IR's interpreter path for a Tofino-style
pipeline (core.stageir: Quantize -> LUTGather -> Reduce -> LabelMap):
per-feature range tables bucket each value, per-feature MATs map bucket ->
per-class partial scores, partials sum across features, argmax/argmin
picks the verdict, and a final table rewrites cluster/leaf ids to classes.
The kernel test asserts verdict equality against this function.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mat_pipeline_ref(
    x: jax.Array,          # [B, F] f32 packet features
    edges: jax.Array,      # [F, BINS-1] range-table edges
    tables: jax.Array,     # [F, BINS, C] per-feature partial scores
    label_map: jax.Array,  # [K] int verdict rewrite (identity when unused)
    *,
    use_min: bool = False,
) -> jax.Array:
    """-> verdicts [B] int32; same searchsorted/gather math as the stages."""
    bins = jax.vmap(
        lambda col, e: jnp.searchsorted(e, col), in_axes=(1, 0), out_axes=1
    )(x, edges)                                         # [B, F]
    partial = jax.vmap(
        lambda b, t: t[b], in_axes=(1, 0), out_axes=1
    )(bins, tables)                                     # [B, F, C]
    scores = partial.sum(1)                             # [B, C]
    fn = jnp.argmin if use_min else jnp.argmax
    ids = fn(scores, -1)
    return jnp.asarray(label_map, jnp.int32)[ids]
