"""Pallas TPU kernel: whole per-packet DNN fused into ONE kernel launch.

This is the TPU-native translation of the paper's Taurus MapReduce pipeline
(Fig. 5): the paper stitches dot-product map/reduce templates into layers and
layers into a pipeline with double-buffered SRAM between stages.  On TPU the
equivalent is a single Pallas kernel where

  * every layer's weights are resident in VMEM for the whole launch (the
    "on-chip memory" of the MapReduce grid; weights never re-read from HBM),
  * the batch is tiled into MXU-aligned blocks (block_b x 128) that stream
    through HBM -> VMEM double-buffering (pallas_call pipelines the grid),
  * layer widths are zero-padded to the 128-lane MXU tile so each layer is
    exactly one 128x128 MXU matmul per batch tile -- a "CU" in our resource
    model (core.feasibility) is one such tile-op.

Zero padding is self-masking: padded weight columns/rows are 0 and padded
biases are 0, so padded activations stay identically 0 through ReLU chains.
Because the pad lanes are exact zeros, the padded matmul is bit-identical
to the unpadded one, so the lane width is a pure tuning knob: the padded
entry points accept any ``lane`` (the Pallas serving backend snaps it to
the model width in interpret mode instead of paying 128-wide tiles on CPU;
on TPU it stays ``LANE`` = the MXU tile).

Grid: (B / block_b,).  VMEM working set = L*lane*lane*4 B of weights
(+2 batch tiles), which core.feasibility checks against the VMEM budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128  # MXU/VREG lane width: TPU layer widths pad to this
DEFAULT_BLOCK_B = 256
# Interpret-mode (CPU) batch tile for fused-DAG launches: the emulated
# grid loop is pure overhead there, so one big tile covers the whole
# micro-batch.  On TPU a single launch streams the grid regardless of the
# tile size, so the DAG keeps the single-model DEFAULT_BLOCK_B (smaller
# VMEM tiles, same launch count); dag_vmem_bytes is the resident set the
# lowering budgets either way.
DAG_BLOCK_B = 1024


def snap_lane(widths: list[int], *, interpret: bool) -> int:
    """Lane width for a model whose widest layer is max(widths).

    On TPU (interpret=False) this is always ``LANE`` — the MXU tile.  In
    interpret mode (CPU) padding to 128 only burns FLOPs, so snap to the
    smallest multiple of 8 covering the model instead (bit-identical: pad
    lanes are exact zeros either way)."""
    if not interpret:
        return LANE
    return min(LANE, max(8, -(-max(widths) // 8) * 8))


def _kernel(x_ref, w_ref, b_ref, o_ref, *, n_layers: int):
    """x_ref: [block_b, LANE]; w_ref: [L, LANE, LANE]; b_ref: [L, LANE]."""
    h = x_ref[...].astype(jnp.float32)
    for l in range(n_layers):  # static unroll: the whole DNN in one launch
        w = w_ref[l].astype(jnp.float32)
        h = jnp.dot(h, w, preferred_element_type=jnp.float32)
        h = h + b_ref[l][None, :]
        if l < n_layers - 1:
            h = jnp.maximum(h, 0.0)
    o_ref[...] = h.astype(o_ref.dtype)


def _classify_kernel(x_ref, w_ref, b_ref, o_ref, *, n_layers: int,
                     num_classes: int):
    """Fused MLP + argmax: class ids leave the kernel, logits never touch
    HBM.  Padded lanes >= num_classes are masked to -inf before the argmax,
    so the result equals argmax over the first num_classes logits."""
    h = x_ref[...].astype(jnp.float32)
    for l in range(n_layers):
        w = w_ref[l].astype(jnp.float32)
        h = jnp.dot(h, w, preferred_element_type=jnp.float32)
        h = h + b_ref[l][None, :]
        if l < n_layers - 1:
            h = jnp.maximum(h, 0.0)
    lane = jax.lax.broadcasted_iota(jnp.int32, h.shape, 1)
    h = jnp.where(lane < num_classes, h, -jnp.inf)
    cls = jnp.argmax(h, axis=1).astype(jnp.int32)
    o_ref[...] = jnp.broadcast_to(cls[:, None], o_ref.shape)


@functools.partial(
    jax.jit, static_argnames=("n_layers", "num_classes", "block_b",
                              "interpret")
)
def fused_mlp_classify_padded(
    x_pad: jax.Array,     # [B_pad, LANE]
    w_stack: jax.Array,   # [L, LANE, LANE]
    b_stack: jax.Array,   # [L, LANE]
    *,
    n_layers: int,
    num_classes: int,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
) -> jax.Array:
    """-> [B_pad, lane] int32, class id broadcast across lanes (take col 0)."""
    B, lane = x_pad.shape
    assert B % block_b == 0
    grid = (B // block_b,)
    return pl.pallas_call(
        functools.partial(
            _classify_kernel, n_layers=n_layers, num_classes=num_classes
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, lane), lambda i: (i, 0)),
            pl.BlockSpec((n_layers, lane, lane), lambda i: (0, 0, 0)),
            pl.BlockSpec((n_layers, lane), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, lane), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, lane), jnp.int32),
        interpret=interpret,
    )(x_pad, w_stack, b_stack)


# ------------------------------------------------------- cross-model DAG
#
# A whole Seq/Par DAG of MLP-shaped models executed as ONE kernel launch:
# every model's weight stack is resident in VMEM for the launch, each model
# runs its statically-unrolled layer chain on the same input tile, and the
# DAG's gating/merge ops (Seq short-circuit as where-masks, Par or/and as
# max/min) apply in-kernel on the int32 verdicts — so chained models cost
# one HBM round trip total instead of one per model.
#
# The DAG structure is a *plan*: nested hashable tuples
#   ("model", i)                   leaf — verdict of model i
#   ("seq", (p0, p1, ...))         gate: flagged packets keep their verdict
#   ("or"|"and", (p0, p1, ...))    parallel merge: max / min
# traced statically into the kernel, mirroring chaining.compile_dag.


def eval_dag_plan(plan: tuple, verdicts: list) -> jax.Array:
    """Fold per-model verdicts through the DAG plan (traceable; used both
    inside the kernel and by reference implementations)."""
    kind = plan[0]
    if kind == "model":
        return verdicts[plan[1]]
    parts = [eval_dag_plan(p, verdicts) for p in plan[1]]
    if kind == "seq":
        out = parts[0]
        for nxt in parts[1:]:
            out = jnp.where(out > 0, out, nxt)
        return out
    if kind == "or":
        return functools.reduce(jnp.maximum, parts)
    if kind == "and":
        return functools.reduce(jnp.minimum, parts)
    raise KeyError(f"unknown DAG plan node {kind!r}")


def _dag_kernel(x_ref, *refs, n_layers: tuple, n_classes: tuple,
                lanes: tuple, plan: tuple):
    """refs = (w_0, b_0, w_1, b_1, ..., o_ref): one (weights, biases) stack
    pair per model, the int32 verdict tile last.  Each model runs at its
    OWN snapped lane (``lanes[i]``) on a static slice of the input tile —
    the fused launch then does exactly the per-model path's FLOPs (on TPU
    every lane is the 128-wide MXU tile and the slices are no-ops)."""
    o_ref = refs[-1]
    h0 = x_ref[...].astype(jnp.float32)
    verdicts = []
    for i, n_l in enumerate(n_layers):
        w_ref, b_ref = refs[2 * i], refs[2 * i + 1]
        h = h0[:, :lanes[i]]
        for l in range(n_l):
            w = w_ref[l].astype(jnp.float32)
            h = jnp.dot(h, w, preferred_element_type=jnp.float32)
            h = h + b_ref[l][None, :]
            if l < n_l - 1:
                h = jnp.maximum(h, 0.0)
        lane_ids = jax.lax.broadcasted_iota(jnp.int32, h.shape, 1)
        h = jnp.where(lane_ids < n_classes[i], h, -jnp.inf)
        verdicts.append(jnp.argmax(h, axis=1).astype(jnp.int32))
    v = eval_dag_plan(plan, verdicts)
    o_ref[...] = jnp.broadcast_to(v[:, None], o_ref.shape)


@functools.partial(
    jax.jit, static_argnames=("n_layers", "n_classes", "lanes", "plan",
                              "block_b", "interpret")
)
def fused_dag_padded(
    x_pad: jax.Array,     # [B_pad, max(lanes)]
    *stacks: jax.Array,   # per model: w [L_i, lane_i, lane_i], b [L_i, lane_i]
    n_layers: tuple,
    n_classes: tuple,
    lanes: tuple,
    plan: tuple,
    block_b: int = DAG_BLOCK_B,
    interpret: bool = False,
) -> jax.Array:
    """-> [B_pad, max(lanes)] int32, DAG verdict broadcast (take col 0)."""
    B, x_lane = x_pad.shape
    assert B % block_b == 0
    assert len(stacks) == 2 * len(n_layers)
    assert x_lane == max(lanes)
    grid = (B // block_b,)
    in_specs = [pl.BlockSpec((block_b, x_lane), lambda i: (i, 0))]
    for n_l, lane in zip(n_layers, lanes):
        in_specs.append(
            pl.BlockSpec((n_l, lane, lane), lambda i: (0, 0, 0))
        )
        in_specs.append(pl.BlockSpec((n_l, lane), lambda i: (0, 0)))
    return pl.pallas_call(
        functools.partial(_dag_kernel, n_layers=n_layers,
                          n_classes=n_classes, lanes=lanes, plan=plan),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, x_lane), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, x_lane), jnp.int32),
        interpret=interpret,
    )(x_pad, *stacks)


def dag_vmem_bytes(n_layers: tuple, lanes: tuple,
                   block_b: int = DEFAULT_BLOCK_B) -> int:
    """VMEM working set of the fused-DAG launch: every chained model's
    weight stack resident at once (each at its own lane), plus the
    double-buffered batch tiles at the widest lane.  The lowering gates
    DAG fusion on this fitting ``DAG_VMEM_BUDGET`` — oversized DAGs fall
    back to per-model launches instead of failing at Mosaic lowering."""
    weights = sum(n_l * (lane * lane + lane) * 4
                  for n_l, lane in zip(n_layers, lanes))
    tiles = 2 * 2 * block_b * max(lanes) * 4
    return weights + tiles


# matches the TPU platform's working-set budget (core.feasibility
# TPUModel.vmem_bytes): the megakernel must leave the envelope honestly
# rather than claim a launch that cannot be resident
DAG_VMEM_BUDGET = 64 * 2**20


def pad_to_lane(arr: jax.Array, axis: int, lane: int = LANE) -> jax.Array:
    n = arr.shape[axis]
    pad = (-n) % lane
    if pad == 0:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, pad)
    return jnp.pad(arr, widths)


def pack_params(weights: list[jax.Array], biases: list[jax.Array],
                lane: int = LANE) -> tuple[jax.Array, jax.Array]:
    """Zero-pad every layer to [lane, lane] and stack: -> ([L,lane,lane],
    [L,lane]).  Requires every layer dim <= lane (per-packet models are)."""
    ws, bs = [], []
    for w, b in zip(weights, biases):
        assert w.shape[0] <= lane and w.shape[1] <= lane, (
            f"fused_mlp supports layer dims <= {lane}, got {w.shape}"
        )
        ws.append(pad_to_lane(pad_to_lane(w, 0, lane), 1, lane))
        bs.append(pad_to_lane(b, 0, lane))
    return jnp.stack(ws).astype(jnp.float32), jnp.stack(bs).astype(jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("n_layers", "block_b", "interpret")
)
def fused_mlp_padded(
    x_pad: jax.Array,     # [B_pad, LANE]
    w_stack: jax.Array,   # [L, LANE, LANE]
    b_stack: jax.Array,   # [L, LANE]
    *,
    n_layers: int,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
) -> jax.Array:
    B, lane = x_pad.shape
    assert B % block_b == 0
    grid = (B // block_b,)
    return pl.pallas_call(
        functools.partial(_kernel, n_layers=n_layers),
        grid=grid,
        in_specs=[
            # batch tile streams; index_map in block units
            pl.BlockSpec((block_b, lane), lambda i: (i, 0)),
            # weights: whole stack resident in VMEM every grid step
            pl.BlockSpec((n_layers, lane, lane), lambda i: (0, 0, 0)),
            pl.BlockSpec((n_layers, lane), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, lane), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, lane), x_pad.dtype),
        interpret=interpret,
    )(x_pad, w_stack, b_stack)


def vmem_bytes(n_layers: int, block_b: int = DEFAULT_BLOCK_B,
               lane: int = LANE) -> int:
    """VMEM working set the kernel claims (feasibility input)."""
    weights = n_layers * lane * lane * 4 + n_layers * lane * 4
    tiles = 2 * 2 * block_b * lane * 4  # double-buffered in + out tiles
    return weights + tiles
