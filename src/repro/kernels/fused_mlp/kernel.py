"""Pallas TPU kernel: whole per-packet DNN fused into ONE kernel launch.

This is the TPU-native translation of the paper's Taurus MapReduce pipeline
(Fig. 5): the paper stitches dot-product map/reduce templates into layers and
layers into a pipeline with double-buffered SRAM between stages.  On TPU the
equivalent is a single Pallas kernel where

  * every layer's weights are resident in VMEM for the whole launch (the
    "on-chip memory" of the MapReduce grid; weights never re-read from HBM),
  * the batch is tiled into MXU-aligned blocks (block_b x 128) that stream
    through HBM -> VMEM double-buffering (pallas_call pipelines the grid),
  * layer widths are zero-padded to the 128-lane MXU tile so each layer is
    exactly one 128x128 MXU matmul per batch tile -- a "CU" in our resource
    model (core.feasibility) is one such tile-op.

Zero padding is self-masking: padded weight columns/rows are 0 and padded
biases are 0, so padded activations stay identically 0 through ReLU chains.
Because the pad lanes are exact zeros, the padded matmul is bit-identical
to the unpadded one, so the lane width is a pure tuning knob: the padded
entry points accept any ``lane`` (the Pallas serving backend snaps it to
the model width in interpret mode instead of paying 128-wide tiles on CPU;
on TPU it stays ``LANE`` = the MXU tile).

Grid: (B / block_b,).  VMEM working set = L*lane*lane*4 B of weights
(+2 batch tiles), which core.feasibility checks against the VMEM budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128  # MXU/VREG lane width: TPU layer widths pad to this
DEFAULT_BLOCK_B = 256


def snap_lane(widths: list[int], *, interpret: bool) -> int:
    """Lane width for a model whose widest layer is max(widths).

    On TPU (interpret=False) this is always ``LANE`` — the MXU tile.  In
    interpret mode (CPU) padding to 128 only burns FLOPs, so snap to the
    smallest multiple of 8 covering the model instead (bit-identical: pad
    lanes are exact zeros either way)."""
    if not interpret:
        return LANE
    return min(LANE, max(8, -(-max(widths) // 8) * 8))


def _kernel(x_ref, w_ref, b_ref, o_ref, *, n_layers: int):
    """x_ref: [block_b, LANE]; w_ref: [L, LANE, LANE]; b_ref: [L, LANE]."""
    h = x_ref[...].astype(jnp.float32)
    for l in range(n_layers):  # static unroll: the whole DNN in one launch
        w = w_ref[l].astype(jnp.float32)
        h = jnp.dot(h, w, preferred_element_type=jnp.float32)
        h = h + b_ref[l][None, :]
        if l < n_layers - 1:
            h = jnp.maximum(h, 0.0)
    o_ref[...] = h.astype(o_ref.dtype)


def _classify_kernel(x_ref, w_ref, b_ref, o_ref, *, n_layers: int,
                     num_classes: int):
    """Fused MLP + argmax: class ids leave the kernel, logits never touch
    HBM.  Padded lanes >= num_classes are masked to -inf before the argmax,
    so the result equals argmax over the first num_classes logits."""
    h = x_ref[...].astype(jnp.float32)
    for l in range(n_layers):
        w = w_ref[l].astype(jnp.float32)
        h = jnp.dot(h, w, preferred_element_type=jnp.float32)
        h = h + b_ref[l][None, :]
        if l < n_layers - 1:
            h = jnp.maximum(h, 0.0)
    lane = jax.lax.broadcasted_iota(jnp.int32, h.shape, 1)
    h = jnp.where(lane < num_classes, h, -jnp.inf)
    cls = jnp.argmax(h, axis=1).astype(jnp.int32)
    o_ref[...] = jnp.broadcast_to(cls[:, None], o_ref.shape)


@functools.partial(
    jax.jit, static_argnames=("n_layers", "num_classes", "block_b",
                              "interpret")
)
def fused_mlp_classify_padded(
    x_pad: jax.Array,     # [B_pad, LANE]
    w_stack: jax.Array,   # [L, LANE, LANE]
    b_stack: jax.Array,   # [L, LANE]
    *,
    n_layers: int,
    num_classes: int,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
) -> jax.Array:
    """-> [B_pad, lane] int32, class id broadcast across lanes (take col 0)."""
    B, lane = x_pad.shape
    assert B % block_b == 0
    grid = (B // block_b,)
    return pl.pallas_call(
        functools.partial(
            _classify_kernel, n_layers=n_layers, num_classes=num_classes
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, lane), lambda i: (i, 0)),
            pl.BlockSpec((n_layers, lane, lane), lambda i: (0, 0, 0)),
            pl.BlockSpec((n_layers, lane), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, lane), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, lane), jnp.int32),
        interpret=interpret,
    )(x_pad, w_stack, b_stack)


def pad_to_lane(arr: jax.Array, axis: int, lane: int = LANE) -> jax.Array:
    n = arr.shape[axis]
    pad = (-n) % lane
    if pad == 0:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, pad)
    return jnp.pad(arr, widths)


def pack_params(weights: list[jax.Array], biases: list[jax.Array],
                lane: int = LANE) -> tuple[jax.Array, jax.Array]:
    """Zero-pad every layer to [lane, lane] and stack: -> ([L,lane,lane],
    [L,lane]).  Requires every layer dim <= lane (per-packet models are)."""
    ws, bs = [], []
    for w, b in zip(weights, biases):
        assert w.shape[0] <= lane and w.shape[1] <= lane, (
            f"fused_mlp supports layer dims <= {lane}, got {w.shape}"
        )
        ws.append(pad_to_lane(pad_to_lane(w, 0, lane), 1, lane))
        bs.append(pad_to_lane(b, 0, lane))
    return jnp.stack(ws).astype(jnp.float32), jnp.stack(bs).astype(jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("n_layers", "block_b", "interpret")
)
def fused_mlp_padded(
    x_pad: jax.Array,     # [B_pad, LANE]
    w_stack: jax.Array,   # [L, LANE, LANE]
    b_stack: jax.Array,   # [L, LANE]
    *,
    n_layers: int,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
) -> jax.Array:
    B, lane = x_pad.shape
    assert B % block_b == 0
    grid = (B // block_b,)
    return pl.pallas_call(
        functools.partial(_kernel, n_layers=n_layers),
        grid=grid,
        in_specs=[
            # batch tile streams; index_map in block units
            pl.BlockSpec((block_b, lane), lambda i: (i, 0)),
            # weights: whole stack resident in VMEM every grid step
            pl.BlockSpec((n_layers, lane, lane), lambda i: (0, 0, 0)),
            pl.BlockSpec((n_layers, lane), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, lane), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, lane), x_pad.dtype),
        interpret=interpret,
    )(x_pad, w_stack, b_stack)


def vmem_bytes(n_layers: int, block_b: int = DEFAULT_BLOCK_B,
               lane: int = LANE) -> int:
    """VMEM working set the kernel claims (feasibility input)."""
    weights = n_layers * lane * lane * 4 + n_layers * lane * 4
    tiles = 2 * 2 * block_b * lane * 4  # double-buffered in + out tiles
    return weights + tiles
