"""Public op: fused per-packet MLP inference.

``fused_mlp(x, weights, biases)`` pads/packs, launches the Pallas kernel
(interpret=True on CPU — the TPU path is the same kernel compiled by
Mosaic), and slices the logits back to the true class count.

This is the executable artifact the Homunculus Taurus backend emits
(core.codegen.TaurusBackend): the generated pipeline closure calls this op
with the trained weights baked in.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fused_mlp.kernel import (
    DAG_BLOCK_B,
    DEFAULT_BLOCK_B,
    LANE,
    eval_dag_plan,
    fused_dag_padded,
    fused_mlp_classify_padded,
    fused_mlp_padded,
    pack_params,
    pad_to_lane,
)
from repro.kernels.fused_mlp.ref import mlp_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _prepare(x, weights, interpret, block_b, lane):
    """Shared kernel preamble for both entry points.

    -> None when the model is outside the fused kernel's envelope (wide
    layers -> XLA reference path), else (x_pad, block_b, interpret, lane)."""
    if interpret is None:
        interpret = not _on_tpu()
    if lane is None:
        lane = LANE
    B, F = x.shape
    if F > lane or any(w.shape[1] > lane for w in weights):
        return None
    block_b = min(block_b, max(8, B))
    pad_b = (-B) % block_b
    x_pad = pad_to_lane(jnp.pad(x, ((0, pad_b), (0, 0))), 1, lane)
    return x_pad, block_b, interpret, lane


def fused_mlp(
    x: jax.Array,
    weights: list[jax.Array],
    biases: list[jax.Array],
    *,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool | None = None,
    lane: int | None = None,
) -> jax.Array:
    """x: [B, F] -> logits [B, num_classes].

    ``lane`` is the padded layer width (default the 128-wide MXU tile);
    the Pallas serving backend passes ``kernel.snap_lane`` so CPU interpret
    mode runs model-sized tiles.  Numerics are lane-independent: pad lanes
    are exact zeros."""
    prep = _prepare(x, weights, interpret, block_b, lane)
    if prep is None:
        return mlp_ref(x, weights, biases)
    x_pad, block_b, interpret, lane = prep
    w_stack, b_stack = pack_params(weights, biases, lane)
    out = fused_mlp_padded(
        x_pad, w_stack, b_stack,
        n_layers=len(weights), block_b=block_b, interpret=interpret,
    )
    return out[:x.shape[0], :weights[-1].shape[1]]


def fused_mlp_classify(
    x: jax.Array,
    weights: list[jax.Array],
    biases: list[jax.Array],
    *,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool | None = None,
    lane: int | None = None,
) -> jax.Array:
    """x: [B, F] -> class ids [B] int32, argmax fused into the kernel.

    Same ``lane`` contract as :func:`fused_mlp`."""
    prep = _prepare(x, weights, interpret, block_b, lane)
    if prep is None:
        return jnp.argmax(mlp_ref(x, weights, biases), -1).astype(jnp.int32)
    x_pad, block_b, interpret, lane = prep
    w_stack, b_stack = pack_params(weights, biases, lane)
    out = fused_mlp_classify_padded(
        x_pad, w_stack, b_stack,
        n_layers=len(weights), num_classes=weights[-1].shape[1],
        block_b=block_b, interpret=interpret,
    )
    return out[:x.shape[0], 0]


def fused_mlp_reference(x, weights, biases):
    return mlp_ref(x, weights, biases)


def fused_dag(
    x: jax.Array,
    stacks: tuple,
    *,
    n_layers: tuple,
    n_classes: tuple,
    lanes: tuple,
    plan: tuple,
    block_b: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Whole-DAG megakernel entry: x [B, F] -> verdicts [B] int32.

    ``stacks`` is the flat tuple of per-model (w_stack, b_stack) pairs,
    each packed at its model's own snapped lane (``lanes[i]``); ``plan``
    the static DAG structure (see ``kernel.eval_dag_plan``).  One
    ``pallas_call`` for the entire chained/parallel model DAG: weights for
    ALL models resident in VMEM, gating applied in-kernel on int32
    verdicts.  The batch tile is ``DAG_BLOCK_B`` in interpret mode (the
    emulated grid loop is pure overhead on CPU, so one tile covers the
    micro-batch) and the single-model ``DEFAULT_BLOCK_B`` on TPU (one
    launch streams the grid either way; smaller tiles keep the VMEM
    working set down), clamped to the padded batch."""
    if interpret is None:
        interpret = not _on_tpu()
    if block_b is None:
        block_b = DAG_BLOCK_B if interpret else DEFAULT_BLOCK_B
    B = x.shape[0]
    block_b = min(block_b, max(8, B))
    pad_b = (-B) % block_b
    x_pad = pad_to_lane(jnp.pad(x, ((0, pad_b), (0, 0))), 1, max(lanes))
    out = fused_dag_padded(
        x_pad, *stacks, n_layers=n_layers, n_classes=n_classes,
        lanes=lanes, plan=plan, block_b=block_b, interpret=interpret,
    )
    return out[:B, 0]


def fused_dag_reference(x, models: list, plan: tuple) -> jax.Array:
    """jnp oracle for the megakernel: per-model MLP+argmax, plan folded on
    the verdicts.  ``models`` is a list of (weights, biases) lists."""
    verdicts = [
        jnp.argmax(mlp_ref(x, w, b), -1).astype(jnp.int32)
        for w, b in models
    ]
    return eval_dag_plan(plan, verdicts)
