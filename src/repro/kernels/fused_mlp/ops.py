"""Public op: fused per-packet MLP inference.

``fused_mlp(x, weights, biases)`` pads/packs, launches the Pallas kernel
(interpret=True on CPU — the TPU path is the same kernel compiled by
Mosaic), and slices the logits back to the true class count.

This is the executable artifact the Homunculus Taurus backend emits
(core.codegen.TaurusBackend): the generated pipeline closure calls this op
with the trained weights baked in.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fused_mlp.kernel import (
    DEFAULT_BLOCK_B,
    LANE,
    fused_mlp_classify_padded,
    fused_mlp_padded,
    pack_params,
    pad_to_lane,
)
from repro.kernels.fused_mlp.ref import mlp_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _prepare(x, weights, interpret, block_b, lane):
    """Shared kernel preamble for both entry points.

    -> None when the model is outside the fused kernel's envelope (wide
    layers -> XLA reference path), else (x_pad, block_b, interpret, lane)."""
    if interpret is None:
        interpret = not _on_tpu()
    if lane is None:
        lane = LANE
    B, F = x.shape
    if F > lane or any(w.shape[1] > lane for w in weights):
        return None
    block_b = min(block_b, max(8, B))
    pad_b = (-B) % block_b
    x_pad = pad_to_lane(jnp.pad(x, ((0, pad_b), (0, 0))), 1, lane)
    return x_pad, block_b, interpret, lane


def fused_mlp(
    x: jax.Array,
    weights: list[jax.Array],
    biases: list[jax.Array],
    *,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool | None = None,
    lane: int | None = None,
) -> jax.Array:
    """x: [B, F] -> logits [B, num_classes].

    ``lane`` is the padded layer width (default the 128-wide MXU tile);
    the Pallas serving backend passes ``kernel.snap_lane`` so CPU interpret
    mode runs model-sized tiles.  Numerics are lane-independent: pad lanes
    are exact zeros."""
    prep = _prepare(x, weights, interpret, block_b, lane)
    if prep is None:
        return mlp_ref(x, weights, biases)
    x_pad, block_b, interpret, lane = prep
    w_stack, b_stack = pack_params(weights, biases, lane)
    out = fused_mlp_padded(
        x_pad, w_stack, b_stack,
        n_layers=len(weights), block_b=block_b, interpret=interpret,
    )
    return out[:x.shape[0], :weights[-1].shape[1]]


def fused_mlp_classify(
    x: jax.Array,
    weights: list[jax.Array],
    biases: list[jax.Array],
    *,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool | None = None,
    lane: int | None = None,
) -> jax.Array:
    """x: [B, F] -> class ids [B] int32, argmax fused into the kernel.

    Same ``lane`` contract as :func:`fused_mlp`."""
    prep = _prepare(x, weights, interpret, block_b, lane)
    if prep is None:
        return jnp.argmax(mlp_ref(x, weights, biases), -1).astype(jnp.int32)
    x_pad, block_b, interpret, lane = prep
    w_stack, b_stack = pack_params(weights, biases, lane)
    out = fused_mlp_classify_padded(
        x_pad, w_stack, b_stack,
        n_layers=len(weights), num_classes=weights[-1].shape[1],
        block_b=block_b, interpret=interpret,
    )
    return out[:x.shape[0], 0]


def fused_mlp_reference(x, weights, biases):
    return mlp_ref(x, weights, biases)
