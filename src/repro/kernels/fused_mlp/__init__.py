from repro.kernels.fused_mlp.ops import (
    fused_mlp,
    fused_mlp_classify,
    fused_mlp_reference,
)
from repro.kernels.fused_mlp.kernel import vmem_bytes, snap_lane, LANE
