from repro.kernels.fused_mlp.ops import (
    fused_dag,
    fused_dag_reference,
    fused_mlp,
    fused_mlp_classify,
    fused_mlp_reference,
)
from repro.kernels.fused_mlp.kernel import (
    DAG_BLOCK_B,
    DAG_VMEM_BUDGET,
    LANE,
    dag_vmem_bytes,
    eval_dag_plan,
    pack_params,
    snap_lane,
    vmem_bytes,
)
