"""Pure-jnp oracle for the fused per-packet MLP pipeline.

This is the *same math* as core.mlalgos.mlp_forward and the generated Taurus
pipeline: x -> (dense + relu)* -> dense logits.  The kernel test sweeps
shapes/dtypes and asserts allclose against this function.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mlp_ref(x: jax.Array, weights: list[jax.Array], biases: list[jax.Array]
            ) -> jax.Array:
    """x: [B, F]; weights[i]: [d_i, d_{i+1}]; biases[i]: [d_{i+1}].

    ReLU between layers, no activation on the output layer. All accumulation
    in fp32 (matches both the MXU accumulate dtype and the Pallas kernel).
    """
    h = x.astype(jnp.float32)
    L = len(weights)
    for i, (w, b) in enumerate(zip(weights, biases)):
        h = h @ w.astype(jnp.float32) + b.astype(jnp.float32)
        if i < L - 1:
            h = jax.nn.relu(h)
    return h.astype(x.dtype)
