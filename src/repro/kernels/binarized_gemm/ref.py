"""Oracle for binarized (±1) GEMM — the N2Net/BNN compute primitive.

y = sign(x) @ sign(W) exactly, computed in fp32.  N2Net [81] maps this to
MAT lookups on switches; the GPU classic is XNOR+popcount.  Neither
construct exists on TPU — see kernel.py for the MXU adaptation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sign_pm1(x: jax.Array) -> jax.Array:
    """sign with sign(0) = +1 (BNN convention)."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(jnp.float32)


def binarized_gemm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x [B, K], w [K, N] (real-valued) -> ±1-quantized product [B, N]."""
    return sign_pm1(x) @ sign_pm1(w)
