"""Public op: binarized GEMM with padding + CPU fallback."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.binarized_gemm.kernel import binarized_gemm_padded
from repro.kernels.binarized_gemm.ref import binarized_gemm_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def binarized_gemm(
    x: jax.Array,  # [B, K] real-valued
    w: jax.Array,  # [K, N] real-valued
    *,
    block: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """sign(x) @ sign(w) -> int32 [B, N] (BNN matmul, bit-exact)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, K = x.shape
    N = w.shape[1]
    if interpret and B * K * N > 2**22:
        return binarized_gemm_ref(x, w).astype(jnp.int32)
    bb = min(block, max(8, B))
    bn = min(block, max(8, N))
    bk = min(block, max(8, K))
    pb, pk, pn = (-B) % bb, (-K) % bk, (-N) % bn
    # pad with -1e-9 so sign() of padding is -1 on BOTH sides: the padded
    # k-extent then contributes (-1)*(-1)=+1 per padded element, which we
    # subtract exactly afterwards.
    xp = jnp.pad(x, ((0, pb), (0, pk)), constant_values=-1e-9)
    wp = jnp.pad(w, ((0, pk), (0, pn)), constant_values=-1e-9)
    out = binarized_gemm_padded(
        xp, wp, block_b=bb, block_n=bn, block_k=bk, interpret=interpret
    )
    out = out[:B, :N] - pk  # remove the padded-k contribution
    return out
