from repro.kernels.binarized_gemm.ops import binarized_gemm
from repro.kernels.binarized_gemm.ref import binarized_gemm_ref, sign_pm1
