"""Pallas TPU kernel: binarized GEMM (N2Net's BNN primitive, TPU-native).

HARDWARE ADAPTATION (DESIGN.md §2): the paper's N2Net backend and the GPU
literature implement ±1 GEMM as XNOR + popcount over bit-packed words.
TPUs have neither warp ballots nor a popcount datapath worth feeding — but
they have an int8 MXU at 2x bf16 rate.  The TPU-native form is therefore:

    sign(x), sign(w) -> int8 (+1/-1)  ->  int8 MXU matmul, int32 accumulate

which is bit-exact with the XNOR-popcount result (n_matches - n_mismatches
== dot of ±1 vectors) while using the systolic array at full int8 rate.
The binarization is fused into the kernel (inputs stream in their original
dtype; no materialized ±1 copies in HBM).

Grid: (B/block_b, N/block_n, K/block_k) with an int32 VMEM accumulator
persisted across the (innermost, sequential) k dim.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xb = jnp.where(x_ref[...] >= 0, 1, -1).astype(jnp.int8)
    wb = jnp.where(w_ref[...] >= 0, 1, -1).astype(jnp.int8)
    acc_ref[...] += jnp.dot(
        xb, wb, preferred_element_type=jnp.int32
    )

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_n", "block_k", "interpret")
)
def binarized_gemm_padded(
    x: jax.Array,  # [B, K]  (B % block_b == 0, K % block_k == 0)
    w: jax.Array,  # [K, N]  (N % block_n == 0)
    *,
    block_b: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, K = x.shape
    N = w.shape[1]
    grid = (B // block_b, N // block_n, K // block_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_k), lambda b, n, k: (b, k)),
            pl.BlockSpec((block_k, block_n), lambda b, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda b, n, k: (b, n)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_b, block_n), jnp.int32)],
        interpret=interpret,
    )(x, w)
