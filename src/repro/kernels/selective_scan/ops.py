"""Public op: Mamba selective scan (y, h_final) with CPU fallback.

Matches ref.selective_scan_ref and the chunked associative-scan XLA twin
(models.ssm._ssm_scan_chunked) that the dry-run lowers.
"""

from __future__ import annotations

import jax

from repro.kernels.selective_scan.kernel import selective_scan_pallas
from repro.kernels.selective_scan.ref import selective_scan_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def selective_scan(
    deltaA: jax.Array,   # [B, S, di, N]
    deltaBx: jax.Array,  # [B, S, di, N]
    C: jax.Array,        # [B, S, N]
    h0: jax.Array,       # [B, di, N]
    *,
    chunk: int = 64,
    interpret: bool | None = None,
):
    if interpret is None:
        interpret = not _on_tpu()
    B, S, di, N = deltaA.shape
    if interpret and B * S * di * N > 2**22:
        return selective_scan_ref(deltaA, deltaBx, C, h0)
    return selective_scan_pallas(
        deltaA, deltaBx, C, h0, chunk=chunk, interpret=interpret
    )
