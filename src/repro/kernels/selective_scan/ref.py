"""Pure-jnp oracle for the Mamba selective scan: naive sequential recurrence.

    h_t = dA_t * h_{t-1} + dBx_t          (elementwise over [di, N])
    y_t = sum_n h_t[:, n] * C_t[n]

This is the *definitionally correct* O(S) loop; both the chunked XLA path
(models.ssm._ssm_scan_chunked) and the Pallas kernel must match it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(
    deltaA: jax.Array,   # [B, S, di, N] f32
    deltaBx: jax.Array,  # [B, S, di, N] f32
    C: jax.Array,        # [B, S, N] f32
    h0: jax.Array,       # [B, di, N] f32
) -> tuple[jax.Array, jax.Array]:
    """Returns (y [B, S, di], h_final [B, di, N])."""

    def step(h, inp):
        dA, dBx, c = inp
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, c)
        return h, y

    xs = (
        deltaA.transpose(1, 0, 2, 3),
        deltaBx.transpose(1, 0, 2, 3),
        C.transpose(1, 0, 2),
    )
    h_final, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2), h_final
