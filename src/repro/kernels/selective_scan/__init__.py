from repro.kernels.selective_scan.ops import selective_scan
from repro.kernels.selective_scan.ref import selective_scan_ref
