"""Pallas TPU kernel: Mamba S6 selective scan with VMEM-resident state.

TPU adaptation of the CUDA selective-scan (Mamba) kernel.  The GPU version
keys on warp-level shuffles for the intra-block scan; TPUs have no warp
shuffles, but they have something better for this access pattern: a large
VMEM scratch that persists across sequential grid steps.  So:

  * grid = (B, S / chunk) with the chunk dim minor (TPU grids execute
    sequentially) — the recurrent state h [di, N] lives in VMEM scratch and
    is carried across chunks *without ever touching HBM*.  A naive XLA
    lowering materializes h [B, S, di, N] (seq_len x d_state larger than the
    activations themselves) in HBM; this kernel's HBM traffic is exactly
    inputs + outputs.
  * within a chunk the recurrence is a VPU elementwise loop over time steps
    (dA_t * h + dBx_t) with the [di, N] state resident in vector registers /
    VMEM; the y readout contracts over N via an MXU-free elementwise-sum
    (N = 16 << 128 lanes, so a matmul would waste the MXU anyway).

The log-space cumprod trick (chunked associative form, used by the XLA twin
in models/ssm.py) is deliberately NOT used here: dA = exp(dt*A) < 1 decays,
and chunk-length cumprods underflow fp32 for large |dt*A| — the sequential
VMEM loop is both exact and bandwidth-optimal.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(
    dA_ref,    # [1, chunk, di, N]
    dBx_ref,   # [1, chunk, di, N]
    c_ref,     # [1, chunk, N]
    h0_ref,    # [1, di, N]
    y_ref,     # out [1, chunk, di]
    hout_ref,  # out [1, di, N]
    h_scr,     # scratch [di, N] f32 (persists across chunk grid steps)
    *,
    chunk: int,
    num_chunks: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    def step(t, h):
        dA_t = dA_ref[0, t]      # [di, N]
        dBx_t = dBx_ref[0, t]
        h = dA_t * h + dBx_t
        c_t = c_ref[0, t]        # [N]
        y_t = jnp.sum(h * c_t[None, :], axis=-1)  # [di]
        pl.store(
            y_ref,
            (pl.dslice(0, 1), pl.dslice(t, 1), slice(None)),
            y_t[None, None, :],
        )
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h

    @pl.when(ci == num_chunks - 1)
    def _final():
        hout_ref[0] = h


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def selective_scan_pallas(
    deltaA: jax.Array,   # [B, S, di, N] f32
    deltaBx: jax.Array,  # [B, S, di, N] f32
    C: jax.Array,        # [B, S, N] f32
    h0: jax.Array,       # [B, di, N] f32
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    B, S, di, N = deltaA.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, f"S={S} must divide chunk={chunk}"
    num_chunks = S // chunk
    grid = (B, num_chunks)

    kernel = functools.partial(
        _scan_kernel, chunk=chunk, num_chunks=num_chunks
    )
    y, h_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, di, N), lambda b, ci: (b, ci, 0, 0)),
            pl.BlockSpec((1, chunk, di, N), lambda b, ci: (b, ci, 0, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, di, N), lambda b, ci: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, di), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, di, N), lambda b, ci: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, di), jnp.float32),
            jax.ShapeDtypeStruct((B, di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((di, N), jnp.float32)],
        interpret=interpret,
    )(deltaA, deltaBx, C, h0)
    return y, h_final
