"""Pallas TPU flash attention: tiled online-softmax, causal/SWA/GQA.

TPU-native design (vs. the CUDA original):
  * The TPU grid is executed *sequentially* with the last dim minor, so the
    kv-block loop is the innermost grid dim and the (m, l, acc) running
    statistics live in VMEM scratch that persists across kv steps — no
    atomics, no shared-memory reductions (those are GPU concepts; on TPU the
    scratch SRAM plays that role).
  * Block shapes are (block_q x head_dim) and (block_k x head_dim) with
    head_dim = 128 = MXU lane width, so qk^T and pv are exact MXU tiles.
  * GQA is handled by an index_map trick: kv blocks are indexed by
    q_head // group_size, so grouped q heads re-read the same kv tile from
    VMEM while it is resident (free on TPU; a gather on GPU).
  * Causal/window skipping: fully-masked kv blocks are skipped with
    @pl.when — the compute predicate, not a memory predicate, because the
    pipelined BlockSpec fetch still streams the block (simple, and correct
    roofline-wise: HBM term unchanged, MXU term halved for causal).

Grid: (B, H, Sq/block_q, Skv/block_k).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,    # [1, block_q, 1, D]
    k_ref,    # [1, block_k, 1, D]
    v_ref,    # [1, block_k, 1, D]
    o_ref,    # [1, block_q, 1, D]
    acc_ref,  # scratch [block_q, D] f32
    m_ref,    # scratch [block_q] f32
    l_ref,    # scratch [block_q] f32
    *,
    causal: bool,
    window: int,
    q_offset: int,
    block_q: int,
    block_k: int,
    num_kv_blocks: int,
    skv: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level skip predicates (compute only on potentially-unmasked blocks)
    q_lo = q_offset + qi * block_q              # first q position in block
    q_hi = q_lo + block_q - 1                   # last q position in block
    k_lo = ki * block_k
    k_hi = k_lo + block_k - 1
    live = True
    if causal:
        live = k_lo <= q_hi
    if window > 0:
        live = jnp.logical_and(live, k_hi > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # [bq, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # [bk, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        s = s * (1.0 / math.sqrt(q.shape[-1]))

        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kv_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kv_pos < skv
        if causal:
            mask = jnp.logical_and(mask, kv_pos <= q_pos)
        if window > 0:
            mask = jnp.logical_and(mask, kv_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        m_ref[...] = m_new
        acc_ref[...] = (
            acc_ref[...] * alpha[:, None]
            + jnp.dot(p, v, preferred_element_type=jnp.float32)
        )

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "skv", "causal", "window", "q_offset", "block_q", "block_k",
        "interpret",
    ),
)
def flash_attention_padded(
    q: jax.Array,  # [B, Sq, H, D]  (Sq % block_q == 0)
    k: jax.Array,  # [B, Skv_pad, K, D]  (Skv_pad % block_k == 0)
    v: jax.Array,
    *,
    skv: int,              # true (unpadded) kv length
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    num_q_blocks = Sq // block_q
    num_kv_blocks = k.shape[1] // block_k
    grid = (B, H, num_q_blocks, num_kv_blocks)

    kernel = functools.partial(
        _flash_kernel,
        causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k,
        num_kv_blocks=num_kv_blocks, skv=skv,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, block_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)
            ),
            pl.BlockSpec(
                (1, block_k, 1, D), lambda b, h, qi, ki: (b, ki, h // G, 0)
            ),
            pl.BlockSpec(
                (1, block_k, 1, D), lambda b, h, qi, ki: (b, ki, h // G, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            # (block_q, D) accumulator + per-row stats in VMEM, persistent
            # across the (sequential, innermost) kv grid dim
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
