"""Public op: flash attention with padding/unpadding and CPU fallback.

``flash_attention(q, k, v, causal=..., window=...)`` matches the semantics
of ref.attention_ref / models.attention.chunked_attention.  On CPU the
kernel runs interpret=True for small shapes (tests) and transparently falls
back to the XLA chunked path for big ones (interpret mode is pure Python —
fine for validation, far too slow for a 32k prefill on CPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_padded
from repro.kernels.flash_attention.ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(
    q: jax.Array,  # [B, Sq, H, D]
    k: jax.Array,  # [B, Skv, K, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    if interpret and (B * H * Sq * Skv > 2**22):
        # interpret mode = Python per grid step; cap it to test sizes
        return attention_ref(
            q, k, v, causal=causal, window=window, q_offset=q_offset
        )

    block_q = min(block_q, max(8, Sq))
    block_k = min(block_k, max(8, Skv))
    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    out = flash_attention_padded(
        qp, kp, vp,
        skv=Skv, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return out[:, :Sq]
