"""Pure-jnp oracle for flash attention: naive full-matrix softmax attention.

Supports causal masking, sliding window, GQA (H % K == 0), and a q position
offset.  Used by the hypothesis sweep in tests/test_kernels.py and as the
semantic spec for models/attention.chunked_attention (the XLA twin that the
dry-run lowers).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jax.Array,  # [B, Sq, H, D]
    k: jax.Array,  # [B, Skv, K, D]
    v: jax.Array,  # [B, Skv, K, D]
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jax.Array:
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, D).astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32))
    s = s / math.sqrt(D)
    q_pos = q_offset + jnp.arange(Sq)
    kv_pos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask = mask & (kv_pos[None, :] <= q_pos[:, None])
    if window > 0:
        mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bkgsd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D).astype(q.dtype)
