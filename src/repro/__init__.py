"""repro: JAX/Pallas reproduction of Homunculus data-plane ML pipelines."""

from repro import _compat  # noqa: F401  (jax forward-compat polyfills)
