"""Qwen3-1.7B: dense decoder with per-head QK-norm and GQA.

[hf:Qwen/Qwen3-8B; hf]  28L, d_model=2048, 16 heads (GQA kv=8), d_ff=6144,
vocab=151936.  Full attention -> long_500k skipped.
"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    use_qk_norm=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen3-1.7b-smoke",
    family="dense",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    use_qk_norm=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
)

register(FULL, SMOKE)
