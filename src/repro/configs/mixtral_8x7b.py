"""Mixtral-8x7B: 8 experts top-2 MoE with sliding-window attention.

[arXiv:2401.04088; hf]  32L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336,
vocab=32000, SWA window 4096.  SWA bounds the decode KV cache -> long_500k
runs with a rolling-window cache.
"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    num_experts=8,
    num_experts_per_tok=2,
    moe_period=1,
    sliding_window=4096,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="mixtral-8x7b-smoke",
    family="moe",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    num_experts=4,
    num_experts_per_tok=2,
    moe_period=1,
    sliding_window=32,
    rope_theta=10_000.0,
)

register(FULL, SMOKE)
