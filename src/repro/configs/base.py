"""Model + shape configuration.

Every assigned architecture registers (a) its exact published config and
(b) a reduced "smoke" config of the same family for CPU tests.  Input-shape
sets are global for the LM family (train_4k / prefill_32k / decode_32k /
long_500k) with per-arch applicability rules.
"""

from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_period: int = 1  # MoE FFN at layers where l % moe_period == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    # --- attention variants ---
    use_qkv_bias: bool = False
    use_qk_norm: bool = False
    sliding_window: int = 0  # 0 = full attention
    rope_theta: float = 1_000_000.0
    # --- hybrid (jamba): one attention layer per attn_period, rest SSM ---
    attn_period: int = 0
    ssm_d_state: int = 16
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    # --- xLSTM: one sLSTM per slstm_period, rest mLSTM ---
    slstm_period: int = 0
    # --- enc-dec ---
    num_encoder_layers: int = 0
    num_decoder_layers: int = 0
    # --- VLM: one cross-attn block per cross_attn_period ---
    cross_attn_period: int = 0
    num_image_tokens: int = 1024
    # --- misc ---
    norm_eps: float = 1e-5
    act: str = "silu"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # Master-weight dtype for training.  fp32 default; bf16 for 398B-scale
    # (fp32 masters + grads would not fit 16 GB/chip on one pod even fully
    # sharded -- see DESIGN.md §5; Adafactor keeps the update stable).
    master_dtype: str = "float32"
    optimizer: str = "adamw"  # adamw | adafactor (398B-scale)
    remat_policy: str = "block"  # none | dots | block
    scan_layers: bool = True
    # serving
    decode_seq_shard: bool = True  # shard KV cache seq dim over model axis
    # int8 KV cache (per-(token,head) scales): halves cache HBM — required
    # for MHA archs whose bf16 cache alone exceeds 16 GB/chip at 32k x 128
    # (qwen1.5-32b: 21.5 GB/dev -> 10.8 GB; see EXPERIMENTS.md §Perf)
    kv_cache_dtype: str = "bfloat16"  # bfloat16 | int8

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context decode is tractable (SSM/hybrid/SWA)."""
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window > 0
        )

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (seamless is enc-dec)

    def param_count(self) -> int:
        from repro.models import registry

        return registry.param_count(self)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: dict[str, ModelConfig] = {}
_SMOKE: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig, smoke: ModelConfig) -> None:
    _REGISTRY[cfg.name] = cfg
    _SMOKE[cfg.name] = smoke


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def get_smoke_config(name: str) -> ModelConfig:
    return _SMOKE[name]


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """Which input shapes apply to this arch (assignment skip rules)."""
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        shapes.append("long_500k")
    return shapes
