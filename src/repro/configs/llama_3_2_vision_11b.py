"""Llama-3.2-Vision-11B: decoder backbone with interleaved cross-attention
image layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]  40L, d_model=4096, 32
heads (GQA kv=8), d_ff=14336, vocab=128256.  One gated cross-attention layer
per 5-layer period attends to precomputed image-patch embeddings (vision
frontend is a STUB per the assignment: ``input_specs()`` feeds
[batch, num_image_tokens, d_model]).  Full attention -> long_500k skipped.
"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    cross_attn_period=5,
    num_image_tokens=6404,  # 4 tiles x 1601 patches
    rope_theta=500_000.0,
)

SMOKE = ModelConfig(
    name="llama-3.2-vision-11b-smoke",
    family="vlm",
    num_layers=10,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    cross_attn_period=5,
    num_image_tokens=16,
    rope_theta=10_000.0,
)

register(FULL, SMOKE)
