"""xLSTM-1.3B: sLSTM + mLSTM recurrent blocks (no attention, no FFN).

[arXiv:2405.04517; unverified]  48 blocks, d_model=2048, 4 heads
(head_dim=512), vocab=50304, d_ff=0 (blocks carry their own up/down
projections).  xLSTM[7:1]: one sLSTM block per 8-block period, rest mLSTM.
O(1) recurrent state -> long_500k decode runs.
"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    slstm_period=8,
)

SMOKE = ModelConfig(
    name="xlstm-1.3b-smoke",
    family="ssm",
    num_layers=8,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=0,
    vocab_size=256,
    slstm_period=8,
)

register(FULL, SMOKE)
