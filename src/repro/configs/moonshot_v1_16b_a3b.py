"""Moonshot-v1-16B-A3B (Moonlight): fine-grained MoE, 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf]  48L, d_model=2048, 16 heads (GQA
kv=16), per-expert d_ff=1408, vocab=163840.  Full attention -> long_500k
skipped per assignment rule (see DESIGN.md §4).
"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    num_experts=64,
    num_experts_per_tok=6,
    moe_period=1,
    rope_theta=50_000.0,
)

SMOKE = ModelConfig(
    name="moonshot-v1-16b-a3b-smoke",
    family="moe",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=32,
    vocab_size=256,
    num_experts=8,
    num_experts_per_tok=3,
    moe_period=1,
    rope_theta=10_000.0,
)

register(FULL, SMOKE)
