"""StarCoder2-15B: dense code model, GQA kv=4, RoPE.

[arXiv:2402.19173; hf]  40L, d_model=6144, 48 heads (GQA kv=4), d_ff=24576,
vocab=49152.  (StarCoder2-15B uses gelu MLP and learned+rope hybrid; we use
RoPE + gelu per the published config.)  Full attention -> long_500k skipped.
"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    act="gelu",
    use_qkv_bias=True,
    rope_theta=100_000.0,
)

SMOKE = ModelConfig(
    name="starcoder2-15b-smoke",
    family="dense",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    act="gelu",
    use_qkv_bias=True,
    rope_theta=10_000.0,
)

register(FULL, SMOKE)
