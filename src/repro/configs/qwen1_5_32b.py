"""Qwen1.5-32B: dense decoder with QKV bias, MHA (kv=heads).

[hf:Qwen/Qwen1.5-0.5B; hf]  64L, d_model=5120, 40 heads (kv=40),
d_ff=27392, vocab=152064.  Full attention -> long_500k skipped.
"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152064,
    use_qkv_bias=True,
    rope_theta=1_000_000.0,
    # MHA (kv=40): the bf16 KV cache alone is 21.5 GB/device at 32k x 128
    # on one pod — int8 KV (per-head-vector scales) halves it and fits.
    kv_cache_dtype="int8",
)

SMOKE = ModelConfig(
    name="qwen1.5-32b-smoke",
    family="dense",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    use_qkv_bias=True,
    rope_theta=10_000.0,
    kv_cache_dtype="int8",  # smoke-covers the quantized-cache path
)

register(FULL, SMOKE)
