"""Jamba-1.5-Large (398B): hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf]  72L, d_model=8192, 64 heads (GQA kv=8), d_ff=24576,
vocab=65536.  One attention layer per 8-layer period (rest Mamba); MoE FFN on
every other layer.  Trained with Adafactor (AdamW state for 398B does not fit
16GB/chip HBM on a single 256-chip pod; see DESIGN.md §5).
"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    num_experts=16,
    num_experts_per_tok=2,
    moe_period=2,
    moe_offset=0,
    attn_period=8,
    ssm_d_state=16,
    ssm_d_conv=4,
    ssm_expand=2,
    optimizer="adafactor",
    master_dtype="bfloat16",
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="jamba-1.5-large-398b-smoke",
    family="hybrid",
    num_layers=16,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    num_experts=4,
    num_experts_per_tok=2,
    moe_period=2,
    moe_offset=0,
    attn_period=8,
    ssm_d_state=8,
    ssm_d_conv=4,
    ssm_expand=2,
    rope_theta=10_000.0,
)

register(FULL, SMOKE)
