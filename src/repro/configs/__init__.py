from repro.configs.base import (
    ModelConfig,
    ShapeConfig,
    SHAPES,
    register,
    get_config,
    get_smoke_config,
    list_archs,
    applicable_shapes,
)

# Import all architecture modules so they self-register.
from repro.configs import (  # noqa: F401
    jamba_1_5_large_398b,
    moonshot_v1_16b_a3b,
    mixtral_8x7b,
    seamless_m4t_large_v2,
    qwen3_1_7b,
    qwen1_5_32b,
    starcoder2_15b,
    qwen2_7b,
    llama_3_2_vision_11b,
    xlstm_1_3b,
)
