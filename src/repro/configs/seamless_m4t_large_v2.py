"""SeamlessM4T-Large-v2: encoder-decoder multimodal (audio) transformer.

[arXiv:2308.11596; hf]  24L total (12 encoder + 12 decoder), d_model=1024,
16 heads (kv=16), d_ff=8192, vocab=256206.  The audio frontend is a STUB per
the assignment: ``input_specs()`` provides precomputed frame embeddings
[batch, frames, d_model].  Decoder decodes with self-attn KV cache +
cross-attn memory.  Full attention -> long_500k skipped.
"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,
    num_encoder_layers=12,
    num_decoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="seamless-m4t-large-v2-smoke",
    family="encdec",
    num_layers=4,
    num_encoder_layers=2,
    num_decoder_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    rope_theta=10_000.0,
)

register(FULL, SMOKE)
