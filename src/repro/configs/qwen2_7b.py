"""Qwen2-7B: dense decoder, GQA kv=4, QKV bias.

[arXiv:2407.10671; hf]  28L, d_model=3584, 28 heads (GQA kv=4), d_ff=18944,
vocab=152064.  Full attention -> long_500k skipped.
"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    use_qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2-7b-smoke",
    family="dense",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    use_qkv_bias=True,
    rope_theta=10_000.0,
)

register(FULL, SMOKE)
