from repro.train.losses import cross_entropy, total_loss
from repro.train.step import (
    TrainSettings,
    make_train_step,
    train_state_defs,
    init_train_state,
)
