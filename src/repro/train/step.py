"""Training step: microbatched grad accumulation, clipping, optimizer update.

``train_state_defs`` gives the abstract state tree (params + optimizer
moments + step) used by the multi-pod dry-run; ``make_train_step`` builds the
jittable step used by both the dry-run (.lower().compile()) and the real CPU
training examples.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.common import pytree as pt
from repro.configs.base import ModelConfig
from repro.models import registry
from repro.models.transformer import forward
from repro.optim import clip_by_global_norm, get_optimizer, warmup_cosine
from repro.train.losses import total_loss


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    microbatches: int = 1
    max_grad_norm: float = 1.0
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    remat: bool = True


def _master_defs(defs, cfg: ModelConfig):
    """Canonical training params (master weights): fp32 by default, bf16 for
    398B-scale configs (cfg.master_dtype); compute casts matmul weights to
    bf16 inside the step (cast-before-gather keeps FSDP all-gathers at
    2 bytes)."""
    dtype = jnp.dtype(cfg.master_dtype)
    return jax.tree.map(
        lambda d: pt.ParamDef(d.shape, dtype, d.axes, d.init, d.init_scale),
        defs, is_leaf=pt.is_def,
    )


def _fp32_defs(defs):  # backwards-compat alias used by tests
    return jax.tree.map(
        lambda d: pt.ParamDef(d.shape, jnp.float32, d.axes, d.init, d.init_scale),
        defs, is_leaf=pt.is_def,
    )


def train_state_defs(cfg: ModelConfig) -> dict:
    pdefs = _master_defs(registry.param_defs(cfg), cfg)
    opt = get_optimizer(cfg.optimizer)
    return {
        "params": pdefs,
        "opt": opt.state_defs(pdefs),
        "step": pt.ParamDef((), jnp.int32, (), "zeros"),
    }


def init_train_state(cfg: ModelConfig, key) -> dict:
    pdefs = _master_defs(registry.param_defs(cfg), cfg)
    params = pt.materialize(pdefs, key)
    opt = get_optimizer(cfg.optimizer)
    return {
        "params": params,
        "opt": opt.init(params),
        "step": jnp.zeros((), jnp.int32),
    }


def cast_for_compute(params):
    """fp32 master -> bf16 compute for rank>=2 weights; 1D scales stay fp32."""
    def leaf(x):
        if jnp.issubdtype(x.dtype, jnp.floating) and x.ndim >= 2:
            return x.astype(jnp.bfloat16)
        return x

    return jax.tree.map(leaf, params)


def _split_micro(batch: dict, n: int) -> dict:
    """[B, ...] -> [n, B/n, ...] with the batch shard pinned to dim 1.

    Without the explicit constraint GSPMD may propagate the batch sharding
    to the *microbatch* dim of the reshape, which replicates every
    microbatch slice on all data ranks (a 16x activation-memory blowup
    observed on the 398B dry-run; see EXPERIMENTS.md §Dry-run).
    """
    from repro.dist.sharding import shard

    def f(x):
        B = x.shape[0]
        x = x.reshape(n, B // n, *x.shape[1:])
        return shard(x, None, "batch", *([None] * (x.ndim - 2)))

    return jax.tree.map(f, batch)


def make_train_step(cfg: ModelConfig, settings: TrainSettings = TrainSettings()):
    opt = get_optimizer(cfg.optimizer)

    def loss_fn(params, mb):
        kwargs = {}
        if cfg.family == "encdec":
            kwargs["memory_embeds"] = mb["frames"]
        if cfg.family == "vlm":
            kwargs["memory_embeds"] = mb["image_embeds"]
        logits, _, aux = forward(
            cast_for_compute(params), cfg, tokens=mb["tokens"], mode="train",
            remat=settings.remat, **kwargs,
        )
        return total_loss(logits, mb["targets"], aux)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        n = settings.microbatches
        if n == 1:
            (_, metrics), grads = grad_fn(params, batch)
        else:
            micro = _split_micro(batch, n)

            def acc_body(g_acc, mb):
                (_, m), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return g_acc, m

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            grads, ms = jax.lax.scan(acc_body, g0, micro)
            grads = jax.tree.map(lambda g: g / n, grads)
            metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), ms)

        grads, gnorm = clip_by_global_norm(grads, settings.max_grad_norm)
        lr = warmup_cosine(
            state["step"], peak_lr=settings.peak_lr,
            warmup=settings.warmup, total=settings.total_steps,
        )
        new_params, new_opt = opt.update(
            grads, state["opt"], params, lr, state["step"]
        )
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        return new_state, metrics

    return train_step
