"""Losses: masked cross-entropy (fp32 logsumexp), z-loss, MoE aux blend."""

from __future__ import annotations

import jax
import jax.numpy as jnp

MOE_LB_WEIGHT = 0.01
MOE_Z_WEIGHT = 0.001
Z_LOSS_WEIGHT = 1e-4
IGNORE = -1


def cross_entropy(logits: jax.Array, targets: jax.Array):
    """logits [B,S,V], targets [B,S] (IGNORE = masked). Returns (ce, z, acc)."""
    logits = logits.astype(jnp.float32)
    mask = (targets != IGNORE).astype(jnp.float32)
    tgt = jnp.maximum(targets, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    true_logit = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    ce = (lse - true_logit) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    z = jnp.sum(jnp.square(lse) * mask) / denom
    acc = jnp.sum((jnp.argmax(logits, -1) == tgt) * mask) / denom
    return jnp.sum(ce) / denom, z, acc


def total_loss(logits, targets, aux: dict):
    ce, z, acc = cross_entropy(logits, targets)
    loss = ce + Z_LOSS_WEIGHT * z
    metrics = {"ce": ce, "z_loss": z, "accuracy": acc}
    if "moe_lb_loss" in aux:
        loss = loss + MOE_LB_WEIGHT * aux["moe_lb_loss"]
        loss = loss + MOE_Z_WEIGHT * aux["moe_z_loss"]
        metrics.update(aux)
    metrics["loss"] = loss
    return loss, metrics
