"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

The shannon/kernels pattern: weak-type-correct, shardable, zero device
allocation — what lets a 398B train_step lower on a 1-core CPU host.
"""

from __future__ import annotations

import jax

from repro.common import pytree as pt
from repro.configs.base import ModelConfig, SHAPES, ShapeConfig
from repro.models import registry
from repro.serve.steps import serve_cache_defs
from repro.train.step import train_state_defs


def _abstract(defs):
    return pt.abstract(defs)


def input_specs(cfg: ModelConfig, shape: ShapeConfig | str) -> dict:
    """Abstract inputs for the step the shape's kind lowers.

    train   -> {"state": train_state, "batch": {tokens, targets, ...}}
    prefill -> {"params", "cache", "batch"}
    decode  -> {"params", "cache", "tokens", "index"}
    """
    if isinstance(shape, str):
        shape = SHAPES[shape]
    if shape.kind == "train":
        return {
            "state": _abstract(train_state_defs(cfg)),
            "batch": _abstract(registry.train_batch_defs(cfg, shape)),
        }
    params = _abstract(registry.param_defs(cfg))
    cache = _abstract(
        serve_cache_defs(cfg, shape.global_batch, shape.seq_len)
    )
    if shape.kind == "prefill":
        return {
            "params": params,
            "cache": cache,
            "batch": _abstract(registry.prefill_batch_defs(cfg, shape)),
        }
    assert shape.kind == "decode"
    return {
        "params": params,
        "cache": cache,
        "batch": _abstract(registry.decode_batch_defs(cfg, shape)),
        "index": jax.ShapeDtypeStruct((), jax.numpy.int32),
    }


def state_defs_for(cfg: ModelConfig, shape: ShapeConfig | str) -> dict:
    """The ParamDef trees matching input_specs (for shardings)."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    if shape.kind == "train":
        return {
            "state": train_state_defs(cfg),
            "batch": registry.train_batch_defs(cfg, shape),
        }
    params = registry.param_defs(cfg)
    cache = serve_cache_defs(cfg, shape.global_batch, shape.seq_len)
    if shape.kind == "prefill":
        return {
            "params": params,
            "cache": cache,
            "batch": registry.prefill_batch_defs(cfg, shape),
        }
    return {
        "params": params,
        "cache": cache,
        "batch": registry.decode_batch_defs(cfg, shape),
        "index": pt.ParamDef((), jax.numpy.int32, (), "zeros"),
    }
