"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (required so smoke tests see 1 CPU device while the dry-run
sees 512 forced host devices).
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh, NamedSharding

from repro.common import pytree as pt
from repro.dist.sharding import AxisRules, DEFAULT_RULES


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


def make_mesh_shape(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (autoshard DSE explores these)."""
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


def fit_pspec(shape: tuple[int, ...], spec, mesh: Mesh):
    """Drop mesh axes that do not divide their dim (replicate instead).

    E.g. GQA with 8 KV heads on a 16-way model axis: the KV projection is
    replicated across pairs of TP ranks — the standard fallback on real
    systems — rather than failing the lowering.
    """
    from jax.sharding import PartitionSpec as P

    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        kept, prod = [], 1
        for a in axes:
            size = mesh.shape[a]
            if dim % (prod * size) == 0:
                kept.append(a)
                prod *= size
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def sharding_tree(defs, mesh: Mesh, rules: AxisRules = DEFAULT_RULES):
    """ParamDef tree -> NamedSharding tree (logical axes, shape-fitted)."""

    def one(d: pt.ParamDef) -> NamedSharding:
        spec = rules.resolve(d.axes, mesh)
        return NamedSharding(mesh, fit_pspec(d.shape, spec, mesh))

    return jax.tree.map(one, defs, is_leaf=pt.is_def)
