"""Post-SPMD HLO parsing: collective inventory + wire-byte accounting.

``compiled.as_text()`` is the partitioned (per-device) module, so every
tensor shape on a collective line is a per-device shard.  For each collective
we record result bytes, group size, and *wire bytes per device* under the
standard ring-algorithm model:

  all-gather      result R over group g: send/recv R*(g-1)/g
  all-reduce      operand O (= result):  2*O*(g-1)/g   (RS + AG phases)
  reduce-scatter  result R (operand R*g): R*(g-1)      == O*(g-1)/g
  all-to-all      operand O: O*(g-1)/g
  collective-permute  operand O: O

CPU-backend caveat: XLA-CPU widens bf16 dot operands to f32 before
collectives, doubling their stated size vs. a TPU lowering.  We report both
``wire_bytes`` (as lowered) and ``wire_bytes_bf16`` (f32 collectives of
matmul operands re-costed at 2 bytes) — the TPU-corrected number used by the
roofline's collective term.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# dtype[1,2,3]{layout} — layout optional
_TYPE_RE = re.compile(r"\b(pred|[sub]\d+|bf16|f16|f32|f64|u8|s8)\[([\d,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_SOURCE_PAIRS_RE = re.compile(r"source_target_pairs=\{(.*?)\}")


def _type_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class Collective:
    kind: str
    dtype: str
    result_bytes: int
    group_size: int
    wire_bytes: float       # per-device wire traffic, as lowered
    wire_bytes_bf16: float  # f32->bf16 corrected (TPU lowering estimate)
    line: str


def _group_size(line: str, total_devices: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]<=...
    m = _LIST_GROUPS_RE.search(line)
    if m:
        return len([t for t in m.group(1).split(",") if t.strip() != ""])
    m = _SOURCE_PAIRS_RE.search(line)
    if m:  # collective-permute: pairwise, "group" of 2
        return 2
    return total_devices


def _result_types(line: str) -> list[tuple[str, str]]:
    """Types on the LHS (result), handling tuples."""
    lhs = line.split("=", 1)[0] if "=" in line else ""
    rhs = line.split("=", 1)[1] if "=" in line else line
    # result types are the first type tokens of the rhs before the op name
    op_idx = min(
        (rhs.find(k) for k in _KINDS if k in rhs), default=-1
    )
    head = rhs[:op_idx] if op_idx > 0 else ""
    types = _TYPE_RE.findall(head)
    if not types:
        types = _TYPE_RE.findall(rhs)[:1]
    return types


def parse_collectives(hlo_text: str, total_devices: int) -> list[Collective]:
    out: list[Collective] = []
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if not line or line.startswith("//"):
            continue
        kind = None
        for k in _KINDS:
            # match op name with word boundary: "all-gather(", "all-gather-start("
            if f" {k}(" in line or f" {k}-start(" in line:
                kind = k
                break
        if kind is None:
            continue
        if " all-gather-done(" in line or " all-reduce-done(" in line:
            continue
        types = _result_types(line)
        if not types:
            continue
        g = _group_size(line, total_devices)
        rb = sum(_type_bytes(dt, dims) for dt, dims in types)
        frac = (g - 1) / g if g > 1 else 0.0
        if kind == "all-gather":
            wire = rb * frac
        elif kind == "all-reduce":
            wire = 2 * rb * frac
        elif kind == "reduce-scatter":
            wire = rb * (g - 1)
        elif kind == "all-to-all":
            wire = rb * frac
        else:  # collective-permute
            wire = float(rb)
        # f32 collectives are (almost always here) widened bf16 matmul
        # operands on the CPU backend; cost them at bf16 for the TPU estimate
        corr = 0.5 if all(dt == "f32" for dt, _ in types) else 1.0
        out.append(Collective(
            kind=kind,
            dtype=",".join(dt for dt, _ in types),
            result_bytes=rb,
            group_size=g,
            wire_bytes=wire,
            wire_bytes_bf16=wire * corr,
            line=line[:200],
        ))
    return out


def summarize_collectives(colls: list[Collective]) -> dict:
    by_kind: dict[str, dict] = defaultdict(
        lambda: {"count": 0, "wire_bytes": 0.0, "wire_bytes_bf16": 0.0}
    )
    for c in colls:
        d = by_kind[c.kind]
        d["count"] += 1
        d["wire_bytes"] += c.wire_bytes
        d["wire_bytes_bf16"] += c.wire_bytes_bf16
    total = {
        "wire_bytes": sum(c.wire_bytes for c in colls),
        "wire_bytes_bf16": sum(c.wire_bytes_bf16 for c in colls),
        "count": len(colls),
    }
    return {"by_kind": dict(by_kind), "total": total}


def hlo_op_histogram(hlo_text: str, top: int = 12) -> dict[str, int]:
    """Rough op histogram (duplicate-op / remat waste diagnostics)."""
    counts: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = re.search(r"=\s+[a-z0-9\[\],{}: ]*?([a-z][a-z0-9-]*)\(", line)
        if m:
            counts[m.group(1)] += 1
    return dict(
        sorted(counts.items(), key=lambda kv: -kv[1])[:top]
    )
