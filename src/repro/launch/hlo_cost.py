"""Static cost model over post-SPMD HLO text: exact loop-aware accounting.

Why this exists: ``compiled.cost_analysis()`` counts a while-loop body ONCE,
but every interesting cell here runs nested loops (microbatch scan x layer
scan), so XLA's numbers under-report flops/bytes/collectives by 1-2 orders
of magnitude.  This module parses ``compiled.as_text()`` into computations,
builds the call graph (fusions, while bodies/conditions, reduce appliers,
conditionals), infers scan trip counts from the canonical
``compare(iv, constant), direction=LT`` loop condition, and accumulates:

  * flops      2*result_elems*K for every ``dot`` (operand shapes resolved
               through a per-computation symbol table), per-device
  * hbm_bytes  operand+result bytes of top-level ops of *control-flow-real*
               computations (entry, while bodies/conds, branches); fusion
               internals are VMEM-resident and free; parameters/GTEs/tuples/
               bitcasts free; while/conditional call sites free (in-place)
  * collective wire bytes   ring-model per-device traffic per collective,
               plus a bf16-corrected variant (XLA-CPU widens bf16 dot
               operands to f32; a TPU lowering keeps them 2-byte)

All totals are per-device (the module is the SPMD-partitioned program).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    "get-dimension-size", "while", "conditional", "call",
}

_TYPE_TOKEN = re.compile(
    r"(pred|bf16|f16|f32|f64|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)"
    r"\[([0-9,]*)\]"
)
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s+([a-z][\w\-]*)\((.*)$"
)
_HEADER_NAME = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)")
_OPERAND_REF = re.compile(r"%([\w\.\-]+)")
_CALL_ATTR = re.compile(
    r"(?:calls|to_apply|true_computation|false_computation)"
    r"=%?([\w\.\-]+)"
)
_WHILE_ATTR = re.compile(r"(body|condition)=%?([\w\.\-]+)")
_BRANCH_ATTR = re.compile(r"branch_computations=\{([^}]*)\}")
_IOTA_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS = re.compile(r"replica_groups=\{\{([^}]*)\}")
_DIRECTION = re.compile(r"direction=(\w+)")
_KNOWN_TRIP = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"')
_INT = re.compile(r"-?\d+")
_CONTRACT_DIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _types_bytes(types: list[tuple[str, str]]) -> int:
    return sum(
        _shape_elems(dims) * _DTYPE_BYTES.get(dt, 4) for dt, dims in types
    )


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_types: list[tuple[str, str]]
    operands: list[str]
    operand_str: str
    attrs: str
    is_root: bool


@dataclasses.dataclass
class Computation:
    name: str
    ops: dict[str, "Op"]
    order: list[str]
    is_entry: bool


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s:
            continue
        # computation header: flush-left, ends with '{', has '->'
        if not raw.startswith(" ") and s.endswith("{") and "->" in s:
            m = _HEADER_NAME.match(s)
            if m:
                cur = Computation(
                    m.group(2), {}, [], bool(m.group(1))
                )
            continue
        if cur is None:
            continue
        if s == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, result_part, kind, rest = m.groups()
        result_types = _TYPE_TOKEN.findall(result_part)
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str = rest[:end]
        attrs = rest[end + 1:]
        operands = _OPERAND_REF.findall(operand_str)
        cur.ops[name] = Op(
            name, kind, result_types, operands, operand_str, attrs,
            s.startswith("ROOT"),
        )
        cur.order.append(name)
    if cur is not None:
        comps[cur.name] = cur
    return comps


# ------------------------------------------------------------- call graph


def _constant_int(comp: Computation, name: str) -> int | None:
    op = comp.ops.get(name)
    if op is None or op.kind != "constant":
        return None
    m = _INT.search(op.operand_str)
    return int(m.group(0)) if m else None


def _trip_count(
    while_op: Op, cond: Computation | None,
    comps: dict[str, Computation],
) -> float | None:
    """Trip count: XLA's known_trip_count backend_config (authoritative),
    else compare-vs-constant in the condition (looking through fusions),
    else None (unknown)."""
    m = _KNOWN_TRIP.search(while_op.attrs)
    if m:
        return float(max(int(m.group(1)), 1))
    if cond is None:
        return None
    # direct compare in the condition
    for op in cond.ops.values():
        if op.kind != "compare":
            continue
        d = _DIRECTION.search(op.attrs)
        direction = d.group(1) if d else "LT"
        for ref in op.operands:
            c = _constant_int(cond, ref)
            if c is None:
                continue
            if direction in ("LE", "GE"):
                return float(max(abs(c) + 1, 1))
            return float(max(abs(c), 1))
    # compare wrapped in a fusion: bound constant is a fusion operand
    for op in cond.ops.values():
        callee = None
        for cn in _CALL_ATTR.findall(op.attrs):
            callee = comps.get(cn)
        if callee is None:
            continue
        if not any(o.kind == "compare" for o in callee.ops.values()):
            continue
        for ref in op.operands:
            c = _constant_int(cond, ref)
            if c is not None and abs(c) > 0:
                return float(max(abs(c), 1))
    return None


def execution_counts(
    comps: dict[str, Computation]
) -> tuple[dict[str, float], dict[str, float], int]:
    """-> (exec counts, control-flow-real counts, #unknown-trip loops)."""
    entries = [c for c in comps.values() if c.is_entry]
    if not entries:  # fall back: computation named main-ish
        entries = [c for c in comps.values() if c.name.startswith("main")]
    counts: dict[str, float] = defaultdict(float)
    real_counts: dict[str, float] = defaultdict(float)
    unknown = 0

    def visit(comp: Computation, mult: float, real: bool, depth: int = 0):
        nonlocal unknown
        if depth > 64:
            return
        counts[comp.name] += mult
        if real:
            real_counts[comp.name] += mult
        for opname in comp.order:
            op = comp.ops[opname]
            if op.kind == "while":
                parts = dict(_WHILE_ATTR.findall(op.attrs))
                cond = comps.get(parts.get("condition", ""))
                body = comps.get(parts.get("body", ""))
                trip = _trip_count(op, cond, comps)
                if trip is None:
                    trip = 1.0
                    unknown += 1
                if body:
                    visit(body, mult * trip, real, depth + 1)
                if cond:
                    visit(cond, mult * (trip + 1), real, depth + 1)
            elif op.kind == "conditional":
                m = _BRANCH_ATTR.search(op.attrs)
                branches = (
                    _OPERAND_REF.findall(m.group(1)) if m else []
                ) or _CALL_ATTR.findall(op.attrs)
                for b in branches:
                    c = comps.get(b)
                    if c:
                        visit(c, mult, real, depth + 1)
            else:
                for callee in _CALL_ATTR.findall(op.attrs):
                    c = comps.get(callee)
                    if c is not None:
                        # fusion bodies / reduce appliers: not "real"
                        visit(c, mult, False, depth + 1)

    for e in entries:
        visit(e, 1.0, True)
    return dict(counts), dict(real_counts), unknown


# ------------------------------------------------------------- accounting


def _fusion_bytes(
    op: Op, sym: dict, comps: dict[str, Computation]
) -> float | None:
    """HBM traffic of a fusion, slice-aware on both sides.

    Scan bodies look like: fusion(big_stacked_buffer, ...) where the body
    only dynamic-slices one layer out of the buffer, and/or whose root is a
    dynamic-update-slice writing one layer back.  On real hardware these
    are slice-sized reads and in-place slice-sized writes; charging the
    full carried buffer per iteration overstates HBM traffic ~L-fold.

    Reads: per operand — if every use inside the body is a (dynamic-)slice
    or gather, charge the slice results; else the full operand.
    Writes: if the root (peeled of converts/bitcasts, a CPU bf16-widening
    artifact) is a dynamic-update-slice, charge the update slice; else the
    full result.
    """
    callee = None
    for cn in _CALL_ATTR.findall(op.attrs):
        callee = comps.get(cn)
    if callee is None:
        return None
    csym = {n: o.result_types for n, o in callee.ops.items()}

    def peel(o: Op) -> Op:
        seen = 0
        while o.kind in ("convert", "bitcast") and o.operands:
            nxt = callee.ops.get(o.operands[0])
            if nxt is None or seen > 8:
                break
            o = nxt
            seen += 1
        return o

    # ---- write side
    root = next((o for o in callee.ops.values() if o.is_root), None)
    if root is None:
        return None
    roots = [root]
    if root.kind == "tuple":
        roots = [callee.ops[r] for r in root.operands if r in callee.ops]
    roots = [peel(r) for r in roots]
    write = 0.0
    for r in roots:
        if r.kind == "dynamic-update-slice":
            upd = (
                _types_bytes(csym.get(r.operands[1], []))
                if len(r.operands) > 1 else _types_bytes(r.result_types)
            )
            write += 2.0 * upd  # read old slice + write new slice
        else:
            write += float(_types_bytes(r.result_types))

    # ---- read side: map parameter index -> uses
    params: dict[int, str] = {}
    for name, o in callee.ops.items():
        if o.kind == "parameter":
            m = _INT.search(o.operand_str)
            if m:
                params[int(m.group(0))] = name
    read = 0.0
    slice_kinds = ("dynamic-slice", "slice", "gather")
    for i, ref in enumerate(op.operands):
        full = _types_bytes(sym.get(ref, []))
        pname = params.get(i)
        if pname is None:
            read += full
            continue
        # users of this parameter inside the body (through convert/bitcast)
        users: list[Op] = []
        frontier = {pname}
        hops = 0
        while frontier and hops < 4:
            nxt: set[str] = set()
            for o in callee.ops.values():
                if any(r in frontier for r in o.operands):
                    if o.kind in ("convert", "bitcast"):
                        nxt.add(o.name)
                    else:
                        users.append(o)
            frontier = nxt
            hops += 1
        if users and all(u.kind in slice_kinds for u in users):
            read += sum(_types_bytes(u.result_types) for u in users)
        elif users and all(
            u.kind in slice_kinds + ("dynamic-update-slice",)
            for u in users
        ):
            # aliased in-place buffer: slices charged, DUS handled on write
            read += sum(
                _types_bytes(u.result_types)
                for u in users if u.kind in slice_kinds
            )
        else:
            read += full
    return write + read


def _dot_flops(op: Op, sym: dict) -> float:
    result_elems = sum(_shape_elems(d) for _, d in op.result_types)
    m = _CONTRACT_DIMS.search(op.attrs)
    if not m or not op.operands:
        return 2.0 * result_elems
    lhs_types = sym.get(op.operands[0], [])
    if not lhs_types:
        return 2.0 * result_elems
    dims = lhs_types[0][1].split(",") if lhs_types[0][1] else []
    k = 1
    for di in m.group(1).split(","):
        if di != "" and int(di) < len(dims):
            k *= int(dims[int(di)])
    return 2.0 * result_elems * k


def _collective_wire(op: Op, total_devices: int) -> tuple[float, float, int]:
    rb = _types_bytes(op.result_types)
    if op.kind.endswith("-start") and len(op.result_types) > 1:
        # async tuple result includes the operand alias; cost the output only
        rb = _types_bytes(op.result_types[-1:])
    g = total_devices
    m = _IOTA_GROUPS.search(op.attrs)
    if m:
        g = int(m.group(2))
    else:
        m = _LIST_GROUPS.search(op.attrs)
        if m:
            g = len([t for t in m.group(1).split(",") if t.strip() != ""])
        elif "source_target_pairs" in op.attrs:
            g = 2
    frac = (g - 1) / g if g > 1 else 0.0
    kind = op.kind.replace("-start", "")
    if kind == "all-gather":
        wire = rb * frac
    elif kind == "all-reduce":
        wire = 2 * rb * frac
    elif kind == "reduce-scatter":
        wire = rb * (g - 1)
    elif kind == "all-to-all":
        wire = rb * frac
    else:  # collective-permute
        wire = float(rb)
    corr = 0.5 if all(dt == "f32" for dt, _ in op.result_types) else 1.0
    return wire, wire * corr, g


@dataclasses.dataclass
class CostReport:
    flops: float
    hbm_bytes: float
    coll_wire_bytes: float
    coll_wire_bytes_bf16: float
    coll_by_kind: dict
    dot_count: float
    unknown_loops: int
    loop_comps: dict[str, float]

    def to_json(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_wire_bytes": self.coll_wire_bytes,
            "coll_wire_bytes_bf16": self.coll_wire_bytes_bf16,
            "coll_by_kind": self.coll_by_kind,
            "dot_count": self.dot_count,
            "unknown_loops": self.unknown_loops,
            "loop_comps": self.loop_comps,
        }


def analyze(text: str, total_devices: int) -> CostReport:
    comps = parse_module(text)
    counts, real_counts, unknown = execution_counts(comps)

    flops = 0.0
    hbm = 0.0
    wire = 0.0
    wire_bf16 = 0.0
    dots = 0.0
    by_kind: dict[str, dict] = defaultdict(
        lambda: {"count": 0.0, "wire_bytes": 0.0, "wire_bytes_bf16": 0.0}
    )

    for comp in comps.values():
        mult = counts.get(comp.name, 0.0)
        real_mult = real_counts.get(comp.name, 0.0)
        if mult <= 0:
            continue
        sym = {name: op.result_types for name, op in comp.ops.items()}
        for opname in comp.order:
            op = comp.ops[opname]
            if op.kind in ("dot", "convolution"):
                flops += mult * _dot_flops(op, sym)
                dots += mult
            ckind = op.kind.replace("-start", "")
            if ckind in _COLLECTIVES and not op.kind.endswith("-done"):
                w, wb, g = _collective_wire(op, total_devices)
                wire += mult * w
                wire_bf16 += mult * wb
                d = by_kind[ckind]
                d["count"] += mult
                d["wire_bytes"] += mult * w
                d["wire_bytes_bf16"] += mult * wb
            if real_mult <= 0:
                continue  # fusion/applier internals: VMEM, free
            if op.kind in _FREE_OPS or op.kind.endswith("-done"):
                continue
            rb = _types_bytes(op.result_types)
            if op.kind in ("dynamic-slice", "slice", "gather"):
                # reads only the slice it produces (not the full operand)
                touched = 2.0 * rb
            elif op.kind == "dynamic-update-slice":
                # in-place on real hardware: writes the update slice only
                upd = (
                    _types_bytes(sym.get(op.operands[1], []))
                    if len(op.operands) > 1 else rb
                )
                touched = 2.0 * upd
            elif op.kind == "scatter":
                upd = (
                    _types_bytes(sym.get(op.operands[2], []))
                    if len(op.operands) > 2 else rb
                )
                touched = 3.0 * upd  # read+write target slots + updates
            elif op.kind == "fusion" and (
                fb := _fusion_bytes(op, sym, comps)
            ) is not None:
                touched = fb
            else:
                ob = sum(
                    _types_bytes(sym.get(ref, [])) for ref in op.operands
                )
                touched = float(rb + ob)
            hbm += real_mult * touched

    return CostReport(
        flops=flops,
        hbm_bytes=hbm,
        coll_wire_bytes=wire,
        coll_wire_bytes_bf16=wire_bf16,
        coll_by_kind=dict(by_kind),
        dot_count=dots,
        unknown_loops=unknown,
        loop_comps={
            k: v for k, v in counts.items() if v > 1.5
        },
    )
