"""Multi-host bring-up: the glue that turns the single-process code into a
1000+-node launch.  Everything else in the framework is already
multi-host-safe by construction:

  * pjit/GSPMD programs are identical on every host (single-controller
    semantics); only jax.distributed.initialize differs per host,
  * the data pipeline is stateless in (seed, host_id, step)
    (`data/tokens.TokenDataset`), so hosts never exchange data-order state
    and a restart replays exactly,
  * checkpoints are sharded + integrity-checked and restore elastically
    onto a different host/device count (`ckpt/checkpoint.py`),
  * the straggler watchdog and RestartManager need no coordination beyond
    the collective ops themselves.

``init_distributed()`` wires the standard cluster environments:

  - GKE/Cloud TPU:  MEGASCALE/JAX autodetection (no args needed)
  - SLURM:          SLURM_PROCID/SLURM_NTASKS/SLURM_NODELIST
  - manual:         REPRO_COORD_ADDR, REPRO_NUM_PROC, REPRO_PROC_ID

``host_batch_slice()`` maps the global batch to this host's rows for
building jax.Arrays from per-host data via
``jax.make_array_from_process_local_data``.
"""

from __future__ import annotations

import dataclasses
import os


@dataclasses.dataclass(frozen=True)
class HostInfo:
    process_id: int
    num_processes: int
    coordinator: str | None


def detect_cluster() -> HostInfo:
    env = os.environ
    if "REPRO_NUM_PROC" in env:
        return HostInfo(
            int(env.get("REPRO_PROC_ID", "0")),
            int(env["REPRO_NUM_PROC"]),
            env.get("REPRO_COORD_ADDR"),
        )
    if "SLURM_NTASKS" in env and int(env["SLURM_NTASKS"]) > 1:
        nodelist = env.get("SLURM_NODELIST", "localhost")
        head = nodelist.split(",")[0].split("[")[0]
        return HostInfo(
            int(env.get("SLURM_PROCID", "0")),
            int(env["SLURM_NTASKS"]),
            f"{head}:12345",
        )
    # Cloud TPU pods: jax.distributed autodetects via metadata
    return HostInfo(0, 1, None)


def init_distributed(info: HostInfo | None = None) -> HostInfo:
    """Call once, before any other jax API, on every host."""
    import jax

    info = info or detect_cluster()
    if info.num_processes > 1:
        jax.distributed.initialize(
            coordinator_address=info.coordinator,
            num_processes=info.num_processes,
            process_id=info.process_id,
        )
    return info


def host_batch_slice(global_batch: int, info: HostInfo) -> slice:
    """Rows of the global batch this host materializes."""
    assert global_batch % info.num_processes == 0, (
        f"global batch {global_batch} must divide {info.num_processes} hosts"
    )
    per = global_batch // info.num_processes
    return slice(info.process_id * per, (info.process_id + 1) * per)


def make_global_batch(local_batch: dict, mesh, shardings) -> dict:
    """Per-host numpy arrays -> global jax.Arrays under ``shardings``."""
    import jax

    return jax.tree.map(
        lambda x, s: jax.make_array_from_process_local_data(s, x),
        local_batch, shardings,
    )
