"""Roofline analysis over dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell, from the dry-run JSON:

  compute term    = HLO_FLOPs_per_device / peak_FLOPs        (197 TF bf16)
  memory term     = HLO_bytes_per_device / HBM_bw            (819 GB/s)
  collective term = wire_bytes_per_device / link_bw          (50 GB/s)

(The assignment states the terms as global/(chips x rate); the partitioned
module is per-device, and global = per-device x chips, so these coincide.)

FLOPs/bytes/wire come from launch.hlo_cost — the loop-exact static model
over the partitioned HLO (XLA's cost_analysis counts while bodies once;
see hlo_cost docstring).  The collective term uses the bf16-corrected wire
bytes (XLA-CPU widens bf16 collective operands to f32; a TPU lowering does
not).  The memory term uses stated-dtype bytes and is therefore a mild
upper bound on a TPU lowering (documented in EXPERIMENTS.md §Roofline).

Also reported per cell: dominant term, MODEL_FLOPS = 6·N_active·D (train) /
2·N_active·D (inference), the useful-compute ratio HLO/MODEL, and a one-line
"what would move the dominant term" note.
"""

from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import os

PEAK_FLOPS = 197e12     # bf16 / chip (TPU v5e-class)
HBM_BW = 819e9          # B/s / chip
LINK_BW = 50e9          # B/s / link (ICI)


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    mesh: str
    ok: bool
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    model_flops: float = 0.0
    hlo_flops_total: float = 0.0
    peak_bytes: float = 0.0
    error: str = ""

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline step time = max of the three overlappable terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        """HLO flops / MODEL flops (remat + attention + padding overhead)."""
        return self.hlo_flops_total / self.model_flops if self.model_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the bound:
        MODEL_FLOPS / (chips * peak * t_bound)."""
        return self.t_model_compute / self.t_bound if self.t_bound else 0.0

    @property
    def t_model_compute(self) -> float:
        # time the *useful* model flops would take at peak
        return (
            self.model_flops / self.hlo_flops_total * self.t_compute
            if self.hlo_flops_total else 0.0
        )

    def note(self) -> str:
        if self.dominant == "compute":
            return (
                "compute-bound: reduce remat recompute or pad waste "
                f"(useful ratio {self.useful_ratio:.2f})"
            )
        if self.dominant == "memory":
            return (
                "memory-bound: fuse attention (flash kernel keeps scores in "
                "VMEM), shard score tensors, cut fp32 intermediates"
            )
        return (
            "collective-bound: hoist weight all-gathers out of inner loops, "
            "reshard to cut gather volume, overlap with compute"
        )


def load_cell(path: str) -> Cell:
    with open(path) as f:
        r = json.load(f)
    cell = Cell(r["arch"], r["shape"], r["mesh"], r.get("ok", False))
    if not cell.ok:
        cell.error = r.get("error", "?")
        return cell
    hc = r["hlo_cost"]
    dev = r["devices"]
    cell.t_compute = hc["flops"] / PEAK_FLOPS
    cell.t_memory = hc["hbm_bytes"] / HBM_BW
    cell.t_collective = hc["coll_wire_bytes_bf16"] / LINK_BW
    cell.model_flops = r["model_flops"]
    cell.hlo_flops_total = hc["flops"] * dev
    cell.peak_bytes = r["memory_analysis"]["peak_bytes_est"]
    return cell


def load_all(dirpath: str, mesh: str | None = None) -> list[Cell]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        c = load_cell(path)
        if mesh is None or c.mesh == mesh:
            cells.append(c)
    return cells


def render_markdown(cells: list[Cell]) -> str:
    hdr = (
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "dominant | HBM/dev GiB | HLO/MODEL | roofline frac | note |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    rows = []
    for c in cells:
        if not c.ok:
            rows.append(
                f"| {c.arch} | {c.shape} | {c.mesh} | - | - | - | FAILED | - |"
                f" - | - | {c.error[:60]} |"
            )
            continue
        rows.append(
            f"| {c.arch} | {c.shape} | {c.mesh} "
            f"| {c.t_compute:.4f} | {c.t_memory:.4f} | {c.t_collective:.4f} "
            f"| **{c.dominant}** | {c.peak_bytes / 2**30:.2f} "
            f"| {c.useful_ratio:.2f} | {c.roofline_fraction:.3f} "
            f"| {c.note()} |"
        )
    return hdr + "\n".join(rows) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/results/dryrun")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    cells = load_all(args.dir, args.mesh)
    md = render_markdown(cells)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md)
    print(md)


if __name__ == "__main__":
    main()
