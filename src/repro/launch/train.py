"""Training launcher: fault-tolerant loop over the token pipeline.

CPU-runnable with the reduced (smoke) configs — the same driver targets a
real pod by passing --mesh pod on a TPU runtime (the mesh context makes all
logical-axis annotations bind to physical axes; on CPU without --mesh they
are no-ops).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data.tokens import TokenDataset
from repro.dist.sharding import DEFAULT_RULES, mesh_context
from repro.ft.restart import RestartManager
from repro.train.step import TrainSettings, init_train_state, make_train_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=25)
    ap.add_argument("--mesh", default="none", choices=["none", "pod", "multipod"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    settings = TrainSettings(
        microbatches=args.microbatches, peak_lr=args.lr,
        warmup=max(5, args.steps // 10), total_steps=args.steps,
        remat=True,
    )

    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
        ctx = mesh_context(mesh, DEFAULT_RULES)
    else:
        import contextlib

        ctx = contextlib.nullcontext()

    data = TokenDataset(cfg.vocab_size, args.seq, args.batch, seed=args.seed)

    def batch_fn(step: int):
        b = data.batch_at(step)
        extra = {}
        if cfg.family == "encdec":
            extra["frames"] = np.zeros(
                (args.batch, args.seq, cfg.d_model), np.float32
            )
        if cfg.family == "vlm":
            extra["image_embeds"] = np.zeros(
                (args.batch, cfg.num_image_tokens, cfg.d_model), np.float32
            )
        return {**{k: jax.numpy.asarray(v) for k, v in b.items()},
                **{k: jax.numpy.asarray(v) for k, v in extra.items()}}

    losses = []

    def metrics_cb(step, metrics, dt):
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps:
            print(
                f"step {step:5d}  loss {float(metrics['loss']):.4f}  "
                f"acc {float(metrics['accuracy']):.3f}  "
                f"gnorm {float(metrics['grad_norm']):.2f}  {dt * 1e3:.0f} ms",
                flush=True,
            )

    with ctx:
        state = init_train_state(cfg, jax.random.PRNGKey(args.seed))
        step_fn = jax.jit(make_train_step(cfg, settings), donate_argnums=(0,))

        t0 = time.perf_counter()
        if args.ckpt_dir:
            mgr = RestartManager(
                args.ckpt_dir, save_every=args.save_every
            )
            state, start = mgr.maybe_restore(state)
            if start:
                print(f"resumed from checkpoint at step {start}")
            state, step = mgr.run(
                state, step_fn, batch_fn,
                num_steps=args.steps, start_step=start,
                metrics_cb=metrics_cb,
            )
        else:
            for step in range(args.steps):
                t1 = time.perf_counter()
                state, metrics = step_fn(state, batch_fn(step))
                metrics_cb(step + 1, metrics, time.perf_counter() - t1)
        wall = time.perf_counter() - t0

    out = {
        "arch": cfg.name,
        "steps": args.steps,
        "first_loss": losses[0] if losses else None,
        "final_loss": losses[-1] if losses else None,
        "wall_s": round(wall, 1),
    }
    print(out)
    return out


if __name__ == "__main__":
    main()
