"""Serving launcher: batched requests through the ServeEngine.

CPU-runnable with the smoke configs; the identical engine drives a pod by
passing --mesh pod on a TPU runtime.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --requests 8
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.dist.sharding import DEFAULT_RULES, mesh_context
from repro.serve.engine import Request, ServeEngine
from repro.train.step import cast_for_compute, init_train_state


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--mesh", default="none", choices=["none", "pod", "multipod"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)

    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
        ctx = mesh_context(mesh, DEFAULT_RULES)
    else:
        import contextlib

        ctx = contextlib.nullcontext()

    rng = np.random.default_rng(args.seed)
    with ctx:
        params = cast_for_compute(
            init_train_state(cfg, jax.random.PRNGKey(args.seed))["params"]
        )
        engine = ServeEngine(
            cfg, params, batch_slots=args.slots, max_seq=args.max_seq
        )
        for rid in range(args.requests):
            engine.submit(Request(
                rid,
                rng.integers(0, cfg.vocab_size, size=args.prompt_len
                             ).astype(np.int32),
                max_new_tokens=args.max_new,
            ))
        stats = engine.run(max_steps=args.requests * args.max_new + 64)
    print(stats)
    return stats


if __name__ == "__main__":
    main()
