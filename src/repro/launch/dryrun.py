import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import (jax locks the device
count on first init); that is why they head the module.

Per cell this proves, without TPU hardware:
  * the sharding config is coherent (GSPMD partitions the step),
  * the per-device memory footprint fits (memory_analysis),
  * and it yields the roofline inputs (cost_analysis + HLO collectives).

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both --out benchmarks/results/dryrun
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.common import pytree as pt
from repro.configs import SHAPES, applicable_shapes, get_config, list_archs
from repro.dist.sharding import (
    DECODE_RULES, DEFAULT_RULES, PREFILL_RULES, mesh_context,
)
from repro.launch import hlo as hlo_mod
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh, sharding_tree
from repro.launch.specs import input_specs, state_defs_for
from repro.models import registry
from repro.serve.steps import make_decode_step, make_prefill_step
from repro.train.step import TrainSettings, make_train_step


def pick_rules(cfg, shape):
    """Decode rules (replicated activations, 2D-sharded weights) only pay
    when weights dwarf activations: >5B params.  Small models keep the
    batch-sharded default — measured crossover in EXPERIMENTS.md §Perf."""
    if shape.kind == "decode":
        from repro.models import registry

        if registry.param_count(cfg) > 5e9:
            return DECODE_RULES
    if shape.kind in ("prefill", "decode"):
        return PREFILL_RULES
    return DEFAULT_RULES


def pick_train_settings(cfg, shape, mesh) -> TrainSettings:
    """Microbatch count targeting ~1 sample/device/microbatch."""
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    micro = max(1, min(16, shape.global_batch // dp))
    while shape.global_batch % micro:
        micro -= 1
    return TrainSettings(microbatches=micro, remat=True)


def build_step_and_specs(cfg, shape, mesh, *, microbatches=None, rules=None):
    """-> (fn, arg_specs tuple, in_shardings, out_shardings, donate)."""
    if rules is None:
        rules = pick_rules(cfg, shape)
    specs = input_specs(cfg, shape)
    defs = state_defs_for(cfg, shape)
    sh = {k: sharding_tree(v, mesh, rules) for k, v in defs.items()}
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(mesh, P())
    tok_sh = sharding_tree(
        pt.ParamDef((1, 1), jnp.int32, ("batch", None), "zeros"), mesh, rules
    )

    if shape.kind == "train":
        settings = pick_train_settings(cfg, shape, mesh)
        if microbatches:
            micro = min(microbatches, shape.global_batch)
            while shape.global_batch % micro:
                micro -= 1
            settings = TrainSettings(microbatches=micro, remat=True)
        fn = make_train_step(cfg, settings)
        args = (specs["state"], specs["batch"])
        in_sh = (sh["state"], sh["batch"])
        out_sh = (sh["state"], rep)
        donate = (0,)
    elif shape.kind == "prefill":
        fn = make_prefill_step(cfg)
        args = (specs["params"], specs["cache"], specs["batch"])
        in_sh = (sh["params"], sh["cache"], sh["batch"])
        out_sh = (tok_sh, sh["cache"])
        donate = (1,)
    else:
        raw = make_decode_step(cfg)

        def fn(params, cache, batch, index):
            return raw(params, cache, batch["tokens"], index)

        args = (specs["params"], specs["cache"], specs["batch"],
                specs["index"])
        in_sh = (sh["params"], sh["cache"], sh["batch"], rep)
        out_sh = (tok_sh, sh["cache"])
        donate = (1,)
    return fn, args, in_sh, out_sh, donate


def analytic_hbm_bytes(cfg, shape, mesh) -> float:
    """Cross-check: parameter+state bytes per device (excl. activations)."""
    defs = state_defs_for(cfg, shape)
    total = 0
    for tree in defs.values():
        total += pt.param_bytes(tree) if not isinstance(tree, pt.ParamDef) \
            else tree.size * jnp.dtype(tree.dtype).itemsize
    return total / mesh.size


def run_cell(arch: str, shape_name: str, mesh_kind: str) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "mesh_shape": dict(mesh.shape), "kind": shape.kind,
        "devices": mesh.size, "ok": False,
    }
    t0 = time.perf_counter()
    rules = pick_rules(cfg, shape)
    try:
        with mesh, mesh_context(mesh, rules):
            fn, args, in_sh, out_sh, donate = build_step_and_specs(
                cfg, shape, mesh, rules=rules
            )
            jitted = jax.jit(
                fn, in_shardings=in_sh, out_shardings=out_sh,
                donate_argnums=donate,
            )
            lowered = jitted.lower(*args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        txt = compiled.as_text()
        colls = hlo_mod.parse_collectives(txt, mesh.size)
        csum = hlo_mod.summarize_collectives(colls)
        cost = hlo_cost.analyze(txt, mesh.size)

        rec.update({
            "hlo_cost": cost.to_json(),
            "ok": True,
            "wall_lower_s": round(t_lower, 2),
            "wall_compile_s": round(t_compile, 2),
            "memory_analysis": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_bytes_est": ma.argument_size_in_bytes
                + ma.temp_size_in_bytes
                + ma.output_size_in_bytes
                - ma.alias_size_in_bytes,
            },
            "cost_analysis": {
                "flops": ca.get("flops", 0.0),
                "transcendentals": ca.get("transcendentals", 0.0),
                "bytes_accessed": ca.get("bytes accessed", 0.0),
            },
            "collectives": csum,
            "hlo_ops": hlo_mod.hlo_op_histogram(txt),
            "model_flops": registry.model_flops(cfg, shape),
            "params": registry.param_count(cfg),
            "active_params": registry.active_param_count(cfg),
            "analytic_state_bytes_per_dev": analytic_hbm_bytes(
                cfg, shape, mesh
            ),
        })
    except Exception as e:  # noqa: BLE001 — a failed cell is a report, not a crash
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc(limit=6)
    rec["wall_total_s"] = round(time.perf_counter() - t0, 2)
    return rec


def all_cells() -> list[tuple[str, str]]:
    cells = []
    for arch in list_archs():
        cfg = get_config(arch)
        for s in applicable_shapes(cfg):
            cells.append((arch, s))
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for arch, s in all_cells():
            print(f"{arch:28s} {s}")
        return

    cells = all_cells() if args.all else [(args.arch, args.shape)]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    os.makedirs(args.out, exist_ok=True)
    for mesh_kind in meshes:
        for arch, shape in cells:
            path = os.path.join(args.out, f"{mesh_kind}__{arch}__{shape}.json")
            if os.path.exists(path) and not args.force:
                with open(path) as f:
                    old = json.load(f)
                if old.get("ok"):
                    print(f"[skip] {mesh_kind} {arch} {shape} (cached ok)")
                    continue
            print(f"[run ] {mesh_kind} {arch} {shape} ...", flush=True)
            rec = run_cell(arch, shape, mesh_kind)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            status = "ok" if rec["ok"] else f"FAIL {rec.get('error', '')[:120]}"
            print(
                f"[done] {mesh_kind} {arch} {shape}: {status} "
                f"({rec['wall_total_s']}s)", flush=True,
            )


if __name__ == "__main__":
    main()
