"""Fault tolerance: restart manager + straggler watchdog.

RestartManager wraps a training loop: it checkpoints every N steps and, on
crash/restart, resumes from the latest complete checkpoint with the exact
data stream position (stateless TokenDataset.batch_at(step)).  The
fault-injection test (tests/test_fault_tolerance.py) proves resumed runs are
bitwise-identical to uninterrupted ones.

StragglerWatchdog tracks per-step wall times; a step slower than
``threshold x`` the running median is flagged.  On real multi-host pods the
flag feeds the rebalance hook (e.g. skip-and-redistribute microbatches or
evict the slow host and trigger an elastic remesh from checkpoint -- the
remesh path is exercised by tests/test_checkpoint.py::test_elastic_reshard).
"""

from __future__ import annotations

import statistics
import time
from typing import Callable

from repro.ckpt import AsyncCheckpointer, latest_step, restore_checkpoint


class StragglerWatchdog:
    def __init__(self, threshold: float = 3.0, window: int = 32):
        self.threshold = threshold
        self.window = window
        self.times: list[float] = []
        self.flagged: list[int] = []
        self.on_straggler: Callable[[int, float], None] | None = None

    def observe(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        if len(self.times) >= 5:
            med = statistics.median(self.times)
            if dt > self.threshold * med:
                self.flagged.append(step)
                if self.on_straggler:
                    self.on_straggler(step, dt / med)
                return True
        return False


class RestartManager:
    def __init__(
        self,
        ckpt_dir: str,
        *,
        save_every: int = 50,
        keep: int = 3,
    ):
        self.ckpt_dir = ckpt_dir
        self.save_every = save_every
        self.ckpt = AsyncCheckpointer(ckpt_dir, keep=keep)
        self.watchdog = StragglerWatchdog()

    def maybe_restore(self, state, shardings=None):
        """Resume from latest checkpoint if one exists."""
        step = latest_step(self.ckpt_dir)
        if step is None:
            return state, 0
        restored, step = restore_checkpoint(
            self.ckpt_dir, state, step, shardings=shardings
        )
        return restored, step

    def run(
        self,
        state,
        step_fn,
        batch_fn,
        *,
        num_steps: int,
        start_step: int = 0,
        metrics_cb=None,
    ):
        """Drive the train loop with periodic async checkpoints."""
        step = start_step
        while step < num_steps:
            t0 = time.perf_counter()
            batch = batch_fn(step)
            state, metrics = step_fn(state, batch)
            dt = time.perf_counter() - t0
            self.watchdog.observe(step, dt)
            step += 1
            if metrics_cb:
                metrics_cb(step, metrics, dt)
            if step % self.save_every == 0 or step == num_steps:
                self.ckpt.save(state, step)
        self.ckpt.wait()
        return state, step
