from repro.ft.restart import RestartManager, StragglerWatchdog
