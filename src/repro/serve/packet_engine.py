"""Batched packet-serving engine for generated data-plane pipelines.

The LM ``ServeEngine`` (serve/engine.py) batches token requests into fixed
decode slots; ``PacketServeEngine`` is its data-plane sibling: it
micro-batches incoming packets into a FIXED batch shape and pushes them
through ONE compiled program — a ``CompiledDag`` (whole-DAG jit from
core.chaining) or a single ``Pipeline``.  The fixed shape means the XLA
executable is compiled exactly once; ragged tails are zero-padded and the
padding verdicts sliced off, so steady-state serving never re-traces.

Typical use::

    dag = chaining.compile_dag(ad > tc, result)
    eng = PacketServeEngine(dag, feature_dim=7, max_batch=512)
    eng.submit(packets)           # any [n, F] chunk, any n
    verdicts = eng.flush()        # all pending verdicts, in arrival order
    print(eng.stats())
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Iterable, Iterator

import numpy as np


@dataclasses.dataclass
class ServeStats:
    packets: int = 0
    batches: int = 0
    pad_packets: int = 0           # zero-rows added to fill the last batch
    wall_s: float = 0.0

    @property
    def pkt_per_s(self) -> float:
        return self.packets / max(self.wall_s, 1e-9)

    def as_dict(self) -> dict:
        return {
            "packets": self.packets,
            "batches": self.batches,
            "pad_packets": self.pad_packets,
            "wall_s": round(self.wall_s, 6),
            "pkt_per_s": round(self.pkt_per_s, 1),
        }


class PacketServeEngine:
    """Micro-batching front-end over one compiled pipeline/DAG callable."""

    def __init__(self, pipeline: Callable[[np.ndarray], np.ndarray], *,
                 feature_dim: int, max_batch: int = 256):
        self.pipeline = pipeline
        self.feature_dim = int(feature_dim)
        self.max_batch = int(max_batch)
        self._queue: collections.deque[np.ndarray] = collections.deque()
        self._pending = 0
        self.stats_ = ServeStats()
        # warm the executable so steady-state timing excludes compilation
        self.pipeline(np.zeros((self.max_batch, self.feature_dim),
                               np.float32))

    # ------------------------------------------------------------ intake

    def submit(self, packets: np.ndarray) -> None:
        """Enqueue a [n, F] chunk of packets (any n >= 1).

        The chunk is copied: callers typically reuse one read buffer per
        chunk, and the queue may hold rows across several flushes."""
        pkts = np.array(packets, np.float32)   # always copies
        if pkts.ndim == 1:
            pkts = pkts[None, :]
        if pkts.shape[1] != self.feature_dim:
            raise ValueError(
                f"expected {self.feature_dim} features, got {pkts.shape[1]}"
            )
        self._queue.append(pkts)
        self._pending += len(pkts)

    @property
    def pending(self) -> int:
        return self._pending

    # ----------------------------------------------------------- serving

    def _take(self, n: int) -> np.ndarray:
        """Pop exactly n rows off the queue head (views where possible)."""
        taken, got = [], 0
        while got < n:
            head = self._queue[0]
            need = n - got
            if len(head) <= need:
                taken.append(self._queue.popleft())
                got += len(head)
            else:
                taken.append(head[:need])
                self._queue[0] = head[need:]   # view; no copy of the tail
                got = n
        self._pending -= n
        return taken[0] if len(taken) == 1 else np.concatenate(taken, 0)

    def _run_batch(self, batch: np.ndarray) -> np.ndarray:
        n = len(batch)
        pad = self.max_batch - n
        if pad:
            batch = np.concatenate(
                [batch, np.zeros((pad, self.feature_dim), np.float32)]
            )
            self.stats_.pad_packets += pad
        t0 = time.perf_counter()
        verdicts = np.asarray(self.pipeline(batch))
        self.stats_.wall_s += time.perf_counter() - t0
        self.stats_.batches += 1
        self.stats_.packets += n
        return verdicts[:n]

    def flush(self) -> np.ndarray:
        """Serve everything pending; verdicts come back in arrival order."""
        outs = []
        while self._pending:
            outs.append(
                self._run_batch(self._take(min(self.max_batch,
                                               self._pending)))
            )
        if not outs:
            return np.zeros((0,), np.int32)
        return outs[0] if len(outs) == 1 else np.concatenate(outs, 0)

    def serve_stream(self, chunks: Iterable[np.ndarray]
                     ) -> Iterator[np.ndarray]:
        """Pull-through mode: yield verdicts per full micro-batch as the
        input stream arrives (tail flushed at end)."""
        for chunk in chunks:
            self.submit(chunk)
            while self._pending >= self.max_batch:
                yield self._run_batch(self._take(self.max_batch))
        if self._pending:
            yield self.flush()

    def stats(self) -> dict:
        return self.stats_.as_dict()
