"""Batched packet-serving engine for generated data-plane pipelines.

The LM ``ServeEngine`` (serve/engine.py) batches token requests into fixed
decode slots; ``PacketServeEngine`` is its data-plane sibling: it
micro-batches incoming packets into a FIXED batch shape and pushes them
through ONE compiled program — a ``CompiledDag`` (whole-DAG jit from
core.chaining), a single ``Pipeline``, or a stateful
``flowstate.StatefulPipeline``.  The fixed shape means the XLA executable
is compiled exactly once; ragged tails are zero-padded and the padding
verdicts sliced off, so steady-state serving never re-traces.

Stateful serving: a ``StatefulPipeline`` threads a per-flow register file
(``FlowState``) through every batch.  The engine owns the state between
batches, feeds padded rows with ``valid=0`` so they NEVER touch the
register table, and applies batches strictly in arrival order — submit/
flush interleavings with ragged chunk sizes cannot reorder updates
(property-tested in tests/test_packet_engine.py).

Typical use::

    dag = chaining.compile_dag(ad > tc, result)
    eng = PacketServeEngine(dag, feature_dim=7, max_batch=512,
                            backend="pallas")
    eng.submit(packets)           # any [n, F] chunk, any n
    verdicts = eng.flush()        # all pending verdicts, in arrival order
    print(eng.stats())            # includes which backend served

    sp = StatefulPipeline(stages, backend="pallas")
    eng = PacketServeEngine(sp, feature_dim=4, max_batch=512)
    # per-flow registers update per packet; eng.state is the live table
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Iterable, Iterator

import numpy as np


@dataclasses.dataclass
class ServeStats:
    packets: int = 0
    batches: int = 0
    pad_packets: int = 0           # zero-rows added to fill the last batch
    wall_s: float = 0.0
    backend: str = "interpret"     # engine the compiled pipeline runs on
    # trailing window of per-batch latencies: bounded so a long-running
    # engine keeps O(1) memory and stats() cost (percentiles are over the
    # most recent LAT_WINDOW batches)
    batch_lat_s: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=ServeStats.LAT_WINDOW)
    )

    LAT_WINDOW = 4096

    @property
    def pkt_per_s(self) -> float:
        if self.batches == 0:
            return 0.0             # nothing served yet: rate is 0, not 0/0
        return self.packets / max(self.wall_s, 1e-9)

    def _lat_ms(self, q: float) -> float:
        if not self.batch_lat_s:
            return 0.0
        return float(np.percentile(np.asarray(self.batch_lat_s), q)) * 1e3

    @property
    def lat_p50_ms(self) -> float:
        """Median per-batch pipeline latency (padding included)."""
        return self._lat_ms(50)

    @property
    def lat_p95_ms(self) -> float:
        return self._lat_ms(95)

    @property
    def backend_batches(self) -> dict:
        """Batch count per serving engine.  One engine serves the whole
        compiled executable, so this is derived; a DAG mixing engines
        per-model reports as "mixed" here with the per-model detail on
        ``CompiledDag.model_backends``."""
        return {self.backend: self.batches} if self.batches else {}

    def as_dict(self) -> dict:
        return {
            "packets": self.packets,
            "batches": self.batches,
            "pad_packets": self.pad_packets,
            "wall_s": round(self.wall_s, 6),
            "pkt_per_s": round(self.pkt_per_s, 1),
            "lat_p50_ms": round(self.lat_p50_ms, 4),
            "lat_p95_ms": round(self.lat_p95_ms, 4),
            "backend": self.backend,
            "backend_batches": self.backend_batches,
        }


class _CompiledPipeline:
    """numpy front-end over a ``stageir.CompiledStages`` recompile."""

    def __init__(self, compiled):
        self._compiled = compiled
        self.backend = compiled.backend

    def __call__(self, X: np.ndarray) -> np.ndarray:
        return np.asarray(self._compiled(X), np.int32)


def _rebind_backend(pipeline, backend: str):
    """Recompile ``pipeline`` for the requested execution engine.

    A ``CompiledDag`` or ``flowstate.StatefulPipeline`` recompiles itself
    (``with_backend``); a ``codegen.Pipeline`` recompiles its stage list;
    a bare callable has no stage list to lower, so the request degrades to
    serving it as-is (the interpreter fallback the stats then report)."""
    from repro.core import stageir

    if backend not in stageir.EXEC_BACKENDS:
        raise KeyError(f"backend must be one of {stageir.EXEC_BACKENDS}")
    if hasattr(pipeline, "with_backend"):            # chaining.CompiledDag
        return pipeline.with_backend(backend)
    if hasattr(pipeline, "stages"):                  # codegen.Pipeline
        return _CompiledPipeline(
            stageir.compile_stages(pipeline.stages, backend=backend)
        )
    return pipeline


class PacketServeEngine:
    """Micro-batching front-end over one compiled pipeline/DAG callable.

    ``pipeline`` may be a ``codegen.Pipeline``, a ``chaining.CompiledDag``
    or any ``[n, F] -> verdicts`` callable.  ``backend`` optionally
    recompiles the pipeline for a specific execution engine:

    * ``backend=None`` (default) serves the callable as given;
    * ``backend="pallas"`` lowers kernel-eligible pipelines onto fused
      Pallas kernel launches (docs/pipeline_ir.md#pallas-lowering-contract)
      and **falls back to the interpreter** when Pallas is unavailable,
      the stage sequence is outside the kernel envelope, or the callable
      carries no stage list to recompile;
    * ``backend="interpret"`` forces the jitted stage-walk engine.

    Stateful pipelines (``flowstate.StatefulPipeline``, or anything with
    an ``init_state()``/``(state, X, valid)`` shape) thread a per-flow
    register file through the engine: pass ``state=`` to resume an
    existing table or leave it None to start empty.  Padded rows carry
    ``valid=0`` and never touch the registers; batches apply strictly in
    arrival order.

    ``stats()["backend"]`` / ``["backend_batches"]`` report the engine that
    actually served each batch after any fallback; ``lat_p50_ms`` /
    ``lat_p95_ms`` are per-batch pipeline latency percentiles."""

    def __init__(self, pipeline: Callable[[np.ndarray], np.ndarray], *,
                 feature_dim: int, max_batch: int = 256,
                 backend: str | None = None, state=None):
        if backend is not None:
            pipeline = _rebind_backend(pipeline, backend)
        self.pipeline = pipeline
        # engine provenance: "interpret" unless the callable says otherwise
        self.backend = getattr(pipeline, "backend", "interpret")
        if self.backend not in ("interpret", "pallas", "mixed"):
            self.backend = "interpret"   # e.g. Pipeline.backend == "taurus"
        if hasattr(pipeline, "compiled_backend"):   # codegen.Pipeline
            self.backend = pipeline.compiled_backend
        self.feature_dim = int(feature_dim)
        self.max_batch = int(max_batch)
        self._stateful = state is not None or hasattr(pipeline, "init_state")
        if self._stateful and state is None:
            state = pipeline.init_state()
        self.state = state
        self._queue: collections.deque[np.ndarray] = collections.deque()
        self._pending = 0
        self.stats_ = ServeStats(backend=self.backend)
        # warm the executable so steady-state timing excludes compilation
        zeros = np.zeros((self.max_batch, self.feature_dim), np.float32)
        if self._stateful:
            # all-invalid warm-up batch: compiles without touching registers
            self.pipeline(self.state, zeros,
                          np.zeros(self.max_batch, np.int32))
        else:
            self.pipeline(zeros)

    # ------------------------------------------------------------ intake

    def submit(self, packets: np.ndarray) -> None:
        """Enqueue a [n, F] chunk of packets (any n >= 1).

        The chunk is copied: callers typically reuse one read buffer per
        chunk, and the queue may hold rows across several flushes."""
        pkts = np.array(packets, np.float32)   # always copies
        if pkts.ndim == 1:
            pkts = pkts[None, :]
        if pkts.shape[1] != self.feature_dim:
            raise ValueError(
                f"expected {self.feature_dim} features, got {pkts.shape[1]}"
            )
        self._queue.append(pkts)
        self._pending += len(pkts)

    @property
    def pending(self) -> int:
        return self._pending

    # ----------------------------------------------------------- serving

    def _take(self, n: int) -> np.ndarray:
        """Pop exactly n rows off the queue head (views where possible)."""
        taken, got = [], 0
        while got < n:
            head = self._queue[0]
            need = n - got
            if len(head) <= need:
                taken.append(self._queue.popleft())
                got += len(head)
            else:
                taken.append(head[:need])
                self._queue[0] = head[need:]   # view; no copy of the tail
                got = n
        self._pending -= n
        return taken[0] if len(taken) == 1 else np.concatenate(taken, 0)

    def _run_batch(self, batch: np.ndarray) -> np.ndarray:
        n = len(batch)
        pad = self.max_batch - n
        if pad:
            batch = np.concatenate(
                [batch, np.zeros((pad, self.feature_dim), np.float32)]
            )
            self.stats_.pad_packets += pad
        t0 = time.perf_counter()
        if self._stateful:
            valid = np.zeros(self.max_batch, np.int32)
            valid[:n] = 1
            self.state, verdicts = self.pipeline(self.state, batch, valid)
            verdicts = np.asarray(verdicts)
        else:
            verdicts = np.asarray(self.pipeline(batch))
        dt = time.perf_counter() - t0
        self.stats_.wall_s += dt
        self.stats_.batch_lat_s.append(dt)
        self.stats_.batches += 1
        self.stats_.packets += n
        return verdicts[:n]

    def flush(self) -> np.ndarray:
        """Serve everything pending; verdicts come back in arrival order."""
        outs = []
        while self._pending:
            outs.append(
                self._run_batch(self._take(min(self.max_batch,
                                               self._pending)))
            )
        if not outs:
            return np.zeros((0,), np.int32)
        return outs[0] if len(outs) == 1 else np.concatenate(outs, 0)

    def serve_stream(self, chunks: Iterable[np.ndarray]
                     ) -> Iterator[np.ndarray]:
        """Pull-through mode: yield verdicts per full micro-batch as the
        input stream arrives (tail flushed at end)."""
        for chunk in chunks:
            self.submit(chunk)
            while self._pending >= self.max_batch:
                yield self._run_batch(self._take(self.max_batch))
        if self._pending:
            yield self.flush()

    def stats(self) -> dict:
        return self.stats_.as_dict()
