"""Batched packet-serving engine for generated data-plane pipelines.

The LM ``ServeEngine`` (serve/engine.py) batches token requests into fixed
decode slots; ``PacketServeEngine`` is its data-plane sibling: it
micro-batches incoming packets into a FIXED batch shape and pushes them
through ONE compiled program — a ``CompiledDag`` (whole-DAG jit from
core.chaining), a single ``Pipeline``, or a stateful
``flowstate.StatefulPipeline``.  The fixed shape means the XLA executable
is compiled exactly once; ragged tails are zero-padded and the padding
verdicts sliced off, so steady-state serving never re-traces.

Overlap pipelining (docs/pipeline_ir.md#serving-performance-contract):
the engine keeps up to ``depth`` batches in flight — batch N+1 is staged
(copied into a reusable ring of pinned staging buffers) and dispatched
while batch N still computes; results are materialized lazily, only when
``flush()``/stream consumption actually needs them.  Compiled pipelines
expose ``dispatch`` (launch, no device→host copy) and JAX's async
dispatch does the overlap; steady-state serving performs zero per-batch
staging allocations.  ``ServeStats`` separates host dispatch time
(``dispatch_s``) from per-batch pipeline latency (dispatch → result
ready) and accumulates ``wall_s`` as the *active serving span*, so pkt/s
stays honest under overlap instead of crediting hidden device time.

Stateful serving: a ``StatefulPipeline`` threads a per-flow register file
(``FlowState``) through every batch.  The engine owns the state between
batches, feeds padded rows with ``valid=0`` so they NEVER touch the
register table, and applies batches strictly in arrival order — the
in-flight chain is sequentialized by the state dependency itself (each
dispatch consumes the previous dispatch's device-resident state), so
overlap never reorders updates (property-tested in
tests/test_packet_engine.py under depth>1).

Typical use::

    dag = chaining.compile_dag(ad > tc, result)
    eng = PacketServeEngine(dag, feature_dim=7, max_batch=512,
                            backend="pallas", depth=2)
    eng.submit(packets)           # any [n, F] chunk, any n
    verdicts = eng.flush()        # all pending verdicts, in arrival order
    print(eng.stats())            # includes which backend served

    sp = StatefulPipeline(stages, backend="pallas")
    eng = PacketServeEngine(sp, feature_dim=4, max_batch=512)
    # per-flow registers update per packet; eng.state is the live table

Multi-device serving is ``repro.serve.sharded.ShardedPacketServeEngine``.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable, Iterable, Iterator

import numpy as np


@dataclasses.dataclass
class ServeStats:
    packets: int = 0
    batches: int = 0
    pad_packets: int = 0           # zero-rows added to fill the last batch
    # hot-swap accounting (docs/pipeline_ir.md#hot-swap-contract): each
    # installed swap records its end-to-end latency (swap() request ->
    # ring-boundary install, warm-up compile included) and the packet
    # offset of the boundary — packets [0, off) were served by the model
    # before the swap, packets [off, ...) by the model after it
    swaps: int = 0
    swap_lat_s: list = dataclasses.field(default_factory=list)
    swap_pkt_offsets: list = dataclasses.field(default_factory=list)
    # batch count per serving engine, accumulated at dispatch time so the
    # split stays correct across hot swaps that change the backend
    backend_counts: dict = dataclasses.field(default_factory=dict)
    # active serving span: dispatch of a batch -> its result materialized,
    # with overlapping in-flight windows merged (never double-counted), so
    # packets / wall_s is honest throughput under depth>1 overlap
    wall_s: float = 0.0
    # host time spent staging + launching batches (the synchronous part of
    # serving); under overlap this is much smaller than wall_s
    dispatch_s: float = 0.0
    backend: str = "interpret"     # engine the compiled pipeline runs on
    depth: int = 1                 # dispatch-pipeline depth (in-flight cap)
    shards: int = 1                # devices serving (ShardedPacketServeEngine)
    # trailing window of per-batch latencies: bounded so a long-running
    # engine keeps O(1) memory and stats() cost (percentiles are over the
    # most recent LAT_WINDOW batches).  A batch's latency is dispatch ->
    # result ready: under overlap it includes in-flight queueing, which is
    # what a packet actually waits.
    batch_lat_s: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=ServeStats.LAT_WINDOW)
    )

    LAT_WINDOW = 4096

    @property
    def pkt_per_s(self) -> float:
        if self.batches == 0:
            return 0.0             # nothing served yet: rate is 0, not 0/0
        return self.packets / max(self.wall_s, 1e-9)

    def _lat_ms(self, q: float) -> float:
        if not self.batch_lat_s:
            return 0.0             # nothing served: percentiles are 0, not nan
        v = float(np.percentile(np.asarray(self.batch_lat_s), q)) * 1e3
        return v if np.isfinite(v) else 0.0

    @property
    def lat_p50_ms(self) -> float:
        """Median per-batch pipeline latency (padding included)."""
        return self._lat_ms(50)

    @property
    def lat_p95_ms(self) -> float:
        return self._lat_ms(95)

    @property
    def lat_p99_ms(self) -> float:
        return self._lat_ms(99)

    @property
    def backend_batches(self) -> dict:
        """Batch count per serving engine, accumulated per dispatched
        batch — across a hot swap the old and new engines keep separate
        counts.  A DAG mixing engines per-model reports as "mixed" here
        with the per-model detail on ``CompiledDag.model_backends``."""
        if self.backend_counts:
            return dict(self.backend_counts)
        return {self.backend: self.batches} if self.batches else {}

    def count_batch(self, backend: str, n: int, pad: int = 0) -> None:
        """Record one dispatched batch of ``n`` real rows on ``backend``."""
        self.batches += 1
        self.packets += n
        self.pad_packets += pad
        self.backend_counts[backend] = \
            self.backend_counts.get(backend, 0) + 1

    def record_swap(self, lat_s: float) -> None:
        """Record one installed hot swap at the current packet offset."""
        self.swaps += 1
        self.swap_lat_s.append(float(lat_s))
        self.swap_pkt_offsets.append(int(self.packets))

    def as_dict(self) -> dict:
        return {
            "packets": self.packets,
            "batches": self.batches,
            "pad_packets": self.pad_packets,
            "wall_s": round(self.wall_s, 6),
            "dispatch_s": round(self.dispatch_s, 6),
            "pkt_per_s": round(self.pkt_per_s, 1),
            "lat_p50_ms": round(self.lat_p50_ms, 4),
            "lat_p95_ms": round(self.lat_p95_ms, 4),
            "lat_p99_ms": round(self.lat_p99_ms, 4),
            "backend": self.backend,
            "backend_batches": self.backend_batches,
            "depth": self.depth,
            "shards": self.shards,
            "swaps": self.swaps,
            "swap_lat_ms": [round(s * 1e3, 3) for s in self.swap_lat_s],
            "swap_pkt_offsets": [int(p) for p in self.swap_pkt_offsets],
        }


@dataclasses.dataclass
class _InFlight:
    """One dispatched-but-unfetched batch."""

    n: int                         # real (non-padding) rows
    out: Any                       # device array (lazy) or numpy (ready)
    t0: float                      # dispatch start
    ready: float | None            # completion time if known at dispatch
    perm: Any = None               # sharded stateful: per-shard row indices


class _CompiledPipeline:
    """numpy front-end over a ``stageir.CompiledStages`` recompile."""

    def __init__(self, compiled):
        self._compiled = compiled
        self.backend = compiled.backend

    def dispatch(self, X: np.ndarray):
        """Launch without forcing the device->host copy."""
        return self._compiled(X)

    def __call__(self, X: np.ndarray) -> np.ndarray:
        return np.asarray(self.dispatch(X), np.int32)


def _rebind_backend(pipeline, backend: str):
    """Recompile ``pipeline`` for the requested execution engine.

    A ``CompiledDag`` or ``flowstate.StatefulPipeline`` recompiles itself
    (``with_backend``); a ``codegen.Pipeline`` recompiles its stage list;
    a bare callable has no stage list to lower, so the request degrades to
    serving it as-is (the interpreter fallback the stats then report)."""
    from repro.core import stageir

    if backend not in stageir.EXEC_BACKENDS:
        raise KeyError(f"backend must be one of {stageir.EXEC_BACKENDS}")
    if hasattr(pipeline, "with_backend"):            # chaining.CompiledDag
        return pipeline.with_backend(backend)
    if hasattr(pipeline, "stages"):                  # codegen.Pipeline
        return _CompiledPipeline(
            stageir.compile_stages(pipeline.stages, backend=backend)
        )
    return pipeline


def _pipeline_backend(pipeline) -> str:
    """The engine a compiled pipeline reports it actually serves on."""
    from repro.core import stageir

    backend = getattr(pipeline, "backend", "interpret")
    if backend not in stageir.REPORT_BACKENDS:
        backend = "interpret"          # e.g. Pipeline.backend == "taurus"
    if hasattr(pipeline, "compiled_backend"):        # codegen.Pipeline
        backend = pipeline.compiled_backend
    return backend


def _backend_stats_key(pipeline, backend: str) -> str:
    """Per-batch accounting key: the serving engine, annotated with the
    fused-path decline reason when a stateful pipeline asked for the
    single-launch fused kernel and fell back to the split path
    (``StatefulPipeline.fallback_reason``) — so ``backend_counts`` says
    not just WHERE batches served but WHY the fused launch declined."""
    reason = getattr(pipeline, "fallback_reason", None)
    return f"{backend}({reason})" if reason else backend


class PacketServeEngine:
    """Micro-batching front-end over one compiled pipeline/DAG callable.

    ``pipeline`` may be a ``codegen.Pipeline``, a ``chaining.CompiledDag``
    or any ``[n, F] -> verdicts`` callable.  ``backend`` optionally
    recompiles the pipeline for a specific execution engine:

    * ``backend=None`` (default) serves the callable as given;
    * ``backend="pallas"`` lowers kernel-eligible pipelines onto fused
      Pallas kernel launches (docs/pipeline_ir.md#pallas-lowering-contract)
      — a whole kernel-eligible DAG onto ONE megakernel launch
      (``"pallas-fused-dag"``) — and **falls back to the interpreter**
      when Pallas is unavailable, the stage sequence is outside the kernel
      envelope, or the callable carries no stage list to recompile;
    * ``backend="interpret"`` forces the jitted stage-walk engine.

    ``depth`` is the dispatch-pipeline depth: up to ``depth`` batches stay
    in flight before the engine blocks on the oldest result (``depth=1``
    reproduces strictly synchronous serving; the default ``2`` is the
    double-buffered pipeline — stage/dispatch batch N+1 while N computes).
    Results are only materialized on ``flush()``/stream consumption, and
    verdicts always come back in arrival order regardless of depth.

    Stateful pipelines (``flowstate.StatefulPipeline``, or anything with
    an ``init_state()``/``(state, X, valid)`` shape) thread a per-flow
    register file through the engine: pass ``state=`` to resume an
    existing table or leave it None to start empty.  Padded rows carry
    ``valid=0`` and never touch the registers; batches apply strictly in
    arrival order — the state dependency itself sequentializes the
    in-flight chain, so overlap is safe.  A pipeline with a trailing
    ``Mitigate`` stage also threads its action table through the same
    state; dropped packets come back as ``flowstate.MITIGATED`` (-1)
    verdicts (docs/pipeline_ir.md#mitigation-contract).

    ``stats()["backend"]`` / ``["backend_batches"]`` report the engine that
    actually served each batch after any fallback; ``lat_p50_ms`` /
    ``lat_p95_ms`` / ``lat_p99_ms`` are per-batch pipeline latency
    percentiles and ``dispatch_s`` the host-side dispatch time.

    ``telemetry`` attaches the unified observability plane
    (docs/pipeline_ir.md#telemetry-contract): ``None``/``True`` create a
    fresh enabled ``repro.telemetry.Telemetry``, ``False`` disables
    recording entirely, and an existing instance is shared (several
    engines reporting into one plane).  Recording happens host-side at
    dispatch-ring boundaries only — counters/spans per dispatched batch,
    flow-table health scans at flush boundaries, operator events (hot
    swaps, backend fallbacks) into the journal — so the compiled
    programs and the overlap pipeline are untouched.  Read it back via
    ``engine.telemetry()``."""

    def __init__(self, pipeline: Callable[[np.ndarray], np.ndarray], *,
                 feature_dim: int, max_batch: int = 256,
                 backend: str | None = None, state=None, depth: int = 2,
                 telemetry=None):
        requested_backend = backend
        if backend is not None:
            pipeline = _rebind_backend(pipeline, backend)
        self.pipeline = pipeline
        # engine provenance: "interpret" unless the callable says otherwise
        self.backend = _pipeline_backend(pipeline)
        self._backend_key = _backend_stats_key(pipeline, self.backend)
        self.feature_dim = int(feature_dim)
        self.max_batch = int(max_batch)
        self.depth = max(1, int(depth))
        self._stateful = state is not None or hasattr(pipeline, "init_state")
        if self._stateful and state is None:
            state = pipeline.init_state()
        self.state = state
        # ``dispatch`` launches without the device->host copy; callables
        # without one are served as-is (their results are simply ready at
        # dispatch time and the overlap is a no-op)
        self._dispatch_fn = getattr(pipeline, "dispatch", pipeline)
        self._queue: collections.deque[np.ndarray] = collections.deque()
        self._pending = 0
        self._inflight: collections.deque[_InFlight] = collections.deque()
        # reusable staging ring: depth+1 pinned buffers so the buffer being
        # filled is never one an in-flight batch may still alias
        self._staging = [
            np.zeros((self.max_batch, self.feature_dim), np.float32)
            for _ in range(self.depth + 1)
        ]
        self._valid_staging = [
            np.zeros((self.max_batch,), np.int32)
            for _ in range(self.depth + 1)
        ]
        self._staging_i = 0
        self._mark: float | None = None   # active-span bookkeeping
        # hot-swap plumbing: swap() (any thread) prepares a new pipeline
        # and parks it here; the serving path installs it at the next
        # dispatch-ring boundary (docs/pipeline_ir.md#hot-swap-contract)
        self._swap_lock = threading.Lock()
        self._pending_swap: tuple | None = None
        self.stats_ = ServeStats(backend=self.backend, depth=self.depth)
        self._init_telemetry(telemetry, requested_backend)
        if self._tel is not None:
            with self._tel.tracer.span("warm_up", cat="compile",
                                       backend=self.backend):
                self._warm_up()
        else:
            self._warm_up()

    # --------------------------------------------------------- telemetry

    def telemetry(self):
        """The attached ``repro.telemetry.Telemetry`` plane (None when
        constructed with ``telemetry=False``)."""
        return self._tel

    # slot-segmentation stats are recomputed host-side from the packet
    # rows — ~50us of numpy per batch that would contend with XLA's CPU
    # threads; sampling every Nth batch (first included) keeps the
    # schedule-shape picture while holding the telemetry overhead
    # inside the 97% throughput budget.  Tests set 1 for exact counts.
    TELEMETRY_SEG_SAMPLE = 8

    def _init_telemetry(self, telemetry, requested_backend) -> None:
        """Resolve the plane and pre-bind every hot-path handle ONCE, so
        per-batch recording is a few attribute adds (no name lookups,
        no locks — see repro.telemetry.metrics)."""
        from repro import telemetry as T

        self._tel = T.resolve(telemetry)
        self._tel_flowkey = None
        self._tel_slots = 0
        self._backend_children: dict[str, Any] = {}
        self._health_keys = None       # previous flush-boundary key scan
        self._health_marked = 0        # previous marked-flow count
        self._seg_n = 0                # segmentation sampling tick
        if self._tel is None:
            return
        m = self._tel.metrics
        self._tm = {
            "packets": m.counter(
                "serve_packets_total", "real packets dispatched").default,
            "batches": m.counter(
                "serve_batches_total", "micro-batches dispatched").default,
            "pad": m.counter(
                "serve_pad_packets_total",
                "zero rows added to fill fixed batch shapes").default,
            "swaps": m.counter(
                "serve_swaps_total", "hot swaps installed").default,
            "mitigated": m.counter(
                "serve_mitigated_packets_total",
                "packets dropped/limited by the action table").default,
            "dispatch_ms": m.histogram(
                "serve_dispatch_ms",
                "host time staging + launching one batch").default,
            "batch_lat_ms": m.histogram(
                "serve_batch_latency_ms",
                "dispatch -> result ready, per batch").default,
            "swap_lat_ms": m.histogram(
                "serve_swap_latency_ms",
                "swap request -> ring-boundary install").default,
            "lockstep": m.counter(
                "flow_lockstep_batches_total",
                "sampled stateful batches retired mostly by the "
                "compacted lockstep rounds"
            ).default,
            "drain": m.counter(
                "flow_drain_batches_total",
                "sampled stateful batches with a drain-heavy traffic "
                "shape (served in-kernel by the compacted drain)"
            ).default,
            "deep_pkts": m.counter(
                "flow_deep_packets_total",
                "packets deeper than PAR_ROUNDS in a same-slot chain "
                "(sampled batches)"
            ).default,
            "max_chain": m.gauge(
                "flow_batch_max_chain",
                "deepest same-slot chain of the last dispatched batch"
            ).default,
            "overflow": m.counter(
                "serve_route_overflow_total",
                "rows pushed back to the queue head because their "
                "shard's sub-batch filled (sharded routing)"
            ).default,
        }
        self._backend_counter = m.counter(
            "serve_backend_batches_total",
            "batches per execution backend actually serving")
        m.gauge("serve_depth", "dispatch-pipeline depth").default.set(
            self.depth)
        self._resolve_flow_telemetry(self.pipeline)
        # a fused-envelope decline (reason carried on the pipeline) is a
        # fallback even when the split path still serves on "pallas"
        reason = getattr(self.pipeline, "fallback_reason", None)
        if reason or (requested_backend == "pallas"
                      and self.backend in ("interpret", "mixed")):
            ev = {"requested": requested_backend or "pallas",
                  "actual": self.backend, "engine": type(self).__name__}
            if reason:
                ev["reason"] = reason
            self._tel.journal.emit("backend_fallback", **ev)

    def _resolve_flow_telemetry(self, pipeline) -> None:
        """Grab the FlowKey stage (if any) so per-batch slot-collision
        stats can be recomputed host-side from the packet rows."""
        if self._tel is None or not self._stateful:
            return
        stages = getattr(pipeline, "stages", None)
        spec = getattr(pipeline, "spec", None)
        if stages is None or spec is None:
            return
        from repro.core import stageir

        fk = next((s for s in stages if isinstance(s, stageir.FlowKey)),
                  None)
        if fk is not None:
            self._tel_flowkey = fk
            self._tel_slots = int(spec.n_slots)
            # pre-bind the segmentation helpers off the hot path
            from repro.flowstate.registers import hash_slot_np
            from repro.telemetry import batch_segmentation

            self._hash_slot_np = hash_slot_np
            self._batch_segmentation = batch_segmentation

    def _seg_tick(self) -> bool:
        """True on the sampled batches (every TELEMETRY_SEG_SAMPLE-th,
        first included) whose slot segmentation gets recomputed."""
        self._seg_n += 1
        return self._seg_n % self.TELEMETRY_SEG_SAMPLE == 1 \
            or self.TELEMETRY_SEG_SAMPLE == 1

    def _record_dispatch(self, rows: np.ndarray, n: int, pad: int,
                         t0: float, t1: float, slots=None) -> None:
        """Per-batch hot-path recording: counters, the dispatch span and
        (stateful pipelines) the slot-segmentation statistics mirroring
        the fused kernel's lockstep-vs-drain schedule split.  ``slots`` is the
        precomputed per-row slot vector (sharded routing already holds
        the keys), ``None`` to compute here on sampled batches, or
        ``False`` when the caller sampled the batch OUT."""
        tm = self._tm
        tm["packets"].inc(n)
        tm["batches"].inc(1)
        if pad:
            tm["pad"].inc(pad)
        child = self._backend_children.get(self.backend)
        if child is None:
            child = self._backend_children[self.backend] = \
                self._backend_counter.labels(backend=self.backend)
        child.inc(1)
        tm["dispatch_ms"].observe((t1 - t0) * 1e3)
        self._tel.tracer.record(
            "dispatch", t0, t1,
            args={"backend": self.backend, "rows": n, "pad": pad})
        if self._tel_flowkey is not None and slots is not False:
            if slots is None:
                if not self._seg_tick():
                    return
                slots = self._hash_slot_np(
                    self._tel_flowkey.apply_keys_np(rows), self._tel_slots)
            seg = self._batch_segmentation(slots)
            (tm["drain"] if seg["drain_heavy"] else tm["lockstep"]).inc(1)
            if seg["n_deep"]:
                tm["deep_pkts"].inc(seg["n_deep"])
            tm["max_chain"].set(seg["max_chain"])

    def _scan_flow_health(self) -> None:
        """Flush-boundary health scan of the live register file(s): one
        [S] key compare per table — occupancy/insert/eviction gauges and
        the mitigation engage/release journal events."""
        if self._tel is None or not self._stateful or self.state is None:
            return
        from repro.telemetry import table_health

        h = table_health(self.state, self._health_keys)
        self._health_keys = h.pop("keys")
        m = self._tel.metrics
        m.gauge("flow_occupied_slots",
                "occupied register-file slots").default.set(h["occupied"])
        m.gauge("flow_occupancy_frac",
                "occupied / total slots").default.set(
            round(h["occupancy_frac"], 6))
        if h["inserts"]:
            m.counter("flow_inserts_total",
                      "slots going empty -> occupied between scans"
                      ).default.inc(h["inserts"])
        if h["evictions"]:
            m.counter("flow_evictions_total",
                      "occupied slots whose key changed between scans "
                      "(collision evictions)").default.inc(h["evictions"])
        if h["mit_slots"]:
            m.gauge("flow_mit_occupied",
                    "occupied action-table slots").default.set(
                h["mit_occupied"])
            m.gauge("flow_mit_marked",
                    "flows past the mitigation threshold").default.set(
                h["mit_marked"])
            delta = h["mit_marked"] - self._health_marked
            if delta > 0:
                self._tel.journal.emit(
                    "mitigation_engage", flows=delta,
                    marked=h["mit_marked"],
                    pkt_offset=int(self.stats_.packets))
            elif delta < 0:
                self._tel.journal.emit(
                    "mitigation_release", flows=-delta,
                    marked=h["mit_marked"],
                    pkt_offset=int(self.stats_.packets))
            self._health_marked = h["mit_marked"]

    def _warm_up(self) -> None:
        """Compile the executable so steady-state timing excludes it."""
        zeros = np.zeros((self.max_batch, self.feature_dim), np.float32)
        if self._stateful:
            # all-invalid warm-up batch: compiles without touching
            # registers; adopt the returned state (identical values) so
            # donated input buffers are never reused
            out = self.pipeline(self.state, zeros,
                                np.zeros(self.max_batch, np.int32))
            self.state = out[0]
        else:
            np.asarray(self.pipeline(zeros))

    # ------------------------------------------------------------ intake

    def submit(self, packets: np.ndarray) -> None:
        """Enqueue a [n, F] chunk of packets (any n >= 1).

        The chunk is copied: callers typically reuse one read buffer per
        chunk, and the queue may hold rows across several flushes."""
        pkts = np.array(packets, np.float32)   # always copies
        if pkts.ndim == 1:
            pkts = pkts[None, :]
        if pkts.shape[1] != self.feature_dim:
            raise ValueError(
                f"expected {self.feature_dim} features, got {pkts.shape[1]}"
            )
        self._queue.append(pkts)
        self._pending += len(pkts)

    @property
    def pending(self) -> int:
        return self._pending

    @property
    def in_flight(self) -> int:
        """Batches dispatched but not yet materialized."""
        return len(self._inflight)

    # ----------------------------------------------------------- serving

    def _take(self, n: int) -> np.ndarray:
        """Pop exactly n rows off the queue head (views where possible).

        When a split leaves only a small residual of a large parent chunk
        on the queue, the residual is copied: a view would retain the
        whole parent buffer for as long as the rows sit queued."""
        taken, got = [], 0
        while got < n:
            head = self._queue[0]
            need = n - got
            if len(head) <= need:
                taken.append(self._queue.popleft())
                got += len(head)
            else:
                taken.append(head[:need])
                rest = head[need:]
                if len(rest) * 4 < len(head):   # retained <25% of parent
                    rest = rest.copy()
                self._queue[0] = rest
                got = n
        self._pending -= n
        return taken[0] if len(taken) == 1 else np.concatenate(taken, 0)

    def _requeue_front(self, rows: np.ndarray) -> None:
        """Push rows back to the queue head (sharded overflow path)."""
        self._queue.appendleft(rows)
        self._pending += len(rows)

    def _next_staging(self) -> tuple[np.ndarray, np.ndarray]:
        buf = self._staging[self._staging_i]
        valid = self._valid_staging[self._staging_i]
        self._staging_i = (self._staging_i + 1) % len(self._staging)
        return buf, valid

    def _dispatch_batch(self, rows: np.ndarray) -> int:
        """Stage + launch one batch; returns rows actually dispatched."""
        self._maybe_install_swap()     # dispatch-ring boundary
        n = len(rows)
        pad = self.max_batch - n
        buf, valid = self._next_staging()
        buf[:n] = rows
        if pad:
            buf[n:] = 0.0
        t0 = time.perf_counter()
        if not self._inflight:
            self._mark = t0            # new active-serving span
        if self._stateful:
            valid[:n] = 1
            if pad:
                valid[n:] = 0
            self.state, out = self._dispatch_fn(self.state, buf, valid)
        else:
            out = self._dispatch_fn(buf)
        t1 = time.perf_counter()
        # a numpy result was computed synchronously inside the dispatch
        # call; anything else is a lazy device handle fetched later
        ready = t1 if isinstance(out, np.ndarray) else None
        self.stats_.dispatch_s += t1 - t0
        self.stats_.count_batch(self._backend_key, n, pad)
        if self._tel is not None:
            self._record_dispatch(rows, n, pad, t0, t1)
        self._inflight.append(_InFlight(n, out, t0, ready))
        return n

    # ---------------------------------------------------------- hot swap

    def swap(self, pipeline, *, backend: str | None = None) -> None:
        """Install ``pipeline`` at the next dispatch-ring boundary.

        Zero-downtime model replacement (the hot-swap contract,
        docs/pipeline_ir.md#hot-swap-contract): the new pipeline is
        compiled and warmed HERE, off the serving hot path — typically on
        a background retrain thread — then parked; the serving loop
        installs it between two dispatches, so in-flight batches finish
        on the old model, no batch is dropped or reordered, and from the
        recorded boundary (``stats()["swap_pkt_offsets"]``) on every
        verdict comes from the new model.

        Stateful engines carry the live ``FlowState`` across the swap
        bit-identically when the new pipeline shares the old
        ``FlowStateSpec``; a changed spec migrates the table through the
        documented re-key path (``flowstate.registers.migrate_state``).
        Swapping between stateless and stateful pipelines is an error —
        that is a different engine, not a new model."""
        t_req = time.perf_counter()
        if backend is not None:
            pipeline = _rebind_backend(pipeline, backend)
        stateful = hasattr(pipeline, "init_state")
        if stateful != self._stateful:
            raise ValueError(
                "hot swap cannot change statefulness: engine is "
                f"{'stateful' if self._stateful else 'stateless'}, new "
                f"pipeline is {'stateful' if stateful else 'stateless'}"
            )
        payload = self._prepare_swap(pipeline)
        if self._tel is not None:
            actual = _pipeline_backend(pipeline)
            self._tel.tracer.record(
                "swap_prepare", t_req, time.perf_counter(), cat="swap",
                args={"backend": actual})
            reason = getattr(pipeline, "fallback_reason", None)
            if reason or (backend == "pallas"
                          and actual in ("interpret", "mixed")):
                ev = {"requested": backend or "pallas", "actual": actual,
                      "engine": type(self).__name__, "during": "swap"}
                if reason:
                    ev["reason"] = reason
                self._tel.journal.emit("backend_fallback", **ev)
        with self._swap_lock:
            self._pending_swap = (payload, t_req)

    @property
    def swap_pending(self) -> bool:
        return self._pending_swap is not None

    def _prepare_swap(self, pipeline) -> dict:
        """Compile + warm the new pipeline on throwaway inputs so the
        install itself is O(1) — never a recompile on the serving path."""
        zeros = np.zeros((self.max_batch, self.feature_dim), np.float32)
        if self._stateful:
            # throwaway table: the live state is NOT touched until install
            out = pipeline(pipeline.init_state(), zeros,
                           np.zeros(self.max_batch, np.int32))
            np.asarray(out[1])
        else:
            np.asarray(pipeline(zeros))
        return {"pipeline": pipeline}

    def _maybe_install_swap(self) -> None:
        # lock-free fast path: this runs at EVERY ring boundary, and the
        # single attribute read is atomic — the lock is only needed to
        # claim an actually-parked swap
        if self._pending_swap is None:
            return
        with self._swap_lock:
            pending, self._pending_swap = self._pending_swap, None
        if pending is None:
            return
        payload, t_req = pending
        old_backend = self.backend
        t0 = time.perf_counter()
        self._install_swap(payload)
        t1 = time.perf_counter()
        lat_s = t1 - t_req
        self.stats_.record_swap(lat_s)
        if self._tel is not None:
            self._tm["swaps"].inc(1)
            self._tm["swap_lat_ms"].observe(lat_s * 1e3)
            self._tel.tracer.record(
                "swap_install", t0, t1, cat="swap",
                args={"from": old_backend, "to": self.backend})
            self._tel.journal.emit(
                "hot_swap", lat_ms=round(lat_s * 1e3, 3),
                pkt_offset=int(self.stats_.packets),
                old_backend=old_backend, new_backend=self.backend,
                engine=type(self).__name__)

    def _install_swap(self, payload: dict) -> None:
        pipeline = payload["pipeline"]
        self._carry_state(pipeline)
        self.pipeline = pipeline
        self.backend = _pipeline_backend(pipeline)
        self._backend_key = _backend_stats_key(pipeline, self.backend)
        self._dispatch_fn = getattr(pipeline, "dispatch", pipeline)
        # segmentation stats must track the NEW pipeline's FlowKey/spec
        self._resolve_flow_telemetry(pipeline)

    def _carry_state(self, pipeline) -> None:
        """Same spec: registers carry over bit-identically (the live
        arrays are simply kept).  Changed spec: the documented re-key
        migration (see the hot-swap contract).  Pipelines that know their
        own state shape (``StatefulPipeline.adopt_state``) own the whole
        carry — including the mitigation action table, which follows the
        same rules (docs/pipeline_ir.md#mitigation-contract)."""
        if not self._stateful:
            return
        adopt = getattr(pipeline, "adopt_state", None)
        if adopt is not None:
            self.state = adopt(self.state)
            return
        new_spec = getattr(pipeline, "spec", None)
        old_spec = getattr(self.state, "spec", None)
        if new_spec is None or old_spec is None or new_spec == old_spec:
            return
        from repro.flowstate.registers import migrate_state

        self.state = migrate_state(self.state, new_spec)

    def _fetch_one(self) -> np.ndarray:
        """Materialize the oldest in-flight batch (FIFO: arrival order)."""
        f = self._inflight.popleft()
        v = np.asarray(f.out)          # blocks until the result exists
        end = f.ready if f.ready is not None else time.perf_counter()
        self.stats_.batch_lat_s.append(end - f.t0)
        if self._mark is not None:
            self.stats_.wall_s += max(0.0, end - self._mark)
            self._mark = max(self._mark, end) if self._inflight else None
        if self._tel is not None:
            self._tm["batch_lat_ms"].observe((end - f.t0) * 1e3)
            self._tel.tracer.record(
                "batch", f.t0, end,
                args={"backend": self.backend, "rows": f.n})
        if f.perm is not None:
            out = self._unshard(v, f)
            self._count_mitigated(out)
            return out
        out = v[:f.n]
        # a plain-numpy pipeline may return a VIEW of its input — i.e. of a
        # reusable staging buffer the next dispatch will overwrite; copy so
        # returned verdicts can never be corrupted in place (device-array
        # results are fresh buffers and never alias the ring)
        if isinstance(f.out, np.ndarray) and any(
            np.shares_memory(out, buf) for buf in self._staging
        ):
            out = out.copy()
        self._count_mitigated(out)
        return out

    def _count_mitigated(self, verdicts: np.ndarray) -> None:
        """Count action-table drops (MITIGATED sentinels) in a fetched
        batch — only mitigated pipelines can emit them."""
        if self._tel is None or getattr(self.state, "mit_spec", None) is None:
            return
        dropped = int(np.sum(verdicts < 0))
        if dropped:
            self._tm["mitigated"].inc(dropped)

    def _unshard(self, v: np.ndarray, f: _InFlight) -> np.ndarray:
        raise NotImplementedError      # ShardedPacketServeEngine only

    def flush(self) -> np.ndarray:
        """Serve everything pending; verdicts come back in arrival order."""
        outs = []
        while self._pending:
            while len(self._inflight) >= self.depth:
                outs.append(self._fetch_one())
            self._dispatch_batch(
                self._take(min(self.max_batch, self._pending))
            )
        while self._inflight:
            outs.append(self._fetch_one())
        # the ring is drained: a boundary — install any pending swap even
        # when no further traffic arrives, so a swap never sits parked
        # past a flush
        self._maybe_install_swap()
        self._scan_flow_health()       # flush-boundary table scan
        if not outs:
            return np.zeros((0,), np.int32)
        return outs[0] if len(outs) == 1 else np.concatenate(outs, 0)

    def serve_stream(self, chunks: Iterable[np.ndarray]
                     ) -> Iterator[np.ndarray]:
        """Pull-through mode: yield verdicts per full micro-batch as the
        input stream arrives (tail flushed at end).  With ``depth>1`` the
        next micro-batch dispatches before the previous result is
        consumed, so the device never sits idle between yields."""
        for chunk in chunks:
            self.submit(chunk)
            while self._pending >= self.max_batch:
                while len(self._inflight) >= self.depth:
                    yield self._fetch_one()
                self._dispatch_batch(self._take(self.max_batch))
        if self._pending or self._inflight:
            tail = self.flush()
            if len(tail):
                yield tail

    def stats(self) -> dict:
        return self.stats_.as_dict()
