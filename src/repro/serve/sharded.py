"""Multi-device packet serving: shard each micro-batch across devices.

``ShardedPacketServeEngine`` extends ``PacketServeEngine`` with a
``jax.shard_map`` serving step over a 1-D ``("data",)`` mesh:

* **Stateless pipelines** split every fixed-shape micro-batch evenly —
  device *d* serves the contiguous row slice ``[d*b, (d+1)*b)`` — so
  verdict order is trivially arrival order and the per-device program is
  exactly the single-device executable (Pallas kernels included).

* **Stateful pipelines** keep one *private register table per device* and
  route packets by flow key (key-partitioned hashing: a second
  multiplicative mix of the FNV flow key, independent of the in-table
  slot hash) so every flow always lands on the same device's table.
  Rows are routed host-side in arrival order; a device whose sub-batch
  fills forces the overflow rows back onto the queue head, so per-flow
  update order is preserved exactly.  Verdicts are scattered back to
  arrival positions before they leave the engine.  A mitigated pipeline
  (trailing ``Mitigate`` stage) threads its per-device ACTION tables the
  same way — both tables key on the same flow key, so a flow's detection
  row and action row always live on the same device
  (docs/pipeline_ir.md#mitigation-contract).

* On a **one-device host** the engine degrades to the plain
  ``PacketServeEngine`` serving path (no mesh, no routing) — same
  results, same stats vocabulary (``stats()["shards"] == 1``).

The dispatch-pipeline ``depth`` machinery (overlap, lazy fetch, staging
ring) is inherited unchanged; the sharded step is just a different
launch.  See docs/pipeline_ir.md#serving-performance-contract.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.serve.packet_engine import (
    PacketServeEngine,
    _CompiledPipeline,
    _InFlight,
    _rebind_backend,
)

# key-partitioned hashing: mix the (already FNV-folded) flow key once more
# with a Knuth multiplicative constant and take high bits, so the shard
# index stays independent of the table's slot index (hash & (S-1)) and a
# skewed low-bit key pattern cannot pile flows onto one device
_SHARD_MIX = np.uint32(0x9E3779B1)


def shard_of_key(keys: np.ndarray, n_shards: int) -> np.ndarray:
    """[B] int32 flow keys -> [B] shard ids in [0, n_shards)."""
    with np.errstate(over="ignore"):
        mixed = keys.astype(np.uint32) * _SHARD_MIX
    return ((mixed >> np.uint32(16)) % np.uint32(n_shards)).astype(np.int64)


def route_prefix(shard_ids: np.ndarray, n_shards: int, capacity: int
                 ) -> tuple[int, list]:
    """Largest arrival-order prefix that fits per-shard ``capacity``.

    Returns ``(m, perm)``: the first ``m`` rows fit, and ``perm[s]`` lists
    the original row indices (ascending = arrival order) that shard ``s``
    serves.  Row ``m`` is the first whose shard is already full — rows
    behind it must wait so per-flow order never inverts."""
    ranks = np.empty(len(shard_ids), np.int64)
    for s in range(n_shards):
        mask = shard_ids == s
        ranks[mask] = np.arange(int(mask.sum()))
    over = ranks >= capacity
    m = int(np.argmax(over)) if over.any() else len(shard_ids)
    ids = shard_ids[:m]
    perm = [np.flatnonzero(ids == s) for s in range(n_shards)]
    return m, perm


@dataclasses.dataclass
class ShardedFlowState:
    """Per-device register tables, stacked on a leading shard axis.

    Mitigated pipelines add the per-device ACTION tables (``mit_*``
    fields, None otherwise) — the same state vocabulary as
    ``flowstate.MitigatedFlowState``, one table per shard."""

    spec: object
    keys: object                   # [D, S] int32
    regs: object                   # [D, S, W] f32
    mit_spec: object = None        # flowstate.MitigationSpec | None
    mit_keys: object = None        # [D, Sm] int32
    mit_regs: object = None        # [D, Sm, 2] f32

    @property
    def n_shards(self) -> int:
        return int(np.shape(self.keys)[0])

    @property
    def occupied(self) -> int:
        return int(np.sum(np.asarray(self.keys) >= 0))

    @property
    def mitigated_flows(self) -> int:
        """Marked action-table slots across every shard."""
        if self.mit_spec is None:
            return 0
        mk = np.asarray(self.mit_keys)
        hits = np.asarray(self.mit_regs)[..., 0]
        return int(np.sum((mk >= 0) & (hits >= self.mit_spec.threshold)))

    def arrays(self) -> tuple:
        """The stacked state arrays, in ``step_fn`` argument order."""
        if self.mit_spec is None:
            return (self.keys, self.regs)
        return (self.keys, self.regs, self.mit_keys, self.mit_regs)

    def with_arrays(self, arrays: tuple) -> "ShardedFlowState":
        """Rebuild around fresh stacked arrays (one serving step's out)."""
        if self.mit_spec is None:
            return ShardedFlowState(self.spec, *arrays)
        return ShardedFlowState(self.spec, arrays[0], arrays[1],
                                self.mit_spec, arrays[2], arrays[3])


class ShardedPacketServeEngine(PacketServeEngine):
    """``PacketServeEngine`` that serves each micro-batch across devices.

    ``devices`` defaults to ``jax.devices()``; ``max_batch`` is rounded up
    to a multiple of the device count (the per-device sub-batch is
    ``max_batch // n_shards``).  ``min_shards`` is the degradation
    threshold: with fewer devices the engine serves exactly like the base
    class (tests pass ``min_shards=1`` to exercise the sharded step on a
    one-device host).  Pipelines with no traceable program (bare numpy
    callables) also degrade — shard_map needs something to trace.

    Stateful serving keeps ``n_shards`` private register tables
    (``ShardedFlowState``); feasibility charges one table per device.
    Cross-flow interleaving ACROSS devices is not defined (each table only
    sees its own flows), but per-flow update order is exactly arrival
    order — the single-table ordering guarantee, per flow."""

    def __init__(self, pipeline, *, feature_dim: int, max_batch: int = 256,
                 backend: str | None = None, state=None, depth: int = 2,
                 devices=None, min_shards: int = 2, telemetry=None):
        import jax

        if backend is not None:
            pipeline = _rebind_backend(pipeline, backend)
        devices = list(devices) if devices is not None else jax.devices()
        self.devices = devices
        n = len(devices)
        traceable = _traceable_fn(pipeline)
        # a multi-table stateful pipeline has no single flow key to
        # partition on — its tables key the same packet differently, so a
        # flow cannot be pinned to one device's tables; degrade to the
        # single-device serving path rather than split state incorrectly
        multi_table = getattr(pipeline, "n_tables", 1) > 1
        self.sharded = (n >= max(1, int(min_shards))
                        and traceable is not None and not multi_table)
        if not self.sharded:
            super().__init__(pipeline, feature_dim=feature_dim,
                             max_batch=max_batch, state=state, depth=depth,
                             telemetry=telemetry)
            return

        self.n_shards = n
        self._sub_batch = -(-int(max_batch) // n)       # ceil
        stateful = hasattr(pipeline, "init_state")
        self._mesh, self._sharded_fn = _build_sharded_step(
            traceable, devices, n_state=_n_state(pipeline) if stateful else 0
        )
        if stateful:
            from repro.core import stageir

            self._flowkey = next(s for s in pipeline.stages
                                 if isinstance(s, stageir.FlowKey))
            if state is None:
                state = _init_sharded_state(pipeline, n)
        super().__init__(pipeline, feature_dim=feature_dim,
                         max_batch=self._sub_batch * n, state=state,
                         depth=depth, telemetry=telemetry)
        if not self._stateful:
            self._dispatch_fn = self._sharded_fn
        self.stats_.shards = n
        if self._tel is not None:
            self._tel.metrics.gauge(
                "serve_shards", "devices serving").default.set(n)

    # --------------------------------------------------------- overrides

    def _warm_up(self) -> None:
        if not self.sharded:
            return super()._warm_up()
        zeros = np.zeros((self.max_batch, self.feature_dim), np.float32)
        if self._stateful:
            state, out = self._launch_stateful(
                zeros, np.zeros(self.max_batch, np.int32))
            self.state = state
            np.asarray(out)
        else:
            np.asarray(self._sharded_fn(zeros))

    def _dispatch_batch(self, rows: np.ndarray) -> int:
        if not self.sharded or not self._stateful:
            return super()._dispatch_batch(rows)
        return self._dispatch_routed(rows)

    def _dispatch_routed(self, rows: np.ndarray) -> int:
        """Stateful sharding: route rows to their flow's device table."""
        self._maybe_install_swap()     # dispatch-ring boundary
        keys = self._flowkey.apply_keys_np(rows)
        shard_ids = shard_of_key(keys, self.n_shards)
        m, perm = route_prefix(shard_ids, self.n_shards, self._sub_batch)
        if m < len(rows):
            if self._tel is not None:
                self._tm["overflow"].inc(len(rows) - m)
            self._requeue_front(rows[m:].copy())
        rows = rows[:m]

        b = self._sub_batch
        buf, valid = self._next_staging()
        x = buf.reshape(self.n_shards, b, self.feature_dim)
        v = valid.reshape(self.n_shards, b)
        x[:] = 0.0
        v[:] = 0
        for s, idx in enumerate(perm):
            x[s, :len(idx)] = rows[idx]
            v[s, :len(idx)] = 1

        t0 = time.perf_counter()
        if not self._inflight:
            self._mark = t0
        self.state, out = self._launch_stateful(buf, valid)
        t1 = time.perf_counter()
        self.stats_.dispatch_s += t1 - t0
        self.stats_.count_batch(self._backend_key, m, self.max_batch - m)
        if self._tel is not None:
            slots = False              # sampled out unless the tick fires
            if self._seg_tick():
                # the flow keys are already in hand: fold the shard id
                # into the slot so same-slot chains on DIFFERENT devices
                # never merge (each device walks its own table)
                n_slots = int(self.state.spec.n_slots)
                slots = (shard_ids[:m] * n_slots
                         + self._hash_slot_np(keys[:m], n_slots))
            self._record_dispatch(rows, m, self.max_batch - m, t0, t1,
                                  slots=slots)
        self._inflight.append(_InFlight(m, out, t0, None, perm=perm))
        return m

    def _launch_stateful(self, buf: np.ndarray, valid: np.ndarray):
        """One sharded stateful step over the stacked register tables."""
        import jax.numpy as jnp

        b = self._sub_batch
        x = jnp.asarray(buf, jnp.float32).reshape(
            self.n_shards, b, self.feature_dim)
        v = jnp.asarray(valid, jnp.int32).reshape(self.n_shards, b)
        outs = self._sharded_fn(*self.state.arrays(), x, v)
        return self.state.with_arrays(outs[:-1]), outs[-1]

    def _unshard(self, v: np.ndarray, f: _InFlight) -> np.ndarray:
        """Scatter per-shard outputs (verdicts, or feature rows when the
        classifier suffix emits vectors) back to arrival positions."""
        out = np.empty((f.n,) + v.shape[2:], v.dtype)
        for s, idx in enumerate(f.perm):
            out[idx] = v[s, :len(idx)]
        return out

    # ---------------------------------------------------------- hot swap

    def _prepare_swap(self, pipeline) -> dict:
        """Build + warm the NEW shard_map step off the serving path.

        The swap must keep the engine sharded: a pipeline shard_map cannot
        trace (a bare callable) is rejected rather than silently degrading
        a multi-device engine to one device mid-stream.  Stateful swaps
        must also keep the flow-key columns — the shard a flow lives on is
        a pure function of its key, so changed key columns would strand
        rows on the wrong device's table (re-key across shards is a
        restart, not a swap — see the hot-swap contract).  Swapping
        mitigation in or out is fine: the step signature grows or loses
        the action-table arrays, and the rebuilt shard_map step matches."""
        if not self.sharded:
            return super()._prepare_swap(pipeline)
        traceable = _traceable_fn(pipeline)
        if traceable is None:
            raise ValueError(
                "cannot hot-swap an untraceable pipeline into a sharded "
                "engine (shard_map needs a traceable program)"
            )
        if getattr(pipeline, "n_tables", 1) > 1:
            raise ValueError(
                "cannot hot-swap a multi-table pipeline into a sharded "
                "engine (flows are key-partitioned on ONE flow key)"
            )
        payload = {"pipeline": pipeline}
        mesh, fn = _build_sharded_step(
            traceable, self.devices,
            n_state=_n_state(pipeline) if self._stateful else 0,
        )
        payload["mesh"], payload["fn"] = mesh, fn
        b = self._sub_batch
        if self._stateful:
            from repro.core import stageir

            flowkey = next(s for s in pipeline.stages
                           if isinstance(s, stageir.FlowKey))
            if tuple(flowkey.key_cols) != tuple(self._flowkey.key_cols):
                raise ValueError(
                    "sharded hot swap must preserve FlowKey.key_cols "
                    f"(flows are key-partitioned across shards): "
                    f"{tuple(self._flowkey.key_cols)} -> "
                    f"{tuple(flowkey.key_cols)}"
                )
            payload["flowkey"] = flowkey
            tmp = _init_sharded_state(pipeline, self.n_shards)
            import jax.numpy as jnp

            x = jnp.zeros((self.n_shards, b, self.feature_dim), jnp.float32)
            v = jnp.zeros((self.n_shards, b), jnp.int32)
            np.asarray(fn(*tmp.arrays(), x, v)[-1])
        else:
            np.asarray(fn(np.zeros((self.max_batch, self.feature_dim),
                                   np.float32)))
        return payload

    def _install_swap(self, payload: dict) -> None:
        if not self.sharded:
            return super()._install_swap(payload)
        super()._install_swap(payload)
        self._sharded_fn = payload["fn"]
        self._mesh = payload["mesh"]
        if self._stateful:
            self._flowkey = payload["flowkey"]
        else:
            self._dispatch_fn = self._sharded_fn

    def _carry_state(self, pipeline) -> None:
        if not (self.sharded and self._stateful):
            return super()._carry_state(pipeline)
        import jax.numpy as jnp

        new_spec = getattr(pipeline, "spec", None)
        if new_spec is None:
            return
        old = self.state
        if new_spec == old.spec:
            keys, regs = old.keys, old.regs
        else:
            from repro.flowstate.registers import FlowState, migrate_state

            ks, rs = [], []
            for d in range(self.n_shards):  # re-key each shard's table
                m = migrate_state(
                    FlowState(old.spec,
                              jnp.asarray(np.asarray(old.keys)[d]),
                              jnp.asarray(np.asarray(old.regs)[d])),
                    new_spec,
                )
                ks.append(np.asarray(m.keys))
                rs.append(np.asarray(m.regs))
            keys = jnp.asarray(np.stack(ks))
            regs = jnp.asarray(np.stack(rs))

        new_mit = getattr(pipeline, "mitigation", None)
        if new_mit is None:
            self.state = ShardedFlowState(new_spec, keys, regs)
            return
        from repro.flowstate.mitigation import migrate_mitigation

        old_mit = old.mit_spec
        if old_mit == new_mit:             # bit-identical carry-over
            mk, mr = old.mit_keys, old.mit_regs
        elif old_mit is None:              # mitigation swapped IN: empty
            from repro.flowstate.mitigation import MIT_WIDTH

            mk = jnp.full((self.n_shards, new_mit.n_slots), -1, jnp.int32)
            mr = jnp.zeros((self.n_shards, new_mit.n_slots, MIT_WIDTH),
                           jnp.float32)
        else:                              # re-key each shard's table
            ks, rs = [], []
            for d in range(self.n_shards):
                k1, r1 = migrate_mitigation(
                    np.asarray(old.mit_keys)[d],
                    np.asarray(old.mit_regs)[d], old_mit, new_mit,
                )
                ks.append(np.asarray(k1))
                rs.append(np.asarray(r1))
            mk = jnp.asarray(np.stack(ks))
            mr = jnp.asarray(np.stack(rs))
        self.state = ShardedFlowState(new_spec, keys, regs, new_mit, mk, mr)


def _n_state(pipeline) -> int:
    """Leading state arrays of the pipeline's traceable step (2 for plain
    flow state; 4 with a mitigation action table)."""
    return int(getattr(pipeline, "n_state_arrays", 2))


def _traceable_fn(pipeline):
    """The jnp program shard_map wraps, or None (degrade to base engine)."""
    from repro.core import stageir

    if hasattr(pipeline, "step_fn"):                 # StatefulPipeline
        return pipeline.step_fn
    if hasattr(pipeline, "fn"):                      # chaining.CompiledDag
        return pipeline.fn
    if isinstance(pipeline, _CompiledPipeline):
        return pipeline._compiled.fn
    if getattr(pipeline, "_compiled", None) is not None:  # codegen.Pipeline
        return pipeline._compiled.fn
    if hasattr(pipeline, "stages"):                  # Pipeline w/ custom run
        return stageir.compile_stages(pipeline.stages).fn
    return None


def _build_sharded_step(traceable, devices, *, n_state: int):
    """jit(shard_map(...)) over a 1-D ("data",) mesh of ``devices``.

    ``n_state`` is the number of leading per-device state arrays the
    traceable step threads (0 = stateless; 2 = flow tables; 4 = flow +
    mitigation action tables) — the step signature is ``(*state, x,
    valid) -> (*state', verdicts)`` with every array sharded on its
    leading axis."""
    import jax
    from jax.sharding import Mesh, PartitionSpec as P

    from repro import _compat  # noqa: F401  (jax.shard_map polyfill)

    mesh = Mesh(np.array(devices), ("data",))

    if n_state:
        def step(*args):
            # each program sees its shard with the leading axis dropped,
            # and returns it re-added: [1, …]
            outs = traceable(*(a[0] for a in args))
            return tuple(o[None] for o in outs)

        fn = jax.shard_map(
            step, mesh=mesh,
            in_specs=(P("data"),) * (n_state + 2),
            out_specs=(P("data"),) * (n_state + 1),
            check_rep=False,
        )
        return mesh, jax.jit(fn)

    fn = jax.shard_map(lambda x: traceable(x), mesh=mesh,
                       in_specs=(P("data"),), out_specs=P("data"),
                       check_rep=False)
    jitted = jax.jit(fn)

    def dispatch(buf):
        import jax.numpy as jnp

        return jitted(jnp.asarray(buf, jnp.float32))

    return mesh, dispatch


def _init_sharded_state(pipeline, n_shards: int) -> ShardedFlowState:
    import jax.numpy as jnp

    spec = pipeline.spec
    keys = jnp.full((n_shards, spec.n_slots), -1, jnp.int32)
    regs = jnp.zeros((n_shards, spec.n_slots, spec.width), jnp.float32)
    mit = getattr(pipeline, "mitigation", None)
    if mit is None:
        return ShardedFlowState(spec, keys, regs)
    from repro.flowstate.mitigation import MIT_WIDTH

    return ShardedFlowState(
        spec, keys, regs, mit,
        jnp.full((n_shards, mit.n_slots), -1, jnp.int32),
        jnp.zeros((n_shards, mit.n_slots, MIT_WIDTH), jnp.float32),
    )
