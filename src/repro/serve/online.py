"""The online-learning loop: drift -> background retrain -> hot swap.

Closes the redeployment loop around the serving engine
(docs/pipeline_ir.md#hot-swap-contract): ``HotSwapController`` watches
every submitted packet window with a ``flowstate.drift.DriftDetector``,
and when drift fires hands the recent windows to a
``BackgroundRetrainer`` — a worker thread that builds a new pipeline
(typically ``core.dse.retrain_model`` over features re-extracted from the
drifted windows, warm-started by ``core.traincache.GLOBAL_CACHE``) and
parks it on the engine with ``engine.swap``.  The foreground thread keeps
submitting and flushing the whole time; the swap installs at the next
ring boundary the engine crosses, so serving never pauses and no batch is
dropped.

Division of labor, deliberately:

  * the CONTROLLER is synchronous and cheap — one numpy EWMA update per
    window on the submit path;
  * the RETRAINER owns everything expensive — feature extraction,
    dataset assembly, the DSE racer, compilation, and the engine-side
    swap warm-up (``engine.swap`` traces/compiles the incoming pipeline
    on the caller's thread BEFORE parking it, so the worker pays the
    compile, not the serving thread);
  * the ENGINE's dispatch path never blocks on either — it checks one
    lock-guarded pointer per ring boundary.

The ``retrain_fn`` callback owns labeling policy: production systems
would label drifted windows by slow-path annotation or delayed feedback;
examples/tests use scenario ground truth.
"""

from __future__ import annotations

import threading
import time
from collections import deque

import numpy as np

from repro.flowstate.drift import DriftDetector


class BackgroundRetrainer:
    """One retrain episode on a worker thread, ending in ``engine.swap``.

    ``fn`` is called with the drifted windows (a list of [n, F] packet
    arrays) and must return the new serving pipeline; any exception is
    captured on ``error`` rather than killing the process — the engine
    then simply keeps serving the old model."""

    def __init__(self, engine, fn, windows: list, *,
                 on_done=None):
        self.engine = engine
        self.fn = fn
        self.windows = windows
        self.on_done = on_done
        self.result = None
        self.error: BaseException | None = None
        self.wall_s = 0.0
        self._thread = threading.Thread(
            target=self._run, name="hot-swap-retrain", daemon=True
        )

    def start(self) -> "BackgroundRetrainer":
        self._thread.start()
        return self

    def _run(self) -> None:
        t0 = time.perf_counter()
        try:
            pipeline = self.fn(self.windows)
            # swap() warms/compiles HERE, on the worker thread, then
            # parks; the serving thread only flips a pointer at the next
            # ring boundary
            self.engine.swap(pipeline)
            self.result = pipeline
        except BaseException as e:       # noqa: BLE001 — report, don't die
            self.error = e
        finally:
            self.wall_s = time.perf_counter() - t0
            if self.on_done is not None:
                self.on_done(self)

    @property
    def running(self) -> bool:
        return self._thread.is_alive()

    def join(self, timeout: float | None = None) -> None:
        self._thread.join(timeout)


class HotSwapController:
    """Drift-triggered retraining glued to one serving engine.

    Call ``observe(window)`` with every packet window as (or just before)
    it is submitted to the engine; the controller folds it into the drift
    statistic, keeps the last ``buffer_windows`` windows as the retrain
    corpus, and — when the detector fires — launches ONE background
    retrain episode.  After the retrained pipeline is parked the detector
    re-arms (``reset``), so the next episode measures drift against the
    same frozen snapshot but needs a fresh patience streak.

    ``retrain_fn(windows) -> pipeline`` owns dataset assembly, labeling
    and search; see module docstring.
    """

    def __init__(self, engine, detector: DriftDetector, retrain_fn, *,
                 buffer_windows: int = 64):
        self.engine = engine
        self.detector = detector
        self.retrain_fn = retrain_fn
        self._buffer: deque = deque(maxlen=int(buffer_windows))
        self._worker: BackgroundRetrainer | None = None
        self.episodes = 0          # retrains launched
        self.swapped = 0           # retrains that ended in a parked swap
        self.errors: list[BaseException] = []

    def observe(self, window: np.ndarray) -> float:
        """Fold one packet window in; may launch a retrain.  Returns the
        current drift score (cheap enough for the submit path)."""
        score = self.detector.update(window)
        self._buffer.append(np.array(window, np.float32))
        if self.detector.fired and not self.retraining:
            self._emit("drift", score=round(float(score), 6),
                       windows=len(self._buffer))
            self._launch()
        return score

    @property
    def retraining(self) -> bool:
        return self._worker is not None and self._worker.running

    def _journal(self):
        """The engine's journal, when a telemetry plane is attached."""
        tel = getattr(self.engine, "telemetry", lambda: None)()
        return tel.journal if tel is not None else None

    def _emit(self, kind: str, **fields) -> None:
        j = self._journal()
        if j is not None:
            j.emit(kind, **fields)

    def _launch(self) -> None:
        self.episodes += 1
        self._emit("retrain_start", episode=self.episodes,
                   windows=len(self._buffer))
        self._worker = BackgroundRetrainer(
            self.engine, self.retrain_fn, list(self._buffer),
            on_done=self._finish,
        ).start()

    def _finish(self, worker: BackgroundRetrainer) -> None:
        if worker.error is not None:
            self.errors.append(worker.error)
            self._emit("retrain_done", episode=self.episodes, ok=False,
                       error=repr(worker.error),
                       wall_s=round(worker.wall_s, 3))
            return
        self.swapped += 1
        self._emit("retrain_done", episode=self.episodes, ok=True,
                   wall_s=round(worker.wall_s, 3))
        # re-arm: the NEW model gets its own drift episode
        self.detector.reset()

    def wait(self, timeout: float | None = None) -> bool:
        """Block until the in-flight retrain (if any) has parked its swap.
        Returns True when no retrain is left running.  NOTE: the swap
        still installs at the engine's next ring boundary — follow with
        ``engine.flush()`` (or more traffic) to force installation."""
        if self._worker is not None:
            self._worker.join(timeout)
        return not self.retraining

    def report(self) -> dict:
        return {
            **self.detector.report(),
            "episodes": self.episodes,
            "swapped": self.swapped,
            "retraining": self.retraining,
            "errors": [repr(e) for e in self.errors],
            "retrain_wall_s": (
                round(self._worker.wall_s, 3) if self._worker else 0.0
            ),
        }
