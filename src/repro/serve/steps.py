"""Serving steps: prefill (builds caches) and single-token decode.

decode_step is what the decode_* / long_* dry-run cells lower: one new token
against a KV cache of seq_len, with the cache seq dim sharded over the
``model`` axis (sequence-parallel decode; see models/attention.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import pytree as pt
from repro.configs.base import ModelConfig
from repro.models import registry
from repro.models.transformer import forward


def serve_cache_defs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    return registry.cache_defs(cfg, batch, max_seq)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    defs = serve_cache_defs(cfg, batch, max_seq)
    return jax.tree.map(
        lambda d: jnp.zeros(d.shape, d.dtype), defs, is_leaf=pt.is_def
    )


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, cache, batch):
        kwargs = {}
        if cfg.family == "encdec":
            kwargs["memory_embeds"] = batch["frames"]
        if cfg.family == "vlm":
            kwargs["memory_embeds"] = batch["image_embeds"]
        logits, new_cache, _ = forward(
            params, cfg, tokens=batch["tokens"], mode="prefill",
            caches=cache, logits_slice_last=True, **kwargs,
        )
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token, new_cache

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, tokens, index):
        """tokens [B,1]; index: scalar position of the new token."""
        logits, new_cache, _ = forward(
            params, cfg, tokens=tokens, mode="decode", index=index,
            caches=cache, logits_slice_last=True,
        )
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token, new_cache

    return decode_step
