"""Minimal batched serving engine (continuous-batching-lite).

Maintains a fixed-size slot table; new requests are prefilled into free
slots, all active slots decode in lockstep.  On CPU this drives the
example end-to-end serving driver; on TPU the same engine wraps the jitted
prefill/decode steps.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.serve.steps import init_cache, make_decode_step, make_prefill_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_seq: int = 128):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.batch = batch_slots
        self.prefill = jax.jit(make_prefill_step(cfg))
        self.decode = jax.jit(make_decode_step(cfg))
        self.cache = init_cache(cfg, batch_slots, max_seq)
        self.queue: list[Request] = []
        self.active: dict[int, Request] = {}
        self.index = 0
        self.tokens_out = 0

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        # lockstep engine: admit up to `batch` requests with equal prompt len
        while self.queue and len(self.active) < self.batch:
            req = self.queue.pop(0)
            self.active[req.rid] = req

    def run(self, max_steps: int = 64) -> dict:
        """Serve queued requests; returns stats."""
        t0 = time.perf_counter()
        served = []
        while (self.queue or self.active) and max_steps > 0:
            self._admit()
            reqs = list(self.active.values())
            S = max(len(r.prompt) for r in reqs)
            toks = np.zeros((self.batch, S), np.int32)
            for i, r in enumerate(reqs):
                toks[i, S - len(r.prompt):] = r.prompt  # left-pad
            batch = {"tokens": jnp.asarray(toks)}
            if self.cfg.family == "vlm":
                batch["image_embeds"] = jnp.zeros(
                    (self.batch, self.cfg.num_image_tokens, self.cfg.d_model),
                    jnp.bfloat16,
                )
            if self.cfg.family == "encdec":
                batch["frames"] = jnp.zeros(
                    (self.batch, S, self.cfg.d_model), jnp.bfloat16
                )
            next_tok, self.cache = self.prefill(self.params, self.cache, batch)
            index = jnp.array(S, jnp.int32)
            cur = next_tok
            n_new = max(r.max_new_tokens for r in reqs)
            for step in range(min(n_new, max_steps)):
                for i, r in enumerate(reqs):
                    if len(r.out) < r.max_new_tokens:
                        r.out.append(int(cur[i]))
                        self.tokens_out += 1
                cur, self.cache = self.decode(
                    self.params, self.cache, cur[:, None], index
                )
                index = index + 1
                max_steps -= 1
            for r in reqs:
                r.done = True
                served.append(r)
            self.active.clear()
        dt = time.perf_counter() - t0
        return {
            "requests": len(served),
            "tokens": self.tokens_out,
            "wall_s": dt,
            "tok_per_s": self.tokens_out / max(dt, 1e-9),
        }
