from repro.serve.steps import (
    make_prefill_step,
    make_decode_step,
    serve_cache_defs,
    init_cache,
)
from repro.serve.engine import ServeEngine, Request
from repro.serve.online import BackgroundRetrainer, HotSwapController
from repro.serve.packet_engine import PacketServeEngine, ServeStats
from repro.serve.sharded import ShardedFlowState, ShardedPacketServeEngine
