"""Synthetic network datasets mirroring the paper's three applications.

The paper's datasets (NSL-KDD [23], IIsy IoT traces [96], PeerRush P2P [77])
are not available offline; these generators synthesize statistically faithful
replicas (seeded, deterministic).  Design goals, in order:

  1. *Capacity -> accuracy correlation.*  Class boundaries are nonlinear and
     multi-modal (mixture components + feature interactions), so a small
     hand-tuned DNN underfits and a larger BO-found model measurably improves
     F1 -- the paper's central Table-2 effect.
  2. *Feature-subset degradation.*  Dropping features loses information
     gracefully (IIsy/MAT backend removes "less impactful features" to fit).
  3. *Botnet reactivity* (paper Fig. 6 / §5.1.1): botnet flows are
     low-volume / high-duration vs benign P2P, so *partial* per-packet
     histograms diverge early, and per-packet F1 approaches flow-level F1
     well before flow end.

Absolute F1 values therefore differ from the paper; every relative claim is
reproducible (see benchmarks/table2_f1.py et al.).
"""

from __future__ import annotations

import dataclasses

import numpy as np

# ------------------------------------------------------------------ common


@dataclasses.dataclass
class Dataset:
    """Feature-matrix dataset with train/test split."""

    name: str
    train_x: np.ndarray  # [N, F] float32
    train_y: np.ndarray  # [N] int32
    test_x: np.ndarray
    test_y: np.ndarray
    feature_names: list[str]
    num_classes: int

    @property
    def num_features(self) -> int:
        return self.train_x.shape[1]

    def fingerprint(self) -> str:
        """Content hash of what training sees (train split + class count) —
        the dataset half of the trained-candidate cache key.  Computed once
        and memoized on the instance; arrays are treated as immutable after
        construction (everything in this repo copies instead of mutating)."""
        if getattr(self, "_fingerprint", None) is None:
            import hashlib

            h = hashlib.sha1()
            for a in (self.train_x, self.train_y):
                a = np.ascontiguousarray(a)
                h.update(str(a.shape).encode())
                h.update(str(a.dtype).encode())
                h.update(a.tobytes())
            h.update(str(self.num_classes).encode())
            self._fingerprint = h.hexdigest()
        return self._fingerprint

    def subset_features(self, idx: list[int]) -> "Dataset":
        return Dataset(
            name=f"{self.name}[{len(idx)}f]",
            train_x=self.train_x[:, idx],
            train_y=self.train_y,
            test_x=self.test_x[:, idx],
            test_y=self.test_y,
            feature_names=[self.feature_names[i] for i in idx],
            num_classes=self.num_classes,
        )

    def split_half(self, seed: int = 0) -> tuple["Dataset", "Dataset"]:
        """Split the training rows in two (model-fusion experiment, Table 4)."""
        rng = np.random.default_rng(seed)
        n = len(self.train_x)
        perm = rng.permutation(n)
        a, b = perm[: n // 2], perm[n // 2:]
        mk = lambda part, rows: Dataset(
            name=f"{self.name}-{part}",
            train_x=self.train_x[rows],
            train_y=self.train_y[rows],
            test_x=self.test_x,
            test_y=self.test_y,
            feature_names=self.feature_names,
            num_classes=self.num_classes,
        )
        return mk("part1", a), mk("part2", b)


def _standardize(train_x, test_x):
    mu = train_x.mean(0, keepdims=True)
    sd = train_x.std(0, keepdims=True) + 1e-6
    return (train_x - mu) / sd, (test_x - mu) / sd


# ------------------------------------------------- anomaly detection (AD)

_AD_FEATURES_7 = [
    "duration", "src_bytes", "dst_bytes", "count",
    "srv_count", "serror_rate", "same_srv_rate",
]

_AD_FEATURES_30 = _AD_FEATURES_7 + [f"stat_{i}" for i in range(23)]


def make_ad_dataset(
    *, features: int = 7, n_train: int = 8192, n_test: int = 4096,
    seed: int = 0,
) -> Dataset:
    """NSL-KDD-like anomaly detection: benign vs malicious (binary).

    Attack traffic is a mixture of 4 "attack families" (DoS / probe / R2L /
    U2R-like), each a distinct cluster in a rotated feature subspace, with
    pairwise feature *interactions* deciding class in two of the families --
    this is what makes small models underfit (Table 2 capacity effect).
    """
    assert features in (7, 30)
    rng = np.random.default_rng(seed)
    F = features
    n = n_train + n_test
    y = (rng.random(n) < 0.45).astype(np.int32)  # ~45% attacks

    x = rng.normal(0, 1.0, size=(n, F)).astype(np.float32)
    fam = rng.integers(0, 4, size=n)

    # family-specific mean shifts on small feature subsets
    centers = rng.normal(0, 2.2, size=(4, F)).astype(np.float32)
    mask = rng.random((4, F)) < (4.0 / F)  # each family touches ~4 features
    centers *= mask
    atk = y == 1
    x[atk] += centers[fam[atk]]

    # nonlinear structure: XOR-ish interaction between duration & src_bytes
    # and a ring in (count, srv_count) for two families
    inter = (x[:, 0] * x[:, 1] > 0.0) & np.isin(fam, (0, 1))
    x[atk & inter, 2] += 1.8
    ring = np.sqrt(x[:, 3] ** 2 + x[:, 4] ** 2)
    x[atk & np.isin(fam, (2, 3)), 5] += (2.0 - ring[atk & np.isin(fam, (2, 3))])

    # benign has its own two modes (web-ish vs bulk-ish) to avoid a trivially
    # separable unimodal benign class
    ben_mode = rng.random(n) < 0.5
    x[(~atk) & ben_mode, 0] += 1.2
    x[(~atk) & ~ben_mode, 3] -= 1.2

    # label noise + heavy-tailed measurement noise
    flip = rng.random(n) < 0.04
    y = np.where(flip, 1 - y, y)
    x += rng.standard_t(4, size=(n, F)).astype(np.float32) * 0.35

    tr_x, te_x = x[:n_train], x[n_train:]
    tr_x, te_x = _standardize(tr_x, te_x)
    names = _AD_FEATURES_7 if F == 7 else _AD_FEATURES_30
    return Dataset("anomaly_detection", tr_x.astype(np.float32),
                   y[:n_train], te_x.astype(np.float32), y[n_train:],
                   list(names), 2)


# --------------------------------------------- traffic classification (TC)

_TC_FEATURES = [
    "pkt_size", "eth_type", "ip_proto", "ip_ttl",
    "ip_tos", "src_port_bucket", "dst_port_bucket",
]

_TC_CLASSES = ["camera", "thermostat", "speaker", "bulb", "hub"]


def make_tc_dataset(
    *, n_train: int = 8192, n_test: int = 4096, seed: int = 1,
) -> Dataset:
    """IIsy-style IoT traffic classification: 5 device classes from
    packet-header features.  Each device emits 2-3 traffic modes (e.g. camera
    keepalive vs video burst), so classes are multi-modal -> clusterable by
    KMeans but better separated by a DNN."""
    rng = np.random.default_rng(seed)
    F = len(_TC_FEATURES)
    C = len(_TC_CLASSES)
    n = n_train + n_test
    y = rng.integers(0, C, size=n).astype(np.int32)

    n_modes = 3
    centers = rng.normal(0, 2.0, size=(C, n_modes, F)).astype(np.float32)
    mode_p = rng.dirichlet(np.ones(n_modes) * 1.5, size=C)
    modes = np.array(
        [rng.choice(n_modes, p=mode_p[c]) for c in y], dtype=np.int64
    )
    x = centers[y, modes] + rng.normal(0, 0.9, size=(n, F)).astype(np.float32)

    # port buckets correlate with (class, mode) but overlap across classes
    x[:, 5] = (y + modes + rng.integers(0, 2, size=n)) % C
    x[:, 6] = ((y * 2 + modes) % C) + rng.normal(0, 0.4, size=n)

    flip = rng.random(n) < 0.03
    y = np.where(flip, rng.integers(0, C, size=n), y).astype(np.int32)

    tr_x, te_x = _standardize(x[:n_train], x[n_train:])
    return Dataset("traffic_classification", tr_x.astype(np.float32),
                   y[:n_train], te_x.astype(np.float32), y[n_train:],
                   list(_TC_FEATURES), C)


# ------------------------------------------------- botnet detection (BD)

_PL_BINS = 23   # packet-length bins (paper: fused from 94 -> 23)
_IPT_BINS = 7   # inter-arrival-time bins (paper: fused to 7)
_BD_FEATURES = (
    [f"pl_bin_{i}" for i in range(_PL_BINS)]
    + [f"ipt_bin_{i}" for i in range(_IPT_BINS)]
)


@dataclasses.dataclass
class FlowTrace:
    """A single P2P flow: per-packet sizes and inter-arrival times."""

    sizes: np.ndarray  # [P] bytes
    ipts: np.ndarray   # [P] seconds
    label: int         # 1 = botnet


def _bin_edges():
    pl_edges = np.linspace(0, 1472, _PL_BINS + 1)          # 64B-ish bins
    ipt_edges = np.geomspace(1e-3, 3600.0, _IPT_BINS + 1)  # log-spaced
    return pl_edges, ipt_edges


def flow_histogram(flow: FlowTrace, upto: int | None = None) -> np.ndarray:
    """Flowmarker: normalized [PL||IPT] histogram over the first ``upto``
    packets (None = full flow).  Per-packet *partial* histograms (paper
    §5.1.1) are this with upto=k."""
    pl_edges, ipt_edges = _bin_edges()
    s = flow.sizes[:upto] if upto else flow.sizes
    t = flow.ipts[:upto] if upto else flow.ipts
    h_pl, _ = np.histogram(s, bins=pl_edges)
    h_ipt, _ = np.histogram(t, bins=ipt_edges)
    h = np.concatenate([h_pl, h_ipt]).astype(np.float32)
    return h / max(len(s), 1)


def make_bd_flows(
    *, n_flows: int = 3000, seed: int = 2,
) -> list[FlowTrace]:
    """P2P flows: botnets (Storm/Waledac-like) are low-volume/high-duration
    command-and-control chatter -- small packets, long inter-arrival gaps;
    benign P2P (uTorrent/eMule-like) is bulk transfer -- large packets, short
    gaps -- with a chatty-benign mode (DHT lookups) as the confuser."""
    rng = np.random.default_rng(seed)
    flows = []
    for _ in range(n_flows):
        botnet = rng.random() < 0.5
        if botnet:
            n_pkts = int(rng.integers(30, 150))          # low volume
            # beaconing: small keepalives + occasional command payloads;
            # deliberately close to the chatty-benign (DHT) mode so the
            # classes overlap per-packet and only the histogram SHAPE over
            # enough packets separates them (paper's gradual Fig-6 curve)
            sizes = np.where(
                rng.random(n_pkts) < 0.8,
                rng.normal(180, 70, n_pkts),
                rng.normal(420, 110, n_pkts),
            )
            ipts = rng.lognormal(np.log(9.0), 1.4, n_pkts)  # long-ish gaps
        else:
            chatty = rng.random() < 0.45
            if chatty:  # DHT-lookup mode: smallish packets, medium gaps
                n_pkts = int(rng.integers(60, 300))
                sizes = rng.normal(270, 90, n_pkts)
                ipts = rng.lognormal(np.log(3.0), 1.2, n_pkts)
            else:  # bulk transfer: MTU-sized packets, tiny gaps
                n_pkts = int(rng.integers(200, 900))
                sizes = np.where(
                    rng.random(n_pkts) < 0.8,
                    rng.normal(1380, 60, n_pkts),
                    rng.normal(600, 150, n_pkts),
                )
                ipts = rng.lognormal(np.log(0.05), 0.8, n_pkts)
        sizes = np.clip(sizes, 40, 1472).astype(np.float32)
        ipts = np.clip(ipts, 1e-3, 3600.0).astype(np.float32)
        flows.append(FlowTrace(sizes, ipts, int(botnet)))
    return flows


def make_bd_dataset(
    *, n_flows: int = 3000, test_frac: float = 0.35, seed: int = 2,
) -> tuple[Dataset, list[FlowTrace]]:
    """Training set = *full-flow* flowmarkers (as the paper trains);
    returns held-out raw test flows too, so per-packet partial-histogram
    evaluation (bd_per_packet_eval) can replay them packet by packet."""
    flows = make_bd_flows(n_flows=n_flows, seed=seed)
    rng = np.random.default_rng(seed + 1)
    perm = rng.permutation(len(flows))
    n_test = int(len(flows) * test_frac)
    test_idx, train_idx = perm[:n_test], perm[n_test:]

    def hist_xy(idx):
        x = np.stack([flow_histogram(flows[i]) for i in idx])
        y = np.array([flows[i].label for i in idx], np.int32)
        return x.astype(np.float32), y

    tr_x, tr_y = hist_xy(train_idx)
    te_x, te_y = hist_xy(test_idx)
    ds = Dataset("botnet_detection", tr_x, tr_y, te_x, te_y,
                 list(_BD_FEATURES), 2)
    return ds, [flows[i] for i in test_idx]


def bd_partial_eval_set(
    flows: list[FlowTrace], checkpoints: tuple[int, ...] = (5, 10, 20, 40, 80),
) -> dict[int, tuple[np.ndarray, np.ndarray]]:
    """{k: (X, y)} -- partial flowmarkers after the first k packets.  This is
    the paper's per-packet inference setting: the switch updates a register
    histogram per packet and classifies on the *partial* histogram."""
    out = {}
    for k in checkpoints:
        x = np.stack([flow_histogram(f, upto=k) for f in flows])
        y = np.array([f.label for f in flows], np.int32)
        out[k] = (x.astype(np.float32), y)
    return out


def mean_histograms(flows: list[FlowTrace]) -> dict[str, np.ndarray]:
    """Average full-flow histograms per class (paper Fig. 6)."""
    bot = np.stack([flow_histogram(f) for f in flows if f.label == 1])
    ben = np.stack([flow_histogram(f) for f in flows if f.label == 0])
    return {"botnet": bot.mean(0), "benign": ben.mean(0)}


# ------------------------------------------------------------- registry

def load(name: str, **kw):
    if name == "ad":
        return make_ad_dataset(**kw)
    if name == "ad30":
        return make_ad_dataset(features=30, **kw)
    if name == "tc":
        return make_tc_dataset(**kw)
    if name == "bd":
        return make_bd_dataset(**kw)[0]
    raise KeyError(name)
