"""Streaming traffic-scenario generator: seeded, replayable packet streams.

netdata.py synthesizes *feature matrices* (offline training sets); this
module synthesizes *packet streams* — time-ordered per-packet records that
the stateful serving path (repro.flowstate) consumes live, reproducing the
paper's per-packet reaction-time setting (§5.1.1) on a stream instead of
precomputed flow histograms.  Scenario shapes follow the SDN-DDoS
synthetic-dataset playbook (Mininet + hping3/iperf traffic, flows labeled
by generation-time ground truth): normal traffic from bulk/interactive
generators, attack traffic as floods/scans, label = how the flow was
generated.

Packet record (float32 row, ``COLUMNS`` order):

  ``flow_id``   integral flow key (< 2^22, exact in f32)
  ``pkt_len``   bytes on the wire
  ``ipt_s``     inter-arrival gap to this flow's previous packet (0 for
                the flow's first packet)
  ``dst_port``  destination port (bucketed small int)

Scenarios (every flow carries a ground-truth label; per-packet labels
inherit the flow's):

  ``benign``         web-ish + bulk + DHT-chatty baseline, label 0
  ``ddos_burst``     baseline, then a volumetric burst: many short
                     high-rate small-packet flows onto one service port
  ``port_scan``      baseline + one scanner: hundreds of 1-2 packet
                     SYN-sized flows sweeping ports
  ``elephant_mice``  heavy-hitter detection: few elephant flows (MTU
                     packets, tiny gaps, label 1) among many mice
  ``concept_drift``  the attack SIGNATURE shifts mid-stream: phase A
                     (before ``DRIFT_FRAC`` of the span) is a tiny-packet
                     volumetric flood, phase B a stealth MTU flood whose
                     per-packet shape mimics benign bulk transfers — a
                     model trained on phase A degrades on phase B (the
                     hot-swap loop's test scenario)

Streams are deterministic in (scenario, seed, sizes) and replayable —
``PacketStream.chunks`` re-yields the identical sequence every call.
"""

from __future__ import annotations

import dataclasses

import numpy as np

COLUMNS = ("flow_id", "pkt_len", "ipt_s", "dst_port")
COL_FLOW, COL_LEN, COL_IPT, COL_PORT = range(4)

SCENARIOS = ("benign", "ddos_burst", "port_scan", "elephant_mice",
             "concept_drift")

# concept_drift: fraction of the span where phase B (the shifted attack
# signature) begins — phase A attacks live strictly before it
DRIFT_FRAC = 0.5


@dataclasses.dataclass
class PacketStream:
    """A time-ordered packet stream with per-packet ground truth."""

    scenario: str
    packets: np.ndarray        # [N, 4] f32, COLUMNS order, time-sorted
    labels: np.ndarray         # [N] int32 per-packet (= flow label)
    flow_ids: np.ndarray       # [N] int32 (packets[:, COL_FLOW] as int)
    flow_labels: dict          # flow_id -> label
    times: np.ndarray | None = None   # [N] f64 arrival timestamps

    @property
    def n_packets(self) -> int:
        return len(self.packets)

    @property
    def n_flows(self) -> int:
        return len(self.flow_labels)

    def chunks(self, size: int):
        """Replayable chunk iterator (fresh, identical sequence per call)."""
        for s in range(0, len(self.packets), size):
            yield self.packets[s:s + size]

    def slice(self, start: int, stop: int | None = None) -> "PacketStream":
        """A contiguous packet-index window as its own stream (flow_labels
        keep only flows that appear — reaction metrics stay per-segment)."""
        sl = slice(start, stop)
        fids = self.flow_ids[sl]
        present = set(int(f) for f in np.unique(fids))
        return PacketStream(
            self.scenario, self.packets[sl], self.labels[sl], fids,
            {f: l for f, l in self.flow_labels.items() if f in present},
            None if self.times is None else self.times[sl],
        )


# ------------------------------------------------------------- flow shapes


def _flow(fid, label, t0, sizes, gaps, port):
    return {"fid": int(fid), "label": int(label), "t0": float(t0),
            "sizes": sizes, "gaps": gaps, "port": int(port)}


def _benign_flows(rng, n_flows: int, span: float) -> list[dict]:
    flows = []
    for _ in range(n_flows):
        kind = rng.random()
        if kind < 0.45:       # interactive/web: smallish bimodal packets
            n = int(rng.integers(8, 60))
            sizes = np.where(rng.random(n) < 0.6,
                             rng.normal(240, 80, n),
                             rng.normal(1100, 180, n))
            gaps = rng.lognormal(np.log(0.15), 1.0, n)
            port = int(rng.choice((80, 443)))
        elif kind < 0.8:      # bulk transfer: MTU-sized, tiny gaps
            n = int(rng.integers(60, 300))
            sizes = rng.normal(1380, 60, n)
            gaps = rng.lognormal(np.log(0.01), 0.7, n)
            port = int(rng.choice((443, 8080)))
        else:                 # DHT-ish chatty mode (the confuser)
            n = int(rng.integers(20, 120))
            sizes = rng.normal(300, 90, n)
            gaps = rng.lognormal(np.log(1.0), 1.1, n)
            port = 6881
        flows.append(_flow(0, 0, rng.uniform(0, span * 0.7), sizes, gaps,
                           port))
    return flows


def _attack_flows(rng, scenario: str, span: float) -> list[dict]:
    flows = []
    if scenario == "ddos_burst":
        # volumetric burst from many (spoofed-source) flows onto one port
        burst_t = span * 0.3
        for _ in range(120):
            n = int(rng.integers(40, 160))
            sizes = rng.normal(90, 25, n)              # tiny payloads
            gaps = rng.lognormal(np.log(1.5e-3), 0.5, n)   # ~kHz per flow
            flows.append(_flow(0, 1, burst_t + rng.uniform(0, span * 0.2),
                               sizes, gaps, 80))
    elif scenario == "port_scan":
        # one scanner host: a 1-2 packet SYN-sized flow per swept port
        t = span * 0.25
        for i in range(400):
            n = int(rng.integers(1, 3))
            sizes = rng.normal(48, 4, n)
            gaps = rng.lognormal(np.log(5e-3), 0.4, n)
            flows.append(_flow(0, 1, t, sizes, gaps, 1024 + i))
            t += float(rng.uniform(2e-3, 8e-3))
    elif scenario == "elephant_mice":
        for _ in range(12):
            n = int(rng.integers(600, 1500))
            sizes = rng.normal(1430, 25, n)
            gaps = rng.lognormal(np.log(8e-4), 0.4, n)
            flows.append(_flow(0, 1, rng.uniform(0, span * 0.3), sizes,
                               gaps, 443))
    elif scenario == "concept_drift":
        drift_t = span * DRIFT_FRAC
        # phase A (< DRIFT_FRAC): the ddos_burst signature — many short
        # tiny-packet high-rate flows onto one service port.  A model
        # trained on this phase keys on the small-packet histogram mass.
        for _ in range(70):
            n = int(rng.integers(40, 120))
            sizes = rng.normal(90, 25, n)
            gaps = rng.lognormal(np.log(1.5e-3), 0.5, n)
            flows.append(_flow(0, 1,
                               rng.uniform(span * 0.05, drift_t * 0.7),
                               sizes, gaps, 80))
        # phase B (>= DRIFT_FRAC): a stealth MTU flood — per-packet shape
        # mimics benign bulk transfers (MTU sizes, similar gaps, port
        # 443); only flow VOLUME separates it (elephant lifetimes, so
        # pkt/byte counters run far past any benign bulk flow).  The
        # phase-A model sees none of its signature and misses it.
        for _ in range(30):
            n = int(rng.integers(500, 1100))
            sizes = rng.normal(1430, 40, n)
            gaps = rng.lognormal(np.log(8e-3), 0.3, n)
            flows.append(_flow(0, 1,
                               drift_t + rng.uniform(0, span * 0.25),
                               sizes, gaps, 443))
    else:
        raise KeyError(scenario)
    return flows


def make_stream(scenario: str, *, n_packets: int = 30_000,
                n_benign_flows: int = 220, span_s: float = 120.0,
                seed: int = 0) -> PacketStream:
    """Synthesize one scenario as a time-ordered stream of ~``n_packets``
    packets (trimmed exactly after the merge).  Deterministic in all
    arguments; attack scenarios keep the benign baseline running
    throughout, so detection is measured against live background traffic."""
    if scenario not in SCENARIOS:
        raise KeyError(f"scenario must be one of {SCENARIOS}")
    rng = np.random.default_rng(seed)
    # scale the baseline with the packet budget so trimming to n_packets
    # never cuts the stream before the attack phase begins
    n_benign = max(8, int(round(n_benign_flows
                                * min(1.0, n_packets / 30_000))))
    flows = _benign_flows(rng, n_benign, span_s)
    if scenario != "benign":
        flows += _attack_flows(rng, scenario, span_s)

    # unique non-negative flow ids, exact in f32
    ids = rng.permutation(1 << 20)[:len(flows)]
    for f, fid in zip(flows, ids):
        f["fid"] = int(fid)

    fid_col, t_col, len_col, port_col, lab_col = [], [], [], [], []
    for f in flows:
        n = len(f["sizes"])
        gaps = np.clip(np.asarray(f["gaps"], np.float64), 1e-5, 600.0)
        t = f["t0"] + np.cumsum(gaps) - gaps[0]    # first packet at t0
        fid_col.append(np.full(n, f["fid"], np.int64))
        t_col.append(t)
        len_col.append(np.clip(f["sizes"], 40, 1500))
        port_col.append(np.full(n, f["port"], np.int64))
        lab_col.append(np.full(n, f["label"], np.int64))
    fid = np.concatenate(fid_col)
    t = np.concatenate(t_col)
    plen = np.concatenate(len_col)
    port = np.concatenate(port_col)
    lab = np.concatenate(lab_col)

    # global arrival order; stable so same-timestamp packets keep flow order
    order = np.argsort(t, kind="stable")
    fid, t, plen, port, lab = (a[order] for a in (fid, t, plen, port, lab))

    # per-flow inter-arrival gaps: diff within each flow's packet sequence
    by_flow = np.lexsort((t, fid))
    tt, ff = t[by_flow], fid[by_flow]
    d = np.diff(tt, prepend=tt[:1])
    same = np.diff(ff, prepend=ff[:1] - 1) == 0
    ipt = np.zeros_like(t)
    ipt[by_flow] = np.where(same, d, 0.0)

    n = min(n_packets, len(fid))
    packets = np.stack(
        [fid[:n], plen[:n], ipt[:n], port[:n]], axis=1
    ).astype(np.float32)
    flow_labels = {int(f["fid"]): int(f["label"]) for f in flows}
    return PacketStream(scenario, packets, lab[:n].astype(np.int32),
                        fid[:n].astype(np.int32), flow_labels,
                        times=t[:n].astype(np.float64))


# ------------------------------------------------- stateful feature stages


def flow_feature_stages(*, n_slots: int = 2048, pl_bins: int = 16,
                        ipt_bins: int = 8, ewma_alpha: float = 0.125):
    """The canonical stateful prefix for ``COLUMNS`` packet streams.

    -> ((FlowKey, RegisterUpdate, WindowStats), feature_names): per-flow
    packet/byte counters, EWMAs of packet length and inter-arrival time,
    and a flowmarker-style windowed histogram (packet-length bins ++
    IPT bins, normalized by the packet count in WindowStats)."""
    from repro.core import stageir
    from repro.flowstate.registers import FlowStateSpec

    pl_edges = np.linspace(0.0, 1500.0, pl_bins + 1)[1:-1]
    ipt_edges = np.geomspace(1e-4, 120.0, ipt_bins + 1)[1:-1]
    spec = FlowStateSpec(
        n_slots=n_slots, n_counters=2, n_ewma=2,
        hist_sizes=(pl_bins, ipt_bins), ewma_alpha=ewma_alpha,
    )
    fk = stageir.FlowKey(key_cols=(COL_FLOW,), n_slots=n_slots)
    ru = stageir.RegisterUpdate(
        spec,
        counter_cols=(COL_LEN,),             # counter 1: byte count
        ewma_cols=(COL_LEN, COL_IPT),
        hist_cols=(COL_LEN, COL_IPT),
        hist_edges=(pl_edges, ipt_edges),
    )
    ws = stageir.WindowStats(spec, mode="all")
    names = (["pkt_count", "byte_count", "ewma_len", "ewma_ipt"]
             + [f"pl_bin_{i}" for i in range(pl_bins)]
             + [f"ipt_bin_{i}" for i in range(ipt_bins)])
    return (fk, ru, ws), names


def stream_feature_dataset(stream: PacketStream, stages, names,
                           *, sample_every: int = 2, test_frac: float = 0.3,
                           chunk: int = 1024, seed: int = 0):
    """Replay a stream through the register file (reference engine) and
    collect per-packet (WindowStats features, flow label) pairs as a
    standardized ``netdata.Dataset`` -> (dataset, mu, sd).

    ``mu``/``sd`` are the training-split feature moments; fold them into
    the classifier's first layer (``fold_input_standardization``) so the
    SERVED pipeline consumes raw register rows."""
    from repro.data.netdata import Dataset
    from repro.flowstate.pipeline import StatefulPipeline
    from repro.serve.packet_engine import PacketServeEngine

    sp = StatefulPipeline(list(stages), backend="interpret")
    eng = PacketServeEngine(sp, feature_dim=len(COLUMNS), max_batch=chunk)
    feats = []
    for c in stream.chunks(chunk):
        eng.submit(c)
        feats.append(eng.flush())
    X = (np.concatenate(feats, 0).astype(np.float32) if feats
         else np.zeros((0, len(list(names))), np.float32))
    y = stream.labels.astype(np.int32)
    X, y = X[::sample_every], y[::sample_every]

    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(X))
    # degenerate guards: a stream shorter than one window still yields a
    # usable dataset — both splits non-empty whenever >= 2 rows exist, a
    # single row serves as its own train AND test, zero rows standardize
    # with identity moments (never NaN)
    if len(X) >= 2:
        n_test = min(max(1, int(len(X) * test_frac)), len(X) - 1)
        te, tr = perm[:n_test], perm[n_test:]
    else:
        te = tr = perm
    if len(tr):
        mu = X[tr].mean(0)
        sd = X[tr].std(0) + 1e-6
    else:
        mu = np.zeros(X.shape[1], np.float32)
        sd = np.ones(X.shape[1], np.float32)
    ds = Dataset(
        name=f"flowstats-{stream.scenario}",
        train_x=((X[tr] - mu) / sd).astype(np.float32), train_y=y[tr],
        test_x=((X[te] - mu) / sd).astype(np.float32), test_y=y[te],
        feature_names=list(names), num_classes=2,
    )
    return ds, mu.astype(np.float32), sd.astype(np.float32)


def fold_input_standardization(stages, mu: np.ndarray, sd: np.ndarray):
    """Fold a (x - mu) / sd input transform into the first dense layer of
    a classifier suffix, so the served pipeline takes RAW register rows.

    z @ W + b with z = (x - mu)/sd  ==  x @ (W / sd[:, None]) + (b - (mu/sd) @ W)
    — exact affine composition; returns a rewritten copy of the stages."""
    from repro.core.stageir import Dense, FusedClassify, FusedMLP

    out = []
    done = False
    for s in stages:
        if not done and isinstance(s, (FusedMLP, FusedClassify)):
            w0 = np.asarray(s.weights[0], np.float32)
            b0 = np.asarray(s.biases[0], np.float32)
            weights = [w0 / sd[:, None]] + [np.asarray(w)
                                            for w in s.weights[1:]]
            biases = [b0 - (mu / sd) @ w0] + [np.asarray(b)
                                              for b in s.biases[1:]]
            out.append(type(s)(weights, biases))
            done = True
        elif not done and isinstance(s, Dense):
            w0 = np.asarray(s.w, np.float32)
            b0 = np.asarray(s.b, np.float32)
            out.append(Dense(w0 / sd[:, None], b0 - (mu / sd) @ w0, s.act))
            done = True
        else:
            out.append(s)
    if not done:
        raise ValueError("no dense layer to fold the standardization into")
    return out


# -------------------------------------------------------- reaction metrics


def reaction_report(stream: PacketStream, verdicts: np.ndarray) -> dict:
    """Reaction-time report: per attack flow, how many of ITS packets
    arrive before the first positive verdict (1-based; the paper's
    packets-until-detection).  Also benign false-positive flow rate."""
    verdicts = np.asarray(verdicts)
    react, undetected, fp_flows, benign_flows = [], 0, 0, 0
    for fid, label in stream.flow_labels.items():
        mask = stream.flow_ids == fid
        if not mask.any():
            continue
        v = verdicts[mask]
        hits = np.nonzero(v == 1)[0]
        if label == 1:
            if len(hits):
                react.append(int(hits[0]) + 1)
            else:
                undetected += 1
        else:
            benign_flows += 1
            fp_flows += bool(len(hits))
    react_arr = np.asarray(react, np.float64)
    n_attack = len(react) + undetected
    # sentinel 0.0 (not NaN) when nothing was detected / no attack flows
    # exist: an all-benign stream must produce a json-clean, comparable
    # report rather than NaNs that poison downstream aggregation
    return {
        "attack_flows": n_attack,
        "detected_flows": len(react),
        "detection_rate": (len(react) / n_attack) if n_attack else 0.0,
        "reaction_pkts_median": (float(np.median(react_arr))
                                 if len(react) else 0.0),
        "reaction_pkts_p95": (float(np.percentile(react_arr, 95))
                              if len(react) else 0.0),
        "benign_fp_flow_rate": (fp_flows / benign_flows) if benign_flows
        else 0.0,
    }
