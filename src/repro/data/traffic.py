"""Streaming traffic-scenario generator: seeded, replayable packet streams.

netdata.py synthesizes *feature matrices* (offline training sets); this
module synthesizes *packet streams* — time-ordered per-packet records that
the stateful serving path (repro.flowstate) consumes live, reproducing the
paper's per-packet reaction-time setting (§5.1.1) on a stream instead of
precomputed flow histograms.  Scenario shapes follow the SDN-DDoS
synthetic-dataset playbook (Mininet + hping3/iperf traffic, flows labeled
by generation-time ground truth): normal traffic from bulk/interactive
generators, attack traffic as floods/scans, label = how the flow was
generated.

Packet record (float32 row, ``COLUMNS`` order):

  ``flow_id``   integral flow key (< 2^22, exact in f32)
  ``pkt_len``   bytes on the wire
  ``ipt_s``     inter-arrival gap to this flow's previous packet (0 for
                the flow's first packet)
  ``dst_port``  destination port (bucketed small int)

Scenarios (every flow carries a ground-truth label; per-packet labels
inherit the flow's):

  ``benign``         web-ish + bulk + DHT-chatty baseline, label 0
  ``ddos_burst``     baseline, then a volumetric burst: many short
                     high-rate small-packet flows onto one service port
  ``port_scan``      baseline + one scanner: hundreds of 1-2 packet
                     SYN-sized flows sweeping ports
  ``elephant_mice``  heavy-hitter detection: few elephant flows (MTU
                     packets, tiny gaps, label 1) among many mice
  ``concept_drift``  the attack SIGNATURE shifts mid-stream: phase A
                     (before ``DRIFT_FRAC`` of the span) is a tiny-packet
                     volumetric flood, phase B a stealth MTU flood whose
                     per-packet shape mimics benign bulk transfers — a
                     model trained on phase A degrades on phase B (the
                     hot-swap loop's test scenario)
  ``syn_flood``      TCP SYN flood in three escalating-rate waves:
                     spoofed-source flows of SYN-sized packets onto one
                     service port, each wave doubling the per-flow rate
  ``udp_flood``      UDP amplification-style flood onto port 53 in two
                     rate waves, mid-size payloads
  ``icmp_flood``     ICMP (port-0 proxy) ping flood: constant small
                     echo-sized packets at kHz per-flow rates
  ``slow_scan``      slow-drip reconnaissance: one scanner emitting
                     1-2-packet SYN-sized probes every few hundred ms
                     across the WHOLE span (rate-invisible, shape-visible)
  ``coordinated_ddos`` multi-source DDoS: several source groups with
                     staggered onsets and per-group rates converging on
                     one service port

Topology-aware serving (``switch_streams``/``compose_streams``) pins
every flow to an ingress switch and slices one stream into per-switch
arrival-ordered views — a multi-switch deployment serves each view
through its own engine, and composing the views reconstructs the global
stream.  ``windowed_flow_stats`` collects Ryu-controller-style per-window
per-flow aggregates, and ``auto_label`` derives heuristic ground-truth
labels from them (pinned against the generating labels in
tests/test_traffic_scenarios.py).

Streams are deterministic in (scenario, seed, sizes) and replayable —
``PacketStream.chunks`` re-yields the identical sequence every call.
"""

from __future__ import annotations

import dataclasses

import numpy as np

COLUMNS = ("flow_id", "pkt_len", "ipt_s", "dst_port")
COL_FLOW, COL_LEN, COL_IPT, COL_PORT = range(4)

SCENARIOS = ("benign", "ddos_burst", "port_scan", "elephant_mice",
             "concept_drift", "syn_flood", "udp_flood", "icmp_flood",
             "slow_scan", "coordinated_ddos")

# scenarios whose attack flows a rate-style detector should catch (used by
# the replay harness to pick what the closed loop is exercised on)
FLOOD_SCENARIOS = ("ddos_burst", "syn_flood", "udp_flood", "icmp_flood",
                   "coordinated_ddos")

# mirror of repro.flowstate.mitigation.MITIGATED, kept local so this
# module stays importable without jax (test_mitigation pins the equality)
_MITIGATED = -1

# concept_drift: fraction of the span where phase B (the shifted attack
# signature) begins — phase A attacks live strictly before it
DRIFT_FRAC = 0.5


@dataclasses.dataclass
class PacketStream:
    """A time-ordered packet stream with per-packet ground truth."""

    scenario: str
    packets: np.ndarray        # [N, 4] f32, COLUMNS order, time-sorted
    labels: np.ndarray         # [N] int32 per-packet (= flow label)
    flow_ids: np.ndarray       # [N] int32 (packets[:, COL_FLOW] as int)
    flow_labels: dict          # flow_id -> label
    times: np.ndarray | None = None   # [N] f64 arrival timestamps

    @property
    def n_packets(self) -> int:
        return len(self.packets)

    @property
    def n_flows(self) -> int:
        return len(self.flow_labels)

    def chunks(self, size: int):
        """Replayable chunk iterator (fresh, identical sequence per call)."""
        for s in range(0, len(self.packets), size):
            yield self.packets[s:s + size]

    def slice(self, start: int, stop: int | None = None) -> "PacketStream":
        """A contiguous packet-index window as its own stream (flow_labels
        keep only flows that appear — reaction metrics stay per-segment)."""
        sl = slice(start, stop)
        fids = self.flow_ids[sl]
        present = set(int(f) for f in np.unique(fids))
        return PacketStream(
            self.scenario, self.packets[sl], self.labels[sl], fids,
            {f: l for f, l in self.flow_labels.items() if f in present},
            None if self.times is None else self.times[sl],
        )


# ------------------------------------------------------------- flow shapes


def _flow(fid, label, t0, sizes, gaps, port):
    return {"fid": int(fid), "label": int(label), "t0": float(t0),
            "sizes": sizes, "gaps": gaps, "port": int(port)}


def _benign_flows(rng, n_flows: int, span: float) -> list[dict]:
    flows = []
    for _ in range(n_flows):
        kind = rng.random()
        if kind < 0.45:       # interactive/web: smallish bimodal packets
            n = int(rng.integers(8, 60))
            sizes = np.where(rng.random(n) < 0.6,
                             rng.normal(240, 80, n),
                             rng.normal(1100, 180, n))
            gaps = rng.lognormal(np.log(0.15), 1.0, n)
            port = int(rng.choice((80, 443)))
        elif kind < 0.8:      # bulk transfer: MTU-sized, tiny gaps
            n = int(rng.integers(60, 300))
            sizes = rng.normal(1380, 60, n)
            gaps = rng.lognormal(np.log(0.01), 0.7, n)
            port = int(rng.choice((443, 8080)))
        else:                 # DHT-ish chatty mode (the confuser)
            n = int(rng.integers(20, 120))
            sizes = rng.normal(300, 90, n)
            gaps = rng.lognormal(np.log(1.0), 1.1, n)
            port = 6881
        flows.append(_flow(0, 0, rng.uniform(0, span * 0.7), sizes, gaps,
                           port))
    return flows


def _attack_flows(rng, scenario: str, span: float) -> list[dict]:
    flows = []
    if scenario == "ddos_burst":
        # volumetric burst from many (spoofed-source) flows onto one port
        burst_t = span * 0.3
        for _ in range(120):
            n = int(rng.integers(40, 160))
            sizes = rng.normal(90, 25, n)              # tiny payloads
            gaps = rng.lognormal(np.log(1.5e-3), 0.5, n)   # ~kHz per flow
            flows.append(_flow(0, 1, burst_t + rng.uniform(0, span * 0.2),
                               sizes, gaps, 80))
    elif scenario == "port_scan":
        # one scanner host: a 1-2 packet SYN-sized flow per swept port
        t = span * 0.25
        for i in range(400):
            n = int(rng.integers(1, 3))
            sizes = rng.normal(48, 4, n)
            gaps = rng.lognormal(np.log(5e-3), 0.4, n)
            flows.append(_flow(0, 1, t, sizes, gaps, 1024 + i))
            t += float(rng.uniform(2e-3, 8e-3))
    elif scenario == "elephant_mice":
        for _ in range(12):
            n = int(rng.integers(600, 1500))
            sizes = rng.normal(1430, 25, n)
            gaps = rng.lognormal(np.log(8e-4), 0.4, n)
            flows.append(_flow(0, 1, rng.uniform(0, span * 0.3), sizes,
                               gaps, 443))
    elif scenario == "concept_drift":
        drift_t = span * DRIFT_FRAC
        # phase A (< DRIFT_FRAC): the ddos_burst signature — many short
        # tiny-packet high-rate flows onto one service port.  A model
        # trained on this phase keys on the small-packet histogram mass.
        for _ in range(70):
            n = int(rng.integers(40, 120))
            sizes = rng.normal(90, 25, n)
            gaps = rng.lognormal(np.log(1.5e-3), 0.5, n)
            flows.append(_flow(0, 1,
                               rng.uniform(span * 0.05, drift_t * 0.7),
                               sizes, gaps, 80))
        # phase B (>= DRIFT_FRAC): a stealth MTU flood — per-packet shape
        # mimics benign bulk transfers (MTU sizes, similar gaps, port
        # 443); only flow VOLUME separates it (elephant lifetimes, so
        # pkt/byte counters run far past any benign bulk flow).  The
        # phase-A model sees none of its signature and misses it.
        for _ in range(30):
            n = int(rng.integers(500, 1100))
            sizes = rng.normal(1430, 40, n)
            gaps = rng.lognormal(np.log(8e-3), 0.3, n)
            flows.append(_flow(0, 1,
                               drift_t + rng.uniform(0, span * 0.25),
                               sizes, gaps, 443))
    elif scenario == "syn_flood":
        # three escalating waves of spoofed-source SYN-sized flows onto
        # one service port; each wave doubles the per-flow packet rate
        for t_frac, gap in ((0.25, 2e-3), (0.45, 1e-3), (0.65, 5e-4)):
            for _ in range(45):
                n = int(rng.integers(30, 120))
                sizes = rng.normal(60, 6, n)
                gaps = rng.lognormal(np.log(gap), 0.4, n)
                flows.append(_flow(0, 1,
                                   span * t_frac + rng.uniform(0, span * 0.08),
                                   sizes, gaps, 443))
    elif scenario == "udp_flood":
        # amplification-style UDP flood onto port 53, two rate waves
        for t_frac, gap in ((0.3, 1.5e-3), (0.55, 8e-4)):
            for _ in range(60):
                n = int(rng.integers(40, 150))
                sizes = rng.normal(512, 120, n)
                gaps = rng.lognormal(np.log(gap), 0.5, n)
                flows.append(_flow(0, 1,
                                   span * t_frac + rng.uniform(0, span * 0.1),
                                   sizes, gaps, 53))
    elif scenario == "icmp_flood":
        # ping flood: constant echo-sized packets, port-0 proxy for ICMP
        for _ in range(100):
            n = int(rng.integers(40, 160))
            sizes = rng.normal(84, 8, n)
            gaps = rng.lognormal(np.log(1e-3), 0.5, n)
            flows.append(_flow(0, 1,
                               span * 0.3 + rng.uniform(0, span * 0.25),
                               sizes, gaps, 0))
    elif scenario == "slow_scan":
        # slow-drip recon: probes every few hundred ms across the WHOLE
        # span — per-flow rate looks benign, only the 1-2-packet
        # SYN-sized shape gives it away
        t = span * 0.05
        for _ in range(260):
            n = int(rng.integers(1, 3))
            sizes = rng.normal(48, 4, n)
            gaps = rng.lognormal(np.log(5e-3), 0.4, n)
            flows.append(_flow(0, 1, t, sizes, gaps,
                               1024 + int(rng.integers(0, 4096))))
            t += float(rng.uniform(0.25, 0.45))
    elif scenario == "coordinated_ddos":
        # multi-source DDoS: four source groups, staggered onsets and
        # per-group rates, converging on one service port
        for g, gap in enumerate((2.5e-3, 1.8e-3, 1.2e-3, 8e-4)):
            t0 = span * (0.3 + 0.08 * g)
            for _ in range(35):
                n = int(rng.integers(30, 120))
                sizes = rng.normal(110, 30, n)
                gaps = rng.lognormal(np.log(gap), 0.4, n)
                flows.append(_flow(0, 1, t0 + rng.uniform(0, span * 0.06),
                                   sizes, gaps, 80))
    else:
        raise KeyError(scenario)
    return flows


def make_stream(scenario: str, *, n_packets: int = 30_000,
                n_benign_flows: int = 220, span_s: float = 120.0,
                seed: int = 0) -> PacketStream:
    """Synthesize one scenario as a time-ordered stream of ~``n_packets``
    packets (trimmed exactly after the merge).  Deterministic in all
    arguments; attack scenarios keep the benign baseline running
    throughout, so detection is measured against live background traffic."""
    if scenario not in SCENARIOS:
        raise KeyError(f"scenario must be one of {SCENARIOS}")
    rng = np.random.default_rng(seed)
    # scale the baseline with the packet budget so trimming to n_packets
    # never cuts the stream before the attack phase begins
    n_benign = max(8, int(round(n_benign_flows
                                * min(1.0, n_packets / 30_000))))
    flows = _benign_flows(rng, n_benign, span_s)
    if scenario != "benign":
        flows += _attack_flows(rng, scenario, span_s)

    # unique non-negative flow ids, exact in f32
    ids = rng.permutation(1 << 20)[:len(flows)]
    for f, fid in zip(flows, ids):
        f["fid"] = int(fid)

    fid_col, t_col, len_col, port_col, lab_col = [], [], [], [], []
    for f in flows:
        n = len(f["sizes"])
        gaps = np.clip(np.asarray(f["gaps"], np.float64), 1e-5, 600.0)
        t = f["t0"] + np.cumsum(gaps) - gaps[0]    # first packet at t0
        fid_col.append(np.full(n, f["fid"], np.int64))
        t_col.append(t)
        len_col.append(np.clip(f["sizes"], 40, 1500))
        port_col.append(np.full(n, f["port"], np.int64))
        lab_col.append(np.full(n, f["label"], np.int64))
    fid = np.concatenate(fid_col)
    t = np.concatenate(t_col)
    plen = np.concatenate(len_col)
    port = np.concatenate(port_col)
    lab = np.concatenate(lab_col)

    # global arrival order; stable so same-timestamp packets keep flow order
    order = np.argsort(t, kind="stable")
    fid, t, plen, port, lab = (a[order] for a in (fid, t, plen, port, lab))

    # per-flow inter-arrival gaps: diff within each flow's packet sequence
    by_flow = np.lexsort((t, fid))
    tt, ff = t[by_flow], fid[by_flow]
    d = np.diff(tt, prepend=tt[:1])
    same = np.diff(ff, prepend=ff[:1] - 1) == 0
    ipt = np.zeros_like(t)
    ipt[by_flow] = np.where(same, d, 0.0)

    n = min(n_packets, len(fid))
    packets = np.stack(
        [fid[:n], plen[:n], ipt[:n], port[:n]], axis=1
    ).astype(np.float32)
    flow_labels = {int(f["fid"]): int(f["label"]) for f in flows}
    return PacketStream(scenario, packets, lab[:n].astype(np.int32),
                        fid[:n].astype(np.int32), flow_labels,
                        times=t[:n].astype(np.float64))


# ------------------------------------------------- stateful feature stages


def flow_feature_stages(*, n_slots: int = 2048, pl_bins: int = 16,
                        ipt_bins: int = 8, ewma_alpha: float = 0.125):
    """The canonical stateful prefix for ``COLUMNS`` packet streams.

    -> ((FlowKey, RegisterUpdate, WindowStats), feature_names): per-flow
    packet/byte counters, EWMAs of packet length and inter-arrival time,
    and a flowmarker-style windowed histogram (packet-length bins ++
    IPT bins, normalized by the packet count in WindowStats)."""
    from repro.core import stageir
    from repro.flowstate.registers import FlowStateSpec

    pl_edges = np.linspace(0.0, 1500.0, pl_bins + 1)[1:-1]
    ipt_edges = np.geomspace(1e-4, 120.0, ipt_bins + 1)[1:-1]
    spec = FlowStateSpec(
        n_slots=n_slots, n_counters=2, n_ewma=2,
        hist_sizes=(pl_bins, ipt_bins), ewma_alpha=ewma_alpha,
    )
    fk = stageir.FlowKey(key_cols=(COL_FLOW,), n_slots=n_slots)
    ru = stageir.RegisterUpdate(
        spec,
        counter_cols=(COL_LEN,),             # counter 1: byte count
        ewma_cols=(COL_LEN, COL_IPT),
        hist_cols=(COL_LEN, COL_IPT),
        hist_edges=(pl_edges, ipt_edges),
    )
    ws = stageir.WindowStats(spec, mode="all")
    names = (["pkt_count", "byte_count", "ewma_len", "ewma_ipt"]
             + [f"pl_bin_{i}" for i in range(pl_bins)]
             + [f"ipt_bin_{i}" for i in range(ipt_bins)])
    return (fk, ru, ws), names


def stream_feature_dataset(stream: PacketStream, stages, names,
                           *, sample_every: int = 2, test_frac: float = 0.3,
                           chunk: int = 1024, seed: int = 0):
    """Replay a stream through the register file (reference engine) and
    collect per-packet (WindowStats features, flow label) pairs as a
    standardized ``netdata.Dataset`` -> (dataset, mu, sd).

    ``mu``/``sd`` are the training-split feature moments; fold them into
    the classifier's first layer (``fold_input_standardization``) so the
    SERVED pipeline consumes raw register rows."""
    from repro.data.netdata import Dataset
    from repro.flowstate.pipeline import StatefulPipeline
    from repro.serve.packet_engine import PacketServeEngine

    sp = StatefulPipeline(list(stages), backend="interpret")
    eng = PacketServeEngine(sp, feature_dim=len(COLUMNS), max_batch=chunk)
    feats = []
    for c in stream.chunks(chunk):
        eng.submit(c)
        feats.append(eng.flush())
    X = (np.concatenate(feats, 0).astype(np.float32) if feats
         else np.zeros((0, len(list(names))), np.float32))
    y = stream.labels.astype(np.int32)
    X, y = X[::sample_every], y[::sample_every]

    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(X))
    # degenerate guards: a stream shorter than one window still yields a
    # usable dataset — both splits non-empty whenever >= 2 rows exist, a
    # single row serves as its own train AND test, zero rows standardize
    # with identity moments (never NaN)
    if len(X) >= 2:
        n_test = min(max(1, int(len(X) * test_frac)), len(X) - 1)
        te, tr = perm[:n_test], perm[n_test:]
    else:
        te = tr = perm
    if len(tr):
        mu = X[tr].mean(0)
        sd = X[tr].std(0) + 1e-6
    else:
        mu = np.zeros(X.shape[1], np.float32)
        sd = np.ones(X.shape[1], np.float32)
    ds = Dataset(
        name=f"flowstats-{stream.scenario}",
        train_x=((X[tr] - mu) / sd).astype(np.float32), train_y=y[tr],
        test_x=((X[te] - mu) / sd).astype(np.float32), test_y=y[te],
        feature_names=list(names), num_classes=2,
    )
    return ds, mu.astype(np.float32), sd.astype(np.float32)


def fold_input_standardization(stages, mu: np.ndarray, sd: np.ndarray):
    """Fold a (x - mu) / sd input transform into the first dense layer of
    a classifier suffix, so the served pipeline takes RAW register rows.

    z @ W + b with z = (x - mu)/sd  ==  x @ (W / sd[:, None]) + (b - (mu/sd) @ W)
    — exact affine composition; returns a rewritten copy of the stages."""
    from repro.core.stageir import Dense, FusedClassify, FusedMLP

    out = []
    done = False
    for s in stages:
        if not done and isinstance(s, (FusedMLP, FusedClassify)):
            w0 = np.asarray(s.weights[0], np.float32)
            b0 = np.asarray(s.biases[0], np.float32)
            weights = [w0 / sd[:, None]] + [np.asarray(w)
                                            for w in s.weights[1:]]
            biases = [b0 - (mu / sd) @ w0] + [np.asarray(b)
                                              for b in s.biases[1:]]
            out.append(type(s)(weights, biases))
            done = True
        elif not done and isinstance(s, Dense):
            w0 = np.asarray(s.w, np.float32)
            b0 = np.asarray(s.b, np.float32)
            out.append(Dense(w0 / sd[:, None], b0 - (mu / sd) @ w0, s.act))
            done = True
        else:
            out.append(s)
    if not done:
        raise ValueError("no dense layer to fold the standardization into")
    return out


# -------------------------------------------------- topology-aware streams


def switch_of_flow(flow_ids: np.ndarray, n_switches: int) -> np.ndarray:
    """Deterministic flow -> ingress-switch pinning (Knuth multiplicative
    mix, so consecutive flow ids spread across switches)."""
    h = np.asarray(flow_ids, np.int64).astype(np.uint32) * np.uint32(2654435761)
    h ^= h >> np.uint32(16)
    return (h % np.uint32(n_switches)).astype(np.int64)


def switch_streams(stream: PacketStream, n_switches: int) -> list:
    """Slice one stream into ``n_switches`` per-switch views: every flow is
    pinned whole to one ingress switch, so per-flow inter-arrival gaps in
    the packet records stay valid and each view is itself arrival-ordered.
    A multi-switch deployment serves each view through its own engine."""
    if n_switches < 1:
        raise ValueError("n_switches must be >= 1")
    sw = switch_of_flow(stream.flow_ids, n_switches)
    out = []
    for s in range(n_switches):
        mask = sw == s
        fids = stream.flow_ids[mask]
        present = set(int(f) for f in np.unique(fids))
        out.append(PacketStream(
            f"{stream.scenario}@sw{s}", stream.packets[mask],
            stream.labels[mask], fids,
            {f: l for f, l in stream.flow_labels.items() if f in present},
            None if stream.times is None else stream.times[mask],
        ))
    return out


def compose_streams(streams, *, scenario: str | None = None) -> PacketStream:
    """Merge time-stamped streams back into one arrival-ordered stream
    (the inverse of ``switch_streams`` up to same-timestamp cross-flow
    ties).  Flow labels merge with attack (1) winning on collision."""
    streams = list(streams)
    if not streams:
        raise ValueError("need at least one stream to compose")
    if any(s.times is None for s in streams):
        raise ValueError("compose_streams requires timestamped streams")
    packets = np.concatenate([s.packets for s in streams])
    labels = np.concatenate([s.labels for s in streams])
    fids = np.concatenate([s.flow_ids for s in streams])
    times = np.concatenate([s.times for s in streams])
    order = np.argsort(times, kind="stable")
    flow_labels: dict = {}
    for s in streams:
        for f, l in s.flow_labels.items():
            flow_labels[f] = max(flow_labels.get(f, 0), l)
    name = scenario or streams[0].scenario.split("@", 1)[0]
    return PacketStream(name, packets[order], labels[order], fids[order],
                        flow_labels, times=times[order])


# ------------------------------------- windowed stats + heuristic labeling


def windowed_flow_stats(stream: PacketStream, *,
                        window_s: float = 1.0) -> dict:
    """Ryu-controller-style stat collection: aggregate the stream into
    per-(time-window, flow) rows.  Returns a dict of equal-length arrays:
    ``window``, ``flow_id``, ``pkt_count``, ``byte_count``, ``mean_len``,
    ``mean_ipt`` (gap sum / packet count, first-packet gap counted as 0).
    Requires timestamps and flow ids < 2^21 (``make_stream`` guarantees
    both)."""
    if stream.times is None:
        raise ValueError("windowed_flow_stats requires timestamped streams")
    if stream.n_packets == 0:
        z = np.zeros(0)
        return {"window": z.astype(np.int64), "flow_id": z.astype(np.int64),
                "pkt_count": z.astype(np.int64), "byte_count": z,
                "mean_len": z, "mean_ipt": z}
    t = stream.times
    win = np.floor((t - t[0]) / float(window_s)).astype(np.int64)
    fid = stream.flow_ids.astype(np.int64)
    if fid.max() >= (1 << 21):
        raise ValueError("flow ids must be < 2^21 for windowed aggregation")
    code = win * (1 << 21) + fid
    uniq, inv = np.unique(code, return_inverse=True)
    count = np.bincount(inv)
    byte = np.bincount(inv, weights=stream.packets[:, COL_LEN].astype(np.float64))
    iptsum = np.bincount(inv, weights=stream.packets[:, COL_IPT].astype(np.float64))
    return {
        "window": uniq >> 21,
        "flow_id": uniq & ((1 << 21) - 1),
        "pkt_count": count.astype(np.int64),
        "byte_count": byte,
        "mean_len": byte / count,
        "mean_ipt": iptsum / count,
    }


def auto_label(stats: dict, *, flood_ipt_s: float = 4e-3,
               flood_min_pkts: int = 10, volume_min_pkts: int = 450,
               scan_max_pkts: int = 3, scan_max_len: float = 80.0) -> dict:
    """Heuristic ground-truth labeling from windowed flow stats -> dict of
    flow_id -> {0, 1}.  Three rules, each with analytic margin against the
    benign generators in ``_benign_flows``:

      flood   mean gap < ``flood_ipt_s`` over >= ``flood_min_pkts``
              packets (benign bulk floors at ~10 ms gaps, floods run
              <= 2.7 ms)
      volume  total packets >= ``volume_min_pkts`` (benign bulk tops out
              at 300; elephants and stealth-drift flows start at 500)
      scan    <= ``scan_max_pkts`` packets of <= ``scan_max_len`` bytes
              (benign flows all run >= 8 packets)
    """
    fid = np.asarray(stats["flow_id"])
    count = np.asarray(stats["pkt_count"], np.float64)
    byte = np.asarray(stats["byte_count"], np.float64)
    iptsum = np.asarray(stats["mean_ipt"], np.float64) * count
    flows, inv = np.unique(fid, return_inverse=True)
    total = np.bincount(inv, weights=count)
    mean_len = np.bincount(inv, weights=byte) / total
    mean_ipt = np.bincount(inv, weights=iptsum) / total
    is_flood = (mean_ipt < flood_ipt_s) & (total >= flood_min_pkts)
    is_volume = total >= volume_min_pkts
    is_scan = (total <= scan_max_pkts) & (mean_len <= scan_max_len)
    label = (is_flood | is_volume | is_scan).astype(np.int64)
    return {int(f): int(l) for f, l in zip(flows, label)}


# -------------------------------------------------------- reaction metrics


def reaction_report(stream: PacketStream, verdicts: np.ndarray) -> dict:
    """Reaction-time report: per attack flow, how many of ITS packets
    arrive before the first positive verdict (1-based; the paper's
    packets-until-detection).  Also benign false-positive flow rate.

    When the verdict stream carries ``MITIGATED`` (-1) sentinels from an
    in-pipeline ``Mitigate`` stage, the report additionally measures what
    the data plane ENFORCES, not just what it flags: ``mitigation_lag_*``
    is the per-flow packet count between first detection and first drop
    (>= 1 by construction — the state BEFORE a packet decides its fate, so
    the threshold-tripping packet itself is still verdicted), and
    ``leaked_pkts_total`` counts attack packets that pass AFTER the flow's
    first drop.  The replay harness gates its SLOs on these, never on the
    detection-only numbers."""
    verdicts = np.asarray(verdicts)
    react, undetected, fp_flows, benign_flows = [], 0, 0, 0
    lags, mitigated, leaked, benign_mitigated = [], 0, 0, 0
    for fid, label in stream.flow_labels.items():
        mask = stream.flow_ids == fid
        if not mask.any():
            continue
        v = verdicts[mask]
        hits = np.nonzero(v == 1)[0]
        mits = np.nonzero(v == _MITIGATED)[0]
        if label == 1:
            if len(hits):
                react.append(int(hits[0]) + 1)
            else:
                undetected += 1
            if len(mits):
                mitigated += 1
                first_mit = int(mits[0])
                if len(hits):
                    lags.append(first_mit - int(hits[0]))
                leaked += int(np.sum(v[first_mit:] != _MITIGATED))
        else:
            benign_flows += 1
            fp_flows += bool(len(hits))
            benign_mitigated += bool(len(mits))
    react_arr = np.asarray(react, np.float64)
    lag_arr = np.asarray(lags, np.float64)
    n_attack = len(react) + undetected
    # sentinel 0.0 (not NaN) when nothing was detected / no attack flows
    # exist: an all-benign stream must produce a json-clean, comparable
    # report rather than NaNs that poison downstream aggregation
    return {
        "attack_flows": n_attack,
        "detected_flows": len(react),
        "detection_rate": (len(react) / n_attack) if n_attack else 0.0,
        "reaction_pkts_median": (float(np.median(react_arr))
                                 if len(react) else 0.0),
        "reaction_pkts_p95": (float(np.percentile(react_arr, 95))
                              if len(react) else 0.0),
        "benign_fp_flow_rate": (fp_flows / benign_flows) if benign_flows
        else 0.0,
        "mitigated_flows": mitigated,
        "mitigation_lag_median": (float(np.median(lag_arr))
                                  if len(lags) else 0.0),
        "mitigation_lag_p95": (float(np.percentile(lag_arr, 95))
                               if len(lags) else 0.0),
        "leaked_pkts_total": leaked,
        "benign_mitigated_flow_rate": (benign_mitigated / benign_flows)
        if benign_flows else 0.0,
    }
