"""Deterministic synthetic LM token pipeline, sharded per host.

A first-order Markov source over a zipf-ish unigram distribution: learnable
structure (bigram statistics) so small-model training loss demonstrably
drops below the unigram entropy floor.  Deterministic in
(seed, host_id, step) -- restarting from a checkpoint replays the exact
stream, which the fault-tolerance test relies on (bitwise-identical resume).
"""

from __future__ import annotations

import numpy as np


class TokenDataset:
    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        batch: int,
        *,
        seed: int = 0,
        host_id: int = 0,
        num_hosts: int = 1,
        branch: int = 4,
    ):
        assert batch % num_hosts == 0
        self.vocab = vocab_size
        self.seq = seq_len
        self.local_batch = batch // num_hosts
        self.seed = seed
        self.host_id = host_id
        self.branch = branch
        # fixed sparse bigram table: each token has `branch` likely successors
        rng = np.random.default_rng(seed)
        self.succ = rng.integers(0, vocab_size, size=(vocab_size, branch))

    def batch_at(self, step: int) -> dict:
        """Stateless: batch for global step (replayable after restart)."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4099 + self.host_id
        )
        B, S = self.local_batch, self.seq
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=B)
        follow = rng.random((B, S)) < 0.8  # 80% markov, 20% noise
        choice = rng.integers(0, self.branch, size=(B, S))
        noise = rng.integers(0, self.vocab, size=(B, S))
        for t in range(S):
            nxt = self.succ[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], nxt, noise[:, t])
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
