from repro.data.tokens import TokenDataset
from repro.data import netdata
