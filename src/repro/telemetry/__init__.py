"""Unified telemetry plane for the serving path.

One ``Telemetry`` object bundles the three observability surfaces
(docs/pipeline_ir.md#telemetry-contract):

  * ``metrics``  — lock-free-on-the-hot-path counters/gauges/histograms
    with snapshot-on-read (``telemetry.metrics``);
  * ``tracer``   — monotonic-clock spans in a bounded ring, exportable
    as Chrome ``trace_event`` JSON (``telemetry.trace``);
  * ``journal``  — the append-only operator event log, JSON lines
    (``telemetry.journal``).

Both serving engines accept ``telemetry=`` (default: a fresh enabled
instance; ``False`` disables recording entirely) and expose the live
object via ``engine.telemetry()``.  Everything is recorded host-side at
dispatch-ring boundaries: the compiled programs, the overlap pipeline
and all bit-identity contracts are untouched, and the overhead budget —
engine pkt/s with full telemetry on >= 97% of off — is gated by
``benchmarks/telemetry_overhead.py``.
"""

from __future__ import annotations

from repro.telemetry.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.telemetry.trace import Span, Tracer
from repro.telemetry.journal import EVENT_KINDS, EventJournal
from repro.telemetry.export import to_json, to_prometheus
from repro.telemetry.flow_health import (
    batch_segmentation,
    mitigation_residency,
    table_health,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "Tracer",
    "EVENT_KINDS",
    "EventJournal",
    "Telemetry",
    "to_json",
    "to_prometheus",
    "table_health",
    "batch_segmentation",
    "mitigation_residency",
]


class Telemetry:
    """The bundle: one metrics registry + one tracer + one journal.

    Share ONE instance across the engines and controllers of a serving
    deployment so the exported view is a single coherent plane (the
    engines label their series by engine/backend); or give each engine
    its own — both compose.

    ``journal_path`` additionally appends every journal event to a
    JSON-lines file (the artifact CI uploads from the attack-defense
    replay)."""

    def __init__(self, *, journal_path: str | None = None,
                 trace_capacity: int = 4096,
                 journal_capacity: int = 65536):
        self.metrics = MetricsRegistry()
        self.tracer = Tracer(capacity=trace_capacity)
        self.journal = EventJournal(journal_path,
                                    capacity=journal_capacity)

    # ------------------------------------------------------------- export

    def snapshot(self) -> dict:
        """Point-in-time metrics copy (see MetricsRegistry.snapshot)."""
        return self.metrics.snapshot()

    def prometheus(self) -> str:
        """Current metrics in Prometheus text exposition format."""
        return to_prometheus(self.snapshot())

    def json(self) -> str:
        """Current metrics as a JSON document."""
        return to_json(self.snapshot())

    def chrome_trace(self) -> dict:
        """Recorded spans as Chrome ``trace_event`` JSON (object form)."""
        return self.tracer.chrome_trace()

    def close(self) -> None:
        self.journal.close()


def resolve(telemetry) -> "Telemetry | None":
    """Normalize an engine's ``telemetry=`` argument: ``None``/``True``
    -> a fresh enabled instance, ``False`` -> no telemetry (engines
    guard every recording site on ``is not None``), an existing
    ``Telemetry`` -> itself (shared plane)."""
    if telemetry is False:
        return None
    if telemetry is None or telemetry is True:
        return Telemetry()
    return telemetry
