"""Monotonic-clock span tracing with a ring-buffer sink.

Spans are recorded host-side at dispatch-ring boundaries — the stage/
dispatch/fetch phases of the serving engines — so the depth-k overlap
pipeline and every bit-identity contract stay untouched: tracing reads
``time.perf_counter()`` twice and appends ONE tuple to a bounded deque.
A long-running engine keeps O(capacity) memory; old spans fall off the
back.

Export: ``chrome_trace()`` renders the ring as Chrome ``trace_event``
JSON (the ``{"traceEvents": [...]}`` object format) — complete events
(``"ph": "X"``) with microsecond timestamps relative to the tracer's
epoch, one ``tid`` lane per recording thread — loadable in
``chrome://tracing`` / Perfetto.
"""

from __future__ import annotations

import collections
import threading
import time
from contextlib import contextmanager

__all__ = ["Span", "Tracer"]


class Span(collections.namedtuple(
        "Span", ["name", "cat", "t0", "dur_s", "tid", "args"])):
    """One recorded span: ``t0`` is seconds on the tracer's monotonic
    clock (``perf_counter`` minus the tracer epoch), ``dur_s`` its
    length, ``tid`` the recording thread's ident, ``args`` a small
    JSON-clean dict of annotations (backend, batch rows, …)."""

    __slots__ = ()


class Tracer:
    """Bounded span sink over the monotonic clock.

    The fast path is ``record(name, t0, t1)`` with timestamps the caller
    already holds (the engines time their dispatches anyway): one tuple
    construction + one deque append, no lock — deque.append is atomic
    under the GIL and the ring bound makes concurrent appends safe.
    ``span()`` is the convenience context manager for non-hot-path
    phases (warm-up, swap prepare, retrain episodes)."""

    def __init__(self, capacity: int = 4096):
        self.capacity = int(capacity)
        self._spans: collections.deque[Span] = collections.deque(
            maxlen=self.capacity
        )
        self.epoch = time.perf_counter()
        self.dropped = 0            # spans pushed out of the ring

    # ---------------------------------------------------------- recording

    def record(self, name: str, t0: float, t1: float, *,
               cat: str = "serve", args: dict | None = None) -> None:
        """Record a completed span from raw ``perf_counter`` stamps."""
        if len(self._spans) == self.capacity:
            self.dropped += 1
        self._spans.append(Span(
            name, cat, t0 - self.epoch, max(0.0, t1 - t0),
            threading.get_ident(), args or {},
        ))

    @contextmanager
    def span(self, name: str, *, cat: str = "serve", **args):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.record(name, t0, time.perf_counter(), cat=cat,
                        args=args or None)

    # ------------------------------------------------------------ reading

    def spans(self) -> list[Span]:
        """Snapshot copy of the ring, oldest first."""
        return list(self._spans)

    def __len__(self) -> int:
        return len(self._spans)

    def clear(self) -> None:
        self._spans.clear()
        self.dropped = 0

    def chrome_trace(self) -> dict:
        """The ring as Chrome ``trace_event`` JSON (object format).

        Complete events (``ph: "X"``), ``ts``/``dur`` in integer
        microseconds from the tracer epoch (monotonic, so events are
        well-ordered), ``pid`` fixed at 1 and ``tid`` a small stable
        int per recording thread.  Structure is what
        ``chrome://tracing`` / Perfetto load directly."""
        tids: dict[int, int] = {}
        events = []
        for s in self._spans:
            tid = tids.setdefault(s.tid, len(tids) + 1)
            events.append({
                "name": s.name,
                "cat": s.cat,
                "ph": "X",
                "ts": int(round(s.t0 * 1e6)),
                "dur": max(1, int(round(s.dur_s * 1e6))),
                "pid": 1,
                "tid": tid,
                "args": s.args,
            })
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {
                "producer": "repro.telemetry",
                "dropped_spans": self.dropped,
            },
        }
