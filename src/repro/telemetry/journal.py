"""Operator event journal: append-only structured log (JSON lines).

Where metrics answer "how much" and spans answer "how long", the journal
answers *"what happened, when, in what order"* — the operator-relevant
state transitions of the serving plane:

=====================  =================================================
kind                   emitted when
=====================  =================================================
``drift``              the drift detector fires on served windows
``retrain_start``      a background retrain episode launches
``retrain_done``       the episode finishes (``ok`` False carries the
                       captured error — the engine kept the old model)
``hot_swap``           a parked swap installs at a ring boundary
                       (latency + packet offset of the boundary)
``mitigation_engage``  the action table marks new flows (count delta)
``mitigation_release`` marked flows leave the table (eviction/re-key)
``backend_fallback``   a requested engine lowered to a lesser one
                       (``"mixed"``, interpreter)
``slo_gate``           a benchmark/replay SLO gate evaluates
=====================  =================================================

Each event is one JSON object: ``seq`` (dense, per journal), ``t_s``
(monotonic seconds since the journal epoch — strictly ordered with
``seq``), ``wall`` (unix time, for cross-host correlation), ``kind``,
plus the event's own fields.  Events append to a bounded in-memory ring
AND, when a path is given, to a JSON-lines file (one event per line,
flushed per write) — the artifact CI uploads from the attack-defense
replay.

Emitting takes a small lock: journal events are RARE (swaps, drift,
gates — not per packet), so this is never on the per-batch hot path.
"""

from __future__ import annotations

import collections
import json
import threading
import time

__all__ = ["EVENT_KINDS", "EventJournal"]

# the documented operator event vocabulary
# (docs/pipeline_ir.md#telemetry-contract); emit() accepts other kinds
# too — the vocabulary is a contract floor, not a straitjacket
EVENT_KINDS = (
    "drift",
    "retrain_start",
    "retrain_done",
    "hot_swap",
    "mitigation_engage",
    "mitigation_release",
    "backend_fallback",
    "slo_gate",
)


class EventJournal:
    """Append-only, time-ordered operator event log."""

    def __init__(self, path: str | None = None, *, capacity: int = 65536):
        self.path = path
        self._events: collections.deque[dict] = collections.deque(
            maxlen=int(capacity)
        )
        self._lock = threading.Lock()
        self._seq = 0
        self._epoch = time.perf_counter()
        self._file = None
        if path is not None:
            self._file = open(path, "a", encoding="utf-8")

    def emit(self, kind: str, **fields) -> dict:
        """Append one event; returns the stamped record.  ``t_s`` is
        monotonic and, together with the dense ``seq``, totally orders
        the journal even when serving and retrain threads interleave."""
        with self._lock:
            event = {
                "seq": self._seq,
                "t_s": round(time.perf_counter() - self._epoch, 6),
                "wall": round(time.time(), 3),
                "kind": str(kind),
                **fields,
            }
            self._seq += 1
            self._events.append(event)
            if self._file is not None:
                self._file.write(json.dumps(event, default=str) + "\n")
                self._file.flush()
        return event

    # ------------------------------------------------------------ reading

    def events(self, kind: str | None = None) -> list[dict]:
        """Snapshot copy, oldest first; optionally one kind only."""
        with self._lock:
            evs = list(self._events)
        if kind is not None:
            evs = [e for e in evs if e["kind"] == kind]
        return evs

    def kinds(self) -> set[str]:
        return {e["kind"] for e in self.events()}

    def __len__(self) -> int:
        return len(self._events)

    def dump(self, path: str) -> str:
        """Write the in-memory ring as a JSON-lines file -> path."""
        with open(path, "w", encoding="utf-8") as f:
            for e in self.events():
                f.write(json.dumps(e, default=str) + "\n")
        return path

    def close(self) -> None:
        with self._lock:
            if self._file is not None:
                self._file.close()
                self._file = None

    @staticmethod
    def load(path: str) -> list[dict]:
        """Parse a JSON-lines journal file back into event dicts."""
        with open(path, encoding="utf-8") as f:
            return [json.loads(line) for line in f if line.strip()]
