"""Metrics registry: counters, gauges and histograms for the serving path.

Design constraints (docs/pipeline_ir.md#telemetry-contract):

  * **Lock-free on the hot path.**  Recording is a plain Python
    float/int mutation on a pre-resolved handle — one attribute add
    under the GIL, no lock, no allocation.  Handles are resolved ONCE
    (``registry.counter(name)`` at engine construction), so the
    per-batch cost is a couple of interpreter ops, never a dict lookup
    chain or a mutex.
  * **Snapshot-on-read.**  ``snapshot()`` copies every value at read
    time; readers (exporters, dashboards) never share mutable state
    with the recording thread, and a snapshot taken mid-serve is a
    consistent-enough point-in-time view (each individual value read is
    atomic under the GIL; cross-metric skew is bounded by one batch).
  * **Bounded memory.**  A metric's label children are interned in a
    dict keyed by the sorted label items; histograms have a FIXED
    bucket layout chosen at creation.  Nothing grows with traffic.

Vocabulary note: metric names are Prometheus-style snake case with the
unit as a suffix (``serve_packets_total``, ``serve_batch_latency_ms``);
the exporters in ``telemetry.export`` render them verbatim.
"""

from __future__ import annotations

import bisect
import threading

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "DEFAULT_LATENCY_BUCKETS_MS",
]

# default histogram layout: sub-ms to multi-second latencies, log-ish
DEFAULT_LATENCY_BUCKETS_MS = (
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
    500.0, 1000.0, 2500.0,
)


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class _Metric:
    """Shared child-interning machinery; subclasses define the child."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._children: dict[tuple, object] = {}
        # child creation is rare (once per label set) and may race with
        # other creators — guard it; RECORDING on a child never locks
        self._create_lock = threading.Lock()

    def labels(self, **labels):
        """The child handle for one label set (interned; resolve once,
        record on the returned handle forever)."""
        key = _label_key(labels)
        child = self._children.get(key)
        if child is None:
            with self._create_lock:
                child = self._children.setdefault(key, self._new_child())
        return child

    def _new_child(self):
        raise NotImplementedError

    @property
    def default(self):
        """The label-less child (the common case)."""
        return self.labels()

    def snapshot(self) -> dict:
        return {
            "name": self.name,
            "kind": self.kind,
            "help": self.help,
            "values": [
                {"labels": dict(key), **child._read()}
                for key, child in sorted(self._children.items())
            ],
        }


class _CounterChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n             # single GIL-atomic float add

    def _read(self) -> dict:
        return {"value": float(self.value)}


class Counter(_Metric):
    """Monotonically increasing count (packets, batches, evictions)."""

    kind = "counter"
    _new_child = staticmethod(_CounterChild)

    def inc(self, n: float = 1.0, **labels) -> None:
        self.labels(**labels).inc(n)

    def value(self, **labels) -> float:
        return float(self.labels(**labels).value)


class _GaugeChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n

    def _read(self) -> dict:
        return {"value": float(self.value)}


class Gauge(_Metric):
    """Point-in-time level (table occupancy, in-flight depth)."""

    kind = "gauge"
    _new_child = staticmethod(_GaugeChild)

    def set(self, v: float, **labels) -> None:
        self.labels(**labels).set(v)

    def value(self, **labels) -> float:
        return float(self.labels(**labels).value)


class _HistogramChild:
    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: tuple):
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)   # + overflow bucket
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1

    def _read(self) -> dict:
        return {
            "buckets": [
                {"le": le, "count": c}
                for le, c in zip(
                    list(self.bounds) + [float("inf")], list(self.counts)
                )
            ],
            "sum": float(self.sum),
            "count": int(self.count),
        }


class Histogram(_Metric):
    """Fixed-bucket distribution (per-batch latency, dispatch time)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "", *,
                 buckets: tuple = DEFAULT_LATENCY_BUCKETS_MS):
        super().__init__(name, help)
        self.buckets = tuple(sorted(float(b) for b in buckets))

    def _new_child(self):
        return _HistogramChild(self.buckets)

    def observe(self, v: float, **labels) -> None:
        self.labels(**labels).observe(v)


class MetricsRegistry:
    """Named metrics, get-or-create, snapshot-on-read.

    ``counter/gauge/histogram`` return the SAME metric object for
    repeated calls with one name (help/buckets are fixed by the first
    creation); asking for an existing name as a different kind is an
    error — one name, one type, like Prometheus."""

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}
        self._create_lock = threading.Lock()

    def _get_or_create(self, cls, name: str, help: str, **kw) -> _Metric:
        m = self._metrics.get(name)
        if m is None:
            with self._create_lock:
                m = self._metrics.get(name)
                if m is None:
                    m = cls(name, help, **kw)
                    self._metrics[name] = m
        if not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"requested {cls.kind}"
            )
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "", *,
                  buckets: tuple = DEFAULT_LATENCY_BUCKETS_MS
                  ) -> Histogram:
        return self._get_or_create(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> _Metric | None:
        return self._metrics.get(name)

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def snapshot(self) -> dict:
        """Point-in-time copy of every metric: ``{name: {...}}``, JSON
        clean, safe to hold while recording continues."""
        return {name: self._metrics[name].snapshot()
                for name in sorted(self._metrics)}
