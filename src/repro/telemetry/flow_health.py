"""Flow-table health: occupancy, churn and schedule statistics.

Two kinds of measurement, both deliberately OFF the device hot path
(docs/pipeline_ir.md#telemetry-contract):

  * ``table_health`` — a cheap host-side scan of the live register
    file(s) at flush/swap boundaries (one ``[S]`` int compare per
    table): occupancy, insert/eviction counts since the previous scan,
    and — for mitigated pipelines — action-table residency and marked
    flows.  The scan forces a device→host copy of the key vector only;
    register rows are never touched.
  * ``batch_segmentation`` — per-batch slot-collision statistics
    recomputed host-side from the packet keys the engine already
    derives (sharded routing) or can derive for free
    (``FlowKey.apply_keys_np``): same stable-sort rank the fused
    kernel's segmentation prelude uses.  ``drain_heavy`` flags batches
    where more than 7/8 of live packets sit deeper than ``PAR_ROUNDS``
    in one chain — a traffic-shape signal (the kernel's doubly-compacted
    drain serves such batches in-kernel; nothing is routed away).
"""

from __future__ import annotations

import numpy as np

__all__ = ["table_health", "batch_segmentation", "mitigation_residency"]


def _par_rounds() -> int:
    from repro.kernels.flow_update.kernel import PAR_ROUNDS

    return int(PAR_ROUNDS)


def mitigation_residency(state) -> dict:
    """Action-table residency of a (possibly sharded) mitigated state:
    occupied slots and flows past the mark threshold.  Zeroes for a
    state without an action table."""
    mit_spec = getattr(state, "mit_spec", None)
    if mit_spec is None:
        return {"mit_slots": 0, "mit_occupied": 0, "mit_marked": 0}
    mk = np.asarray(state.mit_keys)
    hits = np.asarray(state.mit_regs)[..., 0]
    return {
        "mit_slots": int(mk.size),
        "mit_occupied": int(np.sum(mk >= 0)),
        "mit_marked": int(np.sum((mk >= 0) & (hits >= mit_spec.threshold))),
    }


def table_health(state, prev_keys: np.ndarray | None = None) -> dict:
    """Health scan of a live flow state (plain, mitigated or sharded).

    ``prev_keys`` is the key vector (or stacked ``[D, S]`` matrix) from
    the previous scan; when given, ``inserts`` counts slots that went
    empty→occupied and ``evictions`` slots whose stored key CHANGED
    while occupied (the last-writer-wins collision policy displacing a
    live flow) since then.  Returns the current keys under
    ``"keys"`` for the caller to carry to the next scan."""
    keys = np.asarray(state.keys)
    occupied = int(np.sum(keys >= 0))
    total = int(keys.size)
    out = {
        "slots": total,
        "occupied": occupied,
        "occupancy_frac": occupied / max(total, 1),
        "inserts": 0,
        "evictions": 0,
        "keys": keys,
    }
    if prev_keys is not None and prev_keys.shape == keys.shape:
        prev = np.asarray(prev_keys)
        out["inserts"] = int(np.sum((prev < 0) & (keys >= 0)))
        out["evictions"] = int(
            np.sum((prev >= 0) & (keys >= 0) & (prev != keys))
        )
    out.update(mitigation_residency(state))
    return out


def batch_segmentation(slots: np.ndarray, *,
                       par_rounds: int | None = None) -> dict:
    """Slot-collision statistics of one dispatched batch.

    ``slots`` is the per-packet table slot (``hash_slot`` of the flow
    key) of every REAL row in the batch (padding excluded — the engine
    dispatches real rows and pads separately).  Mirrors the fused
    kernel's segmentation prelude: per-slot arrival rank, packets
    deeper than ``par_rounds`` (the drain set), and the drain-heavy
    flag ``n_deep * 8 > n_live * 7`` — the drain-dominated traffic
    shape (served in-kernel by the compacted drain, not routed)."""
    if par_rounds is None:
        par_rounds = _par_rounds()
    slots = np.asarray(slots)
    n_live = int(slots.size)
    if n_live == 0:
        return {"n_live": 0, "n_deep": 0, "max_chain": 0,
                "drain_heavy": False}
    order = np.argsort(slots, kind="stable")
    ss = slots[order]
    new_seg = np.empty(n_live, bool)
    new_seg[0] = True
    new_seg[1:] = ss[1:] != ss[:-1]
    seg_id = np.cumsum(new_seg) - 1
    seg_start = np.flatnonzero(new_seg)
    rank = np.arange(n_live) - seg_start[seg_id]
    n_deep = int(np.sum(rank >= par_rounds))
    return {
        "n_live": n_live,
        "n_deep": n_deep,
        "max_chain": int(rank.max()) + 1,
        "drain_heavy": bool(n_deep * 8 > n_live * 7),
    }
