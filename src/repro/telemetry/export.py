"""Exporters: Prometheus text format and JSON over a metrics snapshot.

Both operate on ``MetricsRegistry.snapshot()`` output — a frozen copy —
so exporting never races the recording threads and costs the hot path
nothing.  The Prometheus rendering follows the text exposition format
(``# HELP`` / ``# TYPE`` headers, ``name{label="v"} value`` samples,
histogram ``_bucket``/``_sum``/``_count`` expansion with cumulative
``le`` buckets).
"""

from __future__ import annotations

import json

__all__ = ["to_prometheus", "to_json"]


def _escape(v) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"')


def _fmt_labels(labels: dict, extra: dict | None = None) -> str:
    merged = {**labels, **(extra or {})}
    if not merged:
        return ""
    body = ",".join(f'{k}="{_escape(v)}"' for k, v in sorted(merged.items()))
    return "{" + body + "}"


def _fmt_value(v: float) -> str:
    f = float(v)
    if f == float("inf"):
        return "+Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def to_prometheus(snapshot: dict) -> str:
    """Render a ``MetricsRegistry.snapshot()`` as Prometheus text."""
    lines: list[str] = []
    for name in sorted(snapshot):
        m = snapshot[name]
        if m.get("help"):
            lines.append(f"# HELP {name} {m['help']}")
        lines.append(f"# TYPE {name} {m['kind']}")
        for val in m["values"]:
            labels = val.get("labels", {})
            if m["kind"] == "histogram":
                cum = 0
                for b in val["buckets"]:
                    cum += b["count"]
                    lines.append(
                        f"{name}_bucket"
                        f"{_fmt_labels(labels, {'le': _fmt_value(b['le'])})}"
                        f" {cum}"
                    )
                lines.append(
                    f"{name}_sum{_fmt_labels(labels)}"
                    f" {_fmt_value(val['sum'])}")
                lines.append(
                    f"{name}_count{_fmt_labels(labels)} {val['count']}")
            else:
                lines.append(
                    f"{name}{_fmt_labels(labels)}"
                    f" {_fmt_value(val['value'])}")
    return "\n".join(lines) + ("\n" if lines else "")


def to_json(snapshot: dict, *, indent: int | None = None) -> str:
    """The snapshot as a JSON document (it is already JSON-clean)."""
    return json.dumps(snapshot, indent=indent, sort_keys=True)
