"""Sharded, integrity-checked, async checkpointing with elastic restore.

Layout:
  <dir>/step_<N>/manifest.msgpack   leaf index: path, shape, dtype, crc32
  <dir>/step_<N>/leaf_<i>.bin.zst   compressed raw array bytes (zstd, or
                                    zlib where zstandard is unavailable;
                                    the codec is recorded in the manifest)
  <dir>/step_<N>/COMPLETE           atomic finalize marker (written last)
  <dir>/latest                      text file with newest complete step

Fault-tolerance properties:
  * a crashed save never corrupts restore (COMPLETE marker is last);
  * crc32 per leaf detects bit-rot / truncation;
  * restore is *elastic*: arrays are materialized on host then device_put
    with the *current* mesh's shardings, so a checkpoint written on N
    devices restores onto M devices (tested N=1 -> M=8 in
    tests/test_checkpoint.py).

AsyncCheckpointer overlaps serialization with training (single background
thread; ``wait()`` before the next save or at exit).
"""

from __future__ import annotations

import os
import shutil
import threading
import zlib

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # container without zstd: zlib fallback (see _CODEC)
    zstandard = None

_ZSTD_LEVEL = 3
_CODEC = "zstd" if zstandard is not None else "zlib"
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"   # zstd frame header


def _compress(raw: bytes) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=_ZSTD_LEVEL).compress(raw)
    return zlib.compress(raw, _ZSTD_LEVEL)


def _decompress(blob: bytes, codec: str) -> bytes:
    """Codec comes from the manifest; pre-codec checkpoints are sniffed by
    the zstd frame magic so either environment reads either format."""
    if codec == "sniff":
        codec = "zstd" if blob[:4] == _ZSTD_MAGIC else "zlib"
    if codec == "zstd":
        if zstandard is None:
            raise IOError(
                "checkpoint was written with zstd but the zstandard "
                "module is unavailable in this environment"
            )
        return zstandard.ZstdDecompressor().decompress(blob)
    if codec == "zlib":
        return zlib.decompress(blob)
    raise IOError(f"unknown checkpoint codec {codec!r}")


def _resolve_dtype(name):
    """dtype by NAME: extension dtypes (bfloat16) have no reconstructible
    .str; ml_dtypes resolves them on load."""
    import numpy as _np
    try:
        return _np.dtype(name)
    except TypeError:
        import ml_dtypes
        return _np.dtype(getattr(ml_dtypes, name))


def _leaf_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, state, step: int) -> str:
    """Blocking save. Returns the step directory."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp_dir = step_dir + ".tmp"
    if os.path.exists(tmp_dir):
        shutil.rmtree(tmp_dir)
    os.makedirs(tmp_dir, exist_ok=True)

    leaves, treedef = _leaf_paths(state)
    manifest = {"treedef": str(treedef), "leaves": [], "step": step,
                "codec": _CODEC}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        raw = arr.tobytes()
        fname = f"leaf_{i:05d}.bin.zst"
        with open(os.path.join(tmp_dir, fname), "wb") as f:
            f.write(_compress(raw))
        manifest["leaves"].append(
            {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": arr.dtype.name,
                "crc32": zlib.crc32(raw),
            }
        )
    with open(os.path.join(tmp_dir, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    with open(os.path.join(tmp_dir, "COMPLETE"), "w") as f:
        f.write("ok")
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)
    with open(os.path.join(ckpt_dir, "latest.tmp"), "w") as f:
        f.write(str(step))
    os.replace(
        os.path.join(ckpt_dir, "latest.tmp"), os.path.join(ckpt_dir, "latest")
    )
    return step_dir


def latest_step(ckpt_dir: str) -> int | None:
    path = os.path.join(ckpt_dir, "latest")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        step = int(f.read().strip())
    if not os.path.exists(
        os.path.join(ckpt_dir, f"step_{step:010d}", "COMPLETE")
    ):
        # fall back: scan for newest complete step
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(ckpt_dir)
            if d.startswith("step_")
            and os.path.exists(os.path.join(ckpt_dir, d, "COMPLETE"))
        )
        return steps[-1] if steps else None
    return step


def restore_checkpoint(ckpt_dir: str, like_tree, step: int | None = None,
                       shardings=None):
    """Restore into the structure of ``like_tree``.

    ``shardings``: optional matching tree of jax.sharding.Sharding for
    elastic re-placement onto the current mesh.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {ckpt_dir}")
    step_dir = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(step_dir, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())

    codec = manifest.get("codec", "sniff")
    arrays = []
    for meta in manifest["leaves"]:
        with open(os.path.join(step_dir, meta["file"]), "rb") as f:
            raw = _decompress(f.read(), codec)
        if zlib.crc32(raw) != meta["crc32"]:
            raise IOError(f"crc mismatch in {meta['file']} (corrupt ckpt)")
        arr = np.frombuffer(raw, dtype=_resolve_dtype(meta["dtype"]))
        arrays.append(arr.reshape(meta["shape"]))

    leaves, treedef = jax.tree_util.tree_flatten(like_tree)
    if len(leaves) != len(arrays):
        raise ValueError(
            f"checkpoint has {len(arrays)} leaves, tree wants {len(leaves)}"
        )
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_flatten(shardings)[0]
        out = [
            jax.device_put(a, s) for a, s in zip(arrays, shard_leaves)
        ]
    else:
        out = [jnp.asarray(a) for a in arrays]
    return jax.tree_util.tree_unflatten(treedef, out), step


class AsyncCheckpointer:
    """Overlap checkpoint serialization with training."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def save(self, state, step: int):
        self.wait()
        # device_get on the main thread (device ops are not thread-safe),
        # serialize + write on the background thread.
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def work():
            save_checkpoint(self.ckpt_dir, host_state, step)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(
            d for d in os.listdir(self.ckpt_dir) if d.startswith("step_")
        )
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, d), ignore_errors=True)
