"""Gradient compression: blockwise symmetric int8 all-reduce.

Wire format: the flat tensor is split into 128-element blocks; each block is
quantized symmetrically to int8 with one f32 scale (max|block| / 127).  An
all-reduce then ships int8 payload + f32 scales (all-gather + local sum)
instead of bf16 ring chunks — >1.5x fewer wire bytes on 2+ devices, with a
quantization error bounded by scale/2 per element.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

BLOCK = 128
_QMAX = 127.0


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Flat f32 -> (int8 [n_blocks, BLOCK], f32 scales [n_blocks])."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / _QMAX
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -_QMAX, _QMAX)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def roundtrip(x: jax.Array) -> jax.Array:
    """quantize |> dequantize — error <= max|block|/254 per element."""
    q, s = quantize(x)
    return dequantize(q, s, x.shape)


def wire_bytes(n_params: int, *, group: int = 2) -> dict:
    """Wire bytes per device: compressed all-gather vs bf16 ring all-reduce."""
    blocks = math.ceil(n_params / BLOCK)
    bf16_ring = 2 * 2 * n_params * (group - 1) / group  # reduce- + all-gather
    compressed = (n_params * 1 + blocks * 4) * (group - 1)
    return {
        "bf16_ring_bytes": bf16_ring,
        "compressed_bytes": compressed,
        "ratio": bf16_ring / max(compressed, 1),
    }


def compressed_psum(x: jax.Array, axis: str) -> jax.Array:
    """psum over ``axis`` shipping int8 + scales (call inside shard_map)."""
    q, s = quantize(x)
    qg = jax.lax.all_gather(q, axis)          # [devices, blocks, BLOCK] int8
    sg = jax.lax.all_gather(s, axis)          # [devices, blocks]
    total = jnp.sum(qg.astype(jnp.float32) * sg[:, :, None], axis=0)
    n = 1
    for d in x.shape:
        n *= d
    return total.reshape(-1)[:n].reshape(x.shape)


def make_compressed_allreduce(mesh, axis: str):
    """-> fn(x sharded on dim0 over ``axis``) doing the compressed psum."""
    from jax.sharding import PartitionSpec as P

    def fn(x):
        return jax.shard_map(
            lambda v: compressed_psum(v, axis),
            mesh=mesh, in_specs=P(axis), out_specs=P(axis),
        )(x)

    return fn
