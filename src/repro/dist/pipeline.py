"""Pipeline parallelism: GPipe schedule over a 1D "pipe" mesh axis.

Each device owns one stage's weights; microbatches stream through the
stages, with activations handed to the next stage via collective-permute.
With M microbatches and P stages the schedule runs M+P-1 ticks, so the
bubble (idle) fraction is (P-1)/(M+P-1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def bubble_fraction(microbatches: int, stages: int) -> float:
    """Idle fraction of the GPipe schedule: (P-1)/(M+P-1)."""
    return (stages - 1) / (microbatches + stages - 1)


def pipeline_apply(stage_fn, stage_params: jax.Array, x: jax.Array, *,
                   mesh, axis: str) -> jax.Array:
    """Run x through P stages, stage p resident on device p of ``axis``.

    stage_fn: (W, h) -> h' applied per microbatch.
    stage_params: [P, ...] per-stage weights (sharded over ``axis``).
    x: [M, microbatch, ...] microbatches (replicated).
    Returns [M, microbatch, ...] after all P stages, replicated.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def body(w_local, x_full):
        w = w_local[0]
        p = jax.lax.axis_index(axis)
        recv = jnp.zeros(x_full.shape[1:], x_full.dtype)
        outs = jnp.zeros_like(x_full)

        def tick(carry, t):
            recv, outs = carry
            # stage 0 ingests microbatch t; later stages consume the
            # activation permuted in from stage p-1 at tick t-1
            h_in = jnp.where(
                p == 0, x_full[jnp.clip(t, 0, n_micro - 1)], recv
            )
            h_out = stage_fn(w, h_in)
            o_idx = t - (n_stages - 1)
            valid = jnp.logical_and(p == n_stages - 1, o_idx >= 0)
            written = outs.at[jnp.clip(o_idx, 0, n_micro - 1)].set(h_out)
            outs = jnp.where(valid, written, outs)
            nxt = jax.lax.ppermute(h_out, axis, perm)
            return (nxt, outs), None

        (_, outs), _ = jax.lax.scan(
            tick, (recv, outs), jnp.arange(n_micro + n_stages - 1)
        )
        # only the last stage holds real outputs (others kept zeros):
        # a psum broadcasts them so the result is replicated
        return jax.lax.psum(outs, axis)

    return jax.shard_map(
        body, mesh=mesh, in_specs=(P(axis), P()), out_specs=P(),
    )(stage_params, x)
