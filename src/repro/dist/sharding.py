"""Logical-axis sharding rules and the ambient mesh context.

Models annotate activations with *logical* axis names ("batch", "tp", ...);
an ``AxisRules`` table maps each logical name to one or more *physical* mesh
axes.  Resolution is mesh-aware: physical axes absent from the current mesh
are dropped (the dim is replicated), and no physical axis is assigned twice
in one spec — the standard GSPMD validity rules.

``mesh_context(mesh, rules)`` installs the ambient (mesh, rules) pair;
``shard(x, *logical)`` is a no-op outside a context, so model code runs
unchanged on a single CPU device.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# ------------------------------------------------------------------- rules


class AxisRules:
    """Mapping logical axis name -> physical mesh axis (or tuple of them)."""

    def __init__(self, table: dict[str, str | tuple[str, ...] | None]):
        self.table = dict(table)

    def resolve(self, logical: Sequence[str | None], mesh) -> P:
        """Logical axes -> PartitionSpec valid on ``mesh``.

        * logical names missing from the table resolve to None (replicated);
        * physical axes not present in ``mesh.shape`` are dropped;
        * a physical axis is used at most once per spec (first dim wins);
        * trailing Nones are trimmed.
        """
        used: set[str] = set()
        out: list = []
        for name in logical:
            entry = self.table.get(name) if name is not None else None
            if entry is None:
                out.append(None)
                continue
            axes = (entry,) if isinstance(entry, str) else tuple(entry)
            kept = [
                a for a in axes if a in mesh.shape and a not in used
            ]
            used.update(kept)
            if not kept:
                out.append(None)
            elif len(kept) == 1:
                out.append(kept[0])
            else:
                out.append(tuple(kept))
        while out and out[-1] is None:
            out.pop()
        return P(*out)


# Default: data-parallel batch (over pods too), 1D tensor parallelism on
# "model", FSDP parameter sharding on "data".
DEFAULT_RULES = AxisRules({
    "batch": ("pod", "data"),
    "kv_batch": ("pod", "data"),
    "moe_group": ("pod", "data"),
    "fsdp": "data",
    "tp": "model",
    "ep": "model",
    "sp": None,          # sequence replicated by default
    "vocab": "model",
})

# Prefill: long sequences — shard the sequence dim over the model axis so
# attention working sets fit; weights stay as in DEFAULT_RULES.
PREFILL_RULES = AxisRules({
    **DEFAULT_RULES.table,
    "sp": "model",
})

# Decode for >5B-param models: replicate the (tiny) activations, keep
# weights 2D-sharded over (data, model); KV caches stay batch-sharded.
DECODE_RULES = AxisRules({
    **DEFAULT_RULES.table,
    "batch": None,
    "sp": None,
    "fsdp": "data",
})


# ----------------------------------------------------------- mesh context

_STATE = threading.local()


def current_mesh():
    return getattr(_STATE, "mesh", None)


def current_rules() -> AxisRules:
    return getattr(_STATE, "rules", None) or DEFAULT_RULES


@contextlib.contextmanager
def mesh_context(mesh, rules: AxisRules = DEFAULT_RULES):
    """Install (mesh, rules) as the ambient sharding context."""
    prev = (current_mesh(), getattr(_STATE, "rules", None))
    _STATE.mesh, _STATE.rules = mesh, rules
    try:
        yield mesh
    finally:
        _STATE.mesh, _STATE.rules = prev


def axis_size(logical: str) -> int:
    """Product of mesh-axis sizes the logical axis maps to (1 if no mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return 1
    entry = current_rules().table.get(logical)
    if entry is None:
        return 1
    axes = (entry,) if isinstance(entry, str) else tuple(entry)
    n = 1
    for a in axes:
        n *= mesh.shape.get(a, 1)
    return n


def _fit_spec(shape: tuple[int, ...], spec: P, mesh) -> P:
    """Drop mesh axes that do not divide their dim (replicate instead)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        kept, prod = [], 1
        for a in axes:
            size = mesh.shape[a]
            if dim % (prod * size) == 0:
                kept.append(a)
                prod *= size
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """Constrain ``x`` to the resolved logical sharding (no-op w/o mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = current_rules().resolve(logical, mesh)
    fitted = _fit_spec(x.shape, spec, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, fitted))
