"""Distribution substrate: logical-axis sharding rules, gradient
compression, and pipeline parallelism."""

from repro.dist import compression, pipeline, sharding

__all__ = ["compression", "pipeline", "sharding"]
