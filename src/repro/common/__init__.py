from repro.common.pytree import (
    ParamDef,
    param_count,
    param_bytes,
    materialize,
    abstract,
    pspec_tree,
    tree_path_str,
)
