"""Parameter-definition pytrees.

Models in this framework describe their parameters as a pytree of
``ParamDef`` (shape, dtype, logical axes, initializer).  The same tree is
used three ways:

  * ``materialize(defs, key)``    -> real arrays (smoke tests / examples)
  * ``abstract(defs)``            -> ShapeDtypeStruct stand-ins (dry-run; no
                                     device allocation, as required to lower
                                     a 398B model on a CPU host)
  * ``pspec_tree(defs, rules)``   -> PartitionSpec tree for pjit shardings

This separation is what lets the multi-pod dry-run lower and compile full
production configs on a single-core CPU container.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declarative description of a single parameter tensor."""

    shape: tuple[int, ...]
    dtype: Any = jnp.bfloat16
    # Logical axis names, one per dim (None = replicated dim). Resolved to
    # physical mesh axes by repro.dist.sharding rules.
    axes: tuple[str | None, ...] = ()
    init: str = "normal"  # normal | zeros | ones | scaled (fan-in)
    init_scale: float = 1.0

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes {self.axes} rank != shape {self.shape} rank"
            )

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


def is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def tree_path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _fold_path(key: jax.Array, path: str) -> jax.Array:
    digest = hashlib.md5(path.encode()).digest()
    return jax.random.fold_in(key, int.from_bytes(digest[:4], "little"))


def _init_one(d: ParamDef, key: jax.Array) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "normal":
        x = jax.random.normal(key, d.shape, jnp.float32) * 0.02 * d.init_scale
        return x.astype(d.dtype)
    if d.init == "scaled":  # fan-in scaled (truncated-normal-ish)
        fan_in = d.shape[-2] if len(d.shape) >= 2 else max(d.shape[0], 1)
        std = d.init_scale / np.sqrt(fan_in)
        x = jax.random.normal(key, d.shape, jnp.float32) * std
        return x.astype(d.dtype)
    if d.init == "ssm_a":  # Mamba A_log: log(1..d_state) per channel
        n = d.shape[-1]
        a = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
        return jnp.broadcast_to(a, d.shape).astype(d.dtype)
    raise ValueError(f"unknown init {d.init!r}")


def materialize(defs: PyTree, key: jax.Array) -> PyTree:
    """Instantiate real parameter arrays from a ParamDef tree."""

    def leaf(path, d: ParamDef):
        return _init_one(d, _fold_path(key, tree_path_str(path)))

    return jax.tree_util.tree_map_with_path(leaf, defs, is_leaf=is_def)


def abstract(defs: PyTree) -> PyTree:
    """ShapeDtypeStruct stand-ins -- no allocation (dry-run path)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=is_def
    )


def pspec_tree(defs: PyTree, resolve: Callable) -> PyTree:
    """PartitionSpec tree. ``resolve(axes) -> PartitionSpec``."""
    return jax.tree.map(lambda d: resolve(d.axes), defs, is_leaf=is_def)


def param_count(defs: PyTree) -> int:
    return sum(d.size for d in jax.tree.leaves(defs, is_leaf=is_def))


def param_bytes(defs: PyTree) -> int:
    return sum(
        d.size * jnp.dtype(d.dtype).itemsize
        for d in jax.tree.leaves(defs, is_leaf=is_def)
    )


def cast_floating(tree: PyTree, dtype) -> PyTree:
    def leaf(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree.map(leaf, tree)
