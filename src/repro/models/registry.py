"""Model registry: parameter trees, input pytrees, FLOP accounting.

The single entry point the rest of the framework uses to talk to the model
zoo.  Everything is derived from the ModelConfig; no per-arch code outside
configs/ and the layout function in transformer.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import pytree as pt
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tfm


def param_defs(cfg: ModelConfig) -> dict:
    return tfm.stack_param_defs(cfg)


def cache_defs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    memory_len = _memory_len(cfg, max_seq)
    return tfm.cache_param_defs(cfg, batch, max_seq, memory_len)


def _memory_len(cfg: ModelConfig, seq: int) -> int:
    if cfg.family == "encdec":
        return seq
    if cfg.family == "vlm":
        return cfg.num_image_tokens
    return 0


def param_count(cfg: ModelConfig) -> int:
    return pt.param_count(param_defs(cfg))


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE experts scaled by k/E)."""
    defs = param_defs(cfg)
    total = 0

    def walk(path, d):
        nonlocal total
        name = pt.tree_path_str(path)
        n = d.size
        if "/ffn/" in name and cfg.num_experts and d.shape[-3:] and len(d.shape) >= 3:
            # stacked expert weights [P, E, ...] under moe ffn
            if "router" not in name:
                n = int(n * cfg.num_experts_per_tok / cfg.num_experts)
        total += n

    jax.tree_util.tree_map_with_path(walk, defs, is_leaf=pt.is_def)
    return total


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6 * N_active * tokens (train) or 2 * N_active * tokens
    (inference fwd), per the assignment's roofline convention."""
    n = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def train_batch_defs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    d = {
        "tokens": pt.ParamDef((B, S), jnp.int32, ("batch", None), "zeros"),
        "targets": pt.ParamDef((B, S), jnp.int32, ("batch", None), "zeros"),
    }
    if cfg.family == "encdec":
        d["frames"] = pt.ParamDef(
            (B, S, cfg.d_model), jnp.bfloat16, ("batch", None, None), "normal"
        )
    if cfg.family == "vlm":
        d["image_embeds"] = pt.ParamDef(
            (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16,
            ("batch", None, None), "normal",
        )
    return d


def prefill_batch_defs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    d = train_batch_defs(cfg, shape)
    d.pop("targets")
    return d


def decode_batch_defs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B = shape.global_batch
    return {
        "tokens": pt.ParamDef((B, 1), jnp.int32, ("batch", None), "zeros"),
    }
