"""Mixture-of-Experts FFN: GShard-style capacity dispatch with expert
parallelism over the ``model`` mesh axis.

Tokens are grouped [G, S_g, d]; a dispatch tensor [G, S_g, E, C] routes each
token to its top-k experts (capacity C per expert per group).  Annotating the
dispatched tensor [G, E, C, d] with E sharded over ``ep`` makes GSPMD lower
the routing to all-to-all collectives -- the classic GShard lowering.

Aux losses: Switch-style load-balance loss + router z-loss.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.common.pytree import ParamDef
from repro.configs.base import ModelConfig
from repro.dist.sharding import shard


def moe_defs(cfg: ModelConfig) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": ParamDef((d, E), jnp.float32, ("fsdp", None), "scaled"),
        "wg": ParamDef((E, d, ff), jnp.bfloat16, ("ep", "fsdp", None), "scaled"),
        "wu": ParamDef((E, d, ff), jnp.bfloat16, ("ep", "fsdp", None), "scaled"),
        "wd": ParamDef((E, ff, d), jnp.bfloat16, ("ep", None, "fsdp"), "scaled"),
    }


def _capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    c = tokens_per_group * cfg.num_experts_per_tok / cfg.num_experts
    c = int(math.ceil(c * cfg.capacity_factor))
    return max(c, 4)


def moe_apply(
    p: dict, x: jax.Array, cfg: ModelConfig, *, group_size: int = 256
) -> tuple[jax.Array, dict]:
    """x: [B, S, d] -> (out [B, S, d], aux metrics incl. load-balance loss)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    g = min(group_size, T)
    G = T // g
    xg = x.reshape(G, g, d)
    xg = shard(xg, "moe_group", None, None)

    logits = (xg.astype(jnp.float32)) @ p["router"]  # [G, g, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, topk_idx = jax.lax.top_k(probs, k)  # [G, g, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    C = _capacity(g, cfg)
    # Expert one-hot per routing slot: [G, g, k, E]
    onehot = jax.nn.one_hot(topk_idx, E, dtype=jnp.float32)
    # Position of each (token, slot) in its expert queue (priority: slot-major)
    # flatten (g, k) -> sequential priority
    flat = onehot.reshape(G, g * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat  # [G, g*k, E] position if assigned
    pos = jnp.sum(pos * flat, axis=-1).reshape(G, g, k)  # [G, g, k]
    expert_idx_pos = pos
    keep = expert_idx_pos < C
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # dispatch [G, g, E, C] = sum_k onehot_E * onehot_C
    pos_oh = jax.nn.one_hot(
        jnp.where(keep, expert_idx_pos, C), C, dtype=jnp.float32
    )  # [G, g, k, C] (overflow -> all-zero row)
    dispatch = jnp.einsum("gske,gskc->gsec", onehot, pos_oh)
    combine = jnp.einsum("gske,gskc,gsk->gsec", onehot, pos_oh, gate_vals)
    dispatch = shard(dispatch, "moe_group", None, None, None)
    combine = shard(combine, "moe_group", None, None, None)

    # route tokens to experts: [G, E, C, d]; E sharded over ep => all-to-all
    ex_in = jnp.einsum("gsd,gsec->gecd", xg.astype(jnp.float32), dispatch)

    # Decode-time layout (EXPERIMENTS.md §Perf, jamba decode iteration):
    # with very few token groups (G < data axis) the G dim cannot soak the
    # data axis, and GSPMD resolves the d-contraction by ALL-GATHERING the
    # expert weights over data — ~6 GB f32 per MoE layer per token step.
    # Sharding the tiny activation's d dim over fsdp instead makes the
    # contraction local (weights stay 2D-sharded); the residual comm is a
    # ~MB-scale partial-sum all-reduce of h.
    from repro.dist.sharding import axis_size

    few_groups = G < max(axis_size("fsdp"), 1)
    if few_groups:
        ex_in = shard(ex_in.astype(x.dtype), None, "ep", None, "fsdp")
    else:
        ex_in = shard(ex_in.astype(x.dtype), "batch", "ep", None, None)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", ex_in, p["wg"]))
    h = h * jnp.einsum("gecd,edf->gecf", ex_in, p["wu"])
    h = shard(h, *((None, "ep", None, None) if few_groups
                   else ("batch", "ep", None, None)))
    ex_out = jnp.einsum("gecf,efd->gecd", h, p["wd"])
    ex_out = shard(ex_out, *((None, "ep", None, "fsdp") if few_groups
                             else ("batch", "ep", None, None)))

    out = jnp.einsum(
        "gecd,gsec->gsd", ex_out.astype(jnp.float32), combine
    ).astype(x.dtype)
    out = shard(out, "moe_group", None, None)
    out = out.reshape(B, S, d)
    out = shard(out, "batch", "sp", None)

    # Switch load-balance loss: E * sum_e f_e * P_e  (f_e = pre-drop routing
    # fraction per expert, normalized by k so sum_e f_e == 1)
    f_e = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1)) / k
    p_e = jnp.mean(probs, axis=(0, 1))
    lb_loss = E * jnp.sum(f_e * p_e)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss, "moe_drop_frac": dropped}
    return out, aux
