"""Core layer primitives: norms, RoPE, MLPs, embeddings.

All layers are (defs, apply) pairs over ParamDef pytrees -- see
repro.common.pytree.  Logical sharding axes are declared on every parameter;
activations are annotated with repro.dist.sharding.shard().
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.common.pytree import ParamDef
from repro.dist.sharding import shard


def stack_defs(defs: Any, n: int) -> Any:
    """Add a leading scan/stack dim of size ``n`` to every ParamDef."""

    def one(d: ParamDef) -> ParamDef:
        axes = d.axes if d.axes else (None,) * len(d.shape)
        return ParamDef(
            shape=(n,) + tuple(d.shape),
            dtype=d.dtype,
            axes=(None,) + tuple(axes),
            init=d.init,
            init_scale=d.init_scale,
        )

    return jax.tree.map(one, defs, is_leaf=lambda x: isinstance(x, ParamDef))


# ---------------------------------------------------------------- norms


def rmsnorm_defs(d: int) -> dict:
    return {"scale": ParamDef((d,), jnp.float32, (None,), init="ones")}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"]).astype(dtype)


def layernorm_defs(d: int) -> dict:
    return {
        "scale": ParamDef((d,), jnp.float32, (None,), init="ones"),
        "bias": ParamDef((d,), jnp.float32, (None,), init="zeros"),
    }


def layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * p["scale"] + p["bias"]).astype(dtype)


# ---------------------------------------------------------------- RoPE


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float
) -> jax.Array:
    """x: [..., S, H, Dh]; positions: broadcastable to [..., S]."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, Dh/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- MLP


def mlp_defs(d: int, d_ff: int, act: str) -> dict:
    if act == "silu":  # SwiGLU: gate + up + down
        return {
            "wg": ParamDef((d, d_ff), jnp.bfloat16, ("fsdp", "tp"), "scaled"),
            "wu": ParamDef((d, d_ff), jnp.bfloat16, ("fsdp", "tp"), "scaled"),
            "wd": ParamDef((d_ff, d), jnp.bfloat16, ("tp", "fsdp"), "scaled"),
        }
    # plain 2-proj (gelu)
    return {
        "wi": ParamDef((d, d_ff), jnp.bfloat16, ("fsdp", "tp"), "scaled"),
        "bi": ParamDef((d_ff,), jnp.float32, ("tp",), "zeros"),
        "wd": ParamDef((d_ff, d), jnp.bfloat16, ("tp", "fsdp"), "scaled"),
        "bd": ParamDef((d,), jnp.float32, (None,), "zeros"),
    }


def mlp_apply(p: dict, x: jax.Array, act: str) -> jax.Array:
    if act == "silu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
        h = shard(h, "batch", "sp", "tp")
        return h @ p["wd"]
    h = jax.nn.gelu((x @ p["wi"]) + p["bi"].astype(x.dtype))
    h = shard(h, "batch", "sp", "tp")
    return (h @ p["wd"]) + p["bd"].astype(x.dtype)


# ---------------------------------------------------------------- embeddings


def embedding_defs(vocab: int, d: int, tie: bool) -> dict:
    out = {
        # d_model sharded over tp => token gather is collective-free.
        "table": ParamDef((vocab, d), jnp.bfloat16, ("fsdp", "tp"), "normal"),
    }
    if not tie:
        out["unembed"] = ParamDef(
            (d, vocab), jnp.bfloat16, ("fsdp", "tp"), "scaled"
        )
    return out


def embed(p: dict, ids: jax.Array) -> jax.Array:
    x = jnp.take(p["table"], ids, axis=0)
    return shard(x, "batch", "sp", None)


def unembed(p: dict, x: jax.Array) -> jax.Array:
    if "unembed" in p:
        logits = x @ p["unembed"]
    else:
        logits = x @ p["table"].T
    return shard(logits, "batch", "sp", "tp")
