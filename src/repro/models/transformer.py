"""Model assembly: periods of heterogeneous layer slots, scanned.

Every architecture is a stack of ``n_periods`` identical *periods*; a period
is a short list of ``Slot``s (mixer kind + optional cross-attention + FFN
kind).  Parameters for each slot are stacked over the period dim and the
period is scanned with ``lax.scan`` -- a 72-layer 398B model lowers to the
HLO of a single period, which is what keeps multi-pod compiles tractable.

Layouts:
  dense/moe    period = 1 layer                          x num_layers
  hybrid/jamba period = [mamba*, attn@mid, mamba*] x8    x num_layers/8
               (MoE FFN every ``moe_period``-th slot)
  ssm/xlstm    period = [sLSTM, mLSTM x7]                x num_layers/8
  vlm          period = [cross-attn layer, self x4]      x num_layers/5
  encdec       encoder stack (bidirectional) + decoder stack (causal+cross)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.common.pytree import ParamDef
from repro.configs.base import ModelConfig
from repro.dist.sharding import shard
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (
    embed,
    embedding_defs,
    mlp_apply,
    mlp_defs,
    rmsnorm,
    rmsnorm_defs,
    stack_defs,
    unembed,
)


@dataclasses.dataclass(frozen=True)
class Slot:
    mixer: str  # attn | attn_nc (non-causal) | mamba | mlstm | slstm
    cross: bool = False
    gated_cross: bool = False
    ffn: str = "dense"  # dense | moe | none


def decoder_layout(cfg: ModelConfig) -> tuple[int, list[Slot]]:
    """(n_periods, slots-per-period) for the decoder stack."""
    fam = cfg.family
    if fam in ("dense", "moe"):
        ffn = "moe" if cfg.num_experts else "dense"
        return cfg.num_layers, [Slot("attn", ffn=ffn)]
    if fam == "hybrid":
        P = cfg.attn_period
        assert cfg.num_layers % P == 0
        slots = []
        for i in range(P):
            mixer = "attn" if i == P // 2 else "mamba"
            ffn = "moe" if (i % cfg.moe_period == cfg.moe_offset) else "dense"
            slots.append(Slot(mixer, ffn=ffn))
        return cfg.num_layers // P, slots
    if fam == "ssm":
        P = cfg.slstm_period
        assert cfg.num_layers % P == 0
        slots = [Slot("slstm" if i == 0 else "mlstm", ffn="none") for i in range(P)]
        return cfg.num_layers // P, slots
    if fam == "vlm":
        P = cfg.cross_attn_period
        assert cfg.num_layers % P == 0
        slots = [
            Slot("attn", cross=(i == 0), gated_cross=True, ffn="dense")
            for i in range(P)
        ]
        return cfg.num_layers // P, slots
    if fam == "encdec":
        return cfg.num_decoder_layers, [Slot("attn", cross=True, ffn="dense")]
    raise ValueError(fam)


def encoder_layout(cfg: ModelConfig) -> tuple[int, list[Slot]]:
    return cfg.num_encoder_layers, [Slot("attn_nc", ffn="dense")]


# ---------------------------------------------------------------- defs


def _slot_defs(cfg: ModelConfig, slot: Slot) -> dict:
    d = {"ln1": rmsnorm_defs(cfg.d_model)}
    if slot.mixer in ("attn", "attn_nc"):
        d["attn"] = attn.attn_defs(cfg)
    elif slot.mixer == "mamba":
        d["mamba"] = ssm_mod.mamba_defs(cfg)
    elif slot.mixer == "mlstm":
        d["mlstm"] = xlstm_mod.mlstm_defs(cfg)
    elif slot.mixer == "slstm":
        d["slstm"] = xlstm_mod.slstm_defs(cfg)
    if slot.cross:
        d["ln_cross"] = rmsnorm_defs(cfg.d_model)
        d["cross"] = attn.attn_defs(cfg, cross=True, gated=slot.gated_cross)
    if slot.ffn != "none":
        d["ln2"] = rmsnorm_defs(cfg.d_model)
        d["ffn"] = (
            moe_mod.moe_defs(cfg) if slot.ffn == "moe" else
            mlp_defs(cfg.d_model, cfg.d_ff, cfg.act)
        )
    return d


def stack_param_defs(cfg: ModelConfig) -> dict:
    """Full parameter tree for an architecture."""
    n_p, slots = decoder_layout(cfg)
    defs: dict[str, Any] = {
        "embed": embedding_defs(cfg.vocab_size, cfg.d_model, cfg.tie_embeddings),
        "final_norm": rmsnorm_defs(cfg.d_model),
        "decoder": {
            f"slot{i}": stack_defs(_slot_defs(cfg, s), n_p)
            for i, s in enumerate(slots)
        },
    }
    if cfg.family == "encdec":
        n_e, eslots = encoder_layout(cfg)
        defs["encoder"] = {
            f"slot{i}": stack_defs(_slot_defs(cfg, s), n_e)
            for i, s in enumerate(eslots)
        }
        defs["enc_norm"] = rmsnorm_defs(cfg.d_model)
    return defs


def cache_param_defs(cfg: ModelConfig, batch: int, max_seq: int, memory_len: int = 0) -> dict:
    """Decode-cache tree, stacked per slot over periods."""
    n_p, slots = decoder_layout(cfg)
    out: dict[str, Any] = {}
    for i, s in enumerate(slots):
        c: dict[str, Any] = {}
        if s.mixer == "attn":
            c["kv"] = attn.cache_defs(cfg, batch, max_seq, n_p)
        elif s.mixer == "mamba":
            c["ssm"] = ssm_mod.mamba_state_defs(cfg, batch, n_p)
        elif s.mixer == "mlstm":
            c["mlstm"] = xlstm_mod.mlstm_state_defs(cfg, batch, n_p)
        elif s.mixer == "slstm":
            c["slstm"] = xlstm_mod.slstm_state_defs(cfg, batch, n_p)
        if s.cross:
            K, Dh = cfg.num_kv_heads, cfg.head_dim
            c["cross_kv"] = {
                "k": ParamDef((n_p, batch, memory_len, K, Dh), jnp.bfloat16,
                              (None, "kv_batch", None, "tp", None), "zeros"),
                "v": ParamDef((n_p, batch, memory_len, K, Dh), jnp.bfloat16,
                              (None, "kv_batch", None, "tp", None), "zeros"),
            }
        out[f"slot{i}"] = c
    return out


# ---------------------------------------------------------------- forward


def _apply_slot(
    p: dict,
    slot: Slot,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    mode: str,  # train | prefill | decode
    positions: jax.Array,
    index: jax.Array | None,
    cache: dict | None,
    memory: jax.Array | None,
) -> tuple[jax.Array, dict, dict]:
    new_cache: dict = {}
    aux: dict = {}
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)

    if slot.mixer in ("attn", "attn_nc"):
        causal = slot.mixer == "attn"
        q = attn.project_q(p["attn"], h, cfg, positions)
        if mode == "decode":
            k_new, v_new = attn.project_kv(p["attn"], h, cfg, positions)
            new_kv = attn.cache_update_tree(
                cache["kv"], k_new, v_new, index, window=cfg.sliding_window,
            )
            if cfg.sliding_window or not cfg.decode_seq_shard:
                o = attn.decode_attention_tree(
                    q, new_kv, index, window=cfg.sliding_window
                )
            else:
                o = attn.seq_sharded_decode_attention_tree(q, new_kv, index)
            new_cache["kv"] = new_kv
        else:
            k, v = attn.project_kv(p["attn"], h, cfg, positions)
            o = attn.chunked_attention(
                q, k, v, causal=causal, window=cfg.sliding_window
            )
            if mode == "prefill":
                T = cache["kv"]["k"].shape[1]
                kw = k[:, -T:] if k.shape[1] > T else k
                vw = v[:, -T:] if v.shape[1] > T else v
                new_cache["kv"] = attn.cache_update_tree(
                    cache["kv"], kw, vw, jnp.array(0, jnp.int32), window=0,
                )
        out = attn.project_out(p["attn"], o, cfg)
    elif slot.mixer == "mamba":
        st = cache["ssm"] if mode != "train" else None
        if mode == "train":
            out = ssm_mod.mamba_apply(p["mamba"], h, cfg)
        else:
            out, st2 = ssm_mod.mamba_apply(
                p["mamba"], h, cfg, state=st if mode == "decode" else None,
                return_state=True,
            )
            new_cache["ssm"] = st2
    elif slot.mixer == "mlstm":
        st = cache["mlstm"] if mode != "train" else None
        if mode == "train":
            out = xlstm_mod.mlstm_apply(p["mlstm"], h, cfg)
        else:
            out, st2 = xlstm_mod.mlstm_apply(
                p["mlstm"], h, cfg, state=st if mode == "decode" else None,
                return_state=True,
            )
            new_cache["mlstm"] = st2
    elif slot.mixer == "slstm":
        st = cache["slstm"] if mode != "train" else None
        if mode == "train":
            out = xlstm_mod.slstm_apply(p["slstm"], h, cfg)
        else:
            out, st2 = xlstm_mod.slstm_apply(
                p["slstm"], h, cfg, state=st if mode == "decode" else None,
                return_state=True,
            )
            new_cache["slstm"] = st2
    else:
        raise ValueError(slot.mixer)
    x = x + out
    x = shard(x, "batch", "sp", None)

    if slot.cross:
        hc = rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        qc = attn.project_q(p["cross"], hc, cfg, positions=None)
        if mode == "decode":
            ck, cv = cache["cross_kv"]["k"], cache["cross_kv"]["v"]
            new_cache["cross_kv"] = {"k": ck, "v": cv}
        else:
            ck, cv = attn.project_kv(p["cross"], memory, cfg, positions=None)
            if mode == "prefill":
                new_cache["cross_kv"] = {
                    "k": ck.astype(jnp.bfloat16), "v": cv.astype(jnp.bfloat16)
                }
        oc = attn.chunked_attention(qc, ck, cv, causal=False)
        x = x + attn.project_out(p["cross"], oc, cfg)
        x = shard(x, "batch", "sp", None)

    if slot.ffn != "none":
        hf = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if slot.ffn == "moe":
            out, aux = moe_mod.moe_apply(p["ffn"], hf, cfg)
        else:
            out = mlp_apply(p["ffn"], hf, cfg.act)
        x = x + out
        x = shard(x, "batch", "sp", None)
    return x, new_cache, aux


def _run_stack(
    params: dict,
    slots: list[Slot],
    x: jax.Array,
    cfg: ModelConfig,
    *,
    mode: str,
    positions: jax.Array,
    index: jax.Array | None = None,
    caches: dict | None = None,
    memory: jax.Array | None = None,
    remat: bool = False,
) -> tuple[jax.Array, dict, dict]:
    """Scan over periods. params/caches: {'slotN': stacked tree}."""

    def period_fn(x, per_params, per_cache, memory):
        new_caches = {}
        aux_sum = None
        for i, slot in enumerate(slots):
            key = f"slot{i}"
            x, nc, aux = _apply_slot(
                per_params[key], slot, x, cfg,
                mode=mode, positions=positions, index=index,
                cache=per_cache.get(key) if per_cache else None,
                memory=memory,
            )
            if nc:
                new_caches[key] = nc
            if aux:
                aux_sum = aux if aux_sum is None else jax.tree.map(
                    jnp.add, aux_sum, aux
                )
        return x, new_caches, (aux_sum or {})

    if remat and mode == "train":
        if cfg.remat_policy == "dots":
            policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
            period_fn = jax.checkpoint(period_fn, policy=policy)
        elif cfg.remat_policy == "block":
            period_fn = jax.checkpoint(period_fn)

    has_moe = any(s.ffn == "moe" for s in slots)
    aux0 = (
        {"moe_lb_loss": jnp.zeros((), jnp.float32),
         "moe_z_loss": jnp.zeros((), jnp.float32),
         "moe_drop_frac": jnp.zeros((), jnp.float32)}
        if has_moe else {}
    )

    def body(carry, per_inputs):
        x, aux_acc = carry
        per_params, per_cache = per_inputs
        x, new_cache, aux = period_fn(x, per_params, per_cache, memory)
        if aux:
            aux_acc = jax.tree.map(jnp.add, aux_acc, aux)
        return (x, aux_acc), new_cache

    cache_xs = caches if caches is not None else {}
    (x, aux), new_caches = jax.lax.scan(body, (x, aux0), (params, cache_xs))
    return x, new_caches, aux


def forward(
    params: dict,
    cfg: ModelConfig,
    *,
    tokens: jax.Array | None = None,  # [B,S] int32 (decoder input ids)
    inputs_embeds: jax.Array | None = None,  # [B,S,d] (stub frontends)
    memory_embeds: jax.Array | None = None,  # [B,M,d] enc frames / img patches
    mode: str = "train",
    index: jax.Array | None = None,
    caches: dict | None = None,
    remat: bool = False,
    logits_slice_last: bool = False,
):
    """Unified forward. Returns (logits, new_caches, aux)."""
    n_p, slots = decoder_layout(cfg)

    # Activation dtype follows the weights (bf16 compute / fp32 smoke): cast
    # externally-supplied embeddings so the layer-scan carry dtype is stable.
    wdtype = jax.tree.leaves(params["embed"])[0].dtype
    if inputs_embeds is None:
        x = embed(params["embed"], tokens)
    else:
        x = inputs_embeds.astype(wdtype)
    if memory_embeds is not None:
        memory_embeds = memory_embeds.astype(wdtype)
    B, S = x.shape[0], x.shape[1]

    if mode == "decode":
        positions = index + jnp.arange(S)
    else:
        positions = jnp.arange(S)

    memory = None
    if cfg.family == "encdec" and mode != "decode":
        n_e, eslots = encoder_layout(cfg)
        epos = jnp.arange(memory_embeds.shape[1])
        menc, _, _ = _run_stack(
            params["encoder"], eslots, memory_embeds, cfg,
            mode="train", positions=epos, remat=remat,
        )
        memory = rmsnorm(params["enc_norm"], menc, cfg.norm_eps)
    elif cfg.family == "vlm":
        memory = memory_embeds  # precomputed patch embeddings (stub frontend)

    x, new_caches, aux = _run_stack(
        params["decoder"], slots, x, cfg,
        mode=mode, positions=positions, index=index,
        caches=caches, memory=memory, remat=remat,
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if logits_slice_last:
        x = x[:, -1:]
    logits = unembed(params["embed"], x)
    return logits, new_caches, aux
