"""xLSTM blocks: chunkwise-parallel mLSTM and sequential sLSTM.

mLSTM (matrix memory, exponential gating) admits a chunk-parallel form:
within a chunk the output is a gated-attention quadratic form; across chunks
a stabilized (C, n, m) state is carried.  This keeps the backward-pass
memory at O(S/L) chunk states instead of O(S) step states -- a naive
sequential scan of the [B,H,512,512] matrix memory would need terabytes of
residuals at train_4k (see EXPERIMENTS.md §Perf).

sLSTM has hidden-to-gate recurrence (R matrices) and is inherently
sequential; xLSTM[7:1] interleaving keeps it off the critical path.

All gate math in fp32 log-space with max-stabilizers (Appendix A of
arXiv:2405.04517).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.common.pytree import ParamDef
from repro.configs.base import ModelConfig
from repro.dist.sharding import shard

NEG_INF = -1e30


# ================================================================= mLSTM


def mlstm_defs(cfg: ModelConfig) -> dict:
    d, H, Dh = cfg.d_model, cfg.num_heads, cfg.head_dim
    HD = H * Dh
    return {
        "wq": ParamDef((d, HD), jnp.bfloat16, ("fsdp", "tp"), "scaled"),
        "wk": ParamDef((d, HD), jnp.bfloat16, ("fsdp", "tp"), "scaled"),
        "wv": ParamDef((d, HD), jnp.bfloat16, ("fsdp", "tp"), "scaled"),
        "wz": ParamDef((d, HD), jnp.bfloat16, ("fsdp", "tp"), "scaled"),
        "wo": ParamDef((HD, d), jnp.bfloat16, ("tp", "fsdp"), "scaled"),
        "w_if": ParamDef((d, 2 * H), jnp.float32, ("fsdp", None), "scaled"),
        "b_if": ParamDef((2 * H,), jnp.float32, (None,), "zeros"),
        "conv_w": ParamDef((4, HD), jnp.bfloat16, (None, "tp"), "scaled"),
        "conv_b": ParamDef((HD,), jnp.float32, ("tp",), "zeros"),
        "hnorm": ParamDef((HD,), jnp.float32, ("tp",), "ones"),
    }


def mlstm_state_defs(cfg: ModelConfig, batch: int, n_layers: int) -> dict:
    H, Dh = cfg.num_heads, cfg.head_dim
    HD = H * Dh
    return {
        "C": ParamDef(
            (n_layers, batch, H, Dh, Dh), jnp.float32,
            (None, "kv_batch", None, None, "tp"), "zeros",
        ),
        "n": ParamDef(
            (n_layers, batch, H, Dh), jnp.float32,
            (None, "kv_batch", None, "tp"), "zeros",
        ),
        "m": ParamDef(
            (n_layers, batch, H), jnp.float32, (None, "kv_batch", None), "zeros"
        ),
        "conv": ParamDef(
            (n_layers, batch, 3, HD), jnp.bfloat16,
            (None, "kv_batch", None, "tp"), "zeros",
        ),
    }


def _mlstm_chunkwise(q, k, v, li, lf, state, chunk: int = 128):
    """q,k,v: [B,S,H,Dh] (k pre-scaled); li,lf: [B,S,H] log gates.

    state: (C [B,H,Dh,Dh], n [B,H,Dh], m [B,H]).  Returns (h, state').
    """
    B, S, H, Dh = q.shape
    L = min(chunk, S)
    nc = S // L
    assert nc * L == S

    def resh(x):
        return x.reshape(B, nc, L, *x.shape[2:]).transpose(1, 0, 2, *range(3, x.ndim + 1))

    qc, kc, vc = resh(q), resh(k), resh(v)  # [nc,B,L,H,Dh]
    lic, lfc = resh(li), resh(lf)  # [nc,B,L,H]

    def body(carry, inputs):
        C0, n0, m0 = carry
        qb, kb, vb, lib, lfb = inputs
        qb = qb.astype(jnp.float32).transpose(0, 2, 1, 3)  # [B,H,L,Dh]
        kb = kb.astype(jnp.float32).transpose(0, 2, 1, 3)
        vb = vb.astype(jnp.float32).transpose(0, 2, 1, 3)
        lib = lib.transpose(0, 2, 1)  # [B,H,L]
        lfb = lfb.transpose(0, 2, 1)
        b = jnp.cumsum(lfb, axis=-1)  # [B,H,L]
        bL = b[..., -1:]

        # intra-chunk log weights D[j,s] = b_j - b_s + li_s (s <= j)
        Dm = b[..., :, None] - b[..., None, :] + lib[..., None, :]
        causal = jnp.tril(jnp.ones((L, L), bool))
        Dm = jnp.where(causal, Dm, NEG_INF)
        m_intra = jnp.max(Dm, axis=-1)  # [B,H,L]
        m_inter = m0[..., None] + b  # [B,H,L]
        mj = jnp.maximum(m_inter, m_intra)

        Sqk = jnp.einsum("bhld,bhsd->bhls", qb, kb)  # [B,H,L,L]
        w = jnp.exp(Dm - mj[..., None])
        num = jnp.einsum("bhls,bhsd->bhld", w * Sqk, vb)
        num = num + jnp.exp(m_inter - mj)[..., None] * jnp.einsum(
            "bhld,bhvd->bhlv", qb, C0
        )
        den = jnp.sum(w * Sqk, axis=-1) + jnp.exp(m_inter - mj) * jnp.einsum(
            "bhld,bhd->bhl", qb, n0
        )
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-mj))[..., None]

        # cross-chunk state update
        m_new = jnp.maximum(
            m0 + bL[..., 0], jnp.max(bL - b + lib, axis=-1)
        )  # [B,H]
        wS = jnp.exp(bL - b + lib - m_new[..., None])  # [B,H,L]
        C_new = jnp.exp(m0 + bL[..., 0] - m_new)[..., None, None] * C0 + jnp.einsum(
            "bhs,bhsv,bhsk->bhvk", wS, vb, kb
        )
        n_new = jnp.exp(m0 + bL[..., 0] - m_new)[..., None] * n0 + jnp.einsum(
            "bhs,bhsk->bhk", wS, kb
        )
        return (C_new, n_new, m_new), h.transpose(0, 2, 1, 3)  # [B,L,H,Dh]

    state, hs = jax.lax.scan(body, state, (qc, kc, vc, lic, lfc))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, Dh)
    return h, state


def mlstm_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    state: dict | None = None,
    return_state: bool = False,
):
    B, S, d = x.shape
    H, Dh = cfg.num_heads, cfg.head_dim

    # causal conv on the shared q/k path (xLSTM uses a small causal conv
    # before the q/k projections; we conv the projected source)
    qk_src = x @ p["wq"]  # [B,S,HD]
    k_src = x @ p["wk"]
    W = p["conv_w"].shape[0]
    prev_c = state["conv"] if state is not None else jnp.zeros((B, W - 1, H * Dh), x.dtype)
    src = jnp.concatenate([prev_c.astype(x.dtype), qk_src + k_src], axis=1)
    conv = sum(src[:, i : i + S, :] * p["conv_w"][i] for i in range(W))
    conv = jax.nn.silu(conv + p["conv_b"].astype(x.dtype))
    new_conv = src[:, -(W - 1) :, :]

    q = (qk_src + conv).reshape(B, S, H, Dh)
    k = ((k_src + conv) / math.sqrt(Dh)).reshape(B, S, H, Dh)
    v = (x @ p["wv"]).reshape(B, S, H, Dh)
    gates = x.astype(jnp.float32) @ p["w_if"] + p["b_if"]  # [B,S,2H]
    li = gates[..., :H]  # input gate (log space, exp activation)
    lf = jax.nn.log_sigmoid(gates[..., H:])  # forget gate

    if state is not None:
        st = (state["C"], state["n"], state["m"])
    else:
        st = (
            jnp.zeros((B, H, Dh, Dh), jnp.float32),
            jnp.zeros((B, H, Dh), jnp.float32),
            jnp.zeros((B, H), jnp.float32),
        )

    if S == 1:  # decode: single recurrence step
        C0, n0, m0 = st
        qs = q[:, 0].astype(jnp.float32)
        ks = k[:, 0].astype(jnp.float32)
        vs = v[:, 0].astype(jnp.float32)
        lis, lfs = li[:, 0], lf[:, 0]
        m_new = jnp.maximum(lfs + m0, lis)
        ip = jnp.exp(lis - m_new)
        fp = jnp.exp(lfs + m0 - m_new)
        C_new = fp[..., None, None] * C0 + ip[..., None, None] * (
            vs[..., :, None] * ks[..., None, :]
        )
        n_new = fp[..., None] * n0 + ip[..., None] * ks
        num = jnp.einsum("bhd,bhvd->bhv", qs, C_new)
        den = jnp.einsum("bhd,bhd->bh", qs, n_new)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
        h = h[:, None]  # [B,1,H,Dh]
        st = (C_new, n_new, m_new)
    else:
        h, st = _mlstm_chunkwise(q, k, v, li, lf, st)

    # per-head norm, output gate, down-projection
    hf = h.reshape(B, S, H * Dh).astype(jnp.float32)
    hh = h.astype(jnp.float32)
    var = jnp.mean(jnp.square(hh), axis=-1, keepdims=True)
    hn = (hh * jax.lax.rsqrt(var + cfg.norm_eps)).reshape(B, S, H * Dh)
    hn = (hn * p["hnorm"]).astype(x.dtype)
    z = jax.nn.silu(x @ p["wz"])
    out = (hn * z) @ p["wo"]
    out = shard(out, "batch", "sp", None)
    if return_state:
        C_new, n_new, m_new = st
        return out, {"C": C_new, "n": n_new, "m": m_new, "conv": new_conv.astype(jnp.bfloat16)}
    return out


# ================================================================= sLSTM


def slstm_defs(cfg: ModelConfig) -> dict:
    d, H, Dh = cfg.d_model, cfg.num_heads, cfg.head_dim
    HD = H * Dh
    return {
        "w": ParamDef((d, 4, HD), jnp.bfloat16, ("fsdp", None, "tp"), "scaled"),
        "b": ParamDef((4, HD), jnp.float32, (None, "tp"), "zeros"),
        # r's OUTPUT Dh dim is tp-sharded: the backward scan all-reduces a
        # weight-shaped dr cotangent every timestep (unavoidable for an
        # h-to-gate recurrence under batch sharding); sharding r makes that
        # per-step reduction 16x smaller (§Perf, xlstm iteration 3).
        "r": ParamDef((H, Dh, 4, Dh), jnp.bfloat16, (None, None, None, "slstm_r"), "scaled"),
        "hnorm": ParamDef((HD,), jnp.float32, ("tp",), "ones"),
        "wo": ParamDef((HD, d), jnp.bfloat16, ("tp", "fsdp"), "scaled"),
    }


def slstm_state_defs(cfg: ModelConfig, batch: int, n_layers: int) -> dict:
    H, Dh = cfg.num_heads, cfg.head_dim
    shp = (n_layers, batch, H, Dh)
    ax = (None, "kv_batch", None, None)
    return {
        "c": ParamDef(shp, jnp.float32, ax, "zeros"),
        "n": ParamDef(shp, jnp.float32, ax, "zeros"),
        "h": ParamDef(shp, jnp.float32, ax, "zeros"),
        "m": ParamDef((n_layers, batch, H), jnp.float32, (None, "kv_batch", None), "zeros"),
    }


def slstm_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    state: dict | None = None,
    return_state: bool = False,
):
    B, S, d = x.shape
    H, Dh = cfg.num_heads, cfg.head_dim

    wx = jnp.einsum("bsd,dgh->bsgh", x.astype(jnp.float32), p["w"].astype(jnp.float32))
    wx = wx + p["b"]  # [B,S,4,HD]
    wx = wx.reshape(B, S, 4, H, Dh)

    if state is not None:
        st = (state["c"], state["n"], state["h"], state["m"])
    else:
        z = jnp.zeros((B, H, Dh), jnp.float32)
        st = (z, z, z, jnp.zeros((B, H), jnp.float32))

    r = p["r"].astype(jnp.float32)

    def step(carry, wx_t):
        c, n, h, m = carry
        rg = jnp.einsum("bhd,hdgk->bghk", h, r)  # [B,4,H,Dh]
        g = wx_t.transpose(0, 2, 1, 3) + rg.transpose(0, 2, 1, 3)  # [B,H,4,Dh]
        i_log = g[:, :, 0]
        lf = jax.nn.log_sigmoid(g[:, :, 1])
        zt = jnp.tanh(g[:, :, 2])
        ot = jax.nn.sigmoid(g[:, :, 3])
        # per-head scalar stabilizer (max over head dim of gate logits)
        m_new = jnp.maximum(
            jnp.max(lf, axis=-1) + m, jnp.max(i_log, axis=-1)
        )
        ip = jnp.exp(i_log - m_new[..., None])
        fp = jnp.exp(lf + (m - m_new)[..., None])
        c_new = fp * c + ip * zt
        n_new = fp * n + ip
        h_new = ot * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, h_new, m_new), h_new

    # Chunked sequential scan with a statically-UNROLLED inner segment
    # (EXPERIMENTS.md §Perf, xlstm iteration): a per-timestep lax.scan makes
    # XLA (a) re-read the recurrent weight r from HBM every step and (b)
    # all-reduce the weight-shaped dr gradient across the data axis every
    # step of the backward scan (S x per layer!).  Unrolling UNROLL steps
    # inside each scan iteration keeps r live across the segment and lets
    # the dr partial sums accumulate locally, cutting both weight traffic
    # and collective count by UNROLL x.  Semantics identical (pure unroll).
    # Train-only: the unroll pays for the BACKWARD scan (dr reductions);
    # forward-only prefill regresses under it (more live intermediates per
    # scan iteration -- observed on the prefill_32k dry-run cell).
    UNROLL = 16
    if (not return_state) and S % UNROLL == 0 and S > UNROLL:
        wxc = wx.transpose(1, 0, 2, 3, 4).reshape(
            S // UNROLL, UNROLL, B, 4, H, Dh
        )

        def chunk_step(carry, wx_chunk):
            hs_u = []
            for t in range(UNROLL):
                carry, h_t = step(carry, wx_chunk[t])
                hs_u.append(h_t)
            return carry, jnp.stack(hs_u)

        (c, n, h, m), hs = jax.lax.scan(chunk_step, st, wxc)
        hs = hs.reshape(S, B, H, Dh)
    else:
        (c, n, h, m), hs = jax.lax.scan(
            step, st, wx.transpose(1, 0, 2, 3, 4)
        )  # hs: [S,B,H,Dh]
    hseq = hs.transpose(1, 0, 2, 3)

    var = jnp.mean(jnp.square(hseq), axis=-1, keepdims=True)
    hn = (hseq * jax.lax.rsqrt(var + cfg.norm_eps)).reshape(B, S, H * Dh)
    out = (hn * p["hnorm"]).astype(x.dtype) @ p["wo"]
    out = shard(out, "batch", "sp", None)
    if return_state:
        return out, {"c": c, "n": n, "h": h, "m": m}
    return out
