"""Mamba (S6) selective-state-space block, chunk-parallel.

Train/prefill uses a chunked scan: ``lax.scan`` over sequence chunks with an
inner ``associative_scan`` -- O(chunk) memory instead of O(S) for the
state tensor.  Decode is the single-step recurrence with carried
(h, conv) state.  The Pallas ``selective_scan`` kernel
(repro.kernels.selective_scan) implements the same chunked algorithm with
explicit VMEM tiling for TPU; this module is its XLA twin used by the
dry-run.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.common.pytree import ParamDef
from repro.configs.base import ModelConfig
from repro.dist.sharding import shard


def dt_rank(cfg: ModelConfig) -> int:
    return math.ceil(cfg.d_model / 16)


def mamba_defs(cfg: ModelConfig) -> dict:
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_d_state
    R = dt_rank(cfg)
    return {
        "in_proj": ParamDef((d, 2 * di), jnp.bfloat16, ("fsdp", "tp"), "scaled"),
        "conv_w": ParamDef((cfg.ssm_d_conv, di), jnp.bfloat16, (None, "tp"), "scaled"),
        "conv_b": ParamDef((di,), jnp.float32, ("tp",), "zeros"),
        "x_proj": ParamDef((di, R + 2 * N), jnp.bfloat16, ("tp", None), "scaled"),
        "dt_proj": ParamDef((R, di), jnp.bfloat16, (None, "tp"), "scaled"),
        "dt_bias": ParamDef((di,), jnp.float32, ("tp",), "zeros"),
        "A_log": ParamDef((di, N), jnp.float32, ("tp", None), "ssm_a"),
        "D": ParamDef((di,), jnp.float32, ("tp",), "ones"),
        "norm": ParamDef((di,), jnp.float32, ("tp",), "ones"),
        "out_proj": ParamDef((di, d), jnp.bfloat16, ("tp", "fsdp"), "scaled"),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, prev: jax.Array | None):
    """Depthwise causal conv1d. x: [B,S,di]; w: [W,di]; prev: [B,W-1,di]."""
    W = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i] for i in range(W)
    ) + b.astype(x.dtype)
    new_prev = xp[:, -(W - 1) :, :] if W > 1 else prev
    return out, new_prev


def _ssm_scan_chunked(
    deltaA: jax.Array,  # [B,S,di,N]
    deltaBx: jax.Array,  # [B,S,di,N]
    C: jax.Array,  # [B,S,N]
    h0: jax.Array,  # [B,di,N]
    chunk: int = 256,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y [B,S,di], h_final [B,di,N])."""
    B, S, di, N = deltaA.shape
    chunk = min(chunk, S)
    n_chunks = max(1, S // chunk)
    assert n_chunks * chunk == S, f"S={S} not divisible by chunk={chunk}"
    dA = deltaA.reshape(B, n_chunks, chunk, di, N).transpose(1, 0, 2, 3, 4)
    dBx = deltaBx.reshape(B, n_chunks, chunk, di, N).transpose(1, 0, 2, 3, 4)
    Cc = C.reshape(B, n_chunks, chunk, N).transpose(1, 0, 2, 3)

    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a2 * a1, a2 * b1 + b2

    def body(h, inputs):
        dA_c, dBx_c, C_c = inputs  # [B,chunk,di,N]
        Acum, Bcum = jax.lax.associative_scan(combine, (dA_c, dBx_c), axis=1)
        h_t = Acum * h[:, None] + Bcum  # [B,chunk,di,N]
        y = jnp.einsum("bsdn,bsn->bsd", h_t, C_c)
        return h_t[:, -1], y

    h_final, ys = jax.lax.scan(body, h0, (dA, dBx, Cc))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, di)
    return y, h_final


def mamba_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    state: dict | None = None,
    return_state: bool = False,
):
    """x: [B,S,d].  state = {'h': [B,di,N] f32, 'conv': [B,W-1,di]}."""
    B, S, d = x.shape
    di, N = cfg.d_inner, cfg.ssm_d_state
    R = dt_rank(cfg)

    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard(xin, "batch", None, "tp")

    prev = state["conv"] if state is not None else None
    xin, conv_state = _causal_conv(xin, p["conv_w"], p["conv_b"], prev)
    xin = jax.nn.silu(xin)

    proj = xin @ p["x_proj"]  # [B,S,R+2N]
    dt, Bm, Cm = jnp.split(proj.astype(jnp.float32), [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        dt @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"]
    )  # [B,S,di]
    A = -jnp.exp(p["A_log"])  # [di,N]
    deltaA = jnp.exp(dt[..., None] * A)  # [B,S,di,N]
    deltaBx = (
        dt[..., None] * Bm[:, :, None, :] * xin.astype(jnp.float32)[..., None]
    )

    h0 = (
        state["h"]
        if state is not None
        else jnp.zeros((B, di, N), jnp.float32)
    )
    if S == 1:  # decode fast path: single recurrence step
        h = deltaA[:, 0] * h0 + deltaBx[:, 0]
        y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])[:, None]
        h_final = h
    else:
        y, h_final = _ssm_scan_chunked(deltaA, deltaBx, Cm, h0)

    y = y + p["D"] * xin.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    # jamba-style RMS norm on the gated output
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps) * p["norm"]).astype(x.dtype)
    out = y @ p["out_proj"]
    out = shard(out, "batch", "sp", None)
    if return_state:
        return out, {"h": h_final, "conv": conv_state}
    return out


def mamba_state_defs(cfg: ModelConfig, batch: int, n_layers: int) -> dict:
    di, N, W = cfg.d_inner, cfg.ssm_d_state, cfg.ssm_d_conv
    return {
        "h": ParamDef(
            (n_layers, batch, di, N), jnp.float32,
            (None, "kv_batch", "tp", None), "zeros",
        ),
        "conv": ParamDef(
            (n_layers, batch, W - 1, di), jnp.bfloat16,
            (None, "kv_batch", None, "tp"), "zeros",
        ),
    }
