"""Attention: GQA/MHA with RoPE, QKV-bias, QK-norm, sliding-window, cross-attn,
KV caches (full / rolling-window) and sequence-parallel sharded decode.

Three execution paths:
  * ``chunked_attention`` -- online-softmax over KV chunks in pure jnp. This
    is the XLA path used by the CPU dry-run and is the oracle-equivalent of
    the Pallas flash_attention kernel (repro.kernels.flash_attention), which
    replaces it on real TPUs.
  * ``decode_attention`` -- single-token attention against a cache.
  * ``seq_sharded_decode_attention`` -- shard_map over the ``model`` axis with
    partial-softmax (m, l) psum combine; the KV cache seq dim is sharded so
    multi-GB 32k/500k caches are never all-gathered.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.pytree import ParamDef
from repro.configs.base import ModelConfig
from repro.dist.sharding import current_mesh, current_rules, shard
from repro.models.layers import apply_rope, rmsnorm

NEG_INF = -1e30


# ---------------------------------------------------------------- params


def attn_defs(cfg: ModelConfig, *, cross: bool = False, gated: bool = False):
    d, H, K, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    defs = {
        "wq": ParamDef((d, H, Dh), jnp.bfloat16, ("fsdp", "tp", None), "scaled"),
        "wk": ParamDef((d, K, Dh), jnp.bfloat16, ("fsdp", "tp", None), "scaled"),
        "wv": ParamDef((d, K, Dh), jnp.bfloat16, ("fsdp", "tp", None), "scaled"),
        "wo": ParamDef((H, Dh, d), jnp.bfloat16, ("tp", None, "fsdp"), "scaled"),
    }
    if cfg.use_qkv_bias:
        defs["bq"] = ParamDef((H, Dh), jnp.float32, ("tp", None), "zeros")
        defs["bk"] = ParamDef((K, Dh), jnp.float32, ("tp", None), "zeros")
        defs["bv"] = ParamDef((K, Dh), jnp.float32, ("tp", None), "zeros")
    if cfg.use_qk_norm:
        defs["q_norm"] = ParamDef((Dh,), jnp.float32, (None,), "ones")
        defs["k_norm"] = ParamDef((Dh,), jnp.float32, (None,), "ones")
    if gated:  # VLM gated cross-attention (tanh gate, init 0 => identity)
        defs["gate"] = ParamDef((), jnp.float32, (), "zeros")
    return defs


def project_q(p, x, cfg: ModelConfig, positions=None):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
    if "q_norm" in p:
        q = rmsnorm({"scale": p["q_norm"]}, q, cfg.norm_eps)
    if positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
    return shard(q, "batch", None, "tp", None)


def project_kv(p, x, cfg: ModelConfig, positions=None):
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bk" in p:
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    if "k_norm" in p:
        k = rmsnorm({"scale": p["k_norm"]}, k, cfg.norm_eps)
    if positions is not None:
        k = apply_rope(k, positions, cfg.rope_theta)
    k = shard(k, "batch", None, "tp", None)
    v = shard(v, "batch", None, "tp", None)
    return k, v


def project_out(p, o, cfg: ModelConfig):
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if "gate" in p:
        out = jnp.tanh(p["gate"]).astype(out.dtype) * out
    return shard(out, "batch", "sp", None)


# ---------------------------------------------------------------- core math


def _group(q, num_kv_heads):
    """[B,S,H,D] -> [B,S,K,G,D]."""
    B, S, H, D = q.shape
    G = H // num_kv_heads
    return q.reshape(B, S, num_kv_heads, G, D)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_offset: int | jax.Array = 0,
    window: int = 0,
    kv_chunk: int = 512,
) -> jax.Array:
    """Online-softmax attention, O(S * chunk) memory, HEADS-SHARDED layout.

    q: [B,Sq,H,D]; k, v: [B,Skv,K,D] (GQA: H % K == 0).
    ``q_offset``: absolute position of q[0] (prefill continuation / decode).
    ``window`` > 0: sliding-window attention (attend to last ``window`` keys).

    Perf note (EXPERIMENTS.md §Perf, iteration 1): scores/accumulators are
    computed in a flat [B, H, ...] head-major layout with an explicit "tp"
    sharding annotation on the head dim.  The original [B, K, G, ...]
    grouped layout left the score tensors replicated across the model axis
    (K < tp for GQA), which dominated the memory roofline term and forced
    per-chunk KV re-gathers inside the scan.  KV heads are broadcast to the
    q-head grid up front (k/v are small; the one-time broadcast replaces
    3584 in-loop gathers on the qwen3 train cell).
    """
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(D)

    # head-major q: [B, H, Sq, D], sharded over tp
    qh = q.transpose(0, 2, 1, 3).astype(jnp.float32)
    qh = shard(qh, "batch", "tp", None, None)
    # broadcast kv heads to q heads once: [B, K, Skv, D] -> [B, H, Skv, D]
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1)
    kh = shard(kh, "batch", "tp", None, None)
    vh = shard(vh, "batch", "tp", None, None)

    Skv = k.shape[1]
    n_chunks = max(1, math.ceil(Skv / kv_chunk))
    pad = n_chunks * kv_chunk - Skv
    if pad:
        kh = jnp.pad(kh, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kc = kh.reshape(B, H, n_chunks, kv_chunk, D).transpose(2, 0, 1, 3, 4)
    vc = vh.reshape(B, H, n_chunks, kv_chunk, D).transpose(2, 0, 1, 3, 4)

    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, inputs):
        acc, m, l = carry
        ci, (kb, vb) = inputs
        kv_pos = ci * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum(
            "bhsd,bhtd->bhst", qh, kb.astype(jnp.float32)
        ) * scale  # [B,H,Sq,C]
        s = shard(s, "batch", "tp", None, None)
        mask = jnp.broadcast_to(kv_pos[None, :] < Skv, (Sq, kv_chunk))
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        if window > 0:
            mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        pexp = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(pexp, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhst,bhtd->bhsd", pexp, vb.astype(jnp.float32)
        )
        acc_new = shard(acc_new, "batch", "tp", None, None)
        return (acc_new, m_new, l_new), None

    acc0 = shard(jnp.zeros((B, H, Sq, D), jnp.float32),
                 "batch", "tp", None, None)
    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0), (jnp.arange(n_chunks), (kc, vc))
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 2, 1, 3)  # [B, Sq, H, D]
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    index: jax.Array,
    *,
    window: int = 0,
) -> jax.Array:
    """One-token attention vs a [B,T,K,D] cache, valid positions <= index.

    For a rolling-window cache (window > 0) the cache holds the last
    ``window`` keys at slots pos % window; all written slots are valid.
    """
    B, Sq, H, D = q.shape
    K = k_cache.shape[2]
    T = k_cache.shape[1]
    qg = _group(q, K).astype(jnp.float32)
    s = jnp.einsum(
        "bskgd,btkd->bkgst", qg, k_cache.astype(jnp.float32)
    ) / math.sqrt(D)
    slot = jnp.arange(T)
    if window > 0:
        n_written = jnp.minimum(index + 1, T)
        valid = slot < n_written
    else:
        valid = slot <= index
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bkgsd", p, v_cache.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D).astype(q.dtype)


def _seq_sharded_body(q, k, v, index, T, *, window: int = 0):
    """shard_map body: local-shape partial-softmax attention + psum combine.

    q [Bl,Sq,H,D]; k/v [Bl,T_local,K,D] (the model-axis shard of the cache).
    """
    Bl, Sq, H, D = q.shape
    Kl = k.shape[2]
    T_local = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    ax = jax.lax.axis_index("model")
    qg = _group(q, Kl).astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32)) * scale
    slot = ax * T_local + jnp.arange(T_local)
    if window > 0:
        n_written = jnp.minimum(index + 1, T)
        valid = slot < n_written
    else:
        valid = slot <= index
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    m_loc = jnp.max(s, axis=-1)  # [B,K,G,Sq]
    p = jnp.exp(s - m_loc[..., None])
    l_loc = jnp.sum(p, axis=-1)
    o_loc = jnp.einsum("bkgst,btkd->bkgsd", p, v.astype(jnp.float32))
    m = jax.lax.pmax(m_loc, "model")
    corr = jnp.where(m_loc > NEG_INF / 2, jnp.exp(m_loc - m), 0.0)
    l = jax.lax.psum(l_loc * corr, "model")
    o = jax.lax.psum(o_loc * corr[..., None], "model")
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(Bl, Sq, H, D)


def seq_sharded_decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    index: jax.Array,
    *,
    window: int = 0,
) -> jax.Array:
    """Sequence-parallel decode: KV cache seq dim sharded over ``model``.

    Each device computes partial attention over its KV shard; the partial
    softmax statistics (max, sum-exp) and weighted values are combined with a
    psum over the model axis (2-pass flash combine).  Falls back to
    ``decode_attention`` without a mesh.
    """
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.shape:
        return decode_attention(q, k_cache, v_cache, index, window=window)

    rules = current_rules()
    # caches are sharded over the kv_batch logical axis (decode rules may
    # replicate activations while caches stay batch-sharded)
    bspec = rules.resolve(("kv_batch",), mesh)
    batch_axes = bspec[0] if len(bspec) else None
    if batch_axes is not None:
        names = (batch_axes,) if isinstance(batch_axes, str) else batch_axes
        bsize = 1
        for a in names:
            bsize *= mesh.shape[a]
        if q.shape[0] % bsize:  # e.g. long_500k: global_batch=1
            batch_axes = None
    if k_cache.shape[1] % mesh.shape["model"]:
        return decode_attention(q, k_cache, v_cache, index, window=window)
    q_spec = P(batch_axes, None, None, None)
    kv_spec = P(batch_axes, "model", None, None)

    T = k_cache.shape[1]

    def body(q, k, v, index):
        return _seq_sharded_body(q, k, v, index, T, window=window)

    fn = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(q_spec, kv_spec, kv_spec, P()),
        out_specs=q_spec,
        check_vma=False,
    )
    return fn(q, k_cache, v_cache, index).astype(q.dtype)


# ---------------------------------------------------------------- caches


def cache_defs(
    cfg: ModelConfig, batch: int, max_seq: int, n_layers: int
) -> dict:
    """Stacked [L, B, T, K, D] KV cache defs for scanned attention layers.

    ``cfg.kv_cache_dtype == "int8"`` stores symmetric per-(token, head)
    quantized keys/values with fp32 scales — half the HBM of bf16 (scales
    are D x smaller), dequantized on read inside the attention math.
    """
    T = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    seq_axis = "sp" if cfg.decode_seq_shard and not cfg.sliding_window else None
    shape = (n_layers, batch, T, cfg.num_kv_heads, cfg.head_dim)
    axes = (None, "kv_batch", seq_axis, None, None)
    if cfg.kv_cache_dtype == "int8":
        sshape = shape[:-1]
        saxes = axes[:-1]
        return {
            "k": ParamDef(shape, jnp.int8, axes, "zeros"),
            "v": ParamDef(shape, jnp.int8, axes, "zeros"),
            "k_scale": ParamDef(sshape, jnp.float32, saxes, "zeros"),
            "v_scale": ParamDef(sshape, jnp.float32, saxes, "zeros"),
        }
    return {
        "k": ParamDef(shape, jnp.bfloat16, axes, "zeros"),
        "v": ParamDef(shape, jnp.bfloat16, axes, "zeros"),
    }


def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """[B,S,K,D] -> (int8 [B,S,K,D], scale f32 [B,S,K]) symmetric/head-vec."""
    x = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_kv(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[..., None]


def cache_update(
    cache_k: jax.Array,
    cache_v: jax.Array,
    k: jax.Array,
    v: jax.Array,
    index: jax.Array,
    *,
    window: int = 0,
):
    """Write k,v [B,S,K,D] into [B,T,K,D] caches at position ``index``."""
    T = cache_k.shape[1]
    if window > 0:
        pos = index % T
    else:
        pos = index
    B = cache_k.shape[0]
    k = k.astype(cache_k.dtype)
    v = v.astype(cache_v.dtype)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k, (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v, (0, pos, 0, 0))
    return cache_k, cache_v


def cache_update_tree(
    kv: dict,
    k: jax.Array,
    v: jax.Array,
    index: jax.Array,
    *,
    window: int = 0,
) -> dict:
    """Dict-cache update; quantizes on write for int8 caches."""
    T = kv["k"].shape[1]
    pos = index % T if window > 0 else index
    if "k_scale" in kv:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        return {
            "k": jax.lax.dynamic_update_slice(kv["k"], kq, (0, pos, 0, 0)),
            "v": jax.lax.dynamic_update_slice(kv["v"], vq, (0, pos, 0, 0)),
            "k_scale": jax.lax.dynamic_update_slice(
                kv["k_scale"], ks, (0, pos, 0)
            ),
            "v_scale": jax.lax.dynamic_update_slice(
                kv["v_scale"], vs, (0, pos, 0)
            ),
        }
    ck, cv = cache_update(kv["k"], kv["v"], k, v, index, window=window)
    return {"k": ck, "v": cv}


def _materialize_kv(kv: dict) -> tuple[jax.Array, jax.Array]:
    if "k_scale" in kv:
        return (
            dequantize_kv(kv["k"], kv["k_scale"]),
            dequantize_kv(kv["v"], kv["v_scale"]),
        )
    return kv["k"], kv["v"]


def decode_attention_tree(q, kv: dict, index, *, window: int = 0):
    kc, vc = _materialize_kv(kv)
    return decode_attention(q, kc, vc, index, window=window)


def seq_sharded_decode_attention_tree(q, kv: dict, index):
    """Sequence-parallel decode over a (possibly int8) dict cache.

    int8 path: dequantize INSIDE the shard_map body so only the int8 bytes
    (+ D x smaller scales) cross HBM; the fp32 view lives per-shard."""
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.shape:
        return decode_attention_tree(q, kv, index)
    if "k_scale" not in kv:
        return seq_sharded_decode_attention(q, kv["k"], kv["v"], index)
    if kv["k"].shape[1] % mesh.shape["model"]:
        return decode_attention_tree(q, kv, index)

    rules = current_rules()
    bspec = rules.resolve(("kv_batch",), mesh)
    batch_axes = bspec[0] if len(bspec) else None
    if batch_axes is not None:
        names = (batch_axes,) if isinstance(batch_axes, str) else batch_axes
        bsize = 1
        for a in names:
            bsize *= mesh.shape[a]
        if q.shape[0] % bsize:
            batch_axes = None
    T = kv["k"].shape[1]

    def body(q, kq, ks, vq, vs, index):
        k = dequantize_kv(kq, ks)
        v = dequantize_kv(vq, vs)
        return _seq_sharded_body(q, k, v, index, T, window=0)

    q_spec = P(batch_axes, None, None, None)
    kv_spec = P(batch_axes, "model", None, None)
    s_spec = P(batch_axes, "model", None)
    fn = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(q_spec, kv_spec, s_spec, kv_spec, s_spec, P()),
        out_specs=q_spec,
        check_vma=False,
    )
    return fn(q, kv["k"], kv["k_scale"], kv["v"], kv["v_scale"], index
              ).astype(q.dtype)
