"""Label-free drift detection over the flow-state window statistics.

Production traffic drifts; the compiler stages (DSE -> training ->
codegen) train offline.  This module is the trigger of the online-learning
loop (docs/pipeline_ir.md#hot-swap-contract): it watches the SAME packet
windows the serving engine micro-batches — the columns the
``RegisterUpdate`` stage folds into the per-flow window statistics — and
scores each window's feature means against a FROZEN training-time
snapshot.  Everything is incremental host-side numpy on buffers the
engine already holds at ``submit()`` time, so detection costs no extra
device launches and no labels.

The statistic: per-window column means, EWMA-smoothed across windows
(``ewma_j = (1-a)*ewma_j + a*mean_j``), scored as the max per-column
z-distance from the snapshot — where ``mu``/``sd`` are the mean and
spread of the per-window means over the TRAINING stream, so the threshold
is in units of the training distribution's own window-to-window
variability.  The detector fires after ``patience`` consecutive windows
above ``threshold``; single-window bursts (one elephant flow, one noisy
window) do not trip it.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DriftSnapshot:
    """Frozen reference: per-window feature-mean moments of the training
    stream.  ``cols`` names the packet columns the statistic watches."""

    mu: np.ndarray                 # [len(cols)] mean of per-window means
    sd: np.ndarray                 # [len(cols)] spread of per-window means
    cols: tuple

    @staticmethod
    def from_packets(packets: np.ndarray, *, cols, window: int
                     ) -> "DriftSnapshot":
        """Freeze a snapshot from the training stream's packet matrix:
        split into ``window``-sized chunks, take each chunk's column
        means, and record their mean/std.  Needs at least one full
        window; a shorter stream falls back to a single whole-stream
        window with unit spread (sane, never NaN)."""
        cols = tuple(int(c) for c in cols)
        pkts = np.asarray(packets, np.float32)
        n_win = len(pkts) // int(window)
        if n_win >= 1:
            means = np.stack([
                pkts[i * window:(i + 1) * window, cols].mean(0)
                for i in range(n_win)
            ])
        else:
            means = pkts[:, cols].mean(0, keepdims=True) if len(pkts) \
                else np.zeros((1, len(cols)), np.float32)
        mu = means.mean(0).astype(np.float32)
        sd = (means.std(0) if len(means) > 1
              else np.ones_like(mu)).astype(np.float32)
        return DriftSnapshot(mu, np.maximum(sd, 1e-6), cols)


class DriftDetector:
    """Incremental window-statistics drift monitor.

    Feed every submitted packet window through ``update`` (the
    ``HotSwapController`` does this alongside ``engine.submit``); read
    ``score`` / ``fired``.  ``reset()`` re-arms after a swap so the NEW
    model gets its own drift episode."""

    def __init__(self, snapshot: DriftSnapshot, *, alpha: float = 0.25,
                 threshold: float = 6.0, patience: int = 3):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.snapshot = snapshot
        self.alpha = float(alpha)
        self.threshold = float(threshold)
        self.patience = max(1, int(patience))
        self.reset()

    def reset(self) -> None:
        # start AT the reference: score 0 until real windows move it
        self._ewma = self.snapshot.mu.astype(np.float64).copy()
        self.score = 0.0
        self.windows = 0
        self._streak = 0
        self.fired = False

    def update(self, window: np.ndarray) -> float:
        """Fold one packet window into the statistic -> current score."""
        w = np.asarray(window, np.float32)
        if w.ndim == 1:
            w = w[None, :]
        if len(w) == 0:
            return self.score          # empty window: nothing to learn
        m = w[:, self.snapshot.cols].mean(0)
        a = self.alpha
        self._ewma = (1.0 - a) * self._ewma + a * m
        z = np.abs(self._ewma - self.snapshot.mu) / self.snapshot.sd
        self.score = float(z.max())
        self.windows += 1
        self._streak = self._streak + 1 if self.score > self.threshold \
            else 0
        if self._streak >= self.patience:
            self.fired = True
        return self.score

    def report(self) -> dict:
        return {
            "score": round(self.score, 3),
            "threshold": self.threshold,
            "windows": self.windows,
            "fired": self.fired,
        }
