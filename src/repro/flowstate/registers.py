"""Per-flow register file: the stateful half of a data-plane ML pipeline.

Real per-packet ML data planes (Taurus, Planter-style P4 targets) keep
per-flow registers — counters, EWMAs, windowed histograms — updated at line
rate, and classify on those registers instead of precomputed offline
features.  This module is that register file for the serving engine:

  * ``FlowStateSpec`` — the shape of one flow's state: a direct-indexed
    hash table with a FIXED slot count (power of two) whose rows hold
    ``n_counters`` accumulators, ``n_ewma`` exponential moving averages and
    one histogram section per entry of ``hist_sizes``;
  * ``FlowState`` — the live table: stored keys [S] (-1 = empty) plus
    register rows [S, W];
  * ``update_flows`` — one batched update through either execution engine
    (jnp scan reference, or the fused Pallas scatter/gather kernel in
    ``kernels/flow_update`` — bit-identical by construction).

Collision policy (see docs/pipeline_ir.md#flow-state-contract): slots are
direct-indexed by ``hash(key) & (S-1)``; a packet whose key differs from
the stored key EVICTS the resident flow — state resets to zero and the new
flow claims the slot (last-writer-wins).  This is the honest semantics of
a fixed-size switch register array: under slot pressure, long-lived flows
can be displaced, and accuracy degrades gracefully with table load rather
than the engine re-allocating memory mid-stream.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FlowStateSpec:
    """Shape of the per-flow register file.

    ``n_counters`` >= 1; counter 0 is by convention the packet count (the
    stage lowering always increments it by 1, and ``WindowStats`` uses it
    as the histogram normalizer).  ``hist_sizes`` lists the bin count of
    each histogram section; sections are laid out back to back after the
    EWMA block."""

    n_slots: int = 1024
    n_counters: int = 1
    n_ewma: int = 0
    hist_sizes: tuple = ()
    ewma_alpha: float = 0.125

    def __post_init__(self):
        if self.n_slots < 2 or self.n_slots & (self.n_slots - 1):
            raise ValueError(
                f"n_slots must be a power of two >= 2, got {self.n_slots}"
            )
        if self.n_counters < 1:
            raise ValueError("n_counters must be >= 1 (slot 0 = pkt count)")
        if any(int(h) < 1 for h in self.hist_sizes):
            raise ValueError("every histogram needs >= 1 bin")
        # shift-EWMA contract: a power-of-two alpha keeps both blend
        # products exact in f32, which is what makes the scan reference,
        # the segmented kernel and the fused kernel bit-identical no
        # matter how the compiler groups the multiply-adds (see
        # kernels.flow_update.ref.ewma_blend).
        a = float(self.ewma_alpha)
        if self.n_ewma and not (0.0 < a < 1.0 and math.frexp(a)[0] == 0.5):
            raise ValueError(
                "ewma_alpha must be a power of two in (0, 1) "
                f"(shift-EWMA contract), got {self.ewma_alpha}"
            )

    @property
    def width(self) -> int:
        """Register words per flow row (counters + EWMAs + hist bins)."""
        return self.n_counters + self.n_ewma + sum(self.hist_sizes)

    @property
    def hist_offsets(self) -> tuple:
        """Absolute start column of each histogram section."""
        offs, base = [], self.n_counters + self.n_ewma
        for h in self.hist_sizes:
            offs.append(base)
            base += int(h)
        return tuple(offs)

    @property
    def sram_bytes(self) -> int:
        """Table footprint: rows plus the stored-key word per slot — what
        feasibility charges against the target's register budget."""
        return self.n_slots * (self.width + 1) * 4


@dataclasses.dataclass
class FlowState:
    """The live register file; arrays are treated as immutable (every
    update returns a new FlowState over fresh buffers)."""

    spec: FlowStateSpec
    keys: jax.Array    # [S] int32 stored flow key, -1 = empty slot
    regs: jax.Array    # [S, W] f32 register rows

    @property
    def occupied(self) -> int:
        return int(np.sum(np.asarray(self.keys) >= 0))


def init_state(spec: FlowStateSpec) -> FlowState:
    return FlowState(
        spec,
        jnp.full((spec.n_slots,), -1, jnp.int32),
        jnp.zeros((spec.n_slots, spec.width), jnp.float32),
    )


@dataclasses.dataclass
class MultiFlowState:
    """Live state of a MULTI-TABLE stateful pipeline: several FlowKey /
    RegisterUpdate tables feeding one classifier (the multi-table DAG
    form), plus an optional mitigation action table.

    ``spec`` / ``keys`` / ``regs`` alias table 0 so single-table readers —
    the telemetry health probe, engine stats, reprs — keep working on the
    primary table; per-table access goes through the ``*_list`` tuples."""

    specs: tuple               # of FlowStateSpec, one per table
    keys_list: tuple           # of [S_t] int32 stored keys (-1 = empty)
    regs_list: tuple           # of [S_t, W_t] f32 register rows
    mit_spec: object = None    # mitigation.MitigationSpec | None
    mit_keys: jax.Array = None
    mit_regs: jax.Array = None

    @property
    def spec(self) -> FlowStateSpec:
        return self.specs[0]

    @property
    def keys(self) -> jax.Array:
        return self.keys_list[0]

    @property
    def regs(self) -> jax.Array:
        return self.regs_list[0]

    @property
    def occupied(self) -> int:
        """Occupied slots summed over every table."""
        return int(sum(np.sum(np.asarray(k) >= 0) for k in self.keys_list))

    @property
    def mitigated_flows(self) -> int:
        """Action-table slots currently marked (hits >= threshold)."""
        if self.mit_spec is None:
            return 0
        mk = np.asarray(self.mit_keys)
        hits = np.asarray(self.mit_regs)[:, 0]
        return int(np.sum((mk >= 0) & (hits >= self.mit_spec.threshold)))


def hash_slot_np(keys: np.ndarray, n_slots: int) -> np.ndarray:
    """Numpy twin of ``kernels.flow_update.ref.hash_slot`` — same Knuth
    multiplicative mix, same xor-fold — for host-side table migration.
    Pinned equal to the traceable form in tests/test_hot_swap.py."""
    with np.errstate(over="ignore"):
        h = np.asarray(keys).astype(np.uint32) * np.uint32(2654435761)
    h = h ^ (h >> np.uint32(16))
    return (h & np.uint32(n_slots - 1)).astype(np.int32)


def migrate_state(state: FlowState, new_spec: FlowStateSpec) -> FlowState:
    """The documented re-key path for a hot swap that CHANGES the spec
    (docs/pipeline_ir.md#hot-swap-contract).  Same-spec swaps never come
    here — the live table carries over bit-identically.

    Every occupied row is re-hashed into the new table (``hash_slot`` over
    ``new_spec.n_slots``), walking slots in ascending order with the table's
    own collision policy: two old flows landing on one new slot resolve
    last-writer-wins, exactly as live eviction would.  Register columns
    carry over section by section — the shared prefix of counters, the
    shared prefix of EWMAs, and each histogram section up to the smaller
    bin count — anything the new spec adds starts at zero, anything it
    drops is discarded.  This is a host-side control-plane operation (one
    table scan), not a per-packet path."""
    old = state.spec
    keys = np.asarray(state.keys)
    regs = np.asarray(state.regs)
    out_k = np.full((new_spec.n_slots,), -1, np.int32)
    out_r = np.zeros((new_spec.n_slots, new_spec.width), np.float32)

    # (old column, new column) pairs of the shared layout sections
    pairs: list[tuple[int, int]] = []
    for j in range(min(old.n_counters, new_spec.n_counters)):
        pairs.append((j, j))
    for j in range(min(old.n_ewma, new_spec.n_ewma)):
        pairs.append((old.n_counters + j, new_spec.n_counters + j))
    for h, (o_off, n_off) in enumerate(
        zip(old.hist_offsets, new_spec.hist_offsets)
    ):
        for j in range(min(old.hist_sizes[h], new_spec.hist_sizes[h])):
            pairs.append((o_off + j, n_off + j))
    o_cols = np.array([p[0] for p in pairs], np.int64)
    n_cols = np.array([p[1] for p in pairs], np.int64)

    occupied = np.flatnonzero(keys >= 0)      # ascending slot order
    slots = hash_slot_np(keys[occupied], new_spec.n_slots)
    for i, s in zip(occupied, slots):         # last-writer-wins collisions
        out_k[s] = keys[i]
        out_r[s] = 0.0
        out_r[s, n_cols] = regs[i, o_cols]
    return FlowState(new_spec, jnp.asarray(out_k), jnp.asarray(out_r))


def update_flows(
    state: FlowState,
    pkt_keys,              # [B] int32 flow key per packet (>= 0)
    upd,                   # [B, C+E] counter increments ++ EWMA values
    bins=None,             # [B, H] absolute hist columns (-1 = none)
    valid=None,            # [B] 0 = padding row, skipped
    *,
    backend: str = "interpret",
) -> tuple[FlowState, jax.Array]:
    """One batched register update -> (new state, per-packet feature rows).

    ``backend="pallas"`` runs the fused scatter/gather kernel (one launch,
    table resident in VMEM); ``"interpret"`` the jitted jnp scan.  Both are
    bit-identical (shared per-packet step) and preserve arrival order."""
    from repro.kernels import flow_update as fu

    spec = state.spec
    B = int(np.shape(pkt_keys)[0])
    if bins is None:
        bins = jnp.full((B, 1), -1, jnp.int32)
    if valid is None:
        valid = jnp.ones((B,), jnp.int32)
    fn = fu.flow_update if backend == "pallas" else fu.flow_update_ref
    keys, regs, feats = fn(
        state.keys, state.regs, jnp.asarray(pkt_keys, jnp.int32),
        jnp.asarray(upd, jnp.float32), jnp.asarray(bins, jnp.int32),
        jnp.asarray(valid, jnp.int32),
        n_counters=spec.n_counters, n_ewma=spec.n_ewma,
        alpha=spec.ewma_alpha,
    )
    return FlowState(spec, keys, regs), feats
