"""In-pipeline mitigation: per-flow drop / rate-limit action registers.

Detection alone is half a data-plane ML pipeline; the paper's operators
act on verdicts at line rate.  This module is the action half: a second,
tiny register file (the ACTION TABLE) keyed by the same FNV flow key the
detection table uses, fed by the classifier's verdict stream.  Once a
flow accumulates ``threshold`` positive verdicts its slot is *marked*,
and every later packet of that flow is dropped (``mode="drop"``) or
rate-limited (``mode="rate_limit"``: every ``keep_every``-th packet
passes through and keeps being classified, the rest are dropped).

A dropped packet's verdict is replaced by the sentinel ``MITIGATED``
(-1) — by construction **no packet is ever both dropped and verdicted**,
and the packet that trips the threshold is itself verdicted, not dropped
(the state *before* a packet decides its fate), so the mitigation lag is
always >= 1 packet.

Layout (mirrors ``registers.FlowState``): stored keys [S] int32 with -1
= empty, register rows [S, 2] f32 — column 0 counts positive verdicts
(*hits*), column 1 counts packets since the slot was marked (*since*,
the rate-limit phase).  Same direct-indexed hash (``hash_slot``), same
evict-on-collision / last-writer-wins policy, same arrival-order
batch-scan semantics as the detection table — and the same honest SRAM
accounting (``MitigationSpec.sram_bytes`` is charged by
``feasibility.mitigation_report``).

The batch scan is ORDER-DEPENDENT (a later packet may evict an earlier
packet's slot), so the reference here runs as a ``fori_loop`` over the
batch — shared jnp code on every execution engine.  Under
``backend="pallas"`` the action table FOLDS INTO the fused flow launch
(``kernels/fused_flow._mitigation_phase``: the [hits, since] row rides
the same segmented lockstep-rounds + drain schedule as the detection
table, the drop decision is one masked lane over the int32 verdicts), so
a mitigated pipeline reports ``"pallas-fused-flow"``; slots never
interact, so the fused phase is bit-identical to this scan by the same
per-slot decomposition that pins the flow tables.  When the rest of the
pipeline is outside the fused envelope, this scan serves as the split
fallback and ``StatefulPipeline`` reports the composite engine honestly
(``"mixed"``).  See docs/pipeline_ir.md#mitigation-contract.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flow_update.ref import hash_slot
from repro.flowstate.registers import FlowStateSpec, hash_slot_np

# verdict sentinel for a dropped packet: the packet never produced a
# verdict — the engine's output vocabulary becomes {MITIGATED} + classes
MITIGATED = -1

MITIGATION_MODES = ("drop", "rate_limit")

# action-table row layout: [hits, since]
MIT_WIDTH = 2


@dataclasses.dataclass(frozen=True)
class MitigationSpec:
    """Shape + policy of the per-flow action table.

    ``threshold`` positive verdicts (class ``attack_class``) mark a
    flow's slot; ``mode="drop"`` then drops every later packet,
    ``mode="rate_limit"`` passes every ``keep_every``-th packet through
    (it keeps being classified — the pass-through cadence is what lets a
    rate-limited flow keep feeding the detector)."""

    n_slots: int = 1024
    mode: str = "drop"
    threshold: int = 3
    keep_every: int = 8
    attack_class: int = 1

    def __post_init__(self):
        if self.n_slots < 2 or self.n_slots & (self.n_slots - 1):
            raise ValueError(
                f"n_slots must be a power of two >= 2, got {self.n_slots}"
            )
        if self.mode not in MITIGATION_MODES:
            raise KeyError(
                f"mode must be one of {MITIGATION_MODES}, got {self.mode!r}"
            )
        if self.threshold < 1:
            raise ValueError("threshold must be >= 1")
        if self.keep_every < 2:
            raise ValueError("keep_every must be >= 2 (1 would disable "
                             "rate limiting entirely)")

    @property
    def width(self) -> int:
        """Register words per action row ([hits, since])."""
        return MIT_WIDTH

    @property
    def sram_bytes(self) -> int:
        """Stored key + row words per slot — what feasibility charges."""
        return self.n_slots * (self.width + 1) * 4


def init_mitigation(spec: MitigationSpec) -> tuple[jax.Array, jax.Array]:
    """Fresh empty action table -> (mit_keys [S], mit_regs [S, 2])."""
    return (jnp.full((spec.n_slots,), -1, jnp.int32),
            jnp.zeros((spec.n_slots, MIT_WIDTH), jnp.float32))


@dataclasses.dataclass
class MitigatedFlowState:
    """Detection register file + action table, threaded as one state.

    The flow fields keep the ``FlowState`` names (``spec``/``keys``/
    ``regs``) so everything that reads a stateful engine's table — the
    sharded router, migrate paths, stats — works unchanged."""

    spec: FlowStateSpec
    keys: jax.Array        # [S] int32 detection table keys
    regs: jax.Array        # [S, W] f32 detection rows
    mit_spec: MitigationSpec
    mit_keys: jax.Array    # [Sm] int32 action-table keys, -1 = empty
    mit_regs: jax.Array    # [Sm, 2] f32 [hits, since]

    @property
    def occupied(self) -> int:
        return int(np.sum(np.asarray(self.keys) >= 0))

    @property
    def mitigated_flows(self) -> int:
        """Action-table slots currently marked (hits >= threshold)."""
        mk = np.asarray(self.mit_keys)
        hits = np.asarray(self.mit_regs)[:, 0]
        return int(np.sum((mk >= 0) & (hits >= self.mit_spec.threshold)))


def mitigate_update(
    mit_keys: jax.Array,   # [S] int32 stored keys (-1 = empty)
    mit_regs: jax.Array,   # [S, 2] f32 [hits, since]
    pkt_keys: jax.Array,   # [B] int32 flow key per packet
    verdicts: jax.Array,   # [B] int32 classifier verdicts
    valid: jax.Array,      # [B] 0 = padding row, skipped
    *,
    spec: MitigationSpec,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One batched action-table update -> (keys', regs', out_verdicts).

    Per packet, in arrival order: the slot's state BEFORE the packet
    decides — a marked slot drops (or rate-limits) the packet and its
    output verdict becomes ``MITIGATED``; an unmarked slot passes the
    classifier verdict through.  Then the row updates: ``hits`` grows by
    one when the verdict is ``attack_class`` (dropped packets still
    count — the detector already saw them), ``since`` counts packets
    while marked.  Padding rows never touch the table and keep their
    (meaningless) verdicts.  Traceable/jittable; shared by every
    execution engine, hence bit-identical across backends."""
    S = int(mit_keys.shape[0])
    B = int(pkt_keys.shape[0])
    pk = jnp.asarray(pkt_keys, jnp.int32)
    vd = jnp.asarray(verdicts, jnp.int32)
    ok = jnp.asarray(valid, jnp.int32) != 0
    slots = hash_slot(pk, S)
    thr = jnp.float32(spec.threshold)
    keep = jnp.float32(spec.keep_every)
    drop_mode = spec.mode == "drop"

    def body(p, carry):
        keys, regs, out = carry
        slot = slots[p]
        key = pk[p]
        stored = jax.lax.dynamic_slice(keys, (slot,), (1,))[0]
        row = jax.lax.dynamic_slice(regs, (slot, 0), (1, MIT_WIDTH))[0]

        # evict-on-collision: empty (-1) or different flow -> fresh row
        fresh = stored != key
        row0 = jnp.where(fresh, jnp.zeros_like(row), row)
        hits0, since0 = row0[0], row0[1]

        marked0 = hits0 >= thr
        if drop_mode:
            drop = marked0
        else:
            # pass every keep_every-th packet of a marked flow through
            drop = marked0 & (jnp.mod(since0, keep) != 0.0)
        v = vd[p]
        out_v = jnp.where(drop, jnp.int32(MITIGATED), v)

        hits1 = hits0 + (v == jnp.int32(spec.attack_class)).astype(
            jnp.float32)
        since1 = jnp.where(marked0, since0 + 1.0, 0.0)
        new_row = jnp.stack([hits1, since1])

        o = ok[p]
        keys = jax.lax.dynamic_update_slice(
            keys, jnp.where(o, key, stored)[None], (slot,))
        regs = jax.lax.dynamic_update_slice(
            regs, jnp.where(o, new_row, row)[None, :], (slot, 0))
        out = out.at[p].set(jnp.where(o, out_v, v))
        return keys, regs, out

    keys, regs, out = jax.lax.fori_loop(
        0, B, body,
        (jnp.asarray(mit_keys, jnp.int32),
         jnp.asarray(mit_regs, jnp.float32), vd),
    )
    return keys, regs, out


def migrate_mitigation(mit_keys, mit_regs, old_spec: MitigationSpec,
                       new_spec: MitigationSpec
                       ) -> tuple[jax.Array, jax.Array]:
    """Re-key the action table for a hot swap that CHANGES the mitigation
    spec — the same host-side control-plane scan as
    ``registers.migrate_state``: occupied rows re-hash into the new table
    in ascending slot order, colliding rows resolve last-writer-wins.
    The row layout is fixed ([hits, since]), so rows carry verbatim; a
    changed ``threshold``/``mode`` re-interprets the carried counts from
    the next packet on (a marked flow stays marked iff its carried hits
    clear the new threshold)."""
    del old_spec  # row layout is spec-independent; only n_slots re-keys
    keys = np.asarray(mit_keys)
    regs = np.asarray(mit_regs)
    out_k = np.full((new_spec.n_slots,), -1, np.int32)
    out_r = np.zeros((new_spec.n_slots, MIT_WIDTH), np.float32)
    occupied = np.flatnonzero(keys >= 0)      # ascending slot order
    slots = hash_slot_np(keys[occupied], new_spec.n_slots)
    for i, s in zip(occupied, slots):         # last-writer-wins collisions
        out_k[s] = keys[i]
        out_r[s] = regs[i]
    return jnp.asarray(out_k), jnp.asarray(out_r)
