"""Stateful pipeline compilation: flow registers + classifier in ONE jit.

``StatefulPipeline`` is the serving artifact for a stage list that starts
with the stateful prefix ``[FlowKey, RegisterUpdate]`` (core.stageir): per
fixed-shape batch it derives flow keys, updates the register file, reads
each packet's post-update feature row, and runs the stateless classifier
suffix — all inside one jitted step, so steady-state serving never
re-traces and the register state threads through as explicit arrays (no
Python-side mutation).

A trailing ``Mitigate`` stage (docs/pipeline_ir.md#mitigation-contract)
closes the loop: the classifier's verdicts feed a per-flow action table
keyed by the same flow key, and marked flows' packets come back as
``mitigation.MITIGATED`` instead of a verdict.  The action table threads
through the SAME jitted step as two extra state arrays
(``MitigatedFlowState``), so mitigation inherits every serving guarantee
— arrival order, overlap safety, hot-swap state carry.

Backend selection mirrors the stateless contract
(docs/pipeline_ir.md#flow-state-contract):

  * under ``backend="pallas"`` the WHOLE pipeline lowers onto the
    single-launch fused kernel (kernels/fused_flow) when the
    post-peephole suffix matches the fused envelope — register table and
    classifier weights co-resident in VMEM, feature rows never touching
    HBM — reported as ``"pallas-fused-flow"``;
  * otherwise the PREFIX lowers onto the flow-update Pallas kernel
    (kernels/flow_update) when the table fits the kernel envelope, else
    the jnp scan reference — bit-identical either way;
  * and the SUFFIX lowers through
    ``core.pallas_backend.lower_stages_pallas`` under the existing Pallas
    lowering contract, else the jitted stage walk.

``backend`` reports what actually serves: ``"pallas-fused-flow"`` for
the single launch, ``"pallas"`` when both parts lowered separately,
``"interpret"`` when neither did, ``"mixed"`` otherwise — never the
engine that was merely requested.  The mitigation scan has no Pallas
lowering (``pallas_backend.lower_mitigation`` always serves
``"interpret"``), so a mitigated pipeline whose detection half runs on
Pallas reports ``"mixed"`` — honest composite reporting.
"""

from __future__ import annotations

import numpy as np

from repro.core import stageir
from repro.flowstate.registers import (
    FlowState,
    FlowStateSpec,
    init_state,
    migrate_state,
)


class StatefulPipeline:
    """Compiled stateful serving pipeline.

    Callable as ``state', verdicts = pipe(state, X, valid=None)`` where
    ``X`` is a [B, F] packet batch and ``valid`` masks ragged-batch
    padding rows (masked rows never touch the register file and their
    verdicts are meaningless — the engine slices them off).  Rows are
    applied in arrival order; see the flow-state contract for the
    eviction/ordering guarantees."""

    def __init__(self, stages: list[stageir.Stage], *,
                 backend: str = "interpret", fuse: bool = True):
        if backend not in stageir.EXEC_BACKENDS:
            raise KeyError(f"backend must be one of {stageir.EXEC_BACKENDS}")
        import jax

        from repro.core import pallas_backend

        self.stages = list(stages)
        self.requested_backend = backend
        self.fuse = bool(fuse)
        rest, mit = stageir.split_mitigation(self.stages)
        prefix, suffix = stageir.split_stateful(rest)
        self.spec: FlowStateSpec = prefix[1].spec
        self.mitigation = mit.spec if mit is not None else None
        self.feature_dim = None          # any F the key/update cols allow

        run_suffix = (stageir.fuse_pipeline_stages(suffix) if fuse
                      else list(suffix))

        # single-launch form first: the whole detection pipeline as ONE
        # Pallas kernel (kernels/fused_flow) when backend="pallas" and the
        # post-peephole suffix matches the fused envelope — bit-identical
        # to the two-dispatch composition below by the flow-state
        # contract, reported honestly as "pallas-fused-flow"
        step = None
        self.fused = False
        if backend == "pallas" and fuse:
            step = pallas_backend.lower_stateful_fused(prefix, run_suffix)
        if step is not None:
            self.fused = True
            self.flow_backend = self.classifier_backend = "pallas"
        else:
            flow_fn, self.flow_backend = pallas_backend.lower_stateful(
                prefix, backend
            )
            suffix_fn = None
            if backend == "pallas" and run_suffix:
                suffix_fn = pallas_backend.lower_stages_pallas(run_suffix)
            self.classifier_backend = ("pallas" if suffix_fn is not None
                                       else "interpret")
            if suffix_fn is None:
                def suffix_fn(feats, _s=run_suffix):
                    return stageir.apply_stages(_s, feats)

            def step(keys, regs, x, valid, _flow=flow_fn, _cls=suffix_fn):
                keys, regs, feats = _flow(keys, regs, x, valid)
                return keys, regs, _cls(feats)

        if mit is not None:
            # the action table appends two more state arrays and the
            # verdict rewrite to the very same jitted step: the flow key
            # is re-derived from the packet rows (cheap vectorized FNV),
            # so detection and action tables stay keyed identically
            mit_fn, self.mitigation_backend = \
                pallas_backend.lower_mitigation(mit)
            base = step

            def step(keys, regs, mkeys, mregs, x, valid, _base=base,
                     _mit=mit_fn, _fk=prefix[0]):
                keys, regs, v = _base(keys, regs, x, valid)
                mkeys, mregs, v = _mit(mkeys, mregs, _fk.apply_keys(x),
                                       v, valid)
                return keys, regs, mkeys, mregs, v
        else:
            self.mitigation_backend = None

        # the raw traceable step: what ShardedPacketServeEngine wraps in
        # shard_map over per-device register tables
        self.step_fn = step
        # donate the register buffers on accelerator backends: the update
        # rewrites the whole table every step, so the input buffers are
        # dead the moment the step is dispatched — steady-state serving
        # then allocates no new table per batch.  (No-op on CPU, where XLA
        # does not support donation; callers must treat a dispatched-into
        # FlowState as consumed — the engine always adopts the returned
        # state.)
        donate = (tuple(range(self.n_state_arrays))
                  if jax.default_backend() != "cpu" else ())
        self._step = jax.jit(step, donate_argnums=donate)
        self._ones_valid: dict[int, object] = {}  # per-batch-size cache

    @property
    def n_state_arrays(self) -> int:
        """Leading state arrays of ``step_fn``: (keys, regs) plus the
        action table's (mit_keys, mit_regs) when mitigation is on — what
        the sharded engine partitions per device."""
        return 4 if self.mitigation is not None else 2

    @property
    def backend(self) -> str:
        """The engine that actually serves, after any fallback:
        ``"pallas-fused-flow"`` when the whole pipeline runs as one
        kernel launch, else ``"pallas"``/``"interpret"``/``"mixed"`` for
        the two-dispatch composition.  The interpret-only mitigation
        scan counts as one of the parts — a Pallas detection half plus
        mitigation reports ``"mixed"``."""
        kinds = {self.flow_backend, self.classifier_backend}
        if self.mitigation_backend is not None:
            kinds.add(self.mitigation_backend)
        if self.fused and len(kinds) == 1:
            return "pallas-fused-flow"
        if self.fused:
            return "mixed"
        return kinds.pop() if len(kinds) == 1 else "mixed"

    def with_backend(self, backend: str) -> "StatefulPipeline":
        """Recompile for another engine (what PacketServeEngine's
        ``backend=`` uses).  Preserves the ``fuse`` flag — an unfused
        pipeline must not silently come back fused."""
        return StatefulPipeline(self.stages, backend=backend,
                                fuse=self.fuse)

    def init_state(self):
        if self.mitigation is None:
            return init_state(self.spec)
        from repro.flowstate.mitigation import (
            MitigatedFlowState,
            init_mitigation,
        )

        base = init_state(self.spec)
        mk, mr = init_mitigation(self.mitigation)
        return MitigatedFlowState(self.spec, base.keys, base.regs,
                                  self.mitigation, mk, mr)

    def adopt_state(self, state):
        """Carry another pipeline's live state into THIS pipeline's state
        shape — the hot-swap install path (both engines call this).

        Detection table: same spec carries the arrays bit-identically;
        a changed spec migrates through the documented re-key path
        (``registers.migrate_state``).  Action table: same mitigation
        spec carries bit-identically (marked flows stay marked across the
        swap); a changed spec re-keys (``mitigation.migrate_mitigation``);
        swapping mitigation IN starts an empty table; swapping it OUT
        drops the table (the engine stops enforcing)."""
        if getattr(state, "spec", None) is None:
            return state                 # opaque state: engine's problem
        if state.spec == self.spec:
            keys, regs = state.keys, state.regs
        else:
            m = migrate_state(FlowState(state.spec, state.keys, state.regs),
                              self.spec)
            keys, regs = m.keys, m.regs
        if self.mitigation is None:
            return FlowState(self.spec, keys, regs)
        from repro.flowstate.mitigation import (
            MitigatedFlowState,
            init_mitigation,
            migrate_mitigation,
        )

        old_mit = getattr(state, "mit_spec", None)
        if old_mit is None:
            mk, mr = init_mitigation(self.mitigation)
        elif old_mit == self.mitigation:
            mk, mr = state.mit_keys, state.mit_regs
        else:
            mk, mr = migrate_mitigation(state.mit_keys, state.mit_regs,
                                        old_mit, self.mitigation)
        return MitigatedFlowState(self.spec, keys, regs, self.mitigation,
                                  mk, mr)

    def dispatch(self, state, X, valid=None):
        """Launch one step WITHOUT forcing the device->host copy: returns
        ``(state', verdict_device_array)``.  The async serving path
        (PacketServeEngine depth>1) chains dispatches through the returned
        state — the state dependency sequentializes in-flight batches —
        and materializes verdicts lazily at flush time."""
        import jax.numpy as jnp

        X = jnp.asarray(X, jnp.float32)
        if valid is None:
            B = int(X.shape[0])
            valid = self._ones_valid.get(B)
            if valid is None:       # device-resident, reused every step
                valid = self._ones_valid.setdefault(
                    B, jnp.ones((B,), jnp.int32))
        valid = jnp.asarray(valid, jnp.int32)
        if self.mitigation is None:
            keys, regs, verdicts = self._step(state.keys, state.regs, X,
                                              valid)
            return FlowState(self.spec, keys, regs), verdicts
        from repro.flowstate.mitigation import MitigatedFlowState

        keys, regs, mk, mr, verdicts = self._step(
            state.keys, state.regs, state.mit_keys, state.mit_regs, X,
            valid,
        )
        return (MitigatedFlowState(self.spec, keys, regs, self.mitigation,
                                   mk, mr), verdicts)

    def __call__(self, state, X, valid=None):
        state, verdicts = self.dispatch(state, X, valid)
        return state, np.asarray(verdicts)

    def __repr__(self):
        mit = (f", mitigation={self.mitigation.mode!r}"
               if self.mitigation is not None else "")
        return (f"StatefulPipeline(slots={self.spec.n_slots}, "
                f"width={self.spec.width}, backend={self.backend!r}, "
                f"flow={self.flow_backend!r}, "
                f"classifier={self.classifier_backend!r}{mit})")
