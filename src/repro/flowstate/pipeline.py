"""Stateful pipeline compilation: flow registers + classifier in ONE jit.

``StatefulPipeline`` is the serving artifact for a stage list that starts
with the stateful prefix ``[FlowKey, RegisterUpdate]`` (core.stageir) —
or, in the multi-table DAG form, SEVERAL such groups feeding one
classifier: per fixed-shape batch it derives flow keys, updates the
register file(s), reads each packet's post-update feature row(s), and
runs the stateless classifier suffix — all inside one jitted step, so
steady-state serving never re-traces and the register state threads
through as explicit arrays (no Python-side mutation).

A trailing ``Mitigate`` stage (docs/pipeline_ir.md#mitigation-contract)
closes the loop: the classifier's verdicts feed a per-flow action table
keyed by the same flow key (the FIRST table's key in the multi-table
form), and marked flows' packets come back as ``mitigation.MITIGATED``
instead of a verdict.  The action table threads through the SAME jitted
step as two extra state arrays, so mitigation inherits every serving
guarantee — arrival order, overlap safety, hot-swap state carry.

Backend selection mirrors the stateless contract
(docs/pipeline_ir.md#flow-state-contract):

  * under ``backend="pallas"`` the WHOLE pipeline — every table, the
    classifier (MLP / MAT / centroid suffixes) AND the mitigation action
    table — lowers onto the single-launch fused kernel
    (kernels/fused_flow) when it matches the fused envelope, reported as
    ``"pallas-fused-flow"``; when it declines, ``fallback_reason`` keeps
    the honest reason string (surfaced by the engines' stats/journal);
  * otherwise each PREFIX lowers onto the flow-update Pallas kernel
    (kernels/flow_update) when its table fits the kernel envelope, else
    the jnp scan reference — bit-identical either way;
  * and the SUFFIX lowers through
    ``core.pallas_backend.lower_stages_pallas`` under the existing Pallas
    lowering contract, else the jitted stage walk.

``backend`` reports what actually serves: ``"pallas-fused-flow"`` for
the single launch (mitigated or not), ``"pallas"`` when the split parts
all lowered, ``"interpret"`` when none did, ``"mixed"`` otherwise —
never the engine that was merely requested.  On the split path the
mitigation scan runs as shared jnp (``lower_mitigation`` serves
``"interpret"``), so a split-path mitigated pipeline whose detection
half runs on Pallas reports ``"mixed"``.
"""

from __future__ import annotations

import numpy as np

from repro.core import stageir
from repro.flowstate.registers import (
    FlowState,
    FlowStateSpec,
    MultiFlowState,
    init_state,
    migrate_state,
)


class StatefulPipeline:
    """Compiled stateful serving pipeline.

    Callable as ``state', verdicts = pipe(state, X, valid=None)`` where
    ``X`` is a [B, F] packet batch and ``valid`` masks ragged-batch
    padding rows (masked rows never touch the register file and their
    verdicts are meaningless — the engine slices them off).  Rows are
    applied in arrival order; see the flow-state contract for the
    eviction/ordering guarantees."""

    def __init__(self, stages: list[stageir.Stage], *,
                 backend: str = "interpret", fuse: bool = True):
        if backend not in stageir.EXEC_BACKENDS:
            raise KeyError(f"backend must be one of {stageir.EXEC_BACKENDS}")
        import jax

        from repro.core import pallas_backend

        self.stages = list(stages)
        self.requested_backend = backend
        self.fuse = bool(fuse)
        rest, mit = stageir.split_mitigation(self.stages)
        n_fk = sum(isinstance(s, stageir.FlowKey) for s in rest)
        if n_fk > 1:
            groups, suffix = stageir.split_stateful_multi(rest)
            fused_prefix = groups
        else:
            prefix, suffix = stageir.split_stateful(rest)
            groups = [(prefix[0], prefix[1], None)]
            fused_prefix = prefix
        self.groups = groups
        self.n_tables = len(groups)
        self.specs: tuple = tuple(g[1].spec for g in groups)
        self.spec: FlowStateSpec = self.specs[0]
        self.mitigation = mit.spec if mit is not None else None
        self.feature_dim = None          # any F the key/update cols allow

        run_suffix = (stageir.fuse_pipeline_stages(suffix) if fuse
                      else list(suffix))

        # single-launch form first: the whole pipeline — every table, the
        # classifier AND the action table — as ONE Pallas kernel
        # (kernels/fused_flow) when backend="pallas" and the post-peephole
        # shape matches the fused envelope.  Bit-identical to the split
        # composition below by the flow-state + mitigation contracts,
        # reported honestly as "pallas-fused-flow"; on decline,
        # `fallback_reason` keeps the honest reason string.
        step = None
        self.fused = False
        self.fallback_reason: str | None = None
        if backend == "pallas" and fuse:
            step = pallas_backend.lower_stateful_fused(
                fused_prefix, run_suffix, mit)
            if step is None:
                self.fallback_reason = \
                    pallas_backend.fused_flow_decline_reason(
                        fused_prefix, run_suffix, mit)
        if step is not None:
            self.fused = True
            self.flow_backend = self.classifier_backend = "pallas"
            self.mitigation_backend = ("pallas" if mit is not None
                                       else None)
        else:
            flows = [
                pallas_backend.lower_stateful([fk, ru], backend)
                for fk, ru, _ in groups
            ]
            flow_kinds = {kind for _, kind in flows}
            self.flow_backend = (flow_kinds.pop() if len(flow_kinds) == 1
                                 else "mixed")
            suffix_fn = None
            if backend == "pallas" and run_suffix:
                suffix_fn = pallas_backend.lower_stages_pallas(run_suffix)
            self.classifier_backend = ("pallas" if suffix_fn is not None
                                       else "interpret")
            if suffix_fn is None:
                def suffix_fn(feats, _s=run_suffix):
                    return stageir.apply_stages(_s, feats)

            import jax.numpy as jnp

            readouts = tuple(g[2] for g in groups)  # WindowStats | None

            def step(*args, _flows=tuple(f for f, _ in flows),
                     _ws=readouts, _cls=suffix_fn):
                x, valid = args[-2], args[-1]
                outs, zs = [], []
                for t, flow in enumerate(_flows):
                    k2, r2, feats = flow(args[2 * t], args[2 * t + 1],
                                         x, valid)
                    outs += [k2, r2]
                    zs.append(_ws[t].apply(feats) if _ws[t] is not None
                              else feats)
                z = zs[0] if len(zs) == 1 else jnp.concatenate(zs, 1)
                return (*outs, _cls(z))

            if mit is not None:
                # split fallback: the action table appends two more state
                # arrays and the verdict rewrite to the very same jitted
                # step — the flow key is re-derived from the packet rows
                # (cheap vectorized FNV), so detection and action tables
                # stay keyed identically
                mit_fn, self.mitigation_backend = \
                    pallas_backend.lower_mitigation(mit)
                base = step

                def step(*args, _base=base, _mit=mit_fn,
                         _fk=groups[0][0]):
                    x, valid = args[-2], args[-1]
                    mkeys, mregs = args[-4], args[-3]
                    out = _base(*args[:-4], x, valid)
                    mkeys, mregs, v = _mit(mkeys, mregs,
                                           _fk.apply_keys(x), out[-1],
                                           valid)
                    return (*out[:-1], mkeys, mregs, v)
            else:
                self.mitigation_backend = None

        # the raw traceable step: what ShardedPacketServeEngine wraps in
        # shard_map over per-device register tables
        self.step_fn = step
        # donate the register buffers on accelerator backends: the update
        # rewrites the whole table every step, so the input buffers are
        # dead the moment the step is dispatched — steady-state serving
        # then allocates no new table per batch.  (No-op on CPU, where XLA
        # does not support donation; callers must treat a dispatched-into
        # FlowState as consumed — the engine always adopts the returned
        # state.)
        donate = (tuple(range(self.n_state_arrays))
                  if jax.default_backend() != "cpu" else ())
        self._step = jax.jit(step, donate_argnums=donate)
        self._ones_valid: dict[int, object] = {}  # per-batch-size cache

    @property
    def n_state_arrays(self) -> int:
        """Leading state arrays of ``step_fn``: (keys, regs) per table
        plus the action table's (mit_keys, mit_regs) when mitigation is
        on — what the sharded engine partitions per device."""
        return 2 * self.n_tables + (2 if self.mitigation is not None else 0)

    @property
    def backend(self) -> str:
        """The engine that actually serves, after any fallback:
        ``"pallas-fused-flow"`` when the whole pipeline (mitigation
        included) runs as one kernel launch, else ``"pallas"`` /
        ``"interpret"`` / ``"mixed"`` for the split composition.  On the
        split path the interpret-only mitigation scan counts as one of
        the parts — a Pallas detection half plus scan mitigation reports
        ``"mixed"``."""
        kinds = {self.flow_backend, self.classifier_backend}
        if self.mitigation_backend is not None:
            kinds.add(self.mitigation_backend)
        if self.fused and len(kinds) == 1:
            return "pallas-fused-flow"
        if self.fused:
            return "mixed"
        return kinds.pop() if len(kinds) == 1 else "mixed"

    def with_backend(self, backend: str) -> "StatefulPipeline":
        """Recompile for another engine (what PacketServeEngine's
        ``backend=`` uses).  Preserves the ``fuse`` flag — an unfused
        pipeline must not silently come back fused."""
        return StatefulPipeline(self.stages, backend=backend,
                                fuse=self.fuse)

    def init_state(self):
        if self.n_tables > 1:
            bases = [init_state(s) for s in self.specs]
            kl = tuple(b.keys for b in bases)
            rl = tuple(b.regs for b in bases)
            if self.mitigation is None:
                return MultiFlowState(self.specs, kl, rl)
            from repro.flowstate.mitigation import init_mitigation

            mk, mr = init_mitigation(self.mitigation)
            return MultiFlowState(self.specs, kl, rl, self.mitigation,
                                  mk, mr)
        if self.mitigation is None:
            return init_state(self.spec)
        from repro.flowstate.mitigation import (
            MitigatedFlowState,
            init_mitigation,
        )

        base = init_state(self.spec)
        mk, mr = init_mitigation(self.mitigation)
        return MitigatedFlowState(self.spec, base.keys, base.regs,
                                  self.mitigation, mk, mr)

    def _adopt_mitigation(self, state):
        """Action-table half of ``adopt_state`` -> (mit_keys, mit_regs)."""
        from repro.flowstate.mitigation import (
            init_mitigation,
            migrate_mitigation,
        )

        old_mit = getattr(state, "mit_spec", None)
        if old_mit is None:
            return init_mitigation(self.mitigation)
        if old_mit == self.mitigation:
            return state.mit_keys, state.mit_regs
        return migrate_mitigation(state.mit_keys, state.mit_regs,
                                  old_mit, self.mitigation)

    def adopt_state(self, state):
        """Carry another pipeline's live state into THIS pipeline's state
        shape — the hot-swap install path (both engines call this).

        Detection table(s): same spec carries the arrays bit-identically;
        a changed spec migrates through the documented re-key path
        (``registers.migrate_state``).  Action table: same mitigation
        spec carries bit-identically (marked flows stay marked across the
        swap); a changed spec re-keys (``mitigation.migrate_mitigation``);
        swapping mitigation IN starts an empty table; swapping it OUT
        drops the table (the engine stops enforcing).  Swapping between a
        single-table and a multi-table pipeline (or changing the table
        count) starts the detection tables fresh — there is no defined
        correspondence between the table sets — while the action table
        still carries by the rules above."""
        if getattr(state, "spec", None) is None:
            return state                 # opaque state: engine's problem
        if self.n_tables > 1:
            old_specs = getattr(state, "specs", None)
            kl, rl = [], []
            if old_specs is not None and len(old_specs) == self.n_tables:
                for t, spec in enumerate(self.specs):
                    if old_specs[t] == spec:
                        kl.append(state.keys_list[t])
                        rl.append(state.regs_list[t])
                    else:
                        m = migrate_state(
                            FlowState(old_specs[t], state.keys_list[t],
                                      state.regs_list[t]), spec)
                        kl.append(m.keys)
                        rl.append(m.regs)
            else:
                for spec in self.specs:   # table-count change: fresh start
                    b = init_state(spec)
                    kl.append(b.keys)
                    rl.append(b.regs)
            if self.mitigation is None:
                return MultiFlowState(self.specs, tuple(kl), tuple(rl))
            mk, mr = self._adopt_mitigation(state)
            return MultiFlowState(self.specs, tuple(kl), tuple(rl),
                                  self.mitigation, mk, mr)
        if getattr(state, "specs", None) is not None \
                and len(state.specs) > 1:
            base = init_state(self.spec)  # multi -> single: fresh start
            keys, regs = base.keys, base.regs
        elif state.spec == self.spec:
            keys, regs = state.keys, state.regs
        else:
            m = migrate_state(FlowState(state.spec, state.keys, state.regs),
                              self.spec)
            keys, regs = m.keys, m.regs
        if self.mitigation is None:
            return FlowState(self.spec, keys, regs)
        from repro.flowstate.mitigation import MitigatedFlowState

        mk, mr = self._adopt_mitigation(state)
        return MitigatedFlowState(self.spec, keys, regs, self.mitigation,
                                  mk, mr)

    def _state_arrays(self, state) -> list:
        if self.n_tables > 1:
            arrs = []
            for k, r in zip(state.keys_list, state.regs_list):
                arrs += [k, r]
        else:
            arrs = [state.keys, state.regs]
        if self.mitigation is not None:
            arrs += [state.mit_keys, state.mit_regs]
        return arrs

    def _wrap_state(self, outs):
        """Step outputs (state arrays ++ verdicts) -> (state, verdicts)."""
        nt = self.n_tables
        if nt > 1:
            kl = tuple(outs[2 * t] for t in range(nt))
            rl = tuple(outs[2 * t + 1] for t in range(nt))
            if self.mitigation is None:
                return MultiFlowState(self.specs, kl, rl), outs[-1]
            return MultiFlowState(self.specs, kl, rl, self.mitigation,
                                  outs[2 * nt], outs[2 * nt + 1]), outs[-1]
        if self.mitigation is None:
            return FlowState(self.spec, outs[0], outs[1]), outs[-1]
        from repro.flowstate.mitigation import MitigatedFlowState

        return (MitigatedFlowState(self.spec, outs[0], outs[1],
                                   self.mitigation, outs[2], outs[3]),
                outs[-1])

    def dispatch(self, state, X, valid=None):
        """Launch one step WITHOUT forcing the device->host copy: returns
        ``(state', verdict_device_array)``.  The async serving path
        (PacketServeEngine depth>1) chains dispatches through the returned
        state — the state dependency sequentializes in-flight batches —
        and materializes verdicts lazily at flush time."""
        import jax.numpy as jnp

        X = jnp.asarray(X, jnp.float32)
        if valid is None:
            B = int(X.shape[0])
            valid = self._ones_valid.get(B)
            if valid is None:       # device-resident, reused every step
                valid = self._ones_valid.setdefault(
                    B, jnp.ones((B,), jnp.int32))
        valid = jnp.asarray(valid, jnp.int32)
        outs = self._step(*self._state_arrays(state), X, valid)
        return self._wrap_state(outs)

    def __call__(self, state, X, valid=None):
        state, verdicts = self.dispatch(state, X, valid)
        return state, np.asarray(verdicts)

    def __repr__(self):
        mit = (f", mitigation={self.mitigation.mode!r}"
               if self.mitigation is not None else "")
        tabs = f", tables={self.n_tables}" if self.n_tables > 1 else ""
        return (f"StatefulPipeline(slots={self.spec.n_slots}, "
                f"width={self.spec.width}, backend={self.backend!r}, "
                f"flow={self.flow_backend!r}, "
                f"classifier={self.classifier_backend!r}{mit}{tabs})")
