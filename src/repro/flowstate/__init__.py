"""Stateful flow-tracking subsystem: per-flow registers for the serving
engine.  See docs/pipeline_ir.md#flow-state-contract."""

from repro.flowstate.registers import (
    FlowState,
    FlowStateSpec,
    init_state,
    migrate_state,
    update_flows,
)
from repro.flowstate.drift import DriftDetector, DriftSnapshot
from repro.flowstate.mitigation import (
    MITIGATED,
    MitigatedFlowState,
    MitigationSpec,
    init_mitigation,
    migrate_mitigation,
    mitigate_update,
)
from repro.flowstate.pipeline import StatefulPipeline
