"""Forward-compat polyfills for the pinned jax in this container.

The codebase targets the current jax API (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.sharding.AxisType``); the
container pins jax 0.4.x where those live elsewhere or do not exist.  This
module backfills the missing names once, at ``import repro`` time, so all
source and tests stay written against the modern surface.  Every patch is
guarded: on a jax that already provides the name, nothing is touched.
"""

from __future__ import annotations

import enum
import functools

import jax
import jax.sharding


def _install() -> None:
    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        jax.shard_map = _shard_map

    # make_mesh: present since 0.4.35 but without the axis_types kwarg
    try:
        import inspect

        sig = inspect.signature(jax.make_mesh)
        has_axis_types = "axis_types" in sig.parameters
    except (AttributeError, ValueError):
        has_axis_types = False
    if hasattr(jax, "make_mesh") and not has_axis_types:
        _orig_make_mesh = jax.make_mesh

        @functools.wraps(_orig_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
            del axis_types  # pre-explicit-sharding jax: all axes are Auto
            return _orig_make_mesh(axis_shapes, axis_names, **kw)

        jax.make_mesh = make_mesh


_install()
