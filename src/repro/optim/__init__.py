from repro.optim.optimizers import (
    Optimizer,
    adamw,
    adafactor,
    get_optimizer,
    opt_state_defs,
    global_norm,
    clip_by_global_norm,
)
from repro.optim.schedule import warmup_cosine
