"""Optimizers from scratch (no optax): AdamW and Adafactor.

Both expose ``state_defs(param_defs)`` so the dry-run can build abstract
optimizer state for a 398B model without allocating it.  Adafactor's
factored second moment is what makes 398B trainable on a single 256-chip
pod (AdamW fp32 m+v would need ~21.8 GB/chip; see DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.common.pytree import ParamDef, is_def


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable  # params -> opt_state
    update: Callable  # (grads, state, params, lr, step) -> (new_params, new_state)
    state_defs: Callable  # param_defs -> opt_state defs


def global_norm(tree) -> jax.Array:
    leaves = [
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)
    ]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


# ----------------------------------------------------------------- AdamW


def adamw(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1) -> Optimizer:
    def init(params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(f32, params), "v": jax.tree.map(f32, params)}

    def state_defs(defs):
        f32 = lambda d: ParamDef(d.shape, jnp.float32, d.axes, "zeros")
        return {
            "m": jax.tree.map(f32, defs, is_leaf=is_def),
            "v": jax.tree.map(f32, defs, is_leaf=is_def),
        }

    def update(grads, state, params, lr, step):
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def leaf(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh = m / bc1
            vh = v / bc2
            upd = mh / (jnp.sqrt(vh) + eps)
            upd = upd + weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
            return new_p, m, v

        out = jax.tree.map(leaf, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer("adamw", init, update, state_defs)


# -------------------------------------------------------------- Adafactor


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor(eps=1e-30, clip_threshold=1.0, decay_pow=0.8, min_scale=1e-3) -> Optimizer:
    def init(params):
        def leaf(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"f": jax.tree.map(leaf, params)}

    def state_defs(defs):
        def leaf(d: ParamDef):
            ax = d.axes if d.axes else (None,) * len(d.shape)
            if _factored(d.shape):
                return {
                    "vr": ParamDef(d.shape[:-1], jnp.float32, ax[:-1], "zeros"),
                    "vc": ParamDef(
                        d.shape[:-2] + d.shape[-1:], jnp.float32,
                        ax[:-2] + ax[-1:], "zeros",
                    ),
                }
            return {"v": ParamDef(d.shape, jnp.float32, ax, "zeros")}

        return {"f": jax.tree.map(leaf, defs, is_leaf=is_def)}

    def update(grads, state, params, lr, step):
        t = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - t ** (-decay_pow)

        def leaf(g, s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(g.shape):
                vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                vhat = vr[..., None] * vc[..., None, :] / denom[..., None]
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                vhat = v
                new_s = {"v": v}
            upd = g * jax.lax.rsqrt(vhat + eps)
            # update clipping by RMS
            rms_u = jnp.sqrt(jnp.mean(jnp.square(upd)) + eps)
            upd = upd / jnp.maximum(1.0, rms_u / clip_threshold)
            # relative step size
            p32 = p.astype(jnp.float32)
            scale = jnp.maximum(jnp.sqrt(jnp.mean(jnp.square(p32))), min_scale)
            new_p = (p32 - lr * scale * upd).astype(p.dtype)
            return new_p, new_s

        flat_out = jax.tree.map(
            leaf, grads, state["f"], params,
            is_leaf=lambda x: isinstance(x, dict) and ("v" in x or "vr" in x),
        )
        # flat_out leaves are tuples aligned with grads structure
        new_params = jax.tree.map(
            lambda o: o[0], flat_out, is_leaf=lambda x: isinstance(x, tuple)
        )
        new_state = jax.tree.map(
            lambda o: o[1], flat_out, is_leaf=lambda x: isinstance(x, tuple)
        )
        return new_params, {"f": new_state}

    return Optimizer("adafactor", init, update, state_defs)


def get_optimizer(name: str) -> Optimizer:
    if name == "adamw":
        return adamw()
    if name == "adafactor":
        return adafactor()
    raise ValueError(name)


def opt_state_defs(name: str, param_defs) -> Any:
    return get_optimizer(name).state_defs(param_defs)
