"""Stage-based pipeline IR (paper §3.3, refactored).

Every backend lowers a ``TrainedModel`` into a typed list of ``Stage`` ops
instead of an opaque per-backend closure.  The vocabulary mirrors what the
paper's templates instantiate on hardware:

  ``FeatureSelect``     pick the feature subset a model consumes
  ``Dense``             one affine layer (+ optional ReLU) — a Taurus
                        map x reduce-tree dot-product template
  ``FusedMLP``          a whole ReLU-MLP executed as ONE Pallas kernel
                        launch (the Taurus MapReduce grid on TPU)
  ``CentroidDistance``  squared distances to K centroids (KMeans table)
  ``Quantize``          per-feature range tables: value -> bucket id
  ``LUTGather``         per-feature MATs: bucket -> per-class partials,
                        summed across features
  ``TreeTraverse``      level-synchronous decision-tree walk (one MAT per
                        level on a switch)
  ``Reduce``            argmax / argmin over class scores
  ``LabelMap``          cluster/leaf id -> class id

Stateful vocabulary (per-flow registers, docs/pipeline_ir.md
#flow-state-contract):

  ``FlowKey``           mix packet header columns into an int32 flow key
  ``RegisterUpdate``    per-flow register file update (counters / EWMAs /
                        windowed histograms) — hash, gather, update,
                        scatter; the Pallas backend fuses it into ONE
                        kernel launch (kernels/flow_update)
  ``WindowStats``       registers -> model-ready windowed statistics
                        (histograms normalized by the packet count)
  ``Mitigate``          verdicts -> actions: per-flow drop/rate-limit
                        action table fed by the classifier's verdicts
                        (must be the LAST stage; ``split_mitigation``)

Stateful stages carry ``stateful = True`` and cannot be compiled
statelessly — ``compile_stages`` rejects them; the serving path is
``repro.flowstate.StatefulPipeline``, which threads a ``FlowState``
through fixed-shape batches.

Two layers of the stack consume the same IR:

  * execution — ``compile_stages`` folds the stage list into one jitted
    JAX program (``apply_stages`` is the traceable form chaining uses to
    inline entire DAGs into a single XLA program); with
    ``backend="pallas"`` kernel-eligible pipelines lower onto ONE fused
    Pallas kernel launch instead (core.pallas_backend);
  * accounting — ``lower_topology`` produces shape-only ``StageSpec``s from
    which the platform resource models (core.feasibility) read layer
    shapes, parameter counts and table counts instead of re-deriving them
    per backend.

A peephole pass (``fuse_pipeline_stages``) rewrites FusedMLP -> Reduce into
``FusedClassify``, which runs the argmax inside the Pallas kernel so class
ids, not logits, cross the kernel boundary.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

# bucket count of the MAT range tables — single source of truth for both
# the executable lowering (codegen._quantize_tables) and the shape-only
# accounting specs below
MAT_BINS = 512

# =========================================================== concrete stages


class Stage:
    """One typed pipeline op: apply() is traceable jnp, meta() is the
    resource metadata feasibility accounting reads."""

    kind: str = "stage"

    def apply(self, h: jax.Array) -> jax.Array:
        raise NotImplementedError

    def meta(self) -> dict:
        return {}

    def __repr__(self):
        m = self.meta()
        inner = ", ".join(f"{k}={v}" for k, v in m.items())
        return f"{type(self).__name__}({inner})"


@dataclasses.dataclass(repr=False)
class FeatureSelect(Stage):
    idx: np.ndarray                      # feature indices to keep

    kind = "feature_select"

    def apply(self, h):
        return h[:, jnp.asarray(np.asarray(self.idx, np.int32))]

    def meta(self):
        return {"n_out": len(self.idx)}


@dataclasses.dataclass(repr=False)
class Dense(Stage):
    w: np.ndarray                        # [n_in, n_out]
    b: np.ndarray                        # [n_out]
    act: str | None = None               # None | "relu"

    kind = "dense"

    def apply(self, h):
        out = h @ jnp.asarray(self.w, jnp.float32) + jnp.asarray(
            self.b, jnp.float32
        )
        if self.act == "relu":
            out = jax.nn.relu(out)
        return out

    def meta(self):
        n_in, n_out = self.w.shape
        return {"n_in": n_in, "n_out": n_out,
                "params": int(self.w.size + self.b.size),
                "macs": int(self.w.size)}


@dataclasses.dataclass(repr=False)
class FusedMLP(Stage):
    """Whole ReLU-MLP -> logits in one fused Pallas kernel launch."""

    weights: list[np.ndarray]
    biases: list[np.ndarray]

    kind = "fused_mlp"

    def apply(self, h):
        from repro.kernels.fused_mlp import fused_mlp

        return fused_mlp(
            h,
            [jnp.asarray(w) for w in self.weights],
            [jnp.asarray(b) for b in self.biases],
        )

    def meta(self):
        return {
            "widths": [int(self.weights[0].shape[0])]
            + [int(w.shape[1]) for w in self.weights],
            "params": int(sum(w.size + b.size
                              for w, b in zip(self.weights, self.biases))),
            "macs": int(sum(w.size for w in self.weights)),
            "layers": len(self.weights),
        }


@dataclasses.dataclass(repr=False)
class FusedClassify(Stage):
    """FusedMLP + argmax folded into the kernel: class ids out, no logits
    round-trip through HBM.  Produced by ``fuse_pipeline_stages``."""

    weights: list[np.ndarray]
    biases: list[np.ndarray]

    kind = "fused_classify"

    def apply(self, h):
        from repro.kernels.fused_mlp import fused_mlp_classify

        return fused_mlp_classify(
            h,
            [jnp.asarray(w) for w in self.weights],
            [jnp.asarray(b) for b in self.biases],
        )

    def meta(self):
        return FusedMLP(self.weights, self.biases).meta()


@dataclasses.dataclass(repr=False)
class CentroidDistance(Stage):
    centroids: np.ndarray                # [K, F']

    kind = "centroid_distance"

    def apply(self, h):
        cent = jnp.asarray(self.centroids, jnp.float32)
        return jnp.sum((h[:, None, :] - cent[None]) ** 2, -1)

    def meta(self):
        k, f = self.centroids.shape
        return {"n_in": f, "n_out": k, "params": int(self.centroids.size),
                "macs": int(self.centroids.size)}


@dataclasses.dataclass(repr=False)
class Quantize(Stage):
    edges: np.ndarray                    # [F, BINS-1] range-table edges

    kind = "quantize"

    def apply(self, h):
        edges = jnp.asarray(self.edges, jnp.float32)
        return jax.vmap(
            lambda col, e: jnp.searchsorted(e, col), in_axes=(1, 0),
            out_axes=1,
        )(h, edges)

    def meta(self):
        f, bins = self.edges.shape
        return {"n_features": f, "bins": bins + 1}


@dataclasses.dataclass(repr=False)
class LUTGather(Stage):
    tables: np.ndarray                   # [F, BINS, C] per-feature partials

    kind = "lut_gather"

    def apply(self, bins):
        tables = jnp.asarray(self.tables, jnp.float32)
        partial = jax.vmap(
            lambda b, t: t[b], in_axes=(1, 0), out_axes=1
        )(bins, tables)                  # [N, F, C]
        return partial.sum(1)

    def meta(self):
        f, bins, c = self.tables.shape
        return {"n_features": f, "bins": bins, "n_out": c,
                "params": int(self.tables.size)}


@dataclasses.dataclass(repr=False)
class TreeTraverse(Stage):
    """Level-synchronous CART walk: ``depth`` rounds of gather/compare —
    the tensor form of one MAT per tree level."""

    feat: np.ndarray                     # [n_nodes] split feature (0 at leaf)
    thr: np.ndarray                      # [n_nodes] f32 threshold
    left: np.ndarray                     # [n_nodes] child ids (self at leaf)
    right: np.ndarray
    leaf_class: np.ndarray               # [n_nodes] class at leaf (0 inner)
    is_leaf: np.ndarray                  # [n_nodes] bool
    depth: int

    kind = "tree_traverse"

    @classmethod
    def from_nodes(cls, nodes: list[dict], depth: int) -> "TreeTraverse":
        n = len(nodes)
        feat = np.zeros(n, np.int32)
        thr = np.zeros(n, np.float32)
        left = np.arange(n, dtype=np.int32)
        right = np.arange(n, dtype=np.int32)
        leaf_class = np.zeros(n, np.int32)
        is_leaf = np.zeros(n, bool)
        for i, nd in enumerate(nodes):
            if "leaf" in nd:
                is_leaf[i] = True
                leaf_class[i] = nd["leaf"]
            else:
                feat[i] = nd["feat"]
                thr[i] = np.float32(nd["thr"])
                left[i] = nd["left"]
                right[i] = nd["right"]
        return cls(feat, thr, left, right, leaf_class, is_leaf, depth)

    def apply(self, h):
        feat = jnp.asarray(self.feat)
        thr = jnp.asarray(self.thr)
        left = jnp.asarray(self.left)
        right = jnp.asarray(self.right)
        leaf_class = jnp.asarray(self.leaf_class)
        is_leaf = jnp.asarray(self.is_leaf)
        nid = jnp.zeros(h.shape[0], jnp.int32)
        for _ in range(self.depth + 1):
            x_f = jnp.take_along_axis(h, feat[nid][:, None], axis=1)[:, 0]
            child = jnp.where(x_f <= thr[nid], left[nid], right[nid])
            nid = jnp.where(is_leaf[nid], nid, child)
        return leaf_class[nid]

    def meta(self):
        return {"n_nodes": len(self.feat), "depth": self.depth,
                "params": int(len(self.feat))}


@dataclasses.dataclass(repr=False)
class Reduce(Stage):
    op: str                              # argmax | argmin

    kind = "reduce"

    def apply(self, scores):
        fn = jnp.argmax if self.op == "argmax" else jnp.argmin
        return fn(scores, -1)

    def meta(self):
        return {"op": self.op}


@dataclasses.dataclass(repr=False)
class LabelMap(Stage):
    table: np.ndarray                    # [K] id -> class

    kind = "label_map"

    def apply(self, ids):
        return jnp.asarray(np.asarray(self.table, np.int32))[ids]

    def meta(self):
        return {"n_in": len(self.table)}


# ======================================================== stateful vocabulary
#
# Per-flow register stages (docs/pipeline_ir.md#flow-state-contract).  The
# register-file semantics (layout, eviction, ordering) live in
# repro.flowstate.registers; these stages are the IR wrapping: FlowKey
# derives the key, RegisterUpdate derives the update vectors and owns the
# table spec, WindowStats is the stateless readout the classifier consumes.


@dataclasses.dataclass(repr=False)
class FlowKey(Stage):
    """Mix packet header columns into a non-negative int32 flow key.

    Columns are rounded to int and FNV-folded, so any integral-valued
    header fields (ids, ports, bucketed addresses) compose into one key.
    The sign bit is cleared: the register file reserves -1 for empty."""

    key_cols: tuple                      # packet columns hashed into the key
    n_slots: int                         # table size the key will index

    kind = "flow_key"
    stateful = True

    def apply(self, h):
        raise TypeError(
            "FlowKey is stateful; serve it through "
            "repro.flowstate.StatefulPipeline, not compile_stages"
        )

    def apply_keys(self, h) -> jax.Array:
        """[B, F] packet rows -> [B] int32 flow keys (traceable)."""
        key = jnp.zeros(h.shape[0], jnp.uint32)
        for c in self.key_cols:
            v = jnp.round(h[:, c]).astype(jnp.int32).astype(jnp.uint32)
            key = key * jnp.uint32(16777619) ^ v     # FNV-1a style fold
        return (key & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)

    def apply_keys_np(self, h: np.ndarray) -> np.ndarray:
        """Numpy twin of ``apply_keys`` — same fold, same rounding — for
        host-side routing (the sharded engine partitions packets across
        per-device register tables BEFORE any device transfer).  Pinned
        equal to the traceable form in tests/test_sharded_engine.py."""
        h = np.asarray(h)
        key = np.zeros(h.shape[0], np.uint32)
        with np.errstate(over="ignore"):
            for c in self.key_cols:
                v = np.round(h[:, c]).astype(np.int32).astype(np.uint32)
                key = key * np.uint32(16777619) ^ v
        return (key & np.uint32(0x7FFFFFFF)).astype(np.int32)

    def meta(self):
        return {"key_cols": tuple(self.key_cols), "n_slots": self.n_slots}


@dataclasses.dataclass(repr=False)
class RegisterUpdate(Stage):
    """Per-flow register update: the stateful heart of the pipeline.

    Per packet: counter 0 += 1 (packet count — the WindowStats
    normalizer); counter 1+j += packet column ``counter_cols[j]``; EWMA j
    blends packet column ``ewma_cols[j]``; histogram j increments the
    bucket ``searchsorted(hist_edges[j], col)`` of its section.  The
    derivation (``prepare``) is stateless vectorized jnp; the stateful
    scatter/gather itself runs in kernels/flow_update (Pallas) or its jnp
    scan reference — bit-identical either way."""

    spec: "object"                       # flowstate.registers.FlowStateSpec
    counter_cols: tuple = ()             # value-accumulating counters 1..
    ewma_cols: tuple = ()
    hist_cols: tuple = ()
    hist_edges: tuple = ()               # np array of edges per histogram

    kind = "register_update"
    stateful = True

    def __post_init__(self):
        s = self.spec
        if s.n_counters != 1 + len(self.counter_cols):
            raise ValueError(
                f"spec.n_counters={s.n_counters} != 1 (pkt count) + "
                f"{len(self.counter_cols)} counter_cols"
            )
        if s.n_ewma != len(self.ewma_cols):
            raise ValueError("spec.n_ewma != len(ewma_cols)")
        if len(self.hist_cols) != len(self.hist_edges):
            raise ValueError("hist_cols and hist_edges must pair up")
        sizes = tuple(len(np.asarray(e)) + 1 for e in self.hist_edges)
        if tuple(s.hist_sizes) != sizes:
            raise ValueError(
                f"spec.hist_sizes={tuple(s.hist_sizes)} != bins implied by "
                f"hist_edges {sizes}"
            )

    def apply(self, h):
        raise TypeError(
            "RegisterUpdate is stateful; serve it through "
            "repro.flowstate.StatefulPipeline, not compile_stages"
        )

    def prepare(self, h) -> tuple[jax.Array, jax.Array]:
        """[B, F] packet rows -> (upd [B, C+E] f32, bins [B, H] int32
        absolute register columns) — the update vectors the register
        kernel consumes.  Stateless, vectorized, traceable."""
        B = h.shape[0]
        cols = [jnp.ones((B, 1), jnp.float32)]       # counter 0: pkt count
        for c in self.counter_cols:
            cols.append(h[:, c:c + 1])
        for c in self.ewma_cols:
            cols.append(h[:, c:c + 1])
        upd = jnp.concatenate(cols, 1).astype(jnp.float32)
        if not self.hist_cols:
            return upd, jnp.full((B, 1), -1, jnp.int32)
        offs = self.spec.hist_offsets
        bins = [
            (jnp.searchsorted(jnp.asarray(e, jnp.float32), h[:, c])
             .astype(jnp.int32) + offs[j])[:, None]
            for j, (c, e) in enumerate(zip(self.hist_cols, self.hist_edges))
        ]
        return upd, jnp.concatenate(bins, 1)

    def meta(self):
        s = self.spec
        return {
            "n_slots": s.n_slots,
            "width": s.width,
            # stored key + W register words per slot: the SRAM the
            # feasibility oracle charges (matches flowstate_specs)
            "params": s.n_slots * (s.width + 1),
            "sram_bytes": s.sram_bytes,
        }


@dataclasses.dataclass(repr=False)
class WindowStats(Stage):
    """Registers -> model-ready windowed statistics (STATELESS readout).

    ``mode="all"``: [counters ++ EWMAs ++ histograms / packet count];
    ``mode="hist"``: normalized histograms only.  Dividing by the count
    (counter 0) turns raw bin tallies into the paper's flowmarker form —
    partial per-flow distributions comparable across flow ages."""

    spec: "object"
    mode: str = "all"                    # all | hist

    kind = "window_stats"

    def __post_init__(self):
        if self.mode not in ("all", "hist"):
            raise KeyError(f"WindowStats mode must be all|hist: {self.mode}")

    @property
    def n_out(self) -> int:
        s = self.spec
        hist = sum(s.hist_sizes)
        return hist if self.mode == "hist" else s.width

    def apply(self, feats):
        s = self.spec
        head = s.n_counters + s.n_ewma
        denom = jnp.maximum(feats[:, :1], 1.0)       # counter 0 = pkt count
        hist = feats[:, head:] / denom
        if self.mode == "hist":
            return hist
        return jnp.concatenate([feats[:, :head], hist], 1)

    def meta(self):
        return {"n_in": self.spec.width, "n_out": self.n_out,
                "mode": self.mode}


@dataclasses.dataclass(repr=False)
class Mitigate(Stage):
    """Verdicts -> actions: per-flow drop / rate-limit action table.

    Closes the detection loop (docs/pipeline_ir.md#mitigation-contract):
    the classifier's verdict stream feeds a second register file keyed by
    the SAME flow key as the detection table; a flow that accumulates
    ``spec.threshold`` positive verdicts is marked, and its later packets
    are dropped (verdict replaced by ``flowstate.mitigation.MITIGATED``)
    or rate-limited.  Stateful and order-dependent — it must be the LAST
    stage of a stateful pipeline (``split_mitigation``), served through
    ``repro.flowstate.StatefulPipeline``."""

    spec: "object"                       # flowstate.mitigation.MitigationSpec

    kind = "mitigate"
    stateful = True

    def apply(self, h):
        raise TypeError(
            "Mitigate is stateful; serve it through "
            "repro.flowstate.StatefulPipeline, not compile_stages"
        )

    def meta(self):
        s = self.spec
        return {
            "n_slots": s.n_slots,
            "mode": s.mode,
            "threshold": s.threshold,
            # stored key + [hits, since] per slot: the SRAM the
            # feasibility oracle charges (matches mitigation_specs)
            "params": s.n_slots * (s.width + 1),
            "sram_bytes": s.sram_bytes,
        }


def is_stateful(stage: Stage) -> bool:
    return bool(getattr(stage, "stateful", False))


def split_mitigation(stages: list[Stage]
                     ) -> tuple[list[Stage], Mitigate | None]:
    """Split off the trailing ``Mitigate`` stage -> (rest, mitigate|None).

    A mitigation stage consumes the pipeline's *verdicts*, so it can only
    sit LAST; any other placement (or more than one) raises.  The
    remainder is a plain stateful pipeline for ``split_stateful``."""
    mits = [i for i, s in enumerate(stages) if isinstance(s, Mitigate)]
    if not mits:
        return list(stages), None
    if len(mits) > 1 or mits[0] != len(stages) - 1:
        raise ValueError(
            "Mitigate consumes verdicts and must be the single LAST "
            f"stage; got it at positions {mits} of {len(stages)} stages"
        )
    return list(stages[:-1]), stages[-1]


def split_stateful(stages: list[Stage]
                   ) -> tuple[list[Stage], list[Stage]]:
    """Split a stateful pipeline into (prefix, suffix).

    The contract: a stateful pipeline starts with exactly
    ``[FlowKey, RegisterUpdate]``; everything after is a stateless
    classifier over the emitted feature rows (typically starting with
    ``WindowStats``).  Raises on any other arrangement."""
    if len(stages) < 2 or not isinstance(stages[0], FlowKey) \
            or not isinstance(stages[1], RegisterUpdate):
        raise ValueError(
            "stateful pipelines must start with [FlowKey, RegisterUpdate]; "
            f"got {[s.kind for s in stages[:2]]}"
        )
    suffix = list(stages[2:])
    bad = [s.kind for s in suffix if is_stateful(s)]
    if bad:
        raise ValueError(f"stateful stages {bad} outside the prefix")
    return list(stages[:2]), suffix


def split_stateful_multi(stages: list[Stage]
                         ) -> tuple[list[tuple], list[Stage]]:
    """Parse a (possibly multi-table) stateful pipeline -> (groups, suffix).

    Grammar: one or more ``FlowKey RegisterUpdate [WindowStats]`` groups —
    a ``WindowStats`` directly following a ``RegisterUpdate`` is THAT
    table's readout — then a stateless classifier suffix consuming the
    concatenated per-table readouts in group order.  Each group is a
    ``(flow_key, register_update, window_stats | None)`` tuple.  This is
    the multi-table DAG form: every table keys and updates off the SAME
    packet rows, one classifier consumes all their feature rows.  Raises
    on any other arrangement (same per-table contract as
    ``split_stateful``)."""
    groups: list[tuple] = []
    rest = list(stages)
    while rest and isinstance(rest[0], FlowKey):
        if len(rest) < 2 or not isinstance(rest[1], RegisterUpdate):
            raise ValueError(
                "each FlowKey must be followed by its RegisterUpdate; got "
                f"{[s.kind for s in rest[:2]]}"
            )
        ws = rest[2] if len(rest) > 2 and isinstance(rest[2], WindowStats) \
            else None
        groups.append((rest[0], rest[1], ws))
        rest = rest[3 if ws is not None else 2:]
    if not groups:
        raise ValueError(
            "stateful pipelines must start with [FlowKey, RegisterUpdate]; "
            f"got {[s.kind for s in stages[:2]]}"
        )
    bad = [s.kind for s in rest if is_stateful(s)]
    if bad:
        raise ValueError(f"stateful stages {bad} outside the table groups")
    return groups, rest


# ---------------------------------------------------------------- execution


def apply_stages(stages: list[Stage], x: jax.Array) -> jax.Array:
    """Traceable whole-pipeline application (what chaining inlines)."""
    h = x
    for s in stages:
        h = s.apply(h)
    return h


def fuse_pipeline_stages(stages: list[Stage]) -> list[Stage]:
    """Peephole: FusedMLP -> Reduce(argmax) becomes FusedClassify (argmax
    runs inside the Pallas kernel)."""
    out: list[Stage] = []
    i = 0
    while i < len(stages):
        s = stages[i]
        nxt = stages[i + 1] if i + 1 < len(stages) else None
        if (isinstance(s, FusedMLP) and isinstance(nxt, Reduce)
                and nxt.op == "argmax"):
            out.append(FusedClassify(s.weights, s.biases))
            i += 2
            continue
        out.append(s)
        i += 1
    return out


EXEC_BACKENDS = ("interpret", "pallas")

# Engines a compiled artifact may REPORT serving on (what actually runs,
# after fallback): the requestable engines, the whole-DAG megakernel
# (chaining.compile_dag's "pallas-fused-dag"), the single-launch stateful
# pipeline (flowstate.StatefulPipeline's "pallas-fused-flow"), and
# "mixed" for DAGs / stateful pipelines whose parts landed on different
# engines.
REPORT_BACKENDS = ("interpret", "pallas", "pallas-fused-dag",
                   "pallas-fused-flow", "mixed")


class CompiledStages:
    """A jitted whole-pipeline executable with backend provenance.

    Callable like the function ``compile_stages`` used to return;
    ``backend`` records what actually serves ("pallas" when the pipeline
    lowered onto a fused kernel, "interpret" otherwise — including the
    fallback case where Pallas was requested but the stage sequence is
    outside the kernel envelope), ``requested_backend`` what was asked."""

    def __init__(self, fn: Callable, backend: str, requested: str):
        self.fn = jax.jit(fn)
        self.backend = backend
        self.requested_backend = requested

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.fn(x)

    def __repr__(self):
        return f"CompiledStages(backend={self.backend!r})"


def compile_stages(stages: list[Stage], *, fuse: bool = True,
                   backend: str = "interpret") -> CompiledStages:
    """Compile the whole stage list into one XLA program.

    ``backend`` selects the execution engine:

    * ``"interpret"`` (default) — walk the stage list (each ``Stage.apply``
      traced into a single jitted program);
    * ``"pallas"`` — lower the whole pipeline onto ONE fused Pallas kernel
      launch (``core.pallas_backend``) when the stage sequence is
      kernel-eligible per docs/pipeline_ir.md#pallas-lowering-contract;
      ineligible pipelines (or an unavailable Pallas toolchain) fall back
      to the interpreter.

    The returned ``CompiledStages`` is callable and reports the backend
    that actually serves via ``.backend``."""
    if backend not in EXEC_BACKENDS:
        raise KeyError(f"backend must be one of {EXEC_BACKENDS}")
    state_kinds = [s.kind for s in stages if is_stateful(s)]
    if state_kinds:
        raise ValueError(
            f"stateful stages {state_kinds} cannot be compiled statelessly; "
            "use repro.flowstate.StatefulPipeline"
        )
    run_list = fuse_pipeline_stages(stages) if fuse else list(stages)

    if backend == "pallas":
        from repro.core import pallas_backend

        kernel_fn = pallas_backend.lower_stages_pallas(run_list)
        if kernel_fn is not None:
            return CompiledStages(kernel_fn, "pallas", backend)

    return CompiledStages(
        lambda x: apply_stages(run_list, x), "interpret", backend
    )


def stage_summary(stages: list[Stage]) -> dict:
    """Aggregate stage metadata (params/macs/tables) for reports."""
    params = macs = 0
    for s in stages:
        m = s.meta()
        params += m.get("params", 0)
        macs += m.get("macs", 0)
    return {
        "stages": [s.kind for s in stages],
        "params": int(params),
        "macs": int(macs),
    }


# ===================================================== shape-only stage specs
#
# The feasibility oracle runs before anything is trained, so it lowers a
# *topology* into StageSpecs — same vocabulary, shapes only.


@dataclasses.dataclass(frozen=True)
class StageSpec:
    kind: str
    n_in: int = 0
    n_out: int = 0
    params: int = 0
    extra: tuple = ()                    # kind-specific (depth, bins, ...)

    @property
    def is_layer(self) -> bool:
        """Does this spec occupy compute as one dense layer (CU rows)?"""
        return self.kind in ("dense", "centroid_distance")


def lower_topology(algorithm: str, topology: dict, *, form: str = "dense"
                   ) -> list[StageSpec]:
    """Topology dict -> abstract stage list for one backend family.

    ``form="dense"``: Taurus/FPGA/TPU MapReduce lowering.
    ``form="mat"``:   IIsy-style match-action-table lowering.
    """
    if form == "dense":
        return _lower_dense(algorithm, topology)
    if form == "mat":
        return _lower_mat(algorithm, topology)
    raise KeyError(form)


def _dense_widths(topology: dict) -> list[int]:
    return list(topology["widths"])


def _lower_dense(algorithm: str, topology: dict) -> list[StageSpec]:
    if algorithm in ("dnn", "logreg"):
        w = _dense_widths(topology)
        specs = [
            StageSpec("dense", w[i], w[i + 1], w[i] * w[i + 1] + w[i + 1])
            for i in range(len(w) - 1)
        ]
        return specs + [StageSpec("reduce")]
    if algorithm == "svm":
        f, c = topology["n_features"], topology["n_classes"]
        return [StageSpec("dense", f, c, f * c + c), StageSpec("reduce")]
    if algorithm == "kmeans":
        f, k = topology["n_features"], topology["k"]
        return [
            StageSpec("centroid_distance", f, k, f * k),
            StageSpec("reduce"),
            StageSpec("label_map", k, k),
        ]
    if algorithm == "tree":
        n = len(topology["nodes"])
        depth = topology.get("depth", 8)
        return [StageSpec("tree_traverse", 0, 0, n, extra=(depth,))]
    raise KeyError(f"dense lowering does not map {algorithm}")


def _lower_mat(algorithm: str, topology: dict, bins: int = MAT_BINS
               ) -> list[StageSpec]:
    if algorithm == "svm":
        f, c = topology["n_features"], topology["n_classes"]
        return [
            StageSpec("quantize", f, f, extra=(bins,)),
            StageSpec("lut_gather", f, c, f * bins * c, extra=(bins,)),
            StageSpec("reduce"),
        ]
    if algorithm == "logreg":
        w = _dense_widths(topology)
        f, c = w[0], w[-1]
        return [
            StageSpec("quantize", f, f, extra=(bins,)),
            StageSpec("lut_gather", f, c, f * bins * c, extra=(bins,)),
            StageSpec("reduce"),
        ]
    if algorithm == "kmeans":
        f, k = topology["n_features"], topology["k"]
        return [
            StageSpec("quantize", f, f, extra=(bins,)),
            StageSpec("lut_gather", f, k, f * bins * k, extra=(bins,)),
            StageSpec("reduce"),
            StageSpec("label_map", k, k),
        ]
    if algorithm == "tree":
        n = len(topology["nodes"])
        depth = topology.get("depth", 8)
        return [StageSpec("tree_traverse", 0, 0, n, extra=(depth,))]
    if algorithm == "dnn":
        # N2Net-style: each dense layer burns ~12 MATs; keep the dense
        # shapes so the accounting can read layer count
        w = _dense_widths(topology)
        return [
            StageSpec("dense", w[i], w[i + 1], w[i] * w[i + 1] + w[i + 1])
            for i in range(len(w) - 1)
        ] + [StageSpec("reduce")]
    raise KeyError(f"MAT lowering does not map {algorithm}")


def flowstate_specs(spec, *, mode: str = "all") -> list[StageSpec]:
    """Shape-only specs for the stateful prefix + readout — what the
    feasibility oracle charges for the register file
    (``feasibility.flowstate_report``) BEFORE anything is trained.

    ``params`` of the register_update spec is the table's word count
    (stored key + W register words per slot) and must stay equal to
    ``RegisterUpdate.meta()["params"]`` — the conformance suite pins the
    specs-==-stage-meta invariant for the stateful vocabulary too."""
    W = spec.width
    n_out = sum(spec.hist_sizes) if mode == "hist" else W
    return [
        StageSpec("flow_key", n_in=0, n_out=1, extra=(spec.n_slots,)),
        StageSpec("register_update", n_in=W, n_out=W,
                  params=spec.n_slots * (W + 1),
                  extra=(spec.n_slots, W)),
        StageSpec("window_stats", n_in=W, n_out=n_out),
    ]


def mitigation_specs(spec) -> list[StageSpec]:
    """Shape-only spec for the mitigation action table — what
    ``feasibility.mitigation_report`` charges.  ``params`` is the table's
    word count (stored key + [hits, since] per slot) and must stay equal
    to ``Mitigate.meta()["params"]``, like the other stateful specs."""
    W = spec.width
    return [
        StageSpec("mitigate", n_in=1, n_out=1,
                  params=spec.n_slots * (W + 1),
                  extra=(spec.n_slots, W)),
    ]


def spec_layers(specs: list[StageSpec]) -> list[tuple[int, int]]:
    """(n_in, n_out) of every compute layer — what Taurus maps to CU rows."""
    return [(s.n_in, s.n_out) for s in specs if s.is_layer]


def spec_params(specs: list[StageSpec]) -> int:
    return sum(s.params for s in specs)
