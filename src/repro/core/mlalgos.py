"""Trainable ML algorithms for the Homunculus optimization core.

The paper delegates training to Keras; here the equivalent substrate is
implemented directly in JAX (DNN) and numpy (KMeans / SVM / decision tree /
logistic regression).  Every algorithm returns a ``TrainedModel`` carrying

  * ``predict(X)``   -- class predictions (what feasibility testing runs),
  * ``topology``     -- the structural description the backend code
                        generators consume (layer widths / centroids /
                        thresholds), and
  * ``param_count``  -- the "# NN Param" column of the paper's Table 2.

Metrics: binary/macro F1 (Table 2) and V-measure (Fig. 7, KMeans on MATs).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.netdata import Dataset

# ------------------------------------------------------------------ metrics


def f1_score(y_true: np.ndarray, y_pred: np.ndarray, *, num_classes: int = 2,
             average: str = "auto") -> float:
    """Binary F1 (positive class = 1) or macro F1 for multiclass."""
    y_true = np.asarray(y_true)
    y_pred = np.asarray(y_pred)
    if average == "auto":
        average = "binary" if num_classes == 2 else "macro"
    classes = [1] if average == "binary" else list(range(num_classes))
    f1s = []
    for c in classes:
        tp = float(np.sum((y_pred == c) & (y_true == c)))
        fp = float(np.sum((y_pred == c) & (y_true != c)))
        fn = float(np.sum((y_pred != c) & (y_true == c)))
        prec = tp / (tp + fp) if tp + fp else 0.0
        rec = tp / (tp + fn) if tp + fn else 0.0
        f1s.append(2 * prec * rec / (prec + rec) if prec + rec else 0.0)
    return float(np.mean(f1s))


def accuracy(y_true, y_pred) -> float:
    return float(np.mean(np.asarray(y_true) == np.asarray(y_pred)))


def v_measure(labels: np.ndarray, clusters: np.ndarray) -> float:
    """Homogeneity/completeness harmonic mean (paper Fig. 7 metric)."""
    labels = np.asarray(labels)
    clusters = np.asarray(clusters)
    n = len(labels)
    ls, cs = np.unique(labels), np.unique(clusters)
    cont = np.zeros((len(ls), len(cs)))
    for i, l in enumerate(ls):
        for j, c in enumerate(cs):
            cont[i, j] = np.sum((labels == l) & (clusters == c))
    p = cont / n

    def entropy(marg):
        marg = marg[marg > 0]
        return -np.sum(marg * np.log(marg))

    h_l, h_c = entropy(p.sum(1)), entropy(p.sum(0))
    nz = p > 0
    h_l_given_c = -np.sum(
        p[nz] * (np.log(p[nz]) - np.log(p.sum(0)[None, :].repeat(len(ls), 0)[nz]))
    )
    h_c_given_l = -np.sum(
        p[nz] * (np.log(p[nz]) - np.log(p.sum(1)[:, None].repeat(len(cs), 1)[nz]))
    )
    hom = 1.0 if h_l == 0 else 1.0 - h_l_given_c / h_l
    com = 1.0 if h_c == 0 else 1.0 - h_c_given_l / h_c
    if hom + com == 0:
        return 0.0
    return float(2 * hom * com / (hom + com))


METRICS: dict[str, Callable] = {
    "f1": f1_score,
    "accuracy": lambda yt, yp, **kw: accuracy(yt, yp),
    "v_measure": lambda yt, yp, **kw: v_measure(yt, yp),
}


def evaluate_metric(metric: str, y_true, y_pred, *, num_classes: int) -> float:
    if metric == "f1":
        return f1_score(y_true, y_pred, num_classes=num_classes)
    return METRICS[metric](y_true, y_pred)


# -------------------------------------------------------------- TrainedModel


@dataclasses.dataclass
class TrainedModel:
    algorithm: str            # dnn | kmeans | svm | tree | logreg
    topology: dict            # structure for the backend codegen
    params: Any               # learned parameters (pytree / ndarray)
    predict: Callable         # X [N,F] -> y [N]
    param_count: int
    num_classes: int
    config: dict              # the DSE configuration that produced it


# ------------------------------------------------------------------- DNN


def _mlp_init(key, widths: list[int]) -> list[dict]:
    params = []
    for i in range(len(widths) - 1):
        key, k1 = jax.random.split(key)
        fan_in = widths[i]
        params.append({
            "w": jax.random.normal(k1, (widths[i], widths[i + 1]), jnp.float32)
            * np.sqrt(2.0 / fan_in),
            "b": jnp.zeros((widths[i + 1],), jnp.float32),
        })
    return params


def mlp_forward(params: list[dict], x: jax.Array) -> jax.Array:
    """ReLU MLP returning logits — the *same math* the generated Taurus
    pipeline executes (kernels/fused_mlp); keeping them identical is what
    makes codegen verification (tests/test_codegen.py) exact."""
    h = x
    for i, layer in enumerate(params):
        h = h @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h


@partial(jax.jit, static_argnames=("nsteps", "batch", "l2"))
def _mlp_train_loop(params, x, y, key, lr, *, nsteps: int, batch: int,
                    l2: float = 1e-4):
    n = x.shape[0]

    def loss_fn(p, xb, yb):
        logits = mlp_forward(p, xb)
        logp = jax.nn.log_softmax(logits)
        ce = -jnp.mean(jnp.take_along_axis(logp, yb[:, None], axis=1))
        reg = sum(jnp.sum(jnp.square(l["w"])) for l in p)
        return ce + l2 * reg

    # Adam
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    def step(carry, i):
        p, m, v, key = carry
        key, kb = jax.random.split(key)
        idx = jax.random.randint(kb, (batch,), 0, n)
        g = jax.grad(loss_fn)(p, x[idx], y[idx])
        t = i.astype(jnp.float32) + 1.0
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        mh = jax.tree.map(lambda a: a / (1 - 0.9 ** t), m)
        vh = jax.tree.map(lambda a: a / (1 - 0.999 ** t), v)
        p = jax.tree.map(
            lambda pp, a, b: pp - lr * a / (jnp.sqrt(b) + 1e-8), p, mh, vh
        )
        return (p, m, v, key), 0.0

    (params, _, _, _), _ = jax.lax.scan(
        step, (params, m, v, key), jnp.arange(nsteps)
    )
    return params


def train_dnn(
    data: Dataset,
    *,
    hidden: list[int],
    lr: float = 3e-3,
    batch: int = 256,
    epochs: int = 12,
    seed: int = 0,
    config: dict | None = None,
) -> TrainedModel:
    F, C = data.num_features, data.num_classes
    widths = [F] + list(hidden) + [C]
    key = jax.random.PRNGKey(seed)
    params = _mlp_init(key, widths)
    x = jnp.asarray(data.train_x)
    y = jnp.asarray(data.train_y)
    nsteps = max(1, epochs * len(data.train_x) // batch)
    params = _mlp_train_loop(
        params, x, y, jax.random.PRNGKey(seed + 1), jnp.float32(lr),
        nsteps=int(nsteps), batch=batch,
    )
    params = jax.tree.map(np.asarray, params)

    def predict(X):
        logits = mlp_forward(
            [{k: jnp.asarray(v) for k, v in l.items()} for l in params],
            jnp.asarray(X, jnp.float32),
        )
        return np.asarray(jnp.argmax(logits, -1), np.int32)

    n_params = sum(int(l["w"].size + l["b"].size) for l in params)
    return TrainedModel(
        "dnn",
        {"widths": widths, "act": "relu"},
        params, predict, n_params, C, config or {"hidden": hidden},
    )


# ----------------------------------------------------------------- KMeans


def train_kmeans(
    data: Dataset, *, k: int, iters: int = 50, seed: int = 0,
    feature_idx: list[int] | None = None, config: dict | None = None,
) -> TrainedModel:
    rng = np.random.default_rng(seed)
    X = data.train_x if feature_idx is None else data.train_x[:, feature_idx]
    init = X[rng.choice(len(X), size=k, replace=False)]
    cent = init.copy()
    for _ in range(iters):
        d = ((X[:, None, :] - cent[None]) ** 2).sum(-1)
        a = d.argmin(1)
        for j in range(k):
            pts = X[a == j]
            if len(pts):
                cent[j] = pts.mean(0)
    # majority-label map cluster -> class (for classification use)
    d = ((X[:, None, :] - cent[None]) ** 2).sum(-1)
    a = d.argmin(1)
    label_map = np.zeros(k, np.int32)
    for j in range(k):
        ys = data.train_y[a == j]
        label_map[j] = np.bincount(ys, minlength=data.num_classes).argmax() \
            if len(ys) else 0

    def assign(X_):
        X_ = X_ if feature_idx is None else X_[:, feature_idx]
        return ((X_[:, None, :] - cent[None]) ** 2).sum(-1).argmin(1)

    def predict(X_):
        return label_map[assign(X_)]

    tm = TrainedModel(
        "kmeans",
        {"k": k, "n_features": cent.shape[1], "feature_idx": feature_idx},
        {"centroids": cent, "label_map": label_map},
        predict, int(cent.size), data.num_classes,
        config or {"k": k},
    )
    tm.topology["assign"] = assign  # raw cluster ids for v_measure
    return tm


# -------------------------------------------------------------- linear SVM


def train_svm(
    data: Dataset, *, c_reg: float = 1.0, epochs: int = 20, lr: float = 1e-2,
    seed: int = 0, config: dict | None = None,
) -> TrainedModel:
    """One-vs-rest linear SVM via hinge-loss SGD (numpy)."""
    rng = np.random.default_rng(seed)
    X, y = data.train_x, data.train_y
    N, F = X.shape
    C = data.num_classes
    W = np.zeros((F, C), np.float32)
    b = np.zeros(C, np.float32)
    Y = np.where(y[:, None] == np.arange(C)[None], 1.0, -1.0).astype(np.float32)
    for ep in range(epochs):
        perm = rng.permutation(N)
        for start in range(0, N, 512):
            idx = perm[start:start + 512]
            s = X[idx] @ W + b  # [b, C]
            margin = Y[idx] * s
            active = (margin < 1.0).astype(np.float32)
            gW = -(X[idx].T @ (active * Y[idx])) / len(idx) + W / (c_reg * N)
            gb = -(active * Y[idx]).mean(0)
            W -= lr * gW
            b -= lr * gb

    def predict(X_):
        return np.argmax(X_ @ W + b, 1).astype(np.int32)

    return TrainedModel(
        "svm", {"n_features": F, "n_classes": C},
        {"W": W, "b": b}, predict, int(W.size + b.size), C,
        config or {"c_reg": c_reg},
    )


# ---------------------------------------------------------- decision tree


def train_tree(
    data: Dataset, *, max_depth: int = 6, min_leaf: int = 16, seed: int = 0,
    config: dict | None = None,
) -> TrainedModel:
    """CART (gini) classifier; nodes stored flat for MAT codegen."""
    X, y = data.train_x, data.train_y
    C = data.num_classes
    nodes: list[dict] = []  # {feat, thr, left, right, leaf_class}

    def gini(ys):
        if len(ys) == 0:
            return 0.0
        p = np.bincount(ys, minlength=C) / len(ys)
        return 1.0 - np.sum(p * p)

    def build(idx, depth) -> int:
        ys = y[idx]
        node_id = len(nodes)
        nodes.append({})
        if depth >= max_depth or len(idx) < 2 * min_leaf or gini(ys) < 1e-6:
            nodes[node_id] = {"leaf": int(np.bincount(ys, minlength=C).argmax())}
            return node_id
        best = (None, None, np.inf)
        for f in range(X.shape[1]):
            vals = X[idx, f]
            qs = np.quantile(vals, np.linspace(0.1, 0.9, 9))
            for thr in qs:
                l = idx[vals <= thr]
                r = idx[vals > thr]
                if len(l) < min_leaf or len(r) < min_leaf:
                    continue
                score = (len(l) * gini(y[l]) + len(r) * gini(y[r])) / len(idx)
                if score < best[2]:
                    best = (f, thr, score)
        if best[0] is None:
            nodes[node_id] = {"leaf": int(np.bincount(ys, minlength=C).argmax())}
            return node_id
        f, thr, _ = best
        # thresholds live at f32 so the numpy walk and the jitted
        # TreeTraverse stage (f32 compare) make identical split decisions
        thr = float(np.float32(thr))
        l_id = build(idx[X[idx, f] <= thr], depth + 1)
        r_id = build(idx[X[idx, f] > thr], depth + 1)
        nodes[node_id] = {"feat": int(f), "thr": thr,
                          "left": l_id, "right": r_id}
        return node_id

    build(np.arange(len(X)), 0)

    def predict(X_):
        out = np.zeros(len(X_), np.int32)
        for i, row in enumerate(X_):
            nid = 0
            while "leaf" not in nodes[nid]:
                nd = nodes[nid]
                nid = nd["left"] if row[nd["feat"]] <= nd["thr"] else nd["right"]
            out[i] = nodes[nid]["leaf"]
        return out

    depth_used = max_depth
    return TrainedModel(
        "tree", {"nodes": nodes, "depth": depth_used},
        {"nodes": nodes}, predict, len(nodes), C,
        config or {"max_depth": max_depth},
    )


# ------------------------------------------------------- logistic regression


def train_logreg(
    data: Dataset, *, lr: float = 0.1, epochs: int = 30, seed: int = 0,
    config: dict | None = None,
) -> TrainedModel:
    tm = train_dnn(data, hidden=[], lr=lr, epochs=epochs, seed=seed,
                   config=config or {})
    tm.algorithm = "logreg"
    return tm


# ------------------------------------------------------------------ train()

SUPPORTED_ALGORITHMS = ["dnn", "kmeans", "svm", "tree", "logreg"]


def train(algorithm: str, data: Dataset, config: dict, *, seed: int = 0
          ) -> TrainedModel:
    """Uniform entry point the DSE loop calls with a BO-suggested config."""
    if algorithm == "dnn":
        hidden = [config[f"h{i}"] for i in range(config["n_layers"])
                  if config.get(f"h{i}", 0) > 0]
        return train_dnn(
            data, hidden=hidden, lr=config.get("lr", 3e-3),
            batch=config.get("batch", 256), epochs=config.get("epochs", 12),
            seed=seed, config=config,
        )
    if algorithm == "kmeans":
        n_feat = config.get("n_features", data.num_features)
        fi = list(range(n_feat)) if n_feat < data.num_features else None
        return train_kmeans(data, k=config["k"], seed=seed, feature_idx=fi,
                            config=config)
    if algorithm == "svm":
        return train_svm(data, c_reg=config.get("c_reg", 1.0), seed=seed,
                         config=config)
    if algorithm == "tree":
        return train_tree(data, max_depth=config.get("max_depth", 6),
                          seed=seed, config=config)
    if algorithm == "logreg":
        return train_logreg(data, lr=config.get("lr", 0.1), seed=seed,
                            config=config)
    raise KeyError(algorithm)
