"""Trainable ML algorithms for the Homunculus optimization core.

The paper delegates training to Keras; here the equivalent substrate is
implemented directly in JAX (DNN) and numpy (KMeans / SVM / decision tree /
logistic regression).  Every algorithm returns a ``TrainedModel`` carrying

  * ``predict(X)``   -- class predictions (what feasibility testing runs),
  * ``topology``     -- the structural description the backend code
                        generators consume (layer widths / centroids /
                        thresholds), and
  * ``param_count``  -- the "# NN Param" column of the paper's Table 2.

Metrics: binary/macro F1 (Table 2) and V-measure (Fig. 7, KMeans on MATs).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.netdata import Dataset

# ------------------------------------------------------------------ metrics


def f1_score(y_true: np.ndarray, y_pred: np.ndarray, *, num_classes: int = 2,
             average: str = "auto") -> float:
    """Binary F1 (positive class = 1) or macro F1 for multiclass.

    Degenerate inputs score 0.0 (sklearn's zero_division=0 convention):
    empty arrays, an empty positive class, or a class absent from both
    y_true and y_pred all contribute 0 rather than NaN.
    """
    y_true = np.asarray(y_true)
    y_pred = np.asarray(y_pred)
    if y_true.size == 0:
        return 0.0
    if average == "auto":
        average = "binary" if num_classes == 2 else "macro"
    classes = [1] if average == "binary" else list(range(num_classes))
    f1s = []
    for c in classes:
        tp = float(np.sum((y_pred == c) & (y_true == c)))
        fp = float(np.sum((y_pred == c) & (y_true != c)))
        fn = float(np.sum((y_pred != c) & (y_true == c)))
        prec = tp / (tp + fp) if tp + fp else 0.0
        rec = tp / (tp + fn) if tp + fn else 0.0
        f1s.append(2 * prec * rec / (prec + rec) if prec + rec else 0.0)
    return float(np.mean(f1s))


def accuracy(y_true, y_pred) -> float:
    y_true = np.asarray(y_true)
    if y_true.size == 0:
        return 0.0
    return float(np.mean(y_true == np.asarray(y_pred)))


def v_measure(labels: np.ndarray, clusters: np.ndarray) -> float:
    """Homogeneity/completeness harmonic mean (paper Fig. 7 metric)."""
    labels = np.asarray(labels)
    clusters = np.asarray(clusters)
    n = len(labels)
    if n == 0:
        return 0.0
    ls, cs = np.unique(labels), np.unique(clusters)
    cont = np.zeros((len(ls), len(cs)))
    for i, l in enumerate(ls):
        for j, c in enumerate(cs):
            cont[i, j] = np.sum((labels == l) & (clusters == c))
    p = cont / n

    def entropy(marg):
        marg = marg[marg > 0]
        return -np.sum(marg * np.log(marg))

    h_l, h_c = entropy(p.sum(1)), entropy(p.sum(0))
    nz = p > 0
    h_l_given_c = -np.sum(
        p[nz] * (np.log(p[nz]) - np.log(p.sum(0)[None, :].repeat(len(ls), 0)[nz]))
    )
    h_c_given_l = -np.sum(
        p[nz] * (np.log(p[nz]) - np.log(p.sum(1)[:, None].repeat(len(cs), 1)[nz]))
    )
    hom = 1.0 if h_l == 0 else 1.0 - h_l_given_c / h_l
    com = 1.0 if h_c == 0 else 1.0 - h_c_given_l / h_c
    if hom + com == 0:
        return 0.0
    return float(2 * hom * com / (hom + com))


METRICS: dict[str, Callable] = {
    "f1": f1_score,
    "accuracy": lambda yt, yp, **kw: accuracy(yt, yp),
    "v_measure": lambda yt, yp, **kw: v_measure(yt, yp),
}


def evaluate_metric(metric: str, y_true, y_pred, *, num_classes: int) -> float:
    if metric == "f1":
        return f1_score(y_true, y_pred, num_classes=num_classes)
    return METRICS[metric](y_true, y_pred)


# -------------------------------------------------------------- TrainedModel


@dataclasses.dataclass
class TrainedModel:
    algorithm: str            # dnn | kmeans | svm | tree | logreg
    topology: dict            # structure for the backend codegen
    params: Any               # learned parameters (pytree / ndarray)
    predict: Callable         # X [N,F] -> y [N]
    param_count: int
    num_classes: int
    config: dict              # the DSE configuration that produced it


# ------------------------------------------------------------------- DNN


def _mlp_init(key, widths: list[int]) -> list[dict]:
    params = []
    for i in range(len(widths) - 1):
        key, k1 = jax.random.split(key)
        fan_in = widths[i]
        params.append({
            "w": jax.random.normal(k1, (widths[i], widths[i + 1]), jnp.float32)
            * np.sqrt(2.0 / fan_in),
            "b": jnp.zeros((widths[i + 1],), jnp.float32),
        })
    return params


def mlp_forward(params: list[dict], x: jax.Array) -> jax.Array:
    """ReLU MLP returning logits — the *same math* the generated Taurus
    pipeline executes (kernels/fused_mlp); keeping them identical is what
    makes codegen verification (tests/test_codegen.py) exact."""
    h = x
    for i, layer in enumerate(params):
        h = h @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h


def _mlp_train_body(params, masks, x, y, key, lr, *, nsteps: int, batch: int,
                    l2: float = 1e-4):
    """Adam training loop shared by the sequential and the vmapped-bucket
    trainers.  ``masks`` zeroes gradients of padded entries: zero-padded
    params with masked grads never move, so a padded lane of a vmapped
    bucket computes the same math as an unpadded sequential run (padded
    units output relu(0)=0 and their outgoing weights stay 0, contributing
    exact +0.0 terms to every dot product)."""
    n = x.shape[0]

    def loss_fn(p, xb, yb):
        logits = mlp_forward(p, xb)
        logp = jax.nn.log_softmax(logits)
        ce = -jnp.mean(jnp.take_along_axis(logp, yb[:, None], axis=1))
        reg = sum(jnp.sum(jnp.square(l["w"])) for l in p)
        return ce + l2 * reg

    # Adam
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    def step(carry, i):
        p, m, v, key = carry
        key, kb = jax.random.split(key)
        idx = jax.random.randint(kb, (batch,), 0, n)
        g = jax.grad(loss_fn)(p, x[idx], y[idx])
        g = jax.tree.map(jnp.multiply, g, masks)
        t = i.astype(jnp.float32) + 1.0
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        mh = jax.tree.map(lambda a: a / (1 - 0.9 ** t), m)
        vh = jax.tree.map(lambda a: a / (1 - 0.999 ** t), v)
        p = jax.tree.map(
            lambda pp, a, b: pp - lr * a / (jnp.sqrt(b) + 1e-8), p, mh, vh
        )
        return (p, m, v, key), 0.0

    (params, _, _, _), _ = jax.lax.scan(
        step, (params, m, v, key), jnp.arange(nsteps)
    )
    return params


@partial(jax.jit, static_argnames=("nsteps", "batch", "l2"))
def _mlp_train_loop(params, x, y, key, lr, *, nsteps: int, batch: int,
                    l2: float = 1e-4):
    masks = jax.tree.map(jnp.ones_like, params)
    return _mlp_train_body(params, masks, x, y, key, lr,
                           nsteps=nsteps, batch=batch, l2=l2)


@partial(jax.jit, static_argnames=("nsteps", "batch", "l2"))
def _mlp_train_bucket(params, masks, x, y, key, lrs, *, nsteps: int,
                      batch: int, l2: float = 1e-4):
    """One jitted program training a whole bucket of same-padded-shape
    candidates: vmap over stacked params/masks/learning rates, the dataset
    and the minibatch RNG stream shared across lanes (exactly what each
    sequential run would draw)."""

    def one(p, msk, lr):
        return _mlp_train_body(p, msk, x, y, key, lr,
                               nsteps=nsteps, batch=batch, l2=l2)

    return jax.vmap(one)(params, masks, lrs)


def _finalize_dnn(params: list[dict], widths: list[int], num_classes: int,
                  config: dict) -> TrainedModel:
    """Package trained numpy MLP params as a TrainedModel (shared by the
    sequential and the vmapped-batch trainers, so both emit identical
    artifacts)."""
    params = jax.tree.map(np.asarray, params)

    def predict(X):
        logits = mlp_forward(
            [{k: jnp.asarray(v) for k, v in l.items()} for l in params],
            jnp.asarray(X, jnp.float32),
        )
        return np.asarray(jnp.argmax(logits, -1), np.int32)

    n_params = sum(int(l["w"].size + l["b"].size) for l in params)
    return TrainedModel(
        "dnn",
        {"widths": widths, "act": "relu"},
        params, predict, n_params, num_classes, config,
    )


def train_dnn(
    data: Dataset,
    *,
    hidden: list[int],
    lr: float = 3e-3,
    batch: int = 256,
    epochs: int = 12,
    seed: int = 0,
    config: dict | None = None,
) -> TrainedModel:
    F, C = data.num_features, data.num_classes
    widths = [F] + list(hidden) + [C]
    key = jax.random.PRNGKey(seed)
    params = _mlp_init(key, widths)
    x = jnp.asarray(data.train_x)
    y = jnp.asarray(data.train_y)
    nsteps = max(1, epochs * len(data.train_x) // batch)
    params = _mlp_train_loop(
        params, x, y, jax.random.PRNGKey(seed + 1), jnp.float32(lr),
        nsteps=int(nsteps), batch=batch,
    )
    return _finalize_dnn(params, widths, C, config or {"hidden": hidden})


# ------------------------------------------- population-parallel DNN training
#
# The DSE engine (core.dse) proposes a *batch* of K configurations per BO
# iteration.  DNN/logreg candidates are bucketed by (layer count, minibatch
# size, step count); within a bucket every layer is zero-padded to the
# bucket-max width, gradients are masked to the real entries, and ONE
# jitted vmap trains the whole bucket.  Each candidate is initialized from
# the same PRNG stream as train_dnn, so a bucket lane reproduces the
# sequential trainer's result for that config.


def _dnn_hidden(config: dict) -> list[int]:
    """Hidden widths a DSE config denotes (mirrors train()'s dnn branch)."""
    return [config[f"h{i}"] for i in range(int(config.get("n_layers", 0)))
            if config.get(f"h{i}", 0) > 0]


def _dnn_job(data: Dataset, config: dict, algorithm: str
             ) -> tuple[list[int], float, int, int]:
    """(widths, lr, batch, nsteps) exactly as the sequential path computes
    them — the bucket key and the cache key both hang off these.  The
    defaults here MUST mirror train()'s dnn branch / train_logreg /
    train_dnn (drift breaks the batched==sequential contract, caught by
    tests/test_dse_parallel.py)."""
    F, C = data.num_features, data.num_classes
    if algorithm == "logreg":
        widths = [F, C]
        lr, batch, epochs = float(config.get("lr", 0.1)), 256, 30
    else:
        widths = [F] + _dnn_hidden(config) + [C]
        lr = float(config.get("lr", 3e-3))
        batch = int(config.get("batch", 256))
        epochs = int(config.get("epochs", 12))
    nsteps = max(1, epochs * len(data.train_x) // batch)
    return widths, lr, batch, int(nsteps)


def _pad_mlp_params(params: list[dict], widths: list[int],
                    padded: list[int]) -> tuple[list[dict], list[dict]]:
    """Zero-pad per-layer params into the bucket shape + matching 0/1 masks."""
    pp, mm = [], []
    for i in range(len(padded) - 1):
        w = np.zeros((padded[i], padded[i + 1]), np.float32)
        b = np.zeros((padded[i + 1],), np.float32)
        mw, mb = np.zeros_like(w), np.zeros_like(b)
        w[: widths[i], : widths[i + 1]] = np.asarray(params[i]["w"])
        b[: widths[i + 1]] = np.asarray(params[i]["b"])
        mw[: widths[i], : widths[i + 1]] = 1.0
        mb[: widths[i + 1]] = 1.0
        pp.append({"w": w, "b": b})
        mm.append({"w": mw, "b": mb})
    return pp, mm


def train_dnn_batch(data: Dataset, configs: list[dict], *, seed: int = 0,
                    algorithm: str = "dnn") -> list[TrainedModel]:
    """Train many DNN/logreg candidates with one vmapped run per bucket."""
    out: list[TrainedModel | None] = [None] * len(configs)
    jobs = [(ci, *_dnn_job(data, cfg, algorithm)) for ci, cfg in
            enumerate(configs)]
    buckets: dict[tuple, list[tuple]] = {}
    for job in jobs:
        ci, widths, lr, batch, nsteps = job
        buckets.setdefault((len(widths), batch, nsteps), []).append(job)

    x = jnp.asarray(data.train_x)
    y = jnp.asarray(data.train_y)
    C = data.num_classes
    for (_, batch, nsteps), js in buckets.items():
        padded = [max(j[1][i] for j in js) for i in range(len(js[0][1]))]
        inits, masks = [], []
        for _, widths, _, _, _ in js:
            p = _mlp_init(jax.random.PRNGKey(seed), widths)
            pp, mm = _pad_mlp_params(p, widths, padded)
            inits.append(pp)
            masks.append(mm)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *inits)
        mstacked = jax.tree.map(lambda *xs: jnp.stack(xs), *masks)
        lrs = jnp.asarray([j[2] for j in js], jnp.float32)
        trained = _mlp_train_bucket(
            stacked, mstacked, x, y, jax.random.PRNGKey(seed + 1), lrs,
            nsteps=nsteps, batch=batch,
        )
        trained = jax.tree.map(np.asarray, trained)
        for lane, (ci, widths, _, _, _) in enumerate(js):
            p = [
                {"w": layer["w"][lane][: widths[i], : widths[i + 1]].copy(),
                 "b": layer["b"][lane][: widths[i + 1]].copy()}
                for i, layer in enumerate(trained)
            ]
            tm = _finalize_dnn(p, widths, C, dict(configs[ci]))
            tm.algorithm = algorithm
            out[ci] = tm
    return out


def train_batch(algorithm: str, data: Dataset, configs: list[dict], *,
                seed: int = 0, workers: int | None = None
                ) -> list[TrainedModel]:
    """Population-parallel ``train``: vmapped buckets for dnn/logreg, a
    thread pool fanning out the numpy algorithms."""
    if not configs:
        return []
    if algorithm in ("dnn", "logreg"):
        return train_dnn_batch(data, configs, seed=seed, algorithm=algorithm)
    if len(configs) == 1:
        return [train(algorithm, data, configs[0], seed=seed)]
    import concurrent.futures
    import os

    workers = workers or min(8, os.cpu_count() or 1, len(configs))
    with concurrent.futures.ThreadPoolExecutor(workers) as pool:
        return list(pool.map(
            lambda cfg: train(algorithm, data, cfg, seed=seed), configs
        ))


def effective_config(algorithm: str, config: dict, data: Dataset) -> dict:
    """The subset of a DSE config that actually reaches ``train`` — the
    content half of the trained-candidate cache key.  Two configs with the
    same effective form train to the same model (e.g. dnn h_i beyond
    n_layers are dead parameters)."""
    if algorithm == "dnn":
        widths, lr, batch, nsteps = _dnn_job(data, config, algorithm)
        return {"widths": widths, "lr": lr, "batch": batch, "nsteps": nsteps}
    if algorithm == "logreg":
        return {"lr": float(config.get("lr", 0.1))}
    if algorithm == "kmeans":
        n_feat = int(config.get("n_features", data.num_features))
        return {"k": int(config["k"]),
                "n_features": min(n_feat, data.num_features)}
    if algorithm == "svm":
        return {"c_reg": float(config.get("c_reg", 1.0))}
    if algorithm == "tree":
        return {"max_depth": int(config.get("max_depth", 6))}
    raise KeyError(algorithm)


# ----------------------------------------------------------------- KMeans


def train_kmeans(
    data: Dataset, *, k: int, iters: int = 50, seed: int = 0,
    feature_idx: list[int] | None = None, config: dict | None = None,
) -> TrainedModel:
    rng = np.random.default_rng(seed)
    X = data.train_x if feature_idx is None else data.train_x[:, feature_idx]
    init = X[rng.choice(len(X), size=k, replace=False)]
    cent = init.copy()
    for _ in range(iters):
        d = ((X[:, None, :] - cent[None]) ** 2).sum(-1)
        a = d.argmin(1)
        for j in range(k):
            pts = X[a == j]
            if len(pts):
                cent[j] = pts.mean(0)
    # majority-label map cluster -> class (for classification use)
    d = ((X[:, None, :] - cent[None]) ** 2).sum(-1)
    a = d.argmin(1)
    label_map = np.zeros(k, np.int32)
    for j in range(k):
        ys = data.train_y[a == j]
        label_map[j] = np.bincount(ys, minlength=data.num_classes).argmax() \
            if len(ys) else 0

    def assign(X_):
        X_ = X_ if feature_idx is None else X_[:, feature_idx]
        return ((X_[:, None, :] - cent[None]) ** 2).sum(-1).argmin(1)

    def predict(X_):
        return label_map[assign(X_)]

    tm = TrainedModel(
        "kmeans",
        {"k": k, "n_features": cent.shape[1], "feature_idx": feature_idx},
        {"centroids": cent, "label_map": label_map},
        predict, int(cent.size), data.num_classes,
        config or {"k": k},
    )
    tm.topology["assign"] = assign  # raw cluster ids for v_measure
    return tm


# -------------------------------------------------------------- linear SVM


def train_svm(
    data: Dataset, *, c_reg: float = 1.0, epochs: int = 20, lr: float = 1e-2,
    seed: int = 0, config: dict | None = None,
) -> TrainedModel:
    """One-vs-rest linear SVM via hinge-loss SGD (numpy)."""
    rng = np.random.default_rng(seed)
    X, y = data.train_x, data.train_y
    N, F = X.shape
    C = data.num_classes
    W = np.zeros((F, C), np.float32)
    b = np.zeros(C, np.float32)
    Y = np.where(y[:, None] == np.arange(C)[None], 1.0, -1.0).astype(np.float32)
    for ep in range(epochs):
        perm = rng.permutation(N)
        for start in range(0, N, 512):
            idx = perm[start:start + 512]
            s = X[idx] @ W + b  # [b, C]
            margin = Y[idx] * s
            active = (margin < 1.0).astype(np.float32)
            gW = -(X[idx].T @ (active * Y[idx])) / len(idx) + W / (c_reg * N)
            gb = -(active * Y[idx]).mean(0)
            W -= lr * gW
            b -= lr * gb

    def predict(X_):
        return np.argmax(X_ @ W + b, 1).astype(np.int32)

    return TrainedModel(
        "svm", {"n_features": F, "n_classes": C},
        {"W": W, "b": b}, predict, int(W.size + b.size), C,
        config or {"c_reg": c_reg},
    )


# ---------------------------------------------------------- decision tree


def train_tree(
    data: Dataset, *, max_depth: int = 6, min_leaf: int = 16, seed: int = 0,
    config: dict | None = None,
) -> TrainedModel:
    """CART (gini) classifier; nodes stored flat for MAT codegen."""
    X, y = data.train_x, data.train_y
    C = data.num_classes
    nodes: list[dict] = []  # {feat, thr, left, right, leaf_class}

    def gini(ys):
        if len(ys) == 0:
            return 0.0
        p = np.bincount(ys, minlength=C) / len(ys)
        return 1.0 - np.sum(p * p)

    def build(idx, depth) -> int:
        ys = y[idx]
        node_id = len(nodes)
        nodes.append({})
        if depth >= max_depth or len(idx) < 2 * min_leaf or gini(ys) < 1e-6:
            nodes[node_id] = {"leaf": int(np.bincount(ys, minlength=C).argmax())}
            return node_id
        best = (None, None, np.inf)
        for f in range(X.shape[1]):
            vals = X[idx, f]
            qs = np.quantile(vals, np.linspace(0.1, 0.9, 9))
            for thr in qs:
                l = idx[vals <= thr]
                r = idx[vals > thr]
                if len(l) < min_leaf or len(r) < min_leaf:
                    continue
                score = (len(l) * gini(y[l]) + len(r) * gini(y[r])) / len(idx)
                if score < best[2]:
                    best = (f, thr, score)
        if best[0] is None:
            nodes[node_id] = {"leaf": int(np.bincount(ys, minlength=C).argmax())}
            return node_id
        f, thr, _ = best
        # thresholds live at f32 so the numpy walk and the jitted
        # TreeTraverse stage (f32 compare) make identical split decisions
        thr = float(np.float32(thr))
        l_id = build(idx[X[idx, f] <= thr], depth + 1)
        r_id = build(idx[X[idx, f] > thr], depth + 1)
        nodes[node_id] = {"feat": int(f), "thr": thr,
                          "left": l_id, "right": r_id}
        return node_id

    build(np.arange(len(X)), 0)

    def predict(X_):
        out = np.zeros(len(X_), np.int32)
        for i, row in enumerate(X_):
            nid = 0
            while "leaf" not in nodes[nid]:
                nd = nodes[nid]
                nid = nd["left"] if row[nd["feat"]] <= nd["thr"] else nd["right"]
            out[i] = nodes[nid]["leaf"]
        return out

    depth_used = max_depth
    return TrainedModel(
        "tree", {"nodes": nodes, "depth": depth_used},
        {"nodes": nodes}, predict, len(nodes), C,
        config or {"max_depth": max_depth},
    )


# ------------------------------------------------------- logistic regression


def train_logreg(
    data: Dataset, *, lr: float = 0.1, epochs: int = 30, seed: int = 0,
    config: dict | None = None,
) -> TrainedModel:
    tm = train_dnn(data, hidden=[], lr=lr, epochs=epochs, seed=seed,
                   config=config or {})
    tm.algorithm = "logreg"
    return tm


# ------------------------------------------------------------------ train()

SUPPORTED_ALGORITHMS = ["dnn", "kmeans", "svm", "tree", "logreg"]


def train(algorithm: str, data: Dataset, config: dict, *, seed: int = 0
          ) -> TrainedModel:
    """Uniform entry point the DSE loop calls with a BO-suggested config."""
    if algorithm == "dnn":
        hidden = _dnn_hidden(config)
        return train_dnn(
            data, hidden=hidden, lr=config.get("lr", 3e-3),
            batch=config.get("batch", 256), epochs=config.get("epochs", 12),
            seed=seed, config=config,
        )
    if algorithm == "kmeans":
        n_feat = config.get("n_features", data.num_features)
        fi = list(range(n_feat)) if n_feat < data.num_features else None
        return train_kmeans(data, k=config["k"], seed=seed, feature_idx=fi,
                            config=config)
    if algorithm == "svm":
        return train_svm(data, c_reg=config.get("c_reg", 1.0), seed=seed,
                         config=config)
    if algorithm == "tree":
        return train_tree(data, max_depth=config.get("max_depth", 6),
                          seed=seed, config=config)
    if algorithm == "logreg":
        return train_logreg(data, lr=config.get("lr", 0.1), seed=seed,
                            config=config)
    raise KeyError(algorithm)
