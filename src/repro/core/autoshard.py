"""Beyond-paper: Homunculus's constrained BO driving LM sharding DSE.

The paper's loop is  suggest -> codegen -> compile -> feasibility verdict ->
update surrogate.  Here the "program" is a (mesh layout x microbatch x remat
x sharding-rule) configuration for one of the assigned architectures, the
"compiler in the loop" is XLA itself (.lower().compile() on the forced-
device-count host, exactly the multi-pod dry-run), the feasibility
constraint is fits-in-HBM (memory_analysis peak <= per-chip budget), and the
objective is minimizing the dominant roofline term (launch.hlo_cost over the
partitioned module).

This is the paper's technique applied at datacenter scale: a network
operator writes ``Model`` + ``Platforms.TPUPod() < {...}`` and Homunculus
searches the layout space instead of the neuron space.  It is also the
engine behind the §Perf hillclimb in EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax

from repro.configs import SHAPES, get_config
from repro.core.bo import ConstrainedBO
from repro.core.designspace import DesignSpace, Param
from repro.dist.sharding import AxisRules, DEFAULT_RULES, mesh_context
from repro.launch import hlo_cost
from repro.launch.mesh import make_mesh_shape, sharding_tree

HBM_BYTES = 16 * 2**30          # per chip (v5e-class)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def layout_space(total_chips: int = 256) -> DesignSpace:
    """The sharding design space: (dp x tp) factorizations + step knobs."""
    factorizations = []
    d = 1
    while d <= total_chips:
        factorizations.append((d, total_chips // d))
        d *= 2
    return DesignSpace([
        Param("layout", "categorical", values=tuple(factorizations)),
        Param("microbatches", "ordinal", values=(1, 2, 4, 8, 16)),
        Param("remat", "categorical", values=("none", "dots", "block")),
        Param("seq_shard", "categorical", values=(False, True)),
    ])


@dataclasses.dataclass
class LayoutResult:
    config: dict
    feasible: bool
    peak_bytes: float
    t_compute: float
    t_memory: float
    t_collective: float
    wall_s: float
    error: str = ""

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute, "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)


def evaluate_layout(
    arch: str,
    shape_name: str,
    config: dict,
    *,
    hbm_budget: float = HBM_BYTES,
) -> LayoutResult:
    """One black-box evaluation: compile the cell under ``config``."""
    import dataclasses as dc

    from repro.launch.dryrun import build_step_and_specs

    t0 = time.perf_counter()
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    dp, tp = config["layout"]
    cfg = dc.replace(
        cfg,
        remat_policy=config.get("remat", cfg.remat_policy),
        decode_seq_shard=config.get("seq_shard", cfg.decode_seq_shard),
    )
    rules = DEFAULT_RULES
    if not config.get("seq_shard", True):
        rules = AxisRules({**DEFAULT_RULES.table})
        rules.table.pop("sp", None)
    mesh = make_mesh_shape((dp, tp), ("data", "model"))
    try:
        with mesh, mesh_context(mesh, rules):
            fn, args, in_sh, out_sh, donate = build_step_and_specs(
                cfg, shape, mesh,
                microbatches=config.get("microbatches"), rules=rules,
            )
            compiled = (
                jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                        donate_argnums=donate)
                .lower(*args).compile()
            )
        ma = compiled.memory_analysis()
        peak = (
            ma.argument_size_in_bytes + ma.temp_size_in_bytes
            + ma.output_size_in_bytes - ma.alias_size_in_bytes
        )
        rep = hlo_cost.analyze(compiled.as_text(), mesh.size)
        return LayoutResult(
            config=config,
            feasible=peak <= hbm_budget,
            peak_bytes=peak,
            t_compute=rep.flops / PEAK_FLOPS,
            t_memory=rep.hbm_bytes / HBM_BW,
            t_collective=rep.coll_wire_bytes_bf16 / LINK_BW,
            wall_s=time.perf_counter() - t0,
        )
    except Exception as e:  # noqa: BLE001 — infeasible layout, not a crash
        return LayoutResult(
            config=config, feasible=False, peak_bytes=float("inf"),
            t_compute=0.0, t_memory=0.0, t_collective=float("inf"),
            wall_s=time.perf_counter() - t0, error=f"{type(e).__name__}: {e}",
        )


def autoshard(
    arch: str,
    shape_name: str,
    *,
    budget: int = 12,
    n_init: int = 4,
    total_chips: int = 256,
    hbm_budget: float = HBM_BYTES,
    seed: int = 0,
    callback=None,
) -> tuple[LayoutResult | None, list[LayoutResult]]:
    """BO over layouts; returns (best, all evaluated)."""
    space = layout_space(total_chips)
    bo = ConstrainedBO(space, n_init=n_init, seed=seed)
    evaluated: list[LayoutResult] = []

    def evaluate(config: dict) -> tuple[float, bool, dict]:
        res = evaluate_layout(arch, shape_name, config,
                              hbm_budget=hbm_budget)
        evaluated.append(res)
        if callback:
            callback(res)
        # maximize negative bound time (BO maximizes)
        value = -res.t_bound if res.feasible else float("nan")
        return value, res.feasible, {"result": res}

    best_obs = bo.run(evaluate, budget)
    best = best_obs.info["result"] if best_obs else None
    return best, evaluated
