"""The Homunculus generation driver (paper §3.2): candidate selection,
BO-guided DSE, feasibility testing, and final code generation.

``generate(platform)`` is the paper's ``homunculus.generate``:

  1. flatten the scheduled Model/DAG into leaf models;
  2. per model, per candidate algorithm: build the design space (§3.2.2),
     pre-prune algorithms whose *minimal* configuration already violates the
     platform (the paper's "rule out as many algorithms as possible");
  3. race a ConstrainedBO per algorithm (the paper runs "multiple parallel
     runs", footnote 1);  evaluate = train -> metric  x  platform.check ->
     feasible;
  4. pick the best feasible configuration across algorithms, codegen the
     pipeline (§3.3), attach regret curves (Fig. 4) and the per-iteration
     history.

Multi-model scheduling: each of the n scheduled models is allocated 1/n of
the platform's resources during its own search (the paper's §5.1.3 split),
and the final DAG report merges resources with *identical-model dedup* —
chained copies of one model share weights and pipeline logic on the target,
which is why the paper's Table 3 resource count stays constant across
chaining strategies.
"""

from __future__ import annotations

import copy
import dataclasses
import time
from typing import Callable

import numpy as np

from repro.core import codegen, mlalgos
from repro.core.alchemy import Model, Par, Platform, Seq
from repro.core.bo import ConstrainedBO, Observation
from repro.core.designspace import algorithm_space
from repro.core.feasibility import FeasibilityReport

# ------------------------------------------------------------------ result


@dataclasses.dataclass
class ModelResult:
    name: str
    algorithm: str
    trained: mlalgos.TrainedModel
    pipeline: codegen.Pipeline
    report: FeasibilityReport
    value: float                  # best feasible objective
    metric: str
    history: list[Observation]
    regret: list[float]
    wall_s: float

    def summary(self) -> dict:
        return {
            "name": self.name,
            "algorithm": self.algorithm,
            "metric": self.metric,
            "value": round(self.value, 4),
            "params": self.trained.param_count,
            "stages": self.pipeline.stage_summary()["stages"],
            "resources": self.report.resources,
            "latency_ns": round(self.report.latency_ns, 1),
            "throughput_pps": self.report.throughput_pps,
            "iterations": len(self.history),
        }


@dataclasses.dataclass
class GenerationResult:
    platform_kind: str
    models: dict[str, ModelResult]
    dag_report: FeasibilityReport | None
    schedule: str

    def __getitem__(self, name: str) -> ModelResult:
        return self.models[name]

    def summary(self) -> dict:
        return {
            "platform": self.platform_kind,
            "schedule": self.schedule,
            "models": {k: v.summary() for k, v in self.models.items()},
            "dag_resources": self.dag_report.resources if self.dag_report else None,
        }


# --------------------------------------------------------------- evaluate


def _metric_value(metric: str, trained: mlalgos.TrainedModel, data) -> float:
    if metric == "v_measure" and trained.algorithm == "kmeans":
        clusters = trained.topology["assign"](data.test_x)
        return mlalgos.v_measure(data.test_y, clusters)
    y_pred = trained.predict(data.test_x)
    return mlalgos.evaluate_metric(
        metric, data.test_y, y_pred, num_classes=data.num_classes
    )


def make_evaluator(
    platform: Platform,
    algorithm: str,
    data,
    metric: str,
    *,
    seed: int = 0,
) -> Callable[[dict], tuple[float, bool, dict]]:
    """The black box f: config -> (objective, feasible, info)  (§3.2.3)."""

    def evaluate(config: dict) -> tuple[float, bool, dict]:
        trained = mlalgos.train(algorithm, data, config, seed=seed)
        rep = platform.check(algorithm, trained.topology)
        value = _metric_value(metric, trained, data)
        return value, rep.feasible, {
            "trained": trained,
            "report": rep,
            "params": trained.param_count,
        }

    return evaluate


def _min_config(algorithm: str, space) -> dict:
    """Smallest configuration in the space (for algorithm pre-pruning)."""
    cfg = {}
    for p in space.params:
        if p.kind in ("ordinal", "categorical"):
            cfg[p.name] = p.values[0]
        elif p.kind == "int":
            cfg[p.name] = int(p.low)
        else:
            cfg[p.name] = float(p.low)
    if algorithm == "dnn":
        cfg["n_layers"] = 1
    return cfg


def _seed_configs(algorithm: str, space) -> list[dict]:
    """Small-model seeds for the BO init phase (paper §3.2.2: bounds are
    "calculated based on the target").  On tight targets a uniform-random
    init may never hit the feasible region (e.g. 30-feature DNNs at II=1 on
    a 16x16 grid); seeding a ladder of small nets anchors the feasibility
    classifier wherever a feasible model exists."""
    seeds = [_min_config(algorithm, space)]
    if algorithm == "dnn":
        base = _min_config(algorithm, space)
        for layers, width in ((1, 16), (2, 8), (2, 16), (3, 8)):
            c = dict(base)
            c["n_layers"] = layers
            for i in range(layers):
                c[f"h{i}"] = width
            seeds.append(c)
    return seeds


def _prune_algorithms(platform: Platform, algorithms: list[str], data
                      ) -> tuple[list[str], dict[str, str]]:
    """Paper §3.2.1: drop algorithms whose minimal config can't fit."""
    kept, dropped = [], {}
    for algo in algorithms:
        if algo not in platform.supported_algorithms():
            dropped[algo] = "not supported by backend"
            continue
        space = algorithm_space(
            algo, n_features=data.num_features, num_classes=data.num_classes
        )
        probe = _min_config(algo, space)
        # structural probe: topology of the minimal model without training
        topo = _probe_topology(algo, probe, data)
        rep = platform.check(algo, topo)
        if rep.feasible:
            kept.append(algo)
        else:
            dropped[algo] = "; ".join(rep.reasons)
    return kept, dropped


def _probe_topology(algo: str, cfg: dict, data) -> dict:
    F, C = data.num_features, data.num_classes
    if algo in ("dnn", "logreg"):
        hidden = (
            [cfg.get("h0", 4)] * cfg.get("n_layers", 1) if algo == "dnn" else []
        )
        return {"widths": [F] + hidden + [C], "act": "relu"}
    if algo == "kmeans":
        return {"k": cfg.get("k", 1), "n_features": cfg.get("n_features", F)}
    if algo == "svm":
        return {"n_features": F, "n_classes": C}
    if algo == "tree":
        d = cfg.get("max_depth", 2)
        return {"nodes": [{}] * (2 ** (d + 1) - 1), "depth": d}
    raise KeyError(algo)


# ----------------------------------------------------------------- search


def search_model(
    platform: Platform,
    model: Model,
    *,
    budget: int = 30,
    n_init: int = 8,
    seed: int = 0,
    max_neurons: int = 64,
    callback=None,
) -> ModelResult:
    """Run the full DSE for one Model on one platform."""
    t0 = time.perf_counter()
    data = model.data()
    metric = model.objective
    algorithms = model.algorithms or platform.supported_algorithms()
    algorithms, dropped = _prune_algorithms(platform, algorithms, data)
    if not algorithms:
        raise RuntimeError(
            f"no candidate algorithm is feasible on {platform.kind}: {dropped}"
        )

    best: tuple[float, str, Observation, ConstrainedBO] | None = None
    histories: list[Observation] = []
    regret: list[float] = []
    # race the algorithms (paper: parallel runs; here round-robin budget)
    for ai, algo in enumerate(algorithms):
        space = algorithm_space(
            algo, n_features=data.num_features,
            num_classes=data.num_classes, max_neurons=max_neurons,
        )
        bo = ConstrainedBO(space, n_init=n_init, seed=seed + 17 * ai)
        evaluate = make_evaluator(platform, algo, data, metric, seed=seed)
        algo_budget = max(4, budget // len(algorithms))
        # seed the history with small-model anchors (count against budget)
        for sc in _seed_configs(algo, space)[:max(2, algo_budget // 4)]:
            value, feasible, info = evaluate(sc)
            bo.observe(sc, value, feasible, info)
            algo_budget -= 1
        bo.run(
            evaluate, max(algo_budget, 2),
            callback=(lambda it, obs: callback(algo, it, obs))
            if callback else None,
        )
        histories += bo.history
        prev = regret[-1] if regret else -np.inf
        for o in bo.history:
            if o.feasible and np.isfinite(o.value):
                prev = max(prev, o.value)
            regret.append(prev)
        if bo.best is not None and (best is None or bo.best.value > best[0]):
            best = (bo.best.value, algo, bo.best, bo)

    if best is None:
        raise RuntimeError(
            f"{model.name}: no feasible configuration found in {budget} "
            f"iterations on {platform.kind} (constraints {platform.performance}"
            f" / {platform.resources})"
        )

    value, algo, obs, _ = best
    trained = obs.info["trained"]
    report = obs.info["report"]
    pipeline = codegen.generate_pipeline(
        platform.kind, model.name, trained, report, data.train_x
    )
    return ModelResult(
        name=model.name, algorithm=algo, trained=trained,
        pipeline=pipeline, report=report, value=value, metric=metric,
        history=histories, regret=regret,
        wall_s=time.perf_counter() - t0,
    )


# ------------------------------------------------------------ generate()


def _split_platform(platform: Platform, n: int) -> Platform:
    """Allocate 1/n of the platform resources to one model (§5.1.3)."""
    if n <= 1:
        return platform
    p = copy.deepcopy(platform)
    if platform.kind == "taurus":
        p.model.rows = max(1, p.model.rows // n)
    elif platform.kind == "tofino":
        p.model.num_tables = max(1, p.model.num_tables // n)
    elif platform.kind == "fpga":
        p.model.total_luts //= n
        p.model.total_ffs //= n
    elif platform.kind == "tpu":
        p.model.vmem_bytes //= n
    return p


def _dag_report(node, results: dict[str, ModelResult]) -> FeasibilityReport:
    """Merge reports over the DAG with identical-model dedup (Table 3)."""
    leaves = node.leaves()
    seen: set[int] = set()
    rep: FeasibilityReport | None = None
    for m in leaves:
        r = results[m.name]
        key = id(r.trained)
        if key in seen:
            continue  # chained copy shares weights + pipeline logic
        seen.add(key)
        rep = r.report if rep is None else rep.merge(r.report)
    assert rep is not None
    return rep


def generate(
    platform: Platform,
    *,
    budget: int = 30,
    n_init: int = 8,
    seed: int = 0,
    max_neurons: int = 64,
    callback=None,
) -> GenerationResult:
    """The paper's ``homunculus.generate(platform)``."""
    assert platform.scheduled is not None, "call platform.schedule(...) first"
    node = platform.scheduled
    leaves = node.leaves()
    # dedup: chained copies of the same Model object search once
    unique: dict[int, Model] = {}
    for m in leaves:
        unique.setdefault(id(m), m)
    sub = _split_platform(platform, len(unique))

    results: dict[str, ModelResult] = {}
    for m in unique.values():
        res = search_model(
            sub, m, budget=budget, n_init=n_init, seed=seed,
            max_neurons=max_neurons, callback=callback,
        )
        results[m.name] = res
    # alias results for duplicate leaf names (chained copies)
    for m in leaves:
        if m.name not in results:
            twin = unique[id(m)]
            results[m.name] = results[twin.name]

    dag_rep = _dag_report(node, results)
    out = GenerationResult(
        platform_kind=platform.kind,
        models=results,
        dag_report=dag_rep,
        schedule=node.describe(),
    )
    platform.generated = out
    return out
