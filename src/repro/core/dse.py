"""The Homunculus generation driver (paper §3.2): candidate selection,
BO-guided DSE, feasibility testing, and final code generation.

``generate(platform)`` is the paper's ``homunculus.generate``:

  1. flatten the scheduled Model/DAG into leaf models;
  2. per model, per candidate algorithm: build the design space (§3.2.2),
     pre-prune algorithms whose *minimal* configuration already violates the
     platform (the paper's "rule out as many algorithms as possible");
  3. race a ConstrainedBO per algorithm (the paper runs "multiple parallel
     runs", footnote 1) in interleaved rounds: each live racer proposes a
     *batch* of K configurations per round (q-EI fantasies), the batch is
     trained population-parallel (vmapped buckets for DNN/logreg, a worker
     pool for the numpy algorithms, all behind the content-addressed
     trained-candidate cache) and feasibility-checked in one pass
     (``platform.check_batch`` reads stage metadata for the whole batch);
  4. pick the best feasible configuration across algorithms, codegen the
     pipeline (§3.3), attach regret curves (Fig. 4) and the per-iteration
     history.

``eval_mode="sequential"`` trains the *same proposal stream* one config at
a time through ``mlalgos.train`` — the reference path the batched engine is
tested against (same best config under a fixed seed).

Multi-model scheduling: each of the n scheduled models is allocated 1/n of
the platform's resources during its own search (the paper's §5.1.3 split),
and the final DAG report merges resources with *identical-model dedup* —
chained copies of one model share weights and pipeline logic on the target,
which is why the paper's Table 3 resource count stays constant across
chaining strategies.
"""

from __future__ import annotations

import copy
import dataclasses
import time
import numpy as np

from repro.core import codegen, mlalgos
from repro.core.alchemy import Model, Par, Platform, Seq
from repro.core.bo import ConstrainedBO, Observation
from repro.core.designspace import algorithm_space
from repro.core.feasibility import FeasibilityReport
from repro.core.traincache import (
    GLOBAL_CACHE,
    CandidateCache,
    candidate_key,
)

# ------------------------------------------------------------------ result


@dataclasses.dataclass
class ModelResult:
    name: str
    algorithm: str
    trained: mlalgos.TrainedModel
    pipeline: codegen.Pipeline
    report: FeasibilityReport
    value: float                  # best feasible objective
    metric: str
    history: list[Observation]
    regret: list[float]
    wall_s: float

    def summary(self) -> dict:
        return {
            "name": self.name,
            "algorithm": self.algorithm,
            "metric": self.metric,
            "value": round(self.value, 4),
            "params": self.trained.param_count,
            "stages": self.pipeline.stage_summary()["stages"],
            "resources": self.report.resources,
            "latency_ns": round(self.report.latency_ns, 1),
            "throughput_pps": self.report.throughput_pps,
            "iterations": len(self.history),
        }


@dataclasses.dataclass
class GenerationResult:
    platform_kind: str
    models: dict[str, ModelResult]
    dag_report: FeasibilityReport | None
    schedule: str

    def __getitem__(self, name: str) -> ModelResult:
        return self.models[name]

    def summary(self) -> dict:
        return {
            "platform": self.platform_kind,
            "schedule": self.schedule,
            "models": {k: v.summary() for k, v in self.models.items()},
            "dag_resources": self.dag_report.resources if self.dag_report else None,
        }


# --------------------------------------------------------------- evaluate


def _metric_value(metric: str, trained: mlalgos.TrainedModel, data) -> float:
    if metric == "v_measure" and trained.algorithm == "kmeans":
        clusters = trained.topology["assign"](data.test_x)
        return mlalgos.v_measure(data.test_y, clusters)
    y_pred = trained.predict(data.test_x)
    return mlalgos.evaluate_metric(
        metric, data.test_y, y_pred, num_classes=data.num_classes
    )


def evaluate_candidates(
    platform: Platform,
    algorithm: str,
    data,
    metric: str,
    configs: list[dict],
    *,
    seed: int = 0,
    mode: str = "batched",
    cache: CandidateCache | None = GLOBAL_CACHE,
    workers: int | None = None,
) -> list[tuple[float, bool, dict]]:
    """Evaluate a whole proposal batch — the black box f of §3.2.3, one
    round at a time: resolve the trained-candidate cache, train the misses
    (``mode="batched"``: vmapped buckets / worker pool;
    ``mode="sequential"``: one ``mlalgos.train`` call each — the reference
    path), then feasibility-check every topology in one ``check_batch``.
    Results come back in proposal order.  ``cache``: the process-wide
    ``GLOBAL_CACHE`` by default, any private ``CandidateCache``, or ``None``
    to disable memoization."""
    keys = [
        candidate_key(algorithm, c, seed, data) if cache is not None else None
        for c in configs
    ]
    trained: list[mlalgos.TrainedModel | None] = [
        cache.get(k) if cache is not None else None for k in keys
    ]
    # unique misses (first occurrence trains; duplicates share the result)
    miss_idx: list[int] = []
    first_of: dict[str, int] = {}
    for i, tm in enumerate(trained):
        if tm is not None:
            continue
        k = keys[i]
        if k is not None:
            if k in first_of:
                continue
            first_of[k] = i
        miss_idx.append(i)

    miss_cfgs = [configs[i] for i in miss_idx]
    if mode == "sequential":
        fresh = [mlalgos.train(algorithm, data, c, seed=seed)
                 for c in miss_cfgs]
    elif mode == "batched":
        fresh = mlalgos.train_batch(algorithm, data, miss_cfgs, seed=seed,
                                    workers=workers)
    else:
        raise KeyError(f"eval_mode {mode!r} (batched|sequential)")
    for i, tm in zip(miss_idx, fresh):
        trained[i] = tm
        if cache is not None:
            cache.put(keys[i], tm)
    for i, tm in enumerate(trained):
        if tm is None:  # in-batch duplicate of a fresh miss
            trained[i] = trained[first_of[keys[i]]]

    reports = platform.check_batch(
        algorithm, [tm.topology for tm in trained]
    )
    return [
        (
            _metric_value(metric, tm, data),
            rep.feasible,
            {"trained": tm, "report": rep, "params": tm.param_count},
        )
        for tm, rep in zip(trained, reports)
    ]


def _min_config(algorithm: str, space) -> dict:
    """Smallest configuration in the space (for algorithm pre-pruning)."""
    cfg = {}
    for p in space.params:
        if p.kind in ("ordinal", "categorical"):
            cfg[p.name] = p.values[0]
        elif p.kind == "int":
            cfg[p.name] = int(p.low)
        else:
            cfg[p.name] = float(p.low)
    if algorithm == "dnn":
        cfg["n_layers"] = 1
    return cfg


def _seed_configs(algorithm: str, space) -> list[dict]:
    """Small-model seeds for the BO init phase (paper §3.2.2: bounds are
    "calculated based on the target").  On tight targets a uniform-random
    init may never hit the feasible region (e.g. 30-feature DNNs at II=1 on
    a 16x16 grid); seeding a ladder of small nets anchors the feasibility
    classifier wherever a feasible model exists."""
    seeds = [_min_config(algorithm, space)]
    if algorithm == "dnn":
        base = _min_config(algorithm, space)
        for layers, width in ((1, 16), (2, 8), (2, 16), (3, 8)):
            c = dict(base)
            c["n_layers"] = layers
            for i in range(layers):
                c[f"h{i}"] = width
            seeds.append(c)
    return seeds


def _prune_algorithms(platform: Platform, algorithms: list[str], data
                      ) -> tuple[list[str], dict[str, str]]:
    """Paper §3.2.1: drop algorithms whose minimal config can't fit."""
    kept, dropped = [], {}
    for algo in algorithms:
        if algo not in platform.supported_algorithms():
            dropped[algo] = "not supported by backend"
            continue
        space = algorithm_space(
            algo, n_features=data.num_features, num_classes=data.num_classes
        )
        probe = _min_config(algo, space)
        # structural probe: topology of the minimal model without training
        topo = _probe_topology(algo, probe, data)
        rep = platform.check(algo, topo)
        if rep.feasible:
            kept.append(algo)
        else:
            dropped[algo] = "; ".join(rep.reasons)
    return kept, dropped


def _probe_topology(algo: str, cfg: dict, data) -> dict:
    F, C = data.num_features, data.num_classes
    if algo in ("dnn", "logreg"):
        hidden = (
            [cfg.get("h0", 4)] * cfg.get("n_layers", 1) if algo == "dnn" else []
        )
        return {"widths": [F] + hidden + [C], "act": "relu"}
    if algo == "kmeans":
        return {"k": cfg.get("k", 1), "n_features": cfg.get("n_features", F)}
    if algo == "svm":
        return {"n_features": F, "n_classes": C}
    if algo == "tree":
        d = cfg.get("max_depth", 2)
        return {"nodes": [{}] * (2 ** (d + 1) - 1), "depth": d}
    raise KeyError(algo)


# ----------------------------------------------------------------- search


@dataclasses.dataclass
class _Racer:
    """One algorithm's lane in the round-interleaved BO race."""

    algorithm: str
    bo: ConstrainedBO
    pending_seeds: list[dict]
    remaining: int
    iteration: int = 0


def search_model(
    platform: Platform,
    model: Model,
    *,
    budget: int = 30,
    n_init: int = 8,
    seed: int = 0,
    max_neurons: int = 64,
    callback=None,
    eval_mode: str = "batched",
    batch_k: int = 8,
    cache: CandidateCache | None = GLOBAL_CACHE,
    workers: int | None = None,
) -> ModelResult:
    """Run the full DSE for one Model on one platform.

    Racers are interleaved round-robin; each round a live racer proposes up
    to ``batch_k`` configs (``suggest_batch``) which are evaluated together
    by ``evaluate_candidates``.  Per-algorithm budgets and the small-model
    seed anchors match the sequential engine eval-for-eval, so regret
    curves remain comparable across modes.
    """
    t0 = time.perf_counter()
    data = model.data()
    metric = model.objective
    algorithms = model.algorithms or platform.supported_algorithms()
    algorithms, dropped = _prune_algorithms(platform, algorithms, data)
    if not algorithms:
        raise RuntimeError(
            f"no candidate algorithm is feasible on {platform.kind}: {dropped}"
        )

    racers: list[_Racer] = []
    for ai, algo in enumerate(algorithms):
        space = algorithm_space(
            algo, n_features=data.num_features,
            num_classes=data.num_classes, max_neurons=max_neurons,
        )
        bo = ConstrainedBO(space, n_init=n_init, seed=seed + 17 * ai)
        algo_budget = max(4, budget // len(algorithms))
        # small-model anchors seed the history (count against the budget)
        seeds = _seed_configs(algo, space)[:max(2, algo_budget // 4)]
        racers.append(_Racer(
            algorithm=algo, bo=bo, pending_seeds=seeds,
            remaining=len(seeds) + max(algo_budget - len(seeds), 2),
        ))

    histories: list[Observation] = []
    regret: list[float] = []
    incumbent = -np.inf
    while any(r.remaining > 0 for r in racers):
        for r in racers:
            if r.remaining <= 0:
                continue
            k = min(batch_k, r.remaining)
            if r.pending_seeds:
                props = r.pending_seeds[:k]
                r.pending_seeds = r.pending_seeds[k:]
            else:
                props = r.bo.suggest_batch(k)
            outs = evaluate_candidates(
                platform, r.algorithm, data, metric, props, seed=seed,
                mode=eval_mode, cache=cache, workers=workers,
            )
            for cfg, (value, feasible, info) in zip(props, outs):
                r.bo.observe(cfg, value, feasible, info)
                obs = r.bo.history[-1]
                histories.append(obs)
                if feasible and np.isfinite(value):
                    incumbent = max(incumbent, value)
                regret.append(incumbent)
                if callback:
                    callback(r.algorithm, r.iteration, obs)
                r.iteration += 1
            r.remaining -= len(props)

    best: tuple[float, str, Observation] | None = None
    for r in racers:
        b = r.bo.best
        if b is not None and (best is None or b.value > best[0]):
            best = (b.value, r.algorithm, b)

    if best is None:
        raise RuntimeError(
            f"{model.name}: no feasible configuration found in {budget} "
            f"iterations on {platform.kind} (constraints {platform.performance}"
            f" / {platform.resources})"
        )

    value, algo, obs = best
    trained = obs.info["trained"]
    report = obs.info["report"]
    pipeline = codegen.generate_pipeline(
        platform.kind, model.name, trained, report, data.train_x
    )
    return ModelResult(
        name=model.name, algorithm=algo, trained=trained,
        pipeline=pipeline, report=report, value=value, metric=metric,
        history=histories, regret=regret,
        wall_s=time.perf_counter() - t0,
    )


# ------------------------------------------------------------ generate()


def _split_platform(platform: Platform, n: int) -> Platform:
    """Allocate 1/n of the platform resources to one model (§5.1.3)."""
    if n <= 1:
        return platform
    p = copy.deepcopy(platform)
    if platform.kind == "taurus":
        p.model.rows = max(1, p.model.rows // n)
    elif platform.kind == "tofino":
        p.model.num_tables = max(1, p.model.num_tables // n)
    elif platform.kind == "fpga":
        p.model.total_luts //= n
        p.model.total_ffs //= n
    elif platform.kind == "tpu":
        p.model.vmem_bytes //= n
    return p


def _dag_report(node, results: dict[str, ModelResult]) -> FeasibilityReport:
    """Merge reports over the DAG with identical-model dedup (Table 3)."""
    leaves = node.leaves()
    seen: set[int] = set()
    rep: FeasibilityReport | None = None
    for m in leaves:
        r = results[m.name]
        key = id(r.trained)
        if key in seen:
            continue  # chained copy shares weights + pipeline logic
        seen.add(key)
        rep = r.report if rep is None else rep.merge(r.report)
    assert rep is not None
    return rep


def generate(
    platform: Platform,
    *,
    budget: int = 30,
    n_init: int = 8,
    seed: int = 0,
    max_neurons: int = 64,
    callback=None,
    eval_mode: str = "batched",
    batch_k: int = 8,
    cache: CandidateCache | None = GLOBAL_CACHE,
    workers: int | None = None,
) -> GenerationResult:
    """The paper's ``homunculus.generate(platform)``."""
    assert platform.scheduled is not None, "call platform.schedule(...) first"
    node = platform.scheduled
    leaves = node.leaves()
    # dedup: chained copies of the same Model object search once
    unique: dict[int, Model] = {}
    for m in leaves:
        unique.setdefault(id(m), m)
    sub = _split_platform(platform, len(unique))

    results: dict[str, ModelResult] = {}
    for m in unique.values():
        res = search_model(
            sub, m, budget=budget, n_init=n_init, seed=seed,
            max_neurons=max_neurons, callback=callback,
            eval_mode=eval_mode, batch_k=batch_k, cache=cache,
            workers=workers,
        )
        results[m.name] = res
    # alias results for duplicate leaf names (chained copies)
    for m in leaves:
        if m.name not in results:
            twin = unique[id(m)]
            results[m.name] = results[twin.name]

    dag_rep = _dag_report(node, results)
    out = GenerationResult(
        platform_kind=platform.kind,
        models=results,
        dag_report=dag_rep,
        schedule=node.describe(),
    )
    platform.generated = out
    return out


def retrain_model(
    platform: Platform,
    data,
    *,
    name: str = "retrain",
    metric: str = "f1",
    algorithms: list[str] | None = None,
    budget: int = 12,
    n_init: int = 4,
    seed: int = 0,
    batch_k: int = 4,
    cache: CandidateCache | None = GLOBAL_CACHE,
) -> ModelResult:
    """One-shot re-search over a FRESH dataset: the online-learning hook.

    The drift loop (serve.online.BackgroundRetrainer) hands in a Dataset
    assembled from recent drifted windows; this wraps it into a Model and
    reruns the racer with the process-wide trained-candidate cache, so
    every (algorithm, config, seed) pair whose content hash survived the
    drift — i.e. anything retrained on identical data, plus the seed
    anchors on repeat episodes — warm-starts instead of retraining.  The
    default budget is deliberately smaller than an offline ``generate``:
    a retrain races against ongoing traffic degradation, and the cache
    plus the already-narrowed algorithm list close most of the gap."""
    model = Model({
        "name": name,
        "optimization_metric": [metric],
        "algorithm": list(algorithms) if algorithms else None,
        "data_loader": lambda data=data: data,
    })
    return search_model(
        platform, model, budget=budget, n_init=n_init, seed=seed,
        batch_k=batch_k, cache=cache,
    )
