"""Multi-application scheduling & execution (paper §5.1.3, Table 3).

Alchemy's ``>`` / ``|`` build a DAG of models sharing one data plane.  This
module executes a generated DAG over packets and accounts resources:

  * Execution semantics (network virtualization): every packet traverses
    the DAG.  Sequential stages gate (short-circuit) later stages — e.g.
    AD in front of TC: packets flagged positive (verdict > 0) keep that
    verdict and skip downstream models; clean packets flow on.  Parallel
    stages all see the packet; verdicts are combined ("or" = any branch
    positive wins the max, "and" = min, "concat" = stacked matrix).
  * Two execution paths with identical semantics:
      - ``run_dag``      eager numpy reference, one pipeline at a time;
      - ``compile_dag``  lowers the ENTIRE DAG into one jitted JAX program
        by inlining every model's stage list (core.stageir) and expressing
        the gate as ``jnp.where`` masking — no per-stage numpy hops, so
        XLA schedules/fuses across model boundaries.
  * Resource semantics (Table 3): chained copies of the *same* model share
    weights and pipeline logic on the target, so total resources are
    constant in the number of copies and independent of the chaining
    strategy; the inter-model glue (stream plumbing between stages) fits in
    already-allocated CUs — modeled as zero marginal cost, as measured in
    the paper.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stageir
from repro.core.alchemy import Model, Par, Seq
from repro.core.dse import GenerationResult, ModelResult
from repro.core.feasibility import FeasibilityReport

COMBINES = ("or", "and", "concat")


def _pipeline_of(result, name: str):
    """Accept GenerationResult, {name: ModelResult} or {name: Pipeline}."""
    entry = result[name]
    return entry.pipeline if hasattr(entry, "pipeline") else entry


# ------------------------------------------------------------ eager path


def run_dag(node, result, X: np.ndarray, *, combine: str = "or"
            ) -> np.ndarray:
    """Run every packet through the DAG; returns final per-packet verdicts.

    Eager numpy reference: each model's compiled pipeline runs separately,
    verdicts merge on host.  ``compile_dag`` is the jitted equivalent and
    matches this bit-for-bit.
    """
    if combine not in COMBINES:
        raise KeyError(f"combine must be one of {COMBINES}")
    X = np.asarray(X, np.float32)

    def eval_node(n) -> np.ndarray:
        if isinstance(n, Model):
            return np.asarray(_pipeline_of(result, n.name)(X))
        if isinstance(n, Seq):
            out = None
            for c in n.children:
                nxt = eval_node(c)
                # gate: packets already flagged keep their verdict and
                # short-circuit the downstream model
                out = nxt if out is None else np.where(out > 0, out, nxt)
            return out
        if isinstance(n, Par):
            outs = [eval_node(c) for c in n.children]
            if combine == "or":
                return functools.reduce(np.maximum, outs)
            if combine == "and":
                return functools.reduce(np.minimum, outs)
            return np.stack(outs, -1)
        raise TypeError(type(n))

    return eval_node(node)


# ---------------------------------------------------------- compiled path


class CompiledDag:
    """An entire Alchemy DAG lowered into ONE jitted JAX program.

    ``model_backends`` records, per model name, which execution engine that
    pipeline actually lowered to ("pallas" = one fused kernel launch,
    "pallas-fused-dag" = the whole DAG as ONE megakernel launch,
    "interpret" = inlined stage walk); ``backend`` summarizes ("pallas" /
    "pallas-fused-dag" / "interpret" / "mixed").  ``with_backend``
    recompiles the same DAG for a different engine (what
    ``PacketServeEngine(backend=...)`` calls)."""

    def __init__(self, fn: Callable, schedule: str, n_models: int,
                 model_backends: dict[str, str] | None = None,
                 rebuild: Callable[[str], "CompiledDag"] | None = None):
        self.fn = fn                    # jitted: jnp [N, F] -> verdicts
        self.schedule = schedule
        self.n_models = n_models
        self.model_backends = model_backends or {}
        self._rebuild = rebuild

    @property
    def backend(self) -> str:
        kinds = set(self.model_backends.values()) or {"interpret"}
        return kinds.pop() if len(kinds) == 1 else "mixed"

    @property
    def fused_dag(self) -> bool:
        """True when the whole DAG serves as one megakernel launch."""
        return self.backend == "pallas-fused-dag"

    def with_backend(self, backend: str) -> "CompiledDag":
        if self._rebuild is None:
            raise ValueError("this CompiledDag cannot be recompiled")
        return self._rebuild(backend)

    def dispatch(self, X) -> jax.Array:
        """Launch the DAG program WITHOUT forcing the device->host copy —
        the async serving path (PacketServeEngine depth>1) fetches the
        returned device array lazily at flush time."""
        return self.fn(jnp.asarray(X, jnp.float32))

    def __call__(self, X: np.ndarray) -> np.ndarray:
        return np.asarray(self.dispatch(X), np.int32)

    def __repr__(self):
        return (f"CompiledDag({self.schedule!r}, models={self.n_models}, "
                f"backend={self.backend!r})")


def compile_dag(node, result, *, combine: str = "or", fuse: bool = True,
                backend: str = "interpret",
                fuse_dag: bool = True) -> CompiledDag:
    """Lower the whole DAG (Seq gating as jnp.where masks, Par merges) and
    every model's stage list into a single jitted callable.

    ``backend="pallas"`` first tries to fuse the ENTIRE DAG into ONE
    megakernel launch (``pallas_backend.lower_dag_pallas``: every chained
    model's weights resident in VMEM, gating applied in-kernel — recorded
    as ``"pallas-fused-dag"`` on every model, bit-exact vs ``run_dag``);
    ``fuse_dag=False`` disables that pattern-match, which is the
    per-model-launch baseline ``benchmarks/dag_throughput.py`` compares
    against.  When the DAG is outside the megakernel envelope the engine
    is picked per-pipeline: each kernel-eligible model becomes one fused
    Pallas kernel launch inside the DAG program
    (docs/pipeline_ir.md#pallas-lowering-contract); ineligible models fall
    back to the inlined stage walk.  The mix actually compiled is reported
    on ``CompiledDag.model_backends``."""
    if combine not in COMBINES:
        raise KeyError(f"combine must be one of {COMBINES}")
    if backend not in stageir.EXEC_BACKENDS:
        raise KeyError(f"backend must be one of {stageir.EXEC_BACKENDS}")
    describe = node.describe() if hasattr(node, "describe") else str(node)

    def rebuild(b: str) -> CompiledDag:
        return compile_dag(node, result, combine=combine, fuse=fuse,
                           backend=b, fuse_dag=fuse_dag)

    if backend == "pallas" and fuse_dag:
        from repro.core import pallas_backend

        dag_fn = pallas_backend.lower_dag_pallas(
            node, result, combine=combine, fuse=fuse
        )
        if dag_fn is not None:
            return CompiledDag(
                jax.jit(dag_fn), describe, len(node.leaves()),
                {m.name: "pallas-fused-dag" for m in node.leaves()},
                rebuild=rebuild,
            )

    model_backends: dict[str, str] = {}

    def lower(n) -> Callable:
        if isinstance(n, Model):
            stages = _pipeline_of(result, n.name).stages
            if fuse:
                stages = stageir.fuse_pipeline_stages(stages)
            if backend == "pallas":
                from repro.core import pallas_backend

                kernel_fn = pallas_backend.lower_stages_pallas(stages)
                if kernel_fn is not None:
                    model_backends[n.name] = "pallas"
                    return kernel_fn
            model_backends[n.name] = "interpret"
            return lambda x, _s=stages: stageir.apply_stages(_s, x)
        if isinstance(n, Seq):
            branches = [lower(c) for c in n.children]

            def seq_fn(x):
                out = branches[0](x)
                for b in branches[1:]:
                    # masked short-circuit: flagged packets hold their
                    # verdict, clean ones take the next model's output
                    out = jnp.where(out > 0, out, b(x))
                return out

            return seq_fn
        if isinstance(n, Par):
            branches = [lower(c) for c in n.children]

            def par_fn(x):
                outs = [b(x) for b in branches]
                if combine == "or":
                    return functools.reduce(jnp.maximum, outs)
                if combine == "and":
                    return functools.reduce(jnp.minimum, outs)
                return jnp.stack(outs, -1)

            return par_fn
        raise TypeError(type(n))

    fn = jax.jit(lower(node))
    return CompiledDag(
        fn, describe, len(node.leaves()), model_backends, rebuild=rebuild,
    )


# ----------------------------------------------------------- accounting


def dag_resources(node, result: GenerationResult) -> FeasibilityReport:
    """Table-3 accounting: identical models counted once (shared weights)."""
    seen: set[int] = set()
    rep: FeasibilityReport | None = None
    for m in node.leaves():
        r: ModelResult = result[m.name]
        if id(r.trained) in seen:
            continue
        seen.add(id(r.trained))
        rep = r.report if rep is None else rep.merge(r.report)
    assert rep is not None
    return rep


def dag_stage_summary(node, result) -> dict:
    """Stage metadata over the DAG with identical-model dedup — the same
    dedup rule as dag_resources, read off Pipeline.stages."""
    seen: set[int] = set()
    total = {"stages": [], "params": 0, "macs": 0}
    for m in node.leaves():
        pipe = _pipeline_of(result, m.name)
        if id(pipe) in seen:
            continue
        seen.add(id(pipe))
        s = stageir.stage_summary(pipe.stages)
        total["stages"] += s["stages"]
        total["params"] += s["params"]
        total["macs"] += s["macs"]
    return total


def strategy_table(strategies: dict[str, Any], result: GenerationResult
                   ) -> list[dict]:
    """One row per chaining strategy: {strategy, cu/mu or mats, ...}."""
    rows = []
    for name, node in strategies.items():
        rep = dag_resources(node, result)
        row = {"strategy": name, **rep.resources}
        row["latency_ns"] = round(rep.latency_ns, 1)
        row["throughput_pps"] = rep.throughput_pps
        rows.append(row)
    return rows
