"""Multi-application scheduling & execution (paper §5.1.3, Table 3).

Alchemy's ``>`` / ``|`` build a DAG of models sharing one data plane.  This
module executes a generated DAG over packets and accounts resources:

  * Execution semantics (network virtualization): every packet traverses
    the DAG.  Sequential stages can gate (short-circuit) later stages —
    e.g. AD in front of TC: packets flagged malicious skip classification.
    Parallel stages all see the packet; verdicts are combined.
  * Resource semantics (Table 3): chained copies of the *same* model share
    weights and pipeline logic on the target, so total resources are
    constant in the number of copies and independent of the chaining
    strategy; the inter-model glue (stream plumbing between stages) fits in
    already-allocated CUs — modeled as zero marginal cost, as measured in
    the paper.
"""

from __future__ import annotations

from typing import Any

import numpy as np

from repro.core.alchemy import Model, Par, Seq
from repro.core.dse import GenerationResult, ModelResult
from repro.core.feasibility import FeasibilityReport


def run_dag(node, result: GenerationResult, X: np.ndarray,
            *, combine: str = "or") -> np.ndarray:
    """Run every packet through the DAG; returns final per-packet verdicts.

    ``combine``: how parallel branches merge ("or" = any positive class,
    "concat" handled by returning the stacked matrix of branch outputs).
    """
    X = np.asarray(X, np.float32)

    def eval_node(n) -> np.ndarray:
        if isinstance(n, Model):
            return np.asarray(result[n.name].pipeline(X))
        if isinstance(n, Seq):
            out = None
            for c in n.children:
                nxt = eval_node(c)
                out = nxt if out is None else np.maximum(out, nxt)
            return out
        if isinstance(n, Par):
            outs = [eval_node(c) for c in n.children]
            if combine == "or":
                return np.maximum.reduce(outs)
            return np.stack(outs, -1)
        raise TypeError(type(n))

    return eval_node(node)


def dag_resources(node, result: GenerationResult) -> FeasibilityReport:
    """Table-3 accounting: identical models counted once (shared weights)."""
    seen: set[int] = set()
    rep: FeasibilityReport | None = None
    for m in node.leaves():
        r: ModelResult = result[m.name]
        if id(r.trained) in seen:
            continue
        seen.add(id(r.trained))
        rep = r.report if rep is None else rep.merge(r.report)
    assert rep is not None
    return rep


def strategy_table(strategies: dict[str, Any], result: GenerationResult
                   ) -> list[dict]:
    """One row per chaining strategy: {strategy, cu/mu or mats, ...}."""
    rows = []
    for name, node in strategies.items():
        rep = dag_resources(node, result)
        row = {"strategy": name, **rep.resources}
        row["latency_ns"] = round(rep.latency_ns, 1)
        row["throughput_pps"] = rep.throughput_pps
        rows.append(row)
    return rows
