"""Design-space definition for the optimization core (paper §3.2.2).

Variables can be real (continuous), integer, ordinal, or categorical — the
exact taxonomy of HyperMapper [68] that the paper adopts.  Per-algorithm
spaces are produced by ``algorithm_space`` with bounds derived from the
target platform (the paper: "bounds ... typically calculated based on the
target being considered").
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Param:
    name: str
    kind: str                       # real | int | ordinal | categorical
    low: float = 0.0                # real/int bounds
    high: float = 1.0
    values: tuple = ()              # ordinal/categorical choices
    log: bool = False               # sample/encode in log space

    def sample(self, rng: np.random.Generator) -> Any:
        if self.kind in ("ordinal", "categorical"):
            return self.values[rng.integers(0, len(self.values))]
        if self.kind == "real":
            if self.log:
                return float(np.exp(rng.uniform(
                    math.log(self.low), math.log(self.high))))
            return float(rng.uniform(self.low, self.high))
        if self.kind == "int":
            return int(rng.integers(int(self.low), int(self.high) + 1))
        raise ValueError(self.kind)

    def encode(self, v: Any) -> float:
        """Map a value to [0, 1] for the surrogate."""
        if self.kind == "categorical":
            return self.values.index(v) / max(len(self.values) - 1, 1)
        if self.kind == "ordinal":
            return self.values.index(v) / max(len(self.values) - 1, 1)
        lo, hi = self.low, self.high
        if self.log:
            return (math.log(v) - math.log(lo)) / (math.log(hi) - math.log(lo))
        return (float(v) - lo) / (hi - lo) if hi > lo else 0.0


@dataclasses.dataclass
class DesignSpace:
    params: list[Param]

    @property
    def names(self) -> list[str]:
        return [p.name for p in self.params]

    def sample(self, rng: np.random.Generator) -> dict:
        return {p.name: p.sample(rng) for p in self.params}

    def sample_n(self, rng: np.random.Generator, n: int) -> list[dict]:
        return [self.sample(rng) for _ in range(n)]

    def encode(self, config: dict) -> np.ndarray:
        return np.array([p.encode(config[p.name]) for p in self.params],
                        np.float32)

    def encode_batch(self, configs: Sequence[dict]) -> np.ndarray:
        return np.stack([self.encode(c) for c in configs])

    def size_estimate(self) -> float:
        """log10 of the (discretized) space cardinality, for reporting."""
        total = 0.0
        for p in self.params:
            if p.kind in ("ordinal", "categorical"):
                total += math.log10(len(p.values))
            elif p.kind == "int":
                total += math.log10(max(p.high - p.low + 1, 1))
            else:
                total += math.log10(64)  # ~6 bits of useful resolution
        return total


# ----------------------------------------------- per-algorithm design spaces

MAX_DNN_LAYERS = 10  # paper's BD winner: "10 hidden layers" — allow that depth


def algorithm_space(algorithm: str, *, n_features: int, num_classes: int,
                    max_neurons: int = 64) -> DesignSpace:
    """The tunable-parameter space per supported algorithm (paper §3.2.2:
    hyperparameters incl. NAS variables; resource/network constraints enter
    through the feasibility oracle, not the space itself)."""
    if algorithm == "dnn":
        neuron_choices = tuple(
            v for v in (4, 8, 12, 16, 24, 32, 48, 64, 96, 128)
            if v <= max_neurons
        )
        params = [
            Param("n_layers", "int", 1, MAX_DNN_LAYERS),
            Param("lr", "real", 3e-4, 3e-2, log=True),
            Param("batch", "ordinal", values=(128, 256, 512)),
            Param("epochs", "ordinal", values=(8, 12, 16)),
        ]
        params += [
            Param(f"h{i}", "ordinal", values=neuron_choices)
            for i in range(MAX_DNN_LAYERS)
        ]
        return DesignSpace(params)
    if algorithm == "kmeans":
        return DesignSpace([
            Param("k", "int", 1, max(num_classes * 3, 2)),
            Param("n_features", "int", min(2, n_features), n_features),
        ])
    if algorithm == "svm":
        return DesignSpace([
            Param("c_reg", "real", 0.01, 100.0, log=True),
        ])
    if algorithm == "tree":
        return DesignSpace([
            Param("max_depth", "int", 2, 10),
        ])
    if algorithm == "logreg":
        return DesignSpace([
            Param("lr", "real", 1e-2, 1.0, log=True),
        ])
    raise KeyError(algorithm)
