"""Feasibility oracle + per-platform resource models (paper §3.2.2, §3.3).

The paper tests every BO-suggested model against (a) the physical resources
of the target (CUs/MUs on Taurus, MATs on Tofino, LUT/FF/BRAM on FPGA) and
(b) network performance constraints (throughput, latency), using a
compiler/simulator in the loop (SARA, P4 Studio, Vivado).  None of those
toolchains exist here, so each platform implements an *analytic* resource
model calibrated to the magnitudes the paper reports (Table 2/5), plus — for
the TPU platform — the real XLA compiler in the loop (jit + cost_analysis),
which is this repo's faithful analogue of "compile in the loop".

The oracle stays a black box to the BO: config in, verdict out (§3.2.3).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

# ------------------------------------------------------------------ report


@dataclasses.dataclass
class FeasibilityReport:
    feasible: bool
    reasons: list[str]                 # why infeasible (empty if feasible)
    resources: dict[str, float]        # platform-specific usage
    latency_ns: float
    throughput_pps: float              # packets/second the mapping sustains

    def merge(self, other: "FeasibilityReport") -> "FeasibilityReport":
        """Co-residency on one target: resources add, latency adds (chain),
        throughput is the min (paper §3.2.1 consistency rule)."""
        res = dict(self.resources)
        for k, v in other.resources.items():
            res[k] = res.get(k, 0) + v
        return FeasibilityReport(
            feasible=self.feasible and other.feasible,
            reasons=self.reasons + other.reasons,
            resources=res,
            latency_ns=self.latency_ns + other.latency_ns,
            throughput_pps=min(self.throughput_pps, other.throughput_pps),
        )


# ---------------------------------------------------------------- topology
#
# All shape/parameter accounting is read off the stage IR: a topology is
# lowered to shape-only StageSpecs (core.stageir.lower_topology) and every
# platform model below consumes stage metadata instead of re-deriving
# layer shapes per backend.


def _dense_specs(algorithm: str, topology: dict):
    from repro.core.stageir import lower_topology

    return lower_topology(algorithm, topology, form="dense")


def _mat_specs(algorithm: str, topology: dict):
    from repro.core.stageir import lower_topology

    return lower_topology(algorithm, topology, form="mat")


def dnn_layers(topology: dict) -> list[tuple[int, int]]:
    """(n_in, n_out) per dense layer, via the stage IR."""
    from repro.core.stageir import spec_layers

    return spec_layers(_dense_specs("dnn", topology))


def topology_params(algorithm: str, topology: dict) -> int:
    from repro.core.stageir import spec_params

    return spec_params(_dense_specs(algorithm, topology))


# ------------------------------------------------------------------ Taurus
#
# Plasticine-style grid of Compute Units (VEC-lane SIMD MAC pipes) and
# Memory Units (small SRAM banks).  Constants calibrated so the paper's
# Table-2 models land at the reported scale (203-param DNN ~ 24 CU / 48 MU).


@dataclasses.dataclass
class TaurusModel:
    rows: int = 16
    cols: int = 16
    vec: int = 8              # MAC lanes per CU
    mu_words: int = 6         # effective words per MU allocation unit
    clock_ghz: float = 1.0    # pipeline clock
    max_ii: int = 8           # max initiation interval the mapper will try

    @property
    def total_cu(self) -> int:
        return self.rows * self.cols

    @property
    def total_mu(self) -> int:
        return self.rows * self.cols

    def _layer_costs(self, layers: list[tuple[int, int]], ii: int):
        # NB: estimate_batch vectorizes these exact formulas — keep the two
        # in lockstep (tests/test_dse_parallel.py pins check == check_batch)
        cus = mus = 0
        stages = 0
        for n_in, n_out in layers:
            macs = n_in * n_out
            cus += max(1, math.ceil(macs / (self.vec * ii)))
            words = macs + n_out + 2 * n_out  # weights + bias + dbl-buffered act
            mus += max(1, math.ceil(words / self.mu_words))
            stages += 1 + math.ceil(math.log2(max(n_in, 2)))  # map + reduce tree
        return cus, mus, stages

    def estimate(self, algorithm: str, topology: dict) -> dict:
        """-> {cu, mu, latency_ns, throughput_pps(ii=1..), ii_options}."""
        from repro.core.stageir import spec_layers

        specs = _dense_specs(algorithm, topology)
        if algorithm == "tree":
            # comparator chain: ~1 CU per 2 nodes, 1 MU per 4 nodes
            tree = specs[0]
            n = tree.params
            depth = tree.extra[0]
            return {
                "options": [{
                    "ii": 1,
                    "cu": max(1, n // 2),
                    "mu": max(1, n // 4),
                    "latency_ns": depth / self.clock_ghz,
                    "throughput_pps": self.clock_ghz * 1e9,
                }]
            }
        # every compute stage (dense layer / centroid table) maps to a
        # map x reduce-tree template occupying CUs at the chosen II
        layers = spec_layers(specs)

        options = []
        for ii in range(1, self.max_ii + 1):
            cu, mu, stages = self._layer_costs(layers, ii)
            options.append({
                "ii": ii,
                "cu": cu,
                "mu": mu,
                "latency_ns": stages / self.clock_ghz,
                "throughput_pps": self.clock_ghz * 1e9 / ii,
            })
        return {"options": options}

    def estimate_batch(self, algorithm: str, topologies: list[dict]
                       ) -> list[dict]:
        """``estimate`` for a whole candidate batch in one numpy pass.

        Every topology is lowered to stage specs once; the per-layer
        CU/MU/stage costs for ALL candidates and ALL initiation intervals
        are then computed on padded [B, L] arrays (padding masked out, so a
        phantom layer never charges the max(1, ...) floor).  Exactly
        equivalent to mapping ``estimate`` (tested), just without the
        per-candidate Python re-derivation.
        """
        from repro.core.stageir import spec_layers

        if algorithm == "tree" or not topologies:
            return [self.estimate(algorithm, t) for t in topologies]
        import numpy as np

        layer_lists = [
            spec_layers(_dense_specs(algorithm, t)) for t in topologies
        ]
        B = len(layer_lists)
        L = max(len(ls) for ls in layer_lists)
        n_in = np.zeros((B, L), np.int64)
        n_out = np.zeros((B, L), np.int64)
        mask = np.zeros((B, L), bool)
        for b, ls in enumerate(layer_lists):
            for i, (fi, fo) in enumerate(ls):
                n_in[b, i], n_out[b, i], mask[b, i] = fi, fo, True
        macs = n_in * n_out
        words = macs + 3 * n_out          # weights + bias + dbl-buffered act
        stages = np.where(
            mask,
            1 + np.ceil(np.log2(np.maximum(n_in, 2))).astype(np.int64),
            0,
        ).sum(1)
        out: list[dict] = [{"options": []} for _ in range(B)]
        for ii in range(1, self.max_ii + 1):
            cus = np.where(
                mask, np.maximum(1, -(-macs // (self.vec * ii))), 0
            ).sum(1)
            mus = np.where(
                mask, np.maximum(1, -(-words // self.mu_words)), 0
            ).sum(1)
            for b in range(B):
                out[b]["options"].append({
                    "ii": ii,
                    "cu": int(cus[b]),
                    "mu": int(mus[b]),
                    "latency_ns": int(stages[b]) / self.clock_ghz,
                    "throughput_pps": self.clock_ghz * 1e9 / ii,
                })
        return out


# ----------------------------------------------------------------- MAT/PISA
#
# IIsy-style mapping rules (paper §4, §5.2.2):
#   KMeans:  one MAT per cluster
#   SVM:     one MAT per feature
#   Tree:    one MAT per tree level
#   LogReg:  one MAT per feature (per-feature LUT of partial scores)
#   DNN:     N2Net-style, ~12 MATs per layer [86]


@dataclasses.dataclass
class MATModel:
    num_tables: int = 12
    stage_ns: float = 25.0          # per-MAT pipeline latency
    line_rate_pps: float = 1e9      # Tofino line rate is fixed by the ASIC
    dnn_mats_per_layer: int = 12
    register_bytes: int = 4 * 2**20  # stateful register SRAM per pipeline

    def mats_for(self, algorithm: str, topology: dict) -> int:
        """Table count read off the MAT-form stage specs (IIsy rules)."""
        specs = _mat_specs(algorithm, topology)
        if algorithm == "kmeans":
            # one MAT per cluster: the LUT stage's output arity
            return next(s for s in specs if s.kind == "lut_gather").n_out
        if algorithm in ("svm", "logreg"):
            # one per-feature score table
            return next(s for s in specs if s.kind == "lut_gather").n_in
        if algorithm == "tree":
            # one MAT per tree level
            return specs[0].extra[0]
        if algorithm == "dnn":
            # N2Net-style folding: ~12 MATs per dense layer
            n_dense = sum(1 for s in specs if s.kind == "dense")
            return self.dnn_mats_per_layer * n_dense
        raise KeyError(algorithm)


# -------------------------------------------------------------------- FPGA
#
# P4-SDNet / Alveo U250-scale linear model: LUTs dominate (they hold model
# parameters [Table 5]), FFs pipeline them, BRAM holds feature buffers.


@dataclasses.dataclass
class FPGAModel:
    total_luts: int = 1_728_000     # Alveo U250
    total_ffs: int = 3_456_000
    total_bram: int = 2_688
    luts_per_param: float = 55.0    # calibrated to Table 5 deltas
    ffs_per_param: float = 25.0
    base_bram: int = 112            # loopback shell (4.15% of U250)
    clock_mhz: float = 322.0        # CMAC-domain clock

    def estimate(self, algorithm: str, topology: dict) -> dict:
        from repro.core.stageir import spec_layers, spec_params

        specs = _dense_specs(algorithm, topology)
        params = spec_params(specs)
        depth = (
            len(spec_layers(specs)) * 6
            if algorithm in ("dnn", "logreg") else 8
        )
        return {
            "luts": int(params * self.luts_per_param),
            "ffs": int(params * self.ffs_per_param),
            "bram": self.base_bram,
            "latency_ns": depth * 1e3 / self.clock_mhz,
            "throughput_pps": self.clock_mhz * 1e6,  # 1 pkt/clk, line-limited
        }


# --------------------------------------------------------------------- TPU
#
# Beyond-paper target: a TPU core serving the fused-MLP Pallas pipeline
# (kernels/fused_mlp).  Feasibility = VMEM fit; performance = 3-term
# roofline over the padded kernel shapes.  ``xla_oracle=True`` additionally
# jit-compiles the generated pipeline and reads cost_analysis() — the
# literal "compiler in the loop" of the paper, with XLA playing SARA.


@dataclasses.dataclass
class TPUModel:
    vmem_bytes: int = 64 * 2**20          # VMEM working-set budget
    peak_flops: float = 197e12            # bf16
    hbm_bw: float = 819e9
    batch: int = 256                       # serving batch per launch
    launch_overhead_us: float = 3.0

    def estimate(self, algorithm: str, topology: dict) -> dict:
        from repro.core.stageir import spec_layers
        from repro.kernels.fused_mlp.kernel import LANE, vmem_bytes

        # each compute stage is one MXU tile-op of the fused kernel; tree
        # lowers to a predicated select chain, counted as one launch stage
        n_layers = max(1, len(spec_layers(_dense_specs(algorithm, topology))))
        vmem = vmem_bytes(n_layers, self.batch)
        flops_per_pkt = n_layers * 2 * LANE * LANE  # padded MXU tiles
        bytes_per_pkt = 2 * LANE * 4                # stream in + out, f32
        t_compute = flops_per_pkt / self.peak_flops
        t_mem = bytes_per_pkt / self.hbm_bw
        t_pkt = max(t_compute, t_mem)
        launch = self.launch_overhead_us * 1e-6
        thr = self.batch / (self.batch * t_pkt + launch)
        lat = (self.batch * t_pkt + launch) * 1e9
        return {
            "vmem_bytes": vmem,
            "flops_per_pkt": flops_per_pkt,
            "latency_ns": lat,
            "throughput_pps": thr,
        }


# -------------------------------------------------------------- flow state
#
# The per-flow register file (repro.flowstate) is a CO-RESIDENT on the
# target: its slot/SRAM budget is charged like any other resource and
# composed with a model's report via FeasibilityReport.merge (the same
# §3.2.1 consistency rule multi-app chaining uses) — resources add,
# latency adds, throughput is the min.  The shape numbers are read off the
# shape-only stage specs (stageir.flowstate_specs), never re-derived here.


def flowstate_report(spec, platform_kind: str = "taurus", model: Any = None
                     ) -> FeasibilityReport:
    """Resource/latency report for one flow register file on one target.

    ``spec`` is a ``flowstate.FlowStateSpec``; ``model`` optionally
    overrides the platform resource model (defaults match the paper-scale
    calibrations above)."""
    from repro.core.stageir import flowstate_specs, spec_params

    words = spec_params(flowstate_specs(spec))
    return _register_table_report(
        words, platform_kind, model, what="flow registers",
        tpu_vmem=lambda m: _flow_update_vmem(spec, m),
    )


def mitigation_report(spec, platform_kind: str = "taurus", model: Any = None
                      ) -> FeasibilityReport:
    """Resource/latency report for one mitigation ACTION table — the
    per-flow drop/rate-limit registers a trailing ``Mitigate`` stage
    keeps (docs/pipeline_ir.md#mitigation-contract).

    ``spec`` is a ``flowstate.MitigationSpec``.  The action table is a
    second register file co-resident with the detection table, so it is
    charged through the SAME per-platform register model and composed via
    ``FeasibilityReport.merge`` — mitigation SRAM is never free.  On the
    TPU target the action table FOLDS INTO the fused flow launch
    (``kernels/fused_flow._mitigation_phase``), so the charge is the
    kernel's actual resident set: the table (keys + [hits, since] rows)
    plus the seven per-batch mitigation operand columns the launch
    stages into VMEM (worst case — the shared-segmentation fast path
    ships only the table pair)."""
    from repro.core.stageir import mitigation_specs, spec_params

    words = spec_params(mitigation_specs(spec))
    return _register_table_report(
        words, platform_kind, model, what="mitigation registers",
        # table + the 7 per-batch [B] operand columns of the fused
        # mitigation phase (keys/valid/rank/seg_slot + verdict gather),
        # matching kernels.fused_flow.vmem_bytes' mit term
        tpu_vmem=lambda m: words * 4 + m.batch * 7 * 4,
    )


def _flow_update_vmem(spec, m) -> int:
    from repro.kernels.flow_update import vmem_bytes as flow_vmem

    return flow_vmem(spec.n_slots, spec.width, m.batch)


def _register_table_report(words: int, platform_kind: str, model: Any, *,
                           what: str, tpu_vmem) -> FeasibilityReport:
    """Shared per-platform charging for one register table of ``words``
    32-bit words (stored keys included) — the flow-state detection table
    and the mitigation action table go through the same rules."""
    nbytes = words * 4
    reasons: list[str] = []

    if platform_kind == "taurus":
        m = model or TaurusModel()
        # register rows live in MU SRAM banks; hash + update occupy a
        # couple of CU ALU slots; one table read + write per packet
        mu = max(1, math.ceil(words / m.mu_words))
        cu = 2
        if mu > m.total_mu:
            reasons.append(
                f"{what} need {mu} MU > {m.total_mu} available"
            )
        return FeasibilityReport(
            feasible=not reasons, reasons=reasons,
            resources={"cu": cu, "mu": mu, "register_words": words},
            latency_ns=4 / m.clock_ghz,    # hash, read, update, write-back
            throughput_pps=m.clock_ghz * 1e9,
        )
    if platform_kind == "tofino":
        m = model or MATModel()
        if nbytes > m.register_bytes:
            reasons.append(
                f"{what} need {nbytes} B > {m.register_bytes} B "
                "register SRAM"
            )
        return FeasibilityReport(
            feasible=not reasons, reasons=reasons,
            resources={"mats": 1, "register_bytes": nbytes},
            latency_ns=2 * m.stage_ns,     # hash stage + register stage
            throughput_pps=m.line_rate_pps,
        )
    if platform_kind == "fpga":
        m = model or FPGAModel()
        bram = max(1, math.ceil(nbytes / 4608))   # 36Kb BRAM blocks
        if bram + m.base_bram > m.total_bram:
            reasons.append(
                f"{what} need {bram} BRAM > "
                f"{m.total_bram - m.base_bram} available"
            )
        return FeasibilityReport(
            feasible=not reasons, reasons=reasons,
            resources={"bram": bram, "register_bytes": nbytes},
            latency_ns=3 * 1e3 / m.clock_mhz,     # hash, read, write
            throughput_pps=m.clock_mhz * 1e6,
        )
    if platform_kind == "tpu":
        m = model or TPUModel()
        vmem = tpu_vmem(m)
        if vmem > m.vmem_bytes:
            reasons.append(
                f"{what} need {vmem} B VMEM > {m.vmem_bytes} budget"
            )
        launch = m.launch_overhead_us * 1e-6
        return FeasibilityReport(
            feasible=not reasons, reasons=reasons,
            resources={"vmem_bytes": vmem, "register_words": words},
            latency_ns=launch * 1e9,
            throughput_pps=m.batch / launch,
        )
    raise KeyError(f"no flow-state model for platform {platform_kind!r}")
