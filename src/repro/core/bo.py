"""Constrained Bayesian optimization (paper §3.2.3–§3.2.4).

HyperMapper-style: uniform-random initialization phase, then iterate
    fit RF surrogate on observed (x, y)
    fit RF feasibility classifier on observed (x, feasible)
    candidate pool <- random sample of the design space
    pick argmax  EI(x) * P(feasible | x)          [Gelbart et al., cEI]
The objective is treated as a noisy black box: the BO never sees model
internals, only (config -> metric, feasible) pairs — exactly the paper's
formulation ("we cannot access other information than the output y ...
given an input value x").

``suggest_batch(k)`` is the population-parallel form the batched DSE racer
consumes: q-EI approximated by greedy Kriging-believer fantasies — pick the
cEI argmax, pretend its outcome equals the surrogate mean, refit, pick the
next — so the k proposals spread instead of piling onto one optimum.  Both
the batched and the sequential evaluation paths consume the same proposal
stream, which is what makes them comparable run-for-run.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

from repro.core.designspace import DesignSpace
from repro.core.surrogate import RandomForest


def expected_improvement(mu: np.ndarray, sigma: np.ndarray, best: float
                         ) -> np.ndarray:
    """EI for maximization, closed form under a Gaussian posterior."""
    z = (mu - best) / sigma
    # standard normal pdf / cdf without scipy
    pdf = np.exp(-0.5 * z * z) / math.sqrt(2 * math.pi)
    cdf = 0.5 * (1.0 + _erf(z / math.sqrt(2.0)))
    return (mu - best) * cdf + sigma * pdf


def _erf(x: np.ndarray) -> np.ndarray:
    # Abramowitz & Stegun 7.1.26 (|err| < 1.5e-7) — scipy-free erf
    sign = np.sign(x)
    x = np.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * x)
    poly = t * (0.254829592 + t * (-0.284496736 + t * (
        1.421413741 + t * (-1.453152027 + t * 1.061405429))))
    return sign * (1.0 - poly * np.exp(-x * x))


@dataclasses.dataclass
class Observation:
    config: dict
    value: float          # objective (maximize); NaN if evaluation failed
    feasible: bool
    info: dict


class ConstrainedBO:
    """suggest()/observe() driver.  Maximizes; infeasible points contribute
    to the feasibility model but not the objective surrogate."""

    def __init__(
        self,
        space: DesignSpace,
        *,
        n_init: int = 10,
        candidates_per_iter: int = 512,
        seed: int = 0,
        rf_kwargs: dict | None = None,
    ):
        self.space = space
        self.n_init = n_init
        self.n_cand = candidates_per_iter
        self.rng = np.random.default_rng(seed)
        self.rf_kwargs = rf_kwargs or {}
        self.history: list[Observation] = []

    # ------------------------------------------------------------- state

    @property
    def feasible_history(self) -> list[Observation]:
        return [o for o in self.history
                if o.feasible and np.isfinite(o.value)]

    @property
    def best(self) -> Observation | None:
        feas = self.feasible_history
        return max(feas, key=lambda o: o.value) if feas else None

    def regret_curve(self) -> list[float]:
        """Best feasible objective so far, per iteration (paper Fig. 4)."""
        out, best = [], -np.inf
        for o in self.history:
            if o.feasible and np.isfinite(o.value):
                best = max(best, o.value)
            out.append(best)
        return out

    # ----------------------------------------------------------- suggest

    def suggest(self) -> dict:
        if len(self.history) < self.n_init:
            return self.space.sample(self.rng)

        feas = self.feasible_history
        cands = self.space.sample_n(self.rng, self.n_cand)
        Xc = self.space.encode_batch(cands)

        # feasibility model over every observation
        p_feas = np.ones(len(cands))
        if any(not o.feasible for o in self.history):
            Xf = self.space.encode_batch([o.config for o in self.history])
            yf = np.array([1.0 if o.feasible else 0.0 for o in self.history])
            clf = RandomForest(seed=int(self.rng.integers(2**31)),
                               **self.rf_kwargs).fit(Xf, yf)
            p_feas = clf.predict_proba(Xc)

        if len(feas) < 2:
            # not enough signal for the objective surrogate: chase feasibility
            return cands[int(np.argmax(p_feas + 1e-3 * self.rng.random(len(cands))))]

        Xo = self.space.encode_batch([o.config for o in feas])
        yo = np.array([o.value for o in feas])
        rf = RandomForest(seed=int(self.rng.integers(2**31)),
                          **self.rf_kwargs).fit(Xo, yo)
        mu, sigma = rf.predict(Xc)
        ei = expected_improvement(mu, sigma, yo.max())
        score = ei * p_feas
        return cands[int(np.argmax(score))]

    def suggest_batch(self, k: int) -> list[dict]:
        """Propose k configurations at once (q-EI via greedy fantasies).

        Init phase: k uniform-random samples.  Too little feasible signal:
        the top-k of the feasibility-probability ranking.  Otherwise the
        Kriging-believer loop: argmax cEI, append (x, mu(x)) as a fantasy
        observation, refit the surrogate, repeat — each refit sees the
        fantasies, so successive picks explore away from each other.
        """
        if k <= 0:
            return []
        if len(self.history) < self.n_init:
            return self.space.sample_n(self.rng, k)

        feas = self.feasible_history
        cands = self.space.sample_n(self.rng, self.n_cand)
        Xc = self.space.encode_batch(cands)

        p_feas = np.ones(len(cands))
        if any(not o.feasible for o in self.history):
            Xf = self.space.encode_batch([o.config for o in self.history])
            yf = np.array([1.0 if o.feasible else 0.0 for o in self.history])
            clf = RandomForest(seed=int(self.rng.integers(2**31)),
                               **self.rf_kwargs).fit(Xf, yf)
            p_feas = clf.predict_proba(Xc)

        if len(feas) < 2:
            score = p_feas + 1e-3 * self.rng.random(len(cands))
            top = np.argsort(-score)[:k]
            return [cands[int(i)] for i in top]

        Xo = self.space.encode_batch([o.config for o in feas])
        yo = np.array([o.value for o in feas])
        X_fit, y_fit = Xo, yo
        avail = np.ones(len(cands), bool)
        picked: list[dict] = []
        for _ in range(min(k, len(cands))):
            rf = RandomForest(seed=int(self.rng.integers(2**31)),
                              **self.rf_kwargs).fit(X_fit, y_fit)
            mu, sigma = rf.predict(Xc)
            ei = expected_improvement(mu, sigma, float(y_fit.max()))
            score = np.where(avail, ei * p_feas, -np.inf)
            j = int(np.argmax(score))
            avail[j] = False
            picked.append(cands[j])
            # Kriging believer: fantasize the surrogate mean as the outcome
            X_fit = np.concatenate([X_fit, Xc[j:j + 1]])
            y_fit = np.concatenate([y_fit, mu[j:j + 1]])
        return picked

    def observe(self, config: dict, value: float, feasible: bool,
                info: dict | None = None) -> None:
        self.history.append(Observation(config, float(value), bool(feasible),
                                        info or {}))

    # ------------------------------------------------------------- drive

    def run(
        self,
        evaluate: Callable[[dict], tuple[float, bool, dict]],
        budget: int,
        *,
        callback: Callable[[int, Observation], None] | None = None,
    ) -> Observation | None:
        """Full loop: ``evaluate(config) -> (value, feasible, info)``."""
        for it in range(budget):
            cfg = self.suggest()
            value, feasible, info = evaluate(cfg)
            self.observe(cfg, value, feasible, info)
            if callback:
                callback(it, self.history[-1])
        return self.best

    def run_batched(
        self,
        evaluate_batch: Callable[[list[dict]],
                                 list[tuple[float, bool, dict]]],
        budget: int,
        *,
        batch_size: int = 8,
        callback: Callable[[int, Observation], None] | None = None,
    ) -> Observation | None:
        """Batched loop: propose ``batch_size`` configs per iteration and
        hand them to ``evaluate_batch`` (which may train them in one vmapped
        program).  Total evaluations still equal ``budget``."""
        done = 0
        while done < budget:
            cfgs = self.suggest_batch(min(batch_size, budget - done))
            for cfg, (value, feasible, info) in zip(
                    cfgs, evaluate_batch(cfgs)):
                self.observe(cfg, value, feasible, info)
                if callback:
                    callback(done, self.history[-1])
                done += 1
        return self.best
