"""Content-addressed trained-candidate cache for the DSE engine.

The Homunculus search races one ConstrainedBO per candidate algorithm and is
re-entered by every benchmark/example/re-run; without memoization the same
(algorithm, config, seed, dataset) quadruple is retrained over and over —
seed-config anchors alone are retrained once per racer.  The cache key is
*content-addressed*:

  * the dataset contributes a sha1 over its training split
    (``Dataset.fingerprint``), not an object id, so two loaders producing
    identical arrays share entries;
  * the config contributes only its *effective* form
    (``mlalgos.effective_config``) — the parameters that actually reach
    ``train`` — so e.g. two DNN configs differing in dead ``h_i`` slots
    (beyond ``n_layers``) hit the same entry.

Feasibility reports are NOT cached: they depend on the platform, which the
multi-model scheduler resplits per search (§5.1.3), so they are recomputed
from the cached topology instead.

The key deliberately does NOT include the evaluation mode: batched and
sequential training compute the same job (that equivalence is its own
tested contract), so either may serve the other's hits.  When *comparing*
the two modes, hand each run a private ``CandidateCache()`` — with the
shared default the second run would replay the first run's models and the
comparison would be vacuous (see tests/test_dse_parallel.py).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import threading

from repro.core import mlalgos
from repro.data.netdata import Dataset


def candidate_key(algorithm: str, config: dict, seed: int,
                  data: Dataset) -> str:
    """Stable content hash of one training job."""
    eff = mlalgos.effective_config(algorithm, config, data)
    blob = json.dumps(
        [algorithm, int(seed), data.fingerprint(),
         {k: repr(v) for k, v in sorted(eff.items())}],
        sort_keys=True,
    )
    return hashlib.sha1(blob.encode()).hexdigest()


@dataclasses.dataclass
class CandidateCache:
    """In-process trained-model store with hit/miss accounting.

    LRU-bounded: ``max_entries`` caps how many TrainedModels (full weight
    arrays) stay resident, so a long-lived process racing many datasets /
    seeds does not grow without bound.  The default comfortably holds
    several full ``generate()`` searches.

    Thread-safe: the online-learning loop (serve.online) retrains on a
    background worker while the foreground may run its own searches
    against ``GLOBAL_CACHE``, so every store access holds a lock.  The
    lock protects the LRU bookkeeping (get's move-to-front mutates), not
    just the dict ops.
    """

    _store: dict[str, mlalgos.TrainedModel] = dataclasses.field(
        default_factory=dict)
    max_entries: int = 1024
    hits: int = 0
    misses: int = 0
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    def __len__(self) -> int:
        return len(self._store)

    def get(self, key: str) -> mlalgos.TrainedModel | None:
        with self._lock:
            hit = self._store.get(key)
            if hit is None:
                self.misses += 1
            else:
                self.hits += 1
                self._store[key] = self._store.pop(key)   # mark most-recent
            return hit

    def put(self, key: str, trained: mlalgos.TrainedModel) -> None:
        with self._lock:
            self._store.pop(key, None)
            self._store[key] = trained
            while len(self._store) > self.max_entries:  # evict least-recent
                self._store.pop(next(iter(self._store)))

    def clear(self) -> None:
        with self._lock:
            self._store.clear()
            self.hits = self.misses = 0

    def stats(self) -> dict:
        with self._lock:
            return {"entries": len(self._store), "hits": self.hits,
                    "misses": self.misses}


# process-wide default: racing BOs across algorithms, repeated generate()
# calls, and the benchmarks all share it unless handed a private cache
GLOBAL_CACHE = CandidateCache()
