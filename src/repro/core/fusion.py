"""Model fusion (paper §3.2.5, Table 4).

"Models learning from similar datasets are most likely learning similar
characteristics ... if there are a certain number of features in common,
[Homunculus] will attempt to build a single model to serve both datasets."

``maybe_fuse`` checks feature overlap (Jaccard over feature names); if above
threshold it builds one *multi-head* DNN: a shared trunk (the shared learned
characteristics) with one output head per task.  Resources are those of a
single trunk + heads instead of two full models — the paper's Table-4
"about the same as one split model" effect.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mlalgos import TrainedModel, f1_score
from repro.data.netdata import Dataset

FUSE_OVERLAP_THRESHOLD = 0.5


def feature_overlap(a: Dataset, b: Dataset) -> float:
    fa, fb = set(a.feature_names), set(b.feature_names)
    if not fa or not fb:
        return 0.0
    return len(fa & fb) / len(fa | fb)


@dataclasses.dataclass
class FusedModel:
    """Shared-trunk multi-head DNN over >=2 tasks."""

    trunk_widths: list[int]          # [F, h1, ..., hk]
    heads: list[int]                 # classes per task
    params: dict                     # {"trunk": [...], "heads": [...]}
    datasets: list[Dataset]

    @property
    def param_count(self) -> int:
        n = sum(int(l["w"].size + l["b"].size) for l in self.params["trunk"])
        n += sum(int(h["w"].size + h["b"].size) for h in self.params["heads"])
        return n

    def topology(self, task: int) -> dict:
        """Topology *as mapped on the target* for one task: trunk + head."""
        widths = list(self.trunk_widths) + [self.heads[task]]
        return {"widths": widths, "act": "relu"}

    def fused_topology(self) -> dict:
        """Topology of the single fused pipeline (trunk + concat heads)."""
        widths = list(self.trunk_widths) + [sum(self.heads)]
        return {"widths": widths, "act": "relu"}

    def task_stages(self, task: int):
        """Lower trunk + one head into the stage IR (FusedMLP + argmax):
        the same per-task pipeline the Taurus backend would emit."""
        from repro.core.stageir import FusedMLP, Reduce

        weights = [np.asarray(l["w"]) for l in self.params["trunk"]]
        biases = [np.asarray(l["b"]) for l in self.params["trunk"]]
        head = self.params["heads"][task]
        weights.append(np.asarray(head["w"]))
        biases.append(np.asarray(head["b"]))
        return [FusedMLP(weights, biases), Reduce("argmax")]

    def task_pipeline(self, task: int, report=None,
                      exec_backend: str = "interpret"):
        """Executable per-task Pipeline built from the fused stage list.

        ``exec_backend="pallas"`` serves the trunk+head MLP as one fused
        Pallas kernel launch (it is always kernel-eligible: FusedMLP →
        Reduce lowers onto kernels/fused_mlp)."""
        from repro.core.codegen import Pipeline, _spatial_dnn
        from repro.core.feasibility import FeasibilityReport
        from repro.core.mlalgos import TrainedModel

        topo = self.topology(task)
        report = report or FeasibilityReport(True, [], {}, 0.0, 0.0)
        # per-task count: trunk + this task's head only (NOT all heads) —
        # keeps the stage_summary()["params"] == model.param_count invariant
        n_params = sum(
            int(l["w"].size + l["b"].size) for l in self.params["trunk"]
        ) + int(self.params["heads"][task]["w"].size
                + self.params["heads"][task]["b"].size)
        trained = TrainedModel(
            "dnn", topo, self.params,
            lambda X, _t=task: self.predict(_t, X),
            n_params, self.heads[task], {"fused_task": task},
        )
        name = f"fused_task{task}"
        return Pipeline(
            name, "taurus", "dnn", self.task_stages(task),
            _spatial_dnn(name, topo["widths"], report.resources),
            report, trained, exec_backend=exec_backend,
        )

    def predict(self, task: int, X: np.ndarray) -> np.ndarray:
        logits = _fused_forward(
            self.params, jnp.asarray(X, jnp.float32)
        )[task]
        return np.asarray(jnp.argmax(logits, -1), np.int32)

    def f1(self, task: int) -> float:
        d = self.datasets[task]
        return f1_score(
            d.test_y, self.predict(task, d.test_x), num_classes=d.num_classes
        )


def _fused_forward(params, x):
    h = x
    for l in params["trunk"]:
        h = jax.nn.relu(h @ l["w"] + l["b"])
    return [h @ hd["w"] + hd["b"] for hd in params["heads"]]


@partial(jax.jit, static_argnames=("nsteps", "batch"))
def _fused_train(params, xs, ys, masks, key, lr, *, nsteps: int, batch: int):
    """xs [N,F]; ys [N, T] labels per task; masks [N, T] row-task validity."""
    n = xs.shape[0]

    def loss_fn(p, xb, yb, mb):
        logits = _fused_forward(p, xb)
        total = 0.0
        for t, lg in enumerate(logits):
            logp = jax.nn.log_softmax(lg)
            ce = -jnp.take_along_axis(logp, yb[:, t][:, None], axis=1)[:, 0]
            total = total + jnp.sum(ce * mb[:, t]) / jnp.maximum(
                jnp.sum(mb[:, t]), 1.0
            )
        return total

    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    def step(carry, i):
        p, m, v, key = carry
        key, kb = jax.random.split(key)
        idx = jax.random.randint(kb, (batch,), 0, n)
        g = jax.grad(loss_fn)(p, xs[idx], ys[idx], masks[idx])
        t = i.astype(jnp.float32) + 1.0
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        mh = jax.tree.map(lambda a: a / (1 - 0.9**t), m)
        vh = jax.tree.map(lambda a: a / (1 - 0.999**t), v)
        p = jax.tree.map(
            lambda pp, a, b: pp - lr * a / (jnp.sqrt(b) + 1e-8), p, mh, vh
        )
        return (p, m, v, key), 0.0

    (params, _, _, _), _ = jax.lax.scan(
        step, (params, m, v, key), jnp.arange(nsteps)
    )
    return params


def fuse(
    datasets: list[Dataset],
    *,
    hidden: list[int] | None = None,
    epochs: int = 12,
    lr: float = 3e-3,
    batch: int = 256,
    seed: int = 0,
) -> FusedModel:
    """Train one shared-trunk model over the (feature-aligned) datasets."""
    assert len(datasets) >= 2
    names = datasets[0].feature_names
    for d in datasets[1:]:
        assert d.feature_names == names, (
            "fusion requires feature-aligned datasets (align first)"
        )
    hidden = hidden or [24, 16]
    F = datasets[0].num_features
    T = len(datasets)
    widths = [F] + hidden

    key = jax.random.PRNGKey(seed)
    trunk = []
    for i in range(len(widths) - 1):
        key, k = jax.random.split(key)
        trunk.append({
            "w": jax.random.normal(k, (widths[i], widths[i + 1]), jnp.float32)
            * np.sqrt(2.0 / widths[i]),
            "b": jnp.zeros((widths[i + 1],), jnp.float32),
        })
    heads = []
    for d in datasets:
        key, k = jax.random.split(key)
        heads.append({
            "w": jax.random.normal(k, (widths[-1], d.num_classes), jnp.float32)
            * np.sqrt(2.0 / widths[-1]),
            "b": jnp.zeros((d.num_classes,), jnp.float32),
        })
    params = {"trunk": trunk, "heads": heads}

    xs = np.concatenate([d.train_x for d in datasets], 0)
    N = len(xs)
    ys = np.zeros((N, T), np.int32)
    masks = np.zeros((N, T), np.float32)
    row = 0
    for t, d in enumerate(datasets):
        n = len(d.train_x)
        ys[row:row + n, t] = d.train_y
        masks[row:row + n, t] = 1.0
        row += n

    nsteps = max(1, epochs * N // batch)
    params = _fused_train(
        params, jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(masks),
        jax.random.PRNGKey(seed + 1), jnp.float32(lr),
        nsteps=int(nsteps), batch=batch,
    )
    params = jax.tree.map(np.asarray, params)
    return FusedModel(widths, [d.num_classes for d in datasets], params,
                      datasets)


def should_fuse(a: Dataset, b: Dataset,
                threshold: float = FUSE_OVERLAP_THRESHOLD) -> bool:
    return feature_overlap(a, b) >= threshold
