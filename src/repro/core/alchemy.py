"""Alchemy: the embedded DSL and frontend of Homunculus (paper §3.1).

Constructs (paper Table 1):

  Model({...})            objectives, algorithm list, data loader
  @DataLoader             dataset loading/preprocessing wrapper
  Platforms.Taurus() ...  backend target + resource/performance constraints
  m1 > m2                 sequential composition
  m1 | m2                 parallel composition
                          (NB: Python chains bare comparisons — write
                          (m1 > m2) > m3, not m1 > m2 > m3)
  platform < {...}        constraint operator (sugar for .constrain)
  IOMap / @IOMapper       wiring between composed models

A program is exactly the paper's Figure-3 shape::

    import homunculus
    from homunculus.alchemy import DataLoader, Model, Platforms

    @DataLoader
    def wrapper_func():
        ...
        return {"data": {"train": tnx, "test": tsx},
                "labels": {"train": tny, "test": tsy}}

    model_spec = Model({"optimization_metric": ["f1"],
                        "algorithm": ["dnn"],
                        "name": "anomaly_detection",
                        "data_loader": wrapper_func})
    platform = Platforms.Taurus()
    platform.constrain(performance={"throughput": 1, "latency": 500},
                       resources={"rows": 16, "cols": 16})
    platform.schedule(model_spec)
    homunculus.generate(platform)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from repro.core import feasibility as feas
from repro.data.netdata import Dataset

# ----------------------------------------------------------------- loaders


def DataLoader(fn: Callable) -> Callable:
    """Decorator: normalize a user loader to a repro Dataset.

    Accepts either a ``Dataset`` or the paper's dict form
    {"data": {"train", "test"}, "labels": {"train", "test"}}.
    """

    def wrapper(*a, **kw) -> Dataset:
        out = fn(*a, **kw)
        if isinstance(out, Dataset):
            return out
        data, labels = out["data"], out["labels"]
        tnx = np.asarray(data["train"], np.float32)
        tsx = np.asarray(data["test"], np.float32)
        tny = np.asarray(labels["train"], np.int32)
        tsy = np.asarray(labels["test"], np.int32)
        ncls = int(max(tny.max(), tsy.max())) + 1
        names = out.get(
            "feature_names", [f"f{i}" for i in range(tnx.shape[1])]
        )
        return Dataset(
            name=out.get("name", fn.__name__),
            train_x=tnx, train_y=tny, test_x=tsx, test_y=tsy,
            feature_names=list(names), num_classes=ncls,
        )

    wrapper.__wrapped__ = fn
    wrapper._is_dataloader = True
    return wrapper


def IOMapper(io_ins: list[str], io_outs: list[str]) -> Callable:
    """Decorator: declare a mapping function's input/output port names."""

    def deco(fn):
        fn._io_ins = list(io_ins)
        fn._io_outs = list(io_outs)
        return fn

    return deco


@dataclasses.dataclass
class IOMap:
    """Connects model inputs/outputs (paper Table 1)."""

    mapper_func: Callable  # (features, upstream_outputs) -> features

    def __call__(self, features, upstream):
        return self.mapper_func(features, upstream)


def passthrough_iomap(features, upstream):
    return features


# ------------------------------------------------------------ composition


class _Composable:
    def __gt__(self, other):  # m1 > m2 : sequential
        return Seq([self, _as_node(other)])

    def __or__(self, other):  # m1 | m2 : parallel
        return Par([self, _as_node(other)])


def _as_node(x):
    if isinstance(x, (Seq, Par, Model)):
        return x
    raise TypeError(f"cannot compose {type(x)}")


@dataclasses.dataclass
class Seq(_Composable):
    children: list

    def __gt__(self, other):
        return Seq(self.children + [_as_node(other)])

    def leaves(self) -> list["Model"]:
        out = []
        for c in self.children:
            out += c.leaves() if isinstance(c, (Seq, Par)) else [c]
        return out

    def describe(self) -> str:
        return " > ".join(
            f"({c.describe()})" if isinstance(c, (Seq, Par)) else c.name
            for c in self.children
        )


@dataclasses.dataclass
class Par(_Composable):
    children: list

    def __or__(self, other):
        return Par(self.children + [_as_node(other)])

    def leaves(self) -> list["Model"]:
        out = []
        for c in self.children:
            out += c.leaves() if isinstance(c, (Seq, Par)) else [c]
        return out

    def describe(self) -> str:
        return " | ".join(
            f"({c.describe()})" if isinstance(c, (Seq, Par)) else c.name
            for c in self.children
        )


# ------------------------------------------------------------------- Model


class Model(_Composable):
    """User intent for one data-plane ML model (paper §3.1.1)."""

    def __init__(self, spec: dict):
        self.spec = dict(spec)
        self.name: str = spec.get("name", "model")
        self.metrics: list[str] = list(spec.get("optimization_metric", ["f1"]))
        self.algorithms: list[str] | None = (
            list(spec["algorithm"]) if spec.get("algorithm") else None
        )
        loader = spec["data_loader"]
        if not getattr(loader, "_is_dataloader", False):
            loader = DataLoader(loader)
        self._loader = loader
        self._data: Dataset | None = None
        self.iomap: IOMap = IOMap(passthrough_iomap)

    @property
    def objective(self) -> str:
        return self.metrics[0]

    def data(self) -> Dataset:
        if self._data is None:
            self._data = self._loader()
        return self._data

    def with_iomap(self, iomap: IOMap) -> "Model":
        self.iomap = iomap
        return self

    def leaves(self) -> list["Model"]:
        return [self]

    def describe(self) -> str:
        return self.name

    def __repr__(self):
        return f"Model({self.name!r}, metric={self.objective})"


# --------------------------------------------------------------- Platforms


class Platform:
    """A physical data-plane target + its constraints (paper Table 1)."""

    kind: str = "abstract"

    def __init__(self):
        self.performance: dict[str, float] = {}
        self.resources: dict[str, float] = {}
        self.scheduled = None  # Model | Seq | Par
        self.generated = None  # filled by homunculus.generate

    # -- constraint API: .constrain(...) and the paper's `<` operator
    def constrain(self, performance: dict | None = None,
                  resources: dict | None = None, **kw):
        performance = performance or kw.get("performance") or {}
        resources = resources or kw.get("resources") or {}
        self.performance.update(performance)
        self.resources.update(resources)
        self._apply_resources()
        return self

    def __lt__(self, cons: dict):
        return self.constrain(
            performance=cons.get("performance"),
            resources=cons.get("resources"),
        )

    def _apply_resources(self):
        pass

    def schedule(self, node):
        """Install a Model or a composition DAG on this platform."""
        self.scheduled = _as_node(node)
        return self

    # -- constraint targets (None = unconstrained)
    @property
    def min_throughput_pps(self) -> float | None:
        thr = self.performance.get("throughput")
        return thr * 1e9 if thr is not None else None  # paper unit: GPkt/s

    @property
    def max_latency_ns(self) -> float | None:
        return self.performance.get("latency")  # paper unit: ns

    # -- to be provided per platform
    def check(self, algorithm: str, topology: dict) -> feas.FeasibilityReport:
        raise NotImplementedError

    def supported_algorithms(self) -> list[str]:
        raise NotImplementedError


class TaurusPlatform(Platform):
    kind = "taurus"

    def __init__(self):
        super().__init__()
        self.model = feas.TaurusModel()

    def _apply_resources(self):
        r = self.resources
        self.model = feas.TaurusModel(
            rows=int(r.get("rows", self.model.rows)),
            cols=int(r.get("cols", self.model.cols)),
        )

    def supported_algorithms(self) -> list[str]:
        return ["dnn", "logreg", "svm", "kmeans"]

    def check(self, algorithm, topology) -> feas.FeasibilityReport:
        est = self.model.estimate(algorithm, topology)
        budget_cu = self.model.total_cu
        budget_mu = self.model.total_mu
        min_thr = self.min_throughput_pps
        max_lat = self.max_latency_ns
        # pick the lowest-II (highest-throughput) option that fits; the
        # CU <-> II tradeoff is the paper's "loop iterations vs line rate"
        for opt in est["options"]:
            fits = opt["cu"] <= budget_cu and opt["mu"] <= budget_mu
            fast = min_thr is None or opt["throughput_pps"] >= min_thr
            slow = max_lat is not None and opt["latency_ns"] > max_lat
            if fits and fast and not slow:
                return feas.FeasibilityReport(
                    True, [],
                    {"cu": opt["cu"], "mu": opt["mu"], "ii": opt["ii"]},
                    opt["latency_ns"], opt["throughput_pps"],
                )
        o = est["options"][0]
        reasons = []
        if o["cu"] > budget_cu:
            reasons.append(f"CU {o['cu']} > {budget_cu}")
        if o["mu"] > budget_mu:
            reasons.append(f"MU {o['mu']} > {budget_mu}")
        if min_thr is not None and o["throughput_pps"] < min_thr:
            reasons.append("throughput below line rate at feasible II")
        if max_lat is not None and o["latency_ns"] > max_lat:
            reasons.append(f"latency {o['latency_ns']}ns > {max_lat}ns")
        if not reasons:
            reasons.append("no II in 1..max_ii satisfies all constraints")
        return feas.FeasibilityReport(
            False, reasons, {"cu": o["cu"], "mu": o["mu"], "ii": o["ii"]},
            o["latency_ns"], o["throughput_pps"],
        )


class TofinoPlatform(Platform):
    kind = "tofino"

    def __init__(self):
        super().__init__()
        self.model = feas.MATModel()

    def _apply_resources(self):
        r = self.resources
        self.model = feas.MATModel(
            num_tables=int(r.get("tables", self.model.num_tables)),
        )

    def supported_algorithms(self) -> list[str]:
        return ["kmeans", "svm", "tree", "logreg"]

    def check(self, algorithm, topology) -> feas.FeasibilityReport:
        mats = self.model.mats_for(algorithm, topology)
        lat = mats * self.model.stage_ns
        thr = self.model.line_rate_pps
        reasons = []
        if mats > self.model.num_tables:
            reasons.append(f"MATs {mats} > {self.model.num_tables}")
        if self.max_latency_ns is not None and lat > self.max_latency_ns:
            reasons.append(f"latency {lat}ns > {self.max_latency_ns}ns")
        if (self.min_throughput_pps is not None
                and thr < self.min_throughput_pps):
            reasons.append("line rate below required throughput")
        return feas.FeasibilityReport(
            not reasons, reasons, {"mats": mats}, lat, thr
        )


class FPGAPlatform(Platform):
    kind = "fpga"

    def __init__(self):
        super().__init__()
        self.model = feas.FPGAModel()

    def _apply_resources(self):
        r = self.resources
        self.model = feas.FPGAModel(
            total_luts=int(r.get("luts", self.model.total_luts)),
            total_ffs=int(r.get("ffs", self.model.total_ffs)),
            total_bram=int(r.get("bram", self.model.total_bram)),
        )

    def supported_algorithms(self) -> list[str]:
        return ["dnn", "logreg", "svm", "kmeans", "tree"]

    def check(self, algorithm, topology) -> feas.FeasibilityReport:
        e = self.model.estimate(algorithm, topology)
        reasons = []
        if e["luts"] > self.model.total_luts:
            reasons.append(f"LUTs {e['luts']} > {self.model.total_luts}")
        if e["ffs"] > self.model.total_ffs:
            reasons.append(f"FFs {e['ffs']} > {self.model.total_ffs}")
        if self.max_latency_ns is not None and e["latency_ns"] > self.max_latency_ns:
            reasons.append(f"latency {e['latency_ns']:.0f}ns > {self.max_latency_ns}ns")
        if (self.min_throughput_pps is not None
                and e["throughput_pps"] < self.min_throughput_pps):
            reasons.append("clock-limited throughput below requirement")
        return feas.FeasibilityReport(
            not reasons, reasons,
            {"luts": e["luts"], "ffs": e["ffs"], "bram": e["bram"]},
            e["latency_ns"], e["throughput_pps"],
        )


class TPUPlatform(Platform):
    """Beyond-paper backend: fused-Pallas per-packet pipeline on a TPU core."""

    kind = "tpu"

    def __init__(self):
        super().__init__()
        self.model = feas.TPUModel()

    def _apply_resources(self):
        r = self.resources
        self.model = feas.TPUModel(
            vmem_bytes=int(r.get("vmem_bytes", self.model.vmem_bytes)),
            batch=int(r.get("batch", self.model.batch)),
        )

    def supported_algorithms(self) -> list[str]:
        return ["dnn", "logreg", "svm", "kmeans"]

    def check(self, algorithm, topology) -> feas.FeasibilityReport:
        e = self.model.estimate(algorithm, topology)
        reasons = []
        if e["vmem_bytes"] > self.model.vmem_bytes:
            reasons.append(
                f"VMEM {e['vmem_bytes']} > {self.model.vmem_bytes}"
            )
        if self.max_latency_ns is not None and e["latency_ns"] > self.max_latency_ns:
            reasons.append(f"latency {e['latency_ns']:.0f}ns > {self.max_latency_ns}ns")
        if (self.min_throughput_pps is not None
                and e["throughput_pps"] < self.min_throughput_pps):
            reasons.append(
                f"roofline throughput {e['throughput_pps']:.2e} pps "
                f"< {self.min_throughput_pps:.2e}"
            )
        return feas.FeasibilityReport(
            not reasons, reasons,
            {"vmem_bytes": e["vmem_bytes"]},
            e["latency_ns"], e["throughput_pps"],
        )


class Platforms:
    """Factory namespace, as the paper spells it: Platforms.Taurus()."""

    Taurus = TaurusPlatform
    Tofino = TofinoPlatform
    FPGA = FPGAPlatform
    TPU = TPUPlatform
