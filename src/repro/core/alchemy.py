"""Alchemy: the embedded DSL and frontend of Homunculus (paper §3.1).

Constructs (paper Table 1):

  Model({...})            objectives, algorithm list, data loader
  @DataLoader             dataset loading/preprocessing wrapper
  Platforms.Taurus() ...  backend target + resource/performance constraints
  m1 > m2                 sequential composition
  m1 | m2                 parallel composition
                          (natural chains work: ``m1 > m2 > m3`` builds the
                          3-stage Seq — Python's chained-comparison
                          evaluation is intercepted via ``Seq.__bool__``)
  platform < {...}        constraint operator (sugar for .constrain)
  IOMap / @IOMapper       wiring between composed models

A program is exactly the paper's Figure-3 shape::

    import homunculus
    from homunculus.alchemy import DataLoader, Model, Platforms

    @DataLoader
    def wrapper_func():
        ...
        return {"data": {"train": tnx, "test": tsx},
                "labels": {"train": tny, "test": tsy}}

    model_spec = Model({"optimization_metric": ["f1"],
                        "algorithm": ["dnn"],
                        "name": "anomaly_detection",
                        "data_loader": wrapper_func})
    platform = Platforms.Taurus()
    platform.constrain(performance={"throughput": 1, "latency": 500},
                       resources={"rows": 16, "cols": 16})
    platform.schedule(model_spec)
    homunculus.generate(platform)
"""

from __future__ import annotations

import dataclasses
import sys
import threading
from typing import Any, Callable

import numpy as np

from repro.core import feasibility as feas
from repro.data.netdata import Dataset

# ----------------------------------------------------------------- loaders


def DataLoader(fn: Callable) -> Callable:
    """Decorator: normalize a user loader to a repro Dataset.

    Accepts either a ``Dataset`` or the paper's dict form
    {"data": {"train", "test"}, "labels": {"train", "test"}}.
    """

    def wrapper(*a, **kw) -> Dataset:
        out = fn(*a, **kw)
        if isinstance(out, Dataset):
            return out
        data, labels = out["data"], out["labels"]
        tnx = np.asarray(data["train"], np.float32)
        tsx = np.asarray(data["test"], np.float32)
        tny = np.asarray(labels["train"], np.int32)
        tsy = np.asarray(labels["test"], np.int32)
        ncls = int(max(tny.max(), tsy.max())) + 1
        names = out.get(
            "feature_names", [f"f{i}" for i in range(tnx.shape[1])]
        )
        return Dataset(
            name=out.get("name", fn.__name__),
            train_x=tnx, train_y=tny, test_x=tsx, test_y=tsy,
            feature_names=list(names), num_classes=ncls,
        )

    wrapper.__wrapped__ = fn
    wrapper._is_dataloader = True
    return wrapper


def IOMapper(io_ins: list[str], io_outs: list[str]) -> Callable:
    """Decorator: declare a mapping function's input/output port names."""

    def deco(fn):
        fn._io_ins = list(io_ins)
        fn._io_outs = list(io_outs)
        return fn

    return deco


@dataclasses.dataclass
class IOMap:
    """Connects model inputs/outputs (paper Table 1)."""

    mapper_func: Callable  # (features, upstream_outputs) -> features

    def __call__(self, features, upstream):
        return self.mapper_func(features, upstream)


def passthrough_iomap(features, upstream):
    return features


# ------------------------------------------------------------ composition
#
# Python *chains* bare comparisons: ``m1 > m2 > m3`` evaluates as
# ``(m1 > m2) and (m2 > m3)`` — naively the left Seq is silently dropped.
# The fix: when Python truth-tests an intermediate ``Seq`` (the ``and``),
# ``Seq.__bool__`` records (seq, last operand); the very next ``__gt__``
# on that same operand extends the recorded Seq instead of starting a new
# one, so natural chains build the full DAG.  Safety rails — a record is
# only left when BOTH hold:
#   * the truth-tested Seq is an unnamed temporary (CPython refcount ==
#     eval stack + bool arg + getrefcount arg), so a variable-bound Seq
#     (``s = a > b; if s: ...``) never records; and
#   * the truth-test executes at a JUMP_IF_*_OR_POP opcode — the implicit
#     ``and`` of a chained comparison — so ``if a > b: ...`` (POP_JUMP_*)
#     and ``bool(a > b)`` (CALL) never record either;
# and the record is consume-once, cleared by the next ``>``.  Both rails
# are CPython-specific; ``_natural_chain_selfcheck`` probes the behavior
# at import and warns (advising parentheses) where it does not hold.
# (Caveat: tools that rewrite chained comparisons into non-short-circuit
# form — e.g. pytest's assertion rewriter INSIDE an ``assert`` expression —
# bypass the __bool__ hook; build the DAG in a plain statement there.)

class _ChainState(threading.local):
    """Per-thread pending records — concurrent DAG building in threads must
    not cross-contaminate chains.  A STACK, not a slot: the right operand
    of a chain may itself be a parenthesized chain (``a > b > (c > d > e)``)
    whose inner record must coexist with the outer one."""

    def __init__(self):
        self.recs: list = []   # [(seq, last_operand, window), ...]


_CHAIN = _ChainState()
_CHAIN_DEPTH = 8    # pathological-nesting backstop
_TEMP_REFS = 3      # CPython refcount of a stack temporary seen by __bool__
_CHAIN_OPS = frozenset(
    op for name, op in __import__("dis").opmap.items()
    if name in ("JUMP_IF_FALSE_OR_POP", "JUMP_IF_TRUE_OR_POP")
)


def _chain_window():
    """If the ``__bool__`` 3 frames up executes a chain's implicit and,
    return (frame_id, lasti, jump_target) — the consuming ``__gt__`` must
    run in that frame strictly inside (lasti, target].  None = not a chain.

    Two bytecode checks make this precise on CPython <= 3.11:
      * the current opcode is the chain's JUMP_IF_*_OR_POP; and
      * the jump targets a ``ROT_TWO; POP_TOP`` cleanup block — ONLY
        chained comparisons emit that epilogue; a plain ``and``/``or``
        jumps to the end of its expression instead, so value-producing
        conjunctions like ``(a > b) and f(b > c)`` never record.
    Bytecode eras without the dedicated opcode (CPython 3.12) and
    non-CPython frame layouts degrade to a permissive window (refcount
    rail only; the import-time self-checks warn there)."""
    if not _CHAIN_OPS:
        return (None, 0, sys.maxsize)
    try:
        import dis

        f = sys._getframe(2)
        code = f.f_code.co_code
        if code[f.f_lasti] not in _CHAIN_OPS:
            return None
        target = next(
            (i.argval for i in dis.get_instructions(f.f_code)
             if i.offset == f.f_lasti),
            None,
        )
        if target is None:
            return (id(f), f.f_lasti, sys.maxsize)
        rot_two = dis.opmap.get("ROT_TWO")
        pop_top = dis.opmap.get("POP_TOP")
        if rot_two is not None and pop_top is not None:
            if not (target + 2 < len(code)
                    and code[target] == rot_two
                    and code[target + 2] == pop_top):
                return None     # an and/or jump, not a chain epilogue
        return (id(f), f.f_lasti, target)
    except Exception:  # pragma: no cover - permissive on odd interpreters
        return (None, 0, sys.maxsize)


def _chain_take(left_operand):
    """Pop the newest pending chain whose last operand is ``left_operand``
    AND whose bytecode window (between the chain's implicit-and jump and
    its target) covers this ``>`` — a later, unrelated ``>`` on the same
    operand falls outside and never absorbs a record.

    Mismatching records stay put: a parenthesized operand like
    ``a > b > (c > d)`` runs inner compositions between the outer record
    and the outer extending ``__gt__``.  The window, not eager clearing,
    is what expires records (same-frame records past their window are
    pruned here)."""
    try:
        f = sys._getframe(2)
        here = (id(f), f.f_lasti)
    except Exception:  # pragma: no cover
        here = None
    recs = _CHAIN.recs
    for i in range(len(recs) - 1, -1, -1):
        node, operand, (fid, lo, hi) = recs[i]
        if here is not None and fid == here[0] and here[1] > hi:
            del recs[i]          # same frame, past its window: stale
            continue
        if operand is left_operand:
            in_window = (fid is None or here is None
                         or (fid == here[0] and lo < here[1] <= hi))
            if in_window:
                del recs[i:]     # consume; inner records above are done
                return node
    return None


class _Composable:
    def __gt__(self, other):  # m1 > m2 : sequential
        other = _as_node(other)
        chained = _chain_take(self)
        if chained is not None:
            return Seq(chained.children + [other])
        return Seq([self, other])

    def __or__(self, other):  # m1 | m2 : parallel
        # NB: must not clear _CHAIN — ``a > b > (c | d)`` evaluates this
        # mid-chain, after Seq.__bool__ and before the extending __gt__
        return Par([self, _as_node(other)])


def _as_node(x):
    if isinstance(x, (Seq, Par, Model)):
        return x
    raise TypeError(f"cannot compose {type(x)}")


@dataclasses.dataclass
class Seq(_Composable):
    children: list

    def __gt__(self, other):
        other = _as_node(other)
        chained = _chain_take(self)
        if chained is not None:
            return Seq(chained.children + [other])
        return Seq(self.children + [other])

    def __bool__(self):
        # truth-tested mid-chain (the implicit ``and``): remember this Seq
        # so the next ``>`` on our last operand extends it — but only when
        # we are an unnamed temporary AND the call site is a chain's
        # JUMP_IF opcode; ``if seq:`` / ``bool(seq)`` are user truth-tests
        if sys.getrefcount(self) <= _TEMP_REFS:
            window = _chain_window()
            if window is not None:
                _CHAIN.recs.append((self, self.children[-1], window))
                del _CHAIN.recs[:-_CHAIN_DEPTH]
        return True

    def leaves(self) -> list["Model"]:
        out = []
        for c in self.children:
            out += c.leaves() if isinstance(c, (Seq, Par)) else [c]
        return out

    def describe(self) -> str:
        return " > ".join(
            f"({c.describe()})" if isinstance(c, (Seq, Par)) else c.name
            for c in self.children
        )


@dataclasses.dataclass
class Par(_Composable):
    children: list

    def __or__(self, other):
        return Par(self.children + [_as_node(other)])

    def __bool__(self):
        # never part of a chained comparison (| is a binary operator);
        # pending records expire via their bytecode window, not here
        return True

    def leaves(self) -> list["Model"]:
        out = []
        for c in self.children:
            out += c.leaves() if isinstance(c, (Seq, Par)) else [c]
        return out

    def describe(self) -> str:
        return " | ".join(
            f"({c.describe()})" if isinstance(c, (Seq, Par)) else c.name
            for c in self.children
        )


# ------------------------------------------------------------------- Model


class Model(_Composable):
    """User intent for one data-plane ML model (paper §3.1.1)."""

    def __init__(self, spec: dict):
        self.spec = dict(spec)
        self.name: str = spec.get("name", "model")
        self.metrics: list[str] = list(spec.get("optimization_metric", ["f1"]))
        self.algorithms: list[str] | None = (
            list(spec["algorithm"]) if spec.get("algorithm") else None
        )
        loader = spec["data_loader"]
        if not getattr(loader, "_is_dataloader", False):
            loader = DataLoader(loader)
        self._loader = loader
        self._data: Dataset | None = None
        self.iomap: IOMap = IOMap(passthrough_iomap)

    @property
    def objective(self) -> str:
        return self.metrics[0]

    def data(self) -> Dataset:
        if self._data is None:
            self._data = self._loader()
        return self._data

    def with_iomap(self, iomap: IOMap) -> "Model":
        self.iomap = iomap
        return self

    def leaves(self) -> list["Model"]:
        return [self]

    def describe(self) -> str:
        return self.name

    def __repr__(self):
        return f"Model({self.name!r}, metric={self.objective})"


def _natural_chain_selfcheck() -> bool:
    """Probe whether un-parenthesized chaining works on this interpreter."""
    a, b, c = (Model.__new__(Model) for _ in range(3))
    chain = a > b > c
    return isinstance(chain, Seq) and len(chain.children) == 3


def _chain_rails_selfcheck() -> bool:
    """Probe the safety rails: a truth-tested temporary must NOT leak into
    the next composition (fails on bytecode eras with no chain opcode,
    e.g. CPython 3.12, where the rails degrade to refcount-only)."""
    a, b, c = (Model.__new__(Model) for _ in range(3))
    if a > b:
        pass
    probe = b > c
    return len(probe.children) == 2


NATURAL_CHAINS_OK = _natural_chain_selfcheck()
CHAIN_RAILS_OK = _chain_rails_selfcheck()
if not (NATURAL_CHAINS_OK and CHAIN_RAILS_OK):  # pragma: no cover
    import warnings

    warnings.warn(
        "this Python implementation degrades Alchemy's chained-comparison "
        "interception ("
        + ("chains mis-parse" if not NATURAL_CHAINS_OK
           else "truth-tests can leak into later compositions")
        + "): prefer the parenthesized form (m1 > m2) > m3",
        RuntimeWarning,
        stacklevel=2,
    )


# --------------------------------------------------------------- Platforms


class Platform:
    """A physical data-plane target + its constraints (paper Table 1)."""

    kind: str = "abstract"

    def __init__(self):
        self.performance: dict[str, float] = {}
        self.resources: dict[str, float] = {}
        self.scheduled = None  # Model | Seq | Par
        self.generated = None  # filled by homunculus.generate

    # -- constraint API: .constrain(...) and the paper's `<` operator
    def constrain(self, performance: dict | None = None,
                  resources: dict | None = None, **kw):
        performance = performance or kw.get("performance") or {}
        resources = resources or kw.get("resources") or {}
        self.performance.update(performance)
        self.resources.update(resources)
        self._apply_resources()
        return self

    def __lt__(self, cons: dict):
        return self.constrain(
            performance=cons.get("performance"),
            resources=cons.get("resources"),
        )

    def _apply_resources(self):
        pass

    def schedule(self, node):
        """Install a Model or a composition DAG on this platform."""
        self.scheduled = _as_node(node)
        return self

    # -- constraint targets (None = unconstrained)
    @property
    def min_throughput_pps(self) -> float | None:
        thr = self.performance.get("throughput")
        return thr * 1e9 if thr is not None else None  # paper unit: GPkt/s

    @property
    def max_latency_ns(self) -> float | None:
        return self.performance.get("latency")  # paper unit: ns

    # -- to be provided per platform
    def check(self, algorithm: str, topology: dict) -> feas.FeasibilityReport:
        raise NotImplementedError

    def check_batch(self, algorithm: str, topologies: list[dict]
                    ) -> list[feas.FeasibilityReport]:
        """Feasibility verdicts for a whole candidate batch.  Platforms with
        a vectorizable resource model override this (Taurus reads the stage
        metadata of the entire batch in one numpy pass); the base form just
        maps ``check``."""
        return [self.check(algorithm, t) for t in topologies]

    def supported_algorithms(self) -> list[str]:
        raise NotImplementedError


class TaurusPlatform(Platform):
    kind = "taurus"

    def __init__(self):
        super().__init__()
        self.model = feas.TaurusModel()

    def _apply_resources(self):
        r = self.resources
        self.model = feas.TaurusModel(
            rows=int(r.get("rows", self.model.rows)),
            cols=int(r.get("cols", self.model.cols)),
        )

    def supported_algorithms(self) -> list[str]:
        return ["dnn", "logreg", "svm", "kmeans"]

    def check(self, algorithm, topology) -> feas.FeasibilityReport:
        return self._verdict(self.model.estimate(algorithm, topology))

    def check_batch(self, algorithm, topologies
                    ) -> list[feas.FeasibilityReport]:
        return [self._verdict(est)
                for est in self.model.estimate_batch(algorithm, topologies)]

    def _verdict(self, est: dict) -> feas.FeasibilityReport:
        budget_cu = self.model.total_cu
        budget_mu = self.model.total_mu
        min_thr = self.min_throughput_pps
        max_lat = self.max_latency_ns
        # pick the lowest-II (highest-throughput) option that fits; the
        # CU <-> II tradeoff is the paper's "loop iterations vs line rate"
        for opt in est["options"]:
            fits = opt["cu"] <= budget_cu and opt["mu"] <= budget_mu
            fast = min_thr is None or opt["throughput_pps"] >= min_thr
            slow = max_lat is not None and opt["latency_ns"] > max_lat
            if fits and fast and not slow:
                return feas.FeasibilityReport(
                    True, [],
                    {"cu": opt["cu"], "mu": opt["mu"], "ii": opt["ii"]},
                    opt["latency_ns"], opt["throughput_pps"],
                )
        o = est["options"][0]
        reasons = []
        if o["cu"] > budget_cu:
            reasons.append(f"CU {o['cu']} > {budget_cu}")
        if o["mu"] > budget_mu:
            reasons.append(f"MU {o['mu']} > {budget_mu}")
        if min_thr is not None and o["throughput_pps"] < min_thr:
            reasons.append("throughput below line rate at feasible II")
        if max_lat is not None and o["latency_ns"] > max_lat:
            reasons.append(f"latency {o['latency_ns']}ns > {max_lat}ns")
        if not reasons:
            reasons.append("no II in 1..max_ii satisfies all constraints")
        return feas.FeasibilityReport(
            False, reasons, {"cu": o["cu"], "mu": o["mu"], "ii": o["ii"]},
            o["latency_ns"], o["throughput_pps"],
        )


class TofinoPlatform(Platform):
    kind = "tofino"

    def __init__(self):
        super().__init__()
        self.model = feas.MATModel()

    def _apply_resources(self):
        r = self.resources
        self.model = feas.MATModel(
            num_tables=int(r.get("tables", self.model.num_tables)),
        )

    def supported_algorithms(self) -> list[str]:
        return ["kmeans", "svm", "tree", "logreg"]

    def check(self, algorithm, topology) -> feas.FeasibilityReport:
        mats = self.model.mats_for(algorithm, topology)
        lat = mats * self.model.stage_ns
        thr = self.model.line_rate_pps
        reasons = []
        if mats > self.model.num_tables:
            reasons.append(f"MATs {mats} > {self.model.num_tables}")
        if self.max_latency_ns is not None and lat > self.max_latency_ns:
            reasons.append(f"latency {lat}ns > {self.max_latency_ns}ns")
        if (self.min_throughput_pps is not None
                and thr < self.min_throughput_pps):
            reasons.append("line rate below required throughput")
        return feas.FeasibilityReport(
            not reasons, reasons, {"mats": mats}, lat, thr
        )


class FPGAPlatform(Platform):
    kind = "fpga"

    def __init__(self):
        super().__init__()
        self.model = feas.FPGAModel()

    def _apply_resources(self):
        r = self.resources
        self.model = feas.FPGAModel(
            total_luts=int(r.get("luts", self.model.total_luts)),
            total_ffs=int(r.get("ffs", self.model.total_ffs)),
            total_bram=int(r.get("bram", self.model.total_bram)),
        )

    def supported_algorithms(self) -> list[str]:
        return ["dnn", "logreg", "svm", "kmeans", "tree"]

    def check(self, algorithm, topology) -> feas.FeasibilityReport:
        e = self.model.estimate(algorithm, topology)
        reasons = []
        if e["luts"] > self.model.total_luts:
            reasons.append(f"LUTs {e['luts']} > {self.model.total_luts}")
        if e["ffs"] > self.model.total_ffs:
            reasons.append(f"FFs {e['ffs']} > {self.model.total_ffs}")
        if self.max_latency_ns is not None and e["latency_ns"] > self.max_latency_ns:
            reasons.append(f"latency {e['latency_ns']:.0f}ns > {self.max_latency_ns}ns")
        if (self.min_throughput_pps is not None
                and e["throughput_pps"] < self.min_throughput_pps):
            reasons.append("clock-limited throughput below requirement")
        return feas.FeasibilityReport(
            not reasons, reasons,
            {"luts": e["luts"], "ffs": e["ffs"], "bram": e["bram"]},
            e["latency_ns"], e["throughput_pps"],
        )


class TPUPlatform(Platform):
    """Beyond-paper backend: fused-Pallas per-packet pipeline on a TPU core."""

    kind = "tpu"

    def __init__(self):
        super().__init__()
        self.model = feas.TPUModel()

    def _apply_resources(self):
        r = self.resources
        self.model = feas.TPUModel(
            vmem_bytes=int(r.get("vmem_bytes", self.model.vmem_bytes)),
            batch=int(r.get("batch", self.model.batch)),
        )

    def supported_algorithms(self) -> list[str]:
        return ["dnn", "logreg", "svm", "kmeans"]

    def check(self, algorithm, topology) -> feas.FeasibilityReport:
        e = self.model.estimate(algorithm, topology)
        reasons = []
        if e["vmem_bytes"] > self.model.vmem_bytes:
            reasons.append(
                f"VMEM {e['vmem_bytes']} > {self.model.vmem_bytes}"
            )
        if self.max_latency_ns is not None and e["latency_ns"] > self.max_latency_ns:
            reasons.append(f"latency {e['latency_ns']:.0f}ns > {self.max_latency_ns}ns")
        if (self.min_throughput_pps is not None
                and e["throughput_pps"] < self.min_throughput_pps):
            reasons.append(
                f"roofline throughput {e['throughput_pps']:.2e} pps "
                f"< {self.min_throughput_pps:.2e}"
            )
        return feas.FeasibilityReport(
            not reasons, reasons,
            {"vmem_bytes": e["vmem_bytes"]},
            e["latency_ns"], e["throughput_pps"],
        )


class Platforms:
    """Factory namespace, as the paper spells it: Platforms.Taurus()."""

    Taurus = TaurusPlatform
    Tofino = TofinoPlatform
    FPGA = FPGAPlatform
    TPU = TPUPlatform
