"""Pallas serving backend: stage pipelines -> single fused kernel launches.

The interpreter backend executes a compiled pipeline by walking its stage
list (``stageir.apply_stages``) inside one jitted program.  This module is
the other side of the lowering contract
(docs/pipeline_ir.md#pallas-lowering-contract): it pattern-matches whole
stage sequences and lowers each *kernel-eligible* pipeline onto the
hand-written Pallas kernels, one ``pallas_call`` per pipeline, so a packet
batch makes a single HBM->VMEM round trip and only int32 verdicts cross the
kernel boundary.

Kernel-eligible sequences (an optional leading prelude of
``FeatureSelect`` / ``WindowStats`` stages — cheap elementwise feature
prep — is folded into the kernel's input transform):

  ``FusedClassify``                        -> kernels/fused_mlp (in-kernel
  ``FusedMLP [Reduce(argmax)]``               argmax when a Reduce follows)
  ``Dense(relu)* Dense [Reduce(argmax)]``  -> same kernel: a Dense chain is
                                              packed as MLP layers
  ``Quantize LUTGather Reduce [LabelMap]`` -> kernels/mat_lut (quantize,
                                              LUT gather, arg-reduce and
                                              label rewrite in one launch)

Stateful prefixes (``FlowKey RegisterUpdate``, the flow-state contract)
lower through ``lower_stateful_pallas`` onto kernels/flow_update — the
whole hash -> gather -> update -> scatter dataflow as ONE kernel launch
with the register table resident in VMEM.

Everything else (``CentroidDistance``, ``TreeTraverse``, out-of-envelope
shapes) returns ``None`` and the caller falls back to the interpreter —
``compile_stages``/``compile_dag``/``PacketServeEngine`` record which
backend actually serves.

Lane snapping: in interpret mode (CPU) the fused-MLP kernel pads layers to
the model width rounded to 8 instead of the 128-wide MXU tile — identical
numerics (pad lanes are exact zeros), ~60x fewer FLOPs for the Table-2
sized models, which is what makes this the serving hot path off-TPU too.
"""

from __future__ import annotations

from typing import Callable

import numpy as np

from repro.core.stageir import (
    Dense,
    FeatureSelect,
    FlowKey,
    FusedClassify,
    FusedMLP,
    LabelMap,
    LUTGather,
    Quantize,
    Reduce,
    RegisterUpdate,
    Stage,
    WindowStats,
)

__all__ = [
    "pallas_available",
    "pallas_eligible",
    "lower_stages_pallas",
    "stateful_eligible",
    "lower_stateful",
    "lower_stateful_pallas",
]

# stages foldable into the kernel's input transform: stateless, cheap,
# elementwise-ish feature prep ahead of the fused classifier
_PRELUDE = (FeatureSelect, WindowStats)


def _split_prelude(stages: list[Stage]):
    pre: list[Stage] = []
    body = list(stages)
    while body and isinstance(body[0], _PRELUDE):
        pre.append(body.pop(0))
    return pre, body


def pallas_available() -> bool:
    """Is the Pallas toolchain importable in this process?"""
    try:
        from jax.experimental import pallas  # noqa: F401

        return True
    except Exception:
        return False


def _match_mlp(stages: list[Stage]):
    """-> (weights, biases, classify) for dense/fused-MLP runs, else None."""
    if not stages:
        return None
    classify = False
    body = list(stages)
    if isinstance(body[-1], Reduce):
        if body[-1].op != "argmax":
            return None
        classify = True
        body = body[:-1]
    if len(body) == 1 and isinstance(body[0], (FusedMLP, FusedClassify)):
        if isinstance(body[0], FusedClassify):
            classify = True
        return body[0].weights, body[0].biases, classify
    if body and all(isinstance(s, Dense) for s in body):
        # a Dense chain is an MLP iff activations follow the relu*…linear
        # shape the kernel hard-codes
        if any(s.act != "relu" for s in body[:-1]) or body[-1].act is not None:
            return None
        return ([s.w for s in body], [s.b for s in body], classify)
    return None


def _match_mat(stages: list[Stage]):
    """-> (edges, tables, label_map, use_min) for MAT runs, else None."""
    if len(stages) < 3 or not isinstance(stages[0], Quantize) \
            or not isinstance(stages[1], LUTGather) \
            or not isinstance(stages[2], Reduce):
        return None
    tail = stages[3:]
    if len(tail) > 1 or (tail and not isinstance(tail[0], LabelMap)):
        return None
    tables = np.asarray(stages[1].tables)
    lmap = (np.asarray(tail[0].table, np.int32) if tail
            else np.arange(tables.shape[2], dtype=np.int32))
    return (np.asarray(stages[0].edges), tables, lmap,
            stages[2].op == "argmin")


def _in_envelope_mlp(weights) -> bool:
    from repro.kernels.fused_mlp import LANE

    widths = [int(weights[0].shape[0])] + [int(w.shape[1]) for w in weights]
    return max(widths) <= LANE


def _in_envelope_mat(tables, lmap) -> bool:
    from repro.kernels import mat_lut as mat_ops

    F, bins, C = tables.shape
    return (F <= mat_ops.MAX_FEATURES and bins <= mat_ops.MAX_BINS
            and C <= mat_ops.LANE and lmap.shape[0] <= mat_ops.LANE)


def pallas_eligible(stages: list[Stage]) -> bool:
    """Would ``lower_stages_pallas`` produce a kernel for this pipeline?

    Shape checks only — no parameter packing or device transfers."""
    if not pallas_available():
        return False
    _, body = _split_prelude(stages)
    mlp = _match_mlp(body)
    if mlp is not None:
        return _in_envelope_mlp(mlp[0])
    mat = _match_mat(body)
    if mat is not None:
        return _in_envelope_mat(mat[1], mat[2])
    return False


def lower_stages_pallas(stages: list[Stage]) -> Callable | None:
    """Lower a whole stage list onto one Pallas kernel launch.

    Returns a traceable ``fn(x: [B, F]) -> verdicts/logits`` closing over
    the packed parameters, or ``None`` when the sequence is outside the
    kernel envelope (the caller then falls back to the interpreter)."""
    if not pallas_available():
        return None
    import jax
    import jax.numpy as jnp

    from repro.kernels import fused_mlp as fm_ops
    from repro.kernels import mat_lut as mat_ops
    from repro.kernels.fused_mlp import snap_lane

    pre, body = _split_prelude(stages)

    def pre_fn(x, _pre=tuple(pre)):
        for s in _pre:
            x = s.apply(x)
        return x

    interpret = jax.default_backend() != "tpu"

    mlp = _match_mlp(body)
    if mlp is not None:
        weights, biases, classify = mlp
        if not _in_envelope_mlp(weights):
            return None
        widths = [int(weights[0].shape[0])] + [int(w.shape[1])
                                               for w in weights]
        lane = snap_lane(widths, interpret=interpret)
        ws = [jnp.asarray(w, jnp.float32) for w in weights]
        bs = [jnp.asarray(b, jnp.float32) for b in biases]
        op = fm_ops.fused_mlp_classify if classify else fm_ops.fused_mlp

        def mlp_fn(x, _op=op, _ws=ws, _bs=bs, _lane=lane):
            return _op(pre_fn(x), _ws, _bs, lane=_lane)

        return mlp_fn

    mat = _match_mat(body)
    if mat is not None:
        edges, tables, lmap, use_min = mat
        if not _in_envelope_mat(tables, lmap):
            return None
        edges_j = jnp.asarray(edges, jnp.float32)
        tables_j = jnp.asarray(tables, jnp.float32)
        lmap_j = jnp.asarray(lmap, jnp.int32)

        def mat_fn(x, _e=edges_j, _t=tables_j, _l=lmap_j, _m=use_min):
            return mat_ops.mat_classify(pre_fn(x), _e, _t, _l, use_min=_m)

        return mat_fn

    return None


# ------------------------------------------------------- stateful prefixes


def stateful_eligible(prefix: list[Stage]) -> bool:
    """Would ``lower_stateful_pallas`` fuse this ``[FlowKey,
    RegisterUpdate]`` prefix?  Shape checks only."""
    if not pallas_available():
        return False
    if len(prefix) != 2 or not isinstance(prefix[0], FlowKey) \
            or not isinstance(prefix[1], RegisterUpdate):
        return False
    from repro.kernels import flow_update as fu

    spec = prefix[1].spec
    return (spec.n_slots <= fu.MAX_SLOTS and spec.width <= fu.MAX_WIDTH
            and len(spec.hist_sizes) <= fu.MAX_HISTS)


def lower_stateful(prefix: list[Stage], backend: str
                   ) -> tuple[Callable, str]:
    """Lower a ``[FlowKey, RegisterUpdate]`` prefix for one engine.

    -> (traceable ``fn(keys, regs, x, valid) -> (keys', regs', feats)``,
    the engine that actually serves).  Key derivation and update-vector
    prep are vectorized jnp either way; the hash/gather/update/scatter
    chain is the fused Pallas kernel (kernels/flow_update) when
    ``backend="pallas"`` and the table fits the kernel envelope, else the
    jnp scan reference — bit-identical per the flow-state contract.  This
    is the ONE place the prefix calling convention is wired; every
    stateful consumer goes through it."""
    use_kernel = backend == "pallas" and stateful_eligible(prefix)
    from repro.kernels import flow_update as fu

    fk, ru = prefix
    spec = ru.spec
    update = fu.flow_update if use_kernel else fu.flow_update_ref

    def flow_fn(keys, regs, x, valid, _fk=fk, _ru=ru, _spec=spec,
                _update=update):
        pkt_keys = _fk.apply_keys(x)
        upd, bins = _ru.prepare(x)
        return _update(
            keys, regs, pkt_keys, upd, bins, valid,
            n_counters=_spec.n_counters, n_ewma=_spec.n_ewma,
            alpha=_spec.ewma_alpha,
        )

    return flow_fn, ("pallas" if use_kernel else "interpret")


def lower_stateful_pallas(prefix: list[Stage]) -> Callable | None:
    """Kernel-or-None form of ``lower_stateful`` (mirrors
    ``lower_stages_pallas``): the fused flow-update launch, or ``None``
    when the table is outside the kernel envelope."""
    if not stateful_eligible(prefix):
        return None
    return lower_stateful(prefix, "pallas")[0]
