"""Pallas serving backend: stage pipelines -> single fused kernel launches.

The interpreter backend executes a compiled pipeline by walking its stage
list (``stageir.apply_stages``) inside one jitted program.  This module is
the other side of the lowering contract
(docs/pipeline_ir.md#pallas-lowering-contract): it pattern-matches whole
stage sequences and lowers each *kernel-eligible* pipeline onto the
hand-written Pallas kernels, one ``pallas_call`` per pipeline, so a packet
batch makes a single HBM->VMEM round trip and only int32 verdicts cross the
kernel boundary.

Kernel-eligible sequences (an optional leading prelude of
``FeatureSelect`` / ``WindowStats`` stages — cheap elementwise feature
prep — is folded into the kernel's input transform):

  ``FusedClassify``                        -> kernels/fused_mlp (in-kernel
  ``FusedMLP [Reduce(argmax)]``               argmax when a Reduce follows)
  ``Dense(relu)* Dense [Reduce(argmax)]``  -> same kernel: a Dense chain is
                                              packed as MLP layers
  ``Quantize LUTGather Reduce [LabelMap]`` -> kernels/mat_lut (quantize,
                                              LUT gather, arg-reduce and
                                              label rewrite in one launch)

Stateful prefixes (``FlowKey RegisterUpdate``, the flow-state contract)
lower through ``lower_stateful_pallas`` onto kernels/flow_update — the
whole hash -> gather -> update -> scatter dataflow as ONE kernel launch
with the register table resident in VMEM.

Everything else (``CentroidDistance``, ``TreeTraverse``, out-of-envelope
shapes) returns ``None`` and the caller falls back to the interpreter —
``compile_stages``/``compile_dag``/``PacketServeEngine`` record which
backend actually serves.

Lane snapping: in interpret mode (CPU) the fused-MLP kernel pads layers to
the model width rounded to 8 instead of the 128-wide MXU tile — identical
numerics (pad lanes are exact zeros), ~60x fewer FLOPs for the Table-2
sized models, which is what makes this the serving hot path off-TPU too.
"""

from __future__ import annotations

from typing import Callable

import numpy as np

from repro.core.stageir import (
    CentroidDistance,
    Dense,
    FeatureSelect,
    FlowKey,
    FusedClassify,
    FusedMLP,
    LabelMap,
    LUTGather,
    Quantize,
    Reduce,
    RegisterUpdate,
    Stage,
    WindowStats,
)

__all__ = [
    "pallas_available",
    "pallas_eligible",
    "lower_stages_pallas",
    "dag_eligible",
    "lower_dag_pallas",
    "stateful_eligible",
    "lower_stateful",
    "lower_mitigation",
    "lower_stateful_pallas",
    "fused_flow_eligible",
    "fused_flow_decline_reason",
    "lower_stateful_fused",
]

# stages foldable into the kernel's input transform: stateless, cheap,
# elementwise-ish feature prep ahead of the fused classifier
_PRELUDE = (FeatureSelect, WindowStats)


def _split_prelude(stages: list[Stage]):
    pre: list[Stage] = []
    body = list(stages)
    while body and isinstance(body[0], _PRELUDE):
        pre.append(body.pop(0))
    return pre, body


def pallas_available() -> bool:
    """Is the Pallas toolchain importable in this process?"""
    try:
        from jax.experimental import pallas  # noqa: F401

        return True
    except Exception:
        return False


def _match_mlp(stages: list[Stage]):
    """-> (weights, biases, classify) for dense/fused-MLP runs, else None."""
    if not stages:
        return None
    classify = False
    body = list(stages)
    if isinstance(body[-1], Reduce):
        if body[-1].op != "argmax":
            return None
        classify = True
        body = body[:-1]
    if len(body) == 1 and isinstance(body[0], (FusedMLP, FusedClassify)):
        if isinstance(body[0], FusedClassify):
            classify = True
        return body[0].weights, body[0].biases, classify
    if body and all(isinstance(s, Dense) for s in body):
        # a Dense chain is an MLP iff activations follow the relu*…linear
        # shape the kernel hard-codes
        if any(s.act != "relu" for s in body[:-1]) or body[-1].act is not None:
            return None
        return ([s.w for s in body], [s.b for s in body], classify)
    return None


def _match_mat(stages: list[Stage]):
    """-> (edges, tables, label_map, use_min) for MAT runs, else None."""
    if len(stages) < 3 or not isinstance(stages[0], Quantize) \
            or not isinstance(stages[1], LUTGather) \
            or not isinstance(stages[2], Reduce):
        return None
    tail = stages[3:]
    if len(tail) > 1 or (tail and not isinstance(tail[0], LabelMap)):
        return None
    tables = np.asarray(stages[1].tables)
    lmap = (np.asarray(tail[0].table, np.int32) if tail
            else np.arange(tables.shape[2], dtype=np.int32))
    return (np.asarray(stages[0].edges), tables, lmap,
            stages[2].op == "argmin")


def _in_envelope_mlp(weights) -> bool:
    from repro.kernels.fused_mlp import LANE

    widths = [int(weights[0].shape[0])] + [int(w.shape[1]) for w in weights]
    return max(widths) <= LANE


def _in_envelope_mat(tables, lmap) -> bool:
    from repro.kernels import mat_lut as mat_ops

    F, bins, C = tables.shape
    return (F <= mat_ops.MAX_FEATURES and bins <= mat_ops.MAX_BINS
            and C <= mat_ops.LANE and lmap.shape[0] <= mat_ops.LANE)


def pallas_eligible(stages: list[Stage]) -> bool:
    """Would ``lower_stages_pallas`` produce a kernel for this pipeline?

    Shape checks only — no parameter packing or device transfers."""
    if not pallas_available():
        return False
    _, body = _split_prelude(stages)
    mlp = _match_mlp(body)
    if mlp is not None:
        return _in_envelope_mlp(mlp[0])
    mat = _match_mat(body)
    if mat is not None:
        return _in_envelope_mat(mat[1], mat[2])
    return False


def lower_stages_pallas(stages: list[Stage]) -> Callable | None:
    """Lower a whole stage list onto one Pallas kernel launch.

    Returns a traceable ``fn(x: [B, F]) -> verdicts/logits`` closing over
    the packed parameters, or ``None`` when the sequence is outside the
    kernel envelope (the caller then falls back to the interpreter)."""
    if not pallas_available():
        return None
    import jax
    import jax.numpy as jnp

    from repro.kernels import fused_mlp as fm_ops
    from repro.kernels import mat_lut as mat_ops
    from repro.kernels.fused_mlp import snap_lane

    pre, body = _split_prelude(stages)

    def pre_fn(x, _pre=tuple(pre)):
        for s in _pre:
            x = s.apply(x)
        return x

    interpret = jax.default_backend() != "tpu"

    mlp = _match_mlp(body)
    if mlp is not None:
        weights, biases, classify = mlp
        if not _in_envelope_mlp(weights):
            return None
        widths = [int(weights[0].shape[0])] + [int(w.shape[1])
                                               for w in weights]
        lane = snap_lane(widths, interpret=interpret)
        ws = [jnp.asarray(w, jnp.float32) for w in weights]
        bs = [jnp.asarray(b, jnp.float32) for b in biases]
        op = fm_ops.fused_mlp_classify if classify else fm_ops.fused_mlp

        def mlp_fn(x, _op=op, _ws=ws, _bs=bs, _lane=lane):
            return _op(pre_fn(x), _ws, _bs, lane=_lane)

        return mlp_fn

    mat = _match_mat(body)
    if mat is not None:
        edges, tables, lmap, use_min = mat
        if not _in_envelope_mat(tables, lmap):
            return None
        edges_j = jnp.asarray(edges, jnp.float32)
        tables_j = jnp.asarray(tables, jnp.float32)
        lmap_j = jnp.asarray(lmap, jnp.int32)

        def mat_fn(x, _e=edges_j, _t=tables_j, _l=lmap_j, _m=use_min):
            return mat_ops.mat_classify(pre_fn(x), _e, _t, _l, use_min=_m)

        return mat_fn

    return None


# ------------------------------------------------------ cross-model DAGs
#
# A Seq/Par DAG whose every leaf is an MLP-shaped classifier lowers onto
# ONE fused Pallas launch (kernels/fused_mlp.fused_dag): all chained
# models' weights resident in VMEM for the launch, Seq gating and Par
# or/and merges applied in-kernel on the int32 verdicts.  Eliminates the
# per-model HBM round trips the per-model-launch path pays between chained
# models; recorded as backend="pallas-fused-dag" by chaining.compile_dag.


def _fold_feature_select(pre: list[Stage], w0: np.ndarray, n_feat: int):
    """Fold a FeatureSelect-only prelude into the first-layer weights.

    ``x[:, idx] @ W0 == x @ S @ W0`` for the 0/1 selection matrix S; with a
    *strictly increasing, duplicate-free* composite index the embedded
    rows keep their original summation order and the interleaved rows are
    exact zeros, so the folded matmul stays bit-identical (the same
    argument that makes lane padding exact).  Returns the [n_feat, h]
    first-layer weights, or ``None`` when the prelude is outside that
    envelope (unsorted/duplicated selection, non-FeatureSelect stages, or
    an index beyond the DAG input width)."""
    if not all(isinstance(s, FeatureSelect) for s in pre):
        return None
    idx = np.asarray(pre[0].idx, np.int64)
    for s in pre[1:]:
        idx = idx[np.asarray(s.idx, np.int64)]
    if idx.size != w0.shape[0] or np.any(np.diff(idx) <= 0):
        return None
    if idx.size and int(idx[-1]) >= n_feat:
        return None
    folded = np.zeros((n_feat, w0.shape[1]), np.float32)
    folded[idx] = np.asarray(w0, np.float32)
    return folded


def _match_dag_leaf(stages: list[Stage]):
    """Post-peephole leaf stage list -> (prelude, weights, biases) for a
    megakernel-eligible classifier, else None.  The leaf must produce
    class-id verdicts (an MLP/Dense chain ending in an in-kernel argmax)."""
    pre, body = _split_prelude(stages)
    if any(not isinstance(s, FeatureSelect) for s in pre):
        return None
    mlp = _match_mlp(body)
    if mlp is None or not mlp[2]:        # gating needs int32 verdicts
        return None
    weights, biases = mlp[0], mlp[1]
    if not _in_envelope_mlp(weights):
        return None
    return pre, list(weights), list(biases)


def _plan_dag(node, result, combine: str, fuse: bool):
    """Walk an Alchemy DAG -> (plan, models) where ``models`` is the
    deduplicated list of (prelude, weights, biases) and ``plan`` the
    nested static structure ``kernels/fused_mlp.eval_dag_plan`` folds.
    Returns None anywhere the DAG leaves the megakernel envelope."""
    from repro.core import stageir
    from repro.core.alchemy import Model, Par, Seq

    models: list = []
    index_of: dict[int, int] = {}        # id(pipeline) -> model slot

    def walk(n):
        if isinstance(n, Model):
            entry = result[n.name]
            pipe = entry.pipeline if hasattr(entry, "pipeline") else entry
            if id(pipe) not in index_of:
                stages = pipe.stages
                if fuse:
                    stages = stageir.fuse_pipeline_stages(stages)
                leaf = _match_dag_leaf(stages)
                if leaf is None:
                    return None
                index_of[id(pipe)] = len(models)
                models.append(leaf)
            return ("model", index_of[id(pipe)])
        if isinstance(n, Seq):
            parts = [walk(c) for c in n.children]
            if any(p is None for p in parts):
                return None
            return ("seq", tuple(parts))
        if isinstance(n, Par):
            if combine not in ("or", "and"):
                return None              # "concat" has no verdict merge
            parts = [walk(c) for c in n.children]
            if any(p is None for p in parts):
                return None
            return (combine, tuple(parts))
        return None

    plan = walk(node)
    if plan is None:
        return None
    return plan, models


def _dag_input_dim(models: list) -> int | None:
    """The DAG input width, read off the no-prelude leaves (every model in
    a DAG consumes the same packet rows).  None when every leaf hides its
    input width behind a FeatureSelect — the fold target is then unknown
    and the DAG falls back to per-model launches."""
    dims = [int(w[0].shape[0]) for pre, w, b in models if not pre]
    if not dims:
        return None
    return max(dims)


def dag_eligible(node, result, *, combine: str = "or",
                 fuse: bool = True) -> bool:
    """Would ``lower_dag_pallas`` fuse this whole DAG into one launch?
    Shape checks only — no parameter packing or device transfers."""
    if not pallas_available():
        return False
    if len(getattr(node, "leaves", lambda: [None])()) < 2:
        return False                     # a bare model is not a DAG
    planned = _plan_dag(node, result, combine, fuse)
    if planned is None:
        return False
    plan, models = planned
    n_feat = _dag_input_dim(models)
    if n_feat is None:
        return False
    import jax

    from repro.kernels import fused_mlp as fm

    interpret = jax.default_backend() != "tpu"
    n_layers, lanes = [], []
    for pre, w, b in models:
        if pre and _fold_feature_select(pre, np.asarray(w[0]), n_feat) is None:
            return False
        if not pre and int(w[0].shape[0]) != n_feat:
            return False
        widths = [n_feat] + [int(x.shape[1]) for x in w]
        if max(widths) > fm.LANE:
            return False
        n_layers.append(len(w))
        lanes.append(fm.snap_lane(widths, interpret=interpret))
    return fm.dag_vmem_bytes(tuple(n_layers), tuple(lanes)) \
        <= fm.DAG_VMEM_BUDGET


def lower_dag_pallas(node, result, *, combine: str = "or",
                     fuse: bool = True):
    """Lower a whole Seq/Par DAG onto ONE fused Pallas kernel launch.

    Returns a traceable ``fn(x: [B, F]) -> verdicts [B] int32`` closing
    over every model's packed weight stacks, or ``None`` when any leaf (or
    the DAG shape itself) is outside the megakernel envelope — the caller
    then falls back to per-model launches.  Bit-exact vs ``run_dag`` by
    the same padding/masking arguments as the single-model kernel."""
    if not pallas_available():
        return None
    if len(getattr(node, "leaves", lambda: [None, None])()) < 2:
        return None
    planned = _plan_dag(node, result, combine, fuse)
    if planned is None:
        return None
    plan, models = planned
    n_feat = _dag_input_dim(models)
    if n_feat is None:
        return None

    import jax
    import jax.numpy as jnp

    from repro.kernels import fused_mlp as fm

    folded: list[tuple[list, list]] = []
    widths_all: list[int] = [n_feat]
    for pre, weights, biases in models:
        w0 = np.asarray(weights[0], np.float32)
        if pre:
            w0 = _fold_feature_select(pre, w0, n_feat)
            if w0 is None:
                return None
        elif w0.shape[0] != n_feat:
            return None                  # inconsistent leaf input widths
        ws = [w0] + [np.asarray(w, np.float32) for w in weights[1:]]
        widths_all += [int(w.shape[1]) for w in ws]
        folded.append((ws, [np.asarray(b, np.float32) for b in biases]))

    interpret = jax.default_backend() != "tpu"
    if max(widths_all) > fm.LANE:
        return None

    # each model keeps its own snapped lane (the per-model path's tile
    # choice), so the fused launch does the same FLOPs as per-model
    # launches and only removes the inter-model HBM round trips
    stacks: list = []
    lanes: list[int] = []
    for ws, bs in folded:
        lane = fm.snap_lane(
            [n_feat] + [int(w.shape[1]) for w in ws], interpret=interpret
        )
        lanes.append(lane)
        w_stack, b_stack = fm.pack_params(
            [jnp.asarray(w) for w in ws], [jnp.asarray(b) for b in bs], lane
        )
        stacks += [w_stack, b_stack]
    stacks = tuple(stacks)
    n_layers = tuple(len(ws) for ws, _ in folded)
    n_classes = tuple(int(ws[-1].shape[1]) for ws, _ in folded)
    if fm.dag_vmem_bytes(n_layers, tuple(lanes)) > fm.DAG_VMEM_BUDGET:
        return None                      # cannot be VMEM-resident: fall back

    def dag_fn(x, _stacks=stacks, _nl=n_layers, _nc=n_classes,
               _lanes=tuple(lanes), _plan=plan, _interp=interpret):
        return fm.fused_dag(
            x, _stacks, n_layers=_nl, n_classes=_nc, lanes=_lanes,
            plan=_plan, interpret=_interp,
        )

    return dag_fn


# ------------------------------------------------------- stateful prefixes


def stateful_eligible(prefix: list[Stage]) -> bool:
    """Would ``lower_stateful_pallas`` fuse this ``[FlowKey,
    RegisterUpdate]`` prefix?  Shape checks only."""
    if not pallas_available():
        return False
    if len(prefix) != 2 or not isinstance(prefix[0], FlowKey) \
            or not isinstance(prefix[1], RegisterUpdate):
        return False
    from repro.kernels import flow_update as fu

    spec = prefix[1].spec
    return (spec.n_slots <= fu.MAX_SLOTS and spec.width <= fu.MAX_WIDTH
            and len(spec.hist_sizes) <= fu.MAX_HISTS)


def lower_stateful(prefix: list[Stage], backend: str
                   ) -> tuple[Callable, str]:
    """Lower a ``[FlowKey, RegisterUpdate]`` prefix for one engine.

    -> (traceable ``fn(keys, regs, x, valid) -> (keys', regs', feats)``,
    the engine that actually serves).  Key derivation and update-vector
    prep are vectorized jnp either way; the hash/gather/update/scatter
    chain is the fused Pallas kernel (kernels/flow_update) when
    ``backend="pallas"`` and the table fits the kernel envelope, else the
    jnp scan reference — bit-identical per the flow-state contract.  This
    is the ONE place the prefix calling convention is wired; every
    stateful consumer goes through it."""
    use_kernel = backend == "pallas" and stateful_eligible(prefix)
    from repro.kernels import flow_update as fu

    fk, ru = prefix
    spec = ru.spec
    update = fu.flow_update if use_kernel else fu.flow_update_ref

    def flow_fn(keys, regs, x, valid, _fk=fk, _ru=ru, _spec=spec,
                _update=update):
        pkt_keys = _fk.apply_keys(x)
        upd, bins = _ru.prepare(x)
        return _update(
            keys, regs, pkt_keys, upd, bins, valid,
            n_counters=_spec.n_counters, n_ewma=_spec.n_ewma,
            alpha=_spec.ewma_alpha,
        )

    return flow_fn, ("pallas" if use_kernel else "interpret")


def lower_mitigation(mit) -> tuple[Callable, str]:
    """Lower a trailing ``Mitigate`` stage for the SPLIT serving path.

    -> (traceable ``fn(mit_keys, mit_regs, pkt_keys, verdicts, valid) ->
    (mit_keys', mit_regs', out_verdicts)``, the engine that actually
    serves).  The fused launch folds the action table in-kernel
    (``lower_stateful_fused`` with ``mitigation=``); this split form is
    the fallback when the rest of the pipeline is outside the fused
    envelope.  Here the action-table scan is the order-dependent shared
    jnp reference (flowstate.mitigation.mitigate_update), so the engine
    is always ``"interpret"`` — reported honestly: ``StatefulPipeline``
    composes it into ``"mixed"`` when the detection half serves on
    Pallas.  Bit-identical to the fused form per the mitigation
    contract."""
    from repro.flowstate.mitigation import mitigate_update

    spec = mit.spec

    def mit_fn(mit_keys, mit_regs, pkt_keys, verdicts, valid, _spec=spec):
        return mitigate_update(mit_keys, mit_regs, pkt_keys, verdicts,
                               valid, spec=_spec)

    return mit_fn, "interpret"


def lower_stateful_pallas(prefix: list[Stage]) -> Callable | None:
    """Kernel-or-None form of ``lower_stateful`` (mirrors
    ``lower_stages_pallas``): the fused flow-update launch, or ``None``
    when the table is outside the kernel envelope."""
    if not stateful_eligible(prefix):
        return None
    return lower_stateful(prefix, "pallas")[0]


# ------------------------------------------------- fully-fused flow path
#
# The whole stateful pipeline — FlowKey -> RegisterUpdate -> feature-emit
# -> classifier [-> Mitigate] — as ONE Pallas launch (kernels/fused_flow):
# register table(s), the classifier parameters AND the mitigation action
# table co-resident in VMEM, feature rows consumed in-kernel, only int32
# verdicts and the updated tables leaving.  The fused envelope covers
# MLP, MAT (Quantize -> LUTGather -> Reduce -> [LabelMap]) and
# CentroidDistance suffixes, plus multi-table DAGs (several FlowKey /
# RegisterUpdate groups feeding one classifier).  StatefulPipeline tries
# this form FIRST under backend="pallas" and reports "pallas-fused-flow"
# when it serves; `fused_flow_decline_reason` names WHY a pipeline fell
# back to the split composition (surfaced in ServeStats / the journal).


def _match_centroid(stages: list[Stage]):
    """-> (feature_idx | None, centroids, label_map, use_min) when the
    stage run is ``[FeatureSelect?] CentroidDistance Reduce [LabelMap?]``,
    else None."""
    body = list(stages)
    fidx = None
    if body and isinstance(body[0], FeatureSelect):
        fidx = tuple(int(i) for i in np.asarray(body[0].idx).ravel())
        body = body[1:]
    if len(body) < 2 or not isinstance(body[0], CentroidDistance) \
            or not isinstance(body[1], Reduce):
        return None
    tail = body[2:]
    if len(tail) > 1 or (tail and not isinstance(tail[0], LabelMap)):
        return None
    cent = np.asarray(body[0].centroids, np.float32)
    lmap = (np.asarray(tail[0].table, np.int32) if tail
            else np.arange(cent.shape[0], dtype=np.int32))
    return fidx, cent, lmap, body[1].op == "argmin"


def _as_table_groups(prefix_or_groups):
    """Normalize the prefix argument: a plain ``[FlowKey, RegisterUpdate]``
    prefix (the single-table form) or a ``split_stateful_multi`` group
    list -> list of (flow_key, register_update, window_stats | None)."""
    seq = list(prefix_or_groups)
    if seq and isinstance(seq[0], FlowKey):
        if len(seq) != 2 or not isinstance(seq[1], RegisterUpdate):
            return None
        return [(seq[0], seq[1], None)]
    groups = []
    for g in seq:
        g = tuple(g)
        if len(g) == 2:
            g = (g[0], g[1], None)
        if len(g) != 3 or not isinstance(g[0], FlowKey) \
                or not isinstance(g[1], RegisterUpdate):
            return None
        groups.append(g)
    return groups or None


def _plan_fused(prefix_or_groups, suffix: list[Stage], mitigation=None):
    """Pattern-match the WHOLE fused launch -> (desc, reason) with exactly
    one of the two non-None.  ``desc`` carries everything the lowering
    needs: the folded table groups + readout modes, a tagged suffix
    descriptor, and the mitigation spec.  ``reason`` is the short honest
    decline string surfaced by ``fused_flow_decline_reason``."""
    from repro.kernels.fused_flow import LANE as FF_LANE

    groups = _as_table_groups(prefix_or_groups)
    if groups is None:
        return None, "no [FlowKey, RegisterUpdate] table groups"
    body = list(suffix)
    # single-table back-compat: a leading suffix WindowStats is that
    # table's readout (multi-table groups carry theirs explicitly)
    if len(groups) == 1 and groups[0][2] is None and body \
            and isinstance(body[0], WindowStats):
        groups[0] = (groups[0][0], groups[0][1], body[0])
        body = body[1:]

    modes, n_in = [], 0
    for fk, ru, ws in groups:
        spec = ru.spec
        if not stateful_eligible([fk, ru]):
            return None, "flow table outside the flow_update envelope"
        if spec.width > FF_LANE:
            return None, "register width exceeds the kernel lane"
        if ws is None:
            modes.append("raw")
            n_in += spec.width
        else:
            s = ws.spec
            if (s.width != spec.width or s.n_counters != spec.n_counters
                    or s.n_ewma != spec.n_ewma):
                return None, "WindowStats readout disagrees with its table"
            modes.append(ws.mode)
            n_in += ws.n_out

    if mitigation is not None:
        from repro.kernels import flow_update as fu

        if mitigation.spec.n_slots > fu.MAX_SLOTS:
            return None, "mitigation table outside the kernel envelope"

    mlp = _match_mlp(body)
    if mlp is not None:
        weights, biases, classify = mlp
        if not classify:
            return None, "classifier lacks an in-kernel argmax reduce"
        if int(weights[0].shape[0]) != n_in:
            return None, "classifier input width mismatch"
        widths = [n_in] + [int(w.shape[1]) for w in weights]
        if max(widths) > FF_LANE:
            return None, "classifier width exceeds the kernel lane"
        sfx = ("mlp", list(weights), list(biases))
    else:
        mat = _match_mat(body)
        if mat is not None:
            edges, tables, lmap, use_min = mat
            if int(edges.shape[0]) != n_in:
                return None, "classifier input width mismatch"
            if not _in_envelope_mat(tables, lmap):
                return None, "MAT shape outside the kernel envelope"
            sfx = ("mat", edges, tables, lmap, use_min)
        else:
            cen = _match_centroid(body)
            if cen is None:
                return None, "suffix is not a fused-envelope classifier"
            fidx, cent, lmap, use_min = cen
            if fidx is not None:
                if max(fidx, default=-1) >= n_in \
                        or cent.shape[1] != len(fidx):
                    return None, "classifier input width mismatch"
            elif cent.shape[1] != n_in:
                return None, "classifier input width mismatch"
            if cent.shape[0] > FF_LANE or lmap.shape[0] > FF_LANE \
                    or cent.shape[1] > FF_LANE:
                return None, "centroid shape outside the kernel envelope"
            sfx = ("centroid", fidx, cent, lmap, use_min)

    mit_spec = mitigation.spec if mitigation is not None else None
    return (groups, tuple(modes), sfx, mit_spec), None


def fused_flow_decline_reason(prefix_or_groups, suffix: list[Stage],
                              mitigation=None) -> str | None:
    """Why would ``lower_stateful_fused`` decline this pipeline?

    ``None`` means the single-launch form serves.  Shape checks only —
    no parameter packing or device transfers.  The string is the honest
    fallback reason the serving engines surface (ServeStats backend keys,
    ``backend_fallback`` journal events)."""
    if not pallas_available():
        return "pallas toolchain unavailable"
    _, reason = _plan_fused(prefix_or_groups, suffix, mitigation)
    return reason


def fused_flow_eligible(prefix_or_groups, suffix: list[Stage],
                        mitigation=None) -> bool:
    """Would ``lower_stateful_fused`` produce the single-launch form?
    Shape checks only — no parameter packing or device transfers."""
    return fused_flow_decline_reason(prefix_or_groups, suffix,
                                     mitigation) is None


def _pack_suffix(sfx, tile: int, interpret: bool):
    """Suffix descriptor -> (SuffixPlan, pre-padded device arrays).

    Packing happens ONCE here, at lowering time: lane-snapped MLP stacks
    (``fused_mlp.pack_params``), +inf-padded MAT edges / zero-padded
    tables (the exact ``mat_lut.mat_classify`` convention, so the in-
    kernel replay sees identical operands), zero-padded centroid rows
    (pad lanes contribute exact zeros to the squared distances)."""
    import jax.numpy as jnp

    from repro.kernels.fused_flow import SuffixPlan
    from repro.kernels.fused_mlp import pack_params, snap_lane
    from repro.kernels.mat_lut.ops import _snap

    if sfx[0] == "mlp":
        _, weights, biases = sfx
        widths = [int(weights[0].shape[0])] + [int(w.shape[1])
                                               for w in weights]
        lane = snap_lane(widths, interpret=interpret)
        w_stack, b_stack = pack_params(
            [jnp.asarray(w, jnp.float32) for w in weights],
            [jnp.asarray(b, jnp.float32) for b in biases],
            lane,
        )
        sp = SuffixPlan("mlp", int(weights[-1].shape[1]),
                        n_layers=len(weights), lane=lane)
        return sp, (w_stack, b_stack)
    if sfx[0] == "mat":
        _, edges, tables, lmap, use_min = sfx
        F, bins, C = tables.shape
        K = lmap.shape[0]
        edges_j = jnp.pad(
            jnp.asarray(edges, jnp.float32),
            ((0, _snap(F, 8) - F), (0, _snap(edges.shape[1], tile)
                                    - edges.shape[1])),
            constant_values=jnp.inf,
        )
        tables_j = jnp.pad(
            jnp.asarray(tables, jnp.float32),
            ((0, _snap(F, 8) - F), (0, _snap(bins, tile) - bins),
             (0, _snap(C, tile) - C)),
        )
        lmap_j = jnp.pad(
            jnp.asarray(lmap, jnp.float32), (0, _snap(K, tile) - K)
        )[None, :]
        sp = SuffixPlan("mat", int(C), n_features=int(F), use_min=use_min)
        return sp, (edges_j, tables_j, lmap_j)
    _, fidx, cent, lmap, use_min = sfx
    K, Fp = cent.shape
    nk = lmap.shape[0]
    cent_j = jnp.pad(
        jnp.asarray(cent, jnp.float32),
        ((0, _snap(K, 8) - K), (0, _snap(Fp, tile) - Fp)),
    )
    lmap_j = jnp.pad(
        jnp.asarray(lmap, jnp.float32), (0, _snap(max(K, nk), tile) - nk)
    )[None, :]
    sp = SuffixPlan("centroid", int(K), use_min=use_min,
                    n_centroids=int(K),
                    feature_idx=tuple(fidx) if fidx else ())
    return sp, (cent_j, lmap_j)


def lower_stateful_fused(prefix_or_groups, suffix: list[Stage],
                         mitigation=None) -> Callable | None:
    """Lower the WHOLE stateful pipeline onto one fused Pallas launch.

    ``prefix_or_groups`` is a ``[FlowKey, RegisterUpdate]`` prefix or a
    ``split_stateful_multi`` group list; ``suffix`` must be post-peephole
    (``fuse_pipeline_stages``); ``mitigation`` an optional trailing
    ``Mitigate`` stage folded into the same launch.  Returns a traceable
    ``fn(k0, r0, [k1, r1, ...,] [mit_keys, mit_regs,] x, valid) ->
    (same state arrays updated ..., verdicts)`` closing over the packed
    classifier parameters, or ``None`` when the pipeline is outside the
    fused envelope (``fused_flow_decline_reason`` says why) — the caller
    then composes the split lowerings as before."""
    if not fused_flow_eligible(prefix_or_groups, suffix, mitigation):
        return None
    import jax

    from repro.kernels import fused_flow as ff

    desc, _ = _plan_fused(prefix_or_groups, suffix, mitigation)
    groups, modes, sfx, mit_spec = desc
    interpret = jax.default_backend() != "tpu"
    tile = 8 if interpret else ff.LANE
    table_plans = tuple(
        ff.TablePlan(ru.spec.n_counters, ru.spec.n_ewma,
                     len(ru.spec.hist_sizes), float(ru.spec.ewma_alpha),
                     ru.spec.width, mode)
        for (fk, ru, ws), mode in zip(groups, modes)
    )
    sp, arrays = _pack_suffix(sfx, tile, interpret)
    nt = len(groups)
    stages_fk_ru = tuple((fk, ru) for fk, ru, _ in groups)

    def fused_fn(*args, _groups=stages_fk_ru, _tp=table_plans, _sp=sp,
                 _arrays=arrays, _mspec=mit_spec, _nt=nt,
                 _interp=interpret):
        x, valid = args[-2], args[-1]
        st = args[:-2]
        tbls = []
        for t, (fk, ru) in enumerate(_groups):
            pkt_keys = fk.apply_keys(x)
            upd, bins = ru.prepare(x)
            tbls.append((st[2 * t], st[2 * t + 1], pkt_keys, upd, bins))
        mit_arg = None
        if _mspec is not None:
            mit_arg = (st[2 * _nt], st[2 * _nt + 1], _mspec)
        return ff.fused_flow_serve(
            tbls, valid, _tp, _sp, _arrays, mitigation=mit_arg,
            interpret=_interp,
        )

    return fused_fn
