"""The paper's primary contribution: the Homunculus compiler.

alchemy     the embedded DSL (Model, DataLoader, Platforms, operators)
designspace design-space definition (real/int/ordinal/categorical params)
surrogate   random-forest surrogate (HyperMapper's §5 setup, from scratch)
bo          constrained Bayesian optimization (EI x P(feasible))
feasibility per-platform resource models + the black-box oracle
mlalgos     trainable algorithms (DNN/KMeans/SVM/tree/logreg) + metrics
codegen     backend generators (Taurus/Spatial, MAT/P4, FPGA, TPU)
dse         the generate() driver tying it all together
fusion      model fusion (§3.2.5)
chaining    multi-app scheduling + Table-3 resource accounting
autoshard   beyond-paper: the same BO core driving LM sharding DSE
"""

from repro.core.alchemy import (
    DataLoader,
    IOMap,
    IOMapper,
    Model,
    Par,
    Platform,
    Platforms,
    Seq,
)
from repro.core.bo import ConstrainedBO, Observation, expected_improvement
from repro.core.designspace import DesignSpace, Param, algorithm_space
from repro.core.dse import GenerationResult, ModelResult, generate, search_model
from repro.core.feasibility import FeasibilityReport
from repro.core.surrogate import RandomForest
