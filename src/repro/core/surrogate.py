"""Random-forest surrogate, from scratch (numpy).

The paper's §5 setup: "we setup HyperMapper to use the Random Forests
surrogate model, which is known to work well with systems workloads that
require modeling of discrete parameters and non-continuous functions".
sklearn is not available offline, so this is a compact CART-regression
forest: variance-reduction splits, bootstrap rows, feature subsampling.
``predict`` returns (mean, std) across trees — the uncertainty the EI
acquisition consumes — matching the SMAC/HyperMapper convention.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class _Node:
    feat: int = -1
    thr: float = 0.0
    left: int = -1
    right: int = -1
    value: float = 0.0
    is_leaf: bool = False


class RegressionTree:
    def __init__(self, *, max_depth: int = 12, min_leaf: int = 2,
                 feature_frac: float = 0.8, rng: np.random.Generator = None):
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.feature_frac = feature_frac
        self.rng = rng or np.random.default_rng(0)
        self.nodes: list[_Node] = []

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RegressionTree":
        self.nodes = []
        self._build(X, y, np.arange(len(X)), 0)
        return self

    def _build(self, X, y, idx, depth) -> int:
        node_id = len(self.nodes)
        self.nodes.append(_Node())
        ys = y[idx]
        if (depth >= self.max_depth or len(idx) < 2 * self.min_leaf
                or ys.std() < 1e-12):
            self.nodes[node_id] = _Node(value=float(ys.mean()), is_leaf=True)
            return node_id

        n_feat = X.shape[1]
        k = max(1, int(round(n_feat * self.feature_frac)))
        feats = self.rng.choice(n_feat, size=k, replace=False)
        best = (None, None, np.inf)
        for f in feats:
            vals = X[idx, f]
            if vals.max() - vals.min() < 1e-12:
                continue
            # candidate thresholds: random midpoints (extra-trees style —
            # cheap and adds the diversity RF needs for useful std)
            cuts = self.rng.uniform(vals.min(), vals.max(), size=8)
            for thr in cuts:
                m = vals <= thr
                nl = int(m.sum())
                if nl < self.min_leaf or len(idx) - nl < self.min_leaf:
                    continue
                yl, yr = ys[m], ys[~m]
                score = nl * yl.var() + (len(idx) - nl) * yr.var()
                if score < best[2]:
                    best = (int(f), float(thr), score)
        if best[0] is None:
            self.nodes[node_id] = _Node(value=float(ys.mean()), is_leaf=True)
            return node_id
        f, thr, _ = best
        m = X[idx, f] <= thr
        l_id = self._build(X, y, idx[m], depth + 1)
        r_id = self._build(X, y, idx[~m], depth + 1)
        self.nodes[node_id] = _Node(feat=f, thr=thr, left=l_id, right=r_id)
        return node_id

    def predict(self, X: np.ndarray) -> np.ndarray:
        out = np.empty(len(X), np.float64)
        for i, row in enumerate(X):
            nid = 0
            while not self.nodes[nid].is_leaf:
                nd = self.nodes[nid]
                nid = nd.left if row[nd.feat] <= nd.thr else nd.right
            out[i] = self.nodes[nid].value
        return out


class RandomForest:
    """Bootstrap ensemble; predict -> (mean, std across trees)."""

    def __init__(self, *, n_trees: int = 24, max_depth: int = 12,
                 min_leaf: int = 2, feature_frac: float = 0.8, seed: int = 0):
        self.n_trees = n_trees
        self.kw = dict(max_depth=max_depth, min_leaf=min_leaf,
                       feature_frac=feature_frac)
        self.seed = seed
        self.trees: list[RegressionTree] = []

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RandomForest":
        rng = np.random.default_rng(self.seed)
        self.trees = []
        n = len(X)
        for t in range(self.n_trees):
            boot = rng.integers(0, n, size=n)
            tree = RegressionTree(rng=np.random.default_rng(rng.integers(2**31)),
                                  **self.kw)
            tree.fit(X[boot], y[boot])
            self.trees.append(tree)
        return self

    def predict(self, X: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        preds = np.stack([t.predict(X) for t in self.trees])  # [T, N]
        return preds.mean(0), preds.std(0) + 1e-9

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        """For 0/1 targets: clipped mean vote = P(class 1) (feasibility)."""
        mean, _ = self.predict(X)
        return np.clip(mean, 0.0, 1.0)
