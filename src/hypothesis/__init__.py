"""Minimal vendored fallback for the `hypothesis` API this repo's tests use.

The CI container does not ship hypothesis and nothing may be pip-installed
there; this shim (shadowing site-packages via PYTHONPATH=src) implements the
small surface the tests need — ``@given`` with keyword strategies,
``settings(max_examples=, deadline=)``, and the strategies
``integers/floats/lists/sampled_from/booleans/data`` — as deterministic
pseudo-random example generation.  Example 0 of every run is the minimal
element (low bound / min_size / first choice), so boundary cases are always
exercised.  It does no shrinking and no database; it is a test runner
fallback, not a property-testing engine.

On environments where the REAL hypothesis is installed, this module finds
it further down sys.path and hands itself over to it (sys.modules
self-replacement), so PYTHONPATH=src never degrades property testing.
"""

from __future__ import annotations

import functools
import inspect
import os
import random
import sys
import zlib


def _defer_to_real_hypothesis():
    """Load a real hypothesis from beyond src/ and install it in our place."""
    import importlib.machinery
    import importlib.util

    pkg_dir = os.path.dirname(os.path.abspath(__file__))   # .../src/hypothesis
    src_dir = os.path.dirname(pkg_dir)
    paths = [
        p for p in sys.path
        if os.path.abspath(p or os.getcwd()) != src_dir
    ]
    spec = importlib.machinery.PathFinder.find_spec("hypothesis", paths)
    if spec is None or spec.origin is None:
        return None
    if os.path.abspath(spec.origin).startswith(pkg_dir):
        return None
    shim = sys.modules.get(__name__)
    real = importlib.util.module_from_spec(spec)
    sys.modules[__name__] = real    # internal imports must resolve to real
    try:
        spec.loader.exec_module(real)
    except Exception:  # broken install: restore the shim and carry on
        sys.modules[__name__] = shim
        return None
    return real


_REAL = _defer_to_real_hypothesis()

if _REAL is None:
    from hypothesis import strategies

__all__ = ["given", "settings", "strategies"]


class settings:
    def __init__(self, max_examples: int = 50, deadline=None, **kw):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._shim_settings = self
        return fn


def given(*arg_strategies, **kw_strategies):
    if arg_strategies:
        raise TypeError(
            "hypothesis shim supports keyword strategies only: "
            "@given(x=st.integers(...))"
        )

    def deco(fn):
        cfg = getattr(fn, "_shim_settings", None)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = (cfg or getattr(wrapper, "_shim_settings", None)
                 or settings()).max_examples
            # crc32, not hash(): str hashing is salted per process and
            # would make example draws irreproducible across runs
            fn_seed = zlib.crc32(fn.__qualname__.encode())
            # HYPOTHESIS_SHIM_SEED rotates the whole example corpus (the
            # CI seed-sweep matrix); unset keeps the historical draws
            env_seed = os.environ.get("HYPOTHESIS_SHIM_SEED")
            if env_seed:
                fn_seed ^= zlib.crc32(env_seed.encode())
            for i in range(n):
                rng = random.Random((fn_seed ^ 0x9E3779B9) + i)
                drawn = {
                    name: s.example(rng, i)
                    for name, s in kw_strategies.items()
                }
                fn(*args, **drawn, **kwargs)

        # hide the strategy-filled params so pytest does not treat them
        # as fixtures
        sig = inspect.signature(fn)
        params = [p for name, p in sig.parameters.items()
                  if name not in kw_strategies]
        wrapper.__signature__ = inspect.Signature(params)
        del wrapper.__wrapped__
        return wrapper

    return deco
