"""Strategies for the vendored hypothesis shim (see package docstring)."""

from __future__ import annotations

import random


class SearchStrategy:
    """Base: example(rng, i) draws one value; i==0 is the minimal case."""

    def example(self, rng: random.Random, i: int = 1):
        raise NotImplementedError


class _Integers(SearchStrategy):
    def __init__(self, min_value: int, max_value: int):
        self.lo, self.hi = min_value, max_value

    def example(self, rng, i=1):
        return self.lo if i == 0 else rng.randint(self.lo, self.hi)


class _Floats(SearchStrategy):
    def __init__(self, min_value: float, max_value: float):
        self.lo, self.hi = min_value, max_value

    def example(self, rng, i=1):
        return self.lo if i == 0 else rng.uniform(self.lo, self.hi)


class _Booleans(SearchStrategy):
    def example(self, rng, i=1):
        return False if i == 0 else rng.random() < 0.5


class _SampledFrom(SearchStrategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def example(self, rng, i=1):
        return self.elements[0] if i == 0 else rng.choice(self.elements)


class _Lists(SearchStrategy):
    def __init__(self, elements: SearchStrategy, min_size=0, max_size=10):
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 10

    def example(self, rng, i=1):
        size = (self.min_size if i == 0
                else rng.randint(self.min_size, self.max_size))
        return [self.elements.example(rng, i) for _ in range(size)]


class _DataObject:
    """Interactive draws inside a test body (st.data())."""

    def __init__(self, rng: random.Random, i: int):
        self._rng, self._i = rng, i

    def draw(self, strategy: SearchStrategy):
        return strategy.example(self._rng, self._i)


class _Data(SearchStrategy):
    def example(self, rng, i=1):
        return _DataObject(rng, i)


def integers(min_value: int = 0, max_value: int = 2**31) -> SearchStrategy:
    return _Integers(min_value, max_value)


def floats(min_value: float = 0.0, max_value: float = 1.0) -> SearchStrategy:
    return _Floats(min_value, max_value)


def booleans() -> SearchStrategy:
    return _Booleans()


def sampled_from(elements) -> SearchStrategy:
    return _SampledFrom(elements)


def lists(elements: SearchStrategy, *, min_size: int = 0,
          max_size: int | None = 10) -> SearchStrategy:
    return _Lists(elements, min_size, max_size)


def data() -> SearchStrategy:
    return _Data()
