"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle.

Each kernel is swept over shapes/dtypes with hypothesis and asserted
allclose against its ref.py.  Tolerances scale with depth/accumulation
length (fp32 reduce-order drift).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.fused_mlp import fused_mlp
from repro.kernels.fused_mlp.ref import mlp_ref
from repro.kernels.selective_scan import selective_scan, selective_scan_ref

HSET = settings(max_examples=12, deadline=None)


# ------------------------------------------------------------- fused_mlp


@given(
    f=st.integers(2, 64),
    c=st.integers(2, 16),
    b=st.integers(1, 300),
    depth=st.integers(0, 6),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    data=st.data(),
)
@HSET
def test_fused_mlp_matches_oracle(f, c, b, depth, dtype, data):
    widths = [f] + [
        data.draw(st.sampled_from([4, 8, 16, 32, 64, 128]))
        for _ in range(depth)
    ] + [c]
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    ws = [
        jnp.asarray(rng.normal(size=(widths[i], widths[i + 1])) * 0.3, dtype)
        for i in range(len(widths) - 1)
    ]
    bs = [
        jnp.asarray(rng.normal(size=(widths[i + 1],)) * 0.1, dtype)
        for i in range(len(widths) - 1)
    ]
    x = jnp.asarray(rng.normal(size=(b, f)), dtype)
    out = fused_mlp(x, ws, bs)
    ref = mlp_ref(x, ws, bs)
    assert out.shape == (b, c)
    tol = 1e-2 if dtype == "bfloat16" else 3e-4 * max(1, len(ws))
    scale = float(jnp.max(jnp.abs(ref))) + 1.0
    np.testing.assert_allclose(
        np.asarray(out, np.float32) / scale,
        np.asarray(ref, np.float32) / scale,
        atol=tol,
    )


def test_fused_mlp_wide_fallback():
    """Widths beyond the 128-lane envelope fall back to the XLA reference."""
    rng = np.random.default_rng(0)
    ws = [jnp.asarray(rng.normal(size=(200, 64)), jnp.float32) * 0.1,
          jnp.asarray(rng.normal(size=(64, 3)), jnp.float32) * 0.1]
    bs = [jnp.zeros((64,)), jnp.zeros((3,))]
    x = jnp.asarray(rng.normal(size=(17, 200)), jnp.float32)
    out = fused_mlp(x, ws, bs)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(mlp_ref(x, ws, bs)), rtol=1e-5, atol=1e-5
    )


# -------------------------------------------------------- flash_attention


@given(
    b=st.integers(1, 2),
    sq=st.integers(4, 80),
    kext=st.integers(0, 64),
    hk=st.sampled_from([(1, 1), (2, 1), (4, 2), (4, 4), (8, 2)]),
    d=st.sampled_from([16, 32, 64]),
    causal=st.booleans(),
    window=st.sampled_from([0, 0, 16, 40]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    seed=st.integers(0, 2**31),
)
@HSET
def test_flash_attention_matches_oracle(
    b, sq, kext, hk, d, causal, window, dtype, seed
):
    h, k = hk
    skv = sq + kext
    q_offset = kext  # realistic: queries start after the cached prefix
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), dtype)
    kk = jnp.asarray(rng.normal(size=(b, skv, k, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, skv, k, d)), dtype)
    kw = dict(causal=causal, window=window, q_offset=q_offset)
    out = flash_attention(q, kk, v, block_q=16, block_k=16, **kw)
    ref = attention_ref(q, kk, v, **kw)
    tol = 3e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


def test_flash_attention_matches_chunked_xla_twin():
    """The XLA chunked path (used by the dry-run) == the kernel semantics."""
    from repro.models.attention import chunked_attention

    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(2, 48, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 48, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 48, 2, 32)), jnp.float32)
    ref = attention_ref(q, k, v, causal=True)
    xla = chunked_attention(q, k, v, causal=True, kv_chunk=16)
    ker = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(xla), np.asarray(ref), atol=2e-5)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), atol=2e-5)


# --------------------------------------------------------- selective_scan


@given(
    b=st.integers(1, 3),
    nchunks=st.integers(1, 4),
    chunk=st.sampled_from([8, 16, 32]),
    di=st.sampled_from([8, 16, 32, 64]),
    n=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31),
)
@HSET
def test_selective_scan_matches_oracle(b, nchunks, chunk, di, n, seed):
    s = nchunks * chunk
    rng = np.random.default_rng(seed)
    dt = jnp.asarray(rng.uniform(0.01, 2.0, size=(b, s, di, 1)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.1, 3.0, size=(1, 1, di, n)), jnp.float32)
    dA = jnp.exp(dt * a)
    dBx = jnp.asarray(rng.normal(size=(b, s, di, n)), jnp.float32) * 0.2
    c = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(b, di, n)), jnp.float32) * 0.1
    y, h = selective_scan(dA, dBx, c, h0, chunk=chunk)
    yr, hr = selective_scan_ref(dA, dBx, c, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-4)


def test_selective_scan_xla_twin_matches_oracle():
    """models.ssm chunked associative scan == sequential oracle."""
    from repro.models.ssm import _ssm_scan_chunked

    rng = np.random.default_rng(2)
    b, s, di, n = 2, 64, 32, 16
    dt = jnp.asarray(rng.uniform(0.01, 1.5, size=(b, s, di, 1)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.1, 2.0, size=(1, 1, di, n)), jnp.float32)
    dA = jnp.exp(dt * a)
    dBx = jnp.asarray(rng.normal(size=(b, s, di, n)), jnp.float32) * 0.2
    c = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    h0 = jnp.zeros((b, di, n), jnp.float32)
    y, h = _ssm_scan_chunked(dA, dBx, c, h0, chunk=16)
    yr, hr = selective_scan_ref(dA, dBx, c, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-3)


def test_vmem_budget_accounting():
    from repro.kernels.fused_mlp.kernel import LANE, vmem_bytes

    v1 = vmem_bytes(1)
    v10 = vmem_bytes(10)
    assert v10 - v1 == 9 * (LANE * LANE * 4 + LANE * 4)


# --------------------------------------------------------- binarized_gemm


@given(
    b=st.integers(1, 64),
    k=st.integers(2, 200),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**31),
)
@HSET
def test_binarized_gemm_bit_exact(b, k, n, seed):
    """±1 int8-MXU GEMM == sign(x) @ sign(w) exactly (N2Net primitive)."""
    from repro.kernels.binarized_gemm import binarized_gemm, binarized_gemm_ref

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    out = binarized_gemm(x, w, block=16)
    ref = binarized_gemm_ref(x, w)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref, np.int32))
    # parity structure: result has the same parity as k
    assert np.all((np.asarray(out) - k) % 2 == 0)
