"""Flow register file: spec validation, collision/eviction policy, EWMA
semantics, kernel/reference parity, stage lowering, feasibility (tier-1).

The slow property suite (test_stageir_conformance.py) sweeps random
configurations; these are the fast deterministic checks of the flow-state
contract (docs/pipeline_ir.md#flow-state-contract)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import feasibility as feas, pallas_backend, stageir
from repro.flowstate import (
    FlowState,
    FlowStateSpec,
    StatefulPipeline,
    init_state,
    update_flows,
)
from repro.kernels.flow_update import flow_update, flow_update_ref, hash_slot

needs_pallas = pytest.mark.skipif(
    not pallas_backend.pallas_available(),
    reason="Pallas toolchain unavailable in this environment",
)


def _spec(**kw):
    base = dict(n_slots=8, n_counters=1, n_ewma=1, hist_sizes=(4,),
                ewma_alpha=0.5)
    base.update(kw)
    return FlowStateSpec(**base)


def _colliding_key(key: int, n_slots: int) -> int:
    """A different key that hashes to the same slot."""
    slot = int(hash_slot(jnp.array([key], jnp.int32), n_slots)[0])
    for cand in range(1 << 12):
        if cand != key and int(
            hash_slot(jnp.array([cand], jnp.int32), n_slots)[0]
        ) == slot:
            return cand
    raise AssertionError("no colliding key found")


# ------------------------------------------------------------------- spec


def test_spec_validation():
    with pytest.raises(ValueError):
        FlowStateSpec(n_slots=12)            # not a power of two
    with pytest.raises(ValueError):
        FlowStateSpec(n_slots=8, n_counters=0)
    with pytest.raises(ValueError):
        FlowStateSpec(n_slots=8, hist_sizes=(0,))
    s = _spec()
    assert s.width == 1 + 1 + 4
    assert s.hist_offsets == (2,)
    assert s.sram_bytes == 8 * (6 + 1) * 4


def test_register_update_validates_against_spec():
    s = _spec()
    with pytest.raises(ValueError):          # counter count mismatch
        stageir.RegisterUpdate(s, counter_cols=(1,), ewma_cols=(1,),
                               hist_cols=(1,),
                               hist_edges=(np.arange(3.0),))
    with pytest.raises(ValueError):          # hist bins mismatch
        stageir.RegisterUpdate(s, ewma_cols=(1,), hist_cols=(1,),
                               hist_edges=(np.arange(7.0),))


# ---------------------------------------------------- update semantics


def test_counter_ewma_hist_accumulation():
    s = _spec()
    st = init_state(s)
    pk = np.array([7, 7, 7], np.int32)
    upd = np.array([[1, 10.0], [1, 20.0], [1, 40.0]], np.float32)
    bins = np.array([[2], [2], [4]], np.int32)
    st2, feats = update_flows(st, pk, upd, bins)
    slot = int(hash_slot(jnp.array([7], jnp.int32), s.n_slots)[0])
    row = np.asarray(st2.regs)[slot]
    assert row[0] == 3                       # packet count
    # ewma: first packet SETS (10), then blends at alpha=0.5: 15, 27.5
    assert row[1] == 27.5
    assert list(row[2:]) == [2.0, 0.0, 1.0, 0.0]
    # per-packet features are the post-update rows, in arrival order
    assert np.asarray(feats)[0, 0] == 1 and np.asarray(feats)[2, 0] == 3
    assert np.asarray(feats)[1, 1] == 15.0


def test_collision_evicts_and_resets():
    s = _spec()
    st = init_state(s)
    st2, _ = update_flows(st, np.array([7, 7], np.int32),
                          np.array([[1, 5.0]] * 2, np.float32),
                          np.array([[2], [2]], np.int32))
    other = _colliding_key(7, s.n_slots)
    st3, feats = update_flows(st2, np.array([other], np.int32),
                              np.array([[1, 99.0]], np.float32),
                              np.array([[3]], np.int32))
    slot = int(hash_slot(jnp.array([7], jnp.int32), s.n_slots)[0])
    row = np.asarray(st3.regs)[slot]
    # last-writer-wins: the resident flow's state was wiped, not blended
    assert row[0] == 1 and row[1] == 99.0 and row[2] == 0.0
    assert int(np.asarray(st3.keys)[slot]) == other
    assert np.asarray(feats)[0, 0] == 1


def test_invalid_rows_never_touch_state():
    s = _spec()
    st = init_state(s)
    pk = np.array([1, 2, 3], np.int32)
    upd = np.ones((3, 2), np.float32)
    bins = np.full((3, 1), 2, np.int32)
    st2, _ = update_flows(st, pk, upd, bins,
                          valid=np.array([1, 0, 1], np.int32))
    st3, _ = update_flows(st, pk[[0, 2]], upd[[0, 2]], bins[[0, 2]])
    np.testing.assert_array_equal(np.asarray(st2.keys),
                                  np.asarray(st3.keys))
    np.testing.assert_array_equal(np.asarray(st2.regs),
                                  np.asarray(st3.regs))


@needs_pallas
def test_kernel_matches_reference_bit_for_bit(rng):
    s = _spec(n_slots=4, n_counters=2, n_ewma=1, hist_sizes=(3, 2),
              ewma_alpha=0.125)
    B = 80
    keys = jnp.full((s.n_slots,), -1, jnp.int32)
    regs = jnp.zeros((s.n_slots, s.width), jnp.float32)
    pk = jnp.asarray(rng.integers(0, 6, B), jnp.int32)   # heavy collisions
    upd = jnp.asarray(rng.normal(size=(B, 3)), jnp.float32)
    bins = jnp.stack([
        jnp.asarray(3 + rng.integers(0, 3, B), jnp.int32),
        jnp.asarray(6 + rng.integers(0, 2, B), jnp.int32),
    ], 1)
    valid = jnp.asarray((rng.random(B) < 0.9).astype(np.int32))
    kw = dict(n_counters=2, n_ewma=1, alpha=0.125)
    ref = flow_update_ref(keys, regs, pk, upd, bins, valid, **kw)
    ker = flow_update(keys, regs, pk, upd, bins, valid, **kw)
    for a, b in zip(ref, ker):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_update_flows_pallas_backend_parity(rng):
    if not pallas_backend.pallas_available():
        pytest.skip("Pallas unavailable")
    s = _spec()
    st = init_state(s)
    pk = rng.integers(0, 5, 30).astype(np.int32)
    upd = rng.normal(size=(30, 2)).astype(np.float32)
    bins = (2 + rng.integers(0, 4, (30, 1))).astype(np.int32)
    a, fa = update_flows(st, pk, upd, bins, backend="interpret")
    b, fb = update_flows(st, pk, upd, bins, backend="pallas")
    np.testing.assert_array_equal(np.asarray(a.regs), np.asarray(b.regs))
    np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


# ------------------------------------------------ stage lowering / specs


def test_flowstate_specs_match_stage_meta():
    s = _spec(n_slots=16, n_counters=2, n_ewma=1, hist_sizes=(5,))
    ru = stageir.RegisterUpdate(
        s, counter_cols=(1,), ewma_cols=(2,), hist_cols=(1,),
        hist_edges=(np.linspace(0, 1, 6)[1:-1],),
    )
    ws = stageir.WindowStats(s, mode="all")
    specs = stageir.flowstate_specs(s)
    by_kind = {sp.kind: sp for sp in specs}
    assert by_kind["register_update"].params == ru.meta()["params"]
    assert by_kind["register_update"].extra == (16, s.width)
    assert by_kind["window_stats"].n_out == ws.n_out
    hist_only = stageir.flowstate_specs(s, mode="hist")
    assert hist_only[-1].n_out == stageir.WindowStats(s, "hist").n_out == 5


def test_window_stats_normalizes_by_count():
    s = _spec()
    ws = stageir.WindowStats(s, mode="all")
    feats = jnp.asarray([[4.0, 2.0, 2.0, 0.0, 2.0, 0.0],
                         [0.0, 1.0, 3.0, 0.0, 0.0, 0.0]], jnp.float32)
    out = np.asarray(ws.apply(feats))
    assert out.shape == (2, s.width)
    np.testing.assert_allclose(out[0], [4.0, 2.0, 0.5, 0.0, 0.5, 0.0])
    # zero-count rows (empty/padded) divide by 1, not 0
    np.testing.assert_allclose(out[1], [0.0, 1.0, 3.0, 0.0, 0.0, 0.0])
    hist = np.asarray(stageir.WindowStats(s, "hist").apply(feats))
    assert hist.shape == (2, 4)


def test_compile_stages_rejects_stateful():
    s = _spec()
    stages = [stageir.FlowKey((0,), s.n_slots),
              stageir.RegisterUpdate(s, ewma_cols=(1,), hist_cols=(1,),
                                     hist_edges=(np.arange(3.0),))]
    with pytest.raises(ValueError, match="stateful"):
        stageir.compile_stages(stages)


def test_split_stateful_validates_prefix():
    s = _spec()
    fk = stageir.FlowKey((0,), s.n_slots)
    ru = stageir.RegisterUpdate(s, ewma_cols=(1,), hist_cols=(1,),
                                hist_edges=(np.arange(3.0),))
    with pytest.raises(ValueError):
        stageir.split_stateful([ru, fk])     # wrong order
    with pytest.raises(ValueError):
        stageir.split_stateful([fk, ru, fk])  # stateful in suffix
    prefix, suffix = stageir.split_stateful([fk, ru,
                                             stageir.Reduce("argmax")])
    assert [p.kind for p in prefix] == ["flow_key", "register_update"]
    assert [p.kind for p in suffix] == ["reduce"]


# ------------------------------------------------------------ feasibility


def test_flowstate_report_platforms():
    small = _spec(n_slots=64)
    for plat in ("taurus", "tofino", "fpga", "tpu"):
        rep = feas.flowstate_report(small, plat)
        assert rep.feasible, (plat, rep.reasons)
        assert rep.throughput_pps > 0
    big = FlowStateSpec(n_slots=1 << 15, n_counters=1, hist_sizes=(500,))
    assert not feas.flowstate_report(big, "taurus").feasible
    with pytest.raises(KeyError):
        feas.flowstate_report(small, "cuda")


def test_flowstate_report_merges_as_coresident():
    rep = feas.flowstate_report(_spec(n_slots=64), "taurus")
    model = feas.FeasibilityReport(True, [], {"cu": 24, "mu": 48}, 10.0,
                                   5e8)
    total = model.merge(rep)
    assert total.resources["mu"] == 48 + rep.resources["mu"]
    assert total.throughput_pps == 5e8       # min rule
    assert total.latency_ns == 10.0 + rep.latency_ns


# ------------------------------------------------------ stateful pipeline


def _mini_pipeline(spec, seed=0):
    rng = np.random.default_rng(seed)
    fk = stageir.FlowKey((0,), spec.n_slots)
    ru = stageir.RegisterUpdate(
        spec, ewma_cols=(1,), hist_cols=(1,),
        hist_edges=(np.linspace(0, 1, spec.hist_sizes[0] + 1)[1:-1],),
    )
    ws = stageir.WindowStats(spec, mode="all")
    w1 = rng.normal(size=(ws.n_out, 6)).astype(np.float32)
    w2 = rng.normal(size=(6, 2)).astype(np.float32)
    mlp = stageir.FusedMLP([w1, w2], [np.zeros(6, np.float32),
                                      np.zeros(2, np.float32)])
    return [fk, ru, ws, mlp, stageir.Reduce("argmax")]


def _packets(rng, n, n_flows=5):
    X = np.zeros((n, 2), np.float32)
    X[:, 0] = rng.integers(0, n_flows, n)
    X[:, 1] = rng.random(n)
    return X


def test_stateful_pipeline_interpret_and_reporting(rng):
    spec = FlowStateSpec(n_slots=8, n_counters=1, n_ewma=1, hist_sizes=(3,))
    pipe = StatefulPipeline(_mini_pipeline(spec))
    assert pipe.backend == "interpret"
    assert pipe.requested_backend == "interpret"
    st = pipe.init_state()
    X = _packets(rng, 20)
    st2, v = pipe(st, X)
    assert v.shape == (20,)
    assert st2.occupied > 0
    assert np.asarray(st.keys).max() == -1   # input state untouched


@needs_pallas
def test_stateful_pipeline_pallas_parity_and_with_backend(rng):
    spec = FlowStateSpec(n_slots=8, n_counters=1, n_ewma=1, hist_sizes=(3,))
    stages = _mini_pipeline(spec)
    pi = StatefulPipeline(stages)
    pp = StatefulPipeline(stages, backend="pallas")
    assert pp.backend == "pallas-fused-flow"
    assert pp.fused
    assert pp.flow_backend == pp.classifier_backend == "pallas"
    X = _packets(rng, 40)
    si, vi = pi(pi.init_state(), X)
    sp, vp = pp(pp.init_state(), X)
    np.testing.assert_array_equal(np.asarray(si.keys), np.asarray(sp.keys))
    np.testing.assert_array_equal(np.asarray(si.regs), np.asarray(sp.regs))
    np.testing.assert_array_equal(vi, vp)
    assert pp.with_backend("interpret").backend == "interpret"


@needs_pallas
def test_stateful_pipeline_mixed_when_suffix_ineligible(rng):
    # an over-wide MLP (hidden > the 128 kernel lane) is outside every
    # kernel envelope: the flow prefix fuses, the suffix honestly reports
    # the interpreter, and the fused decline reason is surfaced
    spec = FlowStateSpec(n_slots=8, n_counters=1, n_ewma=1, hist_sizes=(3,))
    stages = _mini_pipeline(spec)[:3]
    n_in = stages[2].n_out
    r = np.random.default_rng(0)
    stages = stages + [
        stageir.FusedMLP(
            [np.asarray(r.normal(size=(n_in, 200)), np.float32),
             np.asarray(r.normal(size=(200, 2)), np.float32)],
            [np.zeros(200, np.float32), np.zeros(2, np.float32)]),
        stageir.Reduce("argmax"),
    ]
    pp = StatefulPipeline(stages, backend="pallas")
    assert pp.flow_backend == "pallas"
    assert pp.classifier_backend == "interpret"
    assert pp.backend == "mixed"
    assert pp.fallback_reason == "classifier width exceeds the kernel lane"
    pi = StatefulPipeline(stages)
    X = _packets(rng, 16)
    _, vi = pi(pi.init_state(), X)
    _, vp = pp(pp.init_state(), X)
    np.testing.assert_array_equal(vi, vp)


def test_stateful_pipeline_rejects_unknown_backend():
    spec = FlowStateSpec(n_slots=8, n_counters=1, n_ewma=1, hist_sizes=(3,))
    with pytest.raises(KeyError):
        StatefulPipeline(_mini_pipeline(spec), backend="cuda")
