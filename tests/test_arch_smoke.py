"""Per-architecture smoke tests (assignment deliverable f).

Every assigned arch instantiates its REDUCED config and runs one train step
and a prefill+decode round-trip on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised only via the dry-run (no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (
    SHAPES, applicable_shapes, get_config, get_smoke_config, list_archs,
)
from repro.models import registry
from repro.serve.steps import init_cache, make_decode_step, make_prefill_step
from repro.train.step import (
    TrainSettings, cast_for_compute, init_train_state, make_train_step,
)

ARCHS = list_archs()


def _batch(cfg, B, S):
    b = {
        "tokens": jnp.zeros((B, S), jnp.int32),
        "targets": jnp.zeros((B, S), jnp.int32),
    }
    if cfg.family == "encdec":
        b["frames"] = jnp.zeros((B, S, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        b["image_embeds"] = jnp.zeros(
            (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16
        )
    return b


def test_all_archs_assigned():
    assert len(ARCHS) == 10
    expected = {
        "jamba-1.5-large-398b", "moonshot-v1-16b-a3b", "mixtral-8x7b",
        "seamless-m4t-large-v2", "qwen3-1.7b", "qwen1.5-32b",
        "starcoder2-15b", "qwen2-7b", "llama-3.2-vision-11b", "xlstm-1.3b",
    }
    assert set(ARCHS) == expected


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, TrainSettings(remat=True)))
    B, S = 2, 32
    state, m = step(state, _batch(cfg, B, S))
    loss = float(m["loss"])
    assert np.isfinite(loss) and loss > 0
    assert np.isfinite(float(m["grad_norm"]))
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    params = cast_for_compute(state["params"])
    B, S = 2, 32
    cache = init_cache(cfg, B, S)
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))
    batch = _batch(cfg, B, S)
    batch.pop("targets")
    tok, cache = prefill(params, cache, batch)
    assert tok.shape == (B,) and tok.dtype == jnp.int32
    for i in range(3):
        tok, cache = decode(
            params, cache, tok[:, None], jnp.array(S + i, jnp.int32)
        )
        assert tok.shape == (B,)
        assert np.all(np.asarray(tok) >= 0)
        assert np.all(np.asarray(tok) < cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_exact_values(arch):
    """The FULL config matches the assignment table exactly."""
    table = {
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    }
    cfg = get_config(arch)
    L, d, H, K, ff, V = table[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == H
    assert cfg.num_kv_heads == K
    assert cfg.d_ff == ff
    assert cfg.vocab_size == V


def test_moe_configs():
    jamba = get_config("jamba-1.5-large-398b")
    assert (jamba.num_experts, jamba.num_experts_per_tok) == (16, 2)
    moonshot = get_config("moonshot-v1-16b-a3b")
    assert (moonshot.num_experts, moonshot.num_experts_per_tok) == (64, 6)
    mixtral = get_config("mixtral-8x7b")
    assert (mixtral.num_experts, mixtral.num_experts_per_tok) == (8, 2)


def test_shape_applicability_rules():
    """long_500k only for sub-quadratic archs (SSM/hybrid/SWA)."""
    runs_long = {
        a for a in ARCHS if "long_500k" in applicable_shapes(get_config(a))
    }
    assert runs_long == {"jamba-1.5-large-398b", "xlstm-1.3b", "mixtral-8x7b"}
    # every arch decodes (no encoder-only arch assigned)
    for a in ARCHS:
        assert "decode_32k" in applicable_shapes(get_config(a))


def test_param_counts_in_published_ballpark():
    """Total params within a sane band of the published sizes."""
    expect = {
        "jamba-1.5-large-398b": (300e9, 500e9),
        "mixtral-8x7b": (40e9, 56e9),
        "qwen3-1.7b": (1.2e9, 2.6e9),
        "qwen2-7b": (6e9, 9e9),
        "starcoder2-15b": (12e9, 18e9),
        "qwen1.5-32b": (28e9, 38e9),
        "xlstm-1.3b": (0.9e9, 1.9e9),
        # NB: the assignment pins 48L x 64e x d_ff=1408 which gives ~28B
        # total (the published Moonlight-16B uses 27 layers); the assigned
        # config is authoritative — see DESIGN.md §4.
        "moonshot-v1-16b-a3b": (20e9, 32e9),
    }
    for arch, (lo, hi) in expect.items():
        n = registry.param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n / 1e9:.2f}B not in [{lo / 1e9}, {hi / 1e9}]"


def test_active_params_less_than_total_for_moe():
    for arch in ("jamba-1.5-large-398b", "mixtral-8x7b", "moonshot-v1-16b-a3b"):
        cfg = get_config(arch)
        assert registry.active_param_count(cfg) < registry.param_count(cfg)


def test_decode_cache_seq_sharding_flag():
    cfg = get_config("qwen2-7b")
    defs = registry.cache_defs(cfg, 4, 128)
    k = defs["slot0"]["kv"]["k"]
    assert k.axes[2] == "sp"  # cache seq dim sharded over model axis
