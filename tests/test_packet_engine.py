"""PacketServeEngine: arrival-order preservation under arbitrary
submit/flush interleavings, latency percentiles, stateful serving (tier-1).

The ordering property is the engine's core contract: whatever mix of
ragged ``submit`` chunks, intermediate ``flush`` calls and
``serve_stream`` pulls, verdicts come back in arrival order and — on the
stateful path — the register file sees packets in exactly that order."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import pallas_backend, stageir
from repro.data import traffic
from repro.flowstate import FlowStateSpec, StatefulPipeline
from repro.serve.packet_engine import PacketServeEngine, ServeStats

HSET = settings(max_examples=12, deadline=None)


def _tag_pipeline(x):
    """Verdict = the packet's own tag column: order-revealing."""
    return x[:, 0].astype(np.int32)


def _tagged(n, start=0):
    out = np.zeros((n, 2), np.float32)
    out[:, 0] = np.arange(start, start + n)
    return out


# ------------------------------------------------------ ordering property


@given(data=st.data())
@HSET
def test_submit_flush_interleavings_preserve_arrival_order(data):
    eng = PacketServeEngine(_tag_pipeline, feature_dim=2,
                            max_batch=data.draw(st.integers(1, 13)),
                            depth=data.draw(st.integers(1, 4)))
    total, got = 0, []
    for _ in range(data.draw(st.integers(1, 12))):
        if data.draw(st.booleans()) or total == 0:
            n = data.draw(st.integers(1, 37))
            eng.submit(_tagged(n, start=total))
            total += n
        else:
            got.append(eng.flush())
    got.append(eng.flush())
    verdicts = np.concatenate([g for g in got if len(g)])
    np.testing.assert_array_equal(verdicts, np.arange(total))
    assert eng.pending == 0
    assert eng.in_flight == 0


@given(data=st.data())
@HSET
def test_async_depth_preserves_order_on_jitted_pipeline(data):
    """depth>1 keeps device-array results in flight (lazy fetch); order
    must survive arbitrary submit/flush interleavings on a REAL jitted
    pipeline, where outputs are async device handles, not numpy."""
    import jax

    jitted = jax.jit(lambda x: x[:, 0].astype("int32"))
    eng = PacketServeEngine(jitted, feature_dim=2,
                            max_batch=data.draw(st.integers(2, 17)),
                            depth=data.draw(st.integers(2, 4)))
    total, got = 0, []
    for _ in range(data.draw(st.integers(1, 8))):
        n = data.draw(st.integers(1, 53))
        eng.submit(_tagged(n, start=total))
        total += n
        if data.draw(st.booleans()):
            got.append(eng.flush())
    got.append(eng.flush())
    verdicts = np.concatenate([g for g in got if len(g)])
    np.testing.assert_array_equal(verdicts, np.arange(total))


@given(data=st.data())
@HSET
def test_serve_stream_ragged_chunks_preserve_order(data):
    sizes = data.draw(st.lists(st.integers(1, 41), min_size=1, max_size=8))
    eng = PacketServeEngine(_tag_pipeline, feature_dim=2,
                            max_batch=data.draw(st.integers(2, 17)))
    chunks, total = [], 0
    for n in sizes:
        chunks.append(_tagged(n, start=total))
        total += n
    got = np.concatenate(list(eng.serve_stream(iter(chunks))))
    np.testing.assert_array_equal(got, np.arange(total))


# ------------------------------------------------------------ percentiles


def test_latency_percentiles_in_stats():
    stats = ServeStats()
    assert stats.lat_p50_ms == 0.0 and stats.lat_p95_ms == 0.0
    assert stats.lat_p99_ms == 0.0
    eng = PacketServeEngine(_tag_pipeline, feature_dim=2, max_batch=8)
    assert eng.stats()["lat_p50_ms"] == 0.0    # warm-up batch not counted
    for _ in range(5):
        eng.submit(_tagged(11))
        eng.flush()
    s = eng.stats()
    assert s["batches"] == 10
    assert len(eng.stats_.batch_lat_s) == 10
    assert 0.0 < s["lat_p50_ms"] <= s["lat_p95_ms"] <= s["lat_p99_ms"]
    assert s["lat_p95_ms"] <= s["wall_s"] * 1e3 + 1e-9
    assert s["dispatch_s"] <= s["wall_s"] + 1e-9
    assert s["depth"] == eng.depth and s["shards"] == 1


def test_view_returning_pipeline_verdicts_survive_buffer_reuse():
    """A plain-numpy pipeline returning a VIEW of its input must not have
    its already-returned verdicts corrupted when the staging ring is
    reused by later batches."""
    eng = PacketServeEngine(lambda x: x[:, 0], feature_dim=2, max_batch=8,
                            depth=2)
    eng.submit(_tagged(40))              # 5 batches > ring size (depth+1)
    first = eng.flush()
    np.testing.assert_array_equal(first, np.arange(40))
    eng.submit(np.full((16, 2), 777.0, np.float32))
    eng.flush()
    # the earlier verdicts must be untouched by the ring reuse
    np.testing.assert_array_equal(first, np.arange(40))


def test_requested_pallas_unavailable_reports_interpreter(monkeypatch):
    """backend="pallas" with no Pallas toolchain must SERVE (interpreter)
    and REPORT the interpreter — never the engine that was requested."""
    from repro.core import codegen, feasibility as feas, mlalgos
    from repro.data import netdata

    monkeypatch.setattr(pallas_backend, "pallas_available", lambda: False)
    d = netdata.make_ad_dataset(features=7, n_train=256, n_test=128)
    rep = feas.FeasibilityReport(True, [], {"cu": 1}, 1.0, 1e9)
    pipe = codegen.taurus_codegen(
        "ad", mlalgos.train_dnn(d, hidden=[8], epochs=1, seed=0), rep
    )
    eng = PacketServeEngine(pipe, feature_dim=7, max_batch=32,
                            backend="pallas")
    eng.submit(d.test_x[:50])
    ref = PacketServeEngine(pipe, feature_dim=7, max_batch=32)
    ref.submit(d.test_x[:50])
    np.testing.assert_array_equal(eng.flush(), ref.flush())
    assert eng.stats()["backend"] == "interpret"
    assert eng.stats()["backend_batches"] == {"interpret": 2}


# ------------------------------------------------------- stateful serving


def _flow_pipeline(backend="interpret"):
    spec = FlowStateSpec(n_slots=16, n_counters=1, n_ewma=1,
                         hist_sizes=(3,), ewma_alpha=0.5)
    fk = stageir.FlowKey((0,), spec.n_slots)
    ru = stageir.RegisterUpdate(
        spec, ewma_cols=(1,), hist_cols=(1,),
        hist_edges=(np.linspace(0, 1, 4)[1:-1],),
    )
    ws = stageir.WindowStats(spec, mode="all")
    return StatefulPipeline([fk, ru, ws], backend=backend)


def _flow_packets(rng, n):
    X = np.zeros((n, 2), np.float32)
    X[:, 0] = rng.integers(0, 6, n)
    X[:, 1] = rng.random(n)
    return X


@given(data=st.data())
@HSET
def test_stateful_ragged_interleavings_match_single_pass(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 1000)))
    X = _flow_packets(rng, data.draw(st.integers(1, 120)))
    # reference: one unpadded pass through the pipeline
    ref_pipe = _flow_pipeline()
    ref_state, ref_feats = ref_pipe(ref_pipe.init_state(), X)

    eng = PacketServeEngine(_flow_pipeline(), feature_dim=2,
                            max_batch=data.draw(st.integers(2, 19)),
                            depth=data.draw(st.integers(1, 4)))
    got, pos = [], 0
    while pos < len(X):
        n = min(data.draw(st.integers(1, 31)), len(X) - pos)
        eng.submit(X[pos:pos + n])
        pos += n
        if data.draw(st.booleans()):
            got.append(eng.flush())
    got.append(eng.flush())
    feats = np.concatenate([g for g in got if len(g)])
    # padding rows never leaked into the register file, order preserved
    np.testing.assert_array_equal(np.asarray(eng.state.keys),
                                  np.asarray(ref_state.keys))
    np.testing.assert_array_equal(np.asarray(eng.state.regs),
                                  np.asarray(ref_state.regs))
    np.testing.assert_array_equal(feats, np.asarray(ref_feats))


def test_engine_initializes_and_threads_state(rng):
    eng = PacketServeEngine(_flow_pipeline(), feature_dim=2, max_batch=8)
    assert eng.state is not None and eng.state.occupied == 0
    eng.submit(_flow_packets(rng, 20))
    eng.flush()
    assert eng.state.occupied > 0
    # resuming from an existing table continues, not restarts
    resumed = PacketServeEngine(_flow_pipeline(), feature_dim=2,
                                max_batch=8, state=eng.state)
    assert resumed.state.occupied == eng.state.occupied


def _classifier_pipeline():
    """Flow prefix + a fixed MLP classifier (fully kernel-eligible)."""
    base = _flow_pipeline()
    rng = np.random.default_rng(7)
    n_in = base.stages[2].n_out
    w1 = rng.normal(size=(n_in, 6)).astype(np.float32)
    w2 = rng.normal(size=(6, 2)).astype(np.float32)
    mlp = stageir.FusedMLP([w1, w2], [np.zeros(6, np.float32),
                                      np.zeros(2, np.float32)])
    return StatefulPipeline(base.stages + [mlp, stageir.Reduce("argmax")])


@pytest.mark.skipif(not pallas_backend.pallas_available(),
                    reason="Pallas toolchain unavailable")
def test_engine_stateful_backend_rebind_and_parity(rng):
    X = _flow_packets(rng, 50)
    engs = {
        b: PacketServeEngine(_classifier_pipeline(), feature_dim=2,
                             max_batch=16, backend=b)
        for b in ("interpret", "pallas")
    }
    outs = {}
    # the fully-eligible classifier pipeline serves as the single-launch
    # fused form under "pallas"; the interpreter stays itself
    expect = {"interpret": "interpret", "pallas": "pallas-fused-flow"}
    for b, e in engs.items():
        e.submit(X)
        outs[b] = e.flush()
        assert e.stats()["backend"] == expect[b]
    np.testing.assert_array_equal(outs["interpret"], outs["pallas"])
    np.testing.assert_array_equal(np.asarray(engs["interpret"].state.regs),
                                  np.asarray(engs["pallas"].state.regs))


def test_traffic_streams_are_replayable_and_seeded():
    a = traffic.make_stream("port_scan", n_packets=2000, seed=3)
    b = traffic.make_stream("port_scan", n_packets=2000, seed=3)
    np.testing.assert_array_equal(a.packets, b.packets)
    np.testing.assert_array_equal(a.labels, b.labels)
    c1 = list(a.chunks(300))
    c2 = list(a.chunks(300))
    assert len(c1) == len(c2) and all(
        np.array_equal(x, y) for x, y in zip(c1, c2)
    )
    other = traffic.make_stream("port_scan", n_packets=2000, seed=4)
    assert not np.array_equal(a.packets, other.packets)
    with pytest.raises(KeyError):
        traffic.make_stream("nope")


@pytest.mark.parametrize("scenario", traffic.SCENARIOS)
def test_traffic_scenarios_well_formed(scenario):
    s = traffic.make_stream(scenario, n_packets=3000, seed=1)
    assert s.packets.shape[1] == len(traffic.COLUMNS)
    assert s.packets.dtype == np.float32
    # flow ids exact in f32 and consistent with the int column
    np.testing.assert_array_equal(
        s.packets[:, traffic.COL_FLOW].astype(np.int64), s.flow_ids
    )
    assert (s.packets[:, traffic.COL_IPT] >= 0).all()
    has_attack = scenario != "benign"
    assert bool(s.labels.any()) == has_attack
    # per-packet labels match the flow's ground truth
    for fid, lab in list(s.flow_labels.items())[:20]:
        m = s.flow_ids == fid
        if m.any():
            assert (s.labels[m] == lab).all()


def test_reaction_report_counts_packets_to_detection():
    packets = np.zeros((6, 4), np.float32)
    packets[:, 0] = [1, 2, 1, 2, 1, 2]
    stream = traffic.PacketStream(
        "ddos_burst", packets, np.array([0, 1, 0, 1, 0, 1], np.int32),
        packets[:, 0].astype(np.int32), {1: 0, 2: 1},
    )
    verdicts = np.array([0, 0, 1, 0, 0, 1], np.int32)
    rep = traffic.reaction_report(stream, verdicts)
    assert rep["attack_flows"] == 1 and rep["detected_flows"] == 1
    assert rep["reaction_pkts_median"] == 3      # flow 2's 3rd packet
    assert rep["benign_fp_flow_rate"] == 1.0     # flow 1 was flagged once


def test_reaction_report_all_benign_stream_is_json_clean():
    """No attack flows and no detections -> 0.0 sentinels everywhere, not
    NaN: the report must stay json-serializable and aggregation-safe."""
    import json

    stream = traffic.make_stream("benign", n_packets=2000, seed=0)
    rep = traffic.reaction_report(
        stream, np.zeros(stream.n_packets, np.int32)
    )
    assert rep["attack_flows"] == 0 and rep["detected_flows"] == 0
    assert rep["detection_rate"] == 0.0
    assert rep["reaction_pkts_median"] == 0.0
    assert rep["benign_fp_flow_rate"] == 0.0
    vals = [v for v in rep.values() if isinstance(v, float)]
    assert np.isfinite(vals).all()
    assert json.loads(json.dumps(rep)) == rep


def _tiny_stream(n):
    pkts = np.zeros((n, len(traffic.COLUMNS)), np.float32)
    pkts[:, traffic.COL_FLOW] = np.arange(n) % 2
    pkts[:, traffic.COL_LEN] = 500.0
    pkts[:, traffic.COL_IPT] = 1e-3
    fids = pkts[:, traffic.COL_FLOW].astype(np.int32)
    labels = fids % 2
    return traffic.PacketStream("tiny", pkts, labels.astype(np.int32),
                                fids, {0: 0, 1: 1})


@pytest.mark.parametrize("n", [1, 5])
def test_stream_feature_dataset_shorter_than_one_window(n):
    """A stream far shorter than one chunk window still yields a usable,
    finite dataset: both splits non-empty (a single row serves as its own
    train AND test) and identity-safe standardization moments."""
    stages, names = traffic.flow_feature_stages(n_slots=64)
    ds, mu, sd = traffic.stream_feature_dataset(
        _tiny_stream(n), stages, names, sample_every=1
    )
    assert len(ds.train_x) >= 1 and len(ds.test_x) >= 1
    assert np.isfinite(ds.train_x).all() and np.isfinite(ds.test_x).all()
    assert np.isfinite(mu).all() and np.isfinite(sd).all()
    assert (sd > 0).all()              # never divides by zero downstream


def test_stream_feature_dataset_empty_stream_identity_moments():
    stages, names = traffic.flow_feature_stages(n_slots=64)
    ds, mu, sd = traffic.stream_feature_dataset(
        _tiny_stream(0), stages, names, sample_every=1
    )
    assert len(ds.train_x) == 0 and len(ds.test_x) == 0
    np.testing.assert_array_equal(mu, np.zeros_like(mu))
    np.testing.assert_array_equal(sd, np.ones_like(sd))
