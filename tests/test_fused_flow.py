"""Fused single-launch stateful path (kernels/fused_flow): parity,
segmentation ordering, fallback honesty, with_backend regression.

The flow-state contract's fused form: under ``backend="pallas"`` the
whole ``FlowKey -> RegisterUpdate -> feature-emit -> classifier`` chain
runs as ONE Pallas launch, bit-identical to the two-dispatch
prefix+suffix composition — verdicts in arrival order and the same final
register table.  These tests pin the guarantee over the collision
patterns the slot-segmentation prelude must survive: one hot flow (deep
sequential drain), all-distinct keys (pure lockstep rounds), all packets
in the SAME slot with different keys (eviction chain), and ragged-tail
valid masks."""

import numpy as np
import pytest

from repro.core import pallas_backend, stageir
from repro.flowstate import FlowStateSpec, StatefulPipeline
from repro.kernels.fused_flow import fused_flow_classify
from repro.kernels.fused_mlp import pack_params, snap_lane

needs_pallas = pytest.mark.skipif(
    not pallas_backend.pallas_available(),
    reason="Pallas toolchain unavailable in this environment",
)


def _spec(n_slots=16):
    return FlowStateSpec(n_slots=n_slots, n_counters=1, n_ewma=1,
                         hist_sizes=(4,), ewma_alpha=0.25)


def _stages(spec, seed=0):
    rng = np.random.default_rng(seed)
    fk = stageir.FlowKey((0,), spec.n_slots)
    ru = stageir.RegisterUpdate(
        spec, ewma_cols=(1,), hist_cols=(1,),
        hist_edges=(np.linspace(0, 1, spec.hist_sizes[0] + 1)[1:-1],),
    )
    ws = stageir.WindowStats(spec, mode="all")
    w1 = rng.normal(size=(ws.n_out, 6)).astype(np.float32)
    w2 = rng.normal(size=(6, 2)).astype(np.float32)
    mlp = stageir.FusedMLP([w1, w2], [np.zeros(6, np.float32),
                                      np.zeros(2, np.float32)])
    return [fk, ru, ws, mlp, stageir.Reduce("argmax")]


def _same_slot_keys(n, n_slots):
    """n DISTINCT keys that all hash to one slot (eviction chain)."""
    from repro.kernels.flow_update import hash_slot

    cand = np.arange(1, 512 * n_slots, dtype=np.int32)
    slots = np.asarray(hash_slot(cand, n_slots))
    hit = cand[slots == slots[0]]
    assert len(hit) >= n, "widen the candidate scan"
    return hit[:n]


def _traffic(rng, pattern, n, n_slots):
    """[n, 2] packets keyed to exercise one segmentation regime."""
    X = np.zeros((n, 2), np.float32)
    if pattern == "one_hot_flow":       # ~90% one flow: deep drain chain
        hot = rng.random(n) < 0.9
        X[:, 0] = np.where(hot, 7, rng.integers(0, 200, n))
    elif pattern == "all_distinct":     # every key unique: rounds only
        X[:, 0] = np.arange(n) + 1
    elif pattern == "same_slot":        # same slot, different keys: the
        X[:, 0] = _same_slot_keys(n, n_slots)    # eviction chain
    else:                               # mixed collision-heavy
        X[:, 0] = rng.integers(0, 9, n)
    X[:, 1] = rng.random(n)
    return X


@needs_pallas
@pytest.mark.parametrize("pattern", ["one_hot_flow", "all_distinct",
                                     "same_slot", "mixed"])
def test_fused_parity_over_collision_patterns(rng, pattern):
    """Fused launch == interpreter, bit for bit, over a multi-chunk
    stream: verdicts in arrival order AND the final register table."""
    spec = _spec()
    stages = _stages(spec)
    pi = StatefulPipeline(stages)
    pp = StatefulPipeline(stages, backend="pallas")
    assert pp.backend == "pallas-fused-flow"
    si, sp = pi.init_state(), pp.init_state()
    for chunk in range(4):
        X = _traffic(rng, pattern, 96, spec.n_slots)
        si, vi = pi(si, X)
        sp, vp = pp(sp, X)
        np.testing.assert_array_equal(vi, vp, err_msg=f"{pattern}#{chunk}")
    np.testing.assert_array_equal(np.asarray(si.keys), np.asarray(sp.keys))
    np.testing.assert_array_equal(np.asarray(si.regs), np.asarray(sp.regs))


@needs_pallas
def test_fused_parity_ragged_valid(rng):
    """Padding rows (valid=0) never touch the table and the live
    verdicts keep arrival order through the inverse permutation."""
    spec = _spec()
    stages = _stages(spec)
    pi = StatefulPipeline(stages)
    pp = StatefulPipeline(stages, backend="pallas")
    X = _traffic(rng, "one_hot_flow", 64, spec.n_slots)
    valid = np.ones(64, np.int32)
    valid[40:] = 0                       # ragged tail
    valid[rng.integers(0, 40, 5)] = 0    # holes mid-batch
    si, vi = pi(pi.init_state(), X, valid)
    sp, vp = pp(pp.init_state(), X, valid)
    np.testing.assert_array_equal(np.asarray(si.keys), np.asarray(sp.keys))
    np.testing.assert_array_equal(np.asarray(si.regs), np.asarray(sp.regs))
    np.testing.assert_array_equal(vi[valid != 0], vp[valid != 0])


@needs_pallas
def test_fused_op_matches_stage_walk(rng):
    """kernels/fused_flow.fused_flow_classify directly vs the independent
    interpret path (scan-reference update + stage-walk suffix)."""
    from repro.flowstate.registers import init_state, update_flows

    spec = _spec()
    stages = _stages(spec)
    fk, ru = stages[0], stages[1]
    suffix = stages[2:]
    widths = [w.shape[0] for w in stages[3].weights] + [2]
    lane = snap_lane(widths, interpret=True)
    w_stack, b_stack = pack_params(stages[3].weights, stages[3].biases,
                                   lane)
    st = init_state(spec)
    X = _traffic(rng, "same_slot", 80, spec.n_slots)
    pkt_keys = fk.apply_keys(X)
    upd, bins = ru.prepare(X)
    valid = np.ones(80, np.int32)

    keys2, regs2, verd = fused_flow_classify(
        st.keys, st.regs, pkt_keys, upd, bins, valid, w_stack, b_stack,
        n_counters=spec.n_counters, n_ewma=spec.n_ewma,
        alpha=spec.ewma_alpha, mode="all", num_classes=2, lane=lane,
    )
    st_ref, feats_ref = update_flows(st, pkt_keys, upd, bins, valid)
    verd_ref = stageir.apply_stages(suffix, feats_ref)
    np.testing.assert_array_equal(np.asarray(verd), np.asarray(verd_ref))
    np.testing.assert_array_equal(np.asarray(keys2),
                                  np.asarray(st_ref.keys))
    np.testing.assert_array_equal(np.asarray(regs2),
                                  np.asarray(st_ref.regs))


@needs_pallas
def test_with_backend_preserves_fuse_flag():
    """Regression: with_backend must thread ``fuse`` through — an
    unfused pipeline must not silently come back fused."""
    spec = _spec()
    stages = _stages(spec)
    unfused = StatefulPipeline(stages, backend="pallas", fuse=False)
    assert not unfused.fused and unfused.backend == "pallas"
    again = unfused.with_backend("pallas")
    assert not again.fused and again.backend == "pallas"

    fused = StatefulPipeline(stages, backend="pallas")
    assert fused.fused
    assert fused.with_backend("interpret").backend == "interpret"
    assert fused.with_backend("interpret").with_backend("pallas").fused


@needs_pallas
def test_fused_fallback_stays_honest(rng):
    """A suffix outside the fused envelope must NOT report the fused
    backend — and still serve bit-identically to the interpreter."""
    spec = _spec()
    stages = _stages(spec)[:3] + [
        stageir.CentroidDistance(np.asarray(
            np.random.default_rng(1).normal(size=(3, stages_out(spec))),
            np.float32)),
        stageir.Reduce("argmin"),
    ]
    pp = StatefulPipeline(stages, backend="pallas")
    assert not pp.fused
    assert pp.backend in ("pallas", "mixed")
    pi = StatefulPipeline(stages)
    X = _traffic(rng, "mixed", 48, spec.n_slots)
    _, vi = pi(pi.init_state(), X)
    _, vp = pp(pp.init_state(), X)
    np.testing.assert_array_equal(vi, vp)


def stages_out(spec):
    """WindowStats(mode='all') output width for ``spec``."""
    return stageir.WindowStats(spec, mode="all").n_out


@needs_pallas
def test_fused_step_through_sharded_engine(rng):
    """ShardedPacketServeEngine wraps the fused step (1-ary mesh on a
    one-device host) and matches the interpreter engine's verdicts."""
    from repro.serve import PacketServeEngine, ShardedPacketServeEngine

    spec = _spec(n_slots=32)
    stages = _stages(spec)
    pp = StatefulPipeline(stages, backend="pallas")
    assert pp.backend == "pallas-fused-flow"
    X = _traffic(rng, "mixed", 300, spec.n_slots)
    sh = ShardedPacketServeEngine(pp, feature_dim=2, max_batch=64,
                                  min_shards=1)
    sh.submit(X)
    vs = sh.flush()
    assert sh.stats()["backend"] == "pallas-fused-flow"
    base = PacketServeEngine(StatefulPipeline(stages), feature_dim=2,
                             max_batch=64)
    base.submit(X)
    np.testing.assert_array_equal(vs, base.flush())
