"""Fused single-launch stateful path (kernels/fused_flow): parity,
segmentation ordering, fallback honesty, with_backend regression.

The flow-state contract's fused form: under ``backend="pallas"`` the
whole ``FlowKey -> RegisterUpdate -> feature-emit -> classifier`` chain
runs as ONE Pallas launch, bit-identical to the two-dispatch
prefix+suffix composition — verdicts in arrival order and the same final
register table.  These tests pin the guarantee over the collision
patterns the slot-segmentation prelude must survive: one hot flow (deep
sequential drain), all-distinct keys (pure lockstep rounds), all packets
in the SAME slot with different keys (eviction chain), and ragged-tail
valid masks."""

import numpy as np
import pytest

from repro.core import pallas_backend, stageir
from repro.flowstate import FlowStateSpec, StatefulPipeline
from repro.kernels.fused_flow import fused_flow_classify
from repro.kernels.fused_mlp import pack_params, snap_lane

needs_pallas = pytest.mark.skipif(
    not pallas_backend.pallas_available(),
    reason="Pallas toolchain unavailable in this environment",
)


def _spec(n_slots=16):
    return FlowStateSpec(n_slots=n_slots, n_counters=1, n_ewma=1,
                         hist_sizes=(4,), ewma_alpha=0.25)


def _stages(spec, seed=0):
    rng = np.random.default_rng(seed)
    fk = stageir.FlowKey((0,), spec.n_slots)
    ru = stageir.RegisterUpdate(
        spec, ewma_cols=(1,), hist_cols=(1,),
        hist_edges=(np.linspace(0, 1, spec.hist_sizes[0] + 1)[1:-1],),
    )
    ws = stageir.WindowStats(spec, mode="all")
    w1 = rng.normal(size=(ws.n_out, 6)).astype(np.float32)
    w2 = rng.normal(size=(6, 2)).astype(np.float32)
    mlp = stageir.FusedMLP([w1, w2], [np.zeros(6, np.float32),
                                      np.zeros(2, np.float32)])
    return [fk, ru, ws, mlp, stageir.Reduce("argmax")]


def _same_slot_keys(n, n_slots):
    """n DISTINCT keys that all hash to one slot (eviction chain)."""
    from repro.kernels.flow_update import hash_slot

    cand = np.arange(1, 512 * n_slots, dtype=np.int32)
    slots = np.asarray(hash_slot(cand, n_slots))
    hit = cand[slots == slots[0]]
    assert len(hit) >= n, "widen the candidate scan"
    return hit[:n]


def _traffic(rng, pattern, n, n_slots):
    """[n, 2] packets keyed to exercise one segmentation regime."""
    X = np.zeros((n, 2), np.float32)
    if pattern == "one_hot_flow":       # ~90% one flow: deep drain chain
        hot = rng.random(n) < 0.9
        X[:, 0] = np.where(hot, 7, rng.integers(0, 200, n))
    elif pattern == "all_distinct":     # every key unique: rounds only
        X[:, 0] = np.arange(n) + 1
    elif pattern == "same_slot":        # same slot, different keys: the
        X[:, 0] = _same_slot_keys(n, n_slots)    # eviction chain
    else:                               # mixed collision-heavy
        X[:, 0] = rng.integers(0, 9, n)
    X[:, 1] = rng.random(n)
    return X


@needs_pallas
@pytest.mark.parametrize("pattern", ["one_hot_flow", "all_distinct",
                                     "same_slot", "mixed"])
def test_fused_parity_over_collision_patterns(rng, pattern):
    """Fused launch == interpreter, bit for bit, over a multi-chunk
    stream: verdicts in arrival order AND the final register table."""
    spec = _spec()
    stages = _stages(spec)
    pi = StatefulPipeline(stages)
    pp = StatefulPipeline(stages, backend="pallas")
    assert pp.backend == "pallas-fused-flow"
    si, sp = pi.init_state(), pp.init_state()
    for chunk in range(4):
        X = _traffic(rng, pattern, 96, spec.n_slots)
        si, vi = pi(si, X)
        sp, vp = pp(sp, X)
        np.testing.assert_array_equal(vi, vp, err_msg=f"{pattern}#{chunk}")
    np.testing.assert_array_equal(np.asarray(si.keys), np.asarray(sp.keys))
    np.testing.assert_array_equal(np.asarray(si.regs), np.asarray(sp.regs))


@needs_pallas
def test_fused_parity_ragged_valid(rng):
    """Padding rows (valid=0) never touch the table and the live
    verdicts keep arrival order through the inverse permutation."""
    spec = _spec()
    stages = _stages(spec)
    pi = StatefulPipeline(stages)
    pp = StatefulPipeline(stages, backend="pallas")
    X = _traffic(rng, "one_hot_flow", 64, spec.n_slots)
    valid = np.ones(64, np.int32)
    valid[40:] = 0                       # ragged tail
    valid[rng.integers(0, 40, 5)] = 0    # holes mid-batch
    si, vi = pi(pi.init_state(), X, valid)
    sp, vp = pp(pp.init_state(), X, valid)
    np.testing.assert_array_equal(np.asarray(si.keys), np.asarray(sp.keys))
    np.testing.assert_array_equal(np.asarray(si.regs), np.asarray(sp.regs))
    np.testing.assert_array_equal(vi[valid != 0], vp[valid != 0])


@needs_pallas
def test_fused_op_matches_stage_walk(rng):
    """kernels/fused_flow.fused_flow_classify directly vs the independent
    interpret path (scan-reference update + stage-walk suffix)."""
    from repro.flowstate.registers import init_state, update_flows

    spec = _spec()
    stages = _stages(spec)
    fk, ru = stages[0], stages[1]
    suffix = stages[2:]
    widths = [w.shape[0] for w in stages[3].weights] + [2]
    lane = snap_lane(widths, interpret=True)
    w_stack, b_stack = pack_params(stages[3].weights, stages[3].biases,
                                   lane)
    st = init_state(spec)
    X = _traffic(rng, "same_slot", 80, spec.n_slots)
    pkt_keys = fk.apply_keys(X)
    upd, bins = ru.prepare(X)
    valid = np.ones(80, np.int32)

    keys2, regs2, verd = fused_flow_classify(
        st.keys, st.regs, pkt_keys, upd, bins, valid, w_stack, b_stack,
        n_counters=spec.n_counters, n_ewma=spec.n_ewma,
        alpha=spec.ewma_alpha, mode="all", num_classes=2, lane=lane,
    )
    st_ref, feats_ref = update_flows(st, pkt_keys, upd, bins, valid)
    verd_ref = stageir.apply_stages(suffix, feats_ref)
    np.testing.assert_array_equal(np.asarray(verd), np.asarray(verd_ref))
    np.testing.assert_array_equal(np.asarray(keys2),
                                  np.asarray(st_ref.keys))
    np.testing.assert_array_equal(np.asarray(regs2),
                                  np.asarray(st_ref.regs))


@needs_pallas
def test_with_backend_preserves_fuse_flag():
    """Regression: with_backend must thread ``fuse`` through — an
    unfused pipeline must not silently come back fused."""
    spec = _spec()
    stages = _stages(spec)
    unfused = StatefulPipeline(stages, backend="pallas", fuse=False)
    assert not unfused.fused and unfused.backend == "pallas"
    again = unfused.with_backend("pallas")
    assert not again.fused and again.backend == "pallas"

    fused = StatefulPipeline(stages, backend="pallas")
    assert fused.fused
    assert fused.with_backend("interpret").backend == "interpret"
    assert fused.with_backend("interpret").with_backend("pallas").fused


@needs_pallas
def test_fused_fallback_stays_honest(rng):
    """A suffix outside the fused envelope must NOT report the fused
    backend — it serves bit-identically to the interpreter and surfaces
    the decline reason on ``fallback_reason``."""
    spec = _spec()
    r = np.random.default_rng(1)
    n_in = stages_out(spec)
    wide = stageir.FusedMLP(          # hidden width > the 128 kernel lane
        [np.asarray(r.normal(size=(n_in, 200)), np.float32),
         np.asarray(r.normal(size=(200, 2)), np.float32)],
        [np.zeros(200, np.float32), np.zeros(2, np.float32)])
    stages = _stages(spec)[:3] + [wide, stageir.Reduce("argmax")]
    pp = StatefulPipeline(stages, backend="pallas")
    assert not pp.fused
    assert pp.fallback_reason == "classifier width exceeds the kernel lane"
    assert pp.backend in ("pallas", "mixed")
    pi = StatefulPipeline(stages)
    X = _traffic(rng, "mixed", 48, spec.n_slots)
    _, vi = pi(pi.init_state(), X)
    _, vp = pp(pp.init_state(), X)
    np.testing.assert_array_equal(vi, vp)


def stages_out(spec):
    """WindowStats(mode='all') output width for ``spec``."""
    return stageir.WindowStats(spec, mode="all").n_out


@needs_pallas
def test_fused_step_through_sharded_engine(rng):
    """ShardedPacketServeEngine wraps the fused step (1-ary mesh on a
    one-device host) and matches the interpreter engine's verdicts."""
    from repro.serve import PacketServeEngine, ShardedPacketServeEngine

    spec = _spec(n_slots=32)
    stages = _stages(spec)
    pp = StatefulPipeline(stages, backend="pallas")
    assert pp.backend == "pallas-fused-flow"
    X = _traffic(rng, "mixed", 300, spec.n_slots)
    sh = ShardedPacketServeEngine(pp, feature_dim=2, max_batch=64,
                                  min_shards=1)
    sh.submit(X)
    vs = sh.flush()
    assert sh.stats()["backend"] == "pallas-fused-flow"
    base = PacketServeEngine(StatefulPipeline(stages), feature_dim=2,
                             max_batch=64)
    base.submit(X)
    np.testing.assert_array_equal(vs, base.flush())


# ------------------------------------------- widened fused envelope
#
# MAT / centroid suffixes, the in-kernel mitigation fold and two-table
# DAGs all serve out of the SAME single launch ("pallas-fused-flow") —
# each pinned bit-identical to the interpreter stage walk over the
# inputs most likely to split the paths: values exactly on quantization
# edges, exact centroid-distance ties, and collision-heavy same-slot
# eviction chains through the action table.


def _mat_suffix(spec, seed=0, n_classes=3):
    """Quantize -> LUTGather -> Reduce -> LabelMap over the ws readout,
    with edge rows placed ON values the readout actually produces
    (integer packet counts, exact 0.25-grid fractions)."""
    rng = np.random.default_rng(seed)
    n_in = stages_out(spec)
    edges = np.zeros((n_in, 3), np.float32)
    edges[0] = [1.0, 2.0, 3.0]         # count feature: exact integers
    edges[1:] = [0.25, 0.5, 0.75]      # boundaries every fraction can hit
    tables = rng.random((n_in, 4, n_classes)).astype(np.float32)
    lmap = np.asarray([0, 1, 1], np.int32)[:n_classes]
    return [stageir.Quantize(edges), stageir.LUTGather(tables),
            stageir.Reduce("argmax"), stageir.LabelMap(lmap)]


@needs_pallas
def test_fused_mat_suffix_on_quantization_boundaries(rng):
    """MAT suffix in the fused launch: inputs landing EXACTLY on bin
    edges bucket identically on both paths (`>` on shared f32 values),
    so verdicts and the register table stay bit-identical."""
    spec = _spec()
    stages = _stages(spec)[:3] + _mat_suffix(spec)
    pi = StatefulPipeline(stages)
    pp = StatefulPipeline(stages, backend="pallas")
    assert pp.backend == "pallas-fused-flow"
    si, sp = pi.init_state(), pp.init_state()
    for chunk in range(4):
        X = _traffic(rng, "mixed", 96, spec.n_slots)
        X[:, 1] = (rng.integers(0, 5, 96) * 0.25).astype(np.float32)
        si, vi = pi(si, X)
        sp, vp = pp(sp, X)
        np.testing.assert_array_equal(vi, vp, err_msg=f"chunk {chunk}")
    np.testing.assert_array_equal(np.asarray(si.keys), np.asarray(sp.keys))
    np.testing.assert_array_equal(np.asarray(si.regs), np.asarray(sp.regs))


@needs_pallas
def test_fused_centroid_ties_break_to_lowest_index(rng):
    """Centroid suffix with DUPLICATED centroids: every packet nearest
    the pair is an exact distance tie, and the masked argmin must pick
    the lowest index on both paths (label 9 can never win)."""
    spec = _spec()
    cent = np.asarray([[0.5, 0.25], [4.0, 4.0], [0.5, 0.25]], np.float32)
    stages = _stages(spec)[:3] + [
        stageir.FeatureSelect((0, 2)),
        stageir.CentroidDistance(cent),
        stageir.Reduce("argmin"),
        stageir.LabelMap(np.asarray([5, 7, 9], np.int32)),
    ]
    pi = StatefulPipeline(stages)
    pp = StatefulPipeline(stages, backend="pallas")
    assert pp.backend == "pallas-fused-flow"
    si, sp = pi.init_state(), pp.init_state()
    for chunk in range(3):
        X = _traffic(rng, "mixed", 96, spec.n_slots)
        si, vi = pi(si, X)
        sp, vp = pp(sp, X)
        np.testing.assert_array_equal(vi, vp, err_msg=f"chunk {chunk}")
        assert set(np.unique(vp)) <= {5, 7}    # index 2 loses every tie
    np.testing.assert_array_equal(np.asarray(si.regs), np.asarray(sp.regs))


@needs_pallas
def test_fused_mitigation_same_slot_eviction_chain(rng):
    """The in-kernel mitigation fold under the worst segmentation: long
    runs of repeated keys that ALL hash to one detection slot — deep
    drain chains in both tables, threshold crossings mid-chain, and
    evictions resetting the action rows.  Verdict stream (MITIGATED
    sentinels included) and both tables stay bit-identical."""
    from repro.flowstate.mitigation import MITIGATED, MitigationSpec

    spec = _spec()
    n_in = stages_out(spec)
    attack = stageir.FusedMLP(        # always verdicts class 1
        [np.zeros((n_in, 2), np.float32)],
        [np.asarray([0.0, 1.0], np.float32)])
    stages = _stages(spec)[:3] + [
        attack, stageir.Reduce("argmax"),
        stageir.Mitigate(MitigationSpec(n_slots=16, threshold=2)),
    ]
    pi = StatefulPipeline(stages)
    pp = StatefulPipeline(stages, backend="pallas")
    assert pp.backend == "pallas-fused-flow"
    si, sp = pi.init_state(), pp.init_state()
    keys = _same_slot_keys(8, spec.n_slots)
    saw_drop = False
    for chunk in range(3):
        X = np.zeros((96, 2), np.float32)
        X[:, 0] = np.repeat(keys, 12)          # 96-deep same-slot chain
        X[:, 1] = rng.random(96)
        si, vi = pi(si, X)
        sp, vp = pp(sp, X)
        np.testing.assert_array_equal(vi, vp, err_msg=f"chunk {chunk}")
        saw_drop = saw_drop or bool(np.any(np.asarray(vp) == MITIGATED))
    assert saw_drop, "threshold never tripped: test traffic too gentle"
    for f in ("keys", "regs", "mit_keys", "mit_regs"):
        np.testing.assert_array_equal(
            np.asarray(getattr(si, f)), np.asarray(getattr(sp, f)),
            err_msg=f"{f} diverged")


def _two_table_stages(spec, spec2, seed=0):
    rng = np.random.default_rng(seed)
    fk, ru, ws = _stages(spec)[:3]
    fk2 = stageir.FlowKey((0,), spec2.n_slots)
    ru2 = stageir.RegisterUpdate(spec2, counter_cols=(0,))
    n_in = ws.n_out + spec2.width
    w1 = rng.normal(size=(n_in, 6)).astype(np.float32)
    w2 = rng.normal(size=(6, 2)).astype(np.float32)
    mlp = stageir.FusedMLP([w1, w2], [np.zeros(6, np.float32),
                                      np.zeros(2, np.float32)])
    return [fk, ru, ws, fk2, ru2, mlp, stageir.Reduce("argmax")]


@needs_pallas
def test_fused_two_table_dag_parity(rng):
    """Two FlowKey/RegisterUpdate tables feeding one classifier fuse
    into ONE launch, bit-identical to a hand-walked reference (per-table
    ``update_flows`` + stage application) and to the interpreter."""
    from repro.flowstate.registers import update_flows, init_state

    spec = _spec()
    spec2 = FlowStateSpec(n_slots=32, n_counters=2, n_ewma=0, hist_sizes=())
    stages = _two_table_stages(spec, spec2)
    fk, ru, ws, fk2, ru2 = stages[:5]
    pi = StatefulPipeline(stages)
    pp = StatefulPipeline(stages, backend="pallas")
    assert pp.backend == "pallas-fused-flow" and pp.n_tables == 2
    si, sp = pi.init_state(), pp.init_state()
    r0, r1 = init_state(spec), init_state(spec2)
    for chunk in range(3):
        X = _traffic(rng, "mixed", 96, spec.n_slots)
        si, vi = pi(si, X)
        sp, vp = pp(sp, X)
        # hand-walked reference, table by table
        import jax.numpy as jnp

        r0, f0 = update_flows(r0, fk.apply_keys(X), *ru.prepare(X))
        r1, f1 = update_flows(r1, fk2.apply_keys(X), *ru2.prepare(X))
        feats = jnp.concatenate([ws.apply(f0), f1], axis=1)
        vr = stageir.apply_stages(stages[5:], feats)
        np.testing.assert_array_equal(vp, vi, err_msg=f"chunk {chunk}")
        np.testing.assert_array_equal(vp, np.asarray(vr),
                                      err_msg=f"ref chunk {chunk}")
    for t, ref in enumerate((r0, r1)):
        np.testing.assert_array_equal(np.asarray(sp.keys_list[t]),
                                      np.asarray(ref.keys))
        np.testing.assert_array_equal(np.asarray(sp.regs_list[t]),
                                      np.asarray(ref.regs))
        np.testing.assert_array_equal(np.asarray(sp.keys_list[t]),
                                      np.asarray(si.keys_list[t]))


@needs_pallas
def test_mitigated_fused_pipeline_survives_swap(rng):
    """Satellite regression: a hot swap installing a mitigated pipeline
    over the SAME specs must come back still fused — reporting
    "pallas-fused-flow", carrying both tables bit-identically."""
    from repro.flowstate.mitigation import MitigationSpec
    from repro.serve import PacketServeEngine

    spec = _spec(n_slots=32)
    mit = stageir.Mitigate(MitigationSpec(n_slots=32, threshold=2))
    X1 = _traffic(rng, "mixed", 200, spec.n_slots)
    X2 = _traffic(rng, "mixed", 200, spec.n_slots)

    def run(backend):
        eng = PacketServeEngine(
            StatefulPipeline(_stages(spec) + [mit], backend=backend),
            feature_dim=2, max_batch=64)
        eng.submit(X1)
        v1 = eng.flush()
        eng.swap(StatefulPipeline(_stages(spec, seed=3) + [mit],
                                  backend=backend))
        eng.submit(X2)
        return eng, np.concatenate([v1, eng.flush()])

    eng_p, vp = run("pallas")
    assert eng_p.backend == "pallas-fused-flow"
    assert eng_p.pipeline.fused and eng_p.pipeline.fallback_reason is None
    assert set(eng_p.stats()["backend_batches"]) == {"pallas-fused-flow"}
    eng_i, vi = run("interpret")
    np.testing.assert_array_equal(vp, vi)
    for f in ("keys", "regs", "mit_keys", "mit_regs"):
        np.testing.assert_array_equal(
            np.asarray(getattr(eng_p.state, f)),
            np.asarray(getattr(eng_i.state, f)), err_msg=f)


@needs_pallas
def test_fallback_reason_surfaced_in_stats_and_journal(rng):
    """Satellite: when the fused lowering declines, the decline reason
    reaches both the ``backend_fallback`` journal event and the
    ``backend_batches`` accounting key."""
    from repro.serve import PacketServeEngine

    spec = _spec()
    r = np.random.default_rng(2)
    n_in = stages_out(spec)
    wide = stageir.FusedMLP(
        [np.asarray(r.normal(size=(n_in, 200)), np.float32),
         np.asarray(r.normal(size=(200, 2)), np.float32)],
        [np.zeros(200, np.float32), np.zeros(2, np.float32)])
    stages = _stages(spec)[:3] + [wide, stageir.Reduce("argmax")]
    eng = PacketServeEngine(StatefulPipeline(stages, backend="pallas"),
                            feature_dim=2, max_batch=32)
    eng.submit(_traffic(rng, "mixed", 64, spec.n_slots))
    eng.flush()
    reason = "classifier width exceeds the kernel lane"
    (key,) = eng.stats()["backend_batches"]
    assert key == f"{eng.backend}({reason})"
    evs = eng.telemetry().journal.events("backend_fallback")
    assert evs and evs[0]["reason"] == reason
