"""Population-parallel DSE: batched proposals, vmapped training, batched
feasibility, the trained-candidate cache, and the determinism contract —

  * two ``generate()`` runs with the same seed pick the same algorithm and
    config and trace the same regret curve;
  * the batched engine, fed the same proposal stream, returns the same
    best configuration as the sequential reference path.
"""

import numpy as np
import pytest

from homunculus.alchemy import DataLoader, Model, Platforms
from repro.core import dse, mlalgos
from repro.core.bo import ConstrainedBO
from repro.core.designspace import DesignSpace, Param
from repro.core.traincache import CandidateCache, candidate_key
from repro.data import netdata


@DataLoader
def tiny_loader():
    return netdata.make_ad_dataset(features=7, n_train=640, n_test=320)


def _model(algos=("dnn",)):
    return Model({
        "optimization_metric": ["f1"],
        "algorithm": list(algos),
        "name": "ad",
        "data_loader": tiny_loader,
    })


def _platform():
    p = Platforms.Taurus()
    p.constrain(performance={"throughput": 1, "latency": 500},
                resources={"rows": 16, "cols": 16})
    return p


# ----------------------------------------------------- batched BO proposals


def test_suggest_batch_init_phase_and_model_phase():
    space = DesignSpace([Param("x", "real", 0.0, 1.0),
                         Param("y", "real", 0.0, 1.0)])
    bo = ConstrainedBO(space, n_init=4, seed=0)
    init = bo.suggest_batch(3)
    assert len(init) == 3
    assert all(0.0 <= c["x"] <= 1.0 for c in init)
    for cfg in init + [space.sample(bo.rng)]:
        v = -((cfg["x"] - 0.7) ** 2)
        bo.observe(cfg, v, cfg["x"] + cfg["y"] < 1.2, {})
    batch = bo.suggest_batch(4)
    assert len(batch) == 4
    # fantasies must spread the batch: no two picks identical
    seen = {(c["x"], c["y"]) for c in batch}
    assert len(seen) == 4
    assert bo.suggest_batch(0) == []


def test_run_batched_respects_budget_and_finds_optimum():
    space = DesignSpace([Param("x", "real", 0.0, 1.0)])
    bo = ConstrainedBO(space, n_init=6, seed=1)
    best = bo.run_batched(
        lambda cfgs: [(-(c["x"] - 0.3) ** 2, c["x"] < 0.9, {})
                      for c in cfgs],
        budget=30, batch_size=5,
    )
    assert len(bo.history) == 30
    assert best is not None and best.value > -0.05
    curve = bo.regret_curve()
    assert all(b >= a for a, b in zip(curve, curve[1:]))


# -------------------------------------------------------- batched training


def test_train_batch_numpy_pool_matches_sequential():
    d = tiny_loader()
    for algo, cfgs in (
        ("svm", [{"c_reg": 0.5}, {"c_reg": 2.0}]),
        ("kmeans", [{"k": 2}, {"k": 4, "n_features": 3}]),
        ("tree", [{"max_depth": 2}, {"max_depth": 3}]),
    ):
        pooled = mlalgos.train_batch(algo, d, cfgs, seed=2)
        for cfg, tp in zip(cfgs, pooled):
            ts = mlalgos.train(algo, d, cfg, seed=2)
            np.testing.assert_array_equal(ts.predict(d.test_x),
                                          tp.predict(d.test_x))


def test_train_dnn_batch_buckets_match_sequential():
    d = tiny_loader()
    cfgs = [
        {"n_layers": 1, "h0": 8, "lr": 3e-3, "batch": 128, "epochs": 1},
        {"n_layers": 1, "h0": 16, "lr": 1e-3, "batch": 128, "epochs": 1},
        {"n_layers": 2, "h0": 8, "h1": 8, "lr": 2e-3, "batch": 128,
         "epochs": 1},
    ]
    batched = mlalgos.train_batch("dnn", d, cfgs, seed=0)
    for cfg, tb in zip(cfgs, batched):
        ts = mlalgos.train("dnn", d, cfg, seed=0)
        assert ts.topology["widths"] == tb.topology["widths"]
        assert ts.param_count == tb.param_count
        for a, b in zip(ts.params, tb.params):
            np.testing.assert_allclose(a["w"], b["w"], rtol=2e-5, atol=1e-6)
        # same math up to float reduction order: tolerate a rare
        # near-tie argmax flip rather than demand bit-exact logits
        assert np.mean(ts.predict(d.test_x)
                       != tb.predict(d.test_x)) <= 0.005


# ----------------------------------------------------- batched feasibility


def test_check_batch_matches_check():
    p = _platform()
    topologies = [
        {"widths": [7, 8, 2], "act": "relu"},
        {"widths": [7, 64, 64, 2], "act": "relu"},          # feasible
        {"widths": [64] + [128] * 10 + [2], "act": "relu"},  # infeasible
    ]
    batch = p.check_batch("dnn", topologies)
    for topo, rep in zip(topologies, batch):
        one = p.check("dnn", topo)
        assert (one.feasible, one.reasons, one.resources,
                one.latency_ns, one.throughput_pps) == \
            (rep.feasible, rep.reasons, rep.resources,
             rep.latency_ns, rep.throughput_pps)
    km = [{"k": 2, "n_features": 4}, {"k": 5, "n_features": 7}]
    for topo, rep in zip(km, p.check_batch("kmeans", km)):
        assert p.check("kmeans", topo).resources == rep.resources
    # base-class path (tofino has no vectorized model)
    tof = Platforms.Tofino()
    topo = [{"k": 3, "n_features": 7}, {"k": 20, "n_features": 7}]
    got = tof.check_batch("kmeans", topo)
    assert [r.feasible for r in got] == [True, False]


# -------------------------------------------------------- candidate cache


def test_cache_content_addressing_ignores_dead_params():
    d = tiny_loader()
    base = {"n_layers": 1, "h0": 8, "lr": 3e-3, "batch": 128, "epochs": 1}
    alias = dict(base, h7=128)  # dead slot beyond n_layers
    other = dict(base, h0=16)
    k0 = candidate_key("dnn", base, 0, d)
    assert candidate_key("dnn", alias, 0, d) == k0
    assert candidate_key("dnn", other, 0, d) != k0
    assert candidate_key("dnn", base, 1, d) != k0


def test_evaluate_candidates_cache_skips_retraining():
    d = tiny_loader()
    p = _platform()
    cache = CandidateCache()
    cfgs = [{"n_layers": 1, "h0": 8, "lr": 3e-3, "batch": 128, "epochs": 1},
            {"n_layers": 1, "h0": 8, "lr": 3e-3, "batch": 128, "epochs": 1,
             "h9": 64}]  # same effective config
    out1 = dse.evaluate_candidates(p, "dnn", d, "f1", cfgs, seed=0,
                                   cache=cache)
    assert len(cache) == 1  # in-batch dedup: one training for two proposals
    assert out1[0][0] == out1[1][0]
    hits_before = cache.hits
    out2 = dse.evaluate_candidates(p, "dnn", d, "f1", cfgs, seed=0,
                                   cache=cache)
    assert cache.hits == hits_before + 2 and len(cache) == 1
    assert out2[0][0] == out1[0][0]
    # identical info object: the trained model was reused, not retrained
    assert out2[0][2]["trained"] is out1[0][2]["trained"]


# ------------------------------------------------------------- determinism


@pytest.mark.slow
def test_generate_deterministic_across_runs():
    runs = []
    for _ in range(2):
        p = _platform()
        p.schedule(_model())
        res = dse.generate(p, budget=10, n_init=4, seed=3,
                           cache=CandidateCache())
        runs.append(res["ad"])
    a, b = runs
    assert a.algorithm == b.algorithm
    assert a.trained.config == b.trained.config
    assert a.regret == b.regret
    assert [o.config for o in a.history] == [o.config for o in b.history]


@pytest.mark.slow
def test_batched_matches_sequential_reference():
    results = {}
    for mode in ("batched", "sequential"):
        res = dse.search_model(
            _platform(), _model(), budget=10, n_init=4, seed=3,
            eval_mode=mode, cache=CandidateCache(),
        )
        results[mode] = res
    rb, rs = results["batched"], results["sequential"]
    # same proposal stream -> same winner (the acceptance contract); the
    # observed metrics may wiggle by a near-tie label flip (vmap reorders
    # float reductions), so values get a one-flip cushion, not 1e-6
    assert rb.algorithm == rs.algorithm
    assert rb.trained.config == rs.trained.config
    assert rb.value == pytest.approx(rs.value, abs=5e-3)
    assert len(rb.regret) == len(rs.regret)
    np.testing.assert_allclose(rb.regret, rs.regret, atol=5e-3)


@pytest.mark.slow
def test_multi_algorithm_race_is_deterministic_and_feasible():
    p = _platform()
    p.schedule(_model(("dnn", "svm", "kmeans")))
    res = dse.generate(p, budget=12, n_init=3, seed=0, batch_k=4,
                       cache=CandidateCache())
    r = res["ad"]
    assert r.report.feasible
    assert all(b >= a for a, b in zip(r.regret, r.regret[1:]))
    # every algorithm actually raced (its budget floor is >= 4)
    algos = {o.info["trained"].algorithm for o in r.history
             if "trained" in o.info}
    assert algos == {"dnn", "svm", "kmeans"}
