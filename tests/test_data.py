"""Synthetic data pipelines: determinism, statistics, the paper's Fig-6
reactivity property, and the LM token stream."""

import numpy as np
import pytest

from repro.core import mlalgos
from repro.data import netdata
from repro.data.tokens import TokenDataset


def test_ad_deterministic_and_balanced():
    a = netdata.make_ad_dataset(features=7, n_train=512, n_test=256, seed=5)
    b = netdata.make_ad_dataset(features=7, n_train=512, n_test=256, seed=5)
    np.testing.assert_array_equal(a.train_x, b.train_x)
    np.testing.assert_array_equal(a.train_y, b.train_y)
    frac = a.train_y.mean()
    assert 0.3 < frac < 0.6
    assert a.num_features == 7 and a.num_classes == 2


def test_ad_30_feature_variant():
    d = netdata.make_ad_dataset(features=30, n_train=256, n_test=128)
    assert d.num_features == 30


def test_ad_capacity_accuracy_correlation(ad_data):
    """Table-2 central effect: a bigger DNN beats a tiny one."""
    small = mlalgos.train_dnn(ad_data, hidden=[4], epochs=6, seed=0)
    big = mlalgos.train_dnn(ad_data, hidden=[48, 32, 16], epochs=6, seed=0)
    f1_small = mlalgos.f1_score(ad_data.test_y, small.predict(ad_data.test_x))
    f1_big = mlalgos.f1_score(ad_data.test_y, big.predict(ad_data.test_x))
    assert f1_big > f1_small + 0.01


def test_tc_five_classes(tc_data):
    assert tc_data.num_classes == 5
    assert set(np.unique(tc_data.train_y)) == set(range(5))


def test_bd_flow_statistics():
    """Fig. 6: botnet flows are low-volume/high-duration vs benign P2P."""
    flows = netdata.make_bd_flows(n_flows=300, seed=0)
    bot = [f for f in flows if f.label == 1]
    ben = [f for f in flows if f.label == 0]
    assert len(bot) > 20 and len(ben) > 20
    mean_pkts = lambda fs: np.mean([len(f.sizes) for f in fs])
    mean_ipt = lambda fs: np.mean([np.mean(f.ipts) for f in fs])
    assert mean_pkts(bot) < mean_pkts(ben)      # low volume
    assert mean_ipt(bot) > mean_ipt(ben)        # high duration / sparse


def test_bd_partial_histograms_diverge_early():
    """§5.1.1: per-packet partial histograms separate classes well before
    flow end — the reaction-time argument for per-packet ML."""
    data, test_flows = netdata.make_bd_dataset(n_flows=900, seed=1)
    model = mlalgos.train_dnn(data, hidden=[32, 16], epochs=8, seed=0)

    f1_full = mlalgos.f1_score(data.test_y, model.predict(data.test_x))
    partial = netdata.bd_partial_eval_set(test_flows, checkpoints=(10,))
    X10, y10 = partial[10]
    f1_10 = mlalgos.f1_score(y10, model.predict(X10))
    assert f1_full > 0.75
    assert f1_10 > 0.6 * f1_full  # most of the signal in the first packets


def test_token_dataset_deterministic_and_host_sharded():
    d0 = TokenDataset(256, 32, 8, seed=3)
    d1 = TokenDataset(256, 32, 8, seed=3)
    b0, b1 = d0.batch_at(7), d1.batch_at(7)
    np.testing.assert_array_equal(b0["tokens"], b1["tokens"])
    # host sharding partitions the batch deterministically
    h0 = TokenDataset(256, 32, 8, seed=3, host_id=0, num_hosts=2)
    h1 = TokenDataset(256, 32, 8, seed=3, host_id=1, num_hosts=2)
    a, b = h0.batch_at(0), h1.batch_at(0)
    assert a["tokens"].shape == (4, 32)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_token_dataset_has_learnable_structure():
    """Bigram structure: successor entropy << unigram entropy."""
    d = TokenDataset(64, 128, 16, seed=0, branch=4)
    b = d.batch_at(0)
    toks, tgts = b["tokens"], b["targets"]
    # empirical: fraction of transitions that follow the bigram table
    follows = 0
    total = 0
    for i in range(toks.shape[0]):
        for t in range(toks.shape[1]):
            total += 1
            if tgts[i, t] in d.succ[toks[i, t]]:
                follows += 1
    assert follows / total > 0.7


def test_dataset_feature_subset(ad_data):
    sub = ad_data.subset_features([0, 2, 4])
    assert sub.num_features == 3
    assert sub.feature_names == [ad_data.feature_names[i] for i in (0, 2, 4)]
