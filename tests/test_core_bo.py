"""Optimization core: design space, RF surrogate, constrained BO (paper §3.2)."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bo import ConstrainedBO, expected_improvement
from repro.core.designspace import DesignSpace, Param, algorithm_space
from repro.core.surrogate import RandomForest

HSET = settings(max_examples=20, deadline=None)


# ------------------------------------------------------------ design space


@given(seed=st.integers(0, 2**31))
@HSET
def test_samples_respect_bounds_and_encode_to_unit(seed):
    space = algorithm_space("dnn", n_features=7, num_classes=2)
    rng = np.random.default_rng(seed)
    cfg = space.sample(rng)
    assert 1 <= cfg["n_layers"] <= 10
    assert 3e-4 <= cfg["lr"] <= 3e-2
    x = space.encode(cfg)
    assert x.shape == (len(space.params),)
    assert np.all(x >= -1e-6) and np.all(x <= 1 + 1e-6)


@given(seed=st.integers(0, 2**31))
@HSET
def test_log_param_sampling(seed):
    p = Param("lr", "real", 1e-4, 1e-1, log=True)
    rng = np.random.default_rng(seed)
    v = p.sample(rng)
    assert 1e-4 <= v <= 1e-1
    assert 0.0 <= p.encode(v) <= 1.0


def test_space_size_estimate_positive():
    space = algorithm_space("dnn", n_features=7, num_classes=2)
    assert space.size_estimate() > 5  # >10^5 configurations


# --------------------------------------------------------------- surrogate


def test_rf_fits_deterministic_function():
    rng = np.random.default_rng(0)
    X = rng.random((300, 3)).astype(np.float32)
    y = np.sin(4 * X[:, 0]) + X[:, 1] ** 2
    rf = RandomForest(n_trees=16, seed=1).fit(X, y)
    mu, sigma = rf.predict(X[:50])
    assert np.mean(np.abs(mu - y[:50])) < 0.25
    assert np.all(sigma >= 0)


def test_rf_uncertainty_nonzero_where_data_noisy():
    """Ensemble std is positive (EI needs it) and grows with target noise."""
    rng = np.random.default_rng(0)
    X = rng.random((300, 2)).astype(np.float32)
    y_clean = X[:, 0]
    y_noisy = X[:, 0] + rng.normal(0, 0.5, 300)
    s_clean = RandomForest(n_trees=24, seed=2).fit(X, y_clean).predict(X[:50])[1]
    s_noisy = RandomForest(n_trees=24, seed=2).fit(X, y_noisy).predict(X[:50])[1]
    assert np.all(s_clean > 0) and np.all(s_noisy > 0)
    assert s_noisy.mean() > s_clean.mean()


def test_rf_proba_bounds():
    rng = np.random.default_rng(3)
    X = rng.random((100, 2)).astype(np.float32)
    y = (X[:, 0] > 0.5).astype(np.float64)
    clf = RandomForest(n_trees=8, seed=0).fit(X, y)
    p = clf.predict_proba(X)
    assert np.all(p >= 0) and np.all(p <= 1)


# --------------------------------------------------------------------- EI


def test_expected_improvement_properties():
    mu = np.array([0.0, 1.0, 2.0])
    sigma = np.array([1.0, 1.0, 1.0])
    ei = expected_improvement(mu, sigma, best=1.0)
    assert np.all(ei >= 0)
    assert ei[2] > ei[1] > ei[0]
    # zero uncertainty at the incumbent -> ~zero EI
    ei0 = expected_improvement(np.array([1.0]), np.array([1e-9]), best=1.0)
    assert ei0[0] == pytest.approx(0.0, abs=1e-6)


# ------------------------------------------------------------ constrained BO


def _toy_problem(cfg):
    """Max at x=0.7,y=0.2 but feasible only when x+y<0.8."""
    x, y = cfg["x"], cfg["y"]
    value = -((x - 0.7) ** 2) - (y - 0.2) ** 2
    feasible = (x + y) < 0.8
    return value, feasible, {}


def test_bo_finds_feasible_optimum():
    """The optimum (0.7, 0.2) is infeasible (x+y>=0.8); the constrained
    optimum -0.005 sits ON the boundary.  BO must stay feasible and beat
    random search's expected best (~ -0.2 at this budget)."""
    space = DesignSpace([
        Param("x", "real", 0.0, 1.0), Param("y", "real", 0.0, 1.0),
    ])
    bo = ConstrainedBO(space, n_init=8, seed=0)
    best = bo.run(_toy_problem, budget=60)
    assert best is not None
    assert best.config["x"] + best.config["y"] < 0.8
    assert best.value > -0.12


def test_bo_regret_curve_monotone_and_matches_history():
    space = DesignSpace([Param("x", "real", 0.0, 1.0)])
    bo = ConstrainedBO(space, n_init=4, seed=1)
    bo.run(lambda c: (-(c["x"] - 0.3) ** 2, c["x"] < 0.9, {}), budget=15)
    curve = bo.regret_curve()
    assert len(curve) == 15
    assert all(b >= a for a, b in zip(curve, curve[1:]))
    assert curve[-1] == bo.best.value


def test_bo_infeasible_points_excluded_from_best():
    space = DesignSpace([Param("x", "real", 0.0, 1.0)])
    bo = ConstrainedBO(space, n_init=4, seed=2)
    # big values are infeasible — best must come from the feasible region
    bo.run(lambda c: (c["x"], c["x"] < 0.5, {}), budget=20)
    assert bo.best is not None
    assert bo.best.config["x"] < 0.5
    n_feas = sum(1 for o in bo.history if o.feasible)
    assert 0 < n_feas < len(bo.history) or n_feas == len(bo.history)


def test_bo_all_infeasible_returns_none():
    space = DesignSpace([Param("x", "real", 0.0, 1.0)])
    bo = ConstrainedBO(space, n_init=3, seed=3)
    best = bo.run(lambda c: (float("nan"), False, {}), budget=6)
    assert best is None
