"""Documentation integrity (tier-1): links resolve, indexes are complete.

The CI docs job runs tools/check_markdown_links.py standalone and
smoke-runs examples/quickstart.py; these tests keep the same guarantees
enforced locally by the tier-1 suite.
"""

import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))

import check_markdown_links as cml  # noqa: E402


def test_intra_repo_markdown_links_resolve():
    errors = cml.check_tree(REPO)
    assert not errors, "broken markdown links:\n" + "\n".join(errors)


def test_top_level_readme_exists_with_verify_command():
    readme = open(os.path.join(REPO, "README.md"), encoding="utf-8").read()
    # the tier-1 verify command from ROADMAP.md, verbatim
    assert "python -m pytest -x -q" in readme
    assert "examples/quickstart.py" in readme


def test_examples_readme_covers_every_example():
    ex_dir = os.path.join(REPO, "examples")
    readme = open(os.path.join(ex_dir, "README.md"), encoding="utf-8").read()
    examples = sorted(f for f in os.listdir(ex_dir) if f.endswith(".py"))
    assert len(examples) >= 7
    missing = [f for f in examples if f not in readme]
    assert not missing, f"examples missing from examples/README.md: {missing}"


def test_pallas_contract_documented_and_linked():
    doc = open(os.path.join(REPO, "docs", "pipeline_ir.md"),
               encoding="utf-8").read()
    assert "## Pallas lowering contract" in doc
    roadmap = open(os.path.join(REPO, "ROADMAP.md"), encoding="utf-8").read()
    assert "#pallas-lowering-contract" in roadmap


def test_github_slugs():
    assert cml.github_slug("Pallas lowering contract") \
        == "pallas-lowering-contract"
    assert cml.github_slug("DSE batching contract") == "dse-batching-contract"
    assert cml.github_slug("`code` & Links [x](y)") == "code--links-x"


def test_stray_ci_duplicate_removed():
    # tests/ci.yml was an unused copy of .github/workflows/ci.yml
    assert not os.path.exists(os.path.join(REPO, "tests", "ci.yml"))
    assert os.path.exists(
        os.path.join(REPO, ".github", "workflows", "ci.yml")
    )
