"""Documentation integrity (tier-1): links resolve, indexes are complete.

The CI docs job runs tools/check_markdown_links.py standalone and
smoke-runs examples/quickstart.py; these tests keep the same guarantees
enforced locally by the tier-1 suite.
"""

import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))

import check_markdown_links as cml  # noqa: E402


def test_intra_repo_markdown_links_resolve():
    errors = cml.check_tree(REPO)
    assert not errors, "broken markdown links:\n" + "\n".join(errors)


def test_top_level_readme_exists_with_verify_command():
    readme = open(os.path.join(REPO, "README.md"), encoding="utf-8").read()
    # the tier-1 verify command from ROADMAP.md, verbatim
    assert "python -m pytest -x -q" in readme
    assert "examples/quickstart.py" in readme


def test_examples_readme_covers_every_example():
    ex_dir = os.path.join(REPO, "examples")
    readme = open(os.path.join(ex_dir, "README.md"), encoding="utf-8").read()
    examples = sorted(f for f in os.listdir(ex_dir) if f.endswith(".py"))
    assert len(examples) >= 7
    missing = [f for f in examples if f not in readme]
    assert not missing, f"examples missing from examples/README.md: {missing}"


def test_pallas_contract_documented_and_linked():
    doc = open(os.path.join(REPO, "docs", "pipeline_ir.md"),
               encoding="utf-8").read()
    assert "## Pallas lowering contract" in doc
    roadmap = open(os.path.join(REPO, "ROADMAP.md"), encoding="utf-8").read()
    assert "#pallas-lowering-contract" in roadmap


def test_github_slugs():
    assert cml.github_slug("Pallas lowering contract") \
        == "pallas-lowering-contract"
    assert cml.github_slug("DSE batching contract") == "dse-batching-contract"
    assert cml.github_slug("`code` & Links [x](y)") == "code--links-x"


def test_stray_ci_duplicate_removed():
    # tests/ci.yml was an unused copy of .github/workflows/ci.yml
    assert not os.path.exists(os.path.join(REPO, "tests", "ci.yml"))
    assert os.path.exists(
        os.path.join(REPO, ".github", "workflows", "ci.yml")
    )

def test_telemetry_contract_documented_and_linked():
    doc = open(os.path.join(REPO, "docs", "pipeline_ir.md"),
               encoding="utf-8").read()
    assert "## Telemetry contract" in doc
    # the budget and the bit-identity rule are the contract's teeth
    assert "telemetry_overhead" in doc
    assert "bit-identical" in doc.split("## Telemetry contract")[1]
    roadmap = open(os.path.join(REPO, "ROADMAP.md"), encoding="utf-8").read()
    assert "#telemetry-contract" in roadmap
    readme = open(os.path.join(REPO, "README.md"), encoding="utf-8").read()
    assert "#telemetry-contract" in readme
    assert "Observability" in readme


# ---- link-checker features the telemetry docs rely on (unit-tested on
# ---- tmp trees so regressions fail loudly, not as silently-passing scans)


def test_checker_flags_broken_anchor_and_file(tmp_path):
    (tmp_path / "a.md").write_text(
        "# One\n[ok](#one)\n[bad](#nope)\n[gone](missing.md)\n")
    errors = cml.check_tree(str(tmp_path))
    assert len(errors) == 2
    assert any("missing anchor -> #nope" in e for e in errors)
    assert any("broken link -> missing.md" in e for e in errors)


def test_checker_handles_duplicate_heading_suffixes(tmp_path):
    (tmp_path / "a.md").write_text(
        "# Setup\n## Setup\n[first](#setup)\n[second](#setup-1)\n"
        "[third](#setup-2)\n")
    errors = cml.check_tree(str(tmp_path))
    assert len(errors) == 1 and "#setup-2" in errors[0]


def test_checker_accepts_html_anchors_and_ref_defs(tmp_path):
    (tmp_path / "a.md").write_text(
        '<a id="pinned"></a>\n# Doc\n[x](#pinned)\n[ref][1]\n\n'
        "[1]: b.md#part-two\n")
    (tmp_path / "b.md").write_text("# Part One\n# Part Two\n")
    assert cml.check_tree(str(tmp_path)) == []
    # a reference-style def pointing nowhere is still an error
    (tmp_path / "a.md").write_text("[ref][1]\n\n[1]: c.md\n")
    errors = cml.check_tree(str(tmp_path))
    assert len(errors) == 1 and "c.md" in errors[0]


def test_checker_ignores_fenced_code_blocks(tmp_path):
    (tmp_path / "a.md").write_text(
        "# Doc\n```md\n[not a link](nowhere.md)\n```\n")
    assert cml.check_tree(str(tmp_path)) == []
