"""In-pipeline mitigation: the action-table contract end to end.

Pins docs/pipeline_ir.md#mitigation-contract: the state BEFORE a packet
decides its fate (so no packet is ever both dropped and verdicted, and
the threshold-tripping packet is itself verdicted), drop/rate-limit
cadences against python oracles, arrival-order batch-scan semantics with
evict-on-collision, bit-identical action tables across execution engines
(interpret vs Pallas detection path), across serving engines (plain vs
sharded, depth > 1 overlap included), and across a hot swap installed
while flows are actively rate-limited.  Also the reaction_report
``mitigation_lag`` fields — the latent-bug fix: the SLO gate measures
when the data plane STOPS a flow, not when it first flags it."""

import numpy as np
import pytest

from hypothesis import given, settings, strategies as st
from repro.core import pallas_backend, stageir
from repro.data import traffic
from repro.flowstate import (
    MITIGATED,
    MitigatedFlowState,
    MitigationSpec,
    StatefulPipeline,
    init_mitigation,
    migrate_mitigation,
    mitigate_update,
)
from repro.flowstate.registers import FlowStateSpec, hash_slot_np
from repro.serve.packet_engine import PacketServeEngine
from repro.serve.sharded import ShardedPacketServeEngine

HSET = settings(max_examples=8, deadline=None)

needs_pallas = pytest.mark.skipif(
    not pallas_backend.pallas_available(),
    reason="Pallas toolchain unavailable in this environment",
)


def _spec(n_slots=64, **kw):
    return MitigationSpec(n_slots=n_slots, **kw)


def _flow_stages(n_slots=64):
    spec = FlowStateSpec(n_slots=n_slots, n_counters=1, n_ewma=1,
                         hist_sizes=(4,), ewma_alpha=0.25)
    fk = stageir.FlowKey((0,), n_slots)
    ru = stageir.RegisterUpdate(
        spec, ewma_cols=(1,), hist_cols=(1,),
        hist_edges=(np.linspace(0, 1, 5)[1:-1],),
    )
    ws = stageir.WindowStats(spec, mode="all")
    return [fk, ru, ws], ws.n_out


def _always_attack_suffix(n_feat):
    """Classifier that says 1 for every packet (oracle-friendly)."""
    w = np.zeros((n_feat, 2), np.float32)
    b = np.asarray([0.0, 1.0], np.float32)
    return [stageir.FusedMLP([w], [b]), stageir.Reduce("argmax")]


def _pipeline(mit_spec, backend="interpret", n_slots=64):
    stages, n_feat = _flow_stages(n_slots)
    stages += _always_attack_suffix(n_feat)
    if mit_spec is not None:
        stages.append(stageir.Mitigate(mit_spec))
    return StatefulPipeline(stages, backend=backend)


def _packets(rng, n, n_keys=6):
    X = np.zeros((n, 2), np.float32)
    X[:, 0] = rng.integers(1, 1 + n_keys, n)
    X[:, 1] = rng.random(n)
    return X


def _serve(eng, X, batch):
    got = [eng.flush() or None for _ in ()]  # noqa: keep list literal simple
    out = []
    for s in range(0, len(X), batch):
        eng.submit(X[s:s + batch])
        out.append(eng.flush())
    return np.concatenate(out) if out else np.zeros(0, np.int32)


# ------------------------------------------------------------ stage IR


def test_mitigate_must_be_last_and_single():
    mit = stageir.Mitigate(_spec())
    stages, n_feat = _flow_stages()
    with pytest.raises(ValueError, match="LAST"):
        stageir.split_mitigation(stages + [mit] + _always_attack_suffix(n_feat))
    with pytest.raises(ValueError, match="single"):
        stageir.split_mitigation(
            stages + _always_attack_suffix(n_feat) + [mit, mit])
    rest, got = stageir.split_mitigation(
        stages + _always_attack_suffix(n_feat) + [mit])
    assert got is mit and len(rest) == 5


def test_mitigate_meta_matches_specs():
    mit = stageir.Mitigate(_spec(n_slots=128))
    (ss,) = stageir.mitigation_specs(mit.spec)
    assert mit.meta()["params"] == ss.params == 128 * (2 + 1)
    assert mit.stateful
    with pytest.raises(TypeError, match="StatefulPipeline"):
        mit.apply(np.zeros((4, 2), np.float32))


def test_mitigated_sentinel_pinned_everywhere():
    # traffic.py stays jax-free by mirroring the sentinel; pin the mirror
    assert traffic._MITIGATED == MITIGATED == -1


def test_spec_validation():
    with pytest.raises(ValueError, match="power of two"):
        _spec(n_slots=48)
    with pytest.raises(KeyError, match="mode"):
        _spec(mode="shape")
    with pytest.raises(ValueError, match="threshold"):
        _spec(threshold=0)
    with pytest.raises(ValueError, match="keep_every"):
        _spec(mode="rate_limit", keep_every=1)


# ----------------------------------------------------- update semantics


def _oracle(spec, pkt_keys, verdicts, valid):
    """Pure-python reference for mitigate_update (arrival order)."""
    keys = np.full(spec.n_slots, -1, np.int64)
    regs = np.zeros((spec.n_slots, 2))
    out = np.array(verdicts, np.int64)
    for p, (k, v, ok) in enumerate(zip(pkt_keys, verdicts, valid)):
        if not ok:
            continue
        s = int(hash_slot_np(np.asarray([k]), spec.n_slots)[0])
        if keys[s] != k:          # evict-on-collision, fresh row
            keys[s] = k
            regs[s] = 0.0
        hits, since = regs[s]
        marked = hits >= spec.threshold
        if spec.mode == "drop":
            drop = marked
        else:
            drop = marked and (int(since) % spec.keep_every != 0)
        if drop:
            out[p] = MITIGATED
        regs[s, 0] = hits + (v == spec.attack_class)
        regs[s, 1] = since + 1 if marked else 0.0
    return keys, regs, out


@HSET
@given(seed=st.integers(0, 999), mode=st.sampled_from(("drop", "rate_limit")),
       n_slots=st.sampled_from((2, 4, 16)))
def test_mitigate_update_matches_oracle(seed, mode, n_slots):
    """Small tables force eviction chains; the jnp scan must match the
    python arrival-order oracle bit for bit, padding included."""
    rng = np.random.default_rng(seed)
    n = 96
    spec = _spec(n_slots=n_slots, mode=mode, threshold=3, keep_every=3)
    pkt_keys = rng.integers(1, 9, n).astype(np.int32)
    verdicts = rng.integers(0, 2, n).astype(np.int32)
    valid = (rng.random(n) < 0.9).astype(np.int32)
    mk, mr = init_mitigation(spec)
    mk, mr, out = mitigate_update(mk, mr, pkt_keys, verdicts, valid,
                                  spec=spec)
    ok_keys, ok_regs, ok_out = _oracle(spec, pkt_keys, verdicts, valid)
    np.testing.assert_array_equal(np.asarray(mk), ok_keys)
    np.testing.assert_array_equal(np.asarray(mr), ok_regs.astype(np.float32))
    np.testing.assert_array_equal(np.asarray(out), ok_out)
    # padding keeps the classifier verdict and never touches the table
    np.testing.assert_array_equal(np.asarray(out)[valid == 0],
                                  verdicts[valid == 0])


def test_threshold_packet_is_verdicted_not_dropped():
    """The state BEFORE a packet decides its fate: with threshold t, the
    first t packets of an attack flow are verdicted, packet t+1 is the
    first drop — mitigation lag is exactly 1 + (t - 1) - 0 >= 1."""
    spec = _spec(mode="drop", threshold=3)
    keys = np.full(10, 7, np.int32)
    v = np.ones(10, np.int32)
    mk, mr = init_mitigation(spec)
    _, _, out = mitigate_update(mk, mr, keys, v, np.ones(10, np.int32),
                                spec=spec)
    np.testing.assert_array_equal(np.asarray(out),
                                  [1, 1, 1, -1, -1, -1, -1, -1, -1, -1])


def test_rate_limit_cadence():
    """After marking, every keep_every-th packet passes (since resets at
    the mark, so the FIRST post-threshold packet passes)."""
    spec = _spec(mode="rate_limit", threshold=2, keep_every=4)
    keys = np.full(14, 5, np.int32)
    v = np.ones(14, np.int32)
    mk, mr = init_mitigation(spec)
    _, _, out = mitigate_update(mk, mr, keys, v, np.ones(14, np.int32),
                                spec=spec)
    np.testing.assert_array_equal(
        np.asarray(out), [1, 1, 1, -1, -1, -1, 1, -1, -1, -1, 1, -1, -1, -1])


def test_no_packet_both_dropped_and_verdicted():
    rng = np.random.default_rng(0)
    spec = _spec(n_slots=8, mode="rate_limit", threshold=2, keep_every=2)
    pkt_keys = rng.integers(1, 30, 256).astype(np.int32)
    v = np.ones(256, np.int32)
    mk, mr = init_mitigation(spec)
    _, _, out = mitigate_update(mk, mr, pkt_keys, v, np.ones(256, np.int32),
                                spec=spec)
    out = np.asarray(out)
    assert set(np.unique(out)) <= {MITIGATED, 1}
    assert (out == MITIGATED).sum() > 0


def test_migrate_mitigation_rekeys():
    spec = _spec(n_slots=8)
    big = _spec(n_slots=32)
    mk, mr = init_mitigation(spec)
    keys = np.asarray([3, 11, 19], np.int32)
    mk, mr, _ = mitigate_update(mk, mr, keys,
                                np.ones(3, np.int32), np.ones(3, np.int32),
                                spec=spec)
    nk, nr = migrate_mitigation(mk, mr, spec, big)
    nk, nr = np.asarray(nk), np.asarray(nr)
    assert nk.shape == (32,) and nr.shape == (32, 2)
    for k in keys:
        s_old = int(hash_slot_np(np.asarray([k]), 8)[0])
        if np.asarray(mk)[s_old] != k:
            continue                      # evicted in the small table
        s_new = int(hash_slot_np(np.asarray([k]), 32)[0])
        assert nk[s_new] == k
        np.testing.assert_array_equal(nr[s_new], np.asarray(mr)[s_old])


# ------------------------------------------------------- pipeline parity


@needs_pallas
@pytest.mark.parametrize("mode", ["drop", "rate_limit"])
def test_interpret_pallas_parity(mode):
    rng = np.random.default_rng(3)
    X = _packets(rng, 400, n_keys=12)
    spec = _spec(n_slots=16, mode=mode, threshold=4, keep_every=3)
    out = {}
    for b in ("interpret", "pallas"):
        pipe = _pipeline(spec, backend=b, n_slots=32)
        assert pipe.n_state_arrays == 4
        eng = PacketServeEngine(pipe, feature_dim=2, max_batch=64)
        v = _serve(eng, X, 64)
        out[b] = (v, eng.state)
    assert out["pallas"][1].mitigated_flows > 0
    np.testing.assert_array_equal(out["interpret"][0], out["pallas"][0])
    for f in ("keys", "regs", "mit_keys", "mit_regs"):
        np.testing.assert_array_equal(
            np.asarray(getattr(out["interpret"][1], f)),
            np.asarray(getattr(out["pallas"][1], f)),
            err_msg=f"{f} diverged between execution engines")


def test_backend_reported_honestly():
    spec = _spec()
    assert _pipeline(spec, backend="interpret").backend == "interpret"
    assert _pipeline(None, backend="interpret").backend == "interpret"
    if pallas_backend.pallas_available():
        # the action table folds into the fused launch: a mitigated
        # pipeline serves detection + classify + mitigate as ONE kernel
        assert _pipeline(spec, backend="pallas").backend == \
            "pallas-fused-flow"
        assert _pipeline(None, backend="pallas").backend == \
            "pallas-fused-flow"


# ------------------------------------------------------- serving engines


@pytest.mark.parametrize("depth", [1, 3])
def test_engines_bit_identical_registers(depth):
    """Plain vs sharded (forced 1-shard) engines, overlap depth > 1
    included: same verdict stream, same final action table."""
    rng = np.random.default_rng(11)
    X = _packets(rng, 600, n_keys=20)
    spec = _spec(n_slots=32, mode="drop", threshold=3)

    pipe = _pipeline(spec, n_slots=64)
    plain = PacketServeEngine(pipe, feature_dim=2, max_batch=64, depth=depth)
    v_plain = _serve(plain, X, 64)

    pipe = _pipeline(spec, n_slots=64)
    shard = ShardedPacketServeEngine(pipe, feature_dim=2, max_batch=64,
                                     depth=depth, min_shards=1)
    assert shard.sharded and shard.n_shards == 1
    v_shard = _serve(shard, X, 64)

    np.testing.assert_array_equal(v_plain, v_shard)
    assert isinstance(plain.state, MitigatedFlowState)
    np.testing.assert_array_equal(np.asarray(plain.state.mit_keys),
                                  np.asarray(shard.state.mit_keys)[0])
    np.testing.assert_array_equal(np.asarray(plain.state.mit_regs),
                                  np.asarray(shard.state.mit_regs)[0])
    assert plain.state.mitigated_flows == shard.state.mitigated_flows > 0


@HSET
@given(data=st.data())
def test_hot_swap_during_mitigation(data):
    """Swap while flows are actively rate-limited: exactly one swap, no
    packet lost or duplicated, the action table carries (marked flows
    stay marked), and no packet is both dropped and verdicted."""
    rng = np.random.default_rng(data.draw(st.integers(0, 500)))
    X = _packets(rng, 300, n_keys=4)
    spec = _spec(n_slots=16, mode="rate_limit",
                 threshold=data.draw(st.integers(1, 4)), keep_every=3)
    depth = data.draw(st.integers(1, 3))
    batch = data.draw(st.sampled_from((32, 64)))
    swap_at = data.draw(st.integers(1, max(1, len(X) // batch - 1)))

    eng = PacketServeEngine(_pipeline(spec), feature_dim=2,
                            max_batch=batch, depth=depth)
    out = []
    for i, s in enumerate(range(0, len(X), batch)):
        if i == swap_at:
            marked_before = int(eng.state.mitigated_flows)
            eng.swap(_pipeline(spec))
        eng.submit(X[s:s + batch])
        out.append(eng.flush())
    v = np.concatenate(out)
    assert len(v) == len(X)
    assert eng.stats()["swaps"] == 1
    assert set(np.unique(v)) <= {MITIGATED, 1}
    # the carried action table never un-marks a flow
    assert int(eng.state.mitigated_flows) >= marked_before
    # same traffic served without a swap gives the same verdict stream —
    # the swap was invisible to mitigation (bit-identical carry)
    ref = PacketServeEngine(_pipeline(spec), feature_dim=2,
                            max_batch=batch, depth=depth)
    np.testing.assert_array_equal(v, _serve(ref, X, batch))


def test_swap_can_drop_and_add_mitigation():
    rng = np.random.default_rng(5)
    X = _packets(rng, 200, n_keys=3)
    spec = _spec(n_slots=16, threshold=2)
    eng = PacketServeEngine(_pipeline(spec), feature_dim=2, max_batch=50)
    _serve(eng, X, 50)
    assert eng.state.mitigated_flows > 0
    eng.swap(_pipeline(None))          # mitigation removed: table dropped
    eng.submit(X[:50]); v = eng.flush()
    assert not isinstance(eng.state, MitigatedFlowState)
    assert MITIGATED not in v
    eng.swap(_pipeline(spec))          # re-added: fresh empty table
    eng.submit(X[:50]); eng.flush()
    assert isinstance(eng.state, MitigatedFlowState)


# -------------------------------------------------- reaction-report fix


def test_reaction_report_mitigation_lag():
    """Regression for the latent bug: reaction_pkts counts the first
    DETECTED packet; the new fields measure the first MITIGATED one."""
    packets = np.zeros((8, 4), np.float32)
    packets[:, traffic.COL_FLOW] = 9
    stream = traffic.PacketStream(
        "synthetic", packets, np.ones(8, np.int32),
        np.full(8, 9, np.int32), {9: 1},
        times=np.arange(8, dtype=np.float64))
    #            detect here v        v first drop, lag = 3
    verdicts = np.asarray([0, 1, 1, 1, -1, -1, 1, -1])
    r = traffic.reaction_report(stream, verdicts)
    assert r["reaction_pkts_median"] == 2.0        # 1-based first detect
    assert r["mitigated_flows"] == 1
    assert r["mitigation_lag_median"] == 3.0       # first drop - detect
    assert r["leaked_pkts_total"] == 1             # the verdicted pkt 6
    assert r["benign_mitigated_flow_rate"] == 0.0


def test_reaction_report_sentinels_without_mitigation():
    s = traffic.make_stream("benign", n_packets=2_000, seed=0)
    r = traffic.reaction_report(s, np.zeros(s.n_packets, np.int64))
    for k in ("mitigated_flows", "mitigation_lag_median",
              "mitigation_lag_p95", "leaked_pkts_total",
              "benign_mitigated_flow_rate"):
        assert r[k] == 0


# ----------------------------------------------------------- feasibility


def test_mitigation_feasibility_charges_sram():
    from repro.core import feasibility

    spec = _spec(n_slots=256)
    for platform in ("taurus", "tofino", "fpga"):
        rep = feasibility.mitigation_report(spec, platform)
        assert rep.feasible, rep.reasons
    rep = feasibility.mitigation_report(spec, "taurus")
    assert rep.resources["register_words"] == 256 * (2 + 1)
    # the harness-sized table fits switch SRAM but honestly exceeds the
    # Taurus MU budget; a 2^20-slot table overflows Tofino register SRAM
    big = _spec(n_slots=4096)
    assert feasibility.mitigation_report(big, "tofino").feasible
    assert not feasibility.mitigation_report(big, "taurus").feasible
    huge = _spec(n_slots=1 << 20)
    assert not feasibility.mitigation_report(huge, "tofino").feasible
