"""Shared fixtures. NOTE: no XLA_FLAGS here by design — smoke tests and
benches must see 1 CPU device; only launch/dryrun.py forces 512."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def ad_data():
    from repro.data import netdata

    return netdata.make_ad_dataset(features=7, n_train=2048, n_test=1024)


@pytest.fixture(scope="session")
def tc_data():
    from repro.data import netdata

    return netdata.make_tc_dataset(n_train=2048, n_test=1024)
