"""Alchemy DSL + end-to-end generate() (paper §3.1, Fig. 3) + fusion/chaining."""

import numpy as np
import pytest

import homunculus
from homunculus.alchemy import DataLoader, IOMap, Model, Par, Platforms, Seq
from repro.core import chaining, fusion
from repro.data import netdata


@DataLoader
def tiny_ad_loader():
    d = netdata.make_ad_dataset(features=7, n_train=1024, n_test=512)
    return d


@DataLoader
def paper_dict_loader():
    """The paper's Figure-3 dict form."""
    d = netdata.make_ad_dataset(features=7, n_train=256, n_test=128)
    return {
        "data": {"train": d.train_x, "test": d.test_x},
        "labels": {"train": d.train_y, "test": d.test_y},
    }


def _model(name="ad", algos=None):
    return Model({
        "optimization_metric": ["f1"],
        "algorithm": algos,
        "name": name,
        "data_loader": tiny_ad_loader,
    })


# ------------------------------------------------------------------- DSL


def test_dataloader_normalizes_paper_dict_form():
    d = paper_dict_loader()
    assert d.num_features == 7
    assert d.num_classes == 2
    assert len(d.train_x) == 256


def test_composition_operators():
    from repro.core.alchemy import NATURAL_CHAINS_OK

    a, b, c = _model("a"), _model("b"), _model("c")
    # natural chaining works where the interpreter supports the
    # chained-comparison interception (CPython); parenthesized composition
    # builds the same DAG everywhere
    seq = (a > b > c) if NATURAL_CHAINS_OK else ((a > b) > c)
    assert isinstance(seq, Seq) and len(seq.children) == 3
    assert seq.describe() == "a > b > c"
    assert ((a > b) > c).describe() == seq.describe()
    par = a | b
    assert isinstance(par, Par)
    mixed = a > (b | c)
    assert mixed.describe() == "a > (b | c)"
    assert [m.name for m in mixed.leaves()] == ["a", "b", "c"]


def test_platform_schedule_and_constrain():
    p = Platforms.Taurus()
    p.constrain(performance={"throughput": 1, "latency": 500},
                resources={"rows": 16, "cols": 16})
    m = _model()
    p.schedule(m)
    assert p.scheduled is m


# -------------------------------------------------------------- generate()


@pytest.fixture(scope="module")
def gen_result():
    m = _model("anomaly_detection", algos=["dnn"])
    p = Platforms.Taurus()
    p.constrain(performance={"throughput": 1, "latency": 500},
                resources={"rows": 16, "cols": 16})
    p.schedule(m)
    return homunculus.generate(p, budget=16, n_init=6, seed=0), p, m


def test_generate_end_to_end(gen_result):
    res, p, m = gen_result
    r = res["anomaly_detection"]
    assert r.value > 0.6                      # learned something real
    assert r.report.feasible
    assert r.report.resources["cu"] <= 256
    assert r.pipeline.verify(m.data().test_x) == 0.0
    assert p.generated is res


def test_generate_regret_curve_monotone(gen_result):
    res, _, _ = gen_result
    curve = res["anomaly_detection"].regret
    assert all(b >= a for a, b in zip(curve, curve[1:]))


def test_algorithm_pruning_on_tofino():
    """DNN must be pre-pruned on a MAT switch (unsupported), kmeans kept."""
    from repro.core.dse import _prune_algorithms

    p = Platforms.Tofino()
    p.constrain(resources={"tables": 12})
    d = tiny_ad_loader()
    kept, dropped = _prune_algorithms(p, ["dnn", "kmeans", "svm"], d)
    assert "dnn" not in kept and "dnn" in dropped
    assert "kmeans" in kept and "svm" in kept


def test_generate_infeasible_platform_raises():
    m = _model("impossible", algos=["dnn"])
    p = Platforms.Taurus()
    p.constrain(resources={"rows": 1, "cols": 1})  # 1 CU total
    p.schedule(m)
    with pytest.raises(RuntimeError):
        homunculus.generate(p, budget=4, n_init=2, seed=0)


# ----------------------------------------------------------------- chaining


def test_chained_copies_share_resources(gen_result):
    """Paper Table 3: resources constant across chaining strategies."""
    res, p, m = gen_result
    strategies = {
        "seq4": ((m > m) > m) > m,
        "par4": m | m | m | m,
        "mixed": (m > (m | m)) > m,
    }
    rows = chaining.strategy_table(strategies, res)
    cus = {r["strategy"]: r["cu"] for r in rows}
    assert cus["seq4"] == cus["par4"] == cus["mixed"]
    single = res["anomaly_detection"].report.resources["cu"]
    assert cus["seq4"] == single


def test_run_dag_or_semantics(gen_result):
    res, _, m = gen_result
    X = m.data().test_x[:64]
    single = res["anomaly_detection"].pipeline(X)
    both = chaining.run_dag(m | m, res, X)
    np.testing.assert_array_equal(single, both)  # same model OR'd = same


# ------------------------------------------------------------------- fusion


def test_fusion_feature_overlap_metric():
    d = tiny_ad_loader()
    a, b = d.split_half()
    assert fusion.feature_overlap(a, b) == 1.0
    assert fusion.should_fuse(a, b)
    c = d.subset_features([0, 1, 2])
    assert fusion.feature_overlap(d, c) == pytest.approx(3 / 7)
    assert not fusion.should_fuse(d, c)


def test_fusion_halves_resources_and_keeps_f1():
    """Paper Table 4: fused model ~ one split model's resources, both tasks
    served."""
    d = netdata.make_ad_dataset(features=7, n_train=2048, n_test=1024)
    part1, part2 = d.split_half()
    fused = fusion.fuse([part1, part2], hidden=[24, 16], epochs=6)
    # resource accounting: fused topology vs 2x separate topologies
    from repro.core.feasibility import TaurusModel, topology_params

    tm = TaurusModel()
    fused_cu = tm.estimate("dnn", fused.fused_topology())["options"][0]["cu"]
    sep = tm.estimate("dnn", {"widths": [7, 24, 16, 2], "act": "relu"})
    sep_cu = 2 * sep["options"][0]["cu"]
    assert fused_cu < 0.7 * sep_cu
    assert fused.f1(0) > 0.6 and fused.f1(1) > 0.6
    # the two heads learned the SAME task here, so F1s should be close
    assert abs(fused.f1(0) - fused.f1(1)) < 0.1


def test_iomap_passthrough():
    io = IOMap(lambda feats, up: feats)
    x = np.ones((4, 7), np.float32)
    np.testing.assert_array_equal(io(x, None), x)
