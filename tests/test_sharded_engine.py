"""ShardedPacketServeEngine: routing, degradation, parity, stream edges.

One-device hosts exercise the full shard_map serving step by forcing
``min_shards=1`` (a 1-ary mesh is still a mesh); the true multi-device
behavior is pinned by a subprocess test that forces 4 host CPU devices
(slow).  The routing helpers are pure functions tested directly."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import stageir
from repro.flowstate import FlowStateSpec, StatefulPipeline
from repro.serve import (
    PacketServeEngine,
    ShardedFlowState,
    ShardedPacketServeEngine,
)
from repro.serve.sharded import route_prefix, shard_of_key


def _flow_pipeline(backend="interpret"):
    spec = FlowStateSpec(n_slots=32, n_counters=1, n_ewma=1,
                         hist_sizes=(3,), ewma_alpha=0.5)
    fk = stageir.FlowKey((0,), spec.n_slots)
    ru = stageir.RegisterUpdate(
        spec, ewma_cols=(1,), hist_cols=(1,),
        hist_edges=(np.linspace(0, 1, 4)[1:-1],),
    )
    ws = stageir.WindowStats(spec, mode="all")
    return StatefulPipeline([fk, ru, ws], backend=backend)


def _flow_packets(rng, n, n_flows=12):
    X = np.zeros((n, 2), np.float32)
    X[:, 0] = rng.integers(0, n_flows, n)
    X[:, 1] = rng.random(n)
    return X


# -------------------------------------------------------- routing helpers


def test_flow_key_numpy_twin_matches_traceable(rng):
    fk = stageir.FlowKey((0, 2), 64)
    X = np.zeros((500, 3), np.float32)
    X[:, 0] = rng.integers(0, 1 << 20, 500)
    X[:, 2] = rng.integers(0, 70000, 500)
    np.testing.assert_array_equal(
        fk.apply_keys_np(X), np.asarray(fk.apply_keys(X))
    )


def test_shard_of_key_range_and_determinism(rng):
    keys = rng.integers(0, 1 << 31, 2000).astype(np.int32)
    for d in (1, 2, 3, 8):
        ids = shard_of_key(keys, d)
        assert ids.min() >= 0 and ids.max() < d
        np.testing.assert_array_equal(ids, shard_of_key(keys, d))


def test_route_prefix_respects_capacity_and_order():
    ids = np.array([0, 1, 0, 0, 1, 0])
    m, perm = route_prefix(ids, 2, capacity=2)
    # row 3 is shard 0's third packet: it and everything after must wait
    assert m == 3
    np.testing.assert_array_equal(perm[0], [0, 2])
    np.testing.assert_array_equal(perm[1], [1])
    m_all, perm_all = route_prefix(np.array([0, 1, 1, 0]), 2, capacity=2)
    assert m_all == 4
    np.testing.assert_array_equal(perm_all[1], [1, 2])


# ------------------------------------------------- degradation + parity


def test_degrades_to_base_engine_on_one_device(ad_pipe, ad_data):
    eng = ShardedPacketServeEngine(ad_pipe, feature_dim=7, max_batch=64)
    assert not eng.sharded                   # one-device host
    base = PacketServeEngine(ad_pipe, feature_dim=7, max_batch=64)
    eng.submit(ad_data.test_x[:200])
    base.submit(ad_data.test_x[:200])
    np.testing.assert_array_equal(base.flush(), eng.flush())
    assert eng.stats()["shards"] == 1


def test_degrades_for_bare_callables():
    eng = ShardedPacketServeEngine(
        lambda x: x[:, 0].astype(np.int32), feature_dim=2, max_batch=8,
        min_shards=1,
    )
    assert not eng.sharded                   # nothing to trace


def test_sharded_stateless_parity_one_shard(ad_pipe, ad_data):
    eng = ShardedPacketServeEngine(ad_pipe, feature_dim=7, max_batch=64,
                                   backend="pallas", min_shards=1)
    assert eng.sharded and eng.n_shards == 1
    base = PacketServeEngine(ad_pipe, feature_dim=7, max_batch=64,
                             backend="pallas")
    eng.submit(ad_data.test_x[:333])
    base.submit(ad_data.test_x[:333])
    np.testing.assert_array_equal(base.flush(), eng.flush())


def test_sharded_stateful_parity_one_shard(rng):
    X = _flow_packets(rng, 220)
    base = PacketServeEngine(_flow_pipeline(), feature_dim=2, max_batch=16)
    eng = ShardedPacketServeEngine(_flow_pipeline(), feature_dim=2,
                                   max_batch=16, min_shards=1)
    assert eng.sharded
    base.submit(X)
    eng.submit(X)
    np.testing.assert_array_equal(base.flush(), eng.flush())
    # with one shard the stacked table must equal the single table exactly
    assert isinstance(eng.state, ShardedFlowState)
    np.testing.assert_array_equal(np.asarray(base.state.keys),
                                  np.asarray(eng.state.keys)[0])
    np.testing.assert_array_equal(np.asarray(base.state.regs),
                                  np.asarray(eng.state.regs)[0])
    assert eng.state.occupied == base.state.occupied


# ------------------------------------------------- stream edge behavior


def test_sharded_serve_stream_tail_and_empty_flush(rng):
    eng = ShardedPacketServeEngine(_flow_pipeline(), feature_dim=2,
                                   max_batch=16, min_shards=1)
    # empty flush on a fresh engine: empty verdicts, nothing in flight
    out = eng.flush()
    assert out.shape == (0,) and eng.pending == 0 and eng.in_flight == 0

    X = _flow_packets(rng, 37)               # ragged tail (37 % 16 != 0)
    got = list(eng.serve_stream(iter([X[:5], X[5:20], X[20:]])))
    assert sum(len(g) for g in got) == 37
    ref = PacketServeEngine(_flow_pipeline(), feature_dim=2, max_batch=16)
    ref.submit(X)
    np.testing.assert_array_equal(np.concatenate(got), ref.flush())
    # the tail was flushed: nothing pending, nothing in flight, and a
    # second flush is empty
    assert eng.pending == 0 and eng.in_flight == 0
    assert len(eng.flush()) == 0


def test_sharded_stream_empty_input():
    eng = ShardedPacketServeEngine(_flow_pipeline(), feature_dim=2,
                                   max_batch=16, min_shards=1)
    assert list(eng.serve_stream(iter([]))) == []


# ------------------------------------------- overflow push-back + hot swap


def test_dispatch_routed_pushes_overflow_back(rng):
    """Rows beyond a shard's per-dispatch capacity are requeued at the
    FRONT (arrival order preserved), not dropped: a direct
    ``_dispatch_routed`` of more rows than ``max_batch`` dispatches
    exactly the capacity prefix and a flush serves the rest."""
    eng = ShardedPacketServeEngine(_flow_pipeline(), feature_dim=2,
                                   max_batch=16, min_shards=1)
    assert eng.sharded and eng._sub_batch == 16
    X = _flow_packets(rng, 30)
    m = eng._dispatch_routed(X)
    assert m == 16                     # capacity prefix only
    assert eng.pending == 14           # overflow requeued, not dropped
    out = eng.flush()
    assert len(out) == 30
    ref = PacketServeEngine(_flow_pipeline(), feature_dim=2, max_batch=16)
    ref.submit(X)
    np.testing.assert_array_equal(out, ref.flush())


def test_swap_works_on_degraded_engine():
    """min_shards unreachable on a one-device host -> base-engine path;
    the hot swap must keep working there (it is the base swap)."""
    eng = ShardedPacketServeEngine(
        lambda x: x[:, 0].astype(np.int32), feature_dim=2, max_batch=8,
        min_shards=2,
    )
    assert not eng.sharded
    X = np.zeros((6, 2), np.float32)
    X[:, 0] = np.arange(6)
    eng.submit(X)
    np.testing.assert_array_equal(eng.flush(), np.arange(6))
    eng.swap(lambda x: x[:, 0].astype(np.int32) + 100)
    eng.submit(X)
    np.testing.assert_array_equal(eng.flush(), np.arange(6) + 100)
    assert eng.stats()["swaps"] == 1


def test_sharded_swap_rejects_untraceable_pipeline(ad_pipe):
    eng = ShardedPacketServeEngine(ad_pipe, feature_dim=7, max_batch=64,
                                   min_shards=1)
    assert eng.sharded
    with pytest.raises(ValueError, match="untraceable"):
        eng.swap(lambda x: x[:, 0].astype(np.int32))


def test_sharded_swap_rejects_key_cols_change(rng):
    eng = ShardedPacketServeEngine(_flow_pipeline(), feature_dim=2,
                                   max_batch=16, min_shards=1)
    assert eng.sharded
    spec = FlowStateSpec(n_slots=32, n_counters=1, n_ewma=1,
                         hist_sizes=(3,), ewma_alpha=0.5)
    rekeyed = StatefulPipeline([
        stageir.FlowKey((1,), spec.n_slots),
        stageir.RegisterUpdate(spec, ewma_cols=(1,), hist_cols=(1,),
                               hist_edges=(np.linspace(0, 1, 4)[1:-1],)),
        stageir.WindowStats(spec, mode="all"),
    ])
    with pytest.raises(ValueError, match="key_cols"):
        eng.swap(rekeyed)
    # the rejection is clean: the engine still serves on the old pipeline
    X = _flow_packets(rng, 20)
    eng.submit(X)
    assert len(eng.flush()) == 20 and eng.stats()["swaps"] == 0


# ------------------------------------------------------ real multi-device


@pytest.fixture(scope="module")
def ad_pipe():
    from repro.core import codegen, feasibility as feas, mlalgos
    from repro.data import netdata

    d = netdata.make_ad_dataset(features=7, n_train=1024, n_test=512)
    rep = feas.FeasibilityReport(True, [], {"cu": 1}, 1.0, 1e9)
    return codegen.taurus_codegen(
        "ad", mlalgos.train_dnn(d, hidden=[16, 8], epochs=2, seed=0), rep
    )


_MULTIDEV_SCRIPT = textwrap.dedent("""
    import numpy as np
    import jax
    assert len(jax.devices()) == 4, jax.devices()
    from repro.core import codegen, feasibility as feas, mlalgos, stageir
    from repro.data import netdata
    from repro.flowstate import FlowStateSpec, StatefulPipeline
    from repro.serve import PacketServeEngine, ShardedPacketServeEngine
    from repro.serve.sharded import shard_of_key

    d = netdata.make_ad_dataset(features=7, n_train=1024, n_test=2048)
    rep = feas.FeasibilityReport(True, [], {"cu": 1}, 1.0, 1e9)
    pipe = codegen.taurus_codegen(
        "ad", mlalgos.train_dnn(d, hidden=[16, 8], epochs=2, seed=0), rep)

    base = PacketServeEngine(pipe, feature_dim=7, max_batch=64,
                             backend="pallas")
    sh = ShardedPacketServeEngine(pipe, feature_dim=7, max_batch=64,
                                  backend="pallas", depth=3)
    assert sh.sharded and sh.n_shards == 4 and sh.stats()["shards"] == 4
    base.submit(d.test_x[:777]); sh.submit(d.test_x[:777])
    np.testing.assert_array_equal(base.flush(), sh.flush())

    def flow_pipe():
        spec = FlowStateSpec(n_slots=16, n_counters=1, n_ewma=1,
                             hist_sizes=(3,), ewma_alpha=0.5)
        fk = stageir.FlowKey((0,), spec.n_slots)
        ru = stageir.RegisterUpdate(
            spec, ewma_cols=(1,), hist_cols=(1,),
            hist_edges=(np.linspace(0, 1, 4)[1:-1],))
        return StatefulPipeline(
            [fk, ru, stageir.WindowStats(spec, mode="all")])

    rng = np.random.default_rng(1)
    X = np.zeros((300, 2), np.float32)
    X[:, 0] = rng.integers(0, 40, 300)
    X[:, 1] = rng.random(300)
    es = ShardedPacketServeEngine(flow_pipe(), feature_dim=2, max_batch=16)
    es.submit(X)
    vs = es.flush()
    # reference: each shard is its own single-table engine fed its rows
    fk = stageir.FlowKey((0,), 16)
    ids = shard_of_key(fk.apply_keys_np(X), 4)
    ref = np.empty_like(vs)
    for s in range(4):
        e = PacketServeEngine(flow_pipe(), feature_dim=2, max_batch=16)
        e.submit(X[ids == s])
        ref[ids == s] = e.flush()
    np.testing.assert_array_equal(vs, ref)
    print("MULTIDEV-OK")
""")


@pytest.mark.slow
def test_multi_device_parity_subprocess():
    """Force 4 host CPU devices in a subprocess: stateless split parity
    and stateful key-partitioned parity vs per-shard references."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p
    )
    proc = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT],
        capture_output=True, text=True, timeout=600, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "MULTIDEV-OK" in proc.stdout
