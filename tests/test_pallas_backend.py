"""Pallas serving backend: lowering, fallback, engine reporting (tier-1).

The slow property suite (test_stageir_conformance.py) sweeps randomly
configured models; these are the fast deterministic checks: the mat_lut
kernel against its oracle, backend selection/fallback through
``compile_stages`` / ``compile_dag`` / ``PacketServeEngine``, and the
``ServeStats.pkt_per_s`` zero-division guard.
"""

import numpy as np
import pytest

from repro.core import chaining, codegen, feasibility as feas, mlalgos
from repro.core import pallas_backend, stageir
from repro.core.alchemy import Model
from repro.kernels.mat_lut import mat_classify, mat_pipeline_ref
from repro.serve.packet_engine import PacketServeEngine, ServeStats

needs_pallas = pytest.mark.skipif(
    not pallas_backend.pallas_available(),
    reason="Pallas toolchain unavailable in this environment",
)


@pytest.fixture(scope="module")
def pipes(ad_data):
    rep = feas.FeasibilityReport(True, [], {"cu": 1}, 1.0, 1e9)
    dnn = mlalgos.train_dnn(ad_data, hidden=[16, 8], epochs=2, seed=0)
    km = mlalgos.train_kmeans(ad_data, k=4, seed=0)
    svm = mlalgos.train_svm(ad_data, epochs=3, seed=0)
    return {
        "dnn": codegen.taurus_codegen("dnn", dnn, rep),
        "km": codegen.taurus_codegen("km", km, rep),
        "svm": codegen.taurus_codegen("svm", svm, rep),
    }


def _leaf(name):
    return Model({"name": name, "data_loader": lambda: None,
                  "algorithm": None})


# --------------------------------------------------------- mat_lut kernel


@needs_pallas
@pytest.mark.parametrize("use_min", [False, True])
def test_mat_lut_kernel_matches_oracle(rng, use_min):
    F, BINS, C, K, B = 5, 64, 4, 4, 300
    x = rng.normal(size=(B, F)).astype(np.float32)
    lo, hi = x.min(0) - 1e-3, x.max(0) + 1e-3
    edges = np.stack([
        np.linspace(lo[f], hi[f], BINS + 1)[1:-1] for f in range(F)
    ]).astype(np.float32)
    tables = rng.normal(size=(F, BINS, C)).astype(np.float32)
    lmap = rng.integers(0, 3, size=K).astype(np.int32)
    ref = np.asarray(mat_pipeline_ref(x, edges, tables, lmap,
                                      use_min=use_min))
    ker = np.asarray(mat_classify(x, edges, tables, lmap, use_min=use_min))
    np.testing.assert_array_equal(ref, ker)


@needs_pallas
def test_mat_lut_kernel_exact_on_edge_values(rng):
    """Values exactly on a range-table edge bucket identically to
    searchsorted(side='left') — the compare-and-count construction."""
    F, BINS, C = 3, 32, 3
    edges = np.sort(rng.normal(size=(F, BINS - 1)), axis=1).astype(np.float32)
    tables = rng.normal(size=(F, BINS, C)).astype(np.float32)
    lmap = np.arange(C, dtype=np.int32)
    x = np.tile(edges[:, 10][None, :], (4, 1)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(mat_pipeline_ref(x, edges, tables, lmap)),
        np.asarray(mat_classify(x, edges, tables, lmap)),
    )


# ---------------------------------------------------- compile_stages wiring


@needs_pallas
def test_compile_stages_pallas_bit_exact_and_reported(pipes, ad_data):
    X = ad_data.test_x
    interp = stageir.compile_stages(pipes["dnn"].stages)
    pallas = stageir.compile_stages(pipes["dnn"].stages, backend="pallas")
    assert interp.backend == "interpret"
    assert pallas.backend == "pallas"
    assert pallas.requested_backend == "pallas"
    np.testing.assert_array_equal(np.asarray(interp(X)),
                                  np.asarray(pallas(X)))


@needs_pallas
def test_pallas_eligible_probe(pipes):
    # cheap shape-only probe agrees with what compile_stages actually does
    assert pallas_backend.pallas_eligible(pipes["dnn"].stages)
    assert not pallas_backend.pallas_eligible(pipes["km"].stages)


@needs_pallas
def test_compile_stages_pallas_falls_back_for_centroid(pipes, ad_data):
    # CentroidDistance is outside the kernel envelope: the request degrades
    # to the interpreter and says so
    pallas = stageir.compile_stages(pipes["km"].stages, backend="pallas")
    assert pallas.backend == "interpret"
    assert pallas.requested_backend == "pallas"
    interp = stageir.compile_stages(pipes["km"].stages)
    X = ad_data.test_x
    np.testing.assert_array_equal(np.asarray(interp(X)),
                                  np.asarray(pallas(X)))


def test_compile_stages_rejects_unknown_backend(pipes):
    with pytest.raises(KeyError):
        stageir.compile_stages(pipes["dnn"].stages, backend="cuda")


@needs_pallas
def test_compiled_dag_per_pipeline_backend(pipes, ad_data):
    node = _leaf("dnn") > _leaf("km")
    dag = chaining.compile_dag(node, pipes)
    dag_p = chaining.compile_dag(node, pipes, backend="pallas")
    # per-pipeline choice: the MLP lowers, the centroid pipeline falls back
    assert dag_p.model_backends == {"dnn": "pallas", "km": "interpret"}
    assert dag_p.backend == "mixed"
    X = ad_data.test_x[:512]
    np.testing.assert_array_equal(dag(X), dag_p(X))
    # with_backend round-trips (what the engine's backend= uses)
    assert dag_p.with_backend("interpret").backend == "interpret"


# ------------------------------------------------------- fused-DAG kernel


@needs_pallas
def test_fused_dag_megakernel_bit_exact_and_reported(pipes, ad_data):
    X = ad_data.test_x[:700]
    for node in (_leaf("dnn") > _leaf("svm"),
                 _leaf("dnn") | _leaf("svm"),
                 _leaf("dnn") > (_leaf("svm") | _leaf("dnn"))):
        dag = chaining.compile_dag(node, pipes, backend="pallas")
        assert dag.backend == "pallas-fused-dag"
        assert dag.fused_dag
        assert set(dag.model_backends.values()) == {"pallas-fused-dag"}
        ref = chaining.run_dag(node, pipes, X)
        np.testing.assert_array_equal(ref, dag(X))


@needs_pallas
def test_fused_dag_combine_and_is_exact(pipes, ad_data):
    node = _leaf("dnn") | _leaf("svm")
    dag = chaining.compile_dag(node, pipes, backend="pallas", combine="and")
    assert dag.backend == "pallas-fused-dag"
    ref = chaining.run_dag(node, pipes, ad_data.test_x, combine="and")
    np.testing.assert_array_equal(ref, dag(ad_data.test_x))


@needs_pallas
def test_fused_dag_honest_fallbacks(pipes, ad_data):
    X = ad_data.test_x[:256]
    # kmeans leaf -> megakernel ineligible -> per-model mix, still exact
    node = _leaf("dnn") > _leaf("km")
    dag = chaining.compile_dag(node, pipes, backend="pallas")
    assert dag.backend == "mixed"
    np.testing.assert_array_equal(chaining.run_dag(node, pipes, X), dag(X))
    # "concat" has no verdict merge: megakernel refuses, per-model serves
    par = _leaf("dnn") | _leaf("svm")
    dag_c = chaining.compile_dag(par, pipes, backend="pallas",
                                 combine="concat")
    assert dag_c.backend == "pallas"
    np.testing.assert_array_equal(
        chaining.run_dag(par, pipes, X, combine="concat"), dag_c(X))
    # fuse_dag=False is the per-model-launch baseline
    base = chaining.compile_dag(_leaf("dnn") > _leaf("svm"), pipes,
                                backend="pallas", fuse_dag=False)
    assert base.backend == "pallas"
    assert not base.fused_dag


@needs_pallas
def test_fused_dag_eligibility_probe(pipes):
    assert pallas_backend.dag_eligible(_leaf("dnn") > _leaf("svm"), pipes)
    assert not pallas_backend.dag_eligible(_leaf("dnn") > _leaf("km"), pipes)
    # a bare model is not a DAG: the single-model lowering owns that case
    assert not pallas_backend.dag_eligible(_leaf("dnn"), pipes)


@needs_pallas
def test_fused_dag_vmem_budget_gate(pipes, ad_data, monkeypatch):
    """A DAG whose aggregate weight stacks cannot be VMEM-resident must
    fall back to per-model launches, not claim a megakernel."""
    from repro.kernels import fused_mlp as fm

    node = _leaf("dnn") > _leaf("svm")
    monkeypatch.setattr(fm, "DAG_VMEM_BUDGET", 1)   # nothing fits
    assert not pallas_backend.dag_eligible(node, pipes)
    dag = chaining.compile_dag(node, pipes, backend="pallas")
    assert dag.backend == "pallas"                  # honest fallback
    X = ad_data.test_x[:200]
    np.testing.assert_array_equal(chaining.run_dag(node, pipes, X), dag(X))


@needs_pallas
def test_fused_dag_feature_select_fold(rng, ad_data):
    """A sorted-unique FeatureSelect prelude folds into the first layer
    bit-exactly; an unsorted one refuses (per-model fallback)."""
    from repro.core.codegen import Pipeline
    from repro.core.stageir import Dense, FeatureSelect, Reduce

    X = ad_data.test_x[:300]
    w_full = rng.normal(size=(7, 2)).astype(np.float32)
    b = np.zeros(2, np.float32)
    plain = [Dense(w_full, b), Reduce("argmax")]
    idx = np.array([1, 3, 6], np.int32)
    sel = [FeatureSelect(idx), Dense(w_full[idx], b), Reduce("argmax")]
    unsorted = [FeatureSelect(np.array([3, 1, 6], np.int32)),
                Dense(w_full[[3, 1, 6]], b), Reduce("argmax")]

    def pseudo(stages):
        class _P:                          # minimal Pipeline stand-in
            def __init__(self, s):
                self.stages = s

            def __call__(self, x):
                import jax.numpy as jnp

                return np.asarray(
                    stageir.apply_stages(self.stages,
                                         jnp.asarray(x, jnp.float32))
                )

        return _P(stages)

    pipes2 = {"a": pseudo(plain), "b": pseudo(sel), "c": pseudo(unsorted)}
    dag = chaining.compile_dag(_leaf("a") > _leaf("b"), pipes2,
                               backend="pallas")
    assert dag.backend == "pallas-fused-dag"
    ref = chaining.run_dag(_leaf("a") > _leaf("b"), pipes2, X)
    np.testing.assert_array_equal(ref, dag(X))
    dag_u = chaining.compile_dag(_leaf("a") > _leaf("c"), pipes2,
                                 backend="pallas")
    assert dag_u.backend == "pallas"       # fold refused, per-model serves
    np.testing.assert_array_equal(
        chaining.run_dag(_leaf("a") > _leaf("c"), pipes2, X), dag_u(X))


# ----------------------------------------------------------- packet engine


@needs_pallas
def test_engine_pallas_backend_serves_and_reports(pipes, ad_data):
    X = ad_data.test_x[:500]
    eng_i = PacketServeEngine(pipes["dnn"], feature_dim=7, max_batch=128)
    eng_p = PacketServeEngine(pipes["dnn"], feature_dim=7, max_batch=128,
                              backend="pallas")
    eng_i.submit(X)
    eng_p.submit(X)
    np.testing.assert_array_equal(eng_i.flush(), eng_p.flush())
    assert eng_i.stats()["backend"] == "interpret"
    assert eng_p.stats()["backend"] == "pallas"
    assert eng_p.stats()["backend_batches"] == {"pallas": 4}


def test_engine_falls_back_for_bare_callables():
    # a raw callable carries no stage list: the pallas request degrades to
    # serving it as-is and the stats report the interpreter
    eng = PacketServeEngine(
        lambda x: np.zeros(len(x), np.int32), feature_dim=7, max_batch=8,
        backend="pallas",
    )
    eng.submit(np.zeros((4, 7), np.float32))
    eng.flush()
    assert eng.stats()["backend"] == "interpret"


def test_engine_rejects_unknown_backend():
    with pytest.raises(KeyError):
        PacketServeEngine(
            lambda x: np.zeros(len(x), np.int32), feature_dim=3,
            max_batch=4, backend="cuda",
        )


def test_pkt_per_s_zero_before_first_batch():
    stats = ServeStats()
    assert stats.pkt_per_s == 0.0
    assert stats.as_dict()["pkt_per_s"] == 0.0
    eng = PacketServeEngine(
        lambda x: np.zeros(len(x), np.int32), feature_dim=3, max_batch=4
    )
    # warm-up call must not count as served traffic
    assert eng.stats()["pkt_per_s"] == 0.0
    assert eng.stats()["batches"] == 0
