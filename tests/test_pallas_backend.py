"""Pallas serving backend: lowering, fallback, engine reporting (tier-1).

The slow property suite (test_stageir_conformance.py) sweeps randomly
configured models; these are the fast deterministic checks: the mat_lut
kernel against its oracle, backend selection/fallback through
``compile_stages`` / ``compile_dag`` / ``PacketServeEngine``, and the
``ServeStats.pkt_per_s`` zero-division guard.
"""

import numpy as np
import pytest

from repro.core import chaining, codegen, feasibility as feas, mlalgos
from repro.core import pallas_backend, stageir
from repro.core.alchemy import Model
from repro.kernels.mat_lut import mat_classify, mat_pipeline_ref
from repro.serve.packet_engine import PacketServeEngine, ServeStats

needs_pallas = pytest.mark.skipif(
    not pallas_backend.pallas_available(),
    reason="Pallas toolchain unavailable in this environment",
)


@pytest.fixture(scope="module")
def pipes(ad_data):
    rep = feas.FeasibilityReport(True, [], {"cu": 1}, 1.0, 1e9)
    dnn = mlalgos.train_dnn(ad_data, hidden=[16, 8], epochs=2, seed=0)
    km = mlalgos.train_kmeans(ad_data, k=4, seed=0)
    return {
        "dnn": codegen.taurus_codegen("dnn", dnn, rep),
        "km": codegen.taurus_codegen("km", km, rep),
    }


def _leaf(name):
    return Model({"name": name, "data_loader": lambda: None,
                  "algorithm": None})


# --------------------------------------------------------- mat_lut kernel


@needs_pallas
@pytest.mark.parametrize("use_min", [False, True])
def test_mat_lut_kernel_matches_oracle(rng, use_min):
    F, BINS, C, K, B = 5, 64, 4, 4, 300
    x = rng.normal(size=(B, F)).astype(np.float32)
    lo, hi = x.min(0) - 1e-3, x.max(0) + 1e-3
    edges = np.stack([
        np.linspace(lo[f], hi[f], BINS + 1)[1:-1] for f in range(F)
    ]).astype(np.float32)
    tables = rng.normal(size=(F, BINS, C)).astype(np.float32)
    lmap = rng.integers(0, 3, size=K).astype(np.int32)
    ref = np.asarray(mat_pipeline_ref(x, edges, tables, lmap,
                                      use_min=use_min))
    ker = np.asarray(mat_classify(x, edges, tables, lmap, use_min=use_min))
    np.testing.assert_array_equal(ref, ker)


@needs_pallas
def test_mat_lut_kernel_exact_on_edge_values(rng):
    """Values exactly on a range-table edge bucket identically to
    searchsorted(side='left') — the compare-and-count construction."""
    F, BINS, C = 3, 32, 3
    edges = np.sort(rng.normal(size=(F, BINS - 1)), axis=1).astype(np.float32)
    tables = rng.normal(size=(F, BINS, C)).astype(np.float32)
    lmap = np.arange(C, dtype=np.int32)
    x = np.tile(edges[:, 10][None, :], (4, 1)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(mat_pipeline_ref(x, edges, tables, lmap)),
        np.asarray(mat_classify(x, edges, tables, lmap)),
    )


# ---------------------------------------------------- compile_stages wiring


@needs_pallas
def test_compile_stages_pallas_bit_exact_and_reported(pipes, ad_data):
    X = ad_data.test_x
    interp = stageir.compile_stages(pipes["dnn"].stages)
    pallas = stageir.compile_stages(pipes["dnn"].stages, backend="pallas")
    assert interp.backend == "interpret"
    assert pallas.backend == "pallas"
    assert pallas.requested_backend == "pallas"
    np.testing.assert_array_equal(np.asarray(interp(X)),
                                  np.asarray(pallas(X)))


@needs_pallas
def test_pallas_eligible_probe(pipes):
    # cheap shape-only probe agrees with what compile_stages actually does
    assert pallas_backend.pallas_eligible(pipes["dnn"].stages)
    assert not pallas_backend.pallas_eligible(pipes["km"].stages)


@needs_pallas
def test_compile_stages_pallas_falls_back_for_centroid(pipes, ad_data):
    # CentroidDistance is outside the kernel envelope: the request degrades
    # to the interpreter and says so
    pallas = stageir.compile_stages(pipes["km"].stages, backend="pallas")
    assert pallas.backend == "interpret"
    assert pallas.requested_backend == "pallas"
    interp = stageir.compile_stages(pipes["km"].stages)
    X = ad_data.test_x
    np.testing.assert_array_equal(np.asarray(interp(X)),
                                  np.asarray(pallas(X)))


def test_compile_stages_rejects_unknown_backend(pipes):
    with pytest.raises(KeyError):
        stageir.compile_stages(pipes["dnn"].stages, backend="cuda")


@needs_pallas
def test_compiled_dag_per_pipeline_backend(pipes, ad_data):
    node = _leaf("dnn") > _leaf("km")
    dag = chaining.compile_dag(node, pipes)
    dag_p = chaining.compile_dag(node, pipes, backend="pallas")
    # per-pipeline choice: the MLP lowers, the centroid pipeline falls back
    assert dag_p.model_backends == {"dnn": "pallas", "km": "interpret"}
    assert dag_p.backend == "mixed"
    X = ad_data.test_x[:512]
    np.testing.assert_array_equal(dag(X), dag_p(X))
    # with_backend round-trips (what the engine's backend= uses)
    assert dag_p.with_backend("interpret").backend == "interpret"


# ----------------------------------------------------------- packet engine


@needs_pallas
def test_engine_pallas_backend_serves_and_reports(pipes, ad_data):
    X = ad_data.test_x[:500]
    eng_i = PacketServeEngine(pipes["dnn"], feature_dim=7, max_batch=128)
    eng_p = PacketServeEngine(pipes["dnn"], feature_dim=7, max_batch=128,
                              backend="pallas")
    eng_i.submit(X)
    eng_p.submit(X)
    np.testing.assert_array_equal(eng_i.flush(), eng_p.flush())
    assert eng_i.stats()["backend"] == "interpret"
    assert eng_p.stats()["backend"] == "pallas"
    assert eng_p.stats()["backend_batches"] == {"pallas": 4}


def test_engine_falls_back_for_bare_callables():
    # a raw callable carries no stage list: the pallas request degrades to
    # serving it as-is and the stats report the interpreter
    eng = PacketServeEngine(
        lambda x: np.zeros(len(x), np.int32), feature_dim=7, max_batch=8,
        backend="pallas",
    )
    eng.submit(np.zeros((4, 7), np.float32))
    eng.flush()
    assert eng.stats()["backend"] == "interpret"


def test_engine_rejects_unknown_backend():
    with pytest.raises(KeyError):
        PacketServeEngine(
            lambda x: np.zeros(len(x), np.int32), feature_dim=3,
            max_batch=4, backend="cuda",
        )


def test_pkt_per_s_zero_before_first_batch():
    stats = ServeStats()
    assert stats.pkt_per_s == 0.0
    assert stats.as_dict()["pkt_per_s"] == 0.0
    eng = PacketServeEngine(
        lambda x: np.zeros(len(x), np.int32), feature_dim=3, max_batch=4
    )
    # warm-up call must not count as served traffic
    assert eng.stats()["pkt_per_s"] == 0.0
    assert eng.stats()["batches"] == 0
