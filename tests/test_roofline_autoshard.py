"""Roofline math + autoshard design space (fast units; the compile-in-loop
path is exercised by examples/autoshard_pod.py and the §Perf log)."""

import json
import os

import pytest

from repro.core.autoshard import layout_space
from repro.launch.roofline import Cell, render_markdown


def _cell(c, m, x, model_flops=1e15, hlo_total=2e15):
    cell = Cell("a", "s", "pod", True)
    cell.t_compute, cell.t_memory, cell.t_collective = c, m, x
    cell.model_flops = model_flops
    cell.hlo_flops_total = hlo_total
    cell.peak_bytes = 2**30
    return cell


def test_dominant_and_bound():
    c = _cell(1.0, 2.0, 3.0)
    assert c.dominant == "collective"
    assert c.t_bound == 3.0
    assert _cell(5.0, 2.0, 3.0).dominant == "compute"


def test_useful_ratio_and_fraction():
    c = _cell(2.0, 1.0, 1.0, model_flops=1e15, hlo_total=2e15)
    assert c.useful_ratio == pytest.approx(2.0)
    # t_model_compute = (1e15/2e15) * 2.0 = 1.0; bound = 2.0 -> frac 0.5
    assert c.roofline_fraction == pytest.approx(0.5)


def test_render_markdown_includes_failures():
    ok = _cell(1, 2, 3)
    bad = Cell("b", "s", "pod", False)
    bad.error = "boom"
    md = render_markdown([ok, bad])
    assert "FAILED" in md and "boom" in md
    assert "**collective**" in md


def test_layout_space_factorizations():
    space = layout_space(256)
    layouts = dict(zip(space.names, space.params))["layout"].values
    assert (16, 16) in layouts and (1, 256) in layouts and (256, 1) in layouts
    for dp, tp in layouts:
        assert dp * tp == 256


def test_autoshard_artifact_recorded():
    """The §Perf BO run left its evaluation log on disk with a feasible
    winner strictly better than the (16,16,micro=16) faithful baseline."""
    path = "benchmarks/results/autoshard_qwen3_train.json"
    if not os.path.exists(path):
        pytest.skip("autoshard artifact not generated in this environment")
    evals = json.load(open(path))
    feas = [e for e in evals if e["feasible"]]
    assert feas, "no feasible layout recorded"
    best = min(feas, key=lambda e: max(e["t"]))
    assert max(best["t"]) < 7.16  # beats the hand-tuned iteration-1 bound
    assert all(e["peak"] > 0 for e in evals)
