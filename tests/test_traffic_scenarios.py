"""Traffic-scenario suite: replayability, topology slicing, auto-labels.

The closed attack/defense loop is only as trustworthy as its traffic
generator, so these tests pin the suite's load-bearing properties: every
scenario is bit-replayable from its seed (the replay harness depends on
train-on-seed-A / replay-on-seed-B being deterministic), the topology
views partition the stream by whole flows and compose back to it, the
windowed stats aggregate exactly, and the heuristic ``auto_label`` rules
recover the generation-time ground truth with high agreement on EVERY
scenario — the analytic margins in its docstring, checked empirically."""

import numpy as np
import pytest

from hypothesis import given, settings, strategies as st
from repro.data import traffic

HSET = settings(max_examples=6, deadline=None)

NEW_SCENARIOS = ("syn_flood", "udp_flood", "icmp_flood", "slow_scan",
                 "coordinated_ddos")


def _stream(scenario, seed=0, n=12_000):
    return traffic.make_stream(scenario, n_packets=n, seed=seed)


# ---------------------------------------------------------- replayability


@pytest.mark.parametrize("scenario", traffic.SCENARIOS)
def test_seed_replayable_bit_identical(scenario):
    a = _stream(scenario, seed=7)
    b = _stream(scenario, seed=7)
    np.testing.assert_array_equal(a.packets, b.packets)
    np.testing.assert_array_equal(a.labels, b.labels)
    np.testing.assert_array_equal(a.times, b.times)
    assert a.flow_labels == b.flow_labels


@pytest.mark.parametrize("scenario", NEW_SCENARIOS)
def test_stream_invariants(scenario):
    s = _stream(scenario)
    assert s.n_packets > 0 and s.n_flows > 0
    # arrival-ordered, finite, schema-clean
    assert np.all(np.diff(s.times) >= 0)
    assert np.isfinite(s.packets).all()
    assert np.all(s.packets[:, traffic.COL_LEN] >= 40)
    assert np.all(s.packets[:, traffic.COL_LEN] <= 1500)
    assert np.all(s.packets[:, traffic.COL_IPT] >= 0)
    # flow ids exact in f32 and consistent with the int column
    np.testing.assert_array_equal(
        s.packets[:, traffic.COL_FLOW].astype(np.int64), s.flow_ids)
    # per-packet labels inherit the flow label
    for fid in list(s.flow_labels)[:50]:
        m = s.flow_ids == fid
        if m.any():
            assert np.all(s.labels[m] == s.flow_labels[fid])
    # attack scenarios really carry both classes
    assert set(np.unique(s.labels)) == {0, 1}


def test_different_seeds_differ():
    a, b = _stream("syn_flood", seed=0), _stream("syn_flood", seed=1)
    assert not np.array_equal(a.packets, b.packets)


@HSET
@given(start=st.integers(0, 9_000), size=st.integers(1, 3_000))
def test_slice_invariants(start, size):
    s = _stream("coordinated_ddos")
    w = s.slice(start, start + size)
    np.testing.assert_array_equal(w.packets, s.packets[start:start + size])
    np.testing.assert_array_equal(w.times, s.times[start:start + size])
    # flow_labels keep exactly the flows that appear in the window
    assert set(w.flow_labels) == set(int(f) for f in np.unique(w.flow_ids))
    for f, l in w.flow_labels.items():
        assert s.flow_labels[f] == l


# -------------------------------------------------------------- topology


@pytest.mark.parametrize("n_switches", [1, 3, 4])
def test_switch_streams_partition_and_compose(n_switches):
    s = _stream("udp_flood", seed=3)
    views = traffic.switch_streams(s, n_switches)
    assert len(views) == n_switches
    assert sum(v.n_packets for v in views) == s.n_packets
    # flows are pinned whole: no flow id appears on two switches
    seen = [set(np.unique(v.flow_ids)) for v in views]
    for i in range(n_switches):
        for j in range(i + 1, n_switches):
            assert not (seen[i] & seen[j])
        # each view is itself arrival-ordered
        assert np.all(np.diff(views[i].times) >= 0)
    back = traffic.compose_streams(views)
    assert back.scenario == s.scenario
    # parent flow_labels also list flows trimmed out of the packet budget;
    # the views (and hence the composition) only carry flows that appear
    present = set(int(f) for f in np.unique(s.flow_ids))
    assert back.flow_labels == {f: l for f, l in s.flow_labels.items()
                                if f in present}
    # identical packet multiset in identical per-flow order: compare under
    # a deterministic (time, flow) sort — same-flow timestamps are unique
    # (gaps clipped >= 1e-5) so this order is well defined on both sides
    o1 = np.lexsort((s.flow_ids, s.times))
    o2 = np.lexsort((back.flow_ids, back.times))
    np.testing.assert_array_equal(s.packets[o1], back.packets[o2])
    np.testing.assert_array_equal(s.labels[o1], back.labels[o2])


def test_compose_requires_times():
    s = _stream("benign")
    bare = traffic.PacketStream(s.scenario, s.packets, s.labels, s.flow_ids,
                                s.flow_labels, times=None)
    with pytest.raises(ValueError, match="timestamped"):
        traffic.compose_streams([bare])


def test_switch_of_flow_deterministic_and_balanced():
    fids = np.arange(4096, dtype=np.int64)
    a = traffic.switch_of_flow(fids, 4)
    np.testing.assert_array_equal(a, traffic.switch_of_flow(fids, 4))
    counts = np.bincount(a, minlength=4)
    assert counts.min() > 0.15 * len(fids)  # no switch starves


# ------------------------------------------------- windowed stats + labels


def test_windowed_flow_stats_exact():
    s = _stream("syn_flood", seed=2, n=6_000)
    stats = traffic.windowed_flow_stats(s, window_s=2.0)
    n_rows = len(stats["window"])
    assert n_rows > 0
    assert int(stats["pkt_count"].sum()) == s.n_packets
    # cross-check one (window, flow) cell against a direct recompute
    k = n_rows // 2
    w, f = int(stats["window"][k]), int(stats["flow_id"][k])
    win = np.floor((s.times - s.times[0]) / 2.0).astype(np.int64)
    m = (win == w) & (s.flow_ids == f)
    assert int(stats["pkt_count"][k]) == int(m.sum())
    np.testing.assert_allclose(
        stats["byte_count"][k], s.packets[m, traffic.COL_LEN].sum(),
        rtol=1e-6)
    np.testing.assert_allclose(
        stats["mean_ipt"][k], s.packets[m, traffic.COL_IPT].mean(),
        rtol=1e-5, atol=1e-9)


@pytest.mark.parametrize("scenario", traffic.SCENARIOS)
def test_auto_label_matches_ground_truth(scenario):
    s = _stream(scenario, n=30_000)
    labels = traffic.auto_label(traffic.windowed_flow_stats(s))
    atk = [f for f, l in s.flow_labels.items()
           if l == 1 and (s.flow_ids == f).any()]
    ben = [f for f, l in s.flow_labels.items()
           if l == 0 and (s.flow_ids == f).any()]
    assert set(labels) == set(atk) | set(ben)
    if atk:
        det = sum(labels[f] for f in atk) / len(atk)
        assert det >= 0.9, f"{scenario}: auto-label detection {det:.3f}"
    fp = sum(labels[f] for f in ben) / len(ben)
    assert fp <= 0.02, f"{scenario}: auto-label benign FP {fp:.3f}"


def test_flood_scenarios_are_scenarios():
    assert set(traffic.FLOOD_SCENARIOS) <= set(traffic.SCENARIOS)


# --------------------------------------------------- feature-dataset path


@pytest.mark.parametrize("scenario", NEW_SCENARIOS)
def test_stream_feature_dataset_on_new_scenarios(scenario):
    s = _stream(scenario, n=4_000)
    stages, names = traffic.flow_feature_stages(n_slots=256)
    ds, mu, sd = traffic.stream_feature_dataset(s, stages, names,
                                                sample_every=8)
    for x in (ds.train_x, ds.test_x, mu, sd):
        assert np.isfinite(x).all()
    assert len(ds.train_x) > 0 and len(ds.test_x) > 0
    assert ds.train_x.shape[1] == len(names)
    # non-degenerate: the standardized features are not constant — the
    # register file really saw per-flow structure, and both classes
    # survive the subsample
    assert float(ds.train_x.std()) > 0.1
    assert set(np.unique(np.concatenate([ds.train_y, ds.test_y]))) == {0, 1}
