"""Hot-swap contract (docs/pipeline_ir.md#hot-swap-contract), tier-1.

The core property: a ``swap`` injected between arbitrary ``submit`` calls
under the overlap engine (depth > 1) never drops or reorders verdicts —
the stream output equals old-model verdicts for every packet before the
recorded boundary and new-model verdicts after, for stateless AND
stateful pipelines, with the register file carried bit-identically
across a same-spec swap.  Plus: the changed-spec migration path
(``migrate_state``), the drift detector / online controller, and the
stats fields the swap adds."""

import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import stageir
from repro.flowstate import (
    DriftDetector,
    DriftSnapshot,
    FlowStateSpec,
    StatefulPipeline,
    init_state,
    migrate_state,
)
from repro.flowstate.registers import FlowState, hash_slot_np
from repro.serve import HotSwapController, PacketServeEngine

HSET = settings(max_examples=10, deadline=None)

OLD_TAG = 0
NEW_TAG = 1_000_000


def _tagged(n, start=0):
    out = np.zeros((n, 2), np.float32)
    out[:, 0] = np.arange(start, start + n)
    return out


def _spec(n_slots=16, n_counters=1, n_ewma=1, hist=(3,)):
    return FlowStateSpec(n_slots=n_slots, n_counters=n_counters,
                         n_ewma=n_ewma, hist_sizes=hist, ewma_alpha=0.5)


def _flow_stages(spec, extra_counter=False):
    fk = stageir.FlowKey((0,), spec.n_slots)
    ru = stageir.RegisterUpdate(
        spec, counter_cols=(1,) if extra_counter else (),
        ewma_cols=(1,), hist_cols=(1,),
        hist_edges=(np.linspace(0, 1, 4)[1:-1],),
    )
    return [fk, ru, stageir.WindowStats(spec, mode="all")]


def _classifier_pipeline(spec, seed):
    """Flow prefix + a seed-dependent MLP: two pipelines with different
    seeds share the register file but emit different verdicts."""
    base = _flow_stages(spec)
    rng = np.random.default_rng(seed)
    n_in = base[2].n_out
    w1 = rng.normal(size=(n_in, 6)).astype(np.float32)
    w2 = rng.normal(size=(6, 2)).astype(np.float32)
    mlp = stageir.FusedMLP([w1, w2], [np.zeros(6, np.float32),
                                      np.zeros(2, np.float32)])
    return StatefulPipeline(base + [mlp, stageir.Reduce("argmax")])


def _flow_packets(rng, n):
    X = np.zeros((n, 2), np.float32)
    X[:, 0] = rng.integers(0, 6, n)
    X[:, 1] = rng.random(n)
    return X


# ----------------------------------------- swap ordering property (tentpole)


@given(data=st.data())
@HSET
def test_stateless_swap_never_drops_or_reorders_under_overlap(data):
    """Arbitrary submit/flush interleavings with ONE swap injected at an
    arbitrary point: output == old verdicts before the recorded boundary,
    new verdicts after, length preserved."""
    old = jax.jit(lambda x: x[:, 0].astype("int32") + OLD_TAG)
    new = jax.jit(lambda x: x[:, 0].astype("int32") + NEW_TAG)
    eng = PacketServeEngine(old, feature_dim=2,
                            max_batch=data.draw(st.integers(2, 17)),
                            depth=data.draw(st.integers(2, 4)))
    n_ops = data.draw(st.integers(1, 8))
    swap_at = data.draw(st.integers(0, n_ops - 1))
    total, got = 0, []
    for i in range(n_ops):
        if i == swap_at:
            eng.swap(new)
        n = data.draw(st.integers(1, 53))
        eng.submit(_tagged(n, start=total))
        total += n
        if data.draw(st.booleans()):
            got.append(eng.flush())
    got.append(eng.flush())
    verdicts = np.concatenate([g for g in got if len(g)])

    assert len(verdicts) == total, "a batch was dropped across the swap"
    assert eng.stats_.swaps == 1
    off = eng.stats_.swap_pkt_offsets[0]
    np.testing.assert_array_equal(verdicts[:off],
                                  np.arange(off) + OLD_TAG)
    np.testing.assert_array_equal(verdicts[off:],
                                  np.arange(off, total) + NEW_TAG)
    # per-backend batch counts account for every dispatched batch
    assert sum(eng.stats_.backend_counts.values()) == eng.stats_.batches


@given(data=st.data())
@HSET
def test_stateful_swap_preserves_order_and_carries_state(data):
    """Same property on the stateful path: verdicts split exactly at the
    boundary between the two classifiers, and the register file equals a
    reference run that switches pipelines at the same packet — i.e. the
    table carried over bit-identically (same spec)."""
    spec = _spec()
    p_old = _classifier_pipeline(spec, seed=7)
    p_new = _classifier_pipeline(spec, seed=11)

    rng = np.random.default_rng(data.draw(st.integers(0, 1000)))
    X = _flow_packets(rng, data.draw(st.integers(10, 120)))
    eng = PacketServeEngine(p_old, feature_dim=2,
                            max_batch=data.draw(st.integers(2, 19)),
                            depth=data.draw(st.integers(2, 4)))
    n_ops = data.draw(st.integers(1, 6))
    swap_at = data.draw(st.integers(0, n_ops - 1))
    got, pos = [], 0
    for i in range(n_ops):
        if i == swap_at:
            eng.swap(p_new)
        n = min(data.draw(st.integers(1, 31)), len(X) - pos)
        if n:
            eng.submit(X[pos:pos + n])
            pos += n
        if data.draw(st.booleans()):
            got.append(eng.flush())
    got.append(eng.flush())
    verdicts = np.concatenate([g for g in got if len(g)])

    assert len(verdicts) == pos
    assert eng.stats_.swaps == 1
    off = min(eng.stats_.swap_pkt_offsets[0], pos)

    # reference: one continuous register file, classifier switched at off
    ref_old = _classifier_pipeline(spec, seed=7)
    state = ref_old.init_state()
    ref = []
    if off:
        state, v = ref_old(state, X[:off])
        ref.append(v)
    if pos - off:
        ref_new = _classifier_pipeline(spec, seed=11)
        state, v = ref_new(state, X[off:pos])
        ref.append(v)
    np.testing.assert_array_equal(verdicts, np.concatenate(ref))
    np.testing.assert_array_equal(np.asarray(eng.state.keys),
                                  np.asarray(state.keys))
    np.testing.assert_array_equal(np.asarray(eng.state.regs),
                                  np.asarray(state.regs))


# ------------------------------------------------------------ swap API edges


def test_swap_rejects_statefulness_change():
    spec = _spec()
    stateless = jax.jit(lambda x: x[:, 0].astype("int32"))
    eng = PacketServeEngine(stateless, feature_dim=2, max_batch=8)
    with pytest.raises(ValueError, match="statefulness"):
        eng.swap(StatefulPipeline(_flow_stages(spec)))

    eng_sf = PacketServeEngine(StatefulPipeline(_flow_stages(spec)),
                               feature_dim=2, max_batch=8)
    with pytest.raises(ValueError, match="statefulness"):
        eng_sf.swap(stateless)


def test_swap_installs_on_flush_without_traffic():
    """A parked swap never outlives a flush: the drained ring is a
    boundary even when no further packets arrive."""
    old = jax.jit(lambda x: x[:, 0].astype("int32"))
    new = jax.jit(lambda x: x[:, 0].astype("int32") + 1)
    eng = PacketServeEngine(old, feature_dim=2, max_batch=8, depth=3)
    eng.submit(_tagged(20))
    eng.flush()
    eng.swap(new)
    assert eng.swap_pending
    out = eng.flush()                  # no pending traffic
    assert len(out) == 0
    assert not eng.swap_pending
    assert eng.stats_.swaps == 1
    assert eng.pipeline is new
    eng.submit(_tagged(4))
    np.testing.assert_array_equal(eng.flush(), np.arange(4) + 1)


def test_serve_stats_as_dict_json_round_trips_after_swap():
    """No numpy scalars / non-serializable values leak into the new
    swaps/latency fields (regression: json.dumps must succeed and parse
    back equal)."""
    spec = _spec()
    eng = PacketServeEngine(_classifier_pipeline(spec, 7), feature_dim=2,
                            max_batch=8, depth=2)
    rng = np.random.default_rng(0)
    eng.submit(_flow_packets(rng, 30))
    eng.flush()
    eng.swap(_classifier_pipeline(spec, 11))
    eng.submit(_flow_packets(rng, 30))
    eng.flush()
    d = eng.stats()
    blob = json.dumps(d)
    assert json.loads(blob) == d
    assert d["swaps"] == 1
    assert len(d["swap_lat_ms"]) == len(d["swap_pkt_offsets"]) == 1
    assert isinstance(d["swap_pkt_offsets"][0], int)
    assert sum(d["backend_batches"].values()) == d["batches"]


def test_swap_changed_spec_migrates_live_table():
    spec = _spec(n_slots=16)
    eng = PacketServeEngine(StatefulPipeline(_flow_stages(spec)),
                            feature_dim=2, max_batch=8)
    rng = np.random.default_rng(1)
    eng.submit(_flow_packets(rng, 40))
    eng.flush()
    before = eng.state
    spec2 = _spec(n_slots=64)
    eng.swap(StatefulPipeline(_flow_stages(spec2)))
    eng.flush()
    assert eng.state.spec == spec2
    expect = migrate_state(before, spec2)
    np.testing.assert_array_equal(np.asarray(eng.state.keys),
                                  np.asarray(expect.keys))
    np.testing.assert_array_equal(np.asarray(eng.state.regs),
                                  np.asarray(expect.regs))
    # serving continues on the migrated table
    eng.submit(_flow_packets(rng, 10))
    assert len(eng.flush()) == 10


# ------------------------------------------------------- migrate_state rules


def test_hash_slot_np_matches_kernel_reference(rng):
    from repro.kernels.flow_update.ref import hash_slot

    keys = rng.integers(0, 1 << 31, 500).astype(np.int32)
    for n_slots in (16, 64, 1024):
        np.testing.assert_array_equal(
            hash_slot_np(keys, n_slots),
            np.asarray(hash_slot(keys, n_slots)),
        )


def test_migrate_state_rekeys_and_carries_shared_sections():
    spec = _spec(n_slots=16, n_counters=1, n_ewma=1, hist=(3,))
    state = init_state(spec)
    keys = np.asarray(state.keys).copy()
    regs = np.asarray(state.regs).copy()
    # two occupied rows with distinct register patterns
    keys[3], keys[9] = 111, 222
    regs[3] = [5.0, 0.25, 1.0, 2.0, 3.0]      # count, ewma, hist[3]
    regs[9] = [7.0, 0.75, 4.0, 5.0, 6.0]
    state = FlowState(spec, jnp.asarray(keys), jnp.asarray(regs))

    # grow the table, add a counter column, shrink the histogram
    spec2 = FlowStateSpec(n_slots=64, n_counters=2, n_ewma=1,
                          hist_sizes=(2,), ewma_alpha=0.5)
    out = migrate_state(state, spec2)
    ok, orr = np.asarray(out.keys), np.asarray(out.regs)
    for key, old_row in ((111, regs[3]), (222, regs[9])):
        s = int(hash_slot_np(np.array([key]), spec2.n_slots)[0])
        assert ok[s] == key
        # counter 0 carried, new counter 1 zero, ewma at its new offset,
        # hist carried up to min(3, 2) bins, third bin dropped
        assert orr[s, 0] == old_row[0]
        assert orr[s, 1] == 0.0
        assert orr[s, 2] == old_row[1]
        np.testing.assert_array_equal(orr[s, 3:5], old_row[2:4])
    assert (ok >= 0).sum() == 2


def test_migrate_state_collision_is_last_writer_wins():
    spec = _spec(n_slots=16)
    # find two keys that collide in a 2-slot table (hash_slot & 1)
    keys_all = np.arange(1, 200, dtype=np.int32)
    slots = hash_slot_np(keys_all, 2)
    k0 = int(keys_all[slots == 0][0])
    k1 = int(keys_all[slots == 0][1])
    state = init_state(spec)
    keys = np.asarray(state.keys).copy()
    regs = np.asarray(state.regs).copy()
    s0 = int(hash_slot_np(np.array([k0]), spec.n_slots)[0])
    s1 = int(hash_slot_np(np.array([k1]), spec.n_slots)[0])
    if s0 == s1:                       # same 16-table slot: pick another k1
        k1 = int(keys_all[slots == 0][2])
        s1 = int(hash_slot_np(np.array([k1]), spec.n_slots)[0])
    assert s0 != s1
    keys[s0], keys[s1] = k0, k1
    regs[s0, 0], regs[s1, 0] = 10.0, 20.0
    spec_tiny = _spec(n_slots=2)
    out = migrate_state(FlowState(spec, jnp.asarray(keys),
                                  jnp.asarray(regs)), spec_tiny)
    ok = np.asarray(out.keys)
    # both map to new slot 0; the higher ORIGINAL slot index wrote last
    winner = k0 if s0 > s1 else k1
    expect_count = 10.0 if winner == k0 else 20.0
    assert ok[0] == winner
    assert np.asarray(out.regs)[0, 0] == expect_count
    assert (ok >= 0).sum() == 1


# -------------------------------------------------- drift detector / online


def test_drift_snapshot_degenerate_streams_never_nan():
    short = np.ones((3, 4), np.float32)
    snap = DriftSnapshot.from_packets(short, cols=(1, 2), window=100)
    assert not np.isnan(snap.mu).any() and (snap.sd > 0).all()
    empty = np.zeros((0, 4), np.float32)
    snap = DriftSnapshot.from_packets(empty, cols=(1,), window=10)
    assert not np.isnan(snap.mu).any() and (snap.sd > 0).all()


def test_drift_detector_needs_patience_and_rearms():
    base = np.zeros((400, 3), np.float32)
    snap = DriftSnapshot.from_packets(base, cols=(0, 1), window=100)
    det = DriftDetector(snap, alpha=1.0, threshold=0.5, patience=3)
    hot = np.full((100, 3), 50.0, np.float32)
    cold = np.zeros((100, 3), np.float32)
    # spikes shorter than patience never fire
    for w in (hot, hot, cold, hot, hot, cold):
        det.update(w)
    assert not det.fired
    for w in (hot, hot, hot):
        det.update(w)
    assert det.fired
    det.reset()
    assert not det.fired and det.score == 0.0 and det.windows == 0
    with pytest.raises(ValueError, match="alpha"):
        DriftDetector(snap, alpha=0.0)


def test_controller_fires_once_and_swaps():
    old = jax.jit(lambda x: x[:, 0].astype("int32"))
    new = jax.jit(lambda x: x[:, 0].astype("int32") + 1)
    eng = PacketServeEngine(old, feature_dim=3, max_batch=16, depth=2)
    snap = DriftSnapshot.from_packets(np.zeros((400, 3), np.float32),
                                      cols=(1,), window=100)
    det = DriftDetector(snap, alpha=1.0, threshold=0.5, patience=2)
    seen, release = [], threading.Event()

    def retrain(ws):
        seen.append(len(ws))
        # hold the episode open until the observe loop is done, so the
        # detector cannot re-arm and fire a second episode mid-loop
        release.wait(60)
        return new

    ctrl = HotSwapController(eng, det, retrain, buffer_windows=4)
    hot = np.full((100, 3), 9.0, np.float32)
    for _ in range(6):
        ctrl.observe(hot)
    release.set()
    assert ctrl.wait(60)
    eng.flush()
    assert ctrl.episodes == 1          # fired once, not once per window
    assert ctrl.swapped == 1 and not ctrl.errors
    assert eng.stats_.swaps == 1 and eng.pipeline is new
    assert seen == [2]                 # windows buffered when it fired
    assert not det.fired               # re-armed after the swap


def test_controller_captures_retrain_errors():
    eng = PacketServeEngine(jax.jit(lambda x: x[:, 0].astype("int32")),
                            feature_dim=3, max_batch=16)
    snap = DriftSnapshot.from_packets(np.zeros((200, 3), np.float32),
                                      cols=(1,), window=100)
    det = DriftDetector(snap, alpha=1.0, threshold=0.5, patience=1)

    def boom(_ws):
        raise RuntimeError("search exploded")

    ctrl = HotSwapController(eng, det, boom)
    ctrl.observe(np.full((50, 3), 9.0, np.float32))
    assert ctrl.wait(60)
    assert ctrl.episodes == 1 and ctrl.swapped == 0
    assert len(ctrl.errors) == 1
    assert eng.stats_.swaps == 0       # old model keeps serving
    blob = json.dumps(ctrl.report())
    assert "search exploded" in blob
